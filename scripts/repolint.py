#!/usr/bin/env python3
"""Run the repo-aware static analyzers (see docs/STATIC_ANALYSIS.md).

Thin wrapper so the linter works from a clean checkout without an
installed package: bootstraps ``src/`` onto ``sys.path`` and delegates to
``repro.analysis.cli``.
"""
import os
import sys

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                    "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

from repro.analysis.cli import main  # noqa: E402

if __name__ == "__main__":
    raise SystemExit(main())
