#!/usr/bin/env python3
"""Markdown link checker (stdlib-only; the CI docs job runs this).

Scans every tracked ``*.md`` file for inline links/images
(``[text](target)``) and verifies that relative targets resolve to real
files or directories. Remote (``http(s)://``, ``mailto:``) and pure-anchor
(``#...``) targets are only checked syntactically — CI must not depend on
network reachability.

Usage: python scripts/check_md_links.py [root]
Exits non-zero listing every broken link as ``file:line: target``.
"""
from __future__ import annotations

import os
import re
import sys

# [text](target) / ![alt](target); target ends at the first unescaped ')'
_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_INLINE_CODE = re.compile(r"`[^`]*`")
_SKIP_DIRS = {"__pycache__", ".ruff_cache", ".pytest_cache", "node_modules",
              "venv", "build", "dist", "site-packages"}


def iter_md_files(root: str):
    for dirpath, dirnames, filenames in os.walk(root):
        # skip hidden dirs (.git, .venv, ...) and vendored/third-party trees
        dirnames[:] = [d for d in dirnames
                       if d not in _SKIP_DIRS and not d.startswith(".")]
        for name in sorted(filenames):
            if name.endswith(".md"):
                yield os.path.join(dirpath, name)


def check_file(path: str, root: str):
    """Yield (line_no, target) for every broken relative link in one file."""
    in_fence = False
    with open(path, encoding="utf-8") as f:
        for line_no, line in enumerate(f, 1):
            if line.lstrip().startswith("```"):
                in_fence = not in_fence
            if in_fence:
                continue                      # code blocks aren't links
            line = _INLINE_CODE.sub("", line)  # nor are `inline code` spans
            for m in _LINK.finditer(line):
                target = m.group(1)
                if target.startswith(("http://", "https://", "mailto:", "#")):
                    continue
                rel = target.split("#", 1)[0]  # strip intra-doc anchors
                if not rel:
                    continue
                resolved = os.path.normpath(
                    os.path.join(os.path.dirname(path), rel))
                if not os.path.exists(resolved):
                    yield line_no, target


def main() -> int:
    root = sys.argv[1] if len(sys.argv) > 1 else "."
    broken = []
    n_files = 0
    for path in iter_md_files(root):
        n_files += 1
        for line_no, target in check_file(path, root):
            broken.append(f"{os.path.relpath(path, root)}:{line_no}: {target}")
    if broken:
        print(f"BROKEN LINKS ({len(broken)}):")
        print("\n".join(broken))
        return 1
    print(f"ok: {n_files} markdown files, all relative links resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
