#!/usr/bin/env python3
"""Markdown link + orphan-page checker (stdlib-only; the CI docs job runs this).

Scans every tracked ``*.md`` file for inline links/images
(``[text](target)``) and verifies that relative targets resolve to real
files or directories. Remote (``http(s)://``, ``mailto:``) and pure-anchor
(``#...``) targets are only checked syntactically — CI must not depend on
network reachability.

It also enforces reachability: every page under ``docs/`` must be reachable
from the top-level ``README.md`` by following relative markdown links
(transitively). A docs page nobody links to is a page nobody reads — it
fails CI as an orphan instead of silently rotting.

Usage: python scripts/check_md_links.py [root]
Exits non-zero listing every broken link as ``file:line: target`` and every
orphaned docs page.
"""
from __future__ import annotations

import os
import re
import sys

# [text](target) / ![alt](target); target ends at the first unescaped ')'
_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_INLINE_CODE = re.compile(r"`[^`]*`")
_SKIP_DIRS = {"__pycache__", ".ruff_cache", ".pytest_cache", "node_modules",
              "venv", "build", "dist", "site-packages"}


def iter_md_files(root: str):
    for dirpath, dirnames, filenames in os.walk(root):
        # skip hidden dirs (.git, .venv, ...) and vendored/third-party trees
        dirnames[:] = [d for d in dirnames
                       if d not in _SKIP_DIRS and not d.startswith(".")]
        for name in sorted(filenames):
            if name.endswith(".md"):
                yield os.path.join(dirpath, name)


def check_file(path: str, root: str, edges=None):
    """Yield (line_no, target) for every broken relative link in one file.

    When ``edges`` (a dict) is given, every markdown→markdown link that DOES
    resolve is recorded as ``edges[path].add(resolved)`` — the reachability
    graph the orphan check walks.
    """
    in_fence = False
    with open(path, encoding="utf-8") as f:
        for line_no, line in enumerate(f, 1):
            if line.lstrip().startswith("```"):
                in_fence = not in_fence
            if in_fence:
                continue                      # code blocks aren't links
            line = _INLINE_CODE.sub("", line)  # nor are `inline code` spans
            for m in _LINK.finditer(line):
                target = m.group(1)
                if target.startswith(("http://", "https://", "mailto:", "#")):
                    continue
                rel = target.split("#", 1)[0]  # strip intra-doc anchors
                if not rel:
                    continue
                resolved = os.path.normpath(
                    os.path.join(os.path.dirname(path), rel))
                if not os.path.exists(resolved):
                    yield line_no, target
                elif edges is not None and resolved.endswith(".md"):
                    edges.setdefault(os.path.normpath(path),
                                     set()).add(resolved)


def find_orphans(md_files, edges, root: str):
    """Docs pages not reachable from the top-level README via md links."""
    start = os.path.normpath(os.path.join(root, "README.md"))
    seen, frontier = {start}, [start]
    while frontier:
        for nxt in edges.get(frontier.pop(), ()):
            if nxt not in seen:
                seen.add(nxt)
                frontier.append(nxt)
    docs_dir = os.path.normpath(os.path.join(root, "docs"))
    return sorted(
        os.path.relpath(p, root) for p in md_files
        if os.path.normpath(p).startswith(docs_dir + os.sep)
        and os.path.normpath(p) not in seen)


def main() -> int:
    root = sys.argv[1] if len(sys.argv) > 1 else "."
    broken = []
    md_files = list(iter_md_files(root))
    edges = {}
    for path in md_files:
        for line_no, target in check_file(path, root, edges):
            broken.append(f"{os.path.relpath(path, root)}:{line_no}: {target}")
    orphans = find_orphans(md_files, edges, root)
    if broken:
        print(f"BROKEN LINKS ({len(broken)}):")
        print("\n".join(broken))
    if orphans:
        print(f"ORPHANED DOCS PAGES ({len(orphans)}) — not reachable from "
              "README.md; link them from the docs index:")
        print("\n".join(orphans))
    if broken or orphans:
        return 1
    print(f"ok: {len(md_files)} markdown files, all relative links resolve, "
          "no orphaned docs pages")
    return 0


if __name__ == "__main__":
    sys.exit(main())
