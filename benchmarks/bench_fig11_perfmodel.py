"""Fig 11: throughput-prediction model fit quality (NNLS over Eqns 1–6).

Samples (w, p, λ_w, λ_p) setups from a ground-truth job, fits α/β with NNLS,
and reports RMSLE + R² of predicted vs true throughput on held-out configs,
plus the fitted coefficients (paper: α_grad=3.48, α_upd=2.36, α_lookup=2.45,
α_sync=0.68, Σβ=2.45 — ratios are the comparable quantity here).
"""
from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import Row
from repro.core.perf_model import (
    JobResources, JobStatics, PerfModel, synthesize_t_iter,
)


def run(seed: int = 0) -> List[Row]:
    rows: List[Row] = []
    rng = np.random.default_rng(seed)
    # larger model (sync matters) and wide (w, p) ranges so every term of
    # Eqns 2-5 contributes identifiably, as in the paper's sampled setups
    stat = JobStatics(batch_size=512, model_size=6.4e9, bandwidth=1e9, emb_dim=16)
    alpha = [3.48e-3, 2.36e-3, 0.68e-3, 2.45e-5]
    beta = 2.45e-3

    def sample(n):
        out = []
        for _ in range(n):
            r = JobResources(w=int(rng.integers(1, 33)), p=int(rng.integers(1, 5)),
                             cpu_w=float(rng.integers(1, 33)),
                             cpu_p=float(rng.integers(1, 9)))
            t = synthesize_t_iter(r, stat, alpha, beta, noise=0.03, rng=rng)
            out.append((r, stat, t))
        return out

    train, test = sample(64), sample(32)
    model = PerfModel().fit(train)
    rows.append(("rmsle_train", model.rmsle(train), "paper: good fit"))
    rows.append(("rmsle_test", model.rmsle(test), ""))
    pred = np.array([model.throughput(r, s) for r, s, _ in test])
    true = np.array([s.batch_size * r.w / t for r, s, t in test])
    ss_res = float(np.sum((pred - true) ** 2))
    ss_tot = float(np.sum((true - true.mean()) ** 2))
    rows.append(("r2_throughput_test", 1 - ss_res / ss_tot, "paper Fig11: tight"))
    for i, name in enumerate(("grad", "upd", "sync", "emb")):
        ratio = model.alpha[i] / alpha[i] if alpha[i] else float("nan")
        rows.append((f"alpha_{name}_recovery", ratio, "1.0 = exact"))
    rows.append(("beta_sum_recovery", model.beta_sum / beta, "1.0 = exact"))
    return rows
