"""Fig 7: JCT per DLRM model under DLRover-RM vs well-tuned / ES / Optimus.

Small-cluster regime (no failures). DLRover-RM runs with a warmed config DB
(the production deployment state); paper claims: within ~1.4 % of well-tuned,
17.7 % better than ES, 28.5 % better than Optimus. Our synthetic workload has
a wider resource-sensitivity range than the paper's three tuned models, so
relative gaps are larger; ordering is the reproduced claim.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from benchmarks.common import Row, fast_mode
from repro.sim.cluster import CloudSim
from repro.sim.workload import generate_jobs


def run(n_jobs: int = 24, horizon_h: float = 20.0, seed: int = 11) -> List[Row]:
    if fast_mode():
        n_jobs, horizon_h = 10, 12.0
    rows: List[Row] = []
    jobs = generate_jobs(n_jobs, seed=seed, arrival_rate_per_h=40,
                         mean_msamples=40.0)
    med: Dict[str, float] = {}
    per_kind: Dict[str, Dict[str, float]] = {}
    for name in ["static_tuned", "dlrover_rm", "es", "optimus"]:
        sim = CloudSim(name, total_cpu=8192, total_mem_gb=65536, seed=7,
                       enable_failures=False)
        res = sim.run(jobs, horizon_s=horizon_h * 3600)
        jcts = [r.jct_s for r in res.records if r.jct_s is not None]
        med[name] = float(np.median(jcts)) if jcts else float("nan")
        for kind in ("wide_deep", "xdeepfm", "dcn"):
            ks = [r.jct_s for r in res.records
                  if r.jct_s is not None and r.kind == kind]
            per_kind.setdefault(kind, {})[name] = (
                float(np.median(ks)) if ks else float("nan"))
        rows.append((f"median_jct_min.{name}", med[name] / 60.0, "minutes"))
    for kind, vals in per_kind.items():
        for name, v in vals.items():
            rows.append((f"jct_min.{kind}.{name}", v / 60.0, "minutes"))
    base = med["dlrover_rm"]
    rows.append(("dlrover_vs_tuned", med["static_tuned"] / base,
                 "paper: ~0.986 (within 1.4%)"))
    rows.append(("es_vs_dlrover", med["es"] / base, "paper: ~1.18"))
    rows.append(("optimus_vs_dlrover", med["optimus"] / base, "paper: ~1.29"))
    return rows
