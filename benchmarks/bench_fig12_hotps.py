"""Fig 12: hot-PS handling — no-intervention vs stop-and-restart vs seamless.

Deterministic scenario: a PS goes hot (3 % effective speed) 5 minutes into a
job. Three strategies resolve it; the paper reports DLRover-RM cutting JCT by
36.4 % (vs no intervention) and 27.6 % (vs traditional migration), saving
~5 min of provisioning overlap and ~3 min of checkpoint time (flash vs RDS).

Also measures a REAL flash-checkpoint: in-memory save/restore of a ~40 MB
train state vs synchronous npz persistence.
"""
from __future__ import annotations

import tempfile
import time
from typing import List

import jax
import numpy as np

from benchmarks.common import Row, fast_mode
from repro.core.migration import MigrationPlan
from repro.sim.cluster import CloudSim, TIMINGS
from repro.sim.workload import generate_jobs


def _jct_with_strategy(strategy: str, seed: int = 9) -> float:
    """Same allocation for every strategy; only the hot-PS mitigation differs
    (isolates the mechanism, like the paper's Fig 12). The job runs with a
    small PS fleet (p=2, the paper's small-cluster regime) so one hot PS
    actually gates the iteration."""
    import dataclasses
    from repro.core.perf_model import JobResources
    jobs = generate_jobs(1, seed=seed, mean_msamples=40.0)
    jobs[0] = dataclasses.replace(
        jobs[0], oracle=JobResources(w=8, p=2, cpu_w=16, cpu_p=8, mem_p=32.0))
    sim = CloudSim("static_tuned", total_cpu=8192, total_mem_gb=65536, seed=3,
                   enable_failures=False, hotps_rate_per_pod_per_day=0.0)
    orig = CloudSim._throughput
    injected = [False]

    def patched(self, rj, now):
        if not injected[0] and now >= 300.0:
            injected[0] = True
            rj.record.hot_pses += 1
            if strategy == "dlrover":
                # seamless: provisioning overlaps training; flash-ckpt sync
                rj.hotps_until = now + TIMINGS.provision_s
                sync = TIMINGS.flash_ckpt_save_s + TIMINGS.flash_ckpt_load_s
                rj.blocked_until = now + TIMINGS.provision_s + sync
                rj.record.downtime_s += sync
            elif strategy == "traditional":
                # stop-and-restart: pause, RDS ckpt, provision, load
                dt = (TIMINGS.rds_ckpt_save_s + TIMINGS.provision_s
                      + TIMINGS.rds_ckpt_load_s)
                rj.hotps_until = now + dt
                rj.blocked_until = now + dt
                rj.record.downtime_s += dt
            else:
                rj.hotps_until = now + 3600.0          # unhealthy, no action
        return orig(self, rj, now)

    CloudSim._throughput = patched
    try:
        res = sim.run(jobs, horizon_s=10 * 3600)
    finally:
        CloudSim._throughput = orig
    return res.records[0].jct_s or float("nan")


def run() -> List[Row]:
    rows: List[Row] = []
    jct_none = _jct_with_strategy("none")
    jct_trad = _jct_with_strategy("traditional")
    jct_dlr = _jct_with_strategy("dlrover")
    rows.append(("jct_min.no_intervention", jct_none / 60, "minutes"))
    rows.append(("jct_min.traditional_migration", jct_trad / 60, "minutes"))
    rows.append(("jct_min.dlrover_seamless", jct_dlr / 60, "minutes"))
    rows.append(("reduction_vs_none", 1 - jct_dlr / jct_none, "paper: 0.364"))
    rows.append(("reduction_vs_traditional", 1 - jct_dlr / jct_trad,
                 "paper: 0.276"))

    # --- analytic downtime decomposition (MigrationPlan) --------------------
    seamless = MigrationPlan(seamless=True, use_flash_ckpt=True)
    trad = MigrationPlan(seamless=False, use_flash_ckpt=False)
    rows.append(("downtime_s.seamless_flash", seamless.downtime_seconds(),
                 "paper: seconds"))
    rows.append(("downtime_s.stop_restart_rds", trad.downtime_seconds(),
                 "paper: tens of minutes region"))

    # --- REAL flash-checkpoint timing ----------------------------------------
    from repro.core.flash_checkpoint import FlashCheckpoint
    n_arrays = 8 if fast_mode() else 40
    state = {"w": [jax.random.normal(jax.random.PRNGKey(i), (512, 512))
                   for i in range(n_arrays)]}    # ~40 MB (8 MB in fast mode)
    with tempfile.TemporaryDirectory() as d:
        ck = FlashCheckpoint(d, async_persist=False)
        ck.save(state, 1)
        mem_save = ck.last_save_seconds
        disk_save = ck.last_persist_seconds
        like = jax.tree.map(lambda a: np.zeros(a.shape, np.float32), state)
        t0 = time.perf_counter()
        ck.restore(like, 1)
        restore_s = time.perf_counter() - t0
    rows.append(("flash_mem_save_s", mem_save, "critical path (in-memory)"))
    rows.append(("flash_disk_persist_s", disk_save, "async, off critical path"))
    rows.append(("flash_restore_s", restore_s, ""))
    rows.append(("flash_speedup", disk_save / max(mem_save, 1e-9),
                 "mem tier vs disk tier"))

    # --- hot-PS at placement time: skewed rows -> cache + balanced ranges ---
    # The same power-law row popularity that overloads one PS is what the
    # fused embedding engine's hot-row cache and the RecShard-style placement
    # plan exploit (see bench_kernels' skew section for the wall-time side).
    import dataclasses as _dc
    from repro.configs.dlrm_models import WIDE_DEEP, reduced_dlrm
    from repro.core.sharding_service import ParameterPlacementService
    from repro.data.synthetic import criteo_batch
    from repro.sharding.policy import placement_imbalance

    cfg = _dc.replace(reduced_dlrm(WIDE_DEEP), table_rows=(512,) * 6,
                      zipf_alpha=1.05, hot_rows_k=96)
    svc = ParameterPlacementService(cfg.table_rows)
    for lo in range(0, 1024, 256):
        batch = criteo_batch(cfg, 11, np.arange(lo, lo + 256))
        svc.report_batch("w0", batch["sparse"])
    counts = svc.counts
    plan = svc.hot_plan(cfg.hot_rows_k)
    hot_mass = sum(int(counts[o:o + k].sum())
                   for o, k in zip(cfg.table_offsets, plan))
    rows.append(("hotps_cache_hit_rate", hot_mass / max(counts.sum(), 1),
                 f"VMEM cache absorbs this lookup share at K={cfg.hot_rows_k}"))
    rows.append(("hotps_cache_rows_frac",
                 sum(plan) / cfg.total_embedding_rows,
                 "cached fraction of pooled rows"))
    from repro.sharding.policy import uniform_vocab_ranges
    n_ps = 4
    uniform = uniform_vocab_ranges(cfg.total_embedding_rows, n_ps)
    rows.append(("hotps_imbalance_uniform_striping",
                 placement_imbalance(counts, uniform),
                 "max/mean PS load, uniform vocab split"))
    rows.append(("hotps_imbalance_balanced_ranges", svc.imbalance(n_ps),
                 "max/mean PS load, frequency-balanced ranges"))

    # --- padded physical placement: the plan is what GSPMD places -----------
    # Until now the balanced ranges were advisory (GSPMD NamedShardings only
    # materialize equal splits). The padded (n_ps, max_range, D) layout makes
    # them physical: these rows measure the MATERIALIZED store — real rows
    # per shard from the padding mask of an actually-padded parameter array,
    # and lookup imbalance over those physical shards — plus bit-exactness
    # of the padded fused engine against the flat XLA reference.
    import jax.numpy as jnp

    from repro.kernels.fused_embedding import fused_embedding_bag
    from repro.sharding.policy import EmbeddingPlan, padded_layout_for_ranges

    balanced = svc.ps_ranges(n_ps)
    layout = padded_layout_for_ranges(balanced)
    rng = np.random.default_rng(0)
    D = cfg.embed_dim
    pool = jnp.asarray(rng.standard_normal(
        (cfg.total_embedding_rows, D)).astype(np.float32))
    ppool = layout.pad_rows(pool)                 # the (n_ps, max_range, D) store
    mask = layout.padding_mask()
    materialized = mask.sum(axis=1)               # real rows per physical shard
    plan_sizes = np.array([e - s for s, e in balanced])
    rows.append(("padded_shard_rows_match_plan",
                 float(np.array_equal(materialized, plan_sizes)),
                 f"materialized rows/shard {materialized.tolist()} == plan"))
    rows.append(("padded_materialized_imbalance",
                 placement_imbalance(counts, layout.ranges),
                 "max/mean lookup load over the PHYSICAL shards (<=1.05)"))
    rows.append(("padded_equal_split_imbalance",
                 placement_imbalance(counts, uniform),
                 "what the old equal-split materialization suffered"))
    rows.append(("padded_overhead_rows_frac",
                 (layout.padded_rows - cfg.total_embedding_rows)
                 / cfg.total_embedding_rows,
                 f"padding cost of max_range={layout.max_range}"))

    batch = criteo_batch(cfg, 11, np.arange(0, 256))
    idx = jnp.asarray(batch["sparse"])
    flat_plan = EmbeddingPlan(offsets=cfg.table_offsets, combiner="sum")
    pad_plan = flat_plan.with_replan(None, layout)
    out_flat = fused_embedding_bag(pool, idx, plan=flat_plan)
    out_pad = fused_embedding_bag(ppool.reshape(-1, D), idx, plan=pad_plan)
    rows.append(("padded_fwd_bitexact_err",
                 float(jnp.abs(out_pad - out_flat).max()),
                 "padded forward vs flat XLA reference (0 = bit-exact)"))
    import jax as _jax
    g_flat = _jax.grad(lambda p: jnp.sum(
        fused_embedding_bag(p, idx, plan=flat_plan) * 1.3))(pool)
    g_pad = _jax.grad(lambda p3: jnp.sum(fused_embedding_bag(
        p3.reshape(-1, D), idx, plan=pad_plan) * 1.3))(ppool)
    rows.append(("padded_bwd_bitexact_err",
                 float(jnp.abs(layout.unpad_rows(g_pad) - g_flat).max()),
                 "padded backward vs flat XLA reference (0 = bit-exact)"))
    rows.append(("padded_pad_rows_grad_abs_max",
                 float(jnp.abs(jnp.where(jnp.asarray(mask)[..., None],
                                         0.0, g_pad)).max()),
                 "gradient mass on padding slots (must be 0)"))

    # --- live re-planning under DRIFTING skew --------------------------------
    # A plan frozen at compile time re-creates the hot-PS problem the moment
    # row popularity drifts. The HotTableTracker's decayed rolling counts
    # watch the live stream; when the applied plan's imbalance crosses the
    # trigger it emits a ReplanDecision (frequency permutation + balanced
    # ranges + measured cache prefixes) that repro.train.replan applies
    # bit-exactly. Here: plan once, rotate the hot head by 157 ids per table,
    # and show imbalance re-converging to ~1.0 after the second re-plan.
    from repro.core.sharding_service import HotTableTracker
    from repro.train.replan import EmbeddingRemapper

    rows_per_table = cfg.table_rows[0]
    tracker = HotTableTracker(cfg.table_rows, n_ps=n_ps,
                              hot_budget=cfg.hot_rows_k, decay=0.8,
                              trigger=1.2, cooldown=4, min_lookups=512)
    remap = EmbeddingRemapper(cfg.table_rows)

    def feed(lo, shift):
        batch = criteo_batch(cfg, 11, np.arange(lo, lo + 256))
        sparse = ((batch["sparse"].astype(np.int64) + shift) % rows_per_table
                  ).astype(np.int32)
        tracker.observe(remap.remap(sparse))

    for lo in range(0, 1536, 256):              # phase A: stationary skew
        feed(lo, shift=0)
    d1 = tracker.maybe_replan()                 # uniform striping has gone hot
    assert d1 is not None
    tracker.mark_applied(d1)
    remap.compose(d1.permutation)
    rows.append(("replan_initial_imbalance_before", d1.imbalance_before,
                 "uniform striping under stationary skew"))
    rows.append(("replan_initial_imbalance_after", d1.imbalance_after,
                 "first re-plan: balanced ranges"))

    for lo in range(2048, 4096, 256):           # phase B: hot head rotates
        feed(lo, shift=157)
    d2 = tracker.maybe_replan()                 # drift re-arms the trigger
    assert d2 is not None
    tracker.mark_applied(d2)
    remap.compose(d2.permutation)
    rows.append(("replan_drift_imbalance_before", d2.imbalance_before,
                 "stale plan under drifted skew (trigger: 1.2)"))
    rows.append(("replan_drift_imbalance_after", d2.imbalance_after,
                 "second re-plan re-converges (target: <=1.05)"))
    rows.append(("replan_drift_cache_rows", sum(d2.table_hot),
                 f"measured table_hot rows at K={cfg.hot_rows_k}"))
    rows.append(("replan_count", tracker.n_replans, "re-plans applied"))
    return rows
