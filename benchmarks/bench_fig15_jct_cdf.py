"""Fig 15: cluster-level JCT distribution before/after DLRover-RM migration.

Same contended trace as Fig 14; reports median and P90 JCT (pending time
included — the capacity freed by right-sizing shortens queues). Paper:
median −31 %, P90 −35.7 %.
"""
from __future__ import annotations

from typing import List

from benchmarks.common import Row
from repro.sim.cluster import CloudSim
from repro.sim.workload import generate_jobs


def run(n_jobs: int = 60, seed: int = 21) -> List[Row]:
    rows: List[Row] = []
    jobs = generate_jobs(n_jobs, seed=seed, arrival_rate_per_h=120,
                         mean_msamples=40.0)
    stats = {}
    for name, label in [("static_user", "before"), ("dlrover_rm", "after")]:
        sim = CloudSim(name, total_cpu=3072, total_mem_gb=24576, seed=5)
        res = sim.run(jobs, horizon_s=24 * 3600)
        stats[label] = (res.jct_percentile(50), res.jct_percentile(90))
        rows.append((f"median_jct_min.{label}", stats[label][0] / 60, "minutes"))
        rows.append((f"p90_jct_min.{label}", stats[label][1] / 60, "minutes"))
    med_cut = 1 - stats["after"][0] / stats["before"][0]
    p90_cut = 1 - stats["after"][1] / stats["before"][1]
    rows.append(("median_jct_reduction", med_cut, "paper: 0.31"))
    rows.append(("p90_jct_reduction", p90_cut, "paper: 0.357"))
    return rows
