"""Fig 15: JCT distribution on the replayed trace, before/after DLRover-RM.

Same replayed v2020-shaped trace as Fig 14; reports the JCT CDF (deciles,
pending time included — capacity freed by right-sizing shortens queues) for
the static "before" baseline, the best elastic baseline (ES) and DLRover-RM,
plus the paper's headline percentile reductions. Paper: median −31 %,
P90 −35.7 %.
"""
from __future__ import annotations

from typing import Dict, List

from benchmarks.common import Row, fast_mode
from benchmarks.bench_fig14_cluster import load_replay_jobs
from repro.sim.cluster import SimResult
from repro.sim.replay import replay

SCHEDULERS = ("static_user", "es", "dlrover_rm")
DECILES = (10, 25, 50, 75, 90)


def run(seed: int = 21, failure_seed: int = 77) -> List[Row]:
    fast = fast_mode()
    n_synthetic = 0 if fast else 120
    total_cpu = 3072.0 if fast else 8192.0
    total_mem = 24576.0 if fast else 65536.0
    horizon_s = (12.0 if fast else 24.0) * 3600.0

    jobs = load_replay_jobs(n_synthetic, seed)
    rows: List[Row] = []
    results: Dict[str, SimResult] = {}
    for name in SCHEDULERS:
        res = replay(jobs, name, total_cpu=total_cpu, total_mem_gb=total_mem,
                     horizon_s=horizon_s, seed=seed, failure_seed=failure_seed,
                     amplitude=0.15)
        results[name] = res
        for pct in DECILES:
            rows.append((f"jct_p{pct}_min.{name}",
                         res.jct_percentile(pct) / 60, "minutes"))

    before, after = results["static_user"], results["dlrover_rm"]
    med_cut = 1 - after.jct_percentile(50) / max(before.jct_percentile(50), 1e-9)
    p90_cut = 1 - after.jct_percentile(90) / max(before.jct_percentile(90), 1e-9)
    best_med = min(results[n].jct_percentile(50) for n in ("static_user", "es"))
    rows.append(("median_jct_reduction", med_cut, "paper: 0.31"))
    rows.append(("p90_jct_reduction", p90_cut, "paper: 0.357"))
    rows.append(("median_jct_reduction_vs_best_baseline",
                 1 - after.jct_percentile(50) / max(best_med, 1e-9), ""))
    return rows
