"""Table 1: CPU-only vs CPU-GPU hybrid training cost (samples per USD).

Analytic recomputation with the paper's published numbers as anchors: the
hybrid path accelerates only the dense-part compute (GPU), while embedding
lookups and CPU<->GPU transfer (22 % of time, [9]) persist — so the GPU sits
<5 % utilized and the $/sample worsens despite a faster wall clock.
"""
from __future__ import annotations

from typing import List

from benchmarks.common import Row

CPU_PRICE = 0.53          # usd/h (paper Table 1)
HYBRID_PRICE = 3.59


def run() -> List[Row]:
    rows: List[Row] = []
    for model, t_dense, t_lookup, t_other in [
            ("wide_deep", 0.45, 0.40, 0.15),
            ("deepfm", 0.42, 0.45, 0.13)]:
        # CPU-only: iteration normalized to 1.0
        cpu_time = 1.0
        # hybrid: dense 8× faster on GPU, lookups unchanged, +22 % transfer
        hybrid_time = t_dense / 8.0 + t_lookup + t_other + 0.22
        speedup = cpu_time / hybrid_time
        cpu_spd = 1.0 / CPU_PRICE                 # samples/usd (normalized)
        hyb_spd = speedup / HYBRID_PRICE
        gpu_util = (t_dense / 8.0) / hybrid_time
        rows.append((f"{model}.hybrid_speedup", speedup, "x vs CPU-only"))
        rows.append((f"{model}.samples_per_usd_cpu", cpu_spd, "normalized"))
        rows.append((f"{model}.samples_per_usd_hybrid", hyb_spd, "normalized"))
        rows.append((f"{model}.cpu_cheaper_by", cpu_spd / hyb_spd,
                     "paper: 1.5-1.8x"))
        rows.append((f"{model}.gpu_util", gpu_util, "paper: ~3-4%"))
    return rows
