"""Fig 14 + Table 4: production-cluster migration — utilization, JCR, failures.

Contended cluster with failures/stragglers/hot-PSes/OOM-growth. "Before" =
user-configured static jobs on Kubeflow-like infra; "after" = the same trace
under DLRover-RM. Paper: CPU util 19→40 %, memory util ~15→40 %, JCR 84→95 %
(small jobs) / 67→87 % (large), OOM failures 4.7 %→0.23 %.
"""
from __future__ import annotations

from typing import List

from benchmarks.common import Row
from repro.sim.cluster import CloudSim
from repro.sim.workload import generate_jobs


def run(n_jobs: int = 60, seed: int = 21) -> List[Row]:
    rows: List[Row] = []
    jobs = generate_jobs(n_jobs, seed=seed, arrival_rate_per_h=120,
                         mean_msamples=40.0)
    results = {}
    for name, label in [("static_user", "before"), ("dlrover_rm", "after")]:
        sim = CloudSim(name, total_cpu=3072, total_mem_gb=24576, seed=5,
                       pod_failure_rate_per_day=0.015,
                       straggler_rate_per_pod_per_day=0.3,
                       hotps_rate_per_pod_per_day=0.3)
        res = sim.run(jobs, horizon_s=24 * 3600)
        results[label] = res
        rows.append((f"cpu_util.{label}", res.mean_cpu_util(),
                     "paper: 0.19 -> 0.40"))
        rows.append((f"mem_util.{label}", res.mean_mem_util(),
                     "paper: ~0.15 -> ~0.40"))
        rows.append((f"jcr.{label}", res.jcr(), "paper: 0.84 -> 0.95"))
        ev = res.event_rates()
        rows.append((f"oom_per_job.{label}", ev["oom_failure"],
                     "paper: 4.7% -> 0.23%"))
        rows.append((f"restart_failures_per_job.{label}", ev["other_failure"], ""))
    b, a = results["before"], results["after"]
    rows.append(("cpu_util_gain", a.mean_cpu_util() - b.mean_cpu_util(),
                 "paper: +0.21"))
    rows.append(("mem_util_gain", a.mean_mem_util() - b.mean_mem_util(),
                 "paper: +0.17-0.31"))
    rows.append(("jcr_gain", a.jcr() - b.jcr(), "paper: +0.06-0.20"))
    return rows
