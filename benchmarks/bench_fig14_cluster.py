"""Fig 14 + Table 4: cluster migration on a replayed v2020-shaped trace.

Replays the checked-in Alibaba-style job trace (scaled up synthetically in
full mode) through ``CloudSim`` under time-varying capacity, once per
scheduler: the user-configured static baseline ("before" the DLRover-RM
migration), the elastic baselines (ES, Optimus) and the full three-stage
DLRover-RM loop ("after"). Emits utilization/JCR/JCT rows per scheduler plus
the headline gains of DLRover-RM over the *best* baseline on each metric.
Paper: CPU util 19→40 %, memory util ~15→40 %, JCR 84→95 %.

Deterministic for the pinned (seed, failure-seed): rows reproduce exactly.
"""
from __future__ import annotations

from typing import Dict, List

from benchmarks.common import Row, fast_mode
from repro.sim.replay import replay, summarize
from repro.sim.trace import (
    REPLAYABLE_STATUSES, default_trace_path, load_trace, synthesize_trace,
    trace_marginals, trace_to_jobs,
)

SCHEDULERS = ("static_user", "es", "optimus", "dlrover_rm")
BASELINES = ("static_user", "es", "optimus")


def load_replay_jobs(n_synthetic: int, seed: int) -> list:
    """Fixture jobs (fast) or a marginals-matched synthetic scale-up (full)."""
    rows = load_trace(default_trace_path())
    replayable = [r for r in rows if r.status in REPLAYABLE_STATUSES]
    if n_synthetic:
        rows = synthesize_trace(n_synthetic, seed, trace_marginals(replayable))
    return trace_to_jobs(rows, seed=seed)


def run(seed: int = 21, failure_seed: int = 77) -> List[Row]:
    fast = fast_mode()
    n_synthetic = 0 if fast else 120
    total_cpu = 3072.0 if fast else 8192.0
    total_mem = 24576.0 if fast else 65536.0
    horizon_s = (12.0 if fast else 24.0) * 3600.0

    jobs = load_replay_jobs(n_synthetic, seed)
    rows: List[Row] = [("n_jobs", float(len(jobs)), "replayed trace jobs")]
    summaries: Dict[str, Dict[str, float]] = {}
    for name in SCHEDULERS:
        res = replay(jobs, name, total_cpu=total_cpu, total_mem_gb=total_mem,
                     horizon_s=horizon_s, seed=seed, failure_seed=failure_seed,
                     amplitude=0.15)
        s = summarize(res)
        summaries[name] = s
        note = "before (user static)" if name == "static_user" else (
            "after (three-stage loop)" if name == "dlrover_rm" else "baseline")
        rows.append((f"cpu_util.{name}", s["cpu_util"], note))
        rows.append((f"mem_util.{name}", s["mem_util"], note))
        rows.append((f"jcr.{name}", s["jcr"], "paper: 0.84 -> 0.95"))
        rows.append((f"median_jct_min.{name}", s["median_jct_s"] / 60, "minutes"))
        rows.append((f"oom_per_job.{name}", s["oom_per_job"],
                     "paper: 4.7% -> 0.23%"))

    dlr = summaries["dlrover_rm"]
    best_cpu = max(summaries[b]["cpu_util"] for b in BASELINES)
    best_jct = min(summaries[b]["median_jct_s"] for b in BASELINES)
    rows.append(("cpu_util_gain_vs_best_baseline",
                 dlr["cpu_util"] - best_cpu, "paper: +0.15-0.21"))
    rows.append(("cpu_util_gain_vs_static",
                 dlr["cpu_util"] - summaries["static_user"]["cpu_util"],
                 "paper: +0.21"))
    rows.append(("mem_util_gain_vs_static",
                 dlr["mem_util"] - summaries["static_user"]["mem_util"],
                 "paper: +0.17-0.31"))
    rows.append(("jct_reduction_vs_best_baseline",
                 1.0 - dlr["median_jct_s"] / max(best_jct, 1e-9),
                 "paper: 0.31 (fig 15)"))
    rows.append(("jcr_gain_vs_static",
                 dlr["jcr"] - summaries["static_user"]["jcr"],
                 "paper: +0.06-0.20"))
    return rows
