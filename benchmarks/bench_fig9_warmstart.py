"""Fig 9: warm-starting accuracy — initial allocation vs final configuration.

Builds a month-like history of completed jobs, then warm-starts new jobs and
compares the initial allocation against each job's true final (oracle) config.
Paper: 92 % (workers) / 85 % (PS) accuracy; cold-start scaling time reduced
by ~26 % on average.
"""
from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import Row
from repro.core.perf_model import JobResources
from repro.core.warm_start import (
    ConfigDB, ConfigRecord, warm_start, warm_start_accuracy,
)
from repro.sim.workload import generate_jobs


def run(n_history: int = 60, n_eval: int = 20, seed: int = 3) -> List[Row]:
    rows: List[Row] = []
    import dataclasses
    rng = np.random.default_rng(seed + 7)
    history = generate_jobs(n_history, seed=seed)
    db = ConfigDB()
    for j in history:
        # historical finals carry real-world noise around each job's optimum
        final = dataclasses.replace(
            j.oracle,
            w=max(1, int(round(j.oracle.w * rng.lognormal(0, 0.2)))),
            p=max(1, int(round(j.oracle.p * rng.lognormal(0, 0.2)))),
            cpu_w=float(np.clip(j.oracle.cpu_w * rng.lognormal(0, 0.2), 1, 32)),
            cpu_p=float(np.clip(j.oracle.cpu_p * rng.lognormal(0, 0.2), 1, 32)))
        db.add(ConfigRecord(meta=j.meta, final_config=final))

    evals = generate_jobs(n_eval, seed=seed + 1)
    acc_w, acc_p, acc_all = [], [], []
    scaling_steps_warm, scaling_steps_cold = [], []
    cold = JobResources(w=2, p=1, cpu_w=4, cpu_p=4)
    for j in evals:
        init = warm_start(j.meta, db, k=5, mu=0.5, default=cold)
        final = j.oracle
        acc_w.append(1 - abs(init.w - final.w) / max(init.w, final.w))
        acc_p.append(1 - abs(init.p - final.p) / max(init.p, final.p))
        acc_all.append(warm_start_accuracy(init, final))
        # scaling steps ≈ log2 distance in worker count (each step doubles)
        scaling_steps_warm.append(abs(np.log2(max(final.w, 1) / max(init.w, 1))))
        scaling_steps_cold.append(abs(np.log2(max(final.w, 1) / cold.w)))
    rows.append(("worker_accuracy", float(np.mean(acc_w)), "paper: ~0.92"))
    rows.append(("ps_accuracy", float(np.mean(acc_p)), "paper: ~0.85"))
    rows.append(("overall_accuracy", float(np.mean(acc_all)), ""))
    reduction = 1 - np.mean(scaling_steps_warm) / max(np.mean(scaling_steps_cold), 1e-9)
    rows.append(("scaling_time_reduction", float(reduction), "paper: ~0.26"))
    return rows
