"""Fig 13: worker-straggler handling via dynamic data sharding.

Deterministic scenario: one worker drops to 3 % speed 5 minutes in. DLRover
rebalances within ~1 minute by shrinking the straggler's shards; traditional
handling stop-and-restarts; no-intervention persists unhealthy. Paper: JCT
cut 48.5 % (vs none) / 37 % (vs traditional). Also demonstrates the REAL
shard-queue rebalancing (split shards to a straggler + exactly-once coverage).
"""
from __future__ import annotations

from typing import List

from benchmarks.common import Row, fast_mode
from repro.core.sharding_service import ShardingService
from repro.sim.cluster import CloudSim, TIMINGS
from repro.sim.workload import generate_jobs


def _jct(strategy: str, seed: int = 9) -> float:
    """Same well-tuned allocation for every strategy; only the straggler
    mitigation differs (isolates the mechanism, like the paper's Fig 13)."""
    jobs = generate_jobs(1, seed=seed, mean_msamples=40.0)
    sim = CloudSim("static_tuned", total_cpu=8192, total_mem_gb=65536, seed=3,
                   enable_failures=False, straggler_rate_per_pod_per_day=0.0)
    orig = CloudSim._throughput
    injected = [False]

    def patched(self, rj, now):
        if not injected[0] and now >= 300.0:
            injected[0] = True
            rj.record.stragglers += 1
            if strategy == "dlrover":
                # dynamic data sharding rebalances within ~1 minute
                rj.straggler_until = now + 60.0
            elif strategy == "traditional":
                dt = (TIMINGS.rds_ckpt_save_s + TIMINGS.provision_s
                      + TIMINGS.rds_ckpt_load_s)
                rj.straggler_until = now + dt
                rj.blocked_until = now + dt
                rj.record.downtime_s += dt
            else:
                rj.straggler_until = now + 3600.0
        return orig(self, rj, now)

    CloudSim._throughput = patched
    try:
        res = sim.run(jobs, horizon_s=10 * 3600)
    finally:
        CloudSim._throughput = orig
    return res.records[0].jct_s or float("nan")


def run() -> List[Row]:
    rows: List[Row] = []
    jn, jt, jd = _jct("none"), _jct("traditional"), _jct("dlrover")
    rows.append(("jct_min.no_intervention", jn / 60, "minutes"))
    rows.append(("jct_min.traditional", jt / 60, "minutes"))
    rows.append(("jct_min.dlrover_sharding", jd / 60, "minutes"))
    rows.append(("reduction_vs_none", 1 - jd / jn, "paper: 0.485"))
    rows.append(("reduction_vs_traditional", 1 - jd / jt, "paper: 0.37"))

    # --- real shard-queue rebalancing ----------------------------------------
    svc = ShardingService(total_samples=1024 if fast_mode() else 4096,
                          shard_size=512, min_shard=64,
                          heartbeat_timeout=10.0)
    clock = [0.0]

    def tick(adv=1.0):
        clock[0] += adv
        return clock[0]

    # fast worker consumes normally; straggler gets split shards
    fast_sizes, slow_sizes = [], []
    svc._view("slow", 0.0).is_straggler = True
    while True:
        s_fast = svc.request_shard("fast", tick())
        if s_fast is not None:
            svc.heartbeat("fast", s_fast.size, tick())
            svc.report_done("fast", s_fast.index, tick())
            fast_sizes.append(s_fast.size)
        s_slow = svc.request_shard("slow", tick())
        if s_slow is not None:
            svc.heartbeat("slow", s_slow.size, tick())
            svc.report_done("slow", s_slow.index, tick())
            slow_sizes.append(s_slow.size)
        if s_fast is None and s_slow is None:
            break
    ok, covered, dup = svc.coverage(0)
    import numpy as np
    rows.append(("mean_shard.fast", float(np.mean(fast_sizes)), "samples"))
    rows.append(("mean_shard.straggler", float(np.mean(slow_sizes)),
                 "smaller workload per paper §5.1"))
    rows.append(("coverage_exact", float(ok), f"covered={covered} dup={dup}"))
    return rows
