"""Fig 8: elasticity (dynamic data sharding) preserves model convergence.

REAL JAX training of the three DLRM models on the synthetic Criteo-like set:
(a) static single-worker run; (b) elastic run where a worker dies mid-epoch,
its shard is requeued, and a straggly replacement consumes smaller shards.
Both must see exactly the same sample set once => near-identical final loss
and AUC (tolerances cover nondeterministic batch composition).
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, fast_mode
from repro.configs.dlrm_models import DCN, WIDE_DEEP, XDEEPFM, reduced_dlrm
from repro.core.sharding_service import ShardingService
from repro.data.pipeline import ShardDataLoader
from repro.data.synthetic import criteo_batch
from repro.models.dlrm import dlrm_auc, init_dlrm
from repro.train import optim, trainer

TOTAL = 2048
BATCH = 64


def _train(cfg, elastic: bool, seed: int = 0):
    api_step = jax.jit(trainer.make_dlrm_train_step(cfg, optim.adagrad(0.05)))
    params = init_dlrm(cfg, jax.random.PRNGKey(seed))
    state = {"params": params, "opt": optim.adagrad(0.05).init(params),
             "step": jnp.zeros((), jnp.int32)}
    svc = ShardingService(TOTAL, shard_size=256, min_shard=64,
                          heartbeat_timeout=5.0)
    clock = [0.0]

    def tick():
        clock[0] += 1.0
        return clock[0]

    def batch_fn(idx):
        return criteo_batch(cfg, seed=42, indices=idx)

    losses = []
    if not elastic:
        loader = ShardDataLoader(svc, "w0", batch_fn, BATCH, clock=tick)
        for batch in loader:
            state, m = api_step(state, {k: jnp.asarray(v) for k, v in batch.items()})
            losses.append(float(m["loss"]))
    else:
        # worker A dies after 8 batches; B (straggler) finishes the epoch
        loader_a = ShardDataLoader(svc, "wA", batch_fn, BATCH, clock=tick)
        loader_b = ShardDataLoader(svc, "wB", batch_fn, BATCH, clock=tick)
        for _ in range(8):
            b = loader_a.next_batch()
            state, m = api_step(state, {k: jnp.asarray(v) for k, v in b.items()})
            losses.append(float(m["loss"]))
        svc.report_failure("wA", tick())          # shard requeued, no loss
        # mark B a straggler so it receives split shards
        svc._view("wB", tick()).is_straggler = True
        while True:
            b = loader_b.next_batch()
            if b is None:
                break
            state, m = api_step(state, {k: jnp.asarray(v) for k, v in b.items()})
            losses.append(float(m["loss"]))
    # eval AUC on a held-out slice
    ev = criteo_batch(cfg, seed=43, indices=np.arange(512))
    auc = float(dlrm_auc(state["params"], {k: jnp.asarray(v) for k, v in ev.items()}, cfg))
    return losses, auc, svc


def run() -> List[Row]:
    rows: List[Row] = []
    models = (WIDE_DEEP,) if fast_mode() else (WIDE_DEEP, XDEEPFM, DCN)
    for base in models:
        cfg = reduced_dlrm(base)
        l_static, auc_s, _ = _train(cfg, elastic=False)
        l_elastic, auc_e, svc = _train(cfg, elastic=True)
        ok, covered, dup = svc.coverage(0)
        rows.append((f"{cfg.name}.auc_static", auc_s, ""))
        rows.append((f"{cfg.name}.auc_elastic", auc_e, "elastic = fail+straggler"))
        rows.append((f"{cfg.name}.auc_delta", abs(auc_s - auc_e),
                     "paper: no degradation"))
        rows.append((f"{cfg.name}.final_loss_static", float(np.mean(l_static[-5:])), ""))
        rows.append((f"{cfg.name}.final_loss_elastic", float(np.mean(l_elastic[-5:])), ""))
        rows.append((f"{cfg.name}.coverage_exact", float(ok),
                     f"covered={covered} dup={dup}"))
    return rows
