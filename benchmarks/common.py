"""Shared helpers for the benchmark suite (one module per paper table/figure).

Every bench module exposes ``run() -> List[Tuple[str, float, str]]`` rows of
(metric_name, value, notes); ``benchmarks.run`` prints them as CSV.
"""
from __future__ import annotations

import os
import time
from contextlib import contextmanager
from typing import List, Tuple

Row = Tuple[str, float, str]


def fast_mode() -> bool:
    """True when the runner's ``--fast`` flag (``REPRO_BENCH_FAST=1``) is on.

    Bench modules must call this inside ``run()`` — not at import time — so
    the flag is honored regardless of import order."""
    return os.environ.get("REPRO_BENCH_FAST", "") == "1"


@contextmanager
def timed(label: str, rows: List[Row], unit: str = "s"):
    t0 = time.perf_counter()
    yield
    rows.append((label, time.perf_counter() - t0, unit))


def fmt_rows(bench: str, rows: List[Row]) -> str:
    out = []
    for name, value, notes in rows:
        out.append(f"{bench},{name},{value:.6g},{notes}")
    return "\n".join(out)
