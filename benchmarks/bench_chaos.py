"""Goodput under faults: the self-healing loop measured end-to-end.

Runs the REAL reduced DLRM training job twice — once clean, once under a
scripted fault schedule (PS-shard loss, watchdog-visible hang, straggler
delay, checkpoint corruption) — with the recovery supervisor healing every
abnormality from layout-stamped flash checkpoints. Reports recovery latency,
steps lost, goodput fraction, and the wall-clock overhead of surviving the
schedule; asserts (as a metric, not a crash) that the recovered run's final
loss is bit-identical to the clean run's — the paper's "recover, don't
restart" claim made measurable.

The measured recovery latency is then fed back into ``sim/cluster.py``'s
failure model (``SupervisorReport.measured_timings``), closing the loop
between the simulated and the real recovery cost.
"""
from __future__ import annotations

import os
import tempfile
from typing import List

from benchmarks.common import Row


def _fast() -> bool:
    return os.environ.get("REPRO_BENCH_FAST", "") == "1"


def _run_supervised(chaos: str, total_steps: int, deadline: float):
    from repro.configs.dlrm_models import WIDE_DEEP, reduced_dlrm
    from repro.core.faults import FaultInjector, parse_chaos_spec
    from repro.core.flash_checkpoint import FlashCheckpoint
    from repro.train.supervisor import DLRMJob, Supervisor, SupervisorConfig

    cfg = reduced_dlrm(WIDE_DEEP)
    plan = parse_chaos_spec(chaos)
    injector = FaultInjector(plan, seed=0) if plan.specs else None
    ckpt = FlashCheckpoint(
        tempfile.mkdtemp(prefix="bench_chaos_"), async_persist=False,
        fault_hook=injector.on_persist if injector else None)
    if injector is not None:
        injector.bind_checkpoint(ckpt)
    job = DLRMJob(cfg, ckpt, ckpt_every=5, n_ps=4, padded=True,
                  injector=injector)
    sup = Supervisor(job, SupervisorConfig(
        step_deadline_s=deadline, max_restarts=8, backoff_base_s=0.01))
    report = sup.run(total_steps)
    return job, report


def run() -> List[Row]:
    steps = 30 if _fast() else 60
    q = steps // 6
    chaos = (f"ps_loss@{2 * q},straggler@{3 * q}x3:0.05,"
             f"ckpt_corrupt@{3 * q},hang@{4 * q}")

    _, clean = _run_supervised("", steps, deadline=None)
    job, faulty = _run_supervised(chaos, steps, deadline=1.5)

    rows: List[Row] = []
    rows.append(("clean_wall_s", clean.wall_seconds, f"{steps} steps"))
    rows.append(("faulty_wall_s", faulty.wall_seconds, chaos))
    rows.append(("restarts", faulty.restarts, "recoveries performed"))
    rows.append(("steps_lost", faulty.steps_lost, "re-trained after restores"))
    rows.append(("goodput_fraction", faulty.goodput_fraction,
                 "productive steps / step attempts"))
    lat = faulty.recovery_latencies_s
    rows.append(("recovery_latency_mean_s",
                 sum(lat) / len(lat) if lat else 0.0,
                 "flash restore + recompile"))
    rows.append(("overhead_fraction",
                 faulty.wall_seconds / max(clean.wall_seconds, 1e-9) - 1.0,
                 "extra wall clock to survive the schedule"))
    rows.append(("loss_bit_exact",
                 float(clean.final_loss == faulty.final_loss),
                 "1.0 = recovered run matches clean run exactly"))

    # feed measured recovery latency back into the cluster simulator's
    # failure model: sim and system now agree on what a recovery costs
    from repro.sim.cluster import CloudSim
    from repro.sim.workload import generate_jobs
    timings = faulty.measured_timings()
    sim = CloudSim("dlrover_rm", seed=0, failure_seed=42, timings=timings,
                   ckpt_interval_s=600.0)
    res = sim.run(generate_jobs(4 if _fast() else 8, seed=5),
                  horizon_s=4 * 3600)
    done = [r for r in res.records if r.completed]
    rows.append(("sim_with_measured_timings.completed", len(done),
                 f"flash_ckpt_load_s={timings.flash_ckpt_load_s:.3f} measured"))
    return rows
