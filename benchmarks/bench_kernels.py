"""Kernel micro-benchmarks (CPU host): XLA paths wall-time + Pallas interpret
correctness spot checks. Real TPU timings are out of scope on this host — the
structural (roofline) analysis of the kernels lives in benchmarks/roofline.py.

The headline comparison is the fused multi-table embedding engine (one take +
segment_sum over the pooled tables, custom sparse-gradient VJP) against the
legacy per-table Python loop, forward and forward+backward.
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row
from repro.kernels import ref
from repro.kernels.fused_embedding import fused_embedding_bag, table_offsets
from repro.models.attention import chunked_attention


def _time(fn, *args, iters=5, repeats=3) -> float:
    """Best-of-``repeats`` mean over ``iters`` calls (shields host noise)."""
    jax.block_until_ready(fn(*args))                     # warmup / compile
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(iters):
            jax.block_until_ready(fn(*args))
        best = min(best, (time.perf_counter() - t0) / iters * 1e6)   # us
    return best


def run() -> List[Row]:
    rows: List[Row] = []
    key = jax.random.PRNGKey(0)

    # --- single-table embedding bag (legacy shape) --------------------------
    table = jax.random.normal(key, (100_000, 16))
    idx = jax.random.randint(jax.random.fold_in(key, 1), (512, 8), 0, 100_000)
    f_ref = jax.jit(lambda t, i: ref.embedding_bag_ref(t, i, combiner="sum"))
    us = _time(f_ref, table, idx)
    rows.append(("embedding_bag_ref_us", us, "B=512 hot=8 D=16 R=100k"))

    # --- fused multi-table engine vs per-table loop -------------------------
    T, H, B, D, R_t = 8, 4, 512, 16, 20_000
    rows_per = (R_t,) * T
    offs = table_offsets(rows_per)
    pool = jax.random.normal(jax.random.fold_in(key, 2), (T * R_t, D))
    midx = jax.random.randint(jax.random.fold_in(key, 3), (B, T, H), 0, R_t)
    note = f"B={B} T={T} hot={H} D={D} R={R_t}/table"

    def loop_fwd(p, i):
        outs = [ref.embedding_bag_ref(
            jax.lax.dynamic_slice_in_dim(p, offs[t], R_t), i[:, t, :],
            combiner="sum") for t in range(T)]
        return jnp.stack(outs, axis=1)

    def fused_fwd(p, i):
        return fused_embedding_bag(p, i, offsets=offs, combiner="sum")

    f_loop = jax.jit(loop_fwd)
    f_fused = jax.jit(fused_fwd)
    us_loop = _time(f_loop, pool, midx, iters=20)
    us_fused = _time(f_fused, pool, midx, iters=20)
    rows.append(("embed_fwd_per_table_loop_us", us_loop, note))
    rows.append(("embed_fwd_fused_us", us_fused, note))
    rows.append(("embed_fwd_fused_speedup", us_loop / max(us_fused, 1e-9),
                 "fused take vs T gathers"))

    g_loop = jax.jit(jax.grad(lambda p, i: jnp.sum(jnp.sin(loop_fwd(p, i)))))
    g_fused = jax.jit(jax.grad(lambda p, i: jnp.sum(jnp.sin(fused_fwd(p, i)))))
    us_loop_bwd = _time(g_loop, pool, midx, iters=10)
    us_fused_bwd = _time(g_fused, pool, midx, iters=10)
    rows.append(("embed_fwdbwd_per_table_loop_us", us_loop_bwd, note))
    rows.append(("embed_fwdbwd_fused_us", us_fused_bwd, note))
    rows.append(("embed_fwdbwd_fused_speedup",
                 us_loop_bwd / max(us_fused_bwd, 1e-9),
                 "segment_sum VJP vs T scatter-adds"))

    # Pallas interpret correctness of the fused kernel (small shapes: the
    # interpreter is slow, this is a numerics check, not a timing)
    sidx = midx[:32]
    out_p = fused_embedding_bag(pool, sidx, offsets=offs, combiner="sum",
                                method="interpret", block_b=8)
    err = float(jnp.abs(out_p - f_fused(pool, sidx)).max())
    rows.append(("fused_embedding_pallas_err", err, "interpret vs ref, B=32"))

    # --- chunked attention (the dry-run lowering path) ----------------------
    B, S, Hh, Dh = 1, 1024, 8, 64
    q = jax.random.normal(jax.random.fold_in(key, 4), (B, S, Hh, Dh), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 5), (B, S, Hh // 2, Dh))
    v = jax.random.normal(jax.random.fold_in(key, 6), (B, S, Hh // 2, Dh))
    f_attn = jax.jit(lambda q, k, v: chunked_attention(q, k, v, causal=True,
                                                       q_chunk=256, k_chunk=256))
    us = _time(f_attn, q, k, v, iters=3)
    rows.append(("chunked_attention_us", us, f"S={S} H={Hh} D={Dh} causal"))
    f_local = jax.jit(lambda q, k, v: chunked_attention(
        q, k, v, causal=True, window=128, q_chunk=128, k_chunk=128))
    us_local = _time(f_local, q, k, v, iters=3)
    rows.append(("windowed_attention_us", us_local, "window=128 (sub-quadratic)"))
    rows.append(("local_vs_global_speedup", us / max(us_local, 1e-9),
                 "window cuts O(S^2) -> O(S*W)"))
    return rows
