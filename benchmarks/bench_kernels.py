"""Kernel micro-benchmarks (CPU host): XLA paths wall-time + Pallas interpret
correctness spot checks. Real TPU timings are out of scope on this host — the
structural (roofline) analysis of the kernels lives in benchmarks/roofline.py.
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row
from repro.kernels import ref
from repro.kernels.embedding_bag import embedding_bag
from repro.models.attention import chunked_attention


def _time(fn, *args, iters=5) -> float:
    jax.block_until_ready(fn(*args))                     # warmup / compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6      # us


def run() -> List[Row]:
    rows: List[Row] = []
    key = jax.random.PRNGKey(0)

    # embedding bag: ref (jnp gather+pool) jit'd
    table = jax.random.normal(key, (100_000, 16))
    idx = jax.random.randint(key, (512, 8), 0, 100_000)
    f_ref = jax.jit(lambda t, i: ref.embedding_bag_ref(t, i, combiner="sum"))
    us = _time(f_ref, table, idx)
    rows.append(("embedding_bag_ref_us", us, "B=512 hot=8 D=16 R=100k"))
    out_p = embedding_bag(table, idx, combiner="sum", interpret=True)
    err = float(jnp.abs(out_p - f_ref(table, idx)).max())
    rows.append(("embedding_bag_pallas_err", err, "interpret vs ref"))

    # chunked attention (the dry-run lowering path)
    B, S, H, D = 1, 1024, 8, 64
    q = jax.random.normal(key, (B, S, H, D), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H // 2, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H // 2, D))
    f_attn = jax.jit(lambda q, k, v: chunked_attention(q, k, v, causal=True,
                                                       q_chunk=256, k_chunk=256))
    us = _time(f_attn, q, k, v, iters=3)
    rows.append(("chunked_attention_us", us, f"S={S} H={H} D={D} causal"))
    f_local = jax.jit(lambda q, k, v: chunked_attention(
        q, k, v, causal=True, window=128, q_chunk=128, k_chunk=128))
    us_local = _time(f_local, q, k, v, iters=3)
    rows.append(("windowed_attention_us", us_local, "window=128 (sub-quadratic)"))
    rows.append(("local_vs_global_speedup", us / max(us_local, 1e-9),
                 "window cuts O(S^2) -> O(S*W)"))
    return rows
