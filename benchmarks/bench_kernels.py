"""Kernel micro-benchmarks (CPU host): XLA paths wall-time + Pallas interpret
correctness spot checks. Real TPU timings are out of scope on this host — the
structural (roofline) analysis of the kernels lives in benchmarks/roofline.py.

Headline comparisons:
  * fused multi-table embedding engine (one take + segment_sum over the
    pooled tables, custom sparse-gradient VJP) vs the legacy per-table loop;
  * skew-aware engine on a zipfian (α≈1.05) stream at Criteo-ish shapes —
    PR 1's fused kernel on a hashed (scattered) layout vs the frequency-
    packed placement + hot-row cache engine, uniform traffic as control.

``REPRO_BENCH_FAST=1`` (the runner's ``--fast``) shrinks every shape so the
CI bench-smoke job finishes in a couple of minutes.
"""
from __future__ import annotations

import os
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row
from repro.data.synthetic import RowFreqCounter, zipf_indices
from repro.kernels import ref
from repro.kernels.fused_embedding import fused_embedding_bag, table_offsets
from repro.models.attention import chunked_attention
from repro.sharding.policy import EmbeddingPlan, pack_hot_ranges

FAST = os.environ.get("REPRO_BENCH_FAST", "") == "1"


def _time(fn, *args, iters=5, repeats=3) -> float:
    """Best-of-``repeats`` mean over ``iters`` calls (shields host noise)."""
    jax.block_until_ready(fn(*args))                     # warmup / compile
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(iters):
            jax.block_until_ready(fn(*args))
        best = min(best, (time.perf_counter() - t0) / iters * 1e6)   # us
    return best


def run() -> List[Row]:
    rows: List[Row] = []
    key = jax.random.PRNGKey(0)

    # --- single-table embedding bag (legacy shape) --------------------------
    table = jax.random.normal(key, (100_000, 16))
    idx = jax.random.randint(jax.random.fold_in(key, 1), (512, 8), 0, 100_000)
    f_ref = jax.jit(lambda t, i: ref.embedding_bag_ref(t, i, combiner="sum"))
    us = _time(f_ref, table, idx)
    rows.append(("embedding_bag_ref_us", us, "B=512 hot=8 D=16 R=100k"))

    # --- fused multi-table engine vs per-table loop -------------------------
    T, H, B, D, R_t = 8, 4, 512, 16, 20_000
    rows_per = (R_t,) * T
    offs = table_offsets(rows_per)
    pool = jax.random.normal(jax.random.fold_in(key, 2), (T * R_t, D))
    midx = jax.random.randint(jax.random.fold_in(key, 3), (B, T, H), 0, R_t)
    note = f"B={B} T={T} hot={H} D={D} R={R_t}/table"

    def loop_fwd(p, i):
        outs = [ref.embedding_bag_ref(
            jax.lax.dynamic_slice_in_dim(p, offs[t], R_t), i[:, t, :],
            combiner="sum") for t in range(T)]
        return jnp.stack(outs, axis=1)

    fwd_plan = EmbeddingPlan(offsets=offs, combiner="sum")

    def fused_fwd(p, i):
        return fused_embedding_bag(p, i, plan=fwd_plan)

    f_loop = jax.jit(loop_fwd)
    f_fused = jax.jit(fused_fwd)
    us_loop = _time(f_loop, pool, midx, iters=20)
    us_fused = _time(f_fused, pool, midx, iters=20)
    rows.append(("embed_fwd_per_table_loop_us", us_loop, note))
    rows.append(("embed_fwd_fused_us", us_fused, note))
    rows.append(("embed_fwd_fused_speedup", us_loop / max(us_fused, 1e-9),
                 "fused take vs T gathers"))

    g_loop = jax.jit(jax.grad(lambda p, i: jnp.sum(jnp.sin(loop_fwd(p, i)))))
    g_fused = jax.jit(jax.grad(lambda p, i: jnp.sum(jnp.sin(fused_fwd(p, i)))))
    us_loop_bwd = _time(g_loop, pool, midx, iters=10)
    us_fused_bwd = _time(g_fused, pool, midx, iters=10)
    rows.append(("embed_fwdbwd_per_table_loop_us", us_loop_bwd, note))
    rows.append(("embed_fwdbwd_fused_us", us_fused_bwd, note))
    rows.append(("embed_fwdbwd_fused_speedup",
                 us_loop_bwd / max(us_fused_bwd, 1e-9),
                 "deduped-COO VJP vs T scatter-adds (sort-based dedupe "
                 "keeps dense and sparse backward bit-identical)"))

    # Pallas interpret correctness of the fused kernel (small shapes: the
    # interpreter is slow, this is a numerics check, not a timing)
    sidx = midx[:32]
    out_p = fused_embedding_bag(pool, sidx, method="interpret", plan=fwd_plan)
    err = float(jnp.abs(out_p - f_fused(pool, sidx)).max())
    rows.append(("fused_embedding_pallas_err", err,
                 "double-buffered interpret vs ref, B=32"))

    # --- skew-aware engine: zipfian stream, placement + hot-row cache -------
    rows.extend(_skew_rows())

    # --- fused sparse backward + row-wise optimizer update ------------------
    rows.extend(_fused_update_rows())

    # --- chunked attention (the dry-run lowering path) ----------------------
    B, S, Hh, Dh = (1, 256, 8, 64) if FAST else (1, 1024, 8, 64)
    q = jax.random.normal(jax.random.fold_in(key, 4), (B, S, Hh, Dh), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 5), (B, S, Hh // 2, Dh))
    v = jax.random.normal(jax.random.fold_in(key, 6), (B, S, Hh // 2, Dh))
    f_attn = jax.jit(lambda q, k, v: chunked_attention(q, k, v, causal=True,
                                                       q_chunk=256, k_chunk=256))
    us = _time(f_attn, q, k, v, iters=3)
    rows.append(("chunked_attention_us", us, f"S={S} H={Hh} D={Dh} causal"))
    f_local = jax.jit(lambda q, k, v: chunked_attention(
        q, k, v, causal=True, window=128, q_chunk=128, k_chunk=128))
    us_local = _time(f_local, q, k, v, iters=3)
    rows.append(("windowed_attention_us", us_local, "window=128 (sub-quadratic)"))
    rows.append(("local_vs_global_speedup", us / max(us_local, 1e-9),
                 "window cuts O(S^2) -> O(S*W)"))
    return rows


def _skew_rows() -> List[Row]:
    """Zipfian vs uniform traffic: PR 1's fused kernel on a hashed (scattered)
    row layout against the skew-aware engine (frequency-packed placement +
    hot-row cache). Ties into the bench_fig12_hotps skew scenario: the same
    power-law row popularity that overloads one PS is what the placement
    plan and the VMEM cache exploit.
    """
    rows: List[Row] = []
    if FAST:
        T, H, B, D, R_t, budget = 8, 4, 256, 16, 20_000, 8 * 128
    else:
        T, H, B, D, R_t, budget = 26, 4, 512, 16, 1_000_000, 26 * 512
    alpha = 1.05
    offs = table_offsets((R_t,) * T)
    note = f"B={B} T={T} hot={H} D={D} R={R_t}/table alpha={alpha}"

    rng = np.random.default_rng(0)
    pool = jnp.asarray(rng.standard_normal((T * R_t, D), np.float32))

    # popularity ranks drawn from the power law; a hashed vocab scatters them
    # uniformly over each table (PR 1's layout), frequency-aware placement
    # packs them into the leading rows (rank == row id)
    ranks = np.stack([zipf_indices(rng, R_t, (B, H), alpha)
                      for _ in range(T)], axis=1)            # (B, T, H)
    perm = np.stack([rng.permutation(R_t) for _ in range(T)])
    scattered = perm[np.arange(T)[None, :, None], ranks]
    uniform = rng.integers(0, R_t, (B, T, H))

    # plan the cache from measured frequencies, through the real stack
    ctr = RowFreqCounter((R_t,) * T)
    ctr.update(ranks)
    plan = pack_hot_ranges(ctr.counts, (R_t,) * T, budget)
    hit = ctr.hit_rate(plan)
    rows.append(("embed_cache_hit_rate_zipf", hit,
                 f"top-{budget} rows ({budget / (T * R_t):.2%} of pool)"))

    base_plan = EmbeddingPlan(offsets=offs, combiner="sum")
    cache_plan = base_plan.with_replan(plan, None)

    def fused(p, i):
        return fused_embedding_bag(p, i, plan=base_plan)

    def engine(p, i):
        return fused_embedding_bag(p, i, plan=cache_plan)

    f_fused = jax.jit(fused)
    f_engine = jax.jit(engine)
    j_scat = jnp.asarray(scattered.astype(np.int32))
    j_pack = jnp.asarray(ranks.astype(np.int32))
    j_unif = jnp.asarray(uniform.astype(np.int32))

    iters = 10 if FAST else 20
    us_scat = _time(f_fused, pool, j_scat, iters=iters)
    us_pack = _time(f_fused, pool, j_pack, iters=iters)
    us_cache = _time(f_engine, pool, j_pack, iters=iters)
    us_unif = _time(f_fused, pool, j_unif, iters=iters)
    us_unif_c = _time(f_engine, pool, j_unif, iters=iters)
    rows.append(("embed_fwd_zipf_scattered_us", us_scat,
                 f"PR1 fused, hashed layout; {note}"))
    rows.append(("embed_fwd_zipf_packed_us", us_pack,
                 "freq-packed placement, no cache (ablation)"))
    rows.append(("embed_fwd_zipf_cache_us", us_cache,
                 "engine: packed placement + hot-row cache"))
    rows.append(("embed_fwd_zipf_cache_speedup", us_scat / max(us_cache, 1e-9),
                 "fused+cache vs PR1 fused on zipfian stream"))
    rows.append(("embed_fwd_uniform_us", us_unif, "PR1 fused, uniform control"))
    rows.append(("embed_fwd_uniform_cache_parity",
                 us_unif / max(us_unif_c, 1e-9),
                 "engine on uniform traffic (expect ~1.0, no regression)"))

    # interpret-mode numerics: the double-buffered cache path must BIT-match
    # the XLA fallback (small shapes; the interpreter is slow)
    sm = 16
    sm_plan = EmbeddingPlan(offsets=table_offsets((64,) * 8), combiner="sum")
    out_c = fused_embedding_bag(pool[:8 * 64], ranks[:sm, :8, :].clip(0, 63),
                                method="interpret",
                                plan=sm_plan.with_replan((16,) * 8, None))
    out_x = fused_embedding_bag(pool[:8 * 64], ranks[:sm, :8, :].clip(0, 63),
                                method="xla", plan=sm_plan)
    exact = float(np.asarray(jnp.abs(out_c - out_x)).max())
    rows.append(("fused_cache_interpret_err", exact,
                 "hot-row cache interpret vs XLA (0 = bit-exact)"))
    return rows


def _fused_update_rows() -> List[Row]:
    """Fused sparse backward + row-wise adagrad vs the dense reference.

    The dense baseline is what the train step did before the sparse-update
    seam: materialize the full (R, D) pool cotangent through the embedding
    VJP, then run the optimizer over EVERY row (touched or not). The fused
    path dedupes the batch's rows into COO row grads and updates exactly
    those — O(touched) instead of O(R) — the acceptance bar is >= 2x on the
    full 1M-row/table zipfian workload.
    """
    rows: List[Row] = []
    from repro.kernels import ops as kernel_ops

    if FAST:
        T, H, B, D, R_t = 8, 4, 256, 16, 20_000
    else:
        T, H, B, D, R_t = 26, 4, 512, 16, 1_000_000
    alpha = 1.05
    plan = EmbeddingPlan(offsets=table_offsets((R_t,) * T), combiner="sum")
    note = f"B={B} T={T} hot={H} D={D} R={R_t}/table alpha={alpha}"
    lr, eps = 0.05, 1e-10

    rng = np.random.default_rng(1)
    pool = jnp.asarray(rng.standard_normal((T * R_t, D), np.float32))
    acc = jnp.asarray(np.abs(rng.standard_normal((T * R_t, D), np.float32)))
    ranks = np.stack([zipf_indices(rng, R_t, (B, H), alpha)
                      for _ in range(T)], axis=1)
    idx = jnp.asarray(ranks.astype(np.int32))
    ct = jnp.asarray(rng.standard_normal((B, T, D), np.float32))

    def dense_step(p, a, i, g):
        _, vjp = jax.vjp(lambda q: fused_embedding_bag(q, i, plan=plan), p)
        (dp,) = vjp(g)                               # dense (R, D) cotangent
        new_a = a + jnp.square(dp)                   # full-pool adagrad
        return p - lr * dp / (jnp.sqrt(new_a) + eps), new_a

    def sparse_step(p, a, i, g):
        r, v, _ = kernel_ops.sparse_row_grads(p, i, g, plan=plan)
        return kernel_ops.fused_row_update(p, r, v, a, kind="adagrad",
                                           impl="xla", lr=lr, eps=eps)

    # pools are donated, as in the real train step (state threads through the
    # jit): without donation both paths pay two full (R, D) copies per call,
    # which buries the O(touched)-vs-O(R) difference under O(R) memcpy
    def timed_threaded(step):
        f = jax.jit(step, donate_argnums=(0, 1))
        p, a = jnp.array(pool), jnp.array(acc)       # fresh donatable copies
        p, a = f(p, a, idx, ct)                      # warmup / compile
        jax.block_until_ready((p, a))
        best = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            for _ in range(3):
                p, a = f(p, a, idx, ct)
            jax.block_until_ready((p, a))
            best = min(best, (time.perf_counter() - t0) / 3 * 1e6)   # us
        return best

    us_dense = timed_threaded(dense_step)
    us_sparse = timed_threaded(sparse_step)
    rows.append(("fused_bwd_opt_dense_us", us_dense,
                 f"dense VJP + full-pool adagrad; {note}"))
    rows.append(("fused_bwd_opt_sparse_us", us_sparse,
                 "sparse_row_grads + fused row update (touched rows only)"))
    rows.append(("fused_bwd_opt_speedup", us_dense / max(us_sparse, 1e-9),
                 "fused backward+update vs dense reference (bar: >= 2x)"))

    # numerics: the Pallas row-update kernel (interpret) must BIT-match the
    # XLA fallback AND the dense full-pool reference on the touched rows —
    # small shapes, jitted on both sides so FMA contraction is identical
    sp, sa = pool[:8 * 64], acc[:8 * 64]
    s_plan = EmbeddingPlan(offsets=table_offsets((64,) * 8), combiner="sum")
    si = jnp.asarray(ranks[:16, :8, :].clip(0, 63).astype(np.int32))
    sg = ct[:16, :8, :]

    def small_step(impl):
        def step(p, a, i, g):
            r, v, _ = kernel_ops.sparse_row_grads(p, i, g, plan=s_plan)
            return kernel_ops.fused_row_update(p, r, v, a, kind="adagrad",
                                               impl=impl, lr=lr, eps=eps)
        return jax.jit(step)

    px, ax = small_step("xla")(sp, sa, si, sg)
    pi, ai = small_step("interpret")(sp, sa, si, sg)
    err = max(float(jnp.abs(px - pi).max()), float(jnp.abs(ax - ai).max()))
    rows.append(("fused_bwd_opt_err", err,
                 "row-update interpret vs XLA (0 = bit-exact)"))
    return rows
