"""Benchmark harness: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--only fig7,fig12] [--fast]
[--json BENCH.json]`` prints ``bench,metric,value,notes`` CSV rows. ``--fast``
switches bench modules to small-shape quick mode (exported as the
``REPRO_BENCH_FAST=1`` env var) so CI smoke jobs finish in minutes; ``--json``
additionally writes the rows to a machine-readable file for artifact upload,
so the per-PR perf trajectory accumulates.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback

BENCHES = [
    ("table1", "benchmarks.bench_table1_cost"),
    ("fig7", "benchmarks.bench_fig7_jct"),
    ("fig8", "benchmarks.bench_fig8_convergence"),
    ("fig9", "benchmarks.bench_fig9_warmstart"),
    ("fig10", "benchmarks.bench_fig10_autoscaling"),
    ("fig11", "benchmarks.bench_fig11_perfmodel"),
    ("fig12", "benchmarks.bench_fig12_hotps"),
    ("fig13", "benchmarks.bench_fig13_straggler"),
    ("fig14", "benchmarks.bench_fig14_cluster"),
    ("fig15", "benchmarks.bench_fig15_jct_cdf"),
    ("chaos", "benchmarks.bench_chaos"),
    ("kernels", "benchmarks.bench_kernels"),
    ("roofline", "benchmarks.roofline"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated bench keys (e.g. fig7,fig12)")
    ap.add_argument("--fast", action="store_true",
                    help="small-shape quick mode (sets REPRO_BENCH_FAST=1)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as JSON (CI artifact)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    if args.fast:
        os.environ["REPRO_BENCH_FAST"] = "1"

    print("bench,metric,value,notes")
    failed = []
    json_rows = []
    for key, module_name in BENCHES:
        if only and key not in only:
            continue
        t0 = time.perf_counter()
        try:
            import importlib
            mod = importlib.import_module(module_name)
            rows = mod.run()
            for name, value, notes in rows:
                print(f"{key},{name},{value:.6g},{notes}")
                json_rows.append({"bench": key, "metric": name,
                                  "value": float(value), "notes": notes})
            elapsed = time.perf_counter() - t0
            print(f"{key},_elapsed_s,{elapsed:.1f},")
            json_rows.append({"bench": key, "metric": "_elapsed_s",
                              "value": elapsed, "notes": ""})
        except Exception as e:
            failed.append(key)
            print(f"{key},_error,nan,{type(e).__name__}: {e}")
            traceback.print_exc(file=sys.stderr)
    if args.json:
        fast = os.environ.get("REPRO_BENCH_FAST", "") == "1"
        with open(args.json, "w") as f:
            json.dump({"fast": fast, "rows": json_rows}, f, indent=2)
    if failed:
        print(f"#FAILED: {','.join(failed)}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
