"""Fig 10: cold-start auto-scaling — throughput ramp within the same wall time.

One job trained from scratch (cold start, empty config DB) under each elastic
scheduler, adjusting every 3 minutes. Reports throughput at 3-minute marks;
paper: DLRover-RM reaches ~2× the baselines' throughput by minute 12.
"""
from __future__ import annotations

from typing import Dict, List


from benchmarks.common import Row, fast_mode
import repro.sim.cluster as C
from repro.sim.workload import generate_jobs


def run(seed: int = 5) -> List[Row]:
    rows: List[Row] = []
    jobs = generate_jobs(1, seed=seed, mean_msamples=500.0)  # long job
    marks = [6, 12] if fast_mode() else [6, 12, 18, 24, 30]
    curves: Dict[str, Dict[int, float]] = {}
    for name in ["dlrover_rm", "es", "optimus"]:
        sim = C.CloudSim(name, total_cpu=8192, total_mem_gb=65536, seed=7,
                         enable_failures=False)
        trace = []
        orig = C.CloudSim._throughput

        def patched(self, rj, now, _t=trace):
            out = orig(self, rj, now)
            _t.append((now, out[0]))
            return out

        C.CloudSim._throughput = patched
        try:
            sim.run(jobs, horizon_s=(15 if fast_mode() else 40) * 60)
        finally:
            C.CloudSim._throughput = orig
        curves[name] = {}
        dt = 15.0
        for mark in marks:
            # cumulative samples by the mark (robust to restart windows)
            done = sum(thp * dt for t, thp in trace if t < mark * 60)
            curves[name][mark] = float(done)
            rows.append((f"cum_samples_min{mark}.{name}", done, "samples"))
    for mark in marks:
        d = curves["dlrover_rm"][mark]
        e = max(curves["es"][mark], curves["optimus"][mark], 1.0)
        rows.append((f"dlrover_advantage_min{mark}", d / e,
                     "x best baseline, cumulative; paper ~1.7-2.5x by min 12"))
    return rows
