"""§Roofline: three-term roofline per (arch × shape × mesh) from dry-run JSONs.

    compute    = HLO_FLOPs / (chips × 197 TFLOP/s bf16)
    memory     = HLO_bytes / (chips × 819 GB/s HBM)
    collective = collective_bytes / (chips × 50 GB/s ICI)

HLO_FLOPs / bytes come from ``compiled.cost_analysis()`` of the SPMD module.
XLA:CPU reports the *per-device partitioned program*, so terms are already
per-chip; collective bytes are parsed from the partitioned HLO text
(result-shape bytes per collective op ≈ per-device traffic).

Reads experiments/dryrun/*.json (written by repro.launch.dryrun) and emits
one row per cell plus the dominant-term classification.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from benchmarks.common import Row

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")


def analyze_record(rec: Dict) -> Optional[Dict]:
    if "skipped" in rec or "error" in rec:
        return None
    n_dev = rec.get("n_devices", 256)
    # trip-count-exact FLOPs (jaxpr) preferred; fall back to XLA's count
    flops = rec.get("jaxpr_flops", 0.0) / n_dev
    if not flops:
        flops = rec.get("cost", {}).get("flops", 0.0)
    hbm_bytes = rec.get("analytic_hbm", {}).get("total") or \
        rec.get("cost", {}).get("bytes accessed", 0.0)
    coll = rec.get("analytic_collectives", {}).get("total")
    if coll is None:
        coll = rec.get("collectives", {}).get("total", 0.0)
    t_compute = flops / PEAK_FLOPS
    t_memory = hbm_bytes / HBM_BW
    t_coll = coll / ICI_BW
    dominant = max((("compute", t_compute), ("memory", t_memory),
                    ("collective", t_coll)), key=lambda kv: kv[1])[0]
    model_fl = rec.get("model_flops", 0.0)
    useful = model_fl / (flops * n_dev) if flops else 0.0
    bound = max(t_compute, t_memory, t_coll)
    ideal = (model_fl / n_dev) / PEAK_FLOPS if n_dev else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "t_compute": t_compute, "t_memory": t_memory, "t_collective": t_coll,
        "dominant": dominant,
        "useful_flop_frac": useful,
        "roofline_frac": (ideal / bound) if bound else 0.0,
        "flops": flops, "hbm_bytes": hbm_bytes, "coll_bytes": coll,
    }


BASELINE_DIR = "experiments/dryrun_baseline"


def load_all(dirname: str = DRYRUN_DIR) -> List[Dict]:
    """Load optimized-sweep cells; fall back to baseline artifacts for cells
    the (long-running) optimized sweep hasn't re-compiled yet."""
    by_name: Dict[str, str] = {}
    for src in (BASELINE_DIR, dirname):
        for path in sorted(glob.glob(os.path.join(src, "*.json"))):
            by_name[os.path.basename(path)] = path
    out = []
    for name in sorted(by_name):
        path = by_name[name]
        with open(path) as f:
            rec = json.load(f)
        a = analyze_record(rec)
        if a is not None:
            a["provenance"] = "optimized" if path.startswith(dirname) else "baseline"
            out.append(a)
        else:
            out.append({"arch": rec.get("arch"), "shape": rec.get("shape"),
                        "mesh": rec.get("mesh"),
                        "skipped": rec.get("skipped") or rec.get("error")})
    return out


def run() -> List[Row]:
    rows: List[Row] = []
    cells = load_all()
    if not cells:
        rows.append(("no_dryrun_artifacts", 0.0,
                     f"run repro.launch.dryrun --all first (dir={DRYRUN_DIR})"))
        return rows
    n_done = 0
    for c in cells:
        tag = f"{c['arch']}.{c['shape']}.{c['mesh']}"
        if "skipped" in c:
            rows.append((f"{tag}.skipped", 0.0, str(c["skipped"])[:80]))
            continue
        n_done += 1
        rows.append((f"{tag}.t_compute_s", c["t_compute"], ""))
        rows.append((f"{tag}.t_memory_s", c["t_memory"], ""))
        rows.append((f"{tag}.t_collective_s", c["t_collective"], ""))
        rows.append((f"{tag}.dominant", {"compute": 0.0, "memory": 1.0,
                                         "collective": 2.0}[c["dominant"]],
                     c["dominant"]))
        rows.append((f"{tag}.useful_flop_frac", c["useful_flop_frac"],
                     "MODEL_FLOPS / (HLO_FLOPs x chips)"))
        rows.append((f"{tag}.roofline_frac", c["roofline_frac"],
                     "ideal compute time / dominant term"))
    rows.append(("cells_analyzed", float(n_done), ""))
    return rows
