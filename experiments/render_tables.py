"""Render §Dry-run and §Roofline markdown tables from experiments/dryrun JSONs.

    PYTHONPATH=src python experiments/render_tables.py [--dir experiments/dryrun]
"""
import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def fmt_bytes(x):
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(x) < 1024:
            return f"{x:.1f}{unit}"
        x /= 1024
    return f"{x:.1f}PB"


def fmt_t(x):
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.1f}us"
    if x < 1:
        return f"{x*1e3:.2f}ms"
    return f"{x:.2f}s"


def load(dirname):
    recs = []
    for p in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default=None, help="filter mesh (16x16/2x16x16)")
    args = ap.parse_args()
    recs = load(args.dir)

    print("| arch | shape | mesh | status | compile | HLO flops/dev | jaxpr flops (global) | "
          "coll bytes/dev | temp mem/dev |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        if args.mesh and r.get("mesh") != args.mesh:
            continue
        tag = f"| {r['arch']} | {r['shape']} | {r['mesh']} "
        if "skipped" in r:
            print(tag + f"| SKIP ({r['skipped'][:40]}...) | | | | | |")
            continue
        if "error" in r:
            print(tag + f"| **ERROR** {r['error'][:60]} | | | | | |")
            continue
        cost = r.get("cost", {})
        mem = r.get("memory", {})
        print(tag + f"| ok | {r.get('compile_s', 0):.0f}s "
              f"| {cost.get('flops', 0):.3g} "
              f"| {r.get('jaxpr_flops', 0):.3g} "
              f"| {fmt_bytes(r.get('collectives', {}).get('total', 0))} "
              f"| {fmt_bytes(mem.get('temp_size_in_bytes', 0))} |")

    print("\n\n## Roofline (per device, jaxpr-exact FLOPs; 16x16 mesh)\n")
    print("| arch | shape | compute | memory | collective | dominant | "
          "MODEL/HLO flops | roofline frac |")
    print("|---|---|---|---|---|---|---|---|")
    for r in recs:
        if r.get("mesh") != (args.mesh or "16x16"):
            continue
        if "skipped" in r or "error" in r:
            continue
        n_dev = r.get("n_devices", 256)
        fl_dev = r.get("jaxpr_flops", 0) / n_dev
        t_c = fl_dev / PEAK_FLOPS
        hbm = r.get("analytic_hbm", {}).get("total", 0)
        t_m = hbm / HBM_BW
        coll = r.get("analytic_collectives", {}).get("total", 0)
        t_n = coll / ICI_BW
        dom = max((("compute", t_c), ("memory", t_m), ("collective", t_n)),
                  key=lambda kv: kv[1])[0]
        model_fl = r.get("model_flops", 0)
        useful = model_fl / max(r.get("jaxpr_flops", 1), 1)
        ideal = model_fl / n_dev / PEAK_FLOPS
        bound = max(t_c, t_m, t_n)
        frac = ideal / bound if bound else 0
        print(f"| {r['arch']} | {r['shape']} | {fmt_t(t_c)} | {fmt_t(t_m)} "
              f"| {fmt_t(t_n)} | **{dom}** | {useful:.2f} | {frac:.2f} |")


if __name__ == "__main__":
    main()
