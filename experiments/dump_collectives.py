import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Per-op collective dump for one dry-run cell (perf-iteration instrument).

    PYTHONPATH=src python experiments/dump_collectives.py --arch X --shape Y
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch.dryrun import _SHAPE_RE, _DTYPE_BYTES, _COLL_OPS


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--top", type=int, default=25)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from repro.configs.registry import get_arch, get_shape
    from repro.launch.mesh import make_production_mesh
    from repro.models.registry import build_model
    from repro.sharding.policy import logical_spec, make_policy, use_policy
    from repro.train import optim as optim_mod
    from repro.train import trainer as trainer_mod

    cfg = get_arch(args.arch)
    shape = get_shape(args.shape)
    mesh = make_production_mesh()
    policy = make_policy(mesh, cfg, shape)
    api = build_model(cfg)
    optimizer = optim_mod.make("adam", 1e-3)

    with mesh, use_policy(policy):
        from repro.launch.dryrun import batch_shardings
        b_sh = batch_shardings(api, shape, policy)
        in_specs = api.input_specs(shape)
        if shape.kind == "train":
            state = jax.eval_shape(
                lambda k: trainer_mod.make_train_state(api, optimizer, k),
                jax.random.PRNGKey(0))
            st_sh = logical_spec(None, trainer_mod.train_state_specs(api, "adam"),
                                 policy)
            step = trainer_mod.make_train_step(api, optimizer, remat=True)
            lowered = jax.jit(step, in_shardings=(st_sh, b_sh),
                              donate_argnums=(0,)).lower(state, in_specs)
        elif shape.kind == "prefill":
            params = jax.eval_shape(api.init, jax.random.PRNGKey(0))
            p_sh = logical_spec(None, api.param_specs(), policy)
            lowered = jax.jit(api.prefill, in_shardings=(p_sh, b_sh)).lower(
                params, in_specs)
        else:
            params = jax.eval_shape(api.init, jax.random.PRNGKey(0))
            p_sh = logical_spec(None, api.param_specs(), policy)
            cache = jax.eval_shape(lambda: api.init_cache(
                shape.global_batch, shape.seq_len, jnp.bfloat16))
            c_sh = logical_spec(None, api.cache_specs(), policy)
            t_sh = {"tokens": policy.sharding(("batch", None))}
            fn = lambda p, c, b: api.decode_step(p, c, b["tokens"])
            lowered = jax.jit(fn, in_shardings=(p_sh, c_sh, t_sh),
                              donate_argnums=(1,)).lower(params, cache, in_specs)
        hlo = lowered.compile().as_text()

    # group lines by computation (track while-body membership)
    ops = []
    comp = "main"
    for line in hlo.splitlines():
        s = line.strip()
        if s.endswith("{") and ("(" in s) and "->" in s:
            comp = s.split()[0].lstrip("%")
        for op in _COLL_OPS:
            if f" {op}(" in s or f" {op}-start(" in s:
                lhs = s.split("=", 1)[1] if "=" in s else s
                idx = lhs.find(f" {op}")
                rtype = lhs[:idx]
                total = sum(
                    int.__mul__(
                        _DTYPE_BYTES.get(d, 4),
                        eval("*".join(dims.split(",")) or "1"))
                    for d, dims in _SHAPE_RE.findall(rtype))
                ops.append((total, op, comp, rtype.strip()[:90]))
    ops.sort(reverse=True)
    print(f"{len(ops)} collective ops; top {args.top}:")
    for total, op, comp, rtype in ops[: args.top]:
        print(f"{total/1e6:10.1f} MB  {op:20s} in {comp[:40]:40s} {rtype}")


if __name__ == "__main__":
    main()
