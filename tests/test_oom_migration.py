"""OOM prediction (§5.3) and seamless-migration (§5.2) mechanics."""
import pytest

from repro.core.migration import MigrationPlan, MigrationSession, MigrationTimings, Phase
from repro.core.oom import OOMPredictor


def test_oom_linear_growth_prediction():
    pred = OOMPredictor(dtype_bytes=4, emb_dim=16)
    for i in range(10):
        pred.observe(samples_consumed=i * 1000, mem_bytes=1e9 + i * 1e7)
    # slope = 1e7 bytes / 1000 samples = 1e4 bytes/sample
    assert pred.growth_rate() == pytest.approx(1e4, rel=1e-3)
    assert pred.predict(at_samples=20_000) == pytest.approx(1e9 + 2e8, rel=1e-3)
    hit, peak = pred.will_oom(capacity_bytes=1.05e9, samples_to_completion=50_000)
    assert hit and peak > 1.05e9
    ok, _ = pred.will_oom(capacity_bytes=1e12, samples_to_completion=50_000)
    assert not ok


def test_oom_categories_per_sample():
    pred = OOMPredictor(dtype_bytes=4, emb_dim=16)
    pred.observe(0, 0.0)
    pred.observe(1000, 64_000.0)       # 64 bytes/sample = 1 new category
    assert pred.categories_per_sample() == pytest.approx(1.0, rel=1e-3)


def test_oom_noisy_plateau_no_false_positive():
    pred = OOMPredictor()
    for i in range(20):
        pred.observe(i * 1000, 1e9 + (i % 2))    # flat
    hit, _ = pred.will_oom(2e9, 1e9)
    assert not hit


def test_seamless_vs_stop_restart_downtime():
    t = MigrationTimings()
    seamless = MigrationPlan(seamless=True, use_flash_ckpt=True, timings=t)
    trad = MigrationPlan(seamless=False, use_flash_ckpt=False, timings=t)
    assert seamless.downtime_seconds() == t.flash_ckpt_save_s + t.flash_ckpt_load_s
    assert trad.downtime_seconds() == \
        t.rds_ckpt_save_s + t.provision_s + t.rds_ckpt_load_s
    assert seamless.downtime_seconds() < 0.05 * trad.downtime_seconds()


def test_migration_session_overlaps_training():
    plan = MigrationPlan(seamless=True, use_flash_ckpt=True)
    hooks = []
    s = MigrationSession(plan, started_at=0.0, on_sync=lambda: hooks.append(1))
    s.start()
    assert s.phase is Phase.PROVISIONING and not s.training_blocked
    s.tick(100.0)
    assert s.phase is Phase.PROVISIONING          # still training
    s.tick(plan.timings.provision_s + 1)
    assert s.phase is Phase.SYNC and s.training_blocked and hooks == [1]
    s.tick(plan.timings.provision_s + 1 + plan.downtime_seconds() + 0.1)
    assert s.phase is Phase.DONE
    assert s.downtime_accum == pytest.approx(plan.downtime_seconds())
