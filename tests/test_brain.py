"""ClusterBrain three-stage controller: warm-start refinement, staggered
NSGA-II caching, right-sizing reclaim, degradation decay, history pooling,
and the trust-region / idle-penalty operator knobs."""
import numpy as np
import pytest

from repro.core.autoscaler import (
    ClusterCapacity, JobState, generate_candidates, predicted_idle_frac,
    weighted_greedy_select,
)
from repro.core.brain import (
    DEGRADATION_WEIGHTS, ClusterBrain, reclaim_allocation, refine_allocation,
)
from repro.core.perf_model import (
    JobResources, JobStatics, PerfModel, synthesize_t_iter,
)
from repro.core.warm_start import JobMeta

STAT = JobStatics(batch_size=512, model_size=3.2e8, bandwidth=1e9, emb_dim=16)
ALPHA = [3.48e-3, 2.36e-3, 0.68e-3, 2.45e-5]
BETA = 2.45e-3


def _model(seed=0):
    rng = np.random.default_rng(seed)
    obs = []
    for _ in range(48):
        r = JobResources(w=int(rng.integers(1, 24)), p=int(rng.integers(1, 12)),
                         cpu_w=float(rng.integers(1, 32)),
                         cpu_p=float(rng.integers(1, 32)))
        obs.append((r, STAT, synthesize_t_iter(r, STAT, ALPHA, BETA)))
    return PerfModel().fit(obs)


def _job(jid="j0", current=None, remaining=5e6, model=None):
    return JobState(job_id=jid, statics=STAT,
                    current=current or JobResources(w=4, p=2, cpu_w=8, cpu_p=8),
                    model=model or _model(),
                    remaining_samples=remaining)


def _capacity(cpu=2048.0, mem=16384.0):
    return ClusterCapacity(cpu, mem)


# ------------------------------------------------------------------ stage 1
def test_refine_allocation_requires_model_gain():
    """The grid only overrides the warm start when predicted throughput per
    dollar improves by the pinned margin; a fitted model on a throughput
    surface that rewards more worker CPU should move the plan somewhere
    with no worse predicted efficiency."""
    model = _model()
    plan = JobResources(w=2, p=1, cpu_w=2, cpu_p=2)
    refined = refine_allocation(plan, STAT, model)
    from repro.core.autoscaler import Prices, resource_cost

    def eff(r):
        return model.throughput(r, STAT) / resource_cost(r, Prices())

    assert eff(refined) >= eff(plan)


def test_allocate_uses_default_before_history():
    brain = ClusterBrain(_capacity())
    meta = JobMeta("wide_deep", dense_params=1e6, emb_rows=5e6, emb_dim=16,
                   batch_size=512, dataset_samples=1e7, user="u0")
    default = JobResources(w=4, p=2, cpu_w=8, cpu_p=8)
    assert brain.allocate(meta, STAT, default=default) == default


# ------------------------------------------------------------------ stage 2
def test_adjust_caches_nsga_fronts_between_rounds():
    """The staggered cadence: a job's Pareto search runs on round 1, is
    cached on round 2, and re-runs once ``reoptimize_every`` rounds pass."""
    brain = ClusterBrain(_capacity(), reoptimize_every=2)
    job = _job()
    brain.adjust([job])
    assert brain._optimized_at[job.job_id] == 1
    brain.adjust([job])
    assert brain._optimized_at[job.job_id] == 1      # cache hit
    brain.adjust([job])
    assert brain._optimized_at[job.job_id] == 3      # cadence reached


def test_reclaim_shrinks_overprovisioned_job():
    """An allocation with grossly over-provisioned PS CPU (the §2.2 idle
    reservation the greedy will never touch, since shrinking has tg ≤ 0)
    must be right-sized by the reclaim pass (cost down, predicted thp held).
    """
    model = _model()
    fat = JobResources(w=4, p=4, cpu_w=8.0, cpu_p=32.0)
    cand = reclaim_allocation(fat, STAT, model, slack=0.03, min_cut=0.15)
    assert cand is not None
    from repro.core.autoscaler import Prices, resource_cost
    assert resource_cost(cand, Prices()) <= 0.85 * resource_cost(fat, Prices())
    assert model.throughput(cand, STAT) >= 0.97 * model.throughput(fat, STAT)


def test_reclaim_cooldown_prevents_thrash():
    brain = ClusterBrain(_capacity(), reclaim_cooldown=3)
    fat = JobResources(w=8, p=4, cpu_w=32.0, cpu_p=16.0)
    job = _job(current=fat)
    plans1 = brain.adjust([job])
    if job.job_id in plans1:                 # planned (grown or reclaimed)...
        plans2 = brain.adjust([job])
        # ...the very next round must leave it alone (cooldown)
        assert job.job_id not in plans2 or \
            brain._last_plan_round[job.job_id] == brain._round


# ------------------------------------------------------------------ stage 3
def test_degradation_decays_with_halflife():
    brain = ClusterBrain(_capacity(), degradation_halflife_s=600.0)
    p0 = brain.report_degradation("j0", "failure", now=0.0)
    assert p0 == pytest.approx(DEGRADATION_WEIGHTS["failure"])
    assert brain.degradation_penalty("j0", now=600.0) == pytest.approx(p0 / 2)
    assert brain.degradation_penalty("j0", now=1200.0) == pytest.approx(p0 / 4)
    # events accumulate on top of the decayed mass
    p1 = brain.report_degradation("j0", "oom", now=600.0)
    assert p1 == pytest.approx(p0 / 2 + DEGRADATION_WEIGHTS["oom"])


def test_degraded_job_gets_priority_in_greedy():
    """Eqn 14: under contention for the last capacity slice, the degraded
    job's boosted WG weight wins the plan."""
    model = _model()
    a, b = _job("a", model=model), _job("b", model=model)
    cands = {jid: generate_candidates(_job(jid, model=model), seed=0)
             for jid in ("a", "b")}
    # capacity admits only a small delta over current allocations
    current = a.current.total_cpu() + b.current.total_cpu()
    cap = ClusterCapacity(current + 40.0, 16384.0)
    b.degradation = 10.0
    plans = weighted_greedy_select([a, b], cands, cap)
    if plans:                                # contention ⇒ degraded job first
        assert "b" in plans or "a" not in plans


# ------------------------------------------------------------ operator knobs
def test_trust_region_bounds_candidates():
    """trust_factor=2 keeps every NSGA candidate within [v/2, 2v] of the
    current allocation — no extrapolation outside the region the locally
    fitted model has earned."""
    job = _job(current=JobResources(w=4, p=2, cpu_w=8, cpu_p=8))
    cands = generate_candidates(job, seed=0, trust_factor=2.0)
    assert cands
    for c in cands:
        r = c.resources
        assert 2 <= r.w <= 8
        assert 1 <= r.p <= 4
        assert 4.0 <= r.cpu_w <= 16.0
        assert 4.0 <= r.cpu_p <= 16.0


def test_predicted_idle_frac_in_unit_interval_and_penalizes():
    job = _job()
    frac = predicted_idle_frac(job, job.current)
    assert 0.0 <= frac <= 1.0
    # an absurdly over-provisioned plan predicts more idle reservation
    fat = JobResources(w=4, p=2, cpu_w=32.0, cpu_p=32.0)
    assert predicted_idle_frac(job, fat) >= frac


def test_record_history_fits_kind_model_and_warm_starts():
    brain = ClusterBrain(_capacity())
    meta = JobMeta("wide_deep", dense_params=1e6, emb_rows=5e6, emb_dim=16,
                   batch_size=512, dataset_samples=1e7, user="u0")
    rng = np.random.default_rng(0)
    obs = []
    for _ in range(16):
        r = JobResources(w=int(rng.integers(1, 16)), p=int(rng.integers(1, 8)),
                         cpu_w=float(rng.integers(2, 16)),
                         cpu_p=float(rng.integers(2, 16)))
        obs.append((r, STAT, synthesize_t_iter(r, STAT, ALPHA, BETA)))
    final = JobResources(w=8, p=2, cpu_w=16, cpu_p=8)
    brain.record_history(meta, STAT, obs, final_config=final, throughput=1e4)
    assert "wide_deep" in brain.kind_models
    assert brain.kind_models["wide_deep"].fitted
    # a similar new job warm-starts off the recorded config, not the default
    plan = brain.allocate(meta, STAT, default=JobResources(w=1, p=1,
                                                           cpu_w=1, cpu_p=1))
    assert plan != JobResources(w=1, p=1, cpu_w=1, cpu_p=1)


def test_complete_clears_all_ledgers():
    brain = ClusterBrain(_capacity())
    job = _job("gone")
    brain.adjust([job])
    brain.report_degradation("gone", "failure", now=0.0)
    brain.complete("gone", throughput=0.0)
    assert "gone" not in brain._optimized_at
    assert "gone" not in brain._cached
    assert "gone" not in brain._last_plan_round
    assert brain.degradation_penalty("gone") == 0.0
