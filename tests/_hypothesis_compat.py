"""Hypothesis compatibility shim for the test suite.

When ``hypothesis`` is installed (the ``test`` extra in pyproject.toml), this
module re-exports the real ``given``/``settings``/``strategies``. When it is
absent, a minimal deterministic stand-in runs each property test over a fixed
set of pseudo-random examples instead of erroring at import time — the suite
degrades to example-based testing rather than losing 6 modules to collection
errors.

Only the strategy surface this repo uses is shimmed: ``integers``,
``floats``, ``booleans``, ``sampled_from``, ``lists``.
"""
from __future__ import annotations

import functools
import inspect
import random
import zlib

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised when hypothesis missing
    HAVE_HYPOTHESIS = False

    _MAX_SHIM_EXAMPLES = 16

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng: random.Random):
            return self._draw(rng)

    class _St:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def sampled_from(options):
            options = list(options)
            return _Strategy(lambda rng: options[rng.randrange(len(options))])

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng):
                size = rng.randint(min_size, max_size)
                return [elements.draw(rng) for _ in range(size)]
            return _Strategy(draw)

    st = _St()

    def given(**strategy_kwargs):
        def decorator(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                conf = getattr(wrapper, "_shim_settings", {})
                n = min(int(conf.get("max_examples", 10)), _MAX_SHIM_EXAMPLES)
                # deterministic per-test seed: same examples on every run
                rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
                for _ in range(max(n, 1)):
                    example = {k: s.draw(rng)
                               for k, s in strategy_kwargs.items()}
                    fn(*args, **kwargs, **example)

            # hide the strategy params from pytest's fixture resolution
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items()
                if name not in strategy_kwargs])
            del wrapper.__wrapped__
            return wrapper
        return decorator

    def settings(**config):
        def decorator(fn):
            fn._shim_settings = config
            return fn
        return decorator
