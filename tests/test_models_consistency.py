"""Decode-vs-forward equivalence: sequential decode with caches must match the
parallel (teacher-forced) forward pass for every decoder arch family —
validates KV rings, SSD recurrence, RG-LRU scan, MoE dispatch.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import reduce_config
from repro.configs.registry import ARCHS
from repro.models import transformer as tf
from repro.models.registry import build_model

DECODER_ARCHS = [a for a in sorted(ARCHS) if ARCHS[a].family != "encdec"]


@pytest.mark.parametrize("arch", DECODER_ARCHS)
def test_decode_matches_forward(arch):
    cfg = reduce_config(ARCHS[arch])
    if cfg.n_experts:
        # avoid capacity-drop divergence (train drops, decode cannot)
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(1))
    B, S = 2, 24
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size)
    full_logits, _ = tf.forward_lm(params, toks, cfg)
    cache = api.init_cache(B, S, jnp.float32)
    cache, seq_logits = tf.prefill_into_cache(params, cache, toks, cfg)
    err = float(jnp.max(jnp.abs(full_logits - seq_logits)))
    rel = err / float(jnp.max(jnp.abs(full_logits)))
    assert rel < 2e-4, (arch, rel)


def test_remat_does_not_change_loss():
    cfg = reduce_config(ARCHS["llama3.2-3b"])
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.ones((2, 16), jnp.int32),
             "targets": jnp.ones((2, 16), jnp.int32)}
    l0 = api.loss(params, batch, remat=False)
    l1 = api.loss(params, batch, remat=True)
    assert float(jnp.abs(l0 - l1)) < 1e-6


def test_grad_compress_roundtrip_close():
    from repro.train.optim import compress_grads
    g = {"a": jnp.linspace(-1, 1, 128)}
    gc = compress_grads(g)
    assert float(jnp.max(jnp.abs(g["a"] - gc["a"]))) < 1e-2
