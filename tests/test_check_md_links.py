"""Unit tests for scripts/check_md_links.py (link resolution + orphan BFS).

The docs CI job trusts this checker; these tests pin its semantics on
synthetic trees: relative-link resolution, fence/inline-code exclusion,
anchor handling, edge recording, and README-rooted reachability.
"""
import importlib.util
import os
import sys

SCRIPT = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                      "scripts", "check_md_links.py")
_spec = importlib.util.spec_from_file_location("check_md_links", SCRIPT)
cml = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(cml)


def _write(root, rel, text):
    path = os.path.join(root, rel)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w", encoding="utf-8") as f:
        f.write(text)
    return path


# ------------------------------------------------------------- check_file
def test_broken_relative_link_reported(tmp_path):
    root = str(tmp_path)
    page = _write(root, "README.md", "intro\n[gone](docs/missing.md)\n")
    broken = list(cml.check_file(page, root))
    assert broken == [(2, "docs/missing.md")]


def test_resolving_link_and_edge_recording(tmp_path):
    root = str(tmp_path)
    _write(root, "docs/API.md", "api\n")
    page = _write(root, "README.md", "[api](docs/API.md)\n")
    edges = {}
    assert list(cml.check_file(page, root, edges)) == []
    key = os.path.normpath(page)
    assert edges[key] == {os.path.normpath(os.path.join(root, "docs/API.md"))}


def test_remote_and_pure_anchor_links_skipped(tmp_path):
    root = str(tmp_path)
    page = _write(root, "README.md",
                  "[a](https://example.com/x)\n"
                  "[b](http://example.com)\n"
                  "[c](mailto:x@example.com)\n"
                  "[d](#local-section)\n")
    assert list(cml.check_file(page, root)) == []


def test_anchor_suffix_stripped_before_resolution(tmp_path):
    root = str(tmp_path)
    _write(root, "docs/API.md", "# Section\n")
    page = _write(root, "README.md",
                  "[ok](docs/API.md#section)\n"
                  "[bad](docs/nope.md#section)\n")
    assert list(cml.check_file(page, root)) == [(2, "docs/nope.md#section")]


def test_code_fences_and_inline_code_ignored(tmp_path):
    root = str(tmp_path)
    page = _write(root, "README.md",
                  "```\n[fenced](nowhere.md)\n```\n"
                  "use `[inline](also-nowhere.md)` for links\n"
                  "[real](truly-nowhere.md)\n")
    assert list(cml.check_file(page, root)) == [(5, "truly-nowhere.md")]


def test_directory_target_resolves(tmp_path):
    root = str(tmp_path)
    os.makedirs(os.path.join(root, "src"))
    page = _write(root, "README.md", "[src tree](src)\n")
    assert list(cml.check_file(page, root)) == []


def test_relative_link_from_nested_page(tmp_path):
    root = str(tmp_path)
    _write(root, "README.md", "root\n")
    page = _write(root, "docs/DEEP.md", "[up](../README.md)\n[peer](GONE.md)\n")
    assert list(cml.check_file(page, root)) == [(2, "GONE.md")]


# ----------------------------------------------------------- iter_md_files
def test_iter_md_files_skips_hidden_and_cache_dirs(tmp_path):
    root = str(tmp_path)
    _write(root, "README.md", "x\n")
    _write(root, "docs/A.md", "x\n")
    _write(root, ".git/HEAD.md", "x\n")
    _write(root, "__pycache__/junk.md", "x\n")
    found = {os.path.relpath(p, root) for p in cml.iter_md_files(root)}
    assert found == {"README.md", os.path.join("docs", "A.md")}


# ------------------------------------------------------------ find_orphans
def _build_graph(root):
    md_files = list(cml.iter_md_files(root))
    edges = {}
    broken = []
    for path in md_files:
        broken += [(path, ln, t) for ln, t in cml.check_file(path, root, edges)]
    return md_files, edges, broken


def test_orphan_detected_and_transitive_reachability(tmp_path):
    root = str(tmp_path)
    _write(root, "README.md", "[a](docs/A.md)\n")
    _write(root, "docs/A.md", "[b](B.md)\n")
    _write(root, "docs/B.md", "leaf, reachable via A\n")
    _write(root, "docs/ORPHAN.md", "nobody links here\n")
    md_files, edges, broken = _build_graph(root)
    assert broken == []
    orphans = cml.find_orphans(md_files, edges, root)
    assert orphans == [os.path.join("docs", "ORPHAN.md")]


def test_no_orphans_when_everything_linked(tmp_path):
    root = str(tmp_path)
    _write(root, "README.md", "[a](docs/A.md)\n")
    _write(root, "docs/A.md", "fin\n")
    md_files, edges, _ = _build_graph(root)
    assert cml.find_orphans(md_files, edges, root) == []


def test_non_docs_pages_never_count_as_orphans(tmp_path):
    root = str(tmp_path)
    _write(root, "README.md", "no links\n")
    _write(root, "CHANGES.md", "unlinked, but not under docs/\n")
    md_files, edges, _ = _build_graph(root)
    assert cml.find_orphans(md_files, edges, root) == []


def test_cycles_terminate(tmp_path):
    root = str(tmp_path)
    _write(root, "README.md", "[a](docs/A.md)\n")
    _write(root, "docs/A.md", "[b](B.md)\n")
    _write(root, "docs/B.md", "[a again](A.md)\n")
    md_files, edges, _ = _build_graph(root)
    assert cml.find_orphans(md_files, edges, root) == []


# ------------------------------------------------------------------- main()
def test_main_ok_and_failure_exit_codes(tmp_path, capsys, monkeypatch):
    root = str(tmp_path)
    _write(root, "README.md", "[a](docs/A.md)\n")
    _write(root, "docs/A.md", "fin\n")
    monkeypatch.setattr(sys, "argv", ["check_md_links.py", root])
    assert cml.main() == 0
    assert "ok:" in capsys.readouterr().out

    _write(root, "docs/ORPHAN.md", "unlinked\n")
    _write(root, "docs/A.md", "[gone](GONE.md)\n")
    assert cml.main() == 1
    out = capsys.readouterr().out
    assert "BROKEN LINKS" in out and "ORPHANED DOCS PAGES" in out
