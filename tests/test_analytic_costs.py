"""Analytic collective/HBM models: structural invariants (single-device)."""
import pytest

from repro.configs.base import SHAPES
from repro.configs.registry import ARCHS
from repro.launch.costs import analytic_collectives, analytic_hbm_bytes
from repro.sharding.policy import ShardingPolicy


def _policy(rules):
    """Mesh-free policy stub: axis sizes resolved via a fake mesh dict."""
    class FakeMesh:
        def __init__(self, shape):
            self.shape = shape
            self.size = 1
            for v in shape.values():
                self.size *= v
    pol = ShardingPolicy(mesh=FakeMesh({"data": 16, "model": 16}), rules=rules)
    return pol


RULES_TRAIN = {"fsdp": ("data",), "tp": ("model",), "batch": ("data",),
               "kvseq": (), "kv_heads": ("model",)}
RULES_DECODE_LOCAL = {"fsdp": (), "tp": ("model",), "batch": ("data",),
                      "kvseq": (), "kv_heads": ("model",)}


def test_train_collectives_have_fsdp_and_grad_terms():
    cfg = ARCHS["llama3.2-3b"]
    out = analytic_collectives(cfg, SHAPES["train_4k"], _policy(RULES_TRAIN),
                               param_bytes_total=cfg.param_count() * 2.0)
    assert out["fsdp_allgather"] > 0
    assert out["grad_reduce"] > 0
    assert out["total"] >= out["fsdp_allgather"]


def test_decode_without_fsdp_has_no_weight_gather():
    cfg = ARCHS["chameleon-34b"]
    out = analytic_collectives(cfg, SHAPES["decode_32k"],
                               _policy(RULES_DECODE_LOCAL),
                               param_bytes_total=cfg.param_count() * 2.0)
    assert out["fsdp_allgather"] == 0.0


def test_hbm_decode_dominated_by_weights_and_cache():
    cfg = ARCHS["llama3.2-3b"]
    out = analytic_hbm_bytes(cfg, SHAPES["decode_32k"],
                             _policy(RULES_DECODE_LOCAL),
                             param_bytes_total=cfg.param_count() * 2.0,
                             flops_per_device=1e9)
    assert out["params"] > 0 and out["kv_cache_read"] > 0
    assert out["total"] == pytest.approx(sum(v for k, v in out.items()
                                             if k != "total"))


def test_local_window_caps_cache_traffic():
    full = ARCHS["command-r-35b"]          # global attention
    swa = ARCHS["mixtral-8x22b"]           # 4096-window SWA
    pol = _policy(RULES_DECODE_LOCAL)
    a = analytic_hbm_bytes(full, SHAPES["decode_32k"], pol,
                           full.param_count() * 2.0, 1e9)
    b = analytic_hbm_bytes(swa, SHAPES["decode_32k"], pol,
                           swa.param_count() * 2.0, 1e9)
    # per-layer cache read for SWA is window/seq_len of the full-attn one
    per_layer_full = a["kv_cache_read"] / full.num_layers
    per_layer_swa = b["kv_cache_read"] / swa.num_layers
    assert per_layer_swa < per_layer_full / 4


def test_decode_policy_drops_fsdp_for_small_models():
    from repro.configs.base import SHAPES
    import jax as _jax
    if len(_jax.devices()) < 2:
        # rule resolution itself is pure: build a mesh-free check via policy fn
        from repro.sharding.policy import make_policy
        mesh = None
        pol = make_policy(mesh, ARCHS["llama3.2-3b"], SHAPES["decode_32k"])
        assert pol.mesh is None           # degenerate on 1 device; covered in
        # tests/test_policy.py subprocess for the real multi-device meshes
