"""Hot-row cache + double-buffered fused embedding engine: correctness.

Covers the acceptance contract of the skew-aware engine:
  * fused+cache output BIT-matches the XLA fallback on uniform and zipfian
    index streams, for all three combiners, weighted and unweighted, on the
    double-buffered interpret kernel (the TPU code path's numerics).
  * gradients flow through cached rows exactly as through uncached ones
    (global ids are preserved; the segment_sum backward is shared).
  * the frequency estimator, RecShard-style placement planners, and the
    job-master placement service agree with brute-force oracles.
  * DLRM threads ``cfg.hot_rows_k`` / ``table_hot`` down to the fused call
    without changing numerics.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.dlrm_models import WIDE_DEEP, reduced_dlrm
from repro.core.sharding_service import ParameterPlacementService
from repro.data.synthetic import (RowFreqCounter, criteo_batch,
                                  estimate_row_freq, zipf_indices)
from repro.kernels import ops, ref
from repro.kernels.fused_embedding import (cache_slot_offsets,
                                           encode_hot_indices,
                                           fused_embedding_bag, hot_row_ids,
                                           table_offsets)
from repro.models import dlrm
from repro.sharding.policy import (EmbeddingPlan, balanced_vocab_ranges,
                                   frequency_permutation, pack_hot_ranges,
                                   placement_imbalance)

jax.config.update("jax_platform_name", "cpu")

ROWS_PER_TABLE = (64, 40, 96, 24)
OFFSETS = table_offsets(ROWS_PER_TABLE)
TABLE_HOT = (16, 8, 24, 6)


def _plan(combiner="sum", *, block_b=8, table_hot=None):
    return EmbeddingPlan(offsets=OFFSETS, combiner=combiner,
                         block_b=block_b, table_hot=table_hot)


def _stream(B=13, H=4, D=16, seed=0, alpha=0.0):
    """Pool + (B, T, H) local indices; zipfian when alpha > 0."""
    rng = np.random.default_rng(seed)
    T = len(ROWS_PER_TABLE)
    pool = jnp.asarray(rng.standard_normal((sum(ROWS_PER_TABLE), D),
                                           np.float32))
    idx = np.stack([zipf_indices(rng, rows, (B, H), alpha)
                    for rows in ROWS_PER_TABLE], axis=1)
    w = jnp.asarray(rng.uniform(0.1, 2.0, (B, T, H)).astype(np.float32))
    return pool, jnp.asarray(idx.astype(np.int32)), w


# ---------------------------------------------------------------------------
# bit-exactness of the cached, double-buffered kernel
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("combiner", ["sum", "mean", "max"])
@pytest.mark.parametrize("weighted", [False, True])
@pytest.mark.parametrize("alpha", [0.0, 1.05])
def test_cache_bitmatches_xla_fallback(combiner, weighted, alpha):
    pool, idx, w = _stream(alpha=alpha)
    weights = w if weighted else None
    out_c = fused_embedding_bag(
        pool, idx, weights, method="interpret",
        plan=_plan(combiner, block_b=4, table_hot=TABLE_HOT))
    out_x = fused_embedding_bag(pool, idx, weights, method="xla",
                                plan=_plan(combiner))
    np.testing.assert_array_equal(np.asarray(out_c), np.asarray(out_x))


def test_cache_off_equals_cache_on_interpret():
    """The cache only re-routes reads: outputs are bit-identical."""
    pool, idx, _ = _stream(alpha=1.05)
    out_nc = fused_embedding_bag(pool, idx, method="interpret",
                                 plan=_plan(block_b=4))
    out_c = fused_embedding_bag(
        pool, idx, method="interpret",
        plan=_plan(block_b=4, table_hot=TABLE_HOT))
    np.testing.assert_array_equal(np.asarray(out_nc), np.asarray(out_c))


def test_cache_partial_tail_block():
    """B not divisible by block_b: host-side padding covers the tail."""
    pool, idx, _ = _stream(B=11, alpha=1.05)
    out_c = fused_embedding_bag(
        pool, idx, method="interpret",
        plan=_plan(block_b=4, table_hot=TABLE_HOT))
    out_x = fused_embedding_bag(pool, idx, method="xla", plan=_plan())
    np.testing.assert_array_equal(np.asarray(out_c), np.asarray(out_x))


def test_all_hot_and_none_hot_extremes():
    pool, idx, _ = _stream(alpha=1.05)
    all_hot = ROWS_PER_TABLE            # whole pool cached
    none_hot = (0,) * len(ROWS_PER_TABLE)
    out_x = fused_embedding_bag(pool, idx, method="xla", plan=_plan())
    for hot in (all_hot, none_hot):
        out = fused_embedding_bag(
            pool, idx, method="interpret",
            plan=_plan(block_b=4, table_hot=hot))
        np.testing.assert_array_equal(np.asarray(out), np.asarray(out_x))


@pytest.mark.parametrize("combiner", ["sum", "mean", "max"])
@pytest.mark.parametrize("weighted", [False, True])
def test_grads_through_cached_rows(combiner, weighted):
    """Cached rows keep their global ids: pool/weight grads match the
    plain-autodiff oracle AND the uncached engine exactly."""
    pool, idx, w = _stream(alpha=1.05)
    weights = w if weighted else None

    def loss(method, hot):
        def f(p, wt):
            out = fused_embedding_bag(
                p, idx, wt, method=method,
                plan=_plan(combiner, block_b=4, table_hot=hot))
            return jnp.sum(jnp.sin(out))
        return f

    def loss_ref(p, wt):
        out = ref.fused_embedding_bag_ref(p, idx, wt, offsets=OFFSETS,
                                          combiner=combiner)
        return jnp.sum(jnp.sin(out))

    args = (pool, weights)
    gp_c, gw_c = jax.grad(loss("interpret", TABLE_HOT), argnums=(0, 1))(*args)
    gp_n, gw_n = jax.grad(loss("interpret", None), argnums=(0, 1))(*args)
    gp_r, gw_r = jax.grad(loss_ref, argnums=(0, 1))(*args)
    np.testing.assert_array_equal(np.asarray(gp_c), np.asarray(gp_n))
    np.testing.assert_allclose(np.asarray(gp_c), np.asarray(gp_r),
                               atol=1e-5, rtol=1e-5)
    if weighted:
        np.testing.assert_array_equal(np.asarray(gw_c), np.asarray(gw_n))
        np.testing.assert_allclose(np.asarray(gw_c), np.asarray(gw_r),
                                   atol=1e-5, rtol=1e-5)


def test_encode_hot_indices():
    idx = jnp.asarray(np.array([[[0, 15], [7, 8]]]), jnp.int32)  # (1, 2, 2)
    offs, hot = (0, 100), (16, 8)
    gidx = idx + jnp.asarray(offs, jnp.int32)[None, :, None]
    enc, hit = encode_hot_indices(gidx, offs, hot)
    # table 0: both local ids < 16 -> cache slots 0 and 15
    # table 1: local 7 < 8 -> slot 16+7; local 8 >= 8 -> cold global row 108
    np.testing.assert_array_equal(np.asarray(enc)[0, 0], [-1, -16])
    np.testing.assert_array_equal(np.asarray(enc)[0, 1], [-(16 + 7) - 1, 108])
    np.testing.assert_array_equal(np.asarray(hit)[0], [[True, True],
                                                       [True, False]])
    assert cache_slot_offsets(hot) == (0, 16)
    np.testing.assert_array_equal(
        hot_row_ids(offs, hot),
        np.concatenate([np.arange(16), 100 + np.arange(8)]))


def test_xla_path_ignores_cache_bit_identically():
    pool, idx, _ = _stream(alpha=1.05)
    out_a = fused_embedding_bag(pool, idx, method="xla", plan=_plan())
    out_b = fused_embedding_bag(pool, idx, method="xla",
                                plan=_plan(table_hot=TABLE_HOT))
    np.testing.assert_array_equal(np.asarray(out_a), np.asarray(out_b))


# ---------------------------------------------------------------------------
# frequency estimation + placement planning
# ---------------------------------------------------------------------------
def test_zipf_indices_skewed_and_bounded():
    rng = np.random.default_rng(0)
    ids = zipf_indices(rng, 1000, 20_000, 1.05)
    assert ids.min() >= 0 and ids.max() < 1000
    counts = np.bincount(ids, minlength=1000)
    assert counts[0] == counts.max()          # rank 0 is the hottest row
    assert counts[:10].sum() > 5 * counts[500:510].sum()
    uni = zipf_indices(rng, 1000, 20_000, 0.0)
    assert np.bincount(uni, minlength=1000).max() < counts[0]


def test_row_freq_counter_exact():
    ctr = RowFreqCounter((4, 6))
    sparse = np.array([[[0, 0], [5, 1]], [[3, 0], [5, 5]]])   # (2, 2, 2)
    ctr.update(sparse)
    expect = np.zeros(10, np.int64)
    for g in [0, 0, 4 + 5, 4 + 1, 3, 0, 4 + 5, 4 + 5]:
        expect[g] += 1
    np.testing.assert_array_equal(ctr.counts, expect)
    assert ctr.n_lookups == 8
    assert ctr.top_k(1).tolist() == [9]       # global row 9 seen 3x
    assert ctr.hit_rate((1, 0)) == pytest.approx(3 / 8)   # row 0 hits
    assert ctr.hit_rate((4, 6)) == pytest.approx(1.0)


def test_pack_hot_ranges_budget_and_zero_rows():
    counts = np.array([9, 7, 1, 0, 8, 6, 0, 0])
    plan = pack_hot_ranges(counts, (4, 4), 4)
    assert plan == (2, 2)                     # rows 0,1 and 4,5 are hottest
    assert pack_hot_ranges(counts, (4, 4), 0) == (0, 0)
    # never caches rows that were never touched, even with a huge budget
    plan_all = pack_hot_ranges(counts, (4, 4), 8)
    assert plan_all == (3, 2)


def test_balanced_ranges_beat_uniform_striping():
    cfg = dataclasses.replace(reduced_dlrm(WIDE_DEEP),
                              table_rows=(256,) * 6, zipf_alpha=1.05)
    ctr = estimate_row_freq(cfg, seed=3, n_samples=512, batch_size=128)
    n_ps = 4
    balanced = balanced_vocab_ranges(ctr.counts, n_ps)
    uniform = [(i * ctr.total_rows // n_ps, (i + 1) * ctr.total_rows // n_ps)
               for i in range(n_ps)]
    # contiguous, exhaustive, non-overlapping cover of the pool
    assert balanced[0][0] == 0 and balanced[-1][1] == ctr.total_rows
    for (a, b), (c, d) in zip(balanced, balanced[1:]):
        assert b == c
    imb_b = placement_imbalance(ctr.counts, balanced)
    imb_u = placement_imbalance(ctr.counts, uniform)
    assert imb_b < imb_u
    assert imb_b < 1.35


def test_balanced_ranges_no_spurious_empty_shard():
    # the target-crossing row goes to whichever side balances better
    ranges = balanced_vocab_ranges(np.array([4, 6]), 2)
    assert ranges == [(0, 1), (1, 2)]
    # one dominant row: its shard is inherently heavy, but the other rows
    # must not be dragged along with it leaving an empty shard
    ranges = balanced_vocab_ranges(np.array([1, 1, 1, 1, 100]), 2)
    assert ranges == [(0, 4), (4, 5)]


def test_table_hot_respects_budget():
    cfg = dataclasses.replace(reduced_dlrm(WIDE_DEEP), hot_rows_k=3)
    assert cfg.n_tables == 6
    assert sum(cfg.table_hot) == 3            # never exceeds the VMEM budget
    cfg = dataclasses.replace(cfg, hot_rows_k=20)
    assert cfg.table_hot == (4, 4, 3, 3, 3, 3)
    cfg = dataclasses.replace(cfg, hot_rows_k=10 ** 6)
    assert cfg.table_hot == cfg.table_rows    # clipped to the tables


def test_frequency_permutation_packs_hot_rows():
    counts = np.array([1, 9, 3, 0, 2, 8])
    perm = frequency_permutation(counts, (3, 3))
    assert sorted(perm.tolist()) == list(range(6))
    # each table keeps its own rows; hottest old row maps to local rank 0
    assert perm[1] == 0 and perm[2] == 1 and perm[0] == 2
    assert perm[5] == 3 and perm[4] == 4 and perm[3] == 5
    packed = np.zeros(6, counts.dtype)
    packed[perm] = counts
    assert list(packed[:3]) == sorted(counts[:3], reverse=True)
    assert list(packed[3:]) == sorted(counts[3:], reverse=True)


def test_parameter_placement_service():
    svc = ParameterPlacementService((8, 8))
    svc.report_batch("w0", np.array([[[0, 1], [2, 2]]]))      # (1, 2, 2)
    svc.report_counts("w1", np.eye(16, dtype=np.int64)[3])    # one hit row 3
    counts = svc.counts
    assert counts[0] == 1 and counts[1] == 1 and counts[3] == 1
    assert counts[8 + 2] == 2 and counts.sum() == 5
    assert svc.hot_plan(1) == (0, 1)          # global row 10 is hottest
    ranges = svc.ps_ranges(2)
    assert ranges[0][0] == 0 and ranges[-1][1] == 16
    assert svc.imbalance(2) >= 1.0


# ---------------------------------------------------------------------------
# DLRM plumbing: cfg budget -> fused call, numerics unchanged
# ---------------------------------------------------------------------------
def test_dlrm_threads_table_hot(monkeypatch):
    cfg = dataclasses.replace(reduced_dlrm(WIDE_DEEP), zipf_alpha=1.05,
                              hot_rows_k=24)
    assert cfg.table_hot == (4,) * cfg.n_tables
    params = dlrm.init_dlrm(cfg, jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v)
             for k, v in criteo_batch(cfg, 7, np.arange(8)).items()}

    seen = []
    real = ops.fused_embedding_bag

    def spy(*args, **kwargs):
        seen.append(kwargs["plan"].table_hot)
        return real(*args, **kwargs)

    monkeypatch.setattr(ops, "fused_embedding_bag", spy)
    logit_hot = dlrm.dlrm_forward(params, batch, cfg)
    assert seen == [cfg.table_hot, cfg.table_hot]   # deep + wide calls
    cfg_off = dataclasses.replace(cfg, hot_rows_k=0)
    logit_off = dlrm.dlrm_forward(params, batch, cfg_off)
    np.testing.assert_array_equal(np.asarray(logit_hot),
                                  np.asarray(logit_off))
    # a measured plan can override the config default
    seen.clear()
    plan = (2,) * cfg.n_tables
    dlrm.dlrm_forward(params, batch, cfg, table_hot=plan)
    assert seen == [plan, plan]


def test_criteo_batch_zipf_plumbing():
    cfg = dataclasses.replace(reduced_dlrm(WIDE_DEEP),
                              table_rows=(512,) * 6, zipf_alpha=1.05)
    b1 = criteo_batch(cfg, 3, np.arange(64))
    b2 = criteo_batch(cfg, 3, np.arange(64))
    np.testing.assert_array_equal(b1["sparse"], b2["sparse"])  # deterministic
    # skew shows up: leading rows dominate
    ctr = RowFreqCounter(cfg.table_rows)
    ctr.update(b1["sparse"])
    assert ctr.hit_rate((16,) * 6) > 0.25
    # alpha=0 path is byte-identical to the pre-skew generator
    cfg0 = dataclasses.replace(cfg, zipf_alpha=0.0)
    b0 = criteo_batch(cfg0, 3, np.arange(4))
    b0x = criteo_batch(cfg0, 3, np.arange(4), zipf_alpha=0.0)
    np.testing.assert_array_equal(b0["sparse"], b0x["sparse"])
