"""Auto-scaling stage (Eqns 7–14): candidates, quota, greedy capacity."""
import numpy as np

from repro.core.autoscaler import (
    MAX_JOB_CPU, ClusterCapacity, JobState, Prices, generate_candidates,
    get_scaler, list_scalers, register_scaler, resource_cost, weight_wg,
    weighted_greedy_select,
)
from repro.core.perf_model import JobResources, JobStatics, PerfModel, \
    synthesize_t_iter

STAT = JobStatics(batch_size=512, model_size=3.2e8, bandwidth=1e9, emb_dim=16)
ALPHA = [3.48e-3, 2.36e-3, 0.68e-3, 2.45e-5]


def _fitted_model(seed=0):
    rng = np.random.default_rng(seed)
    obs = []
    for _ in range(48):
        r = JobResources(w=int(rng.integers(1, 24)), p=int(rng.integers(1, 12)),
                         cpu_w=float(rng.integers(1, 32)),
                         cpu_p=float(rng.integers(1, 32)))
        obs.append((r, STAT, synthesize_t_iter(r, STAT, ALPHA, 2.45e-3,
                                               noise=0.02, rng=rng)))
    return PerfModel().fit(obs)


def _job(jid="j0", w=2, p=1):
    return JobState(jid, STAT, JobResources(w=w, p=p, cpu_w=4, cpu_p=4),
                    _fitted_model(), remaining_samples=5e6)


def test_candidates_respect_quota_and_improve_throughput():
    job = _job()
    cands = generate_candidates(job, seed=0)
    assert cands
    base = job.model.throughput(job.current, STAT)
    assert any(c.thp > base for c in cands)
    for c in cands:
        if c.tg > 0:
            assert c.resources.total_cpu() <= MAX_JOB_CPU + 1e-6


def test_weighted_greedy_respects_capacity():
    jobs = [_job(f"j{i}") for i in range(3)]
    cands = {j.job_id: generate_candidates(j, seed=i)
             for i, j in enumerate(jobs)}
    cap = ClusterCapacity(total_cpu=100.0, total_mem_gb=1e6)
    plans = weighted_greedy_select(jobs, cands, cap)
    used = sum((plans.get(j.job_id) or j.current).total_cpu() for j in jobs)
    assert used <= cap.total_cpu + 1e-6


def test_wg_prioritizes_short_jobs():
    j_short = _job("s")
    j_short.remaining_samples = 1e5
    j_long = _job("l")
    j_long.remaining_samples = 1e8
    assert weight_wg(j_short, 1000.0) > weight_wg(j_long, 1000.0)


def test_resource_cost_linear():
    p = Prices(cpu=1.0, mem_gb=0.0)
    r = JobResources(w=2, p=1, cpu_w=4, cpu_p=4)
    assert resource_cost(r, p) == r.total_cpu()


def test_plugin_api():
    @register_scaler("noop_test")
    def noop(jobs, capacity):
        return {}
    assert "noop_test" in list_scalers()
    assert get_scaler("noop_test")([], ClusterCapacity(1, 1)) == {}
    assert "dlrover_rm" in list_scalers()
