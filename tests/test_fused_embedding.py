"""Fused multi-table embedding engine: correctness, gradients, dispatch.

Covers the acceptance contract of the fused engine:
  * Pallas (interpret) and XLA forward match the pooled oracle to <= 1e-5 for
    every combiner, weighted and unweighted.
  * jax.grad through the custom-VJP fused path matches jax.grad through the
    plain-autodiff ref path (sparse table grads + lookup-weight grads).
  * dlrm_forward issues exactly ONE fused call for the deep part (plus one
    for the wide part in wide_deep), independent of n_tables.
  * legacy single-table embedding_bag honours combiner when weights are given.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.dlrm_models import DCN, WIDE_DEEP, XDEEPFM, reduced_dlrm
from repro.data.synthetic import criteo_batch
from repro.kernels import common, ops, ref
from repro.kernels import embedding_bag as legacy_eb
from repro.kernels.fused_embedding import fused_embedding_bag, table_offsets
from repro.models import dlrm
from repro.sharding.policy import EmbeddingPlan

jax.config.update("jax_platform_name", "cpu")

ROWS_PER_TABLE = (40, 24, 64, 8)
OFFSETS = table_offsets(ROWS_PER_TABLE)


def _plan(combiner="sum", block_b=8, **kw):
    return EmbeddingPlan(offsets=OFFSETS, combiner=combiner,
                         block_b=block_b, **kw)


def _inputs(B=6, H=4, D=16, seed=0):
    key = jax.random.PRNGKey(seed)
    T = len(ROWS_PER_TABLE)
    pool = jax.random.normal(key, (sum(ROWS_PER_TABLE), D))
    idx = jnp.stack(
        [jax.random.randint(jax.random.fold_in(key, t), (B, H), 0, rows)
         for t, rows in enumerate(ROWS_PER_TABLE)], axis=1)
    w = jax.random.uniform(jax.random.fold_in(key, 99), (B, T, H),
                           minval=0.1, maxval=2.0)
    return pool, idx, w


def test_table_offsets():
    assert OFFSETS == (0, 40, 64, 128)
    assert table_offsets([5]) == (0,)


@pytest.mark.parametrize("combiner", ["sum", "mean", "max"])
@pytest.mark.parametrize("weighted", [False, True])
@pytest.mark.parametrize("method", ["xla", "interpret"])
def test_fused_forward_matches_ref(combiner, weighted, method):
    pool, idx, w = _inputs()
    weights = w if weighted else None
    out = fused_embedding_bag(pool, idx, weights, method=method,
                              plan=_plan(combiner, block_b=4))
    expect = ref.fused_embedding_bag_ref(pool, idx, weights, offsets=OFFSETS,
                                         combiner=combiner)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-5, rtol=1e-5)


def test_fused_partial_batch_block():
    """B not divisible by block_b exercises the clamped tail block."""
    pool, idx, _ = _inputs(B=7)
    out = fused_embedding_bag(pool, idx, method="interpret",
                              plan=_plan(block_b=4))
    expect = ref.fused_embedding_bag_ref(pool, idx, offsets=OFFSETS,
                                         combiner="sum")
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("combiner", ["sum", "mean", "max"])
@pytest.mark.parametrize("weighted", [False, True])
def test_fused_grads_match_ref(combiner, weighted):
    pool, idx, w = _inputs()
    weights = w if weighted else None

    def loss_fused(p, wt):
        out = fused_embedding_bag(p, idx, wt, plan=_plan(combiner))
        return jnp.sum(jnp.sin(out))

    def loss_ref(p, wt):
        out = ref.fused_embedding_bag_ref(p, idx, wt, offsets=OFFSETS,
                                          combiner=combiner)
        return jnp.sum(jnp.sin(out))

    if weighted:
        gp_f, gw_f = jax.grad(loss_fused, argnums=(0, 1))(pool, weights)
        gp_r, gw_r = jax.grad(loss_ref, argnums=(0, 1))(pool, weights)
        np.testing.assert_allclose(np.asarray(gw_f), np.asarray(gw_r),
                                   atol=1e-5, rtol=1e-5)
    else:
        gp_f = jax.grad(loss_fused)(pool, None)
        gp_r = jax.grad(loss_ref)(pool, None)
    np.testing.assert_allclose(np.asarray(gp_f), np.asarray(gp_r),
                               atol=1e-5, rtol=1e-5)


def test_fused_grad_through_pallas_forward():
    """The custom VJP makes the Pallas forward trainable (interpret here)."""
    pool, idx, _ = _inputs()
    g_int = jax.grad(lambda p: jnp.sum(fused_embedding_bag(
        p, idx, method="interpret",
        plan=_plan("mean", block_b=4))))(pool)
    g_ref = jax.grad(lambda p: jnp.sum(ref.fused_embedding_bag_ref(
        p, idx, offsets=OFFSETS, combiner="mean")))(pool)
    np.testing.assert_allclose(np.asarray(g_int), np.asarray(g_ref),
                               atol=1e-5, rtol=1e-5)


def test_fused_max_grad_with_duplicate_indices():
    """Duplicate rows in one bag tie the max; split must match jax.grad."""
    pool, idx, _ = _inputs()
    idx = idx.at[:, :, 1].set(idx[:, :, 0])    # force in-bag duplicates
    g_f = jax.grad(lambda p: jnp.sum(fused_embedding_bag(
        p, idx, plan=_plan("max"))))(pool)
    g_r = jax.grad(lambda p: jnp.sum(ref.fused_embedding_bag_ref(
        p, idx, offsets=OFFSETS, combiner="max")))(pool)
    np.testing.assert_allclose(np.asarray(g_f), np.asarray(g_r),
                               atol=1e-5, rtol=1e-5)


def test_fused_grad_is_sparse_scatter():
    """Rows never looked up get exactly zero gradient (segment_sum dedup)."""
    pool, idx, _ = _inputs()
    g = jax.grad(lambda p: jnp.sum(fused_embedding_bag(
        p, idx, plan=_plan())))(pool)
    flat = (idx + jnp.asarray(OFFSETS)[None, :, None]).reshape(-1)
    untouched = np.setdiff1d(np.arange(pool.shape[0]), np.asarray(flat))
    assert untouched.size > 0
    np.testing.assert_array_equal(np.asarray(g)[untouched], 0.0)


# ---------------------------------------------------------------------------
# dispatch layer: exactly one fused call per forward component
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("base,expected_calls",
                         [(WIDE_DEEP, 2), (XDEEPFM, 1), (DCN, 1)])
def test_dlrm_forward_single_fused_call(base, expected_calls, monkeypatch):
    cfg = reduced_dlrm(base)
    params = dlrm.init_dlrm(cfg, jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v)
             for k, v in criteo_batch(cfg, 7, np.arange(8)).items()}

    calls = []
    real = ops.fused_embedding_bag

    def counting(*args, **kwargs):
        calls.append(kwargs["plan"].combiner)
        return real(*args, **kwargs)

    monkeypatch.setattr(ops, "fused_embedding_bag", counting)
    logit = dlrm.dlrm_forward(params, batch, cfg)
    assert logit.shape == (8,)
    assert len(calls) == expected_calls, calls
    # no other embedding dispatch sneaks in
    monkeypatch.setattr(ops, "embedding_bag",
                        lambda *a, **k: pytest.fail("per-table path used"))
    dlrm.dlrm_forward(params, batch, cfg)


def test_dlrm_pooled_param_layout():
    cfg = reduced_dlrm(WIDE_DEEP)
    params = dlrm.init_dlrm(cfg, jax.random.PRNGKey(0))
    D = cfg.embed_dim
    assert params["tables"].shape == (cfg.total_embedding_rows, D)
    assert params["wide"].shape == (cfg.total_embedding_rows, 1)
    specs = dlrm.dlrm_param_specs(cfg)
    assert specs["tables"] == ("vocab", None)
    assert specs["wide"] == ("vocab", None)
    assert cfg.table_offsets == tuple(
        int(x) for x in np.cumsum((0,) + cfg.table_rows[:-1]))


# ---------------------------------------------------------------------------
# legacy single-table contract: weights compose with every combiner
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("combiner", ["sum", "mean", "max"])
def test_legacy_embedding_bag_weighted_combiner(combiner):
    key = jax.random.PRNGKey(0)
    table = jax.random.normal(key, (50, 16))
    idx = jax.random.randint(jax.random.fold_in(key, 1), (6, 4), 0, 50)
    w = jax.random.uniform(jax.random.fold_in(key, 2), (6, 4))
    out = legacy_eb.embedding_bag(table, idx, w, combiner=combiner,
                                  interpret=True)
    expect = ref.embedding_bag_ref(table, idx, w, combiner=combiner)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("combiner", ["sum", "mean", "max"])
def test_ops_embedding_bag_weighted_combiner(combiner):
    """ops dispatch applies weights before the combiner on every impl."""
    key = jax.random.PRNGKey(3)
    table = jax.random.normal(key, (30, 8))
    idx = jax.random.randint(jax.random.fold_in(key, 1), (5, 3), 0, 30)
    w = jax.random.uniform(jax.random.fold_in(key, 2), (5, 3))
    expect = ref.embedding_bag_ref(table, idx, w, combiner=combiner)
    for impl in ("xla", "interpret"):
        out = ops.embedding_bag(table, idx, w,
                                plan=EmbeddingPlan(combiner=combiner),
                                impl=impl)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   atol=1e-5, rtol=1e-5,
                                   err_msg=f"impl={impl}")


# ---------------------------------------------------------------------------
# plan API: loose-kwarg deprecation shim + plan/kwarg exclusivity
# ---------------------------------------------------------------------------
def test_loose_kwargs_warn_once_and_match_plan(monkeypatch):
    """ops loose kwargs still work (warn-once shim) and equal the plan form."""
    monkeypatch.setattr(ops, "_LEGACY_KWARGS_WARNED", False)
    pool, idx, _ = _inputs()
    with pytest.warns(DeprecationWarning, match="plan=EmbeddingPlan"):
        legacy = ops.fused_embedding_bag(pool, idx, offsets=OFFSETS,
                                         combiner="mean")
    planned = ops.fused_embedding_bag(pool, idx, plan=_plan("mean"))
    np.testing.assert_array_equal(np.asarray(legacy), np.asarray(planned))
    # warn-once: the second legacy call is silent
    import warnings as _warnings
    with _warnings.catch_warnings():
        _warnings.simplefilter("error")
        ops.fused_embedding_bag(pool, idx, offsets=OFFSETS, combiner="mean")


def test_bare_ops_call_does_not_warn(monkeypatch):
    """A call with no loose kwargs gets the default plan silently."""
    monkeypatch.setattr(ops, "_LEGACY_KWARGS_WARNED", False)
    key = jax.random.PRNGKey(5)
    table = jax.random.normal(key, (30, 8))
    idx = jax.random.randint(jax.random.fold_in(key, 1), (5, 3), 0, 30)
    import warnings as _warnings
    with _warnings.catch_warnings():
        _warnings.simplefilter("error")
        ops.embedding_bag(table, idx)
    assert not ops._LEGACY_KWARGS_WARNED


def test_plan_and_loose_kwargs_are_exclusive():
    pool, idx, _ = _inputs()
    with pytest.raises(AssertionError, match="inside plan="):
        ops.fused_embedding_bag(pool, idx, plan=_plan(), combiner="sum")


def test_legacy_module_warns_deprecation(monkeypatch):
    monkeypatch.setattr(legacy_eb, "_DEPRECATION_WARNED", False)
    key = jax.random.PRNGKey(6)
    table = jax.random.normal(key, (20, 8))
    idx = jax.random.randint(jax.random.fold_in(key, 1), (4, 3), 0, 20)
    with pytest.warns(DeprecationWarning, match="ops.embedding_bag"):
        legacy_eb.embedding_bag(table, idx)


# ---------------------------------------------------------------------------
# max-combiner init constant: one shared NEG_INF, adversarial inputs
# ---------------------------------------------------------------------------
def test_neg_inf_constant_shared():
    assert legacy_eb.NEG_INF == common.NEG_INF
    assert ref.NEG_INF == common.NEG_INF
    assert common.NEG_INF < -1e38       # true max identity for finite f32


def test_max_pooling_adversarial_very_negative_rows():
    """Rows below the old -1e30 init must still win the max."""
    table = jnp.full((8, 16), -1.5e31, jnp.float32)
    idx = jnp.array([[0, 3, 5], [1, 1, 7]], jnp.int32)
    expect = ref.embedding_bag_ref(table, idx, combiner="max")
    np.testing.assert_allclose(np.asarray(expect), -1.5e31)
    out_legacy = legacy_eb.embedding_bag(table, idx, combiner="max",
                                         interpret=True)
    np.testing.assert_allclose(np.asarray(out_legacy), np.asarray(expect))
    out_fused = fused_embedding_bag(
        table, idx[:, None, :], method="interpret",
        plan=EmbeddingPlan(offsets=(0,), combiner="max"))
    np.testing.assert_allclose(np.asarray(out_fused[:, 0]), np.asarray(expect))
