"""Physically-unequal PS shards: the padded (n_ps, max_range, D) pooled layout.

Covers the acceptance contract of the padded placement path:
  * the layout planner's row translation is a bijection on real rows, empty
    shards stay fully padded, and n_ps=1 degenerates to the flat pool.
  * fused-engine forward AND backward are bit-exact vs the flat reference on
    every impl/combiner, with and without the hot-row cache; padding slots
    receive exactly zero gradient.
  * pad/unpad of a full train state (params + optimizer moments) round-trips
    bit-exactly, and flat/padded inits from one key are value-equal.
  * a live re-plan crosses layouts (old padded plan -> new padded plan built
    from the new balanced ranges) with bit-exact forward loss, matching the
    flat job's replan to the ulp.
  * layout-stamped checkpoints store the canonical flat order: they
    round-trip flat <-> padded and resume onto a different n_ps.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.dlrm_models import WIDE_DEEP, reduced_dlrm
from repro.core.flash_checkpoint import FlashCheckpoint
from repro.core.sharding_service import HotTableTracker
from repro.data.synthetic import criteo_batch
from repro.kernels.fused_embedding import (fused_embedding_bag, table_offsets,
                                           translate_rows, translate_rows_np)
from repro.models.dlrm import dlrm_loss
from repro.sharding.policy import (EmbeddingPlan, balanced_vocab_ranges,
                                   padded_layout_for_ranges,
                                   uniform_vocab_ranges)
from repro.train import elastic, optim, replan, trainer

jax.config.update("jax_platform_name", "cpu")

ROWS = 512
CFG = dataclasses.replace(reduced_dlrm(WIDE_DEEP), table_rows=(ROWS,) * 6,
                          zipf_alpha=1.05, hot_rows_k=48)
N_PS = 4


def _batch(seed, lo, shift=0):
    b = criteo_batch(CFG, seed, np.arange(lo, lo + 256))
    if shift:
        b = dict(b, sparse=((b["sparse"].astype(np.int64) + shift) % ROWS
                            ).astype(b["sparse"].dtype))
    return b


def _jb(b):
    return {k: jnp.asarray(v) for k, v in b.items()}


# ------------------------------------------------------------- layout planner
def test_planner_geometry_and_translation_bijection():
    lay = padded_layout_for_ranges([(0, 100), (100, 101), (101, 224)])
    assert (lay.n_ps, lay.max_range, lay.total_rows) == (3, 123, 224)
    assert lay.padded_rows == 3 * 123
    assert lay.shard_sizes == (100, 1, 123)
    tr = lay.row_translation()
    assert len(np.unique(tr)) == lay.total_rows          # injective
    np.testing.assert_array_equal(lay.padded_to_flat(tr),
                                  np.arange(lay.total_rows))
    # mask row-sums ARE the materialized physical shard sizes
    np.testing.assert_array_equal(lay.padding_mask().sum(axis=1),
                                  lay.shard_sizes)
    # boundary rows land at slot 0 of their shard
    shard, slot = lay.shard_slot([0, 100, 101, 223])
    np.testing.assert_array_equal(shard, [0, 1, 2, 2])
    np.testing.assert_array_equal(slot, [0, 0, 0, 122])


def test_planner_rejects_gaps_and_wrong_origin():
    with pytest.raises(AssertionError):
        padded_layout_for_ranges([(1, 4), (4, 8)])       # must start at 0
    with pytest.raises(AssertionError):
        padded_layout_for_ranges([(0, 4), (5, 8)])       # gap
    with pytest.raises(AssertionError):
        padded_layout_for_ranges([])                     # no shards


def test_empty_shard_is_fully_padded_tail():
    """A zero-width range is legal: that shard is max_range rows of padding
    and no flat row ever translates into it."""
    lay = padded_layout_for_ranges([(0, 6), (6, 6), (6, 10)])
    assert lay.shard_sizes == (6, 0, 4)
    assert not lay.padding_mask()[1].any()               # all padding
    shard, _ = lay.shard_slot(np.arange(10))
    assert 1 not in shard.tolist()                       # never selected
    flat = jnp.arange(10.0)[:, None] * jnp.ones((1, 3))
    padded = lay.pad_rows(flat)
    np.testing.assert_array_equal(np.asarray(padded[1]), 0.0)
    np.testing.assert_array_equal(np.asarray(lay.unpad_rows(padded)),
                                  np.asarray(flat))


def test_n_ps_1_degenerate_layout_is_flat_plus_leading_axis():
    lay = padded_layout_for_ranges(uniform_vocab_ranges(224, 1))
    assert (lay.n_ps, lay.max_range, lay.padded_rows) == (1, 224, 224)
    np.testing.assert_array_equal(lay.row_translation(), np.arange(224))
    flat = jnp.arange(224.0)[:, None]
    np.testing.assert_array_equal(np.asarray(lay.pad_rows(flat))[0],
                                  np.asarray(flat))


def test_traced_translation_matches_host_translation():
    rng = np.random.default_rng(0)
    lay = padded_layout_for_ranges(
        balanced_vocab_ranges(rng.zipf(1.7, 224).astype(float), N_PS))
    rows = rng.integers(0, 224, 1000)
    np.testing.assert_array_equal(
        np.asarray(translate_rows(jnp.asarray(rows, jnp.int32), lay)),
        translate_rows_np(rows, lay))
    np.testing.assert_array_equal(translate_rows_np(rows, lay),
                                  lay.flat_to_padded(rows))


# ------------------------------------------------ fused engine bit-exactness
TABLE_ROWS = (64, 40, 96, 24)
OFFSETS = table_offsets(TABLE_ROWS)
TABLE_HOT = (16, 8, 24, 6)


def _stream(B=13, H=4, D=16, seed=0):
    rng = np.random.default_rng(seed)
    pool = jnp.asarray(rng.standard_normal((sum(TABLE_ROWS), D), np.float32))
    idx = np.stack([rng.integers(0, r, (B, H)) for r in TABLE_ROWS], axis=1)
    w = jnp.asarray(rng.uniform(0.1, 2.0, (B, len(TABLE_ROWS), H))
                    .astype(np.float32))
    # skewed mass so the balanced plan is genuinely unequal
    counts = np.concatenate([np.arange(r, 0, -1.0) ** 2 for r in TABLE_ROWS])
    lay = padded_layout_for_ranges(balanced_vocab_ranges(counts, 3))
    assert len(set(lay.shard_sizes)) > 1                 # physically unequal
    return pool, jnp.asarray(idx.astype(np.int32)), w, lay


@pytest.mark.parametrize("combiner", ["sum", "mean", "max"])
@pytest.mark.parametrize("weighted", [False, True])
@pytest.mark.parametrize("method", ["xla", "interpret"])
@pytest.mark.parametrize("hot", [None, TABLE_HOT])
def test_padded_forward_bitmatches_flat(combiner, weighted, method, hot):
    pool, idx, w, lay = _stream()
    weights = w if weighted else None
    ppool = lay.pad_rows(pool).reshape(lay.padded_rows, -1)
    plan = EmbeddingPlan(offsets=OFFSETS, combiner=combiner, block_b=4,
                         table_hot=hot)
    out_flat = fused_embedding_bag(pool, idx, weights, method=method,
                                   plan=plan)
    out_pad = fused_embedding_bag(ppool, idx, weights, method=method,
                                  plan=plan.with_replan(hot, lay))
    np.testing.assert_array_equal(np.asarray(out_flat), np.asarray(out_pad))


@pytest.mark.parametrize("combiner", ["sum", "mean", "max"])
def test_padded_backward_bitmatches_flat_and_zeroes_padding(combiner):
    pool, idx, w, lay = _stream(seed=3)
    D = pool.shape[1]

    plan = EmbeddingPlan(offsets=OFFSETS, combiner=combiner)

    def loss_flat(p):
        return jnp.sum(fused_embedding_bag(p, idx, w, plan=plan) * 1.3)

    def loss_pad(p3):
        return jnp.sum(fused_embedding_bag(
            p3.reshape(-1, D), idx, w,
            plan=plan.with_replan(None, lay)) * 1.3)

    g_flat = jax.grad(loss_flat)(pool)
    g_pad = jax.grad(loss_pad)(lay.pad_rows(pool))
    np.testing.assert_array_equal(np.asarray(lay.unpad_rows(g_pad)),
                                  np.asarray(g_flat))
    mask = jnp.asarray(lay.padding_mask())[..., None]
    assert float(jnp.abs(jnp.where(mask, 0.0, g_pad)).max()) == 0.0


# --------------------------------------------------- train-state pad/unpad
def test_pad_unpad_train_state_roundtrip_and_init_equivalence():
    opt = optim.adagrad(0.05)
    lay = padded_layout_for_ranges(
        uniform_vocab_ranges(CFG.total_embedding_rows, N_PS))
    flat = trainer.make_dlrm_train_state(CFG, opt, jax.random.PRNGKey(0))
    padded = trainer.make_dlrm_train_state(CFG, opt, jax.random.PRNGKey(0),
                                           layout=lay)
    # padded init == pad(flat init) leaf for leaf (same keys drawn)
    want = replan.pad_train_state(flat, CFG.total_embedding_rows, lay)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), want, padded)
    assert padded["params"]["tables"].shape[:2] == (N_PS, lay.max_range)
    assert padded["opt"]["acc"]["tables"].shape[:2] == (N_PS, lay.max_range)
    # round trip back to flat
    back = replan.unpad_train_state(padded, CFG.total_embedding_rows, lay)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), flat, back)
    # dense leaves never grow a padded shape
    assert padded["params"]["mlp"]["w0"].shape == flat["params"]["mlp"]["w0"].shape


def test_padded_train_step_matches_flat_step_bit_exactly():
    """One full optimizer step on the padded layout == the flat step, to the
    bit, on params AND losses (adagrad moments ride the same layout)."""
    opt = optim.adagrad(0.05)
    lay = padded_layout_for_ranges(
        uniform_vocab_ranges(CFG.total_embedding_rows, N_PS))
    s_flat = trainer.make_dlrm_train_state(CFG, opt, jax.random.PRNGKey(1))
    s_pad = replan.pad_train_state(s_flat, CFG.total_embedding_rows, lay)
    step_flat = jax.jit(trainer.make_dlrm_train_step(CFG, opt))
    step_pad = jax.jit(trainer.make_dlrm_train_step(CFG, opt, layout=lay))
    b = _jb(_batch(7, 0))
    for _ in range(3):
        s_flat, m_flat = step_flat(s_flat, b)
        s_pad, m_pad = step_pad(s_pad, b)
    assert float(m_pad["loss"]) == float(m_flat["loss"])
    np.testing.assert_array_equal(
        np.asarray(lay.unpad_rows(s_pad["params"]["tables"])),
        np.asarray(s_flat["params"]["tables"]))
    np.testing.assert_array_equal(
        np.asarray(lay.unpad_rows(s_pad["opt"]["acc"]["tables"])),
        np.asarray(s_flat["opt"]["acc"]["tables"]))


# ------------------------------------------------------- replan across layouts
def _drifted_decision(tracker_seed=3):
    tracker = HotTableTracker(CFG.table_rows, n_ps=N_PS,
                              hot_budget=CFG.hot_rows_k, decay=0.8,
                              trigger=1.2, cooldown=0, min_lookups=512)
    for i in range(6):
        tracker.observe(_batch(tracker_seed, 256 * i)["sparse"])
    decision = tracker.maybe_replan()
    assert decision is not None
    return decision


def test_replan_padded_job_matches_flat_replan_bit_exactly():
    """The same decision applied to a flat job and to a padded job (crossing
    to the NEW plan's physical layout) produces bit-identical losses."""
    opt = optim.adagrad(0.05)
    old_lay = padded_layout_for_ranges(
        uniform_vocab_ranges(CFG.total_embedding_rows, N_PS))
    s_flat = trainer.make_dlrm_train_state(CFG, opt, jax.random.PRNGKey(2))
    s_pad = replan.pad_train_state(s_flat, CFG.total_embedding_rows, old_lay)
    decision = _drifted_decision()

    rm_flat = replan.EmbeddingRemapper(CFG.table_rows)
    rm_pad = replan.EmbeddingRemapper(CFG.table_rows)
    res_flat = replan.apply_replan(s_flat, CFG, opt, decision,
                                   remapper=rm_flat)
    res_pad = replan.apply_replan(s_pad, CFG, opt, decision,
                                  remapper=rm_pad, layout=old_lay)
    assert res_flat.layout is None
    assert res_pad.layout == padded_layout_for_ranges(decision.vocab_ranges)
    # physical rows per shard == the balanced plan, exactly
    np.testing.assert_array_equal(
        res_pad.layout.padding_mask().sum(axis=1),
        [e - s for s, e in decision.vocab_ranges])

    probe = rm_flat.remap_batch(_batch(13, 10_000))
    loss_flat = float(dlrm_loss(res_flat.state["params"], _jb(probe), CFG,
                                table_hot=decision.table_hot))
    loss_pad = float(dlrm_loss(res_pad.state["params"], _jb(probe), CFG,
                               table_hot=decision.table_hot,
                               layout=res_pad.layout))
    assert loss_pad == loss_flat
    # and one resumed train step stays bit-identical
    _, m_flat = res_flat.step_fn(res_flat.state, _jb(probe))
    _, m_pad = res_pad.step_fn(res_pad.state, _jb(probe))
    assert float(m_pad["loss"]) == float(m_flat["loss"])


def test_layout_stamped_checkpoint_roundtrips_flat_and_padded():
    """save_with_layout stores the canonical flat order: a padded job's blob
    restores padded (stamp honored) AND unpads to the original flat state."""
    opt = optim.adagrad(0.05)
    decision = _drifted_decision()
    lay = padded_layout_for_ranges(decision.vocab_ranges)
    s_flat = trainer.make_dlrm_train_state(CFG, opt, jax.random.PRNGKey(4))
    s_flat = replan.permute_train_state(s_flat, CFG.total_embedding_rows,
                                        decision.permutation)
    s_pad = replan.pad_train_state(s_flat, CFG.total_embedding_rows, lay)
    remapper = replan.EmbeddingRemapper(CFG.table_rows)
    remapper.compose(decision.permutation)

    ckpt = FlashCheckpoint()
    replan.save_with_layout(ckpt, s_pad, 5, remapper, decision.table_hot,
                            decision.vocab_ranges, layout=lay)
    state2, step2, rm2, hot2, ranges2, lay2 = replan.restore_with_layout(
        CFG, opt, ckpt)
    assert step2 == 5 and lay2 == lay
    assert hot2 == decision.table_hot and ranges2 == decision.vocab_ranges
    np.testing.assert_array_equal(rm2.map, remapper.map)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), state2, s_pad)
    back = replan.unpad_train_state(state2, CFG.total_embedding_rows, lay2)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), back, s_flat)

    raw = _batch(13, 20_000)
    want = float(dlrm_loss(s_flat["params"],
                           _jb(remapper.remap_batch(raw)), CFG,
                           table_hot=decision.table_hot))
    got = float(dlrm_loss(state2["params"], _jb(rm2.remap_batch(raw)), CFG,
                          table_hot=hot2, layout=lay2))
    assert got == want


def test_elastic_resume_onto_different_n_ps():
    """A plain blob saved padded on 4 shards resumes onto 2 shards (and onto
    the flat layout) with bit-identical forward loss."""
    opt = optim.adagrad(0.05)
    R = CFG.total_embedding_rows
    lay4 = padded_layout_for_ranges(uniform_vocab_ranges(R, 4))
    lay2 = padded_layout_for_ranges(uniform_vocab_ranges(R, 2))
    state = trainer.make_dlrm_train_state(CFG, opt, jax.random.PRNGKey(5),
                                          layout=lay4)
    b = _jb(_batch(11, 0))
    want = float(dlrm_loss(state["params"], b, CFG, layout=lay4))

    ckpt = FlashCheckpoint()
    ckpt.save(state, 3)
    s2, step2, _pol = elastic.resume_dlrm_on_mesh(
        CFG, opt, "adagrad", ckpt, None, from_layout=lay4, layout=lay2)
    assert step2 == 3
    assert s2["params"]["tables"].shape[:2] == (2, lay2.max_range)
    assert float(dlrm_loss(s2["params"], b, CFG, layout=lay2)) == want
    s3, _, _ = elastic.resume_dlrm_on_mesh(
        CFG, opt, "adagrad", ckpt, None, from_layout=lay4, layout=None)
    assert s3["params"]["tables"].shape[0] == R
    assert float(dlrm_loss(s3["params"], b, CFG)) == want
