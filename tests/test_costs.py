"""jaxpr cost counter: exact trip-count FLOPs (vs XLA's scan-blind count)."""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import reduce_config
from repro.configs.registry import ARCHS
from repro.launch.costs import flops_of
from repro.models.registry import build_model
from repro.train import optim, trainer


def test_matmul_exact():
    f = lambda a, b: a @ b
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    assert flops_of(f, a, b) == 2 * 64 * 128 * 32


def test_scan_multiplies_by_length():
    w = jax.ShapeDtypeStruct((8, 16, 16), jnp.float32)   # 8 stacked layers

    def f(w, x):
        def body(x, wi):
            return x @ wi, None
        x, _ = jax.lax.scan(body, x, w)
        return x

    x = jax.ShapeDtypeStruct((4, 16), jnp.float32)
    assert flops_of(f, w, x) == 8 * 2 * 4 * 16 * 16


def test_remat_counts_recompute():
    w = jax.ShapeDtypeStruct((16, 16), jnp.float32)

    def loss(w, x):
        f = jax.checkpoint(lambda x: jnp.tanh(x @ w) @ w)
        return jnp.sum(f(x))

    x = jax.ShapeDtypeStruct((4, 16), jnp.float32)
    g = flops_of(jax.grad(loss), w, x)
    nog = flops_of(jax.grad(lambda w, x: jnp.sum(jnp.tanh(x @ w) @ w)), w, x)
    assert g > nog                      # remat adds forward recompute


def test_close_to_xla_on_unrolled_model():
    """On a scan-length-1 model, jaxpr count ≈ XLA count (dots dominate)."""
    cfg = dataclasses.replace(
        reduce_config(ARCHS["llama3.2-3b"]), num_layers=1, d_model=128,
        n_heads=4, n_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512)
    api = build_model(cfg)
    opt = optim.adam(1e-3)
    step = trainer.make_train_step(api, opt, remat=False)
    state = jax.eval_shape(lambda k: trainer.make_train_state(api, opt, k),
                           jax.random.PRNGKey(0))
    batch = {"tokens": jax.ShapeDtypeStruct((4, 64), jnp.int32),
             "targets": jax.ShapeDtypeStruct((4, 64), jnp.int32)}
    mine = flops_of(step, state, batch)
    ca = jax.jit(step).lower(state, batch).compile().cost_analysis()
    if isinstance(ca, (list, tuple)):        # older jax returns [dict]
        ca = ca[0]
    xla = ca["flops"]
    assert 0.8 < mine / xla < 1.25, (mine, xla)


def test_layer_scaling_is_linear():
    cfg1 = dataclasses.replace(reduce_config(ARCHS["llama3.2-3b"]),
                               num_layers=2)
    cfg2 = dataclasses.replace(cfg1, num_layers=8)

    def fl(cfg):
        api = build_model(cfg)
        opt = optim.adam(1e-3)
        step = trainer.make_train_step(api, opt, remat=False)
        state = jax.eval_shape(lambda k: trainer.make_train_state(api, opt, k),
                               jax.random.PRNGKey(0))
        batch = {"tokens": jax.ShapeDtypeStruct((2, 32), jnp.int32),
                 "targets": jax.ShapeDtypeStruct((2, 32), jnp.int32)}
        return flops_of(step, state, batch)

    f1, f2 = fl(cfg1), fl(cfg2)
    layer = (f2 - f1) / 6
    assert layer > 0
    fixed = f1 - 2 * layer
    assert fixed >= 0                   # embed/logits/opt overhead
