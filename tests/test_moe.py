"""MoE dispatch correctness: gather-only grouped dispatch vs dense oracle."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.configs.base import ModelConfig
from repro.models.common import KeyGen
from repro.models.mlp import init_moe, moe_block


def _cfg(E=4, k=2, d=16, ff=32, cf=8.0, act="silu"):
    return ModelConfig(
        name="moe-test", family="moe", num_layers=1, d_model=d, n_heads=2,
        n_kv_heads=1, d_ff=ff, vocab_size=64, n_experts=E, top_k=k,
        capacity_factor=cf, activation=act,
        param_dtype="float32", compute_dtype="float32")


def _dense_oracle(p, x, cfg):
    """Compute every expert for every token, combine top-k — no dispatch."""
    B, S, d = x.shape
    logits = jnp.einsum("gsd,de->gse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_g, top_i = jax.lax.top_k(probs, cfg.top_k)
    top_g = top_g / jnp.sum(top_g, axis=-1, keepdims=True)
    # all experts densely
    h = jnp.einsum("gsd,edf->gsef", x, p["w1"])
    if cfg.activation == "silu":
        h = jax.nn.silu(h) * jnp.einsum("gsd,edf->gsef", x, p["w3"])
    else:
        h = jax.nn.gelu(h, approximate=True)
    ye = jnp.einsum("gsef,efd->gsed", h, p["w2"])           # (B,S,E,d)
    sel = jnp.take_along_axis(ye, top_i[..., None], axis=2)  # (B,S,k,d)
    return jnp.sum(sel * top_g[..., None], axis=2)


@settings(max_examples=12, deadline=None)
@given(B=st.integers(1, 3), S=st.sampled_from([1, 4, 9]),
       E=st.sampled_from([2, 4, 8]), k=st.sampled_from([1, 2]),
       seed=st.integers(0, 100))
def test_moe_matches_dense_oracle_no_drops(B, S, E, k, seed):
    k = min(k, E)
    cfg = _cfg(E=E, k=k, cf=float(E))       # capacity ≥ worst case: no drops
    kg = KeyGen(jax.random.PRNGKey(seed))
    p, _ = init_moe(kg, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(jax.random.PRNGKey(seed), 7),
                          (B, S, cfg.d_model))
    out, aux = moe_block(p, x, cfg)
    expect = _dense_oracle(p, x, cfg)
    np.testing.assert_allclose(out, expect, atol=1e-4, rtol=1e-4)
    assert np.isfinite(float(aux))


def test_moe_capacity_drops_reduce_output_norm():
    """With tiny capacity some tokens are dropped => output differs/shrinks."""
    cfg_full = _cfg(E=4, k=2, cf=8.0)
    cfg_tight = dataclasses.replace(cfg_full, capacity_factor=0.25)
    kg = KeyGen(jax.random.PRNGKey(0))
    p, _ = init_moe(kg, cfg_full, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg_full.d_model))
    out_full, _ = moe_block(p, x, cfg_full)
    out_tight, _ = moe_block(p, x, cfg_tight)
    assert float(jnp.linalg.norm(out_tight)) < float(jnp.linalg.norm(out_full))


def test_moe_grad_flows_to_all_param_groups():
    cfg = _cfg()
    kg = KeyGen(jax.random.PRNGKey(0))
    p, _ = init_moe(kg, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))

    def loss(p):
        out, aux = moe_block(p, x, cfg)
        return jnp.sum(out ** 2) + 0.01 * aux

    g = jax.grad(loss)(p)
    for name, leaf in g.items():
        assert float(jnp.max(jnp.abs(leaf))) > 0, f"no grad for {name}"


def test_moe_aux_loss_balanced_router_is_one():
    """Uniform router => aux ≈ 1 (Switch normalization)."""
    cfg = _cfg(E=4, k=1)
    kg = KeyGen(jax.random.PRNGKey(0))
    p, _ = init_moe(kg, cfg, jnp.float32)
    p = dict(p)
    p["router"] = jnp.zeros_like(p["router"])   # uniform probs
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 64, cfg.d_model))
    _, aux = moe_block(p, x, cfg)
    assert 0.9 < float(aux) < 1.1
