"""Live embedding re-planning: decayed counts track drift, hysteresis stops
thrash, and a re-plan is bit-exact across checkpoint/restore boundaries.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.dlrm_models import WIDE_DEEP, reduced_dlrm
from repro.core.flash_checkpoint import FlashCheckpoint
from repro.core.sharding_service import HotTableTracker
from repro.data.synthetic import criteo_batch
from repro.models.dlrm import dlrm_loss
from repro.train import optim, replan, trainer

ROWS = 512
CFG = dataclasses.replace(reduced_dlrm(WIDE_DEEP), table_rows=(ROWS,) * 6,
                          zipf_alpha=1.05, hot_rows_k=48)
N_PS = 4


def _batch(seed, lo, shift=0):
    """One criteo batch; ``shift`` rotates every table's ids (drifting skew)."""
    b = criteo_batch(CFG, seed, np.arange(lo, lo + 256))
    if shift:
        b = dict(b, sparse=((b["sparse"].astype(np.int64) + shift) % ROWS
                            ).astype(b["sparse"].dtype))
    return b


def _jb(b):
    return {k: jnp.asarray(v) for k, v in b.items()}


# ---------------------------------------------------------------- decayed stats
def test_decayed_counts_converge_under_drifting_skew():
    """After the skew drifts, the rolling window forgets the old hot head and
    ranks the new one first — per table, not just globally."""
    t = HotTableTracker(CFG.table_rows, n_ps=N_PS, decay=0.8)
    for i in range(10):
        t.observe(_batch(3, 256 * i)["sparse"])
    off = t.offsets
    counts = t.snapshot()
    for o in off:                       # zipf rank 0 is the hottest raw id
        assert int(np.argmax(counts[o:o + ROWS])) == 0
    shift = 157
    for i in range(20):                 # drift: hot head rotates to id `shift`
        t.observe(_batch(3, 4096 + 256 * i, shift=shift)["sparse"])
    counts = t.snapshot()
    for o in off:
        assert int(np.argmax(counts[o:o + ROWS])) == shift
        # the old head's decayed mass is a small fraction of the new head's
        assert counts[o] < 0.2 * counts[o + shift]


def test_observe_counts_matches_observe():
    a = HotTableTracker(CFG.table_rows, decay=0.9)
    b = HotTableTracker(CFG.table_rows, decay=0.9)
    off = np.asarray(a.offsets)
    for i in range(3):
        sp = _batch(5, 256 * i)["sparse"]
        a.observe(sp)
        flat = (sp.astype(np.int64) + off[None, :, None]).reshape(-1)
        b.observe_counts(np.bincount(flat, minlength=a.total_rows))
    np.testing.assert_allclose(a.snapshot(), b.snapshot())


# ----------------------------------------------------------------- hysteresis
def _warmed_tracker(cooldown=4, trigger=1.2):
    t = HotTableTracker(CFG.table_rows, n_ps=N_PS, hot_budget=CFG.hot_rows_k,
                        decay=0.8, trigger=trigger, cooldown=cooldown,
                        min_lookups=512)
    for i in range(6):
        t.observe(_batch(3, 256 * i)["sparse"])
    return t


def test_replan_triggers_on_skew_and_cools_down():
    t = _warmed_tracker(cooldown=6)
    d1 = t.maybe_replan()
    assert d1 is not None                       # uniform striping has gone hot
    assert d1.imbalance_before >= 1.2
    assert d1.imbalance_after <= 1.05
    t.mark_applied(d1)

    # immediately drift hard — but the cooldown gates back-to-back re-plans
    remap = replan.EmbeddingRemapper(CFG.table_rows)
    remap.compose(d1.permutation)
    for i in range(5):
        t.observe(remap.remap(_batch(3, 2048 + 256 * i, shift=157)["sparse"]))
    assert t.imbalance() > 1.2                  # drift is real and visible...
    assert t.maybe_replan() is None             # ...but inside the cooldown
    t.observe(remap.remap(_batch(3, 4096, shift=157)["sparse"]))
    d2 = t.maybe_replan()                       # cooldown elapsed: fires
    assert d2 is not None and d2.imbalance_after <= 1.05


def test_no_replan_when_plan_still_good():
    """Steady traffic after an applied plan never re-triggers (no thrash)."""
    t = _warmed_tracker(cooldown=2)
    d1 = t.maybe_replan()
    t.mark_applied(d1)
    remap = replan.EmbeddingRemapper(CFG.table_rows)
    remap.compose(d1.permutation)
    for i in range(8):                          # same distribution, new noise
        t.observe(remap.remap(_batch(9, 256 * i)["sparse"]))
        assert t.maybe_replan() is None
    assert t.imbalance() < 1.1


def test_min_lookups_gate():
    t = HotTableTracker(CFG.table_rows, n_ps=N_PS, trigger=1.0,
                        cooldown=0, min_lookups=10**9)
    t.observe(_batch(3, 0)["sparse"])
    assert t.maybe_replan() is None


# ------------------------------------------------------- bit-exact re-planning
def test_replan_is_bit_exact_and_restores_across_plans():
    """End-to-end: train, drift, re-plan; the permuted state, the resumed
    step, and an old-plan checkpoint restored onto the new plan all produce
    bit-identical forward losses."""
    opt = optim.adagrad(0.05)
    state = trainer.make_dlrm_train_state(CFG, opt, jax.random.PRNGKey(0))
    step_fn = jax.jit(trainer.make_dlrm_train_step(CFG, opt))
    tracker = HotTableTracker(CFG.table_rows, n_ps=N_PS,
                              hot_budget=CFG.hot_rows_k, decay=0.8,
                              trigger=1.2, cooldown=0, min_lookups=512)
    remapper = replan.EmbeddingRemapper(CFG.table_rows)
    for i in range(3):
        b = _batch(7, 256 * i)
        tracker.observe(b["sparse"])
        state, _ = step_fn(state, _jb(b))

    # access skew drifts; the applied (uniform) plan goes hot
    shift = 157
    for i in range(6):
        tracker.observe(_batch(7, 2048 + 256 * i, shift=shift)["sparse"])
    decision = tracker.maybe_replan()
    assert decision is not None
    assert decision.imbalance_before >= 1.2
    assert decision.imbalance_after <= 1.05

    probe = _batch(13, 10_000, shift=shift)     # post-drift traffic
    loss_old = float(dlrm_loss(state["params"], _jb(probe), CFG))

    # old-layout snapshot first (stamping the PRE-compose map), then apply
    ckpt = FlashCheckpoint()
    snap_step = int(state["step"])
    replan.save_with_layout(ckpt, state, snap_step, remapper)
    res = replan.apply_replan(state, CFG, opt, decision, remapper=remapper)
    tracker.mark_applied(decision)
    assert res.policy.vocab_ranges == decision.vocab_ranges

    probe_new = remapper.remap_batch(probe)
    loss_new = float(dlrm_loss(res.state["params"], _jb(probe_new), CFG,
                               table_hot=decision.table_hot))
    assert loss_new == loss_old                 # bit-exact, not approx

    # one full resumed train step matches the old layout's step bit-exactly
    s_old, m_old = step_fn(state, _jb(probe))
    s_new, m_new = res.step_fn(res.state, _jb(probe_new))
    assert float(m_new["loss"]) == float(m_old["loss"])
    np.testing.assert_array_equal(
        np.asarray(s_new["params"]["mlp"]["w0"]),
        np.asarray(s_old["params"]["mlp"]["w0"]))
    # permuted embedding rows match the old rows moved to their new slots
    inv = np.argsort(decision.permutation)
    np.testing.assert_array_equal(
        np.asarray(s_new["params"]["tables"]),
        np.asarray(s_old["params"]["tables"])[inv])

    # old-plan checkpoint -> new-plan state, still bit-exact; the returned
    # remapper comes back already composed with the decision
    state2, restored, step_fn2, policy2, remapper2 = replan.restore_on_plan(
        CFG, opt, "adagrad", ckpt, decision)
    assert restored == snap_step
    np.testing.assert_array_equal(remapper2.map, remapper.map)
    loss2 = float(dlrm_loss(state2["params"], _jb(probe_new), CFG,
                            table_hot=decision.table_hot))
    assert loss2 == loss_old
    _, m2 = step_fn2(state2, _jb(probe_new))
    assert float(m2["loss"]) == float(m_old["loss"])


def test_remapper_composes_across_plans():
    rows = (8, 8)
    r = replan.EmbeddingRemapper(rows)
    p1 = np.array([1, 0, 2, 3, 4, 5, 6, 7,   8, 9, 10, 11, 12, 13, 15, 14])
    p2 = np.array([0, 2, 1, 3, 4, 5, 6, 7,   9, 8, 10, 11, 12, 13, 14, 15])
    r.compose(p1)
    r.compose(p2)
    sparse = np.array([[[0, 1], [6, 7]]])       # (B=1, T=2, H=2) local ids
    out = r.remap(sparse)
    # raw 0 -> p1 1 -> p2 2; raw 1 -> p1 0 -> p2 0 (table 0)
    np.testing.assert_array_equal(out[0, 0], [2, 0])
    # raw local 6 -> global 14 -> p1 15 -> p2 15 -> local 7; 7 -> 14 -> 6
    np.testing.assert_array_equal(out[0, 1], [7, 6])
    assert out.dtype == sparse.dtype


def test_layout_stamped_checkpoint_survives_process_restart():
    """save_with_layout blobs are self-describing: a fresh process (new
    remapper, no ReplanDecision history) restores after a re-plan and
    computes the same forward loss on the same raw data."""
    opt = optim.adagrad(0.05)
    state = trainer.make_dlrm_train_state(CFG, opt, jax.random.PRNGKey(2))
    tracker = HotTableTracker(CFG.table_rows, n_ps=N_PS,
                              hot_budget=CFG.hot_rows_k, decay=0.8,
                              trigger=1.2, cooldown=0, min_lookups=512)
    remapper = replan.EmbeddingRemapper(CFG.table_rows)
    for i in range(6):
        tracker.observe(_batch(3, 256 * i)["sparse"])
    decision = tracker.maybe_replan()
    res = replan.apply_replan(state, CFG, opt, decision, remapper=remapper)
    tracker.mark_applied(decision)

    ckpt = FlashCheckpoint()
    replan.save_with_layout(ckpt, res.state, 7, remapper,
                            decision.table_hot, decision.vocab_ranges)
    # re-saving the same step must not corrupt the memory tier's eviction
    replan.save_with_layout(ckpt, res.state, 7, remapper,
                            decision.table_hot, decision.vocab_ranges)

    raw = _batch(13, 20_000)
    want = float(dlrm_loss(res.state["params"],
                           _jb(remapper.remap_batch(raw)), CFG,
                           table_hot=decision.table_hot))

    # "fresh process": nothing carried over except the checkpoint object
    state2, step2, remapper2, table_hot2, ranges2, layout2 = \
        replan.restore_with_layout(CFG, opt, ckpt)
    assert layout2 is None                      # flat job: no padded stamp
    assert step2 == 7
    assert table_hot2 == decision.table_hot
    assert ranges2 == decision.vocab_ranges
    np.testing.assert_array_equal(remapper2.map, remapper.map)
    got = float(dlrm_loss(state2["params"],
                          _jb(remapper2.remap_batch(raw)), CFG,
                          table_hot=table_hot2))
    assert got == want

    # a fresh tracker seeded with the stamped plan starts from the applied
    # baseline: steady traffic does NOT re-trigger (no spurious re-plan)
    t2 = HotTableTracker(CFG.table_rows, n_ps=N_PS, hot_budget=CFG.hot_rows_k,
                         decay=0.8, trigger=1.2, cooldown=0, min_lookups=512,
                         initial_ranges=ranges2, initial_hot=table_hot2)
    assert t2.current_hot == decision.table_hot
    for i in range(4):
        t2.observe(remapper2.remap(_batch(3, 4096 + 256 * i)["sparse"]))
    assert t2.imbalance() < 1.1
    assert t2.maybe_replan() is None


def test_permute_train_state_touches_only_pooled_rows():
    opt = optim.adagrad(0.05)
    state = trainer.make_dlrm_train_state(CFG, opt, jax.random.PRNGKey(1))
    R = CFG.total_embedding_rows
    rng = np.random.default_rng(0)
    perm = np.concatenate([o + rng.permutation(r) for o, r in
                           zip(CFG.table_offsets, CFG.table_rows)])
    out = replan.permute_train_state(state, R, perm)
    inv = np.argsort(perm)
    np.testing.assert_array_equal(np.asarray(out["params"]["tables"]),
                                  np.asarray(state["params"]["tables"])[inv])
    np.testing.assert_array_equal(np.asarray(out["params"]["wide"]),
                                  np.asarray(state["params"]["wide"])[inv])
    np.testing.assert_array_equal(np.asarray(out["opt"]["acc"]["tables"]),
                                  np.asarray(state["opt"]["acc"]["tables"])[inv])
    # dense leaves and step counter pass through untouched
    np.testing.assert_array_equal(np.asarray(out["params"]["mlp"]["w0"]),
                                  np.asarray(state["params"]["mlp"]["w0"]))
    assert int(out["step"]) == int(state["step"])


def test_remapper_rejects_out_of_range_ids():
    """Out-of-range raw ids raise (naming table and bound) instead of
    silently wrapping into a neighboring table's rows."""
    import pytest

    rm = replan.EmbeddingRemapper((8, 4))
    ok = np.zeros((2, 2, 3), np.int64)
    np.testing.assert_array_equal(rm.remap(ok), ok)   # identity before plans
    bad = ok.copy()
    bad[1, 1, 2] = 4                                  # table 1 has rows=[0,4)
    with pytest.raises(ValueError, match=r"table 1 \(rows=4\)"):
        rm.remap(bad)
    neg = ok.copy()
    neg[0, 0, 0] = -1
    with pytest.raises(ValueError, match="out of range"):
        rm.remap(neg)
