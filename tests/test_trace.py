"""Trace ingestion layer: fixture round-trip, schema validation, synthetic
marginals, SimJob calibration, and the time-varying capacity profile."""
import dataclasses
import math

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.sim.trace import (
    REPLAYABLE_STATUSES, TRACE_COLUMNS, CapacityWave, TraceJob,
    default_trace_path, load_trace, synthesize_trace, trace_marginals,
    trace_to_jobs, write_trace,
)
from repro.sim.workload import true_throughput


def test_fixture_loads_and_has_replayable_jobs():
    rows = load_trace(default_trace_path())
    assert len(rows) >= 40
    replayable = [r for r in rows if r.status in REPLAYABLE_STATUSES]
    assert len(replayable) >= 40
    # non-replayable rows are present on purpose (loader must not choke)
    assert any(r.status not in REPLAYABLE_STATUSES for r in rows)


def test_roundtrip_write_load_identical(tmp_path):
    rows = load_trace(default_trace_path())
    p = tmp_path / "copy.csv"
    write_trace(str(p), rows)
    assert load_trace(str(p)) == rows
    # and byte-stable: writing the reloaded rows reproduces the file
    p2 = tmp_path / "copy2.csv"
    write_trace(str(p2), load_trace(str(p)))
    assert p.read_bytes() == p2.read_bytes()


def test_bad_header_rejected(tmp_path):
    p = tmp_path / "bad.csv"
    p.write_text("job,user,status\nj0,u0,Terminated\n")
    with pytest.raises(ValueError, match="bad trace header"):
        load_trace(str(p))


def test_bad_row_reports_path_and_line(tmp_path):
    p = tmp_path / "bad.csv"
    p.write_text(",".join(TRACE_COLUMNS) + "\n"
                 "j0,u0,Terminated,0,600,800,16,0,4\n"
                 "j1,u0,Terminated,60,not_a_number,800,16,0,4\n")
    with pytest.raises(ValueError, match=r"bad\.csv:3"):
        load_trace(str(p))


def test_synthesize_deterministic_and_schema_valid():
    a = synthesize_trace(50, seed=7)
    b = synthesize_trace(50, seed=7)
    assert a == b
    c = synthesize_trace(50, seed=8)
    assert c != a
    for r in a:
        assert r.status == "Terminated"
        assert r.duration >= 60.0
        assert r.plan_cpu % 100 == 0 and 100 <= r.plan_cpu <= 3200
        assert 1 <= r.inst_num <= 48
    subs = [r.submit_time for r in a]
    assert subs == sorted(subs)


def test_synthetic_marginals_match_fixture():
    """The generator's output distribution tracks the fitted marginals."""
    rows = load_trace(default_trace_path())
    replayable = [r for r in rows if r.status in REPLAYABLE_STATUSES]
    m = trace_marginals(replayable)
    syn = synthesize_trace(600, seed=0, marginals=m)
    sm = trace_marginals(syn)
    assert sm.log_duration_mean == pytest.approx(m.log_duration_mean, abs=0.35)
    assert sm.log_cpu_mean == pytest.approx(m.log_cpu_mean, abs=0.35)
    assert sm.interarrival_mean_s == pytest.approx(
        m.interarrival_mean_s, rel=0.35)
    assert sm.inst_mean == pytest.approx(m.inst_mean, rel=0.5)


def test_marginals_empty_trace_rejected():
    with pytest.raises(ValueError, match="empty trace"):
        trace_marginals([])


def test_trace_to_jobs_deterministic_and_filtered():
    rows = load_trace(default_trace_path())
    a = trace_to_jobs(rows, seed=3)
    b = trace_to_jobs(rows, seed=3)
    assert [j.job_id for j in a] == [j.job_id for j in b]
    assert [j.total_samples for j in a] == [j.total_samples for j in b]
    assert [j.user_request for j in a] == [j.user_request for j in b]
    # only replayable rows survive; arrivals are normalized and sorted
    n_replayable = sum(r.status in REPLAYABLE_STATUSES for r in rows)
    assert len(a) == n_replayable
    arr = [j.arrival_s for j in a]
    assert arr[0] == 0.0 and arr == sorted(arr)


def test_trace_to_jobs_calibrates_static_replay():
    """The static_user anchor: running each job at its user request must
    reproduce the traced duration (that's what total_samples encodes)."""
    rows = load_trace(default_trace_path())
    replayable = sorted(
        (r for r in rows if r.status in REPLAYABLE_STATUSES),
        key=lambda r: (r.submit_time, r.job_name))
    jobs = trace_to_jobs(rows, seed=3)
    for row, job in zip(replayable, jobs):
        thp = true_throughput(job, job.user_request)
        assert job.total_samples / thp == pytest.approx(row.duration, rel=0.01)


def test_trace_to_jobs_kind_is_name_stable():
    """Model-kind assignment depends only on the job name, not on seed."""
    rows = load_trace(default_trace_path())
    a = trace_to_jobs(rows, seed=0)
    b = trace_to_jobs(rows, seed=99)
    assert [j.kind for j in a] == [j.kind for j in b]


@settings(max_examples=20, deadline=None)
@given(amplitude=st.floats(0.0, 0.9), period_h=st.floats(1.0, 24.0),
       t_h=st.floats(0.0, 48.0))
def test_capacity_wave_bounds(amplitude, period_h, t_h):
    wave = CapacityWave(1000.0, 8000.0, amplitude=amplitude,
                        period_s=period_h * 3600.0)
    cpu, mem = wave(t_h * 3600.0)
    assert 1000.0 * (1 - amplitude) - 1e-6 <= cpu <= 1000.0 * (1 + amplitude) + 1e-6
    assert cpu >= 1000.0 * 0.05
    assert mem / 8000.0 == pytest.approx(cpu / 1000.0)


def test_capacity_wave_periodic_and_flat_at_zero():
    wave = CapacityWave(100.0, 800.0, amplitude=0.2, period_s=3600.0)
    assert wave(0.0)[0] == pytest.approx(wave(3600.0)[0])
    assert wave(900.0)[0] == pytest.approx(100.0 * 1.2)
    flat = CapacityWave(100.0, 800.0, amplitude=0.0)
    for t in (0.0, 1234.5, 7200.0):
        assert flat(t) == (100.0, 800.0)


def test_tracejob_is_frozen():
    row = load_trace(default_trace_path())[0]
    with pytest.raises(dataclasses.FrozenInstanceError):
        row.duration = 1.0  # type: ignore[misc]
    assert math.isfinite(row.submit_time)
