"""Resource-performance model (Eqns 1–6): NNLS fit recovery + invariants."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.perf_model import (
    JobResources, JobStatics, PerfModel, feature_vector, synthesize_t_iter,
)

STAT = JobStatics(batch_size=512, model_size=3.2e8, bandwidth=1e9, emb_dim=16)
ALPHA = [3.48e-3, 2.36e-3, 0.68e-3, 2.45e-5]
BETA = 2.45e-3


def _obs(n, seed, noise=0.0):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        r = JobResources(w=int(rng.integers(1, 24)), p=int(rng.integers(1, 12)),
                         cpu_w=float(rng.integers(1, 32)),
                         cpu_p=float(rng.integers(1, 32)))
        out.append((r, STAT, synthesize_t_iter(r, STAT, ALPHA, BETA,
                                               noise=noise, rng=rng)))
    return out


def test_nnls_exact_recovery_noiseless():
    model = PerfModel().fit(_obs(64, 0))
    np.testing.assert_allclose(model.alpha, ALPHA, rtol=0.05)
    np.testing.assert_allclose(model.beta_sum, BETA, rtol=0.1)
    assert model.rmsle(_obs(32, 1)) < 1e-3


def test_fit_with_noise_generalizes():
    model = PerfModel().fit(_obs(96, 0, noise=0.05))
    test = _obs(48, 1, noise=0.0)
    rel_errs = [abs(model.t_iter(r, s) - t) / t for r, s, t in test]
    assert np.median(rel_errs) < 0.15


def test_nonnegative_coefficients():
    model = PerfModel().fit(_obs(64, 2, noise=0.3))
    assert np.all(model.alpha >= 0) and model.beta_sum >= 0


@settings(max_examples=30, deadline=None)
@given(w=st.integers(1, 32), p=st.integers(1, 16),
       cw=st.integers(1, 32), cp=st.integers(1, 32))
def test_throughput_monotonic_in_worker_cpu(w, p, cw, cp):
    """More worker CPU never hurts T_grad => throughput non-decreasing."""
    model = PerfModel(alpha=np.array(ALPHA), beta_sum=BETA, fitted=True)
    r1 = JobResources(w=w, p=p, cpu_w=cw, cpu_p=cp)
    r2 = JobResources(w=w, p=p, cpu_w=cw * 2, cpu_p=cp)
    assert model.throughput(r2, STAT) >= model.throughput(r1, STAT) - 1e-9


# ------------------------------------------------------------ fit regression


def _grid_obs(alpha, beta_sum, noise=0.0, seed=0):
    """Structured w×p×λ grid (not random): the regression fixture the NNLS
    recovery contract is pinned against."""
    rng = np.random.default_rng(seed)
    out = []
    for w in (1, 4, 8, 16):
        for p in (1, 2, 8):
            for c in (2, 8, 24):
                r = JobResources(w=w, p=p, cpu_w=float(c), cpu_p=float(c))
                out.append((r, STAT, synthesize_t_iter(
                    r, STAT, alpha, beta_sum, noise=noise, rng=rng)))
    return out


def test_grid_recovery_rel_error_pinned():
    """Planted coefficients on the structured grid: NNLS must recover every
    α within 2 % relative error and Σβ within 5 % (noiseless)."""
    model = PerfModel().fit(_grid_obs(ALPHA, BETA))
    for a_hat, a_true in zip(model.alpha, ALPHA):
        assert abs(a_hat - a_true) / a_true < 0.02
    assert abs(model.beta_sum - BETA) / BETA < 0.05


def test_grid_recovery_under_noise_pinned():
    """5 % lognormal noise: predictions on a held-out grid stay within a
    pinned 10 % median relative error."""
    model = PerfModel().fit(_grid_obs(ALPHA, BETA, noise=0.05, seed=3))
    clean = _grid_obs(ALPHA, BETA)
    rel = [abs(model.t_iter(r, s) - t) / t for r, s, t in clean]
    assert float(np.median(rel)) < 0.10


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000), noise=st.floats(0.0, 0.5))
def test_coefficients_always_nonnegative(seed, noise):
    """The NNLS domain contract holds for any noise level and draw."""
    model = PerfModel().fit(_obs(48, seed, noise=noise))
    assert np.all(model.alpha >= 0.0)
    assert model.beta_sum >= 0.0


def test_beta_sum_identifiability_contract():
    """The four β's share the constant feature column, so only Σβ is
    identifiable (the paper reports exactly that): two ground truths whose
    per-term β's differ but sum equally must produce the same fit."""
    def synth(beta_split, seed):
        rng = np.random.default_rng(seed)
        out = []
        for r, s, _ in _grid_obs(ALPHA, 0.0):
            x = feature_vector(r, s)
            t = float(x[:4] @ np.asarray(ALPHA)) + sum(beta_split)
            out.append((r, s, max(t, 1e-6)))
        del rng
        return out

    m1 = PerfModel().fit(synth((2.45e-3, 0.0, 0.0, 0.0), 0))
    m2 = PerfModel().fit(synth((1.0e-3, 1.0e-3, 0.45e-3, 0.0), 0))
    np.testing.assert_allclose(m1.alpha, m2.alpha, rtol=1e-6, atol=1e-12)
    assert m1.beta_sum == pytest.approx(m2.beta_sum, rel=1e-6)
    assert m1.beta_sum == pytest.approx(2.45e-3, rel=0.05)


def test_degenerate_observations_fall_back():
    """All observations at one resource point: the system is singular; the
    fit must not raise and must stay in the non-negative domain."""
    r = JobResources(w=4, p=2, cpu_w=8, cpu_p=8)
    obs = [(r, STAT, 0.5)] * 12
    model = PerfModel().fit(obs)
    assert model.fitted
    assert np.all(model.alpha >= 0.0) and model.beta_sum >= 0.0
    assert model.t_iter(r, STAT) > 0.0


def test_feature_vector_matches_paper_structure():
    r = JobResources(w=4, p=2, cpu_w=8, cpu_p=8)
    x = feature_vector(r, STAT)
    assert x[0] == pytest.approx(512 / 8)               # m / λw
    assert x[1] == pytest.approx(4 / 16)                # w / (p·λp)
    assert x[2] == pytest.approx((3.2e8 / 2) / (1e9 / 4))
    assert x[3] == pytest.approx(512 * 16 / 2)          # m·D / p
    assert x[4] == 1.0
