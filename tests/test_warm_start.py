"""Warm-starting (Algorithm 1) behaviour."""
import dataclasses

from repro.core.perf_model import JobResources
from repro.core.warm_start import (
    ConfigDB, ConfigRecord, JobMeta, similarity, warm_start,
    warm_start_accuracy,
)


def _meta(kind="dcn", size=1e6, user="u0"):
    return JobMeta(kind, dense_params=size, emb_rows=1e7, emb_dim=16,
                   batch_size=512, dataset_samples=1e7, user=user)


def test_similarity_identity_is_max():
    m = _meta()
    assert similarity(m, m) >= similarity(m, _meta(size=1e9, user="zz"))


def test_most_similar_job_dominates_smoothing():
    db = ConfigDB()
    db.add(ConfigRecord(_meta(size=1e12, user="x"),
                        JobResources(w=1, p=1, cpu_w=1, cpu_p=1)))
    db.add(ConfigRecord(_meta(size=1e6, user="u0"),
                        JobResources(w=16, p=8, cpu_w=16, cpu_p=16)))
    out = warm_start(_meta(size=1e6, user="u0"), db, k=2, mu=0.8)
    # Ā = 0.8·(most similar) + 0.2·(least similar)
    assert out.w >= 12 and out.p >= 6


def test_cold_start_fallback():
    default = JobResources(w=3, p=2, cpu_w=5, cpu_p=5)
    assert warm_start(_meta(), ConfigDB(), default=default) == default


def test_homogeneous_history_returns_same_config():
    db = ConfigDB()
    cfgr = JobResources(w=8, p=4, cpu_w=8, cpu_p=8)
    for i in range(10):
        db.add(ConfigRecord(_meta(), cfgr))
    out = warm_start(_meta(), db, k=5, mu=0.5)
    assert (out.w, out.p, out.cpu_w, out.cpu_p) == (8, 4, 8.0, 8.0)


def test_accuracy_metric():
    a = JobResources(w=8, p=4, cpu_w=8, cpu_p=8)
    assert warm_start_accuracy(a, a) == 1.0
    b = dataclasses.replace(a, w=4)
    assert 0.5 < warm_start_accuracy(b, a) < 1.0
