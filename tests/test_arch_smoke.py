"""Per-arch smoke tests: reduced config of each family, one forward/train step
on CPU, assert output shapes + finite values; plus one decode step.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import reduce_config
from repro.configs.registry import ARCHS
from repro.models.common import pad_vocab
from repro.models.registry import build_model
from repro.train import optim, trainer

ARCH_IDS = sorted(ARCHS)


def _batch(cfg, B=2, S=16):
    batch = {"tokens": jnp.ones((B, S), jnp.int32),
             "targets": jnp.ones((B, S), jnp.int32)}
    if cfg.family == "encdec":
        batch["frames"] = jnp.ones((B, cfg.n_frames, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = reduce_config(ARCHS[arch])
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits = api.prefill(params, batch)
    assert logits.shape == (2, 16, pad_vocab(cfg.vocab_size))
    assert bool(jnp.all(jnp.isfinite(logits)))
    opt = optim.adam(1e-3)
    state = trainer.make_train_state(api, opt, jax.random.PRNGKey(0))
    step = trainer.make_train_step(api, opt, remat=True)
    state, metrics = jax.jit(step)(state, batch)
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics["grad_norm"])
    assert int(state["step"]) == 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch):
    cfg = reduce_config(ARCHS[arch])
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    cache = api.init_cache(2, 32, jnp.float32)
    toks = jnp.ones((2, 1), jnp.int32)
    decode = jax.jit(api.decode_step)
    logits, cache = decode(params, cache, toks)
    logits, cache = decode(params, cache, toks)
    assert logits.shape == (2, 1, pad_vocab(cfg.vocab_size))
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert int(cache["step"]) == 2


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_count_analytic_close_to_actual(arch):
    """cfg.param_count() (used for MODEL_FLOPS) tracks the real tree."""
    cfg = reduce_config(ARCHS[arch])
    api = build_model(cfg)
    shapes = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    import numpy as np
    actual = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))
    analytic = cfg.param_count()
    # padded vocab and small per-layer extras allowed: within 25 %
    assert abs(actual - analytic) / actual < 0.25, (arch, actual, analytic)
