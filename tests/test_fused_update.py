"""Fused sparse backward + row-wise optimizer update.

Covers the acceptance contract of the sparse-update engine:
  * ``dedupe_rows`` collapses duplicate store rows into one summed COO entry
    each — adversarial duplicate/hot/padding-boundary indices included — and
    pads the tail with the inert sentinel ``num_rows``.
  * scattering ``sparse_row_grads`` reproduces the dense pool cotangent BIT
    for bit (both backward paths share the same dedupe + segment step), on
    the flat and the padded physical layout.
  * the fused row update (XLA fallback and Pallas kernel in interpret mode)
    matches the dense full-pool optimizer on every touched row and is an
    exact no-op on every untouched row, for adagrad and (lazy) adam.
  * the sparse train step equals the dense train step: identical loss and
    grad norm, bit-identical adagrad pooled stores.

Property tests ride the hypothesis shim (``tests/_hypothesis_compat``): a
deterministic example sweep when hypothesis is not installed.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.dlrm_models import DCN, WIDE_DEEP, reduced_dlrm
from repro.data.synthetic import criteo_batch
from repro.kernels import ops
from repro.kernels.fused_embedding import (dedupe_rows, fused_embedding_bag,
                                           table_offsets)
from repro.sharding.policy import (EmbeddingPlan, balanced_vocab_ranges,
                                   padded_layout_for_ranges)
from repro.train import optim, trainer

jax.config.update("jax_platform_name", "cpu")

ROWS_PER_TABLE = (40, 24, 64, 8)
OFFSETS = table_offsets(ROWS_PER_TABLE)
TOTAL = sum(ROWS_PER_TABLE)
TABLE_HOT = (8, 4, 16, 2)


def _plan(combiner="sum", *, table_hot=None, layout=None):
    return EmbeddingPlan(offsets=OFFSETS, combiner=combiner, block_b=4,
                         table_hot=table_hot, layout=layout)


def _assert_ulp_close(a, b, max_ulp, msg=""):
    """Float32 arrays equal up to ``max_ulp`` units in the last place.

    XLA is free to contract ``a*b + c`` into an FMA, and whether it does so
    differs between lowerings (gather/scatter fallback vs the interpreted
    Pallas kernel body) and across shapes — so cross-lowering comparisons
    are ULP-bounded, not bit-exact.  Exactness claims (untouched rows,
    sentinel no-ops) stay ``assert_array_equal``.
    """
    a = np.ascontiguousarray(np.asarray(a, np.float32))
    b = np.ascontiguousarray(np.asarray(b, np.float32))
    ai = a.view(np.int32).astype(np.int64)
    bi = b.view(np.int32).astype(np.int64)
    # fold the sign-magnitude float encoding onto a monotone integer line
    ai = np.where(ai < 0, np.int64(-2**31) - ai, ai)
    bi = np.where(bi < 0, np.int64(-2**31) - bi, bi)
    ulp = int(np.abs(ai - bi).max()) if a.size else 0
    assert ulp <= max_ulp, (
        f"{msg}max ULP distance {ulp} > {max_ulp} "
        f"(max abs diff {np.abs(a - b).max():.3e})")


def _layout():
    """A physically-unequal padded layout over the pooled rows."""
    counts = np.concatenate(
        [np.arange(r, 0, -1.0) ** 2 for r in ROWS_PER_TABLE])
    lay = padded_layout_for_ranges(balanced_vocab_ranges(counts, 3))
    assert len(set(lay.shard_sizes)) > 1
    return lay


def _inputs(B=6, H=4, D=8, seed=0):
    rng = np.random.default_rng(seed)
    pool = jnp.asarray(rng.standard_normal((TOTAL, D), np.float32))
    idx = np.stack([rng.integers(0, r, (B, H)) for r in ROWS_PER_TABLE],
                   axis=1)
    g = jnp.asarray(
        rng.standard_normal((B, len(ROWS_PER_TABLE), D), np.float32))
    return pool, jnp.asarray(idx.astype(np.int32)), g


# ---------------------------------------------------------------------------
# dedupe: duplicate / hot / boundary rows collapse into one entry each
# ---------------------------------------------------------------------------
def test_dedupe_rows_adversarial_duplicates():
    """Hot row repeated across bags, in-bag duplicates, boundary rows 0 and
    R-1 — every duplicate collapses to one entry with the exact sum."""
    R, D = 50, 4
    store = jnp.asarray(
        [7, 7, 7, 7, 0, 49, 0, 7, 3, 49, 49, 7], jnp.int32)
    g = jnp.asarray(np.arange(12 * D, dtype=np.float32).reshape(12, D))
    rows, vals = jax.jit(
        lambda s, gr: dedupe_rows(s, gr, R))(store, g)
    rows_np, vals_np = np.asarray(rows), np.asarray(vals)
    touched = rows_np[rows_np < R]
    assert sorted(touched.tolist()) == [0, 3, 7, 49]
    assert len(set(touched.tolist())) == len(touched)   # unique
    assert (rows_np[len(touched):] == R).all()          # sentinel tail
    assert (vals_np[len(touched):] == 0.0).all()
    want = np.zeros((R, D), np.float64)
    np.add.at(want, np.asarray(store), np.asarray(g, np.float64))
    got = np.zeros((R, D), np.float64)
    got[touched] = vals_np[rows_np < R]
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-6)


@pytest.mark.parametrize("combiner", ["sum", "mean"])
def test_duplicate_rows_within_bag_backward_regression(combiner):
    """Regression for the in-bag-duplicate ordering bug: the backward no
    longer leans on segment_sum visit order — duplicates are deduped into
    one summed contribution, so the fused dense grad, the scattered COO
    grad, and plain autodiff all agree."""
    pool, idx, _ = _inputs()
    # force duplicates inside every bag AND a cross-bag hot row at a table
    # boundary (local 0 of table 2 = pooled row OFFSETS[2])
    idx = idx.at[:, :, 1].set(idx[:, :, 0])
    idx = idx.at[:, 2, 2].set(0)
    plan = _plan(combiner)

    def loss(p):
        return jnp.sum(fused_embedding_bag(p, idx, plan=plan) * 1.3)

    g_dense = jax.jit(jax.grad(loss))(pool)

    def scatter(p):
        ct = jax.grad(lambda o: jnp.sum(o * 1.3))(
            fused_embedding_bag(p, idx, plan=plan))
        rows, vals, _ = ops.sparse_row_grads(p, idx, ct, plan=plan)
        return jnp.zeros_like(p).at[rows].add(vals)

    # both paths share one dedupe: bit-identical, not merely close
    np.testing.assert_array_equal(np.asarray(jax.jit(scatter)(pool)),
                                  np.asarray(g_dense))

    from repro.kernels import ref
    g_ref = jax.jit(jax.grad(lambda p: jnp.sum(ref.fused_embedding_bag_ref(
        p, idx, offsets=OFFSETS, combiner=combiner) * 1.3)))(pool)
    np.testing.assert_allclose(np.asarray(g_dense), np.asarray(g_ref),
                               atol=1e-5, rtol=1e-5)


def test_sparse_row_grads_padded_layout_never_touches_padding():
    pool, idx, g = _inputs(seed=2)
    lay = _layout()
    ppool = lay.pad_rows(pool).reshape(lay.padded_rows, -1)
    plan = _plan(layout=lay)
    rows, vals, _ = jax.jit(lambda p, i, ct: ops.sparse_row_grads(
        p, i, ct, plan=plan))(ppool, idx, g)
    rows_np = np.asarray(rows)
    live = rows_np[rows_np < lay.padded_rows]
    mask = np.asarray(lay.padding_mask()).reshape(-1)
    assert mask[live].all()                       # only real rows touched
    # scattering reproduces the padded dense cotangent bit for bit
    dpool = jax.jit(lambda p, i, ct: jax.vjp(
        lambda q: fused_embedding_bag(q, i, plan=plan), p)[1](ct)[0])(
            ppool, idx, g)
    scat = jnp.zeros_like(ppool).at[rows].add(vals)
    np.testing.assert_array_equal(np.asarray(scat), np.asarray(dpool))


# ---------------------------------------------------------------------------
# fused row update: property test against the dense-grad reference
# ---------------------------------------------------------------------------
def _dense_reference(kind, pool, dense_grad, state, lr):
    """Row-wise optimizer expression applied from the DENSE cotangent."""
    if kind == "adagrad":
        acc = state["acc"] + jnp.square(dense_grad)
        upd = -lr * dense_grad / (jnp.sqrt(acc) + 1e-10)
        return pool + upd, {"acc": acc}
    m = 0.9 * state["m"] + 0.1 * dense_grad
    v = 0.999 * state["v"] + 0.001 * jnp.square(dense_grad)
    tc = (state["count"] + 1).astype(jnp.float32)
    mh = m / (1 - 0.9 ** tc)
    vh = v / (1 - 0.999 ** tc)
    return pool - lr * mh / (jnp.sqrt(vh) + 1e-8), {"m": m, "v": v}


@settings(max_examples=12, deadline=None)
@given(
    combiner=st.sampled_from(["sum", "mean", "max"]),
    padded=st.booleans(),
    hot=st.booleans(),
    kind=st.sampled_from(["adagrad", "adam"]),
    seed=st.integers(0, 99),
)
def test_fused_update_matches_dense_reference(combiner, padded, hot, kind,
                                              seed):
    """fused backward+update == dense-grad reference on touched rows
    (ULP-bounded), exact no-op on untouched rows — across combiners x
    {flat, padded} x table_hot on/off, adagrad and (lazy) adam."""
    pool, idx, g = _inputs(seed=seed)
    lay = _layout() if padded else None
    plan = _plan(combiner, table_hot=TABLE_HOT if hot else None, layout=lay)
    store = lay.pad_rows(pool).reshape(lay.padded_rows, -1) if padded \
        else pool
    rng = np.random.default_rng(seed + 1000)
    lr = 0.05
    if kind == "adagrad":
        state = {"acc": jnp.asarray(
            np.abs(rng.standard_normal(store.shape)).astype(np.float32))}
    else:
        state = {"m": jnp.asarray(
                     rng.standard_normal(store.shape).astype(np.float32)),
                 "v": jnp.asarray(
                     np.abs(rng.standard_normal(store.shape))
                     .astype(np.float32)),
                 "count": jnp.asarray(3, jnp.int32)}

    def sparse(p, st_, ct):
        rows, vals, _ = ops.sparse_row_grads(p, idx, ct, plan=plan)
        if kind == "adagrad":
            new_p, acc = ops.fused_row_update(
                p, rows, vals, st_["acc"], kind="adagrad", impl="xla",
                lr=lr, eps=1e-10)
            return new_p, {"acc": acc}
        tc = (st_["count"] + 1).astype(jnp.float32)
        new_p, m, v = ops.fused_row_update(
            p, rows, vals, st_["m"], st_["v"], kind="adam", impl="xla",
            lr=lr, count=tc, eps=1e-8)
        return new_p, {"m": m, "v": v}

    def dense(p, st_, ct):
        dp = jax.vjp(lambda q: fused_embedding_bag(q, idx, plan=plan),
                     p)[1](ct)[0]
        return _dense_reference(kind, p, dp, st_, lr), dp

    new_p, new_st = jax.jit(sparse)(store, state, g)
    (ref_p, ref_st), dp = jax.jit(dense)(store, state, g)

    touched = np.unique(np.asarray(
        jax.jit(lambda p, ct: ops.sparse_row_grads(
            p, idx, ct, plan=plan)[0])(store, g)))
    touched = touched[touched < store.shape[0]]
    untouched = np.setdiff1d(np.arange(store.shape[0]), touched)

    # touched rows: ULP-bounded vs the dense reference.  params get the
    # wider bound: a 1-ULP FMA divergence in the moment accumulate is
    # amplified by sqrt/divide and the near-cancelling ``p + upd``.
    _assert_ulp_close(np.asarray(new_p)[touched],
                      np.asarray(ref_p)[touched], 64, "params: ")
    # untouched rows: params bit-unchanged; moments bit-unchanged (adagrad
    # is exact; adam is LAZY — no decay off the lookup path)
    np.testing.assert_array_equal(np.asarray(new_p)[untouched],
                                  np.asarray(store)[untouched])
    for name in ("acc", "m", "v"):
        if name in state:
            _assert_ulp_close(np.asarray(new_st[name])[touched],
                              np.asarray(ref_st[name])[touched], 4,
                              f"{name}: ")
            np.testing.assert_array_equal(
                np.asarray(new_st[name])[untouched],
                np.asarray(state[name])[untouched])
    # dense grad really had zero mass on the untouched rows (sanity)
    assert float(jnp.abs(jnp.asarray(dp)[untouched]).max()) == 0.0


@pytest.mark.parametrize("kind", ["adagrad", "adam"])
def test_row_update_interpret_matches_xla(kind):
    """The Pallas row-update kernel (interpret) == XLA fallback to within a
    few ULPs under jit (XLA may contract the multiply-adds into FMAs
    differently between the two lowerings)."""
    rng = np.random.default_rng(7)
    R, D, N = 40, 8, 24
    params = jnp.asarray(rng.standard_normal((R, D)).astype(np.float32))
    rows = jnp.asarray(
        np.concatenate([rng.choice(R, N - 4, replace=False),
                        [R] * 4]).astype(np.int32))   # sentinel tail
    vals = jnp.asarray(rng.standard_normal((N, D)).astype(np.float32))
    vals = vals.at[N - 4:].set(0.0)

    acc = jnp.asarray(np.abs(rng.standard_normal((R, D))).astype(np.float32))
    m0 = jnp.asarray(rng.standard_normal((R, D)).astype(np.float32))
    v0 = jnp.asarray(np.abs(rng.standard_normal((R, D))).astype(np.float32))

    def run(impl):
        if kind == "adagrad":
            f = jax.jit(lambda p, a: ops.fused_row_update(
                p, rows, vals, a, kind="adagrad", impl=impl, block=5,
                lr=0.1, eps=1e-10))
            return f(params, acc)
        f = jax.jit(lambda p, m_, v_: ops.fused_row_update(
            p, rows, vals, m_, v_, kind="adam", impl=impl, block=5,
            lr=0.1, count=jnp.asarray(1.0), eps=1e-8, weight_decay=0.01))
        return f(params, m0, v0)

    for a, b in zip(run("xla"), run("interpret")):
        _assert_ulp_close(a, b, 8)


def test_row_update_sentinel_rows_are_inert():
    """Entries >= R (dedupe padding) must not touch any pool row."""
    R, D = 10, 4
    params = jnp.ones((R, D), jnp.float32)
    acc = jnp.ones((R, D), jnp.float32)
    rows = jnp.asarray([R, R, R, R], jnp.int32)
    vals = jnp.full((4, D), 123.0, jnp.float32)    # non-zero on purpose
    for impl in ("xla", "interpret"):
        new_p, new_a = jax.jit(lambda p, a: ops.fused_row_update(
            p, rows, vals, a, kind="adagrad", impl=impl, block=4,
            lr=0.1, eps=1e-10))(params, acc)
        np.testing.assert_array_equal(np.asarray(new_p), np.asarray(params))
        np.testing.assert_array_equal(np.asarray(new_a), np.asarray(acc))


def test_fused_row_update_unknown_kind():
    with pytest.raises(ValueError, match="unknown row-update kind"):
        ops.fused_row_update(jnp.zeros((4, 2)), jnp.zeros((1,), jnp.int32),
                             jnp.zeros((1, 2)), jnp.zeros((4, 2)),
                             kind="rmsprop")


# ---------------------------------------------------------------------------
# Optimizer.update_rows seam
# ---------------------------------------------------------------------------
def test_optimizer_update_rows_seam():
    assert optim.adagrad(0.05).update_rows is not None
    assert optim.adam(1e-3).update_rows is not None
    assert optim.adam(1e-3, master_weights=True).update_rows is None
    assert optim.sgd(0.1).update_rows is None


def test_sparse_row_grad_leaf_to_dense():
    rows = jnp.asarray([1, 3, 5, 6], jnp.int32)    # 6 == num_rows: dropped
    vals = jnp.asarray(np.arange(8, dtype=np.float32).reshape(4, 2))
    dense = optim.SparseRowGrad(rows, vals).to_dense(6)
    assert dense.shape == (6, 2)
    np.testing.assert_array_equal(np.asarray(dense[1]), [0.0, 1.0])
    np.testing.assert_array_equal(np.asarray(dense[0]), [0.0, 0.0])


# ---------------------------------------------------------------------------
# the sparse train step == the dense train step
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("base", [WIDE_DEEP, DCN])
@pytest.mark.parametrize("opt_name", ["adagrad", "adam"])
def test_sparse_step_matches_dense_step(base, opt_name):
    cfg = reduced_dlrm(base)
    opt = optim.make(opt_name, 0.05)
    state = trainer.make_dlrm_train_state(cfg, opt, jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v)
             for k, v in criteo_batch(cfg, 7, np.arange(16)).items()}
    dense_step = jax.jit(trainer.make_dlrm_train_step(cfg, opt))
    sparse_step = jax.jit(trainer.make_dlrm_train_step(
        cfg, opt, plan=cfg.embedding_plan(sparse_update=True)))
    s_d, m_d = dense_step(state, batch)
    s_s, m_s = sparse_step(state, batch)
    assert float(m_d["loss"]) == float(m_s["loss"])
    assert float(m_d["grad_norm"]) == float(m_s["grad_norm"])
    if opt_name == "adagrad":       # bit-exact (adam differs on untouched
        for k in ("tables",):       # moments: lazy vs decaying)
            np.testing.assert_array_equal(
                np.asarray(s_d["params"][k]), np.asarray(s_s["params"][k]))
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(b, np.float32),
        atol=1e-6, rtol=1e-6), s_d["params"], s_s["params"])
    assert int(s_s["step"]) == 1


def test_sparse_step_requires_update_rows_falls_back():
    """sgd has no row-update seam: the plan's sparse_update flag quietly
    compiles the dense step instead (documented fallback)."""
    cfg = reduced_dlrm(WIDE_DEEP)
    opt = optim.sgd(0.1)
    state = trainer.make_dlrm_train_state(cfg, opt, jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v)
             for k, v in criteo_batch(cfg, 7, np.arange(8)).items()}
    step = jax.jit(trainer.make_dlrm_train_step(
        cfg, opt, plan=cfg.embedding_plan(sparse_update=True)))
    s1, m1 = step(state, batch)
    dense = jax.jit(trainer.make_dlrm_train_step(cfg, opt))
    s2, m2 = dense(state, batch)
    assert float(m1["loss"]) == float(m2["loss"])
    np.testing.assert_array_equal(np.asarray(s1["params"]["tables"]),
                                  np.asarray(s2["params"]["tables"]))


# ---------------------------------------------------------------------------
# EmbeddingPlan surface
# ---------------------------------------------------------------------------
def test_embedding_plan_frozen_hashable_validated():
    plan = _plan("mean", table_hot=TABLE_HOT)
    assert isinstance(hash(plan), int)              # jit-cache key material
    assert plan.n_tables == len(ROWS_PER_TABLE)
    with pytest.raises(Exception):
        plan.combiner = "sum"                       # frozen
    with pytest.raises(ValueError):
        EmbeddingPlan(combiner="median")
    assert plan.with_combiner("sum").combiner == "sum"
    assert plan.with_combiner("sum").table_hot == plan.table_hot
    rep = plan.with_replan((1, 1, 1, 1), None)
    assert rep.table_hot == (1, 1, 1, 1) and rep.layout is None
    assert rep.combiner == "mean" and rep.offsets == plan.offsets
