"""Property: dynamic data sharding is exactly-once under ANY event sequence
(failures, stragglers, elastic worker churn). Hypothesis drives the chaos.
"""
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.sharding_service import ShardingService


@settings(max_examples=60, deadline=None)
@given(
    total=st.integers(16, 600),
    shard_size=st.integers(4, 128),
    n_workers=st.integers(1, 6),
    seed=st.integers(0, 10_000),
    fail_prob=st.floats(0.0, 0.4),
    straggle_prob=st.floats(0.0, 0.4),
)
def test_exactly_once_under_chaos(total, shard_size, n_workers, seed,
                                  fail_prob, straggle_prob):
    rng = np.random.default_rng(seed)
    svc = ShardingService(total, shard_size, min_shard=2,
                          heartbeat_timeout=1e9)
    clock = [0.0]

    def now():
        clock[0] += 1.0
        return clock[0]

    alive = {f"w{i}" for i in range(n_workers)}
    spawned = n_workers
    guard = 0
    while guard < 100_000:
        guard += 1
        if not alive:
            alive.add(f"w{spawned}")
            spawned += 1
        w = rng.choice(sorted(alive))
        r = rng.random()
        if r < fail_prob:
            svc.report_failure(w, now())
            alive.discard(w)
            if rng.random() < 0.8:          # elastic replacement
                alive.add(f"w{spawned}")
                spawned += 1
            continue
        if r < fail_prob + straggle_prob:
            svc._view(w, now()).is_straggler = True
        shard = svc.request_shard(w, now())
        if shard is None:
            if all(svc._view(x, now()).shard is None for x in alive):
                break
            continue
        # consume with heartbeats, then either finish or loop (may fail later)
        svc.heartbeat(w, shard.size // 2, now())
        if rng.random() < 0.9:
            svc.report_done(w, shard.index, now())
    ok, covered, dup = svc.coverage(0)
    # drain any shards still held by living workers
    for w in list(alive):
        v = svc._view(w, now())
        if v.shard is not None:
            svc.report_done(w, v.shard.index, now())
    while True:
        s = svc.request_shard("drainer", now())
        if s is None:
            break
        svc.report_done("drainer", s.index, now())
    ok, covered, dup = svc.coverage(0)
    assert ok, (covered, dup, total)
    assert covered == total
    assert dup == 0


def test_straggler_receives_smaller_shards():
    svc = ShardingService(1000, shard_size=100, min_shard=10)
    svc._view("slow", 0.0).is_straggler = True
    s_fast = svc.request_shard("fast", 1.0)
    s_slow = svc.request_shard("slow", 1.0)
    assert s_slow.size < s_fast.size


def test_heartbeat_timeout_reaps_and_requeues():
    svc = ShardingService(100, shard_size=50, heartbeat_timeout=5.0)
    s = svc.request_shard("w0", 0.0)
    assert s is not None
    dead = svc.check_failures(100.0)
    assert "w0" in dead
    s2 = svc.request_shard("w1", 101.0)
    assert (s2.start, s2.end) == (s.start, s.end)


def test_multi_epoch_refill():
    svc = ShardingService(64, shard_size=32, num_epochs=2)
    seen = []
    while True:
        s = svc.request_shard("w", 0.0)
        if s is None:
            break
        seen.append(s)
        svc.report_done("w", s.index, 0.0)
    assert len(seen) == 4                     # 2 shards × 2 epochs
    assert {s.epoch for s in seen} == {0, 1}
