"""Deterministic fault injection: spec grammar, firing semantics, hooks."""
import os
import tempfile
import threading
import time

import pytest

from repro.core.faults import (
    AttemptAbandoned, FaultInjector, FaultPlan, FaultSpec, PSShardLoss,
    TransientOOM, corrupt_blob, parse_chaos_spec, random_plan,
)


# ---------------------------------------------------------------- spec grammar
def test_parse_round_trip():
    spec = "ps_loss@10,hang@20:0.5,straggler@30x5:0.07"
    plan = parse_chaos_spec(spec)
    assert str(plan) == spec
    assert parse_chaos_spec(str(plan)) == plan


def test_parse_defaults_and_windows():
    plan = parse_chaos_spec("straggler@30x5")
    (s,) = plan.specs
    assert s.param == 0.05                      # kind default filled in
    assert plan.at_step(29) == []
    assert plan.at_step(30) == [s]
    assert plan.at_step(34) == [s]
    assert plan.at_step(35) == []


def test_parse_empty_and_errors():
    assert parse_chaos_spec("") == FaultPlan()
    assert parse_chaos_spec("  ") == FaultPlan()
    with pytest.raises(ValueError, match="kind@step"):
        parse_chaos_spec("ps_loss")
    with pytest.raises(ValueError, match="unknown fault kind"):
        parse_chaos_spec("explode@5")
    with pytest.raises(ValueError, match="bad fault window"):
        FaultSpec("hang", step=-1)
    with pytest.raises(ValueError, match="bad fault window"):
        FaultSpec("hang", step=0, count=0)


def test_random_plan_deterministic():
    a, b = random_plan(4, 100, seed=7), random_plan(4, 100, seed=7)
    assert a == b
    assert random_plan(4, 100, seed=8) != a
    assert all(1 <= s.step < 100 for s in a.specs)


# ------------------------------------------------------------ firing semantics
def test_crash_faults_fire_once():
    inj = FaultInjector(parse_chaos_spec("ps_loss@3:2,oom@5"))
    inj.before_step(2)                          # nothing scheduled
    with pytest.raises(PSShardLoss) as e:
        inj.before_step(3)
    assert e.value.n_lost == 2
    inj.before_step(3)                          # spent: replay doesn't re-fire
    with pytest.raises(TransientOOM):
        inj.before_step(5)
    inj.before_step(5)
    assert [k for _, k in inj.fired] == ["ps_loss", "oom"]


def test_hang_is_cancellable():
    inj = FaultInjector(parse_chaos_spec("hang@1:30"))
    cancel = threading.Event()
    t = threading.Timer(0.05, cancel.set)
    t.start()
    t0 = time.monotonic()
    with pytest.raises(AttemptAbandoned):
        inj.before_step(1, cancel)
    assert time.monotonic() - t0 < 5.0          # unwound, not a 30 s stall


def test_short_hang_completes():
    inj = FaultInjector(parse_chaos_spec("hang@1:0.05"))
    t0 = time.monotonic()
    inj.before_step(1)                          # no cancel: sleeps it out
    assert time.monotonic() - t0 >= 0.04


def test_straggler_delays_batch():
    inj = FaultInjector(parse_chaos_spec("straggler@2x2:0.05"))
    t0 = time.monotonic()
    inj.on_batch(1)
    assert time.monotonic() - t0 < 0.04
    t0 = time.monotonic()
    inj.on_batch(2)
    assert time.monotonic() - t0 >= 0.04
    t0 = time.monotonic()
    inj.on_batch(2)                             # spent for this step
    assert time.monotonic() - t0 < 0.04
    t0 = time.monotonic()
    inj.on_batch(3)                             # window covers step 3 too
    assert time.monotonic() - t0 >= 0.04


def test_injection_log_records_what_fired():
    inj = FaultInjector(parse_chaos_spec("oom@1"))
    with pytest.raises(TransientOOM):
        inj.before_step(1)
    (entry,) = inj.log
    assert entry["kind"] == "fault_injected"
    assert entry["fault"] == "oom" and entry["step"] == 1


# --------------------------------------------------------------- blob sabotage
def test_corrupt_blob_flip_deterministic():
    def make(d, name="blob.bin"):
        p = os.path.join(d, name)
        with open(p, "wb") as f:
            f.write(bytes(range(256)) * 16)
        return p

    with tempfile.TemporaryDirectory() as d:
        a, b = make(d, "a"), make(d, "b")
        corrupt_blob(a, seed=3)
        corrupt_blob(b, seed=3)
        assert open(a, "rb").read() == open(b, "rb").read()
        assert open(a, "rb").read() != bytes(range(256)) * 16


def test_corrupt_blob_truncate():
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "blob.bin")
        with open(p, "wb") as f:
            f.write(b"x" * 1000)
        msg = corrupt_blob(p, mode="truncate")
        assert os.path.getsize(p) == 500 and "truncated" in msg


# ----------------------------------------------------------- data-pipeline hook
def test_shard_loader_fault_hook_sees_batch_indices():
    from repro.core.sharding_service import ShardingService
    from repro.data.pipeline import ShardDataLoader

    seen = []
    svc = ShardingService(64, shard_size=32)
    loader = ShardDataLoader(svc, "w0", lambda idx: {"idx": idx},
                             batch_size=16, fault_hook=seen.append)
    batches = list(loader)
    assert len(batches) == 4
    assert seen == [0, 1, 2, 3]                 # hook fired before every batch
