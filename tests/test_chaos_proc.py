"""Kill-matrix integration suite: real worker processes under the job master.

Each cell trains the reduced wide_deep DLRM for 10 steps in a REAL
subprocess (``repro.train.worker_main``) while ``--chaos-proc`` scripts the
worker's death — SIGKILL before a step, SIGSTOP (caught by the heartbeat
deadline), SIGKILL inside the checkpoint pre-commit window, or a repeated
kill loop — and the job master re-execs it from the newest valid
layout-stamped checkpoint.

The headline assertion in every cell: the merged per-step loss log (latest
incarnation wins for replayed steps) equals the no-fault subprocess run's
**to the ulp** — recovery is bit-exact, not approximately converged. The
measured death→ready latencies are then fed into
``MigrationTimings.worker_reexec_s`` and priced by the cluster sim.

Cells spawn JIT-compiling subprocesses (~5 s each incarnation); the matrix
covers {fault kind} x {kill step} x {n_ps} x {padded/flat} with each axis
value hit at least twice. CI's ``chaos-proc-smoke`` job runs only the
``kill_at4-ps4-padded`` cell (plus its baseline) under a hard deadline.
"""
import json
import os

import pytest

from repro.core.migration import MigrationTimings
from repro.train.job_master import (JobMaster, JobMasterConfig,
                                    JobMasterReport, WorkerSpec)

pytestmark = pytest.mark.chaos_proc

STEPS = 10
CKPT_EVERY = 3
# generous in-harness deadline per master run: a cell is 2-3 incarnations
# x (imports + JIT) plus backoff; a hung cell fails fast instead of wedging
# the suite (JobMasterDeadlineExceeded)
RUN_DEADLINE_S = 300.0


def run_master(root, name, *, chaos, n_ps, padded,
               heartbeat_deadline_s=4.0, max_reexecs=5):
    workdir = os.path.join(str(root), name)
    spec = WorkerSpec(name=name, workdir=workdir,
                      ckpt_dir=os.path.join(workdir, "ckpt"),
                      steps=STEPS, ckpt_every=CKPT_EVERY,
                      n_ps=n_ps, padded=padded, chaos_proc=chaos)
    master = JobMaster([spec], JobMasterConfig(
        heartbeat_deadline_s=heartbeat_deadline_s,
        max_reexecs=max_reexecs, run_deadline_s=RUN_DEADLINE_S))
    report = master.run()
    return spec, report


def merged_losses(spec):
    """Per-step loss with the LATEST incarnation winning replayed steps —
    exactly what survives a recovery."""
    best = {}
    for rec in sorted(spec.read_losses(), key=lambda r: r["incarnation"]):
        best[rec["step"]] = rec["loss"]
    return [best[s] for s in sorted(best)]


@pytest.fixture(scope="module")
def baseline(tmp_path_factory):
    """No-fault subprocess runs, one per (n_ps, padded) config, cached —
    the identical code path the chaos cells must reproduce bit-exactly."""
    cache = {}

    def get(n_ps, padded):
        key = (n_ps, padded)
        if key not in cache:
            root = tmp_path_factory.mktemp(f"base-ps{n_ps}-{padded}")
            spec, report = run_master(root, "base", chaos="",
                                      n_ps=n_ps, padded=padded)
            assert report.completed and report.reexecs == 0
            losses = merged_losses(spec)
            assert len(losses) == STEPS
            cache[key] = losses
        return cache[key]

    return get


# the kill matrix: every fault kind, kill step, n_ps and layout appears in
# at least two cells; expected_reexecs is a floor (stop cells may take an
# extra poll cycle but exactly one SIGSTOP fires)
MATRIX = [
    # id                       chaos            n_ps padded  min_reexecs
    ("kill_at4-ps4-padded",    "kill@4",        4,   True,   1),
    ("kill_at7-ps2-flat",      "kill@7",        2,   False,  1),
    ("stop_at4-ps4-flat",      "stop@4",        4,   False,  1),
    ("stop_at7-ps2-padded",    "stop@7",        2,   True,   1),
    ("killckpt_at3-ps4-padded", "kill_ckpt@3",  4,   True,   1),
    ("killckpt_at6-ps2-flat",  "kill_ckpt@6",   2,   False,  1),
    ("killloop_at4x2-ps2-padded", "kill_loop@4x2", 2, True,  2),
    ("killloop_at7x2-ps4-flat", "kill_loop@7x2", 4,  False,  2),
]


@pytest.mark.parametrize("cell,chaos,n_ps,padded,min_reexecs",
                         MATRIX, ids=[m[0] for m in MATRIX])
def test_kill_matrix_bit_exact(tmp_path, baseline, cell, chaos, n_ps,
                               padded, min_reexecs):
    spec, report = run_master(tmp_path, cell, chaos=chaos,
                              n_ps=n_ps, padded=padded)

    assert report.completed, f"cell {cell} did not complete: {report.events}"
    assert report.final_steps[cell] == STEPS
    assert report.reexecs >= min_reexecs
    # every non-final incarnation died by SIGKILL (the master SIGKILLs
    # SIGSTOPped husks too); the final one exited cleanly
    history = report.exit_history[cell]
    assert history[-1] == 0
    assert all(rc == -9 for rc in history[:-1])

    # headline: post-recovery trajectory == no-fault trajectory, to the ulp
    losses = merged_losses(spec)
    base = baseline(n_ps, padded)
    assert len(losses) == STEPS
    assert losses == base, (
        f"cell {cell}: recovery not bit-exact\n got  {losses}\n want {base}")

    # each re-exec produced a measured death -> ready latency, and the
    # replacement's flash restore was timed
    assert len(report.reexec_latencies_s) >= min_reexecs
    assert all(lat > 0 for lat in report.reexec_latencies_s)
    assert len(report.restore_latencies_s) >= min_reexecs
    assert all(lat > 0 for lat in report.restore_latencies_s)

    # the scripted faults left a durable trace (O_APPEND + fsync survives
    # the SIGKILL that follows)
    fired = [json.loads(ln) for ln in open(spec.faults_path)]
    assert len(fired) >= min_reexecs
    kind = chaos.split("@")[0]
    assert all(rec["fault"] == kind for rec in fired)

    # kill-during-save never poisons the store: whatever staging dirs the
    # SIGKILL stranded, valid_steps counted none of them (satellite fix)
    if kind == "kill_ckpt":
        committed = [d for d in os.listdir(spec.ckpt_dir)
                     if d.startswith("ckpt_") and ".tmp-" not in d]
        assert committed, "no committed checkpoint survived"

    # the measured latencies price worker replacement in the cluster sim
    timings = report.measured_timings()
    mean = sum(report.reexec_latencies_s) / len(report.reexec_latencies_s)
    assert timings.worker_reexec_s == pytest.approx(mean)
    assert timings.reexec_s() == pytest.approx(mean)


def test_master_event_log_roundtrip(tmp_path, baseline):
    """The structured event log is valid JSONL ending in a summary line."""
    spec, report = run_master(tmp_path, "evlog", chaos="kill@4",
                              n_ps=4, padded=True)
    assert report.completed
    path = os.path.join(str(tmp_path), "events.jsonl")
    master = JobMaster([spec])          # write path only needs the events
    master.events = report.events
    master.write_event_log(path, report)
    lines = [json.loads(ln) for ln in open(path)]
    assert lines[-1]["kind"] == "summary"
    assert lines[-1]["reexecs"] == report.reexecs
    assert lines[-1]["completed"] is True
    kinds = [ln["kind"] for ln in lines]
    assert "worker_died" in kinds and "reexec_ready" in kinds
    # and the bit-exactness holds on this extra cell too
    assert merged_losses(spec) == baseline(4, True)


def test_measured_timings_shorten_sim_recovery():
    """Feeding measured re-exec latency into the sim shrinks the worker
    replacement horizon from the 300 s pod-provision default."""
    report = JobMasterReport(
        completed=True, final_steps={"w": STEPS}, reexecs=1,
        exit_history={"w": [-9, 0]}, reexec_latencies_s=[1.7],
        restore_latencies_s=[1.1], wall_seconds=9.0, events=[])
    t = report.measured_timings()
    assert t.reexec_s() == pytest.approx(1.7)
    assert t.flash_ckpt_load_s == pytest.approx(1.1)
    # default (unmeasured) timings keep the conservative provision horizon,
    # so pinned sim/bench artifacts are unchanged
    assert MigrationTimings().reexec_s() == MigrationTimings().provision_s
