"""Per-kernel correctness: Pallas (interpret=True) vs pure-jnp oracles.

Sweeps shapes/dtypes with hypothesis; every kernel must match ref.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.models.attention import chunked_attention
from repro.sharding.policy import EmbeddingPlan


def embedding_bag(table, idx, w=None, *, combiner="sum", interpret=False):
    """Single-table bag through the public plan API (ex-legacy module)."""
    return ops.embedding_bag(table, idx, w,
                             plan=EmbeddingPlan(combiner=combiner),
                             impl="interpret" if interpret else None)

jax.config.update("jax_platform_name", "cpu")


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


# ---------------------------------------------------------------------------
# embedding_bag
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(
    B=st.integers(1, 9),
    n=st.integers(1, 7),
    R=st.integers(4, 80),
    D=st.sampled_from([4, 8, 16, 32]),
    comb=st.sampled_from(["sum", "mean", "max"]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_embedding_bag_sweep(B, n, R, D, comb, dtype):
    key = jax.random.PRNGKey(B * 1000 + n * 100 + R)
    table = jax.random.normal(key, (R, D), dtype=jnp.float32).astype(dtype)
    idx = jax.random.randint(jax.random.fold_in(key, 1), (B, n), 0, R)
    out = embedding_bag(table, idx, combiner=comb, interpret=True)
    expect = ref.embedding_bag_ref(table, idx, combiner=comb)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


def test_embedding_bag_weighted():
    key = jax.random.PRNGKey(0)
    table = jax.random.normal(key, (50, 16))
    idx = jax.random.randint(jax.random.fold_in(key, 1), (6, 4), 0, 50)
    w = jax.random.uniform(jax.random.fold_in(key, 2), (6, 4))
    out = embedding_bag(table, idx, w, combiner="sum", interpret=True)
    expect = ref.embedding_bag_ref(table, idx, w, combiner="sum")
    np.testing.assert_allclose(out, expect, atol=1e-5, rtol=1e-5)


def test_embedding_bag_repeated_indices():
    table = jnp.eye(8, 8)
    idx = jnp.array([[3, 3, 3]])
    out = embedding_bag(table, idx, combiner="sum", interpret=True)
    assert float(out[0, 3]) == 3.0


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------
@settings(max_examples=16, deadline=None)
@given(
    B=st.integers(1, 3),
    Sq=st.sampled_from([8, 24, 64]),
    Hkv=st.sampled_from([1, 2]),
    G=st.sampled_from([1, 2, 4]),
    Dh=st.sampled_from([16, 32, 64]),
    causal=st.booleans(),
    window=st.sampled_from([None, 8, 32]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_flash_attention_sweep(B, Sq, Hkv, G, Dh, causal, window, dtype):
    key = jax.random.PRNGKey(Sq * 10 + Hkv)
    Hq = Hkv * G
    q = jax.random.normal(key, (B, Sq, Hq, Dh)).astype(dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Sq, Hkv, Dh)).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Sq, Hkv, Dh)).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=16, block_k=16, interpret=True)
    expect = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=_tol(dtype) * 4, rtol=_tol(dtype) * 4)


def test_flash_attention_softcap():
    key = jax.random.PRNGKey(7)
    q = jax.random.normal(key, (2, 32, 4, 32))
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, 32, 2, 32))
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, 32, 2, 32))
    out = flash_attention(q, k, v, causal=True, softcap=20.0,
                          block_q=8, block_k=8, interpret=True)
    expect = ref.attention_ref(q, k, v, causal=True, softcap=20.0)
    np.testing.assert_allclose(out, expect, atol=2e-5, rtol=2e-5)


def test_flash_attention_nonmultiple_blocks():
    """seq not divisible by block size exercises padding + kv_len masking."""
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (1, 35, 2, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 35, 2, 16))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 35, 2, 16))
    out = flash_attention(q, k, v, causal=True, block_q=16, block_k=16, interpret=True)
    expect = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, expect, atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# xla chunked attention (the dry-run lowering path) vs oracle
# ---------------------------------------------------------------------------
@settings(max_examples=12, deadline=None)
@given(
    Sq=st.sampled_from([16, 48, 128]),
    G=st.sampled_from([1, 3]),
    causal=st.booleans(),
    window=st.sampled_from([None, 16]),
    q_chunk=st.sampled_from([8, 16, 64]),
)
def test_chunked_attention_sweep(Sq, G, causal, window, q_chunk):
    if window is not None:
        causal = True     # sliding windows are causal in every arch we serve
    key = jax.random.PRNGKey(Sq + G)
    B, Hkv, Dh = 2, 2, 16
    q = jax.random.normal(key, (B, Sq, Hkv * G, Dh))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Sq, Hkv, Dh))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Sq, Hkv, Dh))
    out = chunked_attention(q, k, v, causal=causal, window=window,
                            q_chunk=q_chunk, k_chunk=q_chunk)
    expect = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(out, expect, atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# decode_attention
# ---------------------------------------------------------------------------
@settings(max_examples=16, deadline=None)
@given(
    B=st.integers(1, 3),
    L=st.sampled_from([16, 48, 100]),
    Hkv=st.sampled_from([1, 2]),
    G=st.sampled_from([1, 4]),
    window=st.sampled_from([None, 8]),
    valid_frac=st.floats(0.2, 1.0),
)
def test_decode_attention_sweep(B, L, Hkv, G, window, valid_frac):
    key = jax.random.PRNGKey(L + Hkv)
    Hq, Dh = Hkv * G, 32
    kc = jax.random.normal(key, (B, L, Hkv, Dh))
    vc = jax.random.normal(jax.random.fold_in(key, 1), (B, L, Hkv, Dh))
    q = jax.random.normal(jax.random.fold_in(key, 2), (B, 1, Hq, Dh))
    n_valid = max(1, int(L * valid_frac))
    cache_pos = jnp.broadcast_to(jnp.arange(L), (B, L))
    cache_pos = jnp.where(cache_pos < n_valid, cache_pos, -1).astype(jnp.int32)
    pos = jnp.full((B,), n_valid - 1, jnp.int32)
    out = decode_attention(q, kc, vc, cache_pos, pos, window=window,
                           block_k=16, interpret=True)
    expect = ref.decode_attention_ref(q, kc, vc, cache_pos, pos, window=window)
    np.testing.assert_allclose(out, expect, atol=3e-5, rtol=3e-5)


def test_decode_attention_ring_wrap():
    """Ring-buffer cache positions (wrapped writes) mask correctly."""
    key = jax.random.PRNGKey(9)
    B, L, Hkv, G, Dh = 2, 24, 2, 2, 16
    kc = jax.random.normal(key, (B, L, Hkv, Dh))
    vc = jax.random.normal(jax.random.fold_in(key, 1), (B, L, Hkv, Dh))
    q = jax.random.normal(jax.random.fold_in(key, 2), (B, 1, Hkv * G, Dh))
    base = jnp.arange(L)
    cache_pos = jnp.stack([jnp.where(base < 8, base + L, base)] * B).astype(jnp.int32)
    pos = jnp.full((B,), L + 7, jnp.int32)
    out = decode_attention(q, kc, vc, cache_pos, pos, window=12, block_k=8,
                           interpret=True)
    expect = ref.decode_attention_ref(q, kc, vc, cache_pos, pos, window=12)
    np.testing.assert_allclose(out, expect, atol=3e-5, rtol=3e-5)
