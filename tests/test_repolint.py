"""repolint acceptance: src/ is clean, every bad fixture trips its rule.

These tests pin the contract the CI ``lint-static`` job relies on: exit 0
over the real tree, nonzero over each positive fixture, suppressions only
honored when they name a rule, and ``--list-rules`` matching the registry.
"""
import os
import subprocess
import sys

import pytest

from repro.analysis import all_rules, run_paths
from repro.analysis.cli import main as repolint_main

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXTURES = os.path.join(ROOT, "tests", "analysis_fixtures")

RULE_IDS = ["id-space", "jax-purity", "unseeded-random", "pallas-vmem",
            "pallas-dma", "thread-safety", "silent-except"]


def _fixture(name):
    return os.path.join(FIXTURES, name)


# ------------------------------------------------------------------ registry
def test_registry_matches_documented_rule_ids():
    assert [r.id for r in all_rules()] == RULE_IDS
    assert all(r.summary for r in all_rules())


def test_list_rules_output(capsys):
    assert repolint_main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rule_id in RULE_IDS:
        assert rule_id in out


def test_script_wrapper_list_rules():
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "scripts", "repolint.py"),
         "--list-rules"],
        capture_output=True, text=True, cwd=ROOT)
    assert proc.returncode == 0
    for rule_id in RULE_IDS:
        assert rule_id in proc.stdout


# ------------------------------------------------------------ the real tree
def test_src_scripts_benchmarks_are_clean():
    paths = [os.path.join(ROOT, d) for d in ("src", "scripts", "benchmarks")]
    findings, errors = run_paths(paths)
    assert errors == []
    assert findings == [], "\n" + "\n".join(f.render() for f in findings)


# ------------------------------------------------------------- bad fixtures
@pytest.mark.parametrize("fixture,rule", [
    ("bad_idspace.py", "id-space"),
    ("bad_purity.py", "jax-purity"),
    ("bad_unseeded_random.py", "unseeded-random"),
    ("bad_pallas_vmem.py", "pallas-vmem"),
    ("bad_pallas_alias.py", "pallas-vmem"),
    ("bad_pallas_dma.py", "pallas-dma"),
    ("bad_pallas_dma_slot.py", "pallas-dma"),
    ("bad_threadsafety.py", "thread-safety"),
    ("bad_silent_except.py", "silent-except"),
])
def test_bad_fixture_trips_its_rule(fixture, rule, capsys):
    findings, errors = run_paths([_fixture(fixture)])
    assert errors == []
    assert any(f.rule == rule for f in findings), \
        f"{fixture} produced no {rule} finding"
    assert repolint_main([_fixture(fixture)]) == 1
    capsys.readouterr()


def test_bad_idspace_catches_all_three_shapes():
    findings, _ = run_paths([_fixture("bad_idspace.py")])
    messages = " | ".join(f.message for f in findings)
    assert "without a sanctioned translator" in messages
    assert "mixes" in messages
    assert "double translation" in messages


def test_pallas_alias_catches_both_shapes():
    findings, _ = run_paths([_fixture("bad_pallas_alias.py")])
    messages = " | ".join(f.message for f in findings)
    assert "straddles memory spaces" in messages
    assert "but only 2 outputs exist" in messages


def test_pallas_dma_slot_is_precise():
    findings, _ = run_paths([_fixture("bad_pallas_dma_slot.py")])
    slot = [f for f in findings if f.rule == "pallas-dma"]
    messages = " | ".join(f.message for f in slot)
    assert "SemaphoreType.DMA((2,))" in messages
    assert "sem.at[2]" in messages
    # the in-bounds sem.at[0] uses must NOT be flagged
    assert "sem.at[0]" not in messages


def test_threadsafety_catches_both_hazards():
    findings, _ = run_paths([_fixture("bad_threadsafety.py")])
    messages = " | ".join(f.message for f in findings)
    assert "written bare in reset()" in messages
    assert "has no lock" in messages


def test_clean_fixture_is_negative():
    findings, errors = run_paths([_fixture("clean.py")])
    assert errors == []
    assert findings == [], "\n" + "\n".join(f.render() for f in findings)


# ------------------------------------------------------------- suppressions
def test_line_suppression_honored(tmp_path):
    bad = tmp_path / "mod.py"
    bad.write_text(
        "def f(flat_ids):\n"
        "    padded_ids = flat_ids  # repolint: ignore[id-space] -- test\n"
        "    return padded_ids\n")
    findings, _ = run_paths([str(bad)])
    assert findings == []


def test_file_suppression_honored(tmp_path):
    bad = tmp_path / "mod.py"
    bad.write_text(
        "# repolint: file-ignore[id-space] -- test\n"
        "def f(flat_ids):\n"
        "    padded_ids = flat_ids\n"
        "    return padded_ids\n")
    findings, _ = run_paths([str(bad)])
    assert findings == []


def test_suppression_without_rule_id_not_honored(tmp_path):
    bad = tmp_path / "mod.py"
    bad.write_text(
        "def f(flat_ids):\n"
        "    padded_ids = flat_ids  # repolint: ignore\n"
        "    return padded_ids\n")
    findings, _ = run_paths([str(bad)])
    assert [f.rule for f in findings] == ["id-space"]


def test_suppressing_one_rule_leaves_others(tmp_path):
    bad = tmp_path / "mod.py"
    bad.write_text(
        "import numpy as np\n"
        "def f(flat_ids):\n"
        "    padded_ids = flat_ids  # repolint: ignore[silent-except]\n"
        "    return padded_ids\n")
    findings, _ = run_paths([str(bad)])
    assert [f.rule for f in findings] == ["id-space"]


# ---------------------------------------------------------------- CLI knobs
def test_select_runs_only_named_rules(capsys):
    rc = repolint_main(["--select", "pallas-dma",
                        _fixture("bad_idspace.py")])
    capsys.readouterr()
    assert rc == 0  # id-space violations invisible to a dma-only run


def test_select_unknown_rule_is_usage_error(capsys):
    assert repolint_main(["--select", "no-such-rule", "src"]) == 2
    assert "unknown rule" in capsys.readouterr().err


def test_vmem_cap_override(capsys):
    fixture = _fixture("bad_pallas_vmem.py")
    assert repolint_main(["--select", "pallas-vmem", fixture]) == 1
    capsys.readouterr()
    assert repolint_main(["--select", "pallas-vmem",
                          "--vmem-cap-bytes", str(256 * 1024 * 1024),
                          fixture]) == 0
    capsys.readouterr()


def test_assume_flag_shrinks_estimate(tmp_path, capsys):
    mod = tmp_path / "kern.py"
    mod.write_text(
        "import jax\n"
        "from jax.experimental import pallas as pl\n"
        "def f(x, kernel, BIGDIM):\n"
        "    return pl.pallas_call(\n"
        "        kernel, grid=(1,),\n"
        "        in_specs=[pl.BlockSpec((BIGDIM, BIGDIM), lambda i: (i, 0))],\n"
        "        out_specs=pl.BlockSpec((8, 8), lambda i: (i, 0)),\n"
        "    )(x)\n")
    # unknown symbolic dim defaults to 512 -> 512*512*4*2 = 2 MiB (fits);
    # force it huge, then bound it small again
    assert repolint_main(["--assume", "BIGDIM=65536", str(mod)]) == 1
    capsys.readouterr()
    assert repolint_main(["--assume", "BIGDIM=64", str(mod)]) == 0
    capsys.readouterr()


def test_bad_assume_is_usage_error(capsys):
    assert repolint_main(["--assume", "D=big", "src"]) == 2
    assert "bad --assume" in capsys.readouterr().err


def test_no_paths_is_usage_error(capsys):
    assert repolint_main([]) == 2
    capsys.readouterr()


def test_parse_error_is_reported(tmp_path, capsys):
    bad = tmp_path / "broken.py"
    bad.write_text("def f(:\n")
    assert repolint_main([str(bad)]) == 2
    assert "parse error" in capsys.readouterr().err
