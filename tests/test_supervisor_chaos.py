"""Chaos suite: the self-healing supervisor over the real DLRM train loop.

The load-bearing property everywhere: recovery is BIT-EXACT. Batches are a
pure function of the global step, so after any detect → restore → replay
cycle the loss trajectory must EQUAL the no-fault run's — these tests
assert ``==`` on float losses, never closeness.
"""
import functools
import json
import tempfile

import pytest

from repro.configs.dlrm_models import WIDE_DEEP, reduced_dlrm
from repro.core.faults import FaultInjector, parse_chaos_spec
from repro.core.flash_checkpoint import FlashCheckpoint
from repro.train.supervisor import (
    DLRMJob, RestartBudgetExceeded, Supervisor, SupervisorConfig,
)
from tests._hypothesis_compat import given, settings, st

CFG = reduced_dlrm(WIDE_DEEP)
T = 16                                          # steps per supervised run


def _supervised(chaos: str, *, padded: bool = True, deadline: float = None,
                max_restarts: int = 5, hot_rows_k: int = 0,
                total_steps: int = T):
    import dataclasses
    cfg = dataclasses.replace(CFG, hot_rows_k=hot_rows_k)
    inj = FaultInjector(parse_chaos_spec(chaos), seed=0)
    ckpt = FlashCheckpoint(tempfile.mkdtemp(), keep=3, async_persist=False,
                           fault_hook=inj.on_persist)
    inj.bind_checkpoint(ckpt)
    job = DLRMJob(cfg, ckpt, ckpt_every=4, n_ps=4, padded=padded,
                  injector=inj)
    sup = Supervisor(job, SupervisorConfig(
        step_deadline_s=deadline, max_restarts=max_restarts,
        backoff_base_s=0.01, backoff_cap_s=0.05))
    report = sup.run(total_steps)
    return job, sup, report


@functools.lru_cache(maxsize=None)
def _baseline_losses():
    """Loss trajectory of the clean run (flat == padded, verified below)."""
    job, _, _ = _supervised("")
    return dict(job.losses)


def _assert_bit_exact(job):
    base = _baseline_losses()
    for step, loss in sorted(job.losses.items()):
        assert loss == base[step], (
            f"step {step}: recovered {loss!r} != clean {base[step]!r}")


# ----------------------------------------------------------- fault scenarios
def test_clean_flat_equals_clean_padded():
    job, _, rep = _supervised("", padded=False)
    assert rep.restarts == 0 and rep.goodput_fraction == 1.0
    _assert_bit_exact(job)                      # baseline ran padded


def test_ps_loss_elastic_shrink_bit_exact():
    job, _, rep = _supervised("ps_loss@6")
    assert rep.completed and rep.final_step == T
    assert job.n_ps == 3 and job.layout.n_ps == 3   # shrunk onto survivors
    assert any(e.kind == "fault_detected" and e.detail["fault"] == "ps_loss"
               for e in rep.events)
    assert any(e.kind == "recovered" and
               e.detail["action"] == "elastic_shrink" and
               e.detail["surviving_n_ps"] == 3 for e in rep.events)
    _assert_bit_exact(job)


def test_double_ps_loss_shrinks_twice():
    job, _, rep = _supervised("ps_loss@5,ps_loss@10")
    assert job.n_ps == 2 and rep.restarts == 2
    _assert_bit_exact(job)


def test_hang_watchdog_detection_bit_exact():
    job, _, rep = _supervised("hang@9", deadline=1.0)   # default stall: 30 s
    assert rep.completed and rep.final_step == T
    det = [e for e in rep.events if e.kind == "fault_detected"]
    assert det and det[0].detail["fault"] == "hang"
    rec = [e for e in rep.events if e.kind == "recovered"]
    assert rec and rec[0].detail["cause"] == "hang"
    assert rec[0].detail["recovery_latency_s"] > 0
    _assert_bit_exact(job)


def test_corrupt_latest_ckpt_falls_back_and_recovers():
    # corrupt the step-8 blob (dropping the memory tier), then crash at 10:
    # recovery must fall back past the damaged blob to step 4 and replay
    job, sup, rep = _supervised("ckpt_corrupt@8,ps_loss@10")
    assert rep.completed
    assert any(e["kind"] == "corrupt_blob_fallback"
               for e in job.ckpt.events)
    rec = [e for e in rep.events if e.kind == "recovered"]
    assert rec[0].step == 4 and rec[0].detail["steps_lost"] == 6
    _assert_bit_exact(job)


def test_truncated_ckpt_falls_back_and_recovers():
    job, _, rep = _supervised("ckpt_truncate@8,ps_loss@10")
    assert rep.completed
    assert any(e["kind"] == "corrupt_blob_fallback" for e in job.ckpt.events)
    _assert_bit_exact(job)


def test_straggler_delay_detected_not_restarted():
    _, _, rep = _supervised("straggler@10:0.5")
    assert rep.restarts == 0                    # slow ≠ dead: no restore
    stragglers = [e for e in rep.events if e.kind == "straggler_detected"]
    assert stragglers and stragglers[0].step == 10


def test_oom_walks_degradation_ladder():
    job, _, rep = _supervised("oom@5,oom@9", hot_rows_k=24)
    actions = [e.detail.get("action") for e in rep.events
               if e.kind == "recovered"]
    assert actions == ["drop_hot_cache", f"shrink_batch_to_{CFG.batch_size // 2}"]
    assert rep.completed and rep.steps_lost == 0    # state intact: no replay
    assert job.cfg.hot_rows_k == 0
    assert job.cfg.batch_size == CFG.batch_size // 2


def test_restart_budget_exceeded_raises():
    with pytest.raises(RestartBudgetExceeded):
        _supervised("ps_loss@2,ps_loss@4,ps_loss@6", max_restarts=2)


# ------------------------------------------------------------- event logging
def test_event_log_is_structured_jsonl(tmp_path):
    _, sup, rep = _supervised("ps_loss@6")
    path = tmp_path / "events.jsonl"
    sup.write_event_log(str(path), rep)
    lines = [json.loads(line) for line in path.read_text().splitlines()]
    assert lines[-1]["kind"] == "summary"
    assert lines[-1]["completed"] is True
    assert lines[-1]["restarts"] == 1
    assert 0 < lines[-1]["goodput_fraction"] <= 1
    assert lines[-1]["recovery_latency_mean_s"] > 0
    body = lines[:-1]
    assert {e["kind"] for e in body} >= {"fault_detected", "recovered"}
    assert all({"t", "kind", "step", "detail"} <= set(e) for e in body)


def test_report_feeds_sim_timings():
    from repro.sim.cluster import CloudSim
    _, _, rep = _supervised("ps_loss@6")
    timings = rep.measured_timings()
    assert timings.flash_ckpt_load_s > 0
    sim = CloudSim("dlrover_rm", timings=timings, failure_seed=7)
    assert sim.timings is timings and sim.failure_seed == 7


# ---------------------------------------------- kill/resume property (sat. c)
@settings(max_examples=4, deadline=None)
@given(kill_at=st.integers(2, 11), padded=st.booleans(),
       n_ps2=st.integers(1, 4))
def test_kill_anywhere_resume_anywhere_bit_exact(kill_at, padded, n_ps2):
    """Kill at an arbitrary step; a FRESH process over the same persist dir
    resumes (flat or padded, onto any surviving PS count) and reproduces the
    uninterrupted loss trajectory exactly."""
    base = _baseline_losses()
    with tempfile.TemporaryDirectory() as d:
        ck1 = FlashCheckpoint(d, keep=3, async_persist=False)
        job1 = DLRMJob(CFG, ck1, ckpt_every=3, n_ps=3, padded=padded)
        job1.start(resume=False)
        for _ in range(kill_at):
            job1.run_step()
        del job1, ck1                           # the process dies here
        ck2 = FlashCheckpoint(d, keep=3, async_persist=False)
        job2 = DLRMJob(CFG, ck2, ckpt_every=3, n_ps=3, padded=padded)
        step0 = job2.restore(onto_n_ps=n_ps2 if padded else None)
        assert step0 == (kill_at // 3) * 3      # newest blob on the cadence
        if padded:
            assert job2.layout.n_ps == n_ps2
        while job2.global_step < T:
            job2.run_step()
        for step, loss in sorted(job2.losses.items()):
            assert loss == base[step]
