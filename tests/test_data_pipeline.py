"""Data determinism: the property dynamic sharding relies on — any worker
reproduces identical samples for the same indices; loaders cover datasets.
"""
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.configs.dlrm_models import WIDE_DEEP, reduced_dlrm
from repro.core.sharding_service import ShardingService
from repro.data.pipeline import ShardDataLoader
from repro.data.synthetic import criteo_batch, lm_batch


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000),
       idx=st.lists(st.integers(0, 10_000), min_size=1, max_size=8))
def test_criteo_deterministic_per_index(seed, idx):
    cfg = reduced_dlrm(WIDE_DEEP)
    a = criteo_batch(cfg, seed, np.array(idx))
    b = criteo_batch(cfg, seed, np.array(idx))
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])


def test_criteo_different_indices_differ():
    cfg = reduced_dlrm(WIDE_DEEP)
    a = criteo_batch(cfg, 0, np.array([1]))
    b = criteo_batch(cfg, 0, np.array([2]))
    assert not np.array_equal(a["dense"], b["dense"])


def test_lm_batch_shapes_and_range():
    b = lm_batch(0, np.arange(4), seq_len=32, vocab_size=100)
    assert b["tokens"].shape == (4, 32) and b["targets"].shape == (4, 32)
    assert b["tokens"].max() < 100 and b["tokens"].min() >= 0
    np.testing.assert_array_equal(
        lm_batch(0, np.arange(4), 32, 100)["tokens"], b["tokens"])


def test_two_loaders_partition_dataset():
    svc = ShardingService(total_samples=256, shard_size=64)
    seen = []

    def batch_fn(idx):
        seen.extend(idx.tolist())
        return {"idx": idx}

    la = ShardDataLoader(svc, "a", batch_fn, 32, clock=lambda: 0.0)
    lb = ShardDataLoader(svc, "b", batch_fn, 32, clock=lambda: 0.0)
    done = False
    while not done:
        done = la.next_batch() is None and lb.next_batch() is None
    assert sorted(set(seen)) == list(range(256))
    assert len(seen) == 256                    # no duplicates (divisible case)
