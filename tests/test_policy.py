"""Sharding-policy resolution + real multi-device execution (subprocess).

The child process fakes 8 CPU devices (the parent must keep seeing 1, per the
dry-run isolation rule), builds meshes, checks rule resolution for every
(arch × shape), runs a REAL sharded train step, and performs an ELASTIC
RE-MESH: checkpoint on a (4,2) mesh, restore + resume on (2,4).
"""
import os
import subprocess
import sys
import textwrap

import pytest

_CHILD = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, tempfile
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs.base import SHAPES, reduce_config
    from repro.configs.registry import ARCHS
    from repro.sharding.policy import make_policy, use_policy, logical_spec
    from repro.models.registry import build_model
    from repro.train import optim, trainer, elastic
    from repro.core.flash_checkpoint import FlashCheckpoint

    assert len(jax.devices()) == 8

    # ---- rule resolution for every (arch x shape) on a 4x2 mesh ----------
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    for arch, cfg in ARCHS.items():
        for shape in SHAPES.values():
            pol = make_policy(mesh, cfg, shape)
            spec = pol.spec(("batch", "qseq", "heads", None))
            used = [a for part in spec if part for a in
                    (part if isinstance(part, tuple) else (part,))]
            assert len(used) == len(set(used)), (arch, shape.name, spec)

    # decode policy: small models replicate weights across "data" (no FSDP
    # gather per token); mixtral-8x22b (too big per model shard) keeps FSDP
    pol_small = make_policy(mesh, ARCHS["llama3.2-3b"], SHAPES["decode_32k"])
    assert pol_small.rules["fsdp"] == ()
    pol_big = make_policy(mesh, ARCHS["mixtral-8x22b"], SHAPES["decode_32k"])
    assert pol_big.rules["fsdp"] == ("data",)

    # ---- real sharded training + elastic re-mesh -------------------------
    cfg = reduce_config(ARCHS["llama3.2-3b"], d_model=64, n_heads=4,
                        n_kv_heads=2, head_dim=16, vocab_size=256)
    api = build_model(cfg)
    opt = optim.adam(1e-3)
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=32, global_batch=8)

    def run_steps(mesh_shape, state_host, n, ckpt):
        mesh = jax.make_mesh(mesh_shape, ("data", "model"))
        pol = make_policy(mesh, cfg, shape)
        with mesh, use_policy(pol):
            shardings = elastic.state_shardings(api, "adam", pol)
            if state_host is None:
                state = trainer.make_train_state(api, opt, jax.random.PRNGKey(0))
                state = jax.device_put(state, shardings)
            else:
                like = jax.eval_shape(
                    lambda k: trainer.make_train_state(api, opt, k),
                    jax.random.PRNGKey(0))
                state, _ = ckpt.restore(like, shardings=shardings)
            step = jax.jit(trainer.make_train_step(api, opt, remat=True),
                           in_shardings=(shardings, None),
                           out_shardings=(shardings, None))
            batch = {"tokens": jnp.zeros((8, 32), jnp.int32),
                     "targets": jnp.ones((8, 32), jnp.int32)}
            losses = []
            for _ in range(n):
                state, m = step(state, batch)
                losses.append(float(m["loss"]))
            return state, losses

    ckpt = FlashCheckpoint(None)
    state, losses_a = run_steps((4, 2), None, 3, ckpt)
    ckpt.save(state, 3)
    # elastic re-mesh: same training continues on a different mesh layout
    state2, losses_b = run_steps((2, 4), "restore", 3, ckpt)
    assert losses_b[0] < losses_a[0], (losses_a, losses_b)
    assert all(np.isfinite(losses_a + losses_b))
    print("MULTIDEVICE_OK", losses_a, losses_b)
""")


@pytest.mark.slow
def test_multidevice_policy_and_elastic_remesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                          capture_output=True, text=True, timeout=900,
                          cwd=os.path.dirname(os.path.dirname(__file__)))
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "MULTIDEVICE_OK" in proc.stdout
