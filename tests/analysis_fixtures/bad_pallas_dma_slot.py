"""pallas-dma fixture: semaphore slot past the DMA((k,)) capacity (positive).

The kernel declares a two-slot DMA semaphore array but indexes slot 2 —
on real TPUs that aliases whatever semaphore lives next door; interpret
mode happily runs it.  Every copy is start/wait paired so only the slot
bound trips.
"""
import functools

import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _row_kernel(rows_ref, p_hbm, p_out, stage, sem, *, R):
    del p_hbm
    row = rows_ref[0]
    fetch = pltpu.make_async_copy(
        p_out.at[pl.ds(row, 1), :], stage, sem.at[0])
    fetch.start()
    fetch.wait()
    stage[...] = stage[...] * 2.0
    store = pltpu.make_async_copy(
        stage, p_out.at[pl.ds(row, 1), :], sem.at[2])   # slot 2 of DMA((2,))
    store.start()
    store.wait()


def double_rows(params, rows):
    R, D = params.shape
    kernel = functools.partial(_row_kernel, R=R)
    return pl.pallas_call(
        kernel,
        grid=(rows.shape[0],),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (i,), memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.ANY),
        out_shape=jax.ShapeDtypeStruct(params.shape, params.dtype),
        input_output_aliases={1: 0},
        scratch_shapes=[
            pltpu.VMEM((1, D), params.dtype),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )(rows, params)
