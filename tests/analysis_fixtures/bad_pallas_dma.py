"""pallas-dma fixture: DMA started and never awaited (positive)."""
from jax.experimental.pallas import tpu as pltpu


def leaky_fill(hbm_ref, vmem_ref, sem):
    pltpu.make_async_copy(hbm_ref, vmem_ref, sem).start()
    # no .wait() on `sem` anywhere in this module: the consumer races the copy


def paired_elsewhere(hbm_ref, vmem_ref, other_sem):
    cp = pltpu.make_async_copy(hbm_ref, vmem_ref, other_sem)
    cp.start()


def drain_other(hbm_ref, vmem_ref, unrelated_sem):
    pltpu.make_async_copy(hbm_ref, vmem_ref, unrelated_sem).wait()
