"""silent-except fixture: bare and swallowed exception handlers (positives)."""


def bare_handler(path):
    try:
        return open(path).read()
    except:                          # noqa: E722  (the point of the fixture)
        return None


def swallowed(path):
    try:
        return open(path).read()
    except OSError:
        pass
    return None


def swallowed_ellipsis(fn):
    try:
        fn()
    except (ValueError, KeyError):
        ...
