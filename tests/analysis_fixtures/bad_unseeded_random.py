"""unseeded-random fixture: global/unseeded RNG state (positives)."""
import random

import numpy as np


def legacy_numpy_draw(n):
    return np.random.rand(n)         # legacy global-state RNG


def unseeded_generator():
    return np.random.default_rng()   # no seed: unreproducible


def stdlib_global_draw():
    return random.random()           # stdlib global RNG


def unseeded_instance():
    return random.Random()           # no seed: unreproducible
