"""Near-miss negatives: everything here must pass every rule.

Each block sits just on the legal side of a rule boundary, so a rule that
over-triggers fails the negative half of the fixture tests.
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np


# -- id-space: translator use, supertype flow, neutral names ---------------
def translate_rows(rows, layout):        # sanctioned translator (exempt body)
    return rows + layout.base


def legal_translation(flat_ids, layout):
    padded_ids = translate_rows(flat_ids, layout)   # through the translator
    return padded_ids


def encoded_supertype(flat_ids, padded_ids, pick_padded):
    # flat and padded are both valid cold entries of an encoded stream
    encoded_ids = padded_ids if pick_padded else flat_ids
    return encoded_ids


def neutral_names(flat_ids, layout):
    # a neutral name may hold either space; geometry attrs carry no space
    idx = flat_ids if layout is None else translate_rows(flat_ids, layout)
    return idx, (None if layout is None else layout.padded_rows)


# -- jax-purity: static branches, local mutation, outside-trace effects ----
@jax.jit
def pure_step(x, scale=None):
    if scale is not None:                # `is None` is static under tracing
        x = x * scale
    if x.ndim == 2:                      # shape attrs are static
        x = x[None]
    acc = []
    acc.append(jnp.sum(x))               # local container: rebuilt per trace
    return acc[0]


def host_logging(x):
    print("outside any traced region:", x)   # not reachable from jit
    return np.asarray(x)


# -- unseeded-random: seeded generators are the contract -------------------
def seeded_draw(seed, n):
    rng = np.random.default_rng(seed)
    return rng.normal(size=n)


def seeded_stdlib(seed):
    import random
    return random.Random(seed).random()


# -- thread-safety: consistently guarded + effectively-locked helper -------
class GuardedCounter:
    def __init__(self):
        self._lock = threading.Lock()
        self.count = 0                   # __init__ is single-threaded

    def bump(self):
        with self._lock:
            self._advance()

    def _advance(self):                  # only ever called under the lock
        self.count += 1

    def snapshot(self):
        with self._lock:
            return self.count


# -- silent-except: typed handler with real handling -----------------------
def tolerant_read(path, log):
    try:
        return open(path).read()
    except OSError as e:
        log.append(str(e))               # failure leaves a trace
        return None
