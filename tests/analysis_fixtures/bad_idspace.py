"""id-space fixture: every block below must trip the rule (positives)."""


def assign_across_spaces(flat_ids):
    padded_ids = flat_ids            # padded name <- flat value, no translator
    return padded_ids


def mix_in_arithmetic(flat_ids, padded_ids):
    return flat_ids + padded_ids     # direct cross-space arithmetic


def compare_spaces(raw_ids, flat_ids):
    return raw_ids == flat_ids       # cross-space comparison


def double_translate(padded_ids, layout):
    return translate_rows(padded_ids, layout)  # translator fed its own output


def translate_rows(rows, layout):
    return rows
