"""pallas-vmem fixture: a kernel whose static footprint blows the cap.

(2048, 2048) f32 blocks are 16 MiB each; double-buffered in+out blocks plus
a 32 MiB f32 scratch put the upper bound far over any per-core VMEM.
"""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, o_ref, scratch):
    o_ref[...] = x_ref[...]


def oversized_blocks(x):
    return pl.pallas_call(
        _kernel,
        grid=(4,),
        in_specs=[pl.BlockSpec((2048, 2048), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((2048, 2048), lambda i: (i, 0)),
        scratch_shapes=[pltpu.VMEM((2048, 4096), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)
