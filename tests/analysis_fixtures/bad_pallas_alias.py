"""pallas-vmem fixture: broken input_output_aliases literals (positive).

Two decidable alias bugs: an input index that miscounts the SMEM operand
(aliasing a VMEM-blocked input onto an ``ANY`` output) and an output
index past the output list.  Both only explode at lowering time on real
hardware paths; the dict literal is fully static.
"""
import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, x_ref, p_hbm, p_out, y_out):
    del idx_ref, p_hbm, p_out   # p_out is written via manual DMA in the idiom
    y_out[...] = x_ref[...]


def aliased_wrong_operand(params, idx, x):
    return pl.pallas_call(
        _kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((4,), lambda i: (i,), memory_space=pltpu.SMEM),
            pl.BlockSpec((8, 8), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec((8, 8), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(params.shape, params.dtype),
            jax.ShapeDtypeStruct(x.shape, x.dtype),
        ],
        # miscounted: names the VMEM-blocked x (input 1), not the ANY pool
        # (input 2), so the aliased pair straddles memory spaces
        input_output_aliases={1: 0},
    )(idx, x, params)


def aliased_missing_output(params, idx, x):
    return pl.pallas_call(
        _kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((4,), lambda i: (i,), memory_space=pltpu.SMEM),
            pl.BlockSpec((8, 8), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec((8, 8), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(params.shape, params.dtype),
            jax.ShapeDtypeStruct(x.shape, x.dtype),
        ],
        input_output_aliases={2: 5},    # output 5 of 2
    )(idx, x, params)
