"""thread-safety fixture: guarded state written bare (positives)."""
import threading


class LeakyCounter:
    """`count` is lock-guarded in bump() but written bare in reset()."""

    def __init__(self):
        self._lock = threading.Lock()
        self.count = 0

    def bump(self):
        with self._lock:
            self.count += 1

    def reset(self):
        self.count = 0               # bare write to a guarded attribute


class LocklessWorkerState:
    """Writes the same attribute from a spawned thread and the caller."""

    def __init__(self):
        self.status = "idle"
        self._thread = None

    def launch(self):
        self._thread = threading.Thread(target=self._run)
        self._thread.start()

    def _run(self):
        self.status = "running"      # spawned-thread write, no lock

    def cancel(self):
        self.status = "cancelled"    # main-thread write to the same attr
