"""jax-purity fixture: impurities inside traced code (positives)."""
import jax
import jax.numpy as jnp
import numpy as np

_trace_log = []


@jax.jit
def noisy_step(x):
    print("step", x)                 # host print freezes at trace time
    return x + 1


@jax.jit
def frozen_noise(x):
    return x + np.random.rand()      # host RNG drawn once, at trace time


@jax.jit
def records_traces(x):
    _trace_log.append(1)             # closed-over mutation: once per trace
    return x * 2


@jax.jit
def branches_on_tracer(x):
    y = jnp.sum(x)
    if y > 0:                        # TracerBoolConversionError at runtime
        return x
    return -x


def helper_called_from_jit(x):
    import time
    return x * time.time()           # trace-time wall clock


@jax.jit
def calls_helper(x):
    return helper_called_from_jit(x)
