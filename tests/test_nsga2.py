"""NSGA-II properties: Pareto-front validity, dominance, convergence."""
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.nsga2 import fast_non_dominated_sort, Individual, nsga2


def _dominates(a, b):
    return bool(np.all(a <= b) and np.any(a < b))


def test_front_is_mutually_nondominated():
    front = nsga2(lambda x: (x[0] ** 2, (x[0] - 2) ** 2), [(-5, 5)],
                  pop_size=30, generations=25, integer=False, seed=0)
    for i, (_, fi) in enumerate(front):
        for j, (_, fj) in enumerate(front):
            if i != j:
                assert not _dominates(fi, fj)


def test_converges_to_known_pareto_set():
    """min (x², (x-2)²): Pareto set is x ∈ [0, 2]."""
    front = nsga2(lambda x: (x[0] ** 2, (x[0] - 2) ** 2), [(-5, 5)],
                  pop_size=40, generations=40, integer=False, seed=1)
    xs = np.array([x[0] for x, _ in front])
    assert np.all(xs >= -0.25) and np.all(xs <= 2.25)
    assert xs.min() < 0.6 and xs.max() > 1.4      # spread along the front


def test_integer_mode_rounds():
    front = nsga2(lambda x: (x[0], -x[0]), [(0, 10)], pop_size=16,
                  generations=5, integer=True, seed=2)
    for x, _ in front:
        assert float(x[0]).is_integer()


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000), n=st.integers(4, 24))
def test_nondominated_sort_rank0_correct(seed, n):
    rng = np.random.default_rng(seed)
    pop = [Individual(x=np.zeros(1), f=rng.random(2)) for _ in range(n)]
    fronts = fast_non_dominated_sort(pop)
    rank0 = fronts[0]
    for p in rank0:
        assert not any(_dominates(q.f, p.f) for q in pop)
    for front_i in fronts[1:]:
        for p in front_i:
            assert any(_dominates(q.f, p.f) for q in pop)
    assert sum(len(f) for f in fronts) == n
