"""NSGA-II properties: Pareto-front validity, dominance, convergence."""
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.nsga2 import (
    crowding_distance, fast_non_dominated_sort, Individual, nsga2,
)


def _dominates(a, b):
    return bool(np.all(a <= b) and np.any(a < b))


def test_front_is_mutually_nondominated():
    front = nsga2(lambda x: (x[0] ** 2, (x[0] - 2) ** 2), [(-5, 5)],
                  pop_size=30, generations=25, integer=False, seed=0)
    for i, (_, fi) in enumerate(front):
        for j, (_, fj) in enumerate(front):
            if i != j:
                assert not _dominates(fi, fj)


def test_converges_to_known_pareto_set():
    """min (x², (x-2)²): Pareto set is x ∈ [0, 2]."""
    front = nsga2(lambda x: (x[0] ** 2, (x[0] - 2) ** 2), [(-5, 5)],
                  pop_size=40, generations=40, integer=False, seed=1)
    xs = np.array([x[0] for x, _ in front])
    assert np.all(xs >= -0.25) and np.all(xs <= 2.25)
    assert xs.min() < 0.6 and xs.max() > 1.4      # spread along the front


def test_integer_mode_rounds():
    front = nsga2(lambda x: (x[0], -x[0]), [(0, 10)], pop_size=16,
                  generations=5, integer=True, seed=2)
    for x, _ in front:
        assert float(x[0]).is_integer()


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000), n=st.integers(4, 24))
def test_nondominated_sort_rank0_correct(seed, n):
    rng = np.random.default_rng(seed)
    pop = [Individual(x=np.zeros(1), f=rng.random(2)) for _ in range(n)]
    fronts = fast_non_dominated_sort(pop)
    rank0 = fronts[0]
    for p in rank0:
        assert not any(_dominates(q.f, p.f) for q in pop)
    for front_i in fronts[1:]:
        for p in front_i:
            assert any(_dominates(q.f, p.f) for q in pop)
    assert sum(len(f) for f in fronts) == n


# ---------------------------------------------------------------- properties


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000), n=st.integers(2, 16))
def test_sort_is_permutation_invariant(seed, n):
    """The rank assigned to an objective vector is independent of the order
    in which the population is presented."""
    rng = np.random.default_rng(seed)
    fs = [rng.random(2) for _ in range(n)]
    pop = [Individual(x=np.zeros(1), f=f) for f in fs]
    fast_non_dominated_sort(pop)
    ranks = {i: p.rank for i, p in enumerate(pop)}

    perm = rng.permutation(n)
    pop2 = [Individual(x=np.zeros(1), f=fs[i]) for i in perm]
    fast_non_dominated_sort(pop2)
    for pos, orig_idx in enumerate(perm):
        assert pop2[pos].rank == ranks[orig_idx]


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_crowding_distance_is_deterministic(seed):
    """Two identical fronts get identical crowding values — including the
    tie-break behavior for duplicated objective vectors."""
    rng = np.random.default_rng(seed)
    fs = [rng.random(2) for _ in range(8)]
    fs.append(fs[0].copy())                       # deliberate duplicate
    a = [Individual(x=np.zeros(1), f=f.copy()) for f in fs]
    b = [Individual(x=np.zeros(1), f=f.copy()) for f in fs]
    crowding_distance(a)
    crowding_distance(b)
    got_a = sorted(p.crowding for p in a)
    got_b = sorted(p.crowding for p in b)
    assert got_a == got_b


def test_all_identical_objectives_single_front():
    """Degenerate population: every candidate has the same objectives, so
    they are all rank 0 and crowding never divides by the zero range."""
    pop = [Individual(x=np.zeros(1), f=np.array([1.0, 2.0])) for _ in range(6)]
    fronts = fast_non_dominated_sort(pop)
    assert len(fronts) == 1 and len(fronts[0]) == 6
    crowding_distance(fronts[0])
    assert all(np.isfinite(p.crowding) or np.isinf(p.crowding)
               for p in fronts[0])


def test_all_identical_objectives_nsga2_runs():
    front = nsga2(lambda x: (1.0, 2.0), [(0, 4)], pop_size=8,
                  generations=3, seed=0)
    assert front                                   # at least one survivor
    for _, f in front:
        assert tuple(f) == (1.0, 2.0)


def test_single_candidate_population():
    pop = [Individual(x=np.zeros(1), f=np.array([0.5, 0.5]))]
    fronts = fast_non_dominated_sort(pop)
    assert len(fronts) == 1 and fronts[0][0].rank == 0
    crowding_distance(fronts[0])
    front = nsga2(lambda x: (x[0], -x[0]), [(0, 3)], pop_size=2,
                  generations=2, seed=0)
    assert front


def test_nan_objectives_rejected():
    import pytest
    with pytest.raises(ValueError, match="non-finite"):
        nsga2(lambda x: (float("nan"), 1.0), [(0, 1)], pop_size=4,
              generations=1, seed=0)


def test_inf_objectives_rejected():
    import pytest
    with pytest.raises(ValueError, match="non-finite"):
        nsga2(lambda x: (1.0, float("inf")), [(0, 1)], pop_size=4,
              generations=1, seed=0)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 500))
def test_nsga2_deterministic_for_fixed_seed(seed):
    def obj(x):
        return (x[0] ** 2 + x[1], (x[0] - 3) ** 2 + 0.1 * x[1])

    bounds = [(0, 8), (0, 8)]
    a = nsga2(obj, bounds, pop_size=12, generations=6, seed=seed)
    b = nsga2(obj, bounds, pop_size=12, generations=6, seed=seed)
    assert len(a) == len(b)
    for (xa, fa), (xb, fb) in zip(a, b):
        assert np.array_equal(xa, xb) and np.array_equal(fa, fb)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 500), lo=st.integers(-4, 0), hi=st.integers(1, 6))
def test_front_respects_bounds(seed, lo, hi):
    front = nsga2(lambda x: (x[0] ** 2, (x[0] - 1) ** 2), [(lo, hi)],
                  pop_size=10, generations=4, integer=False, seed=seed)
    for x, _ in front:
        assert lo - 1e-9 <= x[0] <= hi + 1e-9
