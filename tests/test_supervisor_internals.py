"""Unit tests for supervisor internals — no worker processes involved.

Covers the three policy mechanisms the chaos integration suites exercise
only incidentally: the EWMA straggler detector's cold-start window, the
exponential-backoff restart budget (exhaustion raises, delays grow to the
cap), and the OOM degradation ladder's ordering (hot-cache first, then
batch halving with a floor).
"""
import dataclasses
import tempfile

import pytest

from repro.configs.dlrm_models import reduced_dlrm
from repro.configs.registry import get_dlrm
from repro.core.flash_checkpoint import FlashCheckpoint
from repro.train.supervisor import (DLRMJob, RestartBudgetExceeded,
                                    Supervisor, SupervisorConfig)


class StubJob:
    """Duck-typed DLRMJob stand-in: restore/degrade without jax or state."""

    def __init__(self):
        self.injector = None
        self.global_step = 0
        self.restore_calls = 0

    def restore(self, *, onto_n_ps=None):
        self.restore_calls += 1
        return self.global_step

    def degrade(self):
        return "stub_degrade"


def make_sup(**cfg):
    return Supervisor(StubJob(), SupervisorConfig(**cfg))


# ---------------------------------------------------- EWMA cold-start window
def test_ewma_warmup_suppresses_straggler_detection():
    """The first ``ewma_warmup_steps`` samples can be wildly slow (JIT
    compile, cache warm-up) without tripping the detector."""
    sup = make_sup(ewma_warmup_steps=5, straggler_factor=3.0)
    # a 100x outlier inside the cold-start window: folded, not flagged
    for i, dt in enumerate([0.01, 1.0, 0.01, 0.01, 0.01]):
        sup._observe_step_time(i, dt)
    assert not [e for e in sup.events if e.kind == "straggler_detected"]


def test_ewma_detects_after_warmup_and_clips_the_fold():
    sup = make_sup(ewma_warmup_steps=3, straggler_factor=3.0,
                   ewma_alpha=0.25)
    for i in range(4):
        sup._observe_step_time(i, 0.01)
    baseline = sup._ewma
    assert baseline == pytest.approx(0.01)
    sup._observe_step_time(4, 1.0)          # 100x the EWMA: flagged
    ev = [e for e in sup.events if e.kind == "straggler_detected"]
    assert len(ev) == 1 and ev[0].step == 4
    assert ev[0].detail["factor"] == pytest.approx(100.0, rel=0.05)
    # the folded sample was clipped to factor * ewma, so one outlier moves
    # the baseline by at most alpha * (factor - 1) * ewma
    assert sup._ewma <= baseline * (1 + 0.25 * (3.0 - 1)) * 1.001
    # and the detector still works right after (baseline not poisoned)
    sup._observe_step_time(5, 1.0)
    assert len([e for e in sup.events
                if e.kind == "straggler_detected"]) == 2


def test_first_sample_seeds_the_ewma():
    sup = make_sup(ewma_warmup_steps=5)
    sup._observe_step_time(0, 0.5)
    assert sup._ewma == pytest.approx(0.5)


# ----------------------------------------------- backoff + restart budget
def test_backoff_grows_exponentially_to_the_cap():
    sup = make_sup(backoff_base_s=0.01, backoff_cap_s=0.04,
                   backoff_jitter=0.0)
    delays = []
    for failures in (1, 2, 3, 4, 5):
        sup._consecutive_failures = failures
        delays.append(sup._backoff())
    assert delays == pytest.approx([0.01, 0.02, 0.04, 0.04, 0.04])


def test_backoff_jitter_is_bounded_and_deterministic():
    a = make_sup(backoff_base_s=0.01, backoff_jitter=0.25, seed=7)
    b = make_sup(backoff_base_s=0.01, backoff_jitter=0.25, seed=7)
    a._consecutive_failures = b._consecutive_failures = 1
    da, db = a._backoff(), b._backoff()
    assert da == db                          # same seed, same delay
    assert 0.0075 <= da <= 0.0125            # within +/- 25%


def test_restart_budget_exhaustion_raises_with_event():
    sup = make_sup(max_restarts=3, backoff_base_s=0.0, backoff_jitter=0.0)
    for _ in range(3):
        sup._recover("ps_loss", 10)
    assert sup.job.restore_calls == 3
    with pytest.raises(RestartBudgetExceeded, match="budget of 3"):
        sup._recover("ps_loss", 10)
    ev = [e for e in sup.events if e.kind == "restart_budget_exceeded"]
    assert len(ev) == 1
    assert ev[0].detail["budget"] == 3
    # the over-budget attempt never touched the job
    assert sup.job.restore_calls == 3


def test_recover_resets_nothing_but_counts_consecutive_failures():
    sup = make_sup(max_restarts=5, backoff_base_s=0.0, backoff_jitter=0.0)
    sup._recover("hang", 4)
    sup._recover("hang", 5)
    assert sup.restarts == 2
    assert sup._consecutive_failures == 2
    recovered = [e for e in sup.events if e.kind == "recovered"]
    assert [e.detail["action"] for e in recovered] == ["restore", "restore"]


# ------------------------------------------------- OOM degradation ladder
def test_degrade_ladder_ordering_no_processes():
    """First OOM drops the hot-row cache; repeats halve the batch down to
    the floor of 8 — in that order, recompiling each time."""
    cfg = dataclasses.replace(reduced_dlrm(get_dlrm("wide_deep")),
                              hot_rows_k=64)       # arm the first rung
    assert cfg.batch_size >= 32
    with tempfile.TemporaryDirectory() as d:
        job = DLRMJob(cfg, FlashCheckpoint(d, async_persist=False))
        b0 = job.cfg.batch_size
        actions = [job.degrade() for _ in range(4)]
    assert actions[0] == "drop_hot_cache"
    assert job.cfg.hot_rows_k == 0
    expect = []
    b = b0
    for _ in range(3):
        b = max(b // 2, 8)
        expect.append(f"shrink_batch_to_{b}")
    assert actions[1:] == expect
    assert job.cfg.batch_size == b
    assert job.degrade_level == 4
    assert job.global_step == 0              # degradation never loses steps


def test_degrade_floor_never_goes_below_8():
    cfg = reduced_dlrm(get_dlrm("wide_deep"))
    with tempfile.TemporaryDirectory() as d:
        job = DLRMJob(cfg, FlashCheckpoint(d, async_persist=False))
        for _ in range(10):
            job.degrade()
        assert job.cfg.batch_size == 8
