"""Cluster-simulator sanity: scheduler ordering, event handling, accounting."""
import pytest

from repro.sim.cluster import CloudSim
from repro.sim.workload import generate_jobs, true_throughput


@pytest.fixture(scope="module")
def jobs():
    return generate_jobs(10, seed=2, arrival_rate_per_h=60, mean_msamples=20.0)


def test_oracle_beats_user_throughput(jobs):
    for j in jobs:
        assert true_throughput(j, j.oracle) >= true_throughput(j, j.user_request)


def test_all_jobs_complete_without_failures(jobs):
    sim = CloudSim("static_tuned", total_cpu=8192, total_mem_gb=65536,
                   seed=1, enable_failures=False)
    res = sim.run(jobs, horizon_s=24 * 3600)
    assert res.jcr() == 1.0


def test_dlrover_beats_optimus_jct(jobs):
    out = {}
    for name in ("dlrover_rm", "optimus"):
        sim = CloudSim(name, total_cpu=8192, total_mem_gb=65536, seed=1,
                       enable_failures=False)
        res = sim.run(jobs, horizon_s=24 * 3600)
        out[name] = res.jct_percentile(50)
    assert out["dlrover_rm"] < out["optimus"]


def test_failures_tracked_and_recovered():
    jobs = generate_jobs(6, seed=4, mean_msamples=20.0)
    sim = CloudSim("dlrover_rm", total_cpu=8192, total_mem_gb=65536, seed=2,
                   pod_failure_rate_per_day=5.0)   # absurdly failure-prone
    res = sim.run(jobs, horizon_s=24 * 3600)
    assert sum(r.failures for r in res.records) > 0
    assert res.jcr() > 0.5                          # survives via sharding


def test_oom_prevention_reduces_oom_events():
    jobs = generate_jobs(12, seed=6, mean_msamples=30.0)
    ooms = {}
    for name in ("static_user", "dlrover_rm"):
        sim = CloudSim(name, total_cpu=8192, total_mem_gb=65536, seed=3,
                       enable_failures=True, pod_failure_rate_per_day=0.0,
                       straggler_rate_per_pod_per_day=0.0,
                       hotps_rate_per_pod_per_day=0.0)
        res = sim.run(jobs, horizon_s=24 * 3600)
        ooms[name] = sum(r.ooms for r in res.records)
    assert ooms["dlrover_rm"] <= ooms["static_user"]


def test_utilization_timeseries_populated(jobs):
    sim = CloudSim("static_user", total_cpu=8192, total_mem_gb=65536, seed=1)
    res = sim.run(jobs, horizon_s=8 * 3600)
    assert len(res.ts_time) > 10
    assert all(u <= a + 1e-6 for u, a in zip(res.ts_used_cpu, res.ts_alloc_cpu)
               if a > 0)


def test_failure_rng_seeding_deterministic():
    """Same (seed, failure_seed) → identical records; the failure stream is
    decoupled from the scheduler seed and fully reproducible."""
    def records(seed, failure_seed):
        jobs = generate_jobs(6, seed=4, mean_msamples=20.0)
        sim = CloudSim("dlrover_rm", total_cpu=8192, total_mem_gb=65536,
                       seed=seed, failure_seed=failure_seed,
                       pod_failure_rate_per_day=5.0)
        res = sim.run(jobs, horizon_s=12 * 3600)
        return [(r.completed, r.failures, r.stragglers, r.hot_pses,
                 round(r.downtime_s, 6)) for r in res.records]

    assert records(2, 77) == records(2, 77)
    assert records(2, 77) != records(2, 78)     # failure stream is its own knob


def test_failure_seed_default_preserves_legacy_stream():
    """failure_seed=None must reproduce the historical ``seed + 1`` stream."""
    sim_default = CloudSim("dlrover_rm", seed=9)
    sim_explicit = CloudSim("dlrover_rm", seed=9, failure_seed=10)
    assert sim_default.failure_seed == 10
    assert (sim_default.rng.integers(0, 1 << 30, 8).tolist()
            == sim_explicit.rng.integers(0, 1 << 30, 8).tolist())


def test_recovery_time_parameters_are_config():
    from repro.core.migration import MigrationTimings
    slow = MigrationTimings(flash_ckpt_load_s=123.0)
    sim = CloudSim("dlrover_rm", seed=1, timings=slow,
                   straggler_rebalance_s=30.0, unmitigated_s=900.0)
    assert sim.timings.flash_ckpt_load_s == 123.0
    assert sim.straggler_rebalance_s == 30.0
    assert sim.unmitigated_s == 900.0


def test_event_log_byte_identical_for_fixed_seeds():
    """The determinism contract the replayed benches pin: same
    (workload, scheduler seed, failure_seed, config) ⇒ the serialized event
    log reproduces byte-for-byte; a different failure seed diverges."""
    def log(failure_seed):
        jobs = generate_jobs(8, seed=4, mean_msamples=20.0)
        sim = CloudSim("dlrover_rm", total_cpu=4096, total_mem_gb=32768,
                       seed=2, failure_seed=failure_seed,
                       pod_failure_rate_per_day=2.0,
                       straggler_rate_per_pod_per_day=0.3)
        return sim.run(jobs, horizon_s=8 * 3600).event_log()

    a, b = log(77), log(77)
    assert a == b
    assert "start" in a and "complete" in a
    assert log(78) != a


def test_on_event_feeds_brain_degradation():
    """Stage-3 plumbing: engine events reach the scheduler hook, and for
    DLRover-RM they land in the brain's degradation ledger."""
    jobs = generate_jobs(6, seed=4, mean_msamples=20.0)
    sim = CloudSim("dlrover_rm", total_cpu=4096, total_mem_gb=32768,
                   seed=2, failure_seed=77, pod_failure_rate_per_day=5.0,
                   straggler_rate_per_pod_per_day=1.0)
    res = sim.run(jobs, horizon_s=6 * 3600)
    engine_events = [(t, j, k) for t, j, k in res.events
                     if k in ("failure", "straggler", "hot_ps", "oom")]
    assert engine_events, "failure-prone run must emit instability events"
    t, jid, kind = engine_events[-1]
    penalty = sim.scheduler.brain.degradation_penalty(jid, now=t)
    assert penalty > 0.0


def test_baseline_scheduler_ignores_events():
    """The base on_event hook is a no-op: baselines never raise on it."""
    jobs = generate_jobs(4, seed=4, mean_msamples=20.0)
    sim = CloudSim("es", total_cpu=4096, total_mem_gb=32768, seed=2,
                   failure_seed=77, pod_failure_rate_per_day=5.0)
    res = sim.run(jobs, horizon_s=4 * 3600)
    assert res.records                      # ran to the horizon without error


def test_capacity_profile_moves_shared_capacity():
    """A CapacityWave profile must move the shared ClusterCapacity each
    step (recorded in ts_capacity_cpu) and bound admission during dips."""
    from repro.sim.trace import CapacityWave
    jobs = generate_jobs(8, seed=2, mean_msamples=20.0)
    wave = CapacityWave(2048.0, 16384.0, amplitude=0.5, period_s=2 * 3600.0)
    sim = CloudSim("static_user", total_cpu=2048, total_mem_gb=16384,
                   seed=1, enable_failures=False, capacity_profile=wave)
    res = sim.run(jobs, horizon_s=8 * 3600)
    caps = res.ts_capacity_cpu
    assert len(caps) > 10
    assert max(caps) > 2048.0 * 1.3 and min(caps) < 2048.0 * 0.7
    # allocation never exceeds the instantaneous envelope at admission time
    for t, alloc in zip(res.ts_time, res.ts_alloc_cpu):
        assert alloc <= 2048.0 * 1.5 + 1e-6


def test_replay_summary_rows_deterministic():
    """The bench-facing replay path: same seeds ⇒ identical summary dict."""
    from repro.sim.replay import replay, summarize
    from repro.sim.trace import default_trace_path, load_trace, trace_to_jobs
    jobs = trace_to_jobs(load_trace(default_trace_path()), seed=3)[:10]

    def rows():
        res = replay(jobs, "static_user", total_cpu=2048.0,
                     total_mem_gb=16384.0, horizon_s=6 * 3600.0, seed=3,
                     failure_seed=77, amplitude=0.15)
        return summarize(res)

    assert rows() == rows()


def test_measured_timings_change_downtime():
    """The sim actually consumes injected timings: a catastrophically slow
    recovery model must show up as more downtime under heavy failures."""
    from repro.core.migration import MigrationTimings

    def downtime(timings):
        jobs = generate_jobs(6, seed=4, mean_msamples=20.0)
        sim = CloudSim("static_tuned", total_cpu=8192, total_mem_gb=65536,
                       seed=2, failure_seed=5, timings=timings,
                       pod_failure_rate_per_day=5.0)
        res = sim.run(jobs, horizon_s=12 * 3600)
        return sum(r.downtime_s for r in res.records)

    fast = downtime(MigrationTimings())
    slow = downtime(MigrationTimings(provision_s=1800.0,
                                     rds_ckpt_load_s=1800.0))
    assert slow > fast
