"""End-to-end behaviour: DLRM training with elastic data sharding, checkpoint
resume, and convergence — the paper's system running for real (reduced scale).
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.dlrm_models import WIDE_DEEP, reduced_dlrm
from repro.core.flash_checkpoint import FlashCheckpoint
from repro.core.sharding_service import ShardingService
from repro.data.pipeline import ShardDataLoader
from repro.data.synthetic import criteo_batch
from repro.models.dlrm import init_dlrm
from repro.train import optim, trainer


def _mk(cfg, seed=0):
    opt = optim.adagrad(0.05)
    params = init_dlrm(cfg, jax.random.PRNGKey(seed))
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    step = jax.jit(trainer.make_dlrm_train_step(cfg, opt))
    return state, step


def test_dlrm_trains_and_improves():
    cfg = reduced_dlrm(WIDE_DEEP)
    state, step = _mk(cfg)
    svc = ShardingService(total_samples=1024, shard_size=128)
    loader = ShardDataLoader(svc, "w0",
                             lambda idx: criteo_batch(cfg, 7, idx), 32,
                             clock=lambda: 0.0)
    losses = []
    for batch in loader:
        state, m = step(state, {k: jnp.asarray(v) for k, v in batch.items()})
        losses.append(float(m["loss"]))
    assert len(losses) == 1024 // 32
    assert np.mean(losses[-4:]) < np.mean(losses[:4])
    ok, covered, dup = svc.coverage(0)
    assert ok and covered == 1024 and dup == 0


def test_worker_failure_recovery_preserves_data():
    """A worker dies mid-shard; replacement resumes; exactly-once holds."""
    cfg = reduced_dlrm(WIDE_DEEP)
    state, step = _mk(cfg)
    svc = ShardingService(total_samples=512, shard_size=128,
                          heartbeat_timeout=10.0)
    clock = [0.0]

    def tick():
        clock[0] += 1.0
        return clock[0]

    la = ShardDataLoader(svc, "wA", lambda i: criteo_batch(cfg, 7, i), 32,
                         clock=tick)
    for _ in range(2):                       # partial shard consumption
        b = la.next_batch()
        state, _ = step(state, {k: jnp.asarray(v) for k, v in b.items()})
    svc.report_failure("wA", tick())
    lb = ShardDataLoader(svc, "wB", lambda i: criteo_batch(cfg, 7, i), 32,
                         clock=tick)
    n = 0
    for b in lb:
        state, _ = step(state, {k: jnp.asarray(v) for k, v in b.items()})
        n += 1
    ok, covered, dup = svc.coverage(0)
    assert ok and covered == 512 and dup == 0


def test_checkpoint_resume_training():
    cfg = reduced_dlrm(WIDE_DEEP)
    state, step = _mk(cfg)
    batch = {k: jnp.asarray(v)
             for k, v in criteo_batch(cfg, 7, np.arange(32)).items()}
    for _ in range(3):
        state, _ = step(state, batch)
    with tempfile.TemporaryDirectory() as d:
        ck = FlashCheckpoint(d, async_persist=False)
        ck.save(state, 3)
        # fresh process simulation: new ckpt instance reads from disk
        ck2 = FlashCheckpoint(d)
        like = jax.eval_shape(lambda: state)
        restored, rstep = ck2.restore(like)
        assert rstep == 3
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_allclose(a, b)
        state2, m2 = step(restored, batch)
        state1, m1 = step(state, batch)
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                                   rtol=1e-6)
