"""Serving engine + cluster-brain orchestration integration."""
import numpy as np
import jax

from repro.configs.base import reduce_config
from repro.configs.registry import ARCHS
from repro.core.autoscaler import ClusterCapacity
from repro.core.brain import ClusterBrain, JobMaster, Profiler
from repro.core.perf_model import JobResources, JobStatics
from repro.core.sharding_service import ShardingService
from repro.core.warm_start import JobMeta
from repro.models.registry import build_model
from repro.serve.engine import Request, ServeEngine


def test_serve_engine_batched_completions():
    cfg = reduce_config(ARCHS["llama3.2-3b"])
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    eng = ServeEngine(api, params, slots=2, max_len=48)
    for r in range(5):
        eng.submit(Request(rid=r, prompt=np.arange(4) + r, max_new_tokens=3))
    outs = eng.run()
    assert len(outs) == 5
    for c in outs.values():
        assert len(c.tokens) == 3


def test_serve_greedy_deterministic():
    cfg = reduce_config(ARCHS["llama3.2-3b"])
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    outs = []
    for _ in range(2):
        eng = ServeEngine(api, params, slots=1, max_len=32)
        eng.submit(Request(rid=0, prompt=np.arange(6), max_new_tokens=4))
        outs.append(eng.run()[0].tokens)
    assert outs[0] == outs[1]


def _master(jid="j0"):
    stat = JobStatics(batch_size=512, model_size=3.2e8, bandwidth=1e9, emb_dim=16)
    meta = JobMeta("dcn", 1e6, 1e7, 16, 512, 1e7)
    return JobMaster(
        job_id=jid, meta=meta, statics=stat,
        resources=JobResources(w=2, p=1, cpu_w=4, cpu_p=4),
        total_samples=1e6,
        sharding=ShardingService(1000, 100),
        profiler=Profiler(statics=stat))


def test_brain_three_stage_lifecycle():
    brain = ClusterBrain(ClusterCapacity(2048, 16384))
    m = _master()
    plan = brain.admit(m)                         # stage 1 (cold DB: default)
    assert plan.w >= 1
    # profile some iterations so stage 2 can fit the model
    from repro.core.perf_model import synthesize_t_iter
    rng = np.random.default_rng(0)
    import dataclasses
    for i in range(12):
        r = dataclasses.replace(m.resources, w=1 + i % 6, p=1 + i % 3)
        t = synthesize_t_iter(r, m.statics, [3.48e-3, 2.36e-3, 0.68e-3, 2.45e-5],
                              2.45e-3, noise=0.02, rng=rng)
        m.profiler.record_iteration(r, t)
    plans = brain.optimize()                      # stage 2
    assert isinstance(plans, dict)
    # stage 3: memory growth triggers predictive scale-up
    for i in range(8):
        m.profiler.record_memory(i * 1e5, 4e9 + i * 2e9)
    brain.check_oom()
    assert m.resources.mem_p >= 16.0
    brain.complete("j0", throughput=1000.0)
    assert len(brain.config_db) == 1
    # a similar new job now warm-starts from history
    m2 = _master("j1")
    plan2 = brain.admit(m2)
    assert plan2 is not None
