"""Hardened flash checkpoint: atomicity, checksums, newest-valid fallback."""
import json
import os
import tempfile

import numpy as np
import pytest

from repro.core.faults import corrupt_blob
from repro.core.flash_checkpoint import (
    CheckpointCorruptError, FlashCheckpoint,
)


def _state(x: float):
    return {"w": np.full(16, x, np.float32), "b": np.arange(4.0)}


def _dirname(step: int) -> str:
    return f"ckpt_{step:012d}"


@pytest.fixture()
def store():
    with tempfile.TemporaryDirectory() as d:
        yield FlashCheckpoint(d, keep=3, async_persist=False), d


# -------------------------------------------------------------------- basics
def test_save_restore_round_trip(store):
    ck, d = store
    ck.save(_state(1.5), 10)
    assert os.path.isdir(os.path.join(d, _dirname(10)))
    manifest = json.load(open(os.path.join(d, _dirname(10), "MANIFEST.json")))
    assert manifest["step"] == 10 and len(manifest["leaves"]) == 2
    restored, step = ck.restore(_state(0.0))
    assert step == 10
    np.testing.assert_array_equal(np.asarray(restored["w"]), _state(1.5)["w"])


def test_no_staging_dirs_left_behind(store):
    ck, d = store
    for s in (5, 10, 15):
        ck.save(_state(s), s)
    assert not [n for n in os.listdir(d) if ".tmp-" in n]


def test_eviction_keeps_newest(store):
    ck, d = store
    for s in (5, 10, 15, 20, 25):
        ck.save(_state(s), s)
    assert ck.valid_steps() == [15, 20, 25]     # keep=3


# ------------------------------------------------------- corruption handling
def test_corrupt_latest_falls_back_to_newest_valid(store):
    ck, d = store
    for s in (5, 10, 15):
        ck.save(_state(s), s)
    ck.drop_memory_tier()
    corrupt_blob(os.path.join(d, _dirname(15)))
    restored, step = ck.restore(_state(0.0))
    assert step == 10
    np.testing.assert_array_equal(np.asarray(restored["w"]), _state(10)["w"])
    assert any(e["kind"] == "corrupt_blob_fallback" and e["step"] == 15
               for e in ck.events)
    assert ck.valid_steps() == [5, 10]


def test_truncated_blob_detected(store):
    ck, d = store
    ck.save(_state(1.0), 5)
    ck.save(_state(2.0), 10)
    ck.drop_memory_tier()
    corrupt_blob(os.path.join(d, _dirname(10)), mode="truncate")
    _, step = ck.restore(_state(0.0))
    assert step == 5


def test_explicit_corrupt_step_raises(store):
    ck, d = store
    ck.save(_state(1.0), 5)
    ck.save(_state(2.0), 10)
    ck.drop_memory_tier()
    corrupt_blob(os.path.join(d, _dirname(10)))
    with pytest.raises(CheckpointCorruptError):
        ck.restore(_state(0.0), step=10)        # asked for that exact blob


def test_all_blobs_corrupt_raises_filenotfound(store):
    ck, d = store
    ck.save(_state(1.0), 5)
    ck.drop_memory_tier()
    corrupt_blob(os.path.join(d, _dirname(5)))
    with pytest.raises(FileNotFoundError, match="no valid checkpoint"):
        ck.restore(_state(0.0))


def test_memory_tier_shadows_corrupt_disk(store):
    ck, d = store
    ck.save(_state(3.0), 5)
    corrupt_blob(os.path.join(d, _dirname(5)))  # disk damaged, memory intact
    restored, step = ck.restore(_state(0.0))
    assert step == 5
    np.testing.assert_array_equal(np.asarray(restored["w"]), _state(3.0)["w"])


def test_manifest_leaf_set_mismatch_detected(store):
    ck, d = store
    ck.save(_state(1.0), 5)
    ck.drop_memory_tier()
    mpath = os.path.join(d, _dirname(5), "MANIFEST.json")
    manifest = json.load(open(mpath))
    manifest["leaves"]["['extra']"] = {"crc32": 0, "shape": [1],
                                       "dtype": "float32"}
    json.dump(manifest, open(mpath, "w"))
    with pytest.raises(FileNotFoundError):
        ck.restore(_state(0.0))                 # sole blob fails verification


# ----------------------------------------------- malformed neighbors skipped
def test_malformed_entries_skipped_and_logged(store):
    ck, d = store
    ck.save(_state(1.0), 5)
    os.makedirs(os.path.join(d, "ckpt_garbage"))
    os.makedirs(os.path.join(d, "ckpt_000000000099.tmp-123"))  # dead staging
    os.makedirs(os.path.join(d, _dirname(50)))  # step dir without manifest
    ck.drop_memory_tier()
    _, step = ck.restore(_state(0.0))           # neighbors don't break restore
    assert step == 5
    kinds = {e["kind"] for e in ck.events}
    assert {"skip_malformed", "skip_staging_dir",
            "skip_missing_manifest"} <= kinds
    ck.save(_state(2.0), 10)                    # eviction survives them too
    assert 10 in ck.valid_steps()


def test_eviction_does_not_remove_staging_or_malformed(store):
    ck, d = store
    os.makedirs(os.path.join(d, "ckpt_notastep"))
    for s in (5, 10, 15, 20):
        ck.save(_state(s), s)
    assert os.path.isdir(os.path.join(d, "ckpt_notastep"))


# ------------------------------------------------------------- legacy format
def test_legacy_npz_blob_still_restores(store):
    ck, d = store
    flat = {"['w']": _state(7.0)["w"], "['b']": _state(7.0)["b"]}
    np.savez(os.path.join(d, "ckpt_000000000007.npz"), **flat)
    restored, step = ck.restore(_state(0.0))
    assert step == 7
    np.testing.assert_array_equal(np.asarray(restored["w"]), _state(7.0)["w"])


def test_missing_leaf_raises_not_zero_fills(store):
    ck, d = store
    ck.save({"w": np.ones(4)}, 5)
    with pytest.raises(KeyError, match="missing leaf"):
        ck.restore({"w": np.zeros(4), "extra": np.zeros(2)})


def test_optional_leaves_zero_fill(store):
    ck, d = store
    ck.save({"w": np.ones(4)}, 5)
    like = {"w": np.zeros(4), "extra": np.ones(2, np.float32)}
    restored, _ = ck.restore(like, optional_leaves=("['extra']",))
    np.testing.assert_array_equal(np.asarray(restored["extra"]),
                                  np.zeros(2, np.float32))


def test_async_persist_waits(tmp_path):
    ck = FlashCheckpoint(str(tmp_path), keep=2, async_persist=True)
    for s in (5, 10):
        ck.save(_state(s), s)
    ck.wait()
    ck.drop_memory_tier()
    _, step = ck.restore(_state(0.0))
    assert step == 10
