"""Hardened flash checkpoint: atomicity, checksums, newest-valid fallback.

The property tests at the bottom ride the hypothesis shim
(``tests/_hypothesis_compat``): under arbitrary combinations of truncated /
bit-flipped blobs and torn manifest dirs, ``restore`` must return the newest
fully-valid step bit-exactly or raise cleanly — never hand back damaged
state. The fork-based regression pins the atomic-rename commit point: a
SIGKILL anywhere before ``_commit``'s ``os.replace`` (even with every byte
of the staging dir already written) must leave nothing ``valid_steps``
counts as valid.
"""
import json
import os
import shutil
import signal
import tempfile

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.faults import corrupt_blob
from repro.core.flash_checkpoint import (
    CheckpointCorruptError, FlashCheckpoint,
)


def _state(x: float):
    return {"w": np.full(16, x, np.float32), "b": np.arange(4.0)}


def _dirname(step: int) -> str:
    return f"ckpt_{step:012d}"


@pytest.fixture()
def store():
    with tempfile.TemporaryDirectory() as d:
        yield FlashCheckpoint(d, keep=3, async_persist=False), d


# -------------------------------------------------------------------- basics
def test_save_restore_round_trip(store):
    ck, d = store
    ck.save(_state(1.5), 10)
    assert os.path.isdir(os.path.join(d, _dirname(10)))
    manifest = json.load(open(os.path.join(d, _dirname(10), "MANIFEST.json")))
    assert manifest["step"] == 10 and len(manifest["leaves"]) == 2
    restored, step = ck.restore(_state(0.0))
    assert step == 10
    np.testing.assert_array_equal(np.asarray(restored["w"]), _state(1.5)["w"])


def test_no_staging_dirs_left_behind(store):
    ck, d = store
    for s in (5, 10, 15):
        ck.save(_state(s), s)
    assert not [n for n in os.listdir(d) if ".tmp-" in n]


def test_eviction_keeps_newest(store):
    ck, d = store
    for s in (5, 10, 15, 20, 25):
        ck.save(_state(s), s)
    assert ck.valid_steps() == [15, 20, 25]     # keep=3


# ------------------------------------------------------- corruption handling
def test_corrupt_latest_falls_back_to_newest_valid(store):
    ck, d = store
    for s in (5, 10, 15):
        ck.save(_state(s), s)
    ck.drop_memory_tier()
    corrupt_blob(os.path.join(d, _dirname(15)))
    restored, step = ck.restore(_state(0.0))
    assert step == 10
    np.testing.assert_array_equal(np.asarray(restored["w"]), _state(10)["w"])
    assert any(e["kind"] == "corrupt_blob_fallback" and e["step"] == 15
               for e in ck.events)
    assert ck.valid_steps() == [5, 10]


def test_truncated_blob_detected(store):
    ck, d = store
    ck.save(_state(1.0), 5)
    ck.save(_state(2.0), 10)
    ck.drop_memory_tier()
    corrupt_blob(os.path.join(d, _dirname(10)), mode="truncate")
    _, step = ck.restore(_state(0.0))
    assert step == 5


def test_explicit_corrupt_step_raises(store):
    ck, d = store
    ck.save(_state(1.0), 5)
    ck.save(_state(2.0), 10)
    ck.drop_memory_tier()
    corrupt_blob(os.path.join(d, _dirname(10)))
    with pytest.raises(CheckpointCorruptError):
        ck.restore(_state(0.0), step=10)        # asked for that exact blob


def test_all_blobs_corrupt_raises_filenotfound(store):
    ck, d = store
    ck.save(_state(1.0), 5)
    ck.drop_memory_tier()
    corrupt_blob(os.path.join(d, _dirname(5)))
    with pytest.raises(FileNotFoundError, match="no valid checkpoint"):
        ck.restore(_state(0.0))


def test_memory_tier_shadows_corrupt_disk(store):
    ck, d = store
    ck.save(_state(3.0), 5)
    corrupt_blob(os.path.join(d, _dirname(5)))  # disk damaged, memory intact
    restored, step = ck.restore(_state(0.0))
    assert step == 5
    np.testing.assert_array_equal(np.asarray(restored["w"]), _state(3.0)["w"])


def test_manifest_leaf_set_mismatch_detected(store):
    ck, d = store
    ck.save(_state(1.0), 5)
    ck.drop_memory_tier()
    mpath = os.path.join(d, _dirname(5), "MANIFEST.json")
    manifest = json.load(open(mpath))
    manifest["leaves"]["['extra']"] = {"crc32": 0, "shape": [1],
                                       "dtype": "float32"}
    json.dump(manifest, open(mpath, "w"))
    with pytest.raises(FileNotFoundError):
        ck.restore(_state(0.0))                 # sole blob fails verification


# ----------------------------------------------- malformed neighbors skipped
def test_malformed_entries_skipped_and_logged(store):
    ck, d = store
    ck.save(_state(1.0), 5)
    os.makedirs(os.path.join(d, "ckpt_garbage"))
    os.makedirs(os.path.join(d, "ckpt_000000000099.tmp-123"))  # dead staging
    os.makedirs(os.path.join(d, _dirname(50)))  # step dir without manifest
    ck.drop_memory_tier()
    _, step = ck.restore(_state(0.0))           # neighbors don't break restore
    assert step == 5
    kinds = {e["kind"] for e in ck.events}
    assert {"skip_malformed", "skip_staging_dir",
            "skip_missing_manifest"} <= kinds
    ck.save(_state(2.0), 10)                    # eviction survives them too
    assert 10 in ck.valid_steps()


def test_eviction_does_not_remove_staging_or_malformed(store):
    ck, d = store
    os.makedirs(os.path.join(d, "ckpt_notastep"))
    for s in (5, 10, 15, 20):
        ck.save(_state(s), s)
    assert os.path.isdir(os.path.join(d, "ckpt_notastep"))


# ------------------------------------------------------------- legacy format
def test_legacy_npz_blob_still_restores(store):
    ck, d = store
    flat = {"['w']": _state(7.0)["w"], "['b']": _state(7.0)["b"]}
    np.savez(os.path.join(d, "ckpt_000000000007.npz"), **flat)
    restored, step = ck.restore(_state(0.0))
    assert step == 7
    np.testing.assert_array_equal(np.asarray(restored["w"]), _state(7.0)["w"])


def test_missing_leaf_raises_not_zero_fills(store):
    ck, d = store
    ck.save({"w": np.ones(4)}, 5)
    with pytest.raises(KeyError, match="missing leaf"):
        ck.restore({"w": np.zeros(4), "extra": np.zeros(2)})


def test_optional_leaves_zero_fill(store):
    ck, d = store
    ck.save({"w": np.ones(4)}, 5)
    like = {"w": np.zeros(4), "extra": np.ones(2, np.float32)}
    restored, _ = ck.restore(like, optional_leaves=("['extra']",))
    np.testing.assert_array_equal(np.asarray(restored["extra"]),
                                  np.zeros(2, np.float32))


# --------------------------------------- property: damage never lies upward
STEPS = (5, 10, 15, 20)
DAMAGE = ("none",            # leave the blob intact
          "flip",            # bit-flip bytes mid-file (bad DMA / bit rot)
          "truncate",        # cut leaves.npz in half (mid-write kill)
          "flip_manifest",   # corrupt the manifest JSON itself
          "drop_manifest",   # torn dir: data present, manifest missing
          "drop_leaves")     # torn dir: manifest present, data missing


def _apply_damage(d: str, step: int, action: str) -> None:
    path = os.path.join(d, _dirname(step))
    if action == "flip":
        corrupt_blob(path, mode="flip", seed=step)
    elif action == "truncate":
        corrupt_blob(path, mode="truncate")
    elif action == "flip_manifest":
        corrupt_blob(os.path.join(path, "MANIFEST.json"), seed=step)
    elif action == "drop_manifest":
        os.remove(os.path.join(path, "MANIFEST.json"))
    elif action == "drop_leaves":
        os.remove(os.path.join(path, "leaves.npz"))


@settings(max_examples=30, deadline=None)
@given(damage=st.lists(st.sampled_from(DAMAGE), min_size=len(STEPS),
                       max_size=len(STEPS)),
       torn_staging=st.booleans())
def test_restore_newest_fully_valid_or_clean_raise(damage, torn_staging):
    """Whatever subset of blobs is damaged however, restore returns the
    newest untouched step bit-exactly — or raises FileNotFoundError when
    none survive. Damaged steps also vanish from valid_steps()."""
    with tempfile.TemporaryDirectory() as d:
        ck = FlashCheckpoint(d, keep=len(STEPS), async_persist=False)
        for s in STEPS:
            ck.save(_state(float(s)), s)
        ck.drop_memory_tier()               # force the disk tier under test
        for s, action in zip(STEPS, damage):
            _apply_damage(d, s, action)
        if torn_staging:                    # a kill-during-save leftover
            os.makedirs(os.path.join(d, "ckpt_000000000099.tmp-1"))
        survivors = [s for s, a in zip(STEPS, damage) if a == "none"]

        if not survivors:
            with pytest.raises(FileNotFoundError, match="no valid checkpoint"):
                ck.restore(_state(0.0))
            return
        restored, step = ck.restore(_state(0.0))
        assert step == max(survivors)
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      _state(float(step))["w"])
        assert ck.valid_steps() == survivors


# ------------------------------------------- regression: the commit point
def _fork_save_killed_in_pre_commit(d: str, step: int) -> int:
    """Fork a child that SIGKILLs itself inside the pre-commit window of
    ``save(step)`` — after every staging byte is written and fsynced, before
    the atomic rename. The nastiest torn-write case a real kill produces."""
    pid = os.fork()
    if pid == 0:                            # pragma: no cover - dies by signal
        ck = FlashCheckpoint(
            d, keep=3, async_persist=False,
            pre_commit_hook=lambda tmp, s: os.kill(os.getpid(),
                                                   signal.SIGKILL))
        ck.save(_state(float(step)), step)
        os._exit(1)                         # unreachable: hook killed us
    _, status = os.waitpid(pid, 0)
    assert os.WIFSIGNALED(status) and os.WTERMSIG(status) == signal.SIGKILL
    return pid


def test_midwrite_sigkill_never_counts_as_valid(tmp_path):
    """Satellite fix: kill-during-save must never leave a directory that
    ``valid_steps`` counts as valid — commit is ONE atomic rename."""
    d = str(tmp_path)
    ck = FlashCheckpoint(d, keep=3, async_persist=False)
    ck.save(_state(1.0), 5)                 # one good committed blob
    child = _fork_save_killed_in_pre_commit(d, 10)

    # the stranded staging dir is byte-complete (data + manifest written,
    # only the rename missing) yet invisible to validity and restore
    staging = os.path.join(d, f"ckpt_{10:012d}.tmp-{child}")
    assert os.path.isdir(staging)
    assert os.path.exists(os.path.join(staging, "leaves.npz"))
    assert os.path.exists(os.path.join(staging, "MANIFEST.json"))
    assert ck.valid_steps() == [5]
    ck.drop_memory_tier()
    restored, step = ck.restore(_state(0.0))
    assert step == 5
    np.testing.assert_array_equal(np.asarray(restored["w"]), _state(1.0)["w"])

    # the survivor keeps checkpointing: a later save commits normally and
    # eviction never touches the stranded staging dir
    ck.save(_state(3.0), 15)
    assert ck.valid_steps() == [5, 15]
    assert os.path.isdir(staging)


def test_commit_is_last_step_before_fault_hook(tmp_path):
    """Hook ordering pins the commit point: pre_commit sees only the
    staging path (no final dir yet); fault_hook sees only the final dir."""
    calls = []

    def pre(tmp, step):
        calls.append(("pre", os.path.basename(tmp),
                      os.path.isdir(tmp.rsplit(".tmp-", 1)[0])))

    def post(final, step):
        calls.append(("post", os.path.basename(final), os.path.isdir(final)))

    ck = FlashCheckpoint(str(tmp_path), async_persist=False,
                         pre_commit_hook=pre, fault_hook=post)
    ck.save(_state(1.0), 7)
    assert calls == [("pre", f"ckpt_{7:012d}.tmp-{os.getpid()}", False),
                     ("post", f"ckpt_{7:012d}", True)]


def test_async_persist_waits(tmp_path):
    ck = FlashCheckpoint(str(tmp_path), keep=2, async_persist=True)
    for s in (5, 10):
        ck.save(_state(s), s)
    ck.wait()
    ck.drop_memory_tier()
    _, step = ck.restore(_state(0.0))
    assert step == 10
