"""End-to-end driver: ~100M-parameter DLRM trained through the full
DLRover-RM lifecycle — warm start, profiling, auto-scaling decisions,
a mid-training worker failure (shard requeued), a straggler (smaller shards),
flash-checkpoint, and resume. Real JAX training on CPU, a few hundred steps.

    PYTHONPATH=src python examples/elastic_dlrm_train.py [--steps 300]
"""
import argparse
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.dlrm_models import DLRMConfig
from repro.core.autoscaler import ClusterCapacity
from repro.core.brain import ClusterBrain, JobMaster, Profiler
from repro.core.flash_checkpoint import FlashCheckpoint
from repro.core.perf_model import JobResources, JobStatics
from repro.core.sharding_service import ShardingService
from repro.core.warm_start import JobMeta
from repro.data.pipeline import ShardDataLoader
from repro.data.synthetic import criteo_batch
from repro.models.dlrm import dlrm_auc, init_dlrm
from repro.train import optim, trainer


def build_cfg() -> DLRMConfig:
    # ~100M params: 26 tables, ~240k rows each, D=16 -> ~100M embedding params
    rows = tuple(int(2.4e5 * (1 + (i % 5))) for i in range(26))
    return DLRMConfig(name="wide_deep_100m", kind="wide_deep",
                      table_rows=rows, embed_dim=16,
                      mlp_dims=(256, 128, 64), batch_size=256)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()

    cfg = build_cfg()
    print(f"DLRM {cfg.name}: {cfg.param_count():,} params "
          f"({cfg.total_embedding_rows:,} embedding rows)")

    opt = optim.adagrad(0.05)
    t0 = time.time()
    params = init_dlrm(cfg, jax.random.PRNGKey(0))
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    step_fn = jax.jit(trainer.make_dlrm_train_step(cfg, opt))
    print(f"init in {time.time()-t0:.1f}s")

    # --- cluster brain admission (stage 1: warm start) -----------------------
    brain = ClusterBrain(ClusterCapacity(2048, 16384))
    statics = JobStatics(batch_size=cfg.batch_size,
                         model_size=cfg.param_count() * 4.0,
                         bandwidth=1e9, emb_dim=cfg.embed_dim)
    meta = JobMeta(cfg.kind, dense_params=1e6,
                   emb_rows=cfg.total_embedding_rows, emb_dim=cfg.embed_dim,
                   batch_size=cfg.batch_size, dataset_samples=args.steps * 256)
    total_samples = args.steps * cfg.batch_size
    master = JobMaster(
        job_id="dlrm-100m", meta=meta, statics=statics,
        resources=JobResources(w=2, p=1, cpu_w=4, cpu_p=4),
        total_samples=total_samples,
        sharding=ShardingService(total_samples, shard_size=cfg.batch_size * 8,
                                 min_shard=cfg.batch_size),
        profiler=Profiler(statics=statics))
    plan = brain.admit(master)
    print(f"stage-1 warm start plan: {plan}")

    ckpt = FlashCheckpoint(tempfile.mkdtemp(prefix="flashckpt_"))
    svc = master.sharding
    clock = [0.0]

    def tick():
        clock[0] += 1.0
        return clock[0]

    def batch_fn(idx):
        return criteo_batch(cfg, seed=11, indices=idx)

    loader = ShardDataLoader(svc, "workerA", batch_fn, cfg.batch_size,
                             clock=tick)
    losses = []
    failed_over = False
    straggled = False
    t_train = time.time()
    while True:
        b = loader.next_batch()
        if b is None:
            break
        state, m = step_fn(state, {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(m["loss"]))
        n = len(losses)
        # profile for stage 2
        master.profiler.record_iteration(
            master.resources, float(np.random.default_rng(n).lognormal(-2, .05)))
        master.samples_done = n * cfg.batch_size
        master.profiler.record_memory(master.samples_done,
                                      4e9 + master.samples_done * 1e3)
        if n % 50 == 0:
            print(f"step {n:4d} loss={losses[-1]:.4f}")
            ckpt.save(state, n)
        if n == 60 and not failed_over:
            # --- stage 3: worker failure -> shard requeued, new worker -----
            failed_over = True
            svc.report_failure("workerA", tick())
            loader = ShardDataLoader(svc, "workerB", batch_fn,
                                     cfg.batch_size, clock=tick)
            print("workerA failed: shard requeued, workerB resumed "
                  "(no data loss)")
        if n == 120 and not straggled:
            straggled = True
            svc._view("workerB", tick()).is_straggler = True
            print("workerB flagged straggler: now receives split shards")
        if n == 150:
            plans = brain.optimize()
            print(f"stage-2 auto-scale plan: {plans.get('dlrm-100m')}")
            scaled = brain.check_oom()
            if scaled:
                print(f"stage-3 OOM prevention resized PS memory: {scaled}")

    dt = time.time() - t_train
    ok, covered, dup = svc.coverage(0)
    ev = criteo_batch(cfg, seed=12, indices=np.arange(512))
    auc = float(dlrm_auc(state["params"],
                         {k: jnp.asarray(v) for k, v in ev.items()}, cfg))
    print(f"\ntrained {len(losses)} steps in {dt:.1f}s "
          f"({len(losses)*cfg.batch_size/dt:.0f} samples/s)")
    print(f"loss {losses[0]:.4f} -> {np.mean(losses[-10:]):.4f}  AUC={auc:.4f}")
    print(f"exactly-once coverage: exact={ok} covered={covered} dup={dup}")
    ckpt.wait()
    print(f"final flash-ckpt: mem {ckpt.last_save_seconds*1e3:.1f} ms / "
          f"disk {ckpt.last_persist_seconds*1e3:.1f} ms (async)")
    brain.complete("dlrm-100m", throughput=len(losses) * cfg.batch_size / dt)
    print("job recorded to config DB for future warm starts")


if __name__ == "__main__":
    main()
