"""Quickstart: train a small LM with the full DLRover-RM substrate.

Covers: config registry -> model build -> shard-queue data pipeline ->
train step -> flash checkpoint -> restore. Runs on CPU in ~a minute.

    PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

import jax
import jax.numpy as jnp

from repro.configs.base import reduce_config
from repro.configs.registry import get_arch
from repro.core.flash_checkpoint import FlashCheckpoint
from repro.core.sharding_service import ShardingService
from repro.data.pipeline import ShardDataLoader
from repro.data.synthetic import lm_batch
from repro.models.registry import build_model
from repro.train import optim, trainer


def main():
    cfg = reduce_config(get_arch("llama3.2-3b"), d_model=128, n_heads=4,
                        n_kv_heads=2, head_dim=32, d_ff=256, num_layers=4,
                        vocab_size=512)
    api = build_model(cfg)
    opt = optim.adamw(3e-3)
    state = trainer.make_train_state(api, opt, jax.random.PRNGKey(0))
    step = jax.jit(trainer.make_train_step(api, opt, remat=True))

    svc = ShardingService(total_samples=2048, shard_size=256)
    loader = ShardDataLoader(svc, "worker0",
                             lambda idx: lm_batch(0, idx, 64, cfg.vocab_size),
                             batch_size=16)

    print(f"arch={cfg.name} params={cfg.param_count():,}")
    for i, batch in enumerate(loader):
        state, m = step(state, {k: jnp.asarray(v) for k, v in batch.items()})
        if i % 16 == 0:
            print(f"step {int(state['step']):4d} loss={float(m['loss']):.4f} "
                  f"gnorm={float(m['grad_norm']):.3f}")

    ok, covered, dup = svc.coverage(0)
    print(f"data coverage exact={ok} covered={covered} dup={dup}")

    with tempfile.TemporaryDirectory() as d:
        ck = FlashCheckpoint(d)
        ck.save(state, int(state["step"]))
        ck.wait()
        print(f"flash-checkpoint: mem tier {ck.last_save_seconds*1e3:.1f} ms, "
              f"async disk tier {ck.last_persist_seconds*1e3:.1f} ms")
        like = jax.eval_shape(lambda k: trainer.make_train_state(api, opt, k),
                              jax.random.PRNGKey(0))
        _, restored_step = ck.restore(like)
        print(f"restored at step {restored_step}")


if __name__ == "__main__":
    main()
