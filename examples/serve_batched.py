"""Batched serving: decode a small LM with slot-based continuous batching.

    PYTHONPATH=src python examples/serve_batched.py [--arch mamba2-2.7b]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs.base import reduce_config
from repro.configs.registry import get_arch
from repro.models.registry import build_model
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    args = ap.parse_args()

    cfg = reduce_config(get_arch(args.arch))
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    eng = ServeEngine(api, params, slots=args.slots, max_len=96)

    rng = np.random.default_rng(0)
    for rid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, size=rng.integers(4, 12))
        eng.submit(Request(rid=rid, prompt=prompt,
                           max_new_tokens=int(rng.integers(4, 10))))

    t0 = time.time()
    outs = eng.run()
    dt = time.time() - t0
    total_tokens = sum(len(c.tokens) for c in outs.values())
    print(f"arch={cfg.name} slots={args.slots} requests={args.requests}")
    for rid in sorted(outs):
        print(f"  req {rid}: {outs[rid].tokens}")
    print(f"{total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens/dt:.1f} tok/s, {eng.steps} engine steps)")


if __name__ == "__main__":
    main()
