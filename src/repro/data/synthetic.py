"""Deterministic synthetic datasets, addressed by absolute sample index.

Every sample is a pure function of (seed, sample_index) — the property the
paper's dynamic data sharding relies on: a shard reassigned to any worker
after a failure yields byte-identical data, so elasticity cannot disturb the
training data sequence (§5.1 "without any data omission or duplication").

The Criteo-like generator plants a learnable logistic structure so DLRM
training (Fig 8) has a real signal: labels depend on dense features and on a
few "informative" embedding buckets.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence

import numpy as np

from repro.configs.dlrm_models import DLRMConfig


def _rng_for(seed: int, idx_block: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, idx_block]))


# --- power-law (zipfian) sparse-feature ids -------------------------------------
def zipf_indices(rng: np.random.Generator, rows: int, size,
                 alpha: float) -> np.ndarray:
    """Bounded-Zipf row ids in ``[0, rows)``: P(id = i) ∝ (i + 1)^-alpha.

    ``alpha = 0`` degenerates to the uniform distribution. Ids are popularity
    *ranks* — id 0 is the hottest row — which is exactly the frequency-packed
    placement the hot-row cache assumes (real systems obtain it by remapping
    hashed ids through ``repro.sharding.policy.frequency_permutation``).
    Sampling is O(size) via the continuous inverse CDF.
    """
    if alpha <= 0.0:
        return rng.integers(0, rows, size)
    u = rng.random(size)
    if abs(alpha - 1.0) < 1e-9:
        x = np.exp(u * np.log(rows))
    else:
        x = ((rows ** (1.0 - alpha) - 1.0) * u + 1.0) ** (1.0 / (1.0 - alpha))
    # x is continuous in [1, rows]; floor then shift so ranks start at 0
    return np.minimum(x.astype(np.int64), rows) - 1


class RowFreqCounter:
    """Streaming per-row access-frequency estimator over the pooled table.

    Feed it per-batch (B, T, H) local index tensors; it accumulates exact
    lookup counts per *global* pool row. The counts drive the RecShard-style
    placement planners (``pack_hot_ranges`` / ``balanced_vocab_ranges``) and
    the fused engine's hot-row cache sizing.
    """

    def __init__(self, table_rows: Sequence[int]):
        self.table_rows = tuple(int(r) for r in table_rows)
        self.offsets = np.concatenate(
            ([0], np.cumsum(self.table_rows)[:-1])).astype(np.int64)
        self.total_rows = int(sum(self.table_rows))
        self.counts = np.zeros((self.total_rows,), np.int64)
        self.n_lookups = 0

    def update(self, sparse: np.ndarray) -> None:
        """sparse: (B, T, H) per-table-local ids from one batch."""
        sparse = np.asarray(sparse)
        flat = (sparse + self.offsets[None, :, None]).reshape(-1)
        self.counts += np.bincount(flat, minlength=self.total_rows)
        self.n_lookups += flat.size

    def top_k(self, k: int) -> np.ndarray:
        """Global row ids of the k most-frequent rows (hottest first)."""
        k = min(int(k), self.total_rows)
        part = np.argpartition(self.counts, -k)[-k:]
        return part[np.argsort(-self.counts[part], kind="stable")]

    def hit_rate(self, table_hot: Sequence[int]) -> float:
        """Fraction of observed lookups a per-table hot-prefix cache serves."""
        if self.n_lookups == 0:
            return 0.0
        hot = 0
        for off, k in zip(self.offsets, table_hot):
            hot += int(self.counts[off:off + int(k)].sum())
        return hot / self.n_lookups


def estimate_row_freq(cfg: DLRMConfig, seed: int, n_samples: int = 2048,
                      batch_size: int = 256,
                      start: int = 0) -> RowFreqCounter:
    """Row-frequency estimate from a deterministic synthetic sample range."""
    ctr = RowFreqCounter(cfg.table_rows)
    for lo in range(start, start + n_samples, batch_size):
        hi = min(lo + batch_size, start + n_samples)
        batch = criteo_batch(cfg, seed, np.arange(lo, hi))
        ctr.update(batch["sparse"])
    return ctr


# --- Criteo-like CTR samples ----------------------------------------------------
def criteo_batch(cfg: DLRMConfig, seed: int, indices: np.ndarray,
                 zipf_alpha: Optional[float] = None) -> Dict[str, np.ndarray]:
    """indices: (B,) absolute sample ids -> batch dict (dense/sparse/label).

    ``zipf_alpha`` (default ``cfg.zipf_alpha``) skews the sparse-feature ids
    to a power law; 0 keeps the original uniform stream byte-identical.
    """
    alpha = cfg.zipf_alpha if zipf_alpha is None else zipf_alpha
    B = len(indices)
    dense = np.empty((B, cfg.n_dense), np.float32)
    sparse = np.empty((B, cfg.n_tables, cfg.multi_hot), np.int64)
    label = np.empty((B,), np.float32)
    w_dense = np.linspace(-1.0, 1.0, cfg.n_dense).astype(np.float32)
    for i, idx in enumerate(np.asarray(indices)):
        rng = _rng_for(seed, int(idx))
        dense[i] = rng.normal(0, 1, cfg.n_dense).astype(np.float32)
        for t, rows in enumerate(cfg.table_rows):
            if alpha > 0.0:
                sparse[i, t] = zipf_indices(rng, rows, cfg.multi_hot, alpha)
            else:
                sparse[i, t] = rng.integers(0, rows, cfg.multi_hot)
        # informative structure: dense projection + parity of first buckets
        logit = float(dense[i] @ w_dense)
        logit += 0.5 * ((sparse[i, 0, 0] % 2) - 0.5) * 2
        logit += 0.25 * ((sparse[i, 1 % cfg.n_tables, 0] % 4 == 0) - 0.25) * 4
        p = 1.0 / (1.0 + np.exp(-logit))
        label[i] = float(rng.random() < p)
    return {"dense": dense, "sparse": sparse.astype(np.int32), "label": label}


# --- LM token streams -------------------------------------------------------------
def lm_batch(seed: int, indices: np.ndarray, seq_len: int,
             vocab_size: int) -> Dict[str, np.ndarray]:
    """Markov-ish synthetic token stream; deterministic per sample index."""
    B = len(indices)
    tokens = np.empty((B, seq_len + 1), np.int64)
    for i, idx in enumerate(np.asarray(indices)):
        rng = _rng_for(seed, int(idx))
        # piecewise-linear congruential stream => learnable local structure
        start = rng.integers(0, vocab_size)
        steps = rng.integers(1, 7, seq_len + 1)
        tokens[i] = (start + np.cumsum(steps)) % vocab_size
    return {"tokens": tokens[:, :-1].astype(np.int32),
            "targets": tokens[:, 1:].astype(np.int32)}
