"""Deterministic synthetic datasets, addressed by absolute sample index.

Every sample is a pure function of (seed, sample_index) — the property the
paper's dynamic data sharding relies on: a shard reassigned to any worker
after a failure yields byte-identical data, so elasticity cannot disturb the
training data sequence (§5.1 "without any data omission or duplication").

The Criteo-like generator plants a learnable logistic structure so DLRM
training (Fig 8) has a real signal: labels depend on dense features and on a
few "informative" embedding buckets.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.configs.dlrm_models import DLRMConfig


def _rng_for(seed: int, idx_block: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, idx_block]))


# --- Criteo-like CTR samples ----------------------------------------------------
def criteo_batch(cfg: DLRMConfig, seed: int, indices: np.ndarray) -> Dict[str, np.ndarray]:
    """indices: (B,) absolute sample ids -> batch dict (dense/sparse/label)."""
    B = len(indices)
    dense = np.empty((B, cfg.n_dense), np.float32)
    sparse = np.empty((B, cfg.n_tables, cfg.multi_hot), np.int64)
    label = np.empty((B,), np.float32)
    w_dense = np.linspace(-1.0, 1.0, cfg.n_dense).astype(np.float32)
    for i, idx in enumerate(np.asarray(indices)):
        rng = _rng_for(seed, int(idx))
        dense[i] = rng.normal(0, 1, cfg.n_dense).astype(np.float32)
        for t, rows in enumerate(cfg.table_rows):
            sparse[i, t] = rng.integers(0, rows, cfg.multi_hot)
        # informative structure: dense projection + parity of first buckets
        logit = float(dense[i] @ w_dense)
        logit += 0.5 * ((sparse[i, 0, 0] % 2) - 0.5) * 2
        logit += 0.25 * ((sparse[i, 1 % cfg.n_tables, 0] % 4 == 0) - 0.25) * 4
        p = 1.0 / (1.0 + np.exp(-logit))
        label[i] = float(rng.random() < p)
    return {"dense": dense, "sparse": sparse.astype(np.int32), "label": label}


# --- LM token streams -------------------------------------------------------------
def lm_batch(seed: int, indices: np.ndarray, seq_len: int,
             vocab_size: int) -> Dict[str, np.ndarray]:
    """Markov-ish synthetic token stream; deterministic per sample index."""
    B = len(indices)
    tokens = np.empty((B, seq_len + 1), np.int64)
    for i, idx in enumerate(np.asarray(indices)):
        rng = _rng_for(seed, int(idx))
        # piecewise-linear congruential stream => learnable local structure
        start = rng.integers(0, vocab_size)
        steps = rng.integers(1, 7, seq_len + 1)
        tokens[i] = (start + np.cumsum(steps)) % vocab_size
    return {"tokens": tokens[:, :-1].astype(np.int32),
            "targets": tokens[:, 1:].astype(np.int32)}
