"""Shard-queue-driven input pipeline (worker side of dynamic data sharding).

A ``ShardDataLoader`` belongs to one (possibly elastic) worker: it requests
shards from the job master's ``ShardingService``, generates the shard's
samples deterministically, emits fixed-size batches, and reports heartbeats
with progress offsets. If the worker dies, the master requeues its shard and
any replacement worker reproduces exactly the same samples.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Iterator, Optional

import numpy as np

from repro.core.sharding_service import Shard, ShardingService


class ShardDataLoader:
    """``fault_hook(batch_index)`` — if given — runs before each batch is
    built; it is the data-pipeline injection point of
    ``repro.core.faults.FaultInjector.on_batch`` (straggler delays land on
    the ingestion path, where real host-side stalls live)."""

    def __init__(self, service: ShardingService, worker_id: str,
                 batch_fn: Callable[[np.ndarray], Dict[str, np.ndarray]],
                 batch_size: int, *, clock: Callable[[], float] = time.monotonic,
                 heartbeat_every: int = 1,
                 fault_hook: Optional[Callable[[int], None]] = None):
        self.service = service
        self.worker_id = worker_id
        self.batch_fn = batch_fn
        self.batch_size = batch_size
        self.clock = clock
        self.heartbeat_every = heartbeat_every
        self.fault_hook = fault_hook
        self._shard: Optional[Shard] = None
        self._cursor = 0
        self._batches_since_hb = 0
        self._batches_emitted = 0

    # ------------------------------------------------------------------
    def _ensure_shard(self) -> bool:
        if self._shard is not None and self._cursor < self._shard.size:
            return True
        if self._shard is not None:
            self.service.report_done(self.worker_id, self._shard.index, self.clock())
            self._shard = None
        shard = self.service.request_shard(self.worker_id, self.clock())
        if shard is None:
            return False
        self._shard = shard
        self._cursor = 0
        return True

    def next_batch(self) -> Optional[Dict[str, np.ndarray]]:
        """Next batch or None when the dataset is exhausted.

        Batches never span shards; a short tail is padded by wrapping within
        the shard (training-only semantics, keeps shapes static for jit).
        """
        if not self._ensure_shard():
            return None
        if self.fault_hook is not None:
            self.fault_hook(self._batches_emitted)
        self._batches_emitted += 1
        shard = self._shard
        lo = shard.start + self._cursor
        hi = min(lo + self.batch_size, shard.end)
        idx = np.arange(lo, hi)
        if len(idx) < self.batch_size:                    # pad by wrapping
            extra = np.arange(shard.start,
                              shard.start + self.batch_size - len(idx))
            idx = np.concatenate([idx, extra % max(shard.size, 1) + shard.start])
        self._cursor += self.batch_size
        self._batches_since_hb += 1
        if self._batches_since_hb >= self.heartbeat_every:
            progress = min(self._cursor, shard.size)
            self.service.heartbeat(self.worker_id, progress, self.clock())
            self._batches_since_hb = 0
        return self.batch_fn(idx)

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            b = self.next_batch()
            if b is None:
                return
            yield b
