"""Plug-in scheduling-algorithm API (paper §4.3 "Plug-in Algorithm API").

Re-exported from the autoscaler: register a custom cluster-level scaler by
name and select it via ``ClusterBrain(scaler=<name>)``.

    from repro.core.plugin import register_scaler

    @register_scaler("my_scaler")
    def my_scaler(jobs, capacity):
        return {job.job_id: job.current for job in jobs}
"""
from repro.core.autoscaler import (  # noqa: F401
    ScalerFn, get_scaler, list_scalers, register_scaler,
)
