"""Seamless migration (paper §5.2): overlap provisioning with training.

Stop-and-restart (baseline):   pause → ckpt→RDS → provision → load → resume
Seamless (DLRover-RM):         provision ∥ training → pause → flash-ckpt →
                               flash-load → resume

Downtime = only the flash-ckpt save+load window (sub-second for in-memory
tier) instead of the full provision+RDS round trip. The state machine is
clock-driven so the simulator and real integrations share it; real hooks
(save/restore callbacks) plug into ``on_sync``.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass
from typing import Callable, Optional


class Phase(enum.Enum):
    RUNNING = "running"
    PROVISIONING = "provisioning"      # new pods starting; training continues
    SYNC = "sync"                      # paused: checkpoint save + load
    DONE = "done"


@dataclass(frozen=True)
class MigrationTimings:
    """Calibrated from the paper §2.2/§5.2 and Fig 12."""
    provision_s: float = 300.0         # new pod request+image pull+launch (5 min)
    rds_ckpt_save_s: float = 120.0     # checkpoint to remote disk storage
    rds_ckpt_load_s: float = 90.0
    flash_ckpt_save_s: float = 1.0     # in-memory tier (<1 s for 20 GB, §5.2)
    flash_ckpt_load_s: float = 2.0
    # process re-exec on a still-live pod (job-master kill/re-exec path).
    # None = fall back to provision_s (the pre-measurement behavior); the
    # kill-matrix harness fills it with JobMasterReport.measured_timings()
    worker_reexec_s: Optional[float] = None

    def reexec_s(self) -> float:
        """Worker-replacement horizon: measured re-exec when available,
        else the conservative full pod provision."""
        return self.provision_s if self.worker_reexec_s is None \
            else self.worker_reexec_s


@dataclass
class MigrationPlan:
    seamless: bool = True
    use_flash_ckpt: bool = True
    timings: MigrationTimings = MigrationTimings()

    def downtime_seconds(self) -> float:
        t = self.timings
        save = t.flash_ckpt_save_s if self.use_flash_ckpt else t.rds_ckpt_save_s
        load = t.flash_ckpt_load_s if self.use_flash_ckpt else t.rds_ckpt_load_s
        if self.seamless:
            return save + load
        return save + t.provision_s + load

    def total_seconds(self) -> float:
        t = self.timings
        return t.provision_s + self.downtime_seconds() if self.seamless \
            else self.downtime_seconds()


@dataclass
class MigrationSession:
    """Clock-driven migration of one job; training continues in PROVISIONING."""
    plan: MigrationPlan
    started_at: float
    on_sync: Optional[Callable[[], None]] = None     # real ckpt hook
    phase: Phase = Phase.RUNNING
    _sync_started: Optional[float] = None
    downtime_accum: float = 0.0

    def start(self) -> None:
        self.phase = Phase.PROVISIONING if self.plan.seamless else Phase.SYNC
        if self.phase is Phase.SYNC:
            self._sync_started = self.started_at

    def tick(self, now: float) -> Phase:
        t = self.plan.timings
        if self.phase is Phase.PROVISIONING:
            if now - self.started_at >= t.provision_s:
                self.phase = Phase.SYNC
                self._sync_started = now
                if self.on_sync:
                    self.on_sync()
        if self.phase is Phase.SYNC:
            dt = self.plan.downtime_seconds() if self.plan.seamless else \
                self.plan.downtime_seconds()
            assert self._sync_started is not None
            if now - self._sync_started >= dt:
                self.downtime_accum = dt
                self.phase = Phase.DONE
        return self.phase

    @property
    def training_blocked(self) -> bool:
        return self.phase is Phase.SYNC
