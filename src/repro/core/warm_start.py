"""Pre-scaling stage: warm-starting (paper §4.3, Algorithm 1).

Given a new job's metadata, find the top-k most similar historical jobs in
the config DB and exponentially smooth their final resource configurations,
ordered from least to most similar so the most similar job dominates:

    Ā⁰ = A⁰;   Āⁱ = μ·Aⁱ + (1-μ)·Āⁱ⁻¹;   return Ā^{k-1}      (Eqn 10)
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional

from repro.core.perf_model import JobResources


@dataclass(frozen=True)
class JobMeta:
    """Features used for similarity (model metadata, §4.3)."""
    model_kind: str           # e.g. "wide_deep" / "dcn" / "xdeepfm"
    dense_params: float       # dense-part parameter count
    emb_rows: float           # total embedding rows
    emb_dim: int
    batch_size: int
    dataset_samples: float
    user: str = ""


@dataclass
class ConfigRecord:
    meta: JobMeta
    final_config: JobResources
    throughput: float = 0.0
    completed: bool = True


class ConfigDB:
    """Historical job traces (the cluster brain's config DB, §3)."""

    def __init__(self) -> None:
        self.records: List[ConfigRecord] = []

    def add(self, record: ConfigRecord) -> None:
        self.records.append(record)

    def __len__(self) -> int:
        return len(self.records)


_NUMERIC = ("dense_params", "emb_rows", "emb_dim", "batch_size", "dataset_samples")


def similarity(a: JobMeta, b: JobMeta) -> float:
    """Log-scale numeric proximity + categorical agreement, in [0, 1]."""
    score = 0.0
    for name in _NUMERIC:
        va, vb = getattr(a, name), getattr(b, name)
        la, lb = math.log1p(max(va, 0.0)), math.log1p(max(vb, 0.0))
        score += 1.0 - min(abs(la - lb) / max(la, lb, 1e-9), 1.0)
    score /= len(_NUMERIC)
    cat = (0.5 * (a.model_kind == b.model_kind) + 0.5 * (a.user == b.user))
    return 0.7 * score + 0.3 * cat


def _blend(a: JobResources, b: JobResources, mu: float) -> JobResources:
    """μ·a + (1-μ)·b elementwise (the exponential smoothing step ℰ)."""
    mix = lambda x, y: mu * x + (1 - mu) * y
    return JobResources(
        w=max(1, round(mix(a.w, b.w))),
        p=max(1, round(mix(a.p, b.p))),
        cpu_w=mix(a.cpu_w, b.cpu_w),
        cpu_p=mix(a.cpu_p, b.cpu_p),
        mem_w=mix(a.mem_w, b.mem_w),
        mem_p=mix(a.mem_p, b.mem_p),
    )


def warm_start(job: JobMeta, db: ConfigDB, *, k: int = 5, mu: float = 0.5,
               default: Optional[JobResources] = None) -> JobResources:
    """Algorithm 1. Falls back to ``default`` (cold start) on an empty DB."""
    default = default or JobResources(w=2, p=1, cpu_w=4, cpu_p=4)
    if not db.records:
        return default
    scored = sorted(
        ((similarity(job, rec.meta), i, rec) for i, rec in enumerate(db.records)
         if rec.completed),
        key=lambda t: (t[0], -t[1]))
    top = scored[-k:]                       # ascending similarity: A⁰ … A^{k-1}
    if not top:
        return default
    smoothed = top[0][2].final_config       # Ā⁰ = A⁰ (least similar of top-k)
    for _, _, rec in top[1:]:
        smoothed = _blend(rec.final_config, smoothed, mu)   # Āⁱ = μAⁱ+(1-μ)Āⁱ⁻¹
    return smoothed


def warm_start_accuracy(initial: JobResources, final: JobResources) -> float:
    """Paper Fig 9 metric: how close the initial allocation is to the final."""
    pairs = [(initial.w, final.w), (initial.p, final.p),
             (initial.cpu_w, final.cpu_w), (initial.cpu_p, final.cpu_p)]
    accs = [1.0 - abs(a - b) / max(a, b, 1e-9) for a, b in pairs]
    return sum(accs) / len(accs)
