"""Scaling stage (paper §4.2–4.3): RC/TG objectives, NSGA-II candidate
generation, and cluster-level weighted greedy selection.

    RC(A)  = Σ_r a_r · Money(a_r)                            (Eqn 7)
    TG(A)  = ΔΨ_thp − Overhead(A)                            (Eqn 8)
    argmin_A (RC(A), 1/TG(A))                                (Eqn 9)
    RE(Aʲ) = TG(Aʲ)/RC(Aʲ)                                   (Eqn 11)
    argmax Σ_j RE(Aʲ)·WG(Aʲ)  s.t. Σ_j Aʲ ≤ S                (Eqn 12–13)
    WG(Aʲ) = 1 / (Φ_sp/Ψ_thp + ε)^ρ                          (Eqn 14)
"""
from __future__ import annotations

import dataclasses
import zlib
from dataclasses import dataclass
from typing import Callable, Dict, List, Sequence, Tuple

import numpy as np

from repro.core.nsga2 import nsga2
from repro.core.perf_model import JobResources, JobStatics, PerfModel


@dataclass(frozen=True)
class Prices:
    """Money(a_r): unit prices (AWS-style $/h per unit, Table 1 spirit)."""
    cpu: float = 0.02
    mem_gb: float = 0.005


@dataclass(frozen=True)
class ScalingOverheads:
    """Historical scaling-cost statistics (the Overhead(A) estimator, Eqn 8)."""
    worker_start_s: float = 30.0
    ps_start_s: float = 90.0
    per_cpu_s: float = 0.2

    def overhead_seconds(self, old: JobResources, new: JobResources) -> float:
        dw = max(0, new.w - old.w)
        dp = max(0, new.p - old.p)
        dcpu = max(0.0, new.total_cpu() - old.total_cpu())
        return dw * self.worker_start_s + dp * self.ps_start_s + dcpu * self.per_cpu_s


def resource_cost(r: JobResources, prices: Prices) -> float:
    return r.total_cpu() * prices.cpu + r.total_mem() * prices.mem_gb   # Eqn 7


@dataclass
class PlanCandidate:
    job_id: str
    resources: JobResources
    rc: float                 # resource cost of the new allocation
    tg: float                 # throughput gain net of scaling overhead
    thp: float                # predicted absolute throughput

    @property
    def re(self) -> float:                                            # Eqn 11
        return self.tg / max(self.rc, 1e-9)


@dataclass
class JobState:
    """What the cluster brain knows about one running job.

    ``degradation`` is the stage-3 penalty signal Φ_sp (Eqn 14): an
    exponentially-decayed count of recent instability events (failures,
    stragglers, hot PSes, OOMs) reported back by the supervisor/simulator.
    Degraded jobs get a larger WG weight so the weighted greedy rescues
    them first — the deliverable-guarantee feedback loop of §4.3.
    """
    job_id: str
    statics: JobStatics
    current: JobResources
    model: PerfModel
    remaining_samples: float
    priority_rho: float = 2.5
    degradation: float = 0.0


def job_seed(job_id: str) -> int:
    """Process-stable per-job RNG seed (``hash(str)`` is salted per process,
    which silently broke cross-run reproducibility of the NSGA-II search)."""
    return zlib.crc32(job_id.encode()) % 2**31


BOUNDS = dict(w=(1, 32), p=(1, 16), cpu_w=(1, 32), cpu_p=(1, 32))
MAX_JOB_CPU = 256.0        # per-job quota (matches cluster policy)


def _vec_to_resources(x: np.ndarray, like: JobResources) -> JobResources:
    return dataclasses.replace(
        like, w=int(x[0]), p=int(x[1]), cpu_w=float(x[2]), cpu_p=float(x[3]))


def generate_candidates(job: JobState, *, prices: Prices = Prices(),
                        overheads: ScalingOverheads = ScalingOverheads(),
                        horizon_s: float = 600.0,
                        pop_size: int = 40, generations: int = 25,
                        seed: int = 0,
                        trust_factor: float = 0.0) -> List[PlanCandidate]:
    """Job-level NSGA-II over (RC, 1/TG) — the Pareto frontier of Eqn 9.

    ``trust_factor`` > 1 restricts the search box to a multiplicative trust
    region around the current allocation (each variable within
    ``[v/trust_factor, v·trust_factor]``): the NNLS model is fitted on
    observations near the operating point, so a plan far outside it rides on
    pure extrapolation — gradual re-centered steps are how the controller
    stays inside the region the model has earned.
    """
    base_thp = job.model.throughput(job.current, job.statics)

    def objectives(x: np.ndarray) -> Tuple[float, float]:
        r = _vec_to_resources(x, job.current)
        rc = resource_cost(r, prices)
        if r.total_cpu() > MAX_JOB_CPU:                   # per-job quota
            return rc * 100.0, 1e9
        thp = job.model.throughput(r, job.statics)
        # Overhead converted to samples over the decision horizon (Eqn 8)
        ovh = overheads.overhead_seconds(job.current, r) * base_thp / horizon_s
        tg = (thp - base_thp) - ovh
        return rc, 1.0 / max(tg, 1e-6)

    bounds = [BOUNDS["w"], BOUNDS["p"], BOUNDS["cpu_w"], BOUNDS["cpu_p"]]
    x0 = np.array([job.current.w, job.current.p, job.current.cpu_w,
                   job.current.cpu_p], float)
    if trust_factor > 1.0:
        bounds = [(max(lo, v / trust_factor), min(hi, v * trust_factor))
                  for (lo, hi), v in zip(bounds, x0)]
    seeds = [x0, x0 * 2, x0 * 0.5,
             x0 * np.array([2, 1, 1, 1]), x0 * np.array([1, 2, 1, 1]),
             x0 * np.array([1, 1, 2, 1]), x0 * np.array([1, 1, 1, 2]),
             x0 * np.array([2, 2, 1, 1]), x0 * np.array([4, 4, 1, 1])]
    front = nsga2(objectives, bounds, pop_size=pop_size,
                  generations=generations, seed=seed, init=seeds)
    out = []
    for x, f in front:
        r = _vec_to_resources(x, job.current)
        thp = job.model.throughput(r, job.statics)
        ovh = overheads.overhead_seconds(job.current, r) * base_thp / horizon_s
        out.append(PlanCandidate(job.job_id, r, rc=f[0],
                                 tg=(thp - base_thp) - ovh, thp=thp))
    return out


def weight_wg(job: JobState, thp: float, *, eps: float = 1e-6) -> float:
    """Eqn 14: prioritize shorter-remaining jobs (ρ=2.5 at AntGroup).

    The stage-3 degradation penalty Φ_sp enters multiplicatively: a job that
    recently lost pods / hit stragglers / OOMed has its weight boosted by
    ``1 + degradation`` so capacity flows to rescuing it before it misses
    its deliverable deadline.
    """
    remaining_time = job.remaining_samples / max(thp, 1e-9)
    boost = 1.0 + max(job.degradation, 0.0)
    return boost / ((remaining_time + eps) ** job.priority_rho)


@dataclass
class ClusterCapacity:
    total_cpu: float
    total_mem_gb: float


def predicted_idle_frac(job: JobState, r: JobResources) -> float:
    """Model-predicted fraction of a plan's CPU that would sit idle.

    Busy fractions follow the Eqn 2–5 decomposition: workers are busy for the
    T_grad share of an iteration, PSes for the T_upd + T_emb share. What's
    left is reserved-but-idle CPU — the §2.2 waste the utilization claim of
    Fig 14 is about."""
    br = job.model.term_breakdown(r, job.statics)
    t_iter = max(sum(br.values()), 1e-9)
    fw = min(br["grad"] / t_iter, 1.0)
    fp = min((br["upd"] + br["emb"]) / t_iter, 1.0)
    total = max(r.total_cpu(), 1e-9)
    busy = (r.w * r.cpu_w * fw + r.p * r.cpu_p * fp) / total
    return float(min(max(1.0 - busy, 0.0), 1.0))


def weighted_greedy_select(jobs: Sequence[JobState],
                           candidates: Dict[str, List[PlanCandidate]],
                           capacity: ClusterCapacity, *,
                           idle_penalty: float = 0.0
                           ) -> Dict[str, JobResources]:
    """Eqns 12–13: pick ≤1 plan per job maximizing Σ RE·WG within capacity.

    Greedy by score density; jobs keep their current allocation when no
    candidate fits (current allocations are charged against capacity first).
    ``idle_penalty`` > 0 inflates a candidate's effective resource cost by
    ``1 + idle_penalty · predicted_idle_frac`` — money prices alone make idle
    PS cores look cheap, so a utilization-aware operator charges reservations
    the model predicts will not be used.
    """
    jmap = {j.job_id: j for j in jobs}
    used_cpu = sum(j.current.total_cpu() for j in jobs)
    used_mem = sum(j.current.total_mem() for j in jobs)

    scored: List[Tuple[float, PlanCandidate]] = []
    for jid, cands in candidates.items():
        job = jmap[jid]
        for c in cands:
            if c.tg <= 0:
                continue
            re = c.re
            if idle_penalty > 0.0:
                re /= 1.0 + idle_penalty * predicted_idle_frac(job, c.resources)
            scored.append((re * weight_wg(job, c.thp), c))
    scored.sort(key=lambda t: -t[0])

    plans: Dict[str, JobResources] = {}
    for score, cand in scored:
        if cand.job_id in plans:
            continue
        job = jmap[cand.job_id]
        dcpu = cand.resources.total_cpu() - job.current.total_cpu()
        dmem = cand.resources.total_mem() - job.current.total_mem()
        if used_cpu + dcpu <= capacity.total_cpu and \
           used_mem + dmem <= capacity.total_mem_gb:
            plans[cand.job_id] = cand.resources
            used_cpu += dcpu
            used_mem += dmem
    return plans


# --- plug-in algorithm API (paper §4.3 "Plug-in Algorithm API") -----------------
ScalerFn = Callable[[Sequence[JobState], ClusterCapacity], Dict[str, JobResources]]
_REGISTRY: Dict[str, ScalerFn] = {}


def register_scaler(name: str):
    def deco(fn: ScalerFn) -> ScalerFn:
        _REGISTRY[name] = fn
        return fn
    return deco


def get_scaler(name: str) -> ScalerFn:
    return _REGISTRY[name]


def list_scalers() -> List[str]:
    return sorted(_REGISTRY)


@register_scaler("dlrover_rm")
def dlrover_rm_scaler(jobs: Sequence[JobState],
                      capacity: ClusterCapacity) -> Dict[str, JobResources]:
    """Stage-2 auto-scaling: per-job NSGA-II + cluster weighted greedy."""
    candidates = {j.job_id: generate_candidates(j, seed=job_seed(j.job_id))
                  for j in jobs if j.model.fitted}
    return weighted_greedy_select(jobs, candidates, capacity)
