"""Scaling stage (paper §4.2–4.3): RC/TG objectives, NSGA-II candidate
generation, and cluster-level weighted greedy selection.

    RC(A)  = Σ_r a_r · Money(a_r)                            (Eqn 7)
    TG(A)  = ΔΨ_thp − Overhead(A)                            (Eqn 8)
    argmin_A (RC(A), 1/TG(A))                                (Eqn 9)
    RE(Aʲ) = TG(Aʲ)/RC(Aʲ)                                   (Eqn 11)
    argmax Σ_j RE(Aʲ)·WG(Aʲ)  s.t. Σ_j Aʲ ≤ S                (Eqn 12–13)
    WG(Aʲ) = 1 / (Φ_sp/Ψ_thp + ε)^ρ                          (Eqn 14)
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Callable, Dict, List, Sequence, Tuple

import numpy as np

from repro.core.nsga2 import nsga2
from repro.core.perf_model import JobResources, JobStatics, PerfModel


@dataclass(frozen=True)
class Prices:
    """Money(a_r): unit prices (AWS-style $/h per unit, Table 1 spirit)."""
    cpu: float = 0.02
    mem_gb: float = 0.005


@dataclass(frozen=True)
class ScalingOverheads:
    """Historical scaling-cost statistics (the Overhead(A) estimator, Eqn 8)."""
    worker_start_s: float = 30.0
    ps_start_s: float = 90.0
    per_cpu_s: float = 0.2

    def overhead_seconds(self, old: JobResources, new: JobResources) -> float:
        dw = max(0, new.w - old.w)
        dp = max(0, new.p - old.p)
        dcpu = max(0.0, new.total_cpu() - old.total_cpu())
        return dw * self.worker_start_s + dp * self.ps_start_s + dcpu * self.per_cpu_s


def resource_cost(r: JobResources, prices: Prices) -> float:
    return r.total_cpu() * prices.cpu + r.total_mem() * prices.mem_gb   # Eqn 7


@dataclass
class PlanCandidate:
    job_id: str
    resources: JobResources
    rc: float                 # resource cost of the new allocation
    tg: float                 # throughput gain net of scaling overhead
    thp: float                # predicted absolute throughput

    @property
    def re(self) -> float:                                            # Eqn 11
        return self.tg / max(self.rc, 1e-9)


@dataclass
class JobState:
    """What the cluster brain knows about one running job."""
    job_id: str
    statics: JobStatics
    current: JobResources
    model: PerfModel
    remaining_samples: float
    priority_rho: float = 2.5


BOUNDS = dict(w=(1, 32), p=(1, 16), cpu_w=(1, 32), cpu_p=(1, 32))
MAX_JOB_CPU = 256.0        # per-job quota (matches cluster policy)


def _vec_to_resources(x: np.ndarray, like: JobResources) -> JobResources:
    return dataclasses.replace(
        like, w=int(x[0]), p=int(x[1]), cpu_w=float(x[2]), cpu_p=float(x[3]))


def generate_candidates(job: JobState, *, prices: Prices = Prices(),
                        overheads: ScalingOverheads = ScalingOverheads(),
                        horizon_s: float = 600.0,
                        pop_size: int = 40, generations: int = 25,
                        seed: int = 0) -> List[PlanCandidate]:
    """Job-level NSGA-II over (RC, 1/TG) — the Pareto frontier of Eqn 9."""
    base_thp = job.model.throughput(job.current, job.statics)

    def objectives(x: np.ndarray) -> Tuple[float, float]:
        r = _vec_to_resources(x, job.current)
        rc = resource_cost(r, prices)
        if r.total_cpu() > MAX_JOB_CPU:                   # per-job quota
            return rc * 100.0, 1e9
        thp = job.model.throughput(r, job.statics)
        # Overhead converted to samples over the decision horizon (Eqn 8)
        ovh = overheads.overhead_seconds(job.current, r) * base_thp / horizon_s
        tg = (thp - base_thp) - ovh
        return rc, 1.0 / max(tg, 1e-6)

    bounds = [BOUNDS["w"], BOUNDS["p"], BOUNDS["cpu_w"], BOUNDS["cpu_p"]]
    x0 = np.array([job.current.w, job.current.p, job.current.cpu_w,
                   job.current.cpu_p], float)
    seeds = [x0, x0 * 2, x0 * 0.5,
             x0 * np.array([2, 1, 1, 1]), x0 * np.array([1, 2, 1, 1]),
             x0 * np.array([1, 1, 2, 1]), x0 * np.array([1, 1, 1, 2]),
             x0 * np.array([2, 2, 1, 1]), x0 * np.array([4, 4, 1, 1])]
    front = nsga2(objectives, bounds, pop_size=pop_size,
                  generations=generations, seed=seed, init=seeds)
    out = []
    for x, f in front:
        r = _vec_to_resources(x, job.current)
        thp = job.model.throughput(r, job.statics)
        ovh = overheads.overhead_seconds(job.current, r) * base_thp / horizon_s
        out.append(PlanCandidate(job.job_id, r, rc=f[0],
                                 tg=(thp - base_thp) - ovh, thp=thp))
    return out


def weight_wg(job: JobState, thp: float, *, eps: float = 1e-6) -> float:
    """Eqn 14: prioritize shorter-remaining jobs (ρ=2.5 at AntGroup)."""
    remaining_time = job.remaining_samples / max(thp, 1e-9)
    return 1.0 / ((remaining_time + eps) ** job.priority_rho)


@dataclass
class ClusterCapacity:
    total_cpu: float
    total_mem_gb: float


def weighted_greedy_select(jobs: Sequence[JobState],
                           candidates: Dict[str, List[PlanCandidate]],
                           capacity: ClusterCapacity
                           ) -> Dict[str, JobResources]:
    """Eqns 12–13: pick ≤1 plan per job maximizing Σ RE·WG within capacity.

    Greedy by score density; jobs keep their current allocation when no
    candidate fits (current allocations are charged against capacity first).
    """
    jmap = {j.job_id: j for j in jobs}
    used_cpu = sum(j.current.total_cpu() for j in jobs)
    used_mem = sum(j.current.total_mem() for j in jobs)

    scored: List[Tuple[float, PlanCandidate]] = []
    for jid, cands in candidates.items():
        job = jmap[jid]
        for c in cands:
            if c.tg <= 0:
                continue
            scored.append((c.re * weight_wg(job, c.thp), c))
    scored.sort(key=lambda t: -t[0])

    plans: Dict[str, JobResources] = {}
    for score, cand in scored:
        if cand.job_id in plans:
            continue
        job = jmap[cand.job_id]
        dcpu = cand.resources.total_cpu() - job.current.total_cpu()
        dmem = cand.resources.total_mem() - job.current.total_mem()
        if used_cpu + dcpu <= capacity.total_cpu and \
           used_mem + dmem <= capacity.total_mem_gb:
            plans[cand.job_id] = cand.resources
            used_cpu += dcpu
            used_mem += dmem
    return plans


# --- plug-in algorithm API (paper §4.3 "Plug-in Algorithm API") -----------------
ScalerFn = Callable[[Sequence[JobState], ClusterCapacity], Dict[str, JobResources]]
_REGISTRY: Dict[str, ScalerFn] = {}


def register_scaler(name: str):
    def deco(fn: ScalerFn) -> ScalerFn:
        _REGISTRY[name] = fn
        return fn
    return deco


def get_scaler(name: str) -> ScalerFn:
    return _REGISTRY[name]


def list_scalers() -> List[str]:
    return sorted(_REGISTRY)


@register_scaler("dlrover_rm")
def dlrover_rm_scaler(jobs: Sequence[JobState],
                      capacity: ClusterCapacity) -> Dict[str, JobResources]:
    """Stage-2 auto-scaling: per-job NSGA-II + cluster weighted greedy."""
    candidates = {j.job_id: generate_candidates(j, seed=hash(j.job_id) % 2**31)
                  for j in jobs if j.model.fitted}
    return weighted_greedy_select(jobs, candidates, capacity)
