"""Resource–performance model (paper §4.1, Eqns 1–6) + online NNLS fitting.

Iteration time decomposes into

    T_grad = α_grad · m/λ_w + β_grad                         (Eqn 2)
    T_upd  = α_upd  · w/(p·λ_p) + β_upd                      (Eqn 3)
    T_sync = α_sync · (M/p)/(B/w) + β_sync                   (Eqn 4)
    T_emb  = α_emb  · m·D/p + β_emb                          (Eqn 5)

    Ψ_thp  = w·m / (T_comp + T_comm)                         (Eqn 1)

All α, β ≥ 0. The four β's share a constant feature column, so only their sum
is identifiable — the paper itself reports "2.45 for the sum of β". Fitting
minimizes relative error (a first-order proxy for the paper's RMSLE) via
non-negative least squares on rows scaled by 1/T (SciPy NNLS [4]).

On the TPU mesh the same algebra holds with w ↔ data-axis size, p ↔ model-axis
size, λ ↔ chips per node, B ↔ ICI bandwidth (see DESIGN.md §2).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple

import numpy as np
from scipy.optimize import nnls


@dataclass(frozen=True)
class JobResources:
    """One resource allocation A (decision variables of §4.2)."""
    w: int            # number of workers
    p: int            # number of parameter servers
    cpu_w: float      # λ_w: CPU cores per worker
    cpu_p: float      # λ_p: CPU cores per PS
    mem_w: float = 8.0   # GB per worker
    mem_p: float = 16.0  # GB per PS

    def total_cpu(self) -> float:
        return self.w * self.cpu_w + self.p * self.cpu_p

    def total_mem(self) -> float:
        return self.w * self.mem_w + self.p * self.mem_p


@dataclass(frozen=True)
class JobStatics:
    """Per-job constants of the model."""
    batch_size: int      # m (fixed during training, §4.1)
    model_size: float    # M: dense-part parameter bytes (network traffic unit)
    bandwidth: float     # B: per-worker NIC / ICI bandwidth (bytes/s)
    emb_dim: float       # D: embedding dimension (Eqn 5)


FEATURES = ("grad", "upd", "sync", "emb")


def feature_vector(r: JobResources, s: JobStatics) -> np.ndarray:
    m = s.batch_size
    return np.array([
        m / max(r.cpu_w, 1e-9),                              # T_grad slope
        r.w / max(r.p * r.cpu_p, 1e-9),                      # T_upd slope
        (s.model_size / max(r.p, 1)) / (s.bandwidth / max(r.w, 1)),  # T_sync
        m * s.emb_dim / max(r.p, 1),                         # T_emb slope
        1.0,                                                  # Σβ
    ])


@dataclass
class PerfModel:
    alpha: np.ndarray = field(default_factory=lambda: np.zeros(4))
    beta_sum: float = 0.0
    fitted: bool = False

    # --------------------------------------------------------------- predict
    def t_iter(self, r: JobResources, s: JobStatics) -> float:
        x = feature_vector(r, s)
        coef = np.concatenate([self.alpha, [self.beta_sum]])
        return float(x @ coef)

    def throughput(self, r: JobResources, s: JobStatics) -> float:
        t = self.t_iter(r, s)
        if t <= 0:
            return 0.0
        return r.w * s.batch_size / t                         # Eqn 1

    def term_breakdown(self, r: JobResources, s: JobStatics) -> Dict[str, float]:
        x = feature_vector(r, s)
        return {name: float(self.alpha[i] * x[i]) for i, name in enumerate(FEATURES)} | {
            "beta": self.beta_sum}

    # ------------------------------------------------------------------- fit
    def fit(self, observations: Sequence[Tuple[JobResources, JobStatics, float]]
            ) -> "PerfModel":
        """observations: (resources, statics, measured T_iter seconds)."""
        if len(observations) < 2:
            return self
        X = np.stack([feature_vector(r, s) for r, s, _ in observations])
        t = np.array([max(ti, 1e-9) for _, _, ti in observations])
        # relative-error weighting ≈ RMSLE for small errors
        Xw = X / t[:, None]
        yw = np.ones_like(t)
        try:
            coef, _ = nnls(Xw, yw)
        except (np.linalg.LinAlgError, RuntimeError):
            # newer scipy raises LinAlgError on singular systems (e.g. all
            # observations at the same resource point); fall back to a
            # minimum-norm least-squares fit clipped to the NNLS domain
            coef, *_ = np.linalg.lstsq(Xw, yw, rcond=None)
            coef = np.clip(coef, 0.0, None)
        self.alpha = coef[:4]
        self.beta_sum = float(coef[4])
        self.fitted = True
        return self

    def rmsle(self, observations) -> float:
        errs = []
        for r, s, ti in observations:
            pred = max(self.t_iter(r, s), 1e-9)
            errs.append((np.log1p(pred) - np.log1p(max(ti, 1e-9))) ** 2)
        return float(np.sqrt(np.mean(errs))) if errs else float("nan")


def synthesize_t_iter(r: JobResources, s: JobStatics, alpha: Sequence[float],
                      beta_sum: float, noise: float = 0.0,
                      rng: Optional[np.random.Generator] = None) -> float:
    """Ground-truth generator for tests/simulator (same algebra as the model)."""
    x = feature_vector(r, s)
    t = float(x @ np.concatenate([np.asarray(alpha, float), [beta_sum]]))
    if noise and rng is not None:
        t *= float(rng.lognormal(0.0, noise))
    return max(t, 1e-6)
