"""Cluster brain + job master: the three-stage controller (paper §3–§4, Fig 4).

ClusterBrain = optimizer + config DB (cluster level). JobMaster = profiler +
executor (job level). The three stages:

  ① **allocate** — a new job's ``JobResources`` is warm-started from the
     config-DB similarity search (Eqn 10) and then *refined* against the
     kind-level performance model fitted on completed-job history: a small
     deterministic grid around the warm-start plan keeps the allocation only
     if the model predicts better throughput per dollar (§4.3 Algorithm 1).
  ② **adjust** — periodic profiles → online NNLS fit (Eqns 1–6) → per-job
     NSGA-II over (RC, 1/TG) (Eqns 7–9) → cluster-level weighted greedy
     selection under the shared capacity vector (Eqns 11–14). Pareto fronts
     are re-searched on a staggered cadence and cached in between.
  ③ **guarantee** — instability signals (pod failures, stragglers, hot
     PSes, OOMs) reported by the supervisor/simulator feed an exponentially
     decayed per-job degradation penalty Φ_sp that boosts the job's WG
     weight (Eqn 14), plus predictive PS-memory scale-ups (§5.3).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.autoscaler import (
    BOUNDS, MAX_JOB_CPU, ClusterCapacity, JobState, PlanCandidate, Prices,
    ScalingOverheads, generate_candidates, get_scaler, job_seed, resource_cost,
    weighted_greedy_select,
)
from repro.core.oom import OOMPredictor
from repro.core.perf_model import JobResources, JobStatics, PerfModel
from repro.core.sharding_service import ShardingService
from repro.core.warm_start import ConfigDB, ConfigRecord, JobMeta, warm_start

Observation = Tuple[JobResources, JobStatics, float]

DEFAULT_RESOURCES = JobResources(w=2, p=1, cpu_w=4, cpu_p=4)

#: Relative severity of stage-3 instability events (OOM restarts lose the
#: most progress; stragglers/hot-PSes degrade but do not restart).
DEGRADATION_WEIGHTS: Dict[str, float] = {
    "oom": 2.0,
    "failure": 1.0,
    "straggler": 0.5,
    "hot_ps": 0.5,
}


@dataclass
class Profiler:
    """Job-level runtime collection (reported to the brain periodically)."""
    statics: JobStatics
    observations: List[Observation] = field(default_factory=list)
    oom: OOMPredictor = field(default_factory=OOMPredictor)
    max_obs: int = 256

    def record_iteration(self, resources: JobResources, t_iter: float) -> None:
        self.observations.append((resources, self.statics, t_iter))
        if len(self.observations) > self.max_obs:
            self.observations.pop(0)

    def record_memory(self, samples_consumed: float, mem_bytes: float) -> None:
        self.oom.observe(samples_consumed, mem_bytes)


@dataclass
class JobMaster:
    """One per job: owns the shard queue, profiler and executor hook."""
    job_id: str
    meta: JobMeta
    statics: JobStatics
    resources: JobResources
    total_samples: float
    sharding: ShardingService
    profiler: Profiler
    apply_plan: Optional[Callable[[JobResources], None]] = None
    samples_done: float = 0.0
    model: PerfModel = field(default_factory=PerfModel)

    def refit(self) -> None:
        if len(self.profiler.observations) >= 4:
            self.model.fit(self.profiler.observations)

    def job_state(self, rho: float = 2.5, degradation: float = 0.0) -> JobState:
        return JobState(
            job_id=self.job_id, statics=self.statics, current=self.resources,
            model=self.model,
            remaining_samples=max(self.total_samples - self.samples_done, 0.0),
            priority_rho=rho, degradation=degradation)

    def execute(self, plan: JobResources) -> None:
        self.resources = plan
        if self.apply_plan:
            self.apply_plan(plan)


@dataclass
class DegradationState:
    """Stage-3 per-job penalty Φ_sp: exponentially decayed event mass."""
    penalty: float = 0.0
    events: int = 0
    last_event_s: float = 0.0


def refine_allocation(plan: JobResources, statics: JobStatics,
                      model: PerfModel, *, prices: Prices = Prices(),
                      min_gain: float = 1.10) -> JobResources:
    """Stage-1 model refinement: deterministic grid around the warm start.

    Evaluates ×½/×1/×2 steps of each decision variable against the fitted
    kind-level model and moves only if predicted throughput-per-dollar
    improves by ≥ ``min_gain`` (the warm start already encodes history; the
    model earns overrides, it doesn't get them for free).
    """
    def score(r: JobResources) -> float:
        return model.throughput(r, statics) / max(resource_cost(r, prices), 1e-9)

    def clip(v: float, lo_hi: Tuple[float, float]) -> float:
        return min(max(v, lo_hi[0]), lo_hi[1])

    best, best_score = plan, score(plan) * min_gain
    for fw in (0.5, 1.0, 2.0):
        for fp in (0.5, 1.0, 2.0):
            for fcw in (0.5, 1.0, 2.0):
                for fcp in (0.5, 1.0, 2.0):
                    cand = dataclasses.replace(
                        plan,
                        w=int(round(clip(plan.w * fw, BOUNDS["w"]))),
                        p=int(round(clip(plan.p * fp, BOUNDS["p"]))),
                        cpu_w=clip(plan.cpu_w * fcw, BOUNDS["cpu_w"]),
                        cpu_p=clip(plan.cpu_p * fcp, BOUNDS["cpu_p"]))
                    if cand.total_cpu() > MAX_JOB_CPU:
                        continue
                    s = score(cand)
                    if s > best_score:
                        best, best_score = cand, s
    return best


def reclaim_allocation(plan: JobResources, statics: JobStatics,
                       model: PerfModel, *, prices: Prices = Prices(),
                       slack: float = 0.03, min_cut: float = 0.15
                       ) -> Optional[JobResources]:
    """Stage-2 right-sizing: the cheapest nearby config that keeps throughput.

    The weighted greedy only *grows* jobs (it requires a positive throughput
    gain), so over-provisioned allocations — the §2.2 regime the paper's
    +15 % CPU-utilization claim comes from — would never shrink without this
    pass. A deterministic shrink grid (fractional steps of each decision
    variable) is scored against the fitted model; a config is returned only
    if it cuts resource cost by ≥ ``min_cut`` while predicted throughput
    stays within ``slack`` of the current plan's.
    """
    base_thp = model.throughput(plan, statics)
    if base_thp <= 0.0:
        return None
    best: Optional[JobResources] = None
    best_cost = resource_cost(plan, prices) * (1.0 - min_cut)

    def clip(v: float, lo_hi: Tuple[float, float]) -> float:
        return min(max(v, lo_hi[0]), lo_hi[1])

    for fw in (0.75, 1.0):
        for fp in (0.5, 1.0):
            for fcw in (0.25, 0.5, 0.75, 1.0):
                for fcp in (0.5, 0.75, 1.0):
                    cand = dataclasses.replace(
                        plan,
                        w=max(int(round(clip(plan.w * fw, BOUNDS["w"]))), 1),
                        p=max(int(round(clip(plan.p * fp, BOUNDS["p"]))), 1),
                        cpu_w=clip(plan.cpu_w * fcw, BOUNDS["cpu_w"]),
                        cpu_p=clip(plan.cpu_p * fcp, BOUNDS["cpu_p"]))
                    cost = resource_cost(cand, prices)
                    if cost >= best_cost:
                        continue
                    if model.throughput(cand, statics) >= (1.0 - slack) * base_thp:
                        best, best_cost = cand, cost
    return best


class ClusterBrain:
    """The cluster-level controller; all three stages are methods here."""

    def __init__(self, capacity: ClusterCapacity, *,
                 scaler: str = "dlrover_rm",
                 prices: Prices = Prices(),
                 overheads: ScalingOverheads = ScalingOverheads(),
                 degradation_halflife_s: float = 1800.0,
                 reoptimize_every: int = 2,
                 nsga_pop: int = 24, nsga_generations: int = 12,
                 reclaim_slack: float = 0.03, reclaim_min_cut: float = 0.15,
                 reclaim_cooldown: int = 3, idle_penalty: float = 1.0,
                 trust_factor: float = 2.0):
        self.capacity = capacity
        self.config_db = ConfigDB()
        self.scaler_name = scaler
        self.prices = prices
        self.overheads = overheads
        self.masters: Dict[str, JobMaster] = {}
        # stage-1 history: pooled observations + fitted model per model kind
        self.kind_models: Dict[str, PerfModel] = {}
        self._kind_obs: Dict[str, List[Observation]] = {}
        # stage-2 staggered NSGA-II cache
        self.reoptimize_every = reoptimize_every
        self.nsga_pop = nsga_pop
        self.nsga_generations = nsga_generations
        self._round = 0
        self._optimized_at: Dict[str, int] = {}
        self._cached: Dict[str, List[PlanCandidate]] = {}
        # stage-2 right-sizing (reclaim) knobs + anti-thrash ledger
        self.reclaim_slack = reclaim_slack
        self.reclaim_min_cut = reclaim_min_cut
        self.reclaim_cooldown = reclaim_cooldown
        self.idle_penalty = idle_penalty
        self.trust_factor = trust_factor
        self._last_plan_round: Dict[str, int] = {}
        # stage-3 degradation ledger
        self.degradation_halflife_s = degradation_halflife_s
        self._degradation: Dict[str, DegradationState] = {}

    # ---------------------------------------------------------- stage 1
    def allocate(self, meta: JobMeta, statics: Optional[JobStatics] = None, *,
                 default: Optional[JobResources] = None,
                 k: int = 5, mu: float = 0.5) -> JobResources:
        """Warm-start a new job's resources, refined by the kind model."""
        plan = warm_start(meta, self.config_db, k=k, mu=mu,
                          default=default or DEFAULT_RESOURCES)
        model = self.kind_models.get(meta.model_kind)
        if model is not None and model.fitted and statics is not None:
            plan = refine_allocation(plan, statics, model, prices=self.prices)
        return plan

    def admit(self, master: JobMaster, *, k: int = 5, mu: float = 0.5
              ) -> JobResources:
        plan = self.allocate(master.meta, master.statics,
                             default=master.resources, k=k, mu=mu)
        master.execute(plan)
        self.masters[master.job_id] = master
        return plan

    # ---------------------------------------------------------- stage 2
    def adjust(self, jobs: Sequence[JobState], *, now: float = 0.0
               ) -> Dict[str, JobResources]:
        """Per-job NSGA-II (staggered, cached) + cluster weighted greedy.

        Mutates each ``JobState.degradation`` to the current stage-3 penalty
        before selection so Eqn 14's WG weights see it.
        """
        self._round += 1
        for j in jobs:
            j.degradation = self.degradation_penalty(j.job_id, now)
        candidates: Dict[str, List[PlanCandidate]] = {}
        for j in jobs:
            if not j.model.fitted:
                continue
            last = self._optimized_at.get(j.job_id)
            if last is None or self._round - last >= self.reoptimize_every:
                self._cached[j.job_id] = generate_candidates(
                    j, seed=job_seed(j.job_id), prices=self.prices,
                    overheads=self.overheads,
                    pop_size=self.nsga_pop, generations=self.nsga_generations,
                    trust_factor=self.trust_factor)
                self._optimized_at[j.job_id] = self._round
            candidates[j.job_id] = self._cached.get(j.job_id, [])
        plans = weighted_greedy_select(jobs, candidates, self.capacity,
                                       idle_penalty=self.idle_penalty)
        # right-sizing reclaim: jobs the greedy left alone give back resources
        # the model says they cannot convert into throughput (a cooldown keeps
        # shrink/regrow cycles from thrashing the same job every round)
        for j in jobs:
            jid = j.job_id
            if jid in plans:
                self._last_plan_round[jid] = self._round
                continue
            if not j.model.fitted:
                continue
            last = self._last_plan_round.get(jid)
            if last is not None and self._round - last < self.reclaim_cooldown:
                continue
            cand = reclaim_allocation(
                j.current, j.statics, j.model, prices=self.prices,
                slack=self.reclaim_slack, min_cut=self.reclaim_min_cut)
            if cand is not None:
                plans[jid] = cand
                self._last_plan_round[jid] = self._round
        return plans

    def optimize(self, now: float = 0.0) -> Dict[str, JobResources]:
        for m in self.masters.values():
            m.refit()
        jobs = [m.job_state(degradation=self.degradation_penalty(m.job_id, now))
                for m in self.masters.values()]
        if self.scaler_name == "dlrover_rm":
            plans = self.adjust(jobs, now=now)
        else:
            plans = get_scaler(self.scaler_name)(jobs, self.capacity)
        for jid, plan in plans.items():
            self.masters[jid].execute(plan)
        return plans

    # ---------------------------------------------------------- stage 3
    def report_degradation(self, job_id: str, kind: str,
                           now: float = 0.0) -> float:
        """Fold one instability event into the job's penalty Φ_sp."""
        weight = DEGRADATION_WEIGHTS.get(kind, 1.0)
        st = self._degradation.setdefault(job_id, DegradationState())
        st.penalty = self._decayed(st, now) + weight
        st.events += 1
        st.last_event_s = now
        return st.penalty

    def degradation_penalty(self, job_id: str, now: float = 0.0) -> float:
        st = self._degradation.get(job_id)
        return 0.0 if st is None else self._decayed(st, now)

    def _decayed(self, st: DegradationState, now: float) -> float:
        age = max(now - st.last_event_s, 0.0)
        return st.penalty * 0.5 ** (age / max(self.degradation_halflife_s, 1e-9))

    def check_oom(self, now: float = 0.0) -> Dict[str, float]:
        """Predictive PS memory scale-ups (GB) per job."""
        out: Dict[str, float] = {}
        for jid, m in self.masters.items():
            remaining = max(m.total_samples - m.samples_done, 0.0)
            capacity_bytes = m.resources.p * m.resources.mem_p * 1e9
            hit, peak = m.profiler.oom.will_oom(capacity_bytes, remaining)
            if hit and peak is not None:
                rec = m.profiler.oom.recommended_capacity(remaining)
                new_mem_p = max(rec / m.resources.p / 1e9, m.resources.mem_p)
                m.execute(dataclasses.replace(m.resources, mem_p=new_mem_p))
                self.report_degradation(jid, "oom", now)
                out[jid] = new_mem_p
        return out

    # ---------------------------------------------------------- completion
    def record_history(self, meta: JobMeta, statics: JobStatics,
                       observations: Sequence[Observation],
                       final_config: Optional[JobResources] = None,
                       throughput: float = 0.0) -> None:
        """Feed one finished job into stage-1 history: the config DB for the
        similarity warm start and the pooled kind-level perf-model fit."""
        if final_config is not None:
            self.config_db.add(ConfigRecord(
                meta=meta, final_config=final_config, throughput=throughput))
        pool = self._kind_obs.setdefault(meta.model_kind, [])
        pool.extend(observations[-32:])
        del pool[:-256]
        if len(pool) >= 8:
            self.kind_models[meta.model_kind] = PerfModel().fit(pool)

    def complete(self, job_id: str, throughput: float) -> None:
        m = self.masters.pop(job_id, None)
        self._degradation.pop(job_id, None)
        self._optimized_at.pop(job_id, None)
        self._cached.pop(job_id, None)
        self._last_plan_round.pop(job_id, None)
        if m is not None:
            self.record_history(m.meta, m.statics, m.profiler.observations,
                                final_config=m.resources, throughput=throughput)
