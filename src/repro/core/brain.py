"""Cluster brain + job master (paper §3, Fig 4).

ClusterBrain = optimizer + config DB (cluster level). JobMaster = profiler +
executor (job level). The life cycle:

  ① submission → warm-start plan from config-DB similarity (stage 1)
  ② periodic profiles → online NNLS fit → NSGA-II candidates → cluster-level
     weighted greedy → execution plans (stage 2)
  ③ instability handling: dynamic data sharding, seamless migration +
     flash-checkpoint, OOM prediction (stage 3; §5)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.autoscaler import (
    ClusterCapacity, JobState, Prices, ScalingOverheads, get_scaler,
)
from repro.core.oom import OOMPredictor
from repro.core.perf_model import JobResources, JobStatics, PerfModel
from repro.core.sharding_service import ShardingService
from repro.core.warm_start import ConfigDB, ConfigRecord, JobMeta, warm_start


@dataclass
class Profiler:
    """Job-level runtime collection (reported to the brain periodically)."""
    statics: JobStatics
    observations: List[Tuple[JobResources, JobStatics, float]] = field(
        default_factory=list)
    oom: OOMPredictor = field(default_factory=OOMPredictor)
    max_obs: int = 256

    def record_iteration(self, resources: JobResources, t_iter: float) -> None:
        self.observations.append((resources, self.statics, t_iter))
        if len(self.observations) > self.max_obs:
            self.observations.pop(0)

    def record_memory(self, samples_consumed: float, mem_bytes: float) -> None:
        self.oom.observe(samples_consumed, mem_bytes)


@dataclass
class JobMaster:
    """One per job: owns the shard queue, profiler and executor hook."""
    job_id: str
    meta: JobMeta
    statics: JobStatics
    resources: JobResources
    total_samples: float
    sharding: ShardingService
    profiler: Profiler
    apply_plan: Optional[Callable[[JobResources], None]] = None
    samples_done: float = 0.0
    model: PerfModel = field(default_factory=PerfModel)

    def refit(self) -> None:
        if len(self.profiler.observations) >= 4:
            self.model.fit(self.profiler.observations)

    def job_state(self, rho: float = 2.5) -> JobState:
        return JobState(
            job_id=self.job_id, statics=self.statics, current=self.resources,
            model=self.model,
            remaining_samples=max(self.total_samples - self.samples_done, 0.0),
            priority_rho=rho)

    def execute(self, plan: JobResources) -> None:
        self.resources = plan
        if self.apply_plan:
            self.apply_plan(plan)


class ClusterBrain:
    def __init__(self, capacity: ClusterCapacity, *,
                 scaler: str = "dlrover_rm",
                 prices: Prices = Prices(),
                 overheads: ScalingOverheads = ScalingOverheads()):
        self.capacity = capacity
        self.config_db = ConfigDB()
        self.scaler_name = scaler
        self.prices = prices
        self.overheads = overheads
        self.masters: Dict[str, JobMaster] = {}

    # ---------------------------------------------------------- stage 1
    def admit(self, master: JobMaster, *, k: int = 5, mu: float = 0.5
              ) -> JobResources:
        plan = warm_start(master.meta, self.config_db, k=k, mu=mu,
                          default=master.resources)
        master.execute(plan)
        self.masters[master.job_id] = master
        return plan

    # ---------------------------------------------------------- stage 2
    def optimize(self) -> Dict[str, JobResources]:
        for m in self.masters.values():
            m.refit()
        jobs = [m.job_state() for m in self.masters.values()]
        scaler = get_scaler(self.scaler_name)
        plans = scaler(jobs, self.capacity)
        for jid, plan in plans.items():
            self.masters[jid].execute(plan)
        return plans

    # ---------------------------------------------------------- stage 3
    def check_oom(self) -> Dict[str, float]:
        """Predictive PS memory scale-ups (GB) per job."""
        out: Dict[str, float] = {}
        for jid, m in self.masters.items():
            remaining = max(m.total_samples - m.samples_done, 0.0)
            capacity_bytes = m.resources.p * m.resources.mem_p * 1e9
            hit, peak = m.profiler.oom.will_oom(capacity_bytes, remaining)
            if hit and peak is not None:
                rec = m.profiler.oom.recommended_capacity(remaining)
                new_mem_p = max(rec / m.resources.p / 1e9, m.resources.mem_p)
                import dataclasses as _dc
                m.execute(_dc.replace(m.resources, mem_p=new_mem_p))
                out[jid] = new_mem_p
        return out

    # ---------------------------------------------------------- completion
    def complete(self, job_id: str, throughput: float) -> None:
        m = self.masters.pop(job_id, None)
        if m is not None:
            self.config_db.add(ConfigRecord(
                meta=m.meta, final_config=m.resources, throughput=throughput))
