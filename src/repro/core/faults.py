"""Deterministic fault injection (paper §2.2/§5): scripted cloud abnormalities.

AntGroup's clusters lose ~1.5 %/pod/day to failures, plus stragglers, hangs
and OOMs; DLRover-RM's reliability win comes from *detecting* these and
recovering via flash checkpoints and elastic re-scaling. This module makes
those abnormalities reproducible on the **real** training path: a
``FaultPlan`` scripts what goes wrong at which global step, and a
``FaultInjector`` fires the plan through three hook points —

* the trainer loop (``before_step``): PS-shard loss, step hang (a
  watchdog-visible stall), transient OOM;
* the data pipeline (``on_batch`` / ``ShardDataLoader(fault_hook=...)``):
  per-step straggler delays;
* the checkpoint layer (``on_persist`` / ``FlashCheckpoint(fault_hook=...)``):
  blob corruption / truncation of just-persisted checkpoints.

Plans are fully scripted (no hidden randomness at fire time); the only RNG —
seeded, explicit — picks which bytes a corruption flips, so every chaos run
is replayable. ``repro.train.supervisor`` is the recovery side of the loop.

Spec grammar (the launcher's ``--chaos`` / ``--chaos-proc`` flags)::

    spec     := fault ("," fault)*
    fault    := kind "@" step ["x" count] [":" param]
    kind     := ps_loss | hang | straggler | oom | ckpt_corrupt | ckpt_truncate
              | kill | stop | kill_ckpt | kill_loop

Examples: ``ps_loss@10`` (lose one PS shard at step 10), ``hang@20:0.5``
(stall 0.5 s at step 20), ``straggler@30x5:0.05`` (50 ms extra per step for
steps 30..34), ``ckpt_corrupt@40`` (corrupt the first blob persisted at
step ≥ 40 and drop the memory tier — only older disk blobs survive).

The second block of kinds is **process-level** (the ``--chaos-proc`` mode):
they are fired *inside a real worker process* by ``ProcessFaultInjector``
and model pod-eviction-class failures the in-process injector cannot —
``kill@10`` SIGKILLs the worker right before it executes global step 10,
``stop@10`` SIGSTOPs it (a wedged process: only the job master's heartbeat
deadline can see it), ``kill_ckpt@10`` SIGKILLs in the checkpoint layer's
pre-commit window (mid-write: the staging dir is complete but the atomic
rename never happened), and ``kill_loop@10x3`` SIGKILLs the first three
incarnations at step 10 (a crash loop bounded only by the job master's
capped re-exec budget). ``repro.train.job_master`` is the recovery side.
"""
from __future__ import annotations

import os
import signal
import threading
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

KINDS = ("ps_loss", "hang", "straggler", "oom", "ckpt_corrupt", "ckpt_truncate",
         "kill", "stop", "kill_ckpt", "kill_loop")

#: kinds fired at process level by ``ProcessFaultInjector`` (the worker kills
#: itself) rather than through the in-process trainer/data/checkpoint hooks
PROC_KINDS = ("kill", "stop", "kill_ckpt", "kill_loop")

# default param per kind: ps_loss = shards lost, hang = stall seconds,
# straggler = extra seconds per step, others unused
_DEFAULT_PARAM = {"ps_loss": 1.0, "hang": 30.0, "straggler": 0.05,
                  "oom": 0.0, "ckpt_corrupt": 0.0, "ckpt_truncate": 0.0,
                  "kill": 0.0, "stop": 0.0, "kill_ckpt": 0.0, "kill_loop": 0.0}


# --------------------------------------------------------------------- errors
class FaultError(RuntimeError):
    """Base class of every injected abnormality."""


class PSShardLoss(FaultError):
    """A parameter-server shard vanished (pod eviction / hardware loss)."""

    def __init__(self, n_lost: int = 1):
        super().__init__(f"lost {n_lost} PS shard(s)")
        self.n_lost = int(n_lost)


class TransientOOM(FaultError):
    """A worker was OOM-killed; the step never ran (state is intact)."""


class AttemptAbandoned(RuntimeError):
    """A watchdog cancelled this step attempt; discard it silently."""


# ----------------------------------------------------------------------- plan
@dataclass(frozen=True)
class FaultSpec:
    """One scripted abnormality: ``kind`` fires at global steps
    ``[step, step + count)`` with a kind-specific ``param``."""
    kind: str
    step: int
    count: int = 1
    param: float = float("nan")

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(expected one of {KINDS})")
        if self.step < 0 or self.count < 1:
            raise ValueError(f"bad fault window: step={self.step} "
                             f"count={self.count}")
        if np.isnan(self.param):
            object.__setattr__(self, "param", _DEFAULT_PARAM[self.kind])


@dataclass(frozen=True)
class FaultPlan:
    """An ordered, immutable script of abnormalities for one run."""
    specs: Tuple[FaultSpec, ...] = ()

    def at_step(self, step: int) -> List[FaultSpec]:
        return [s for s in self.specs if s.step <= step < s.step + s.count]

    def __str__(self) -> str:
        parts = []
        for s in self.specs:
            p = f"{s.kind}@{s.step}"
            if s.count != 1:
                p += f"x{s.count}"
            if s.param != _DEFAULT_PARAM[s.kind]:
                p += f":{s.param:g}"
            parts.append(p)
        return ",".join(parts)


def parse_chaos_spec(spec: str) -> FaultPlan:
    """Parse a ``--chaos`` spec string into a ``FaultPlan``.

    >>> plan = parse_chaos_spec("ps_loss@10,hang@20:0.5,straggler@30x5:0.05")
    >>> [s.kind for s in plan.specs]
    ['ps_loss', 'hang', 'straggler']
    >>> plan.at_step(32)[0].param
    0.05
    >>> parse_chaos_spec("")
    FaultPlan(specs=())
    """
    specs = []
    for part in (p.strip() for p in spec.split(",") if p.strip()):
        if "@" not in part:
            raise ValueError(f"bad fault spec {part!r}: expected kind@step"
                             f"[xcount][:param]")
        kind, rest = part.split("@", 1)
        param = float("nan")
        if ":" in rest:
            rest, p = rest.split(":", 1)
            param = float(p)
        count = 1
        if "x" in rest:
            rest, c = rest.split("x", 1)
            count = int(c)
        specs.append(FaultSpec(kind.strip(), int(rest), count, param))
    return FaultPlan(tuple(sorted(specs, key=lambda s: (s.step, s.kind))))


def random_plan(n_faults: int, horizon_steps: int, *, seed: int = 0,
                kinds: Tuple[str, ...] = ("ps_loss", "hang", "straggler",
                                          "oom")) -> FaultPlan:
    """A seeded random-but-reproducible plan (for chaos benchmarks).

    >>> str(random_plan(2, 100, seed=7)) == str(random_plan(2, 100, seed=7))
    True
    """
    rng = np.random.default_rng(seed)
    specs = []
    steps = sorted(rng.choice(np.arange(1, max(horizon_steps, 2)),
                              size=min(n_faults, horizon_steps - 1),
                              replace=False).tolist())
    for step in steps:
        specs.append(FaultSpec(str(rng.choice(kinds)), int(step)))
    return FaultPlan(tuple(specs))


# -------------------------------------------------------------- blob sabotage
def corrupt_blob(path: str, *, mode: str = "flip", seed: int = 0) -> str:
    """Deterministically damage a persisted checkpoint (dir or legacy file).

    ``mode="flip"`` flips 64 bytes in the middle of the data file (a bad
    DMA / bit-rot analog); ``mode="truncate"`` cuts the file in half (a
    mid-write kill analog). Returns a description of what was damaged.
    """
    target = path
    if os.path.isdir(path):
        data = os.path.join(path, "leaves.npz")
        target = data if os.path.exists(data) else os.path.join(
            path, "MANIFEST.json")
    size = os.path.getsize(target)
    if mode == "truncate":
        with open(target, "r+b") as f:
            f.truncate(size // 2)
        return f"truncated {target} {size} -> {size // 2} bytes"
    rng = np.random.default_rng(seed)
    n = min(64, max(size // 2, 1))
    off = size // 3
    with open(target, "r+b") as f:
        f.seek(off)
        junk = rng.integers(0, 256, size=n, dtype=np.uint8).tobytes()
        f.write(junk)
    return f"flipped {n} bytes at offset {off} of {target}"


# ------------------------------------------------------------------- injector
class FaultInjector:
    """Fires a ``FaultPlan`` through the trainer/data/checkpoint hooks.

    Each spec fires **once per step in its window** and is then spent —
    recovery replaying the same global step does not re-trigger it (the
    cloud's pod is already gone; re-killing it on every retry would make
    recovery untestable). ``fired`` and ``log`` record exactly what was
    injected and when, for the chaos event log.
    """

    def __init__(self, plan: FaultPlan, *, seed: int = 0):
        self.plan = plan
        self.seed = int(seed)
        self._spent: set = set()          # (spec, step) pairs already fired
        self._lock = threading.Lock()
        self.fired: List[Tuple[int, str]] = []
        self.log: List[Dict] = []
        self._ckpt = None                 # bound FlashCheckpoint (optional)

    def bind_checkpoint(self, ckpt) -> None:
        """Give checkpoint-layer faults access to the store's memory tier."""
        self._ckpt = ckpt

    def _take(self, step: int, kinds: Tuple[str, ...]) -> List[FaultSpec]:
        """Unspent specs of the given kinds active at ``step`` (marks spent)."""
        out = []
        with self._lock:
            for spec in self.plan.at_step(step):
                if spec.kind in kinds and (spec, step) not in self._spent:
                    self._spent.add((spec, step))
                    self.fired.append((step, spec.kind))
                    out.append(spec)
        return out

    def _note(self, step: int, spec: FaultSpec, detail: str) -> None:
        self.log.append({"t": time.time(), "kind": "fault_injected",
                         "fault": spec.kind, "step": int(step),
                         "detail": detail})

    # ------------------------------------------------------------- trainer hook
    def before_step(self, step: int,
                    cancel: Optional[threading.Event] = None) -> None:
        """Trainer-loop hook; call right before executing global ``step``.

        Raises ``PSShardLoss``/``TransientOOM`` for crash-class faults;
        sleeps for hang-class faults (interruptibly: a watchdog that sets
        ``cancel`` turns the stall into ``AttemptAbandoned`` so the hung
        attempt unwinds without touching state).
        """
        for spec in self._take(step, ("hang",)):
            self._note(step, spec, f"stall {spec.param:g}s")
            deadline = time.monotonic() + float(spec.param)
            while time.monotonic() < deadline:
                if cancel is not None:
                    if cancel.wait(0.01):
                        raise AttemptAbandoned(
                            f"hang at step {step} cancelled")
                else:
                    time.sleep(max(min(0.01, deadline - time.monotonic()),
                                   0.0))
        if cancel is not None and cancel.is_set():
            raise AttemptAbandoned(f"step {step} cancelled")
        for spec in self._take(step, ("ps_loss",)):
            self._note(step, spec, f"lost {int(spec.param)} shard(s)")
            raise PSShardLoss(int(spec.param))
        for spec in self._take(step, ("oom",)):
            self._note(step, spec, "worker OOM-killed")
            raise TransientOOM(f"injected OOM at step {step}")

    # ------------------------------------------------------- data-pipeline hook
    def on_batch(self, step: int) -> None:
        """Data-pipeline hook; injects straggler delay while building a batch."""
        for spec in self._take(step, ("straggler",)):
            self._note(step, spec, f"straggler +{spec.param:g}s")
            time.sleep(float(spec.param))

    # ----------------------------------------------------- checkpoint-layer hook
    def on_persist(self, path: str, step: int) -> None:
        """Checkpoint-layer hook (``FlashCheckpoint(fault_hook=...)``).

        A pending ``ckpt_corrupt``/``ckpt_truncate`` spec damages the first
        blob persisted at a step ≥ its trigger, and drops the store's memory
        tier — modelling the paper's node-loss scenario where only (possibly
        damaged) remote-storage copies survive.
        """
        for spec in self._take_persist(step):
            mode = "truncate" if spec.kind == "ckpt_truncate" else "flip"
            detail = corrupt_blob(path, mode=mode, seed=self.seed)
            if self._ckpt is not None:
                self._ckpt.drop_memory_tier()
                detail += " + dropped memory tier"
            self._note(step, spec, detail)

    def _take_persist(self, step: int) -> List[FaultSpec]:
        """Corruption specs trigger on the first persist at/after their step."""
        out = []
        with self._lock:
            for spec in self.plan.specs:
                if spec.kind in ("ckpt_corrupt", "ckpt_truncate") and \
                        spec.step <= step and spec not in self._spent:
                    self._spent.add(spec)
                    self.fired.append((step, spec.kind))
                    out.append(spec)
        return out


# --------------------------------------------------------- process-level chaos
class ProcessFaultInjector:
    """Fires the ``PROC_KINDS`` of a plan *inside a real worker process*.

    Unlike ``FaultInjector`` (scripted exceptions inside one interpreter),
    these faults end the process: ``kill``/``kill_loop``/``kill_ckpt`` raise
    SIGKILL against the worker's own pid, ``stop`` raises SIGSTOP (the
    process freezes mid-run; only the job master's heartbeat deadline can
    detect it and SIGKILL the husk). Recovery is therefore exercised for
    real — a fresh interpreter must re-exec, restore the newest valid
    layout-stamped checkpoint, and replay.

    Determinism across re-execs comes from **incarnation gating** rather
    than the in-process injector's spent-set (which dies with the process):
    the job master passes each worker its incarnation number (0 for the
    first exec, +1 per re-exec), and

    * ``kill`` / ``stop`` / ``kill_ckpt`` fire only in incarnation 0 — the
      cloud's pod is already gone; the replacement replaying the same
      global step must not re-die;
    * ``kill_loop`` fires in every incarnation ``< count`` — a scripted
      crash loop whose only exit is the master's capped re-exec budget
      (or outliving ``count``).

    ``signal_fn`` is a test seam (defaults to ``os.kill`` on own pid).
    """

    def __init__(self, plan: FaultPlan, *, incarnation: int = 0,
                 signal_fn: Optional[Callable[[int], None]] = None,
                 log_path: Optional[str] = None):
        self.plan = plan
        self.incarnation = int(incarnation)
        self._signal = signal_fn if signal_fn is not None else (
            lambda signum: os.kill(os.getpid(), signum))
        self.log_path = log_path

    @staticmethod
    def fires(spec: FaultSpec, step: int, incarnation: int) -> bool:
        """Pure gating predicate: does ``spec`` fire here? (doctested)

        >>> from repro.core.faults import FaultSpec, ProcessFaultInjector
        >>> f = ProcessFaultInjector.fires
        >>> f(FaultSpec("kill", 5), 5, 0)          # first exec dies at step 5
        True
        >>> f(FaultSpec("kill", 5), 5, 1)          # the re-exec replays it
        False
        >>> f(FaultSpec("kill_loop", 5, count=3), 5, 2)   # crash loop: 0,1,2
        True
        >>> f(FaultSpec("kill_loop", 5, count=3), 5, 3)   # incarnation 3 lives
        False
        >>> f(FaultSpec("kill_ckpt", 4), 6, 0)     # first persist at step >= 4
        True
        >>> f(FaultSpec("ps_loss", 5), 5, 0)       # in-process kind: not ours
        False
        """
        if spec.kind in ("kill", "stop", "kill_ckpt"):
            if incarnation != 0:
                return False
        elif spec.kind == "kill_loop":
            if incarnation >= spec.count:
                return False
        else:
            return False
        if spec.kind == "kill_ckpt":
            return step >= spec.step
        return step == spec.step

    def _log(self, spec: FaultSpec, step: int, detail: str) -> None:
        """Append a pre-death marker (O_APPEND: survives the SIGKILL)."""
        if self.log_path is None:
            return
        import json
        with open(self.log_path, "a") as f:
            f.write(json.dumps({
                "t": time.time(), "kind": "proc_fault_fired",
                "fault": spec.kind, "step": int(step),
                "incarnation": self.incarnation, "detail": detail}) + "\n")
            f.flush()
            os.fsync(f.fileno())

    # ------------------------------------------------------------ worker hooks
    def before_step(self, step: int) -> None:
        """Worker-loop hook; call right before executing global ``step``.

        May not return: ``kill``/``kill_loop`` specs SIGKILL the process,
        ``stop`` specs SIGSTOP it (execution resumes here only if an
        external SIGCONT arrives — the job master never sends one; it
        SIGKILLs the husk on heartbeat expiry and re-execs).
        """
        for spec in self.plan.specs:
            if spec.kind in ("kill", "kill_loop") and \
                    self.fires(spec, step, self.incarnation):
                self._log(spec, step, "SIGKILL self before step")
                self._signal(signal.SIGKILL)
            elif spec.kind == "stop" and \
                    self.fires(spec, step, self.incarnation):
                self._log(spec, step, "SIGSTOP self before step")
                self._signal(signal.SIGSTOP)

    def on_pre_commit(self, path: str, step: int) -> None:
        """Checkpoint-layer hook (``FlashCheckpoint(pre_commit_hook=...)``).

        Fires in the mid-write window: the staging directory under ``path``
        is fully written (data + manifest, checksums valid) but the atomic
        rename has not happened. A SIGKILL here is the worst torn-save case
        — ``valid_steps`` must never count the leftover.
        """
        for spec in self.plan.specs:
            if spec.kind == "kill_ckpt" and \
                    self.fires(spec, step, self.incarnation):
                self._log(spec, step, f"SIGKILL self mid-save of {path}")
                self._signal(signal.SIGKILL)
