"""Flash-checkpoint (paper §5.2): in-memory checkpoints + async persistence.

The migration-critical path stores checkpoints in host memory (the paper's
distributed caching service; "<1 s for a 20 GB model") and flushes them to
persistent storage (the paper's RDS) on a background thread. Restore prefers
the memory tier. Checkpoints are stored *mesh-agnostic* (plain host arrays
keyed by pytree path), so restore can re-shard onto a different mesh — the
substrate of seamless migration and elastic re-meshing.

The disk tier is hardened against the §2.2 failure modes a restart must
survive:

* **atomic persistence** — each step writes into a ``*.tmp-<pid>`` staging
  directory and lands via one ``os.replace``; a mid-save kill leaves only a
  staging dir that eviction skips (and logs), never a half-written blob
  under a valid name;
* **per-leaf checksums** — every leaf's CRC32 is recorded in the step's
  ``MANIFEST.json`` and verified on restore, so bit-rot or a torn write
  raises ``CheckpointCorruptError`` instead of silently loading garbage;
* **newest-valid fallback** — when no explicit step is requested, restore
  walks candidates newest-first and transparently falls back past corrupt
  or unreadable blobs (recorded in ``self.events``), so recovery never
  needs manual intervention.

Legacy single-file ``ckpt_NNN.npz`` blobs (the pre-hardening format) still
restore — without checksum verification, since they carry none.
"""
from __future__ import annotations

import json
import logging
import os
import shutil
import threading
import time
import zlib
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

logger = logging.getLogger("repro.flash_checkpoint")

_DATA_FILE = "leaves.npz"
_MANIFEST_FILE = "MANIFEST.json"
_FORMAT = 1


class CheckpointCorruptError(RuntimeError):
    """A persisted blob failed checksum/structure verification."""


def _flatten(state) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(state)[0]:
        key = jax.tree_util.keystr(path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _unflatten(like, flat: Dict[str, np.ndarray], *,
               optional_leaves: Tuple[str, ...] = ()):
    """Rebuild ``like``'s pytree from flat path-keyed arrays.

    A leaf absent from ``flat`` raises — restoring a truncated or
    wrong-schema blob must never silently zero state — UNLESS its keystr is
    named in ``optional_leaves``, in which case it is filled with zeros of
    the ``like`` leaf's shape/dtype. That is how newer blob schemas (e.g.
    the layout stamp's ``padded_n_ps`` field) restore older checkpoints
    that predate the field, without loosening the guard for anything else.
    """
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, leaf in paths:
        key = jax.tree_util.keystr(path)
        if key not in flat:
            if key not in optional_leaves:
                raise KeyError(f"checkpoint missing leaf {key}")
            leaves.append(np.zeros(leaf.shape, leaf.dtype))
            continue
        leaves.append(flat[key])
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _leaf_crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes())


class FlashCheckpoint:
    """Two-tier checkpoint store: memory (fast) + disk (persistent, async).

    ``fault_hook(path, step)`` — if given — runs right after each blob lands
    on disk (and before eviction); it is the checkpoint-layer injection
    point of ``repro.core.faults.FaultInjector.on_persist``.

    ``pre_commit_hook(tmp_path, step)`` — if given — runs in the mid-write
    window: the staging directory is fully written (data + manifest) but
    ``_commit`` has not renamed it yet. It is the injection point of
    ``repro.core.faults.ProcessFaultInjector.on_pre_commit`` (kill-during-
    checkpoint-write chaos): a process killed inside the hook must leave
    nothing that ``valid_steps``/``restore`` would count as a checkpoint.
    """

    def __init__(self, persist_dir: Optional[str] = None, *,
                 keep: int = 2, async_persist: bool = True,
                 fault_hook: Optional[Callable[[str, int], None]] = None,
                 pre_commit_hook: Optional[Callable[[str, int], None]] = None):
        self.persist_dir = persist_dir
        self.keep = keep
        self.async_persist = async_persist
        self.fault_hook = fault_hook
        self.pre_commit_hook = pre_commit_hook
        self._mem: Dict[int, Dict[str, np.ndarray]] = {}
        self._mem_order: List[int] = []
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._pending: List[Future] = []
        self._lock = threading.Lock()
        self.last_save_seconds = 0.0      # memory-tier latency (critical path)
        self.last_persist_seconds = 0.0   # disk-tier latency (off critical path)
        self.last_restore_seconds = 0.0
        self.events: List[Dict] = []      # skipped dirs, corrupt-blob fallbacks
        if persist_dir:
            os.makedirs(persist_dir, exist_ok=True)

    def _event(self, kind: str, **detail) -> None:
        self.events.append({"kind": kind, "t": time.time(), **detail})

    def note(self, kind: str, **detail) -> None:
        """Record an externally-observed event into this store's log.

        Public seam for callers (the supervisor's restore fallbacks) so
        their recovery decisions land next to the store's own skip/corrupt
        records instead of vanishing.
        """
        self._event(kind, **detail)
        logger.warning("flash_checkpoint %s: %s", kind, detail)

    # ------------------------------------------------------------------ save
    def save(self, state, step: int) -> None:
        t0 = time.perf_counter()
        flat = _flatten(state)
        with self._lock:
            if step in self._mem:                # re-save: refresh recency,
                self._mem_order.remove(step)     # never double-count for keep
            self._mem[step] = flat
            self._mem_order.append(step)
            while len(self._mem_order) > self.keep:
                old = self._mem_order.pop(0)
                self._mem.pop(old, None)
        self.last_save_seconds = time.perf_counter() - t0
        if self.persist_dir:
            if self.async_persist:
                self._pending.append(self._pool.submit(self._persist, flat, step))
            else:
                self._persist(flat, step)

    def drop_memory_tier(self) -> None:
        """Forget every in-memory checkpoint (node-loss simulation: only the
        persisted disk tier survives a host failure)."""
        with self._lock:
            self._mem.clear()
            self._mem_order.clear()

    def _persist(self, flat: Dict[str, np.ndarray], step: int) -> None:
        t0 = time.perf_counter()
        final = os.path.join(self.persist_dir, f"ckpt_{step:012d}")
        tmp = final + f".tmp-{os.getpid()}"
        if os.path.isdir(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        with open(os.path.join(tmp, _DATA_FILE), "wb") as f:
            np.savez(f, **{k: v for k, v in flat.items()})
            f.flush()
            os.fsync(f.fileno())
        manifest = {
            "format": _FORMAT, "step": int(step),
            "leaves": {k: {"crc32": _leaf_crc(v),
                           "shape": list(v.shape), "dtype": str(v.dtype)}
                       for k, v in flat.items()},
        }
        with open(os.path.join(tmp, _MANIFEST_FILE), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if self.pre_commit_hook is not None:     # kill-during-save chaos seam
            self.pre_commit_hook(tmp, step)
        self._commit(tmp, final)
        if self.fault_hook is not None:
            self.fault_hook(final, step)
        self._evict()
        self.last_persist_seconds = time.perf_counter() - t0

    def _commit(self, tmp: str, final: str) -> None:
        """THE atomic commit point: one ``os.replace`` of the staging dir.

        Everything before this call is preparation a kill may interrupt
        freely — a leftover ``*.tmp-<pid>`` dir is skipped by
        ``_disk_steps`` and never counted by ``valid_steps``/``restore``.
        Everything after it is a fully-valid checkpoint: the data and
        manifest files were fsynced before the rename, and the parent
        directory entry is fsynced after it, so the blob either exists
        completely under its valid name or not at all — there is no state
        in between for a SIGKILL (or power loss) to expose.
        """
        if os.path.isdir(final):                 # re-persist of the same step
            shutil.rmtree(final)
        elif os.path.exists(final):              # legacy file under this name
            os.remove(final)
        os.replace(tmp, final)
        dir_fd = os.open(os.path.dirname(final) or ".", os.O_RDONLY)
        try:
            os.fsync(dir_fd)                     # durably publish the rename
        finally:
            os.close(dir_fd)

    def _evict(self) -> None:
        for old in self._disk_steps()[:-self.keep]:
            entry = os.path.join(self.persist_dir, f"ckpt_{old:012d}")
            try:
                if os.path.isdir(entry):
                    shutil.rmtree(entry)
                else:
                    os.remove(entry + ".npz")
            except OSError as e:
                self._event("evict_failed", step=old, error=str(e))

    def wait(self) -> None:
        for fut in self._pending:
            fut.result()
        self._pending.clear()

    # --------------------------------------------------------------- restore
    def _disk_steps(self) -> List[int]:
        """Steps with a plausibly-restorable disk entry, oldest first.

        Malformed entries — unparsable names, staging (``*.tmp-*``) dirs
        left by a mid-save kill, step dirs missing their manifest — are
        skipped (and logged), never raised on: one corrupt neighbor must not
        take down eviction or restore for everyone else. Content-level
        validation (checksums) happens at load time.
        """
        if not self.persist_dir or not os.path.isdir(self.persist_dir):
            return []
        steps = []
        for name in sorted(os.listdir(self.persist_dir)):
            full = os.path.join(self.persist_dir, name)
            if not name.startswith("ckpt_"):
                continue
            if ".tmp-" in name:
                self._event("skip_staging_dir", name=name)
                continue
            if name.endswith(".npz"):            # legacy single-file blob
                try:
                    steps.append(int(name[5:-4]))
                except ValueError:
                    self._event("skip_malformed", name=name)
                continue
            try:
                step = int(name[5:])
            except ValueError:
                self._event("skip_malformed", name=name)
                continue
            if not os.path.exists(os.path.join(full, _MANIFEST_FILE)):
                self._event("skip_missing_manifest", name=name)
                continue
            steps.append(step)
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        with self._lock:
            mem = max(self._mem) if self._mem else None
        disk = self._disk_steps()
        best = max([s for s in [mem, disk[-1] if disk else None] if s is not None],
                   default=None)
        return best

    def valid_steps(self) -> List[int]:
        """Disk steps that fully verify (manifest + checksums), oldest first."""
        out = []
        for step in self._disk_steps():
            try:
                self._load_disk(step)
                out.append(step)
            except CheckpointCorruptError as e:
                self._event("corrupt_blob_skipped", step=step, error=str(e))
        return out

    def _load_disk(self, step: int) -> Dict[str, np.ndarray]:
        """Load + verify one persisted step; raises ``CheckpointCorruptError``."""
        dirpath = os.path.join(self.persist_dir, f"ckpt_{step:012d}")
        legacy = dirpath + ".npz"
        if not os.path.isdir(dirpath):
            if os.path.exists(legacy):           # pre-hardening format
                try:
                    with np.load(legacy) as z:
                        return {k: z[k] for k in z.files}
                except Exception as e:
                    raise CheckpointCorruptError(
                        f"legacy blob {legacy} unreadable: {e}") from e
            raise FileNotFoundError(f"no disk blob for step {step}")
        try:
            with open(os.path.join(dirpath, _MANIFEST_FILE)) as f:
                manifest = json.load(f)
            with np.load(os.path.join(dirpath, _DATA_FILE)) as z:
                flat = {k: z[k] for k in z.files}
        except Exception as e:
            raise CheckpointCorruptError(
                f"step {step} blob unreadable: {e}") from e
        want = manifest.get("leaves", {})
        if set(want) != set(flat):
            raise CheckpointCorruptError(
                f"step {step} leaf set mismatch: manifest has {len(want)}, "
                f"data has {len(flat)}")
        for key, meta in want.items():
            if _leaf_crc(flat[key]) != meta["crc32"]:
                raise CheckpointCorruptError(
                    f"step {step} leaf {key} failed CRC32 verification")
        return flat

    def restore(self, like, step: Optional[int] = None, *,
                shardings=None,
                optional_leaves: Tuple[str, ...] = ()) -> Tuple[Any, int]:
        """Restore (optionally onto new shardings — cross-mesh elastic load).

        With ``step=None``, candidates are tried newest-first across both
        tiers; a corrupt disk blob is logged (``self.events``) and skipped,
        so the newest *valid* checkpoint wins automatically. An explicitly
        requested ``step`` that fails verification raises
        ``CheckpointCorruptError`` instead — the caller asked for that exact
        blob, silently substituting another would be wrong.

        ``optional_leaves`` names (by ``jax.tree_util.keystr``) the specific
        leaves of ``like`` that may be absent from the blob and zero-fill —
        the schema-evolution escape hatch; every other missing leaf still
        raises (see ``_unflatten``).
        """
        t0 = time.perf_counter()
        with self._lock:
            mem_steps = set(self._mem)
        if step is not None:
            candidates = [step]
        else:
            candidates = sorted(mem_steps | set(self._disk_steps()),
                                reverse=True)
        if not candidates:
            raise FileNotFoundError("no checkpoint available")
        flat = None
        used_step = None
        for s in candidates:
            with self._lock:
                flat = self._mem.get(s)
            if flat is not None:
                used_step = s
                break
            try:
                flat = self._load_disk(s)
                used_step = s
                break
            except CheckpointCorruptError as e:
                if step is not None:
                    raise
                self._event("corrupt_blob_fallback", step=s, error=str(e))
            except FileNotFoundError:
                if step is not None:
                    raise
        if flat is None:
            raise FileNotFoundError(
                "no valid checkpoint available "
                f"(all {len(candidates)} candidate(s) corrupt or missing)")
        state = _unflatten(like, flat, optional_leaves=optional_leaves)
        if shardings is not None:
            state = jax.tree.map(
                lambda leaf, sh: jax.device_put(leaf, sh) if sh is not None
                else jax.device_put(leaf),
                state, shardings,
                is_leaf=lambda x: not isinstance(x, (dict, list, tuple)))
        else:
            state = jax.tree.map(jnp_asarray, state)
        self.last_restore_seconds = time.perf_counter() - t0
        return state, used_step


def jnp_asarray(x):
    import jax.numpy as jnp
    return jnp.asarray(x)
