"""Flash-checkpoint (paper §5.2): in-memory checkpoints + async persistence.

The migration-critical path stores checkpoints in host memory (the paper's
distributed caching service; "<1 s for a 20 GB model") and flushes them to
persistent storage (the paper's RDS) on a background thread. Restore prefers
the memory tier. Checkpoints are stored *mesh-agnostic* (plain host arrays
keyed by pytree path), so restore can re-shard onto a different mesh — the
substrate of seamless migration and elastic re-meshing.
"""
from __future__ import annotations

import json
import os
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(state) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(state)[0]:
        key = jax.tree_util.keystr(path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _unflatten(like, flat: Dict[str, np.ndarray], *,
               optional_leaves: Tuple[str, ...] = ()):
    """Rebuild ``like``'s pytree from flat path-keyed arrays.

    A leaf absent from ``flat`` raises — restoring a truncated or
    wrong-schema blob must never silently zero state — UNLESS its keystr is
    named in ``optional_leaves``, in which case it is filled with zeros of
    the ``like`` leaf's shape/dtype. That is how newer blob schemas (e.g.
    the layout stamp's ``padded_n_ps`` field) restore older checkpoints
    that predate the field, without loosening the guard for anything else.
    """
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, leaf in paths:
        key = jax.tree_util.keystr(path)
        if key not in flat:
            if key not in optional_leaves:
                raise KeyError(f"checkpoint missing leaf {key}")
            leaves.append(np.zeros(leaf.shape, leaf.dtype))
            continue
        leaves.append(flat[key])
    return jax.tree_util.tree_unflatten(treedef, leaves)


class FlashCheckpoint:
    """Two-tier checkpoint store: memory (fast) + disk (persistent, async)."""

    def __init__(self, persist_dir: Optional[str] = None, *,
                 keep: int = 2, async_persist: bool = True):
        self.persist_dir = persist_dir
        self.keep = keep
        self.async_persist = async_persist
        self._mem: Dict[int, Dict[str, np.ndarray]] = {}
        self._mem_order: List[int] = []
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._pending: List[Future] = []
        self._lock = threading.Lock()
        self.last_save_seconds = 0.0      # memory-tier latency (critical path)
        self.last_persist_seconds = 0.0   # disk-tier latency (off critical path)
        if persist_dir:
            os.makedirs(persist_dir, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, state, step: int) -> None:
        t0 = time.perf_counter()
        flat = _flatten(state)
        with self._lock:
            if step in self._mem:                # re-save: refresh recency,
                self._mem_order.remove(step)     # never double-count for keep
            self._mem[step] = flat
            self._mem_order.append(step)
            while len(self._mem_order) > self.keep:
                old = self._mem_order.pop(0)
                self._mem.pop(old, None)
        self.last_save_seconds = time.perf_counter() - t0
        if self.persist_dir:
            if self.async_persist:
                self._pending.append(self._pool.submit(self._persist, flat, step))
            else:
                self._persist(flat, step)

    def _persist(self, flat: Dict[str, np.ndarray], step: int) -> None:
        t0 = time.perf_counter()
        path = os.path.join(self.persist_dir, f"ckpt_{step:012d}.npz")
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            np.savez(f, **{k: v for k, v in flat.items()})
        os.replace(tmp, path)
        manifest = os.path.join(self.persist_dir, "manifest.json")
        steps = self._disk_steps()
        with open(manifest, "w") as f:
            json.dump({"steps": steps}, f)
        for old in steps[:-self.keep]:
            try:
                os.remove(os.path.join(self.persist_dir, f"ckpt_{old:012d}.npz"))
            except OSError:
                pass
        self.last_persist_seconds = time.perf_counter() - t0

    def wait(self) -> None:
        for fut in self._pending:
            fut.result()
        self._pending.clear()

    # --------------------------------------------------------------- restore
    def _disk_steps(self) -> List[int]:
        if not self.persist_dir or not os.path.isdir(self.persist_dir):
            return []
        steps = []
        for name in os.listdir(self.persist_dir):
            if name.startswith("ckpt_") and name.endswith(".npz"):
                steps.append(int(name[5:-4]))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        with self._lock:
            mem = max(self._mem) if self._mem else None
        disk = self._disk_steps()
        best = max([s for s in [mem, disk[-1] if disk else None] if s is not None],
                   default=None)
        return best

    def restore(self, like, step: Optional[int] = None, *,
                shardings=None,
                optional_leaves: Tuple[str, ...] = ()) -> Tuple[Any, int]:
        """Restore (optionally onto new shardings — cross-mesh elastic load).

        ``optional_leaves`` names (by ``jax.tree_util.keystr``) the specific
        leaves of ``like`` that may be absent from the blob and zero-fill —
        the schema-evolution escape hatch; every other missing leaf still
        raises (see ``_unflatten``).
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError("no checkpoint available")
        with self._lock:
            flat = self._mem.get(step)
        if flat is None:
            path = os.path.join(self.persist_dir, f"ckpt_{step:012d}.npz")
            with np.load(path) as z:
                flat = {k: z[k] for k in z.files}
        state = _unflatten(like, flat, optional_leaves=optional_leaves)
        if shardings is not None:
            state = jax.tree.map(
                lambda leaf, sh: jax.device_put(leaf, sh) if sh is not None
                else jax.device_put(leaf),
                state, shardings,
                is_leaf=lambda x: not isinstance(x, (dict, list, tuple)))
        else:
            state = jax.tree.map(jnp_asarray, state)
        return state, step


def jnp_asarray(x):
    import jax.numpy as jnp
    return jnp.asarray(x)
