"""NSGA-II multi-objective evolutionary optimizer (paper §4.3 uses pymoo's [3]).

Self-contained implementation: fast non-dominated sorting, crowding distance,
binary tournament selection, SBX crossover + polynomial mutation, with
integer rounding for discrete resource variables. Minimizes all objectives.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np


@dataclass
class Individual:
    x: np.ndarray
    f: np.ndarray
    rank: int = 0
    crowding: float = 0.0


def _dominates(a: np.ndarray, b: np.ndarray) -> bool:
    return bool(np.all(a <= b) and np.any(a < b))


def fast_non_dominated_sort(pop: List[Individual]) -> List[List[Individual]]:
    fronts: List[List[Individual]] = [[]]
    S = {id(p): [] for p in pop}
    n = {id(p): 0 for p in pop}
    for p in pop:
        for q in pop:
            if p is q:
                continue
            if _dominates(p.f, q.f):
                S[id(p)].append(q)
            elif _dominates(q.f, p.f):
                n[id(p)] += 1
        if n[id(p)] == 0:
            p.rank = 0
            fronts[0].append(p)
    i = 0
    while fronts[i]:
        nxt: List[Individual] = []
        for p in fronts[i]:
            for q in S[id(p)]:
                n[id(q)] -= 1
                if n[id(q)] == 0:
                    q.rank = i + 1
                    nxt.append(q)
        i += 1
        fronts.append(nxt)
    return fronts[:-1]


def crowding_distance(front: List[Individual]) -> None:
    if not front:
        return
    n_obj = len(front[0].f)
    for p in front:
        p.crowding = 0.0
    for m in range(n_obj):
        front.sort(key=lambda p: p.f[m])
        front[0].crowding = front[-1].crowding = float("inf")
        lo, hi = front[0].f[m], front[-1].f[m]
        if hi - lo < 1e-12:
            continue
        for i in range(1, len(front) - 1):
            front[i].crowding += (front[i + 1].f[m] - front[i - 1].f[m]) / (hi - lo)


def _tournament(pop: List[Individual], rng) -> Individual:
    if len(pop) == 1:                      # degenerate population
        return pop[0]
    a, b = rng.choice(len(pop), 2, replace=False)
    pa, pb = pop[a], pop[b]
    if pa.rank != pb.rank:
        return pa if pa.rank < pb.rank else pb
    return pa if pa.crowding > pb.crowding else pb


def _sbx(x1, x2, lo, hi, rng, eta: float = 15.0):
    u = rng.random(len(x1))
    beta = np.where(u <= 0.5, (2 * u) ** (1 / (eta + 1)),
                    (1 / (2 * (1 - u))) ** (1 / (eta + 1)))
    c1 = 0.5 * ((1 + beta) * x1 + (1 - beta) * x2)
    c2 = 0.5 * ((1 - beta) * x1 + (1 + beta) * x2)
    return np.clip(c1, lo, hi), np.clip(c2, lo, hi)


def _poly_mutate(x, lo, hi, rng, eta: float = 20.0, pm: Optional[float] = None):
    pm = pm if pm is not None else 1.0 / len(x)
    y = x.copy()
    for i in range(len(x)):
        if rng.random() < pm:
            u = rng.random()
            delta = ((2 * u) ** (1 / (eta + 1)) - 1 if u < 0.5
                     else 1 - (2 * (1 - u)) ** (1 / (eta + 1)))
            y[i] = np.clip(y[i] + delta * (hi[i] - lo[i]), lo[i], hi[i])
    return y


def nsga2(objectives: Callable[[np.ndarray], Sequence[float]],
          bounds: Sequence[Tuple[float, float]], *,
          pop_size: int = 40, generations: int = 30,
          integer: bool = True, seed: int = 0,
          init: Optional[Sequence[np.ndarray]] = None
          ) -> List[Tuple[np.ndarray, np.ndarray]]:
    """Minimize ``objectives`` over box bounds; returns the Pareto front."""
    rng = np.random.default_rng(seed)
    lo = np.array([b[0] for b in bounds], float)
    hi = np.array([b[1] for b in bounds], float)

    def make(x) -> Individual:
        x = np.clip(np.round(x) if integer else x, lo, hi)
        f = np.asarray(objectives(x), float)
        if not np.all(np.isfinite(f)):
            raise ValueError(
                f"objectives returned non-finite values {f.tolist()} at "
                f"x={x.tolist()}; NSGA-II dominance is undefined for NaN/inf "
                "— clamp or penalize inside the objective function instead")
        return Individual(x=x, f=f)

    pop = [make(lo + rng.random(len(bounds)) * (hi - lo)) for _ in range(pop_size)]
    for i, x0 in enumerate(init or []):
        if i < len(pop):
            pop[i] = make(np.asarray(x0, float))

    for front in fast_non_dominated_sort(pop):
        crowding_distance(front)

    for _ in range(generations):
        children: List[Individual] = []
        while len(children) < pop_size:
            p1, p2 = _tournament(pop, rng), _tournament(pop, rng)
            c1, c2 = _sbx(p1.x, p2.x, lo, hi, rng)
            children.append(make(_poly_mutate(c1, lo, hi, rng)))
            if len(children) < pop_size:
                children.append(make(_poly_mutate(c2, lo, hi, rng)))
        union = pop + children
        fronts = fast_non_dominated_sort(union)
        new_pop: List[Individual] = []
        for front in fronts:
            crowding_distance(front)
            if len(new_pop) + len(front) <= pop_size:
                new_pop.extend(front)
            else:
                front.sort(key=lambda p: -p.crowding)
                new_pop.extend(front[: pop_size - len(new_pop)])
                break
        pop = new_pop

    pareto = fast_non_dominated_sort(pop)[0]
    seen = set()
    out = []
    for p in pareto:
        key = tuple(p.x.tolist())
        if key not in seen:
            seen.add(key)
            out.append((p.x, p.f))
    return out
