"""OOM prevention (paper §5.3): predictive embedding-memory growth model.

    M_emb = T · D · φ_cats,   Δφ_cats ∝ Ψ_thp · Δt

i.e. embedding memory grows linearly in *samples consumed* while new feature
categories keep arriving. The predictor regresses observed PS memory against
cumulative samples and extrapolates to job completion; if the prediction
crosses the PS memory capacity before the job finishes, it recommends a
pre-emptive vertical scale-up (paper: OOM-caused failures 4.7 % → 0.23 %).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np


@dataclass
class OOMPredictor:
    dtype_bytes: int = 4          # T
    emb_dim: int = 16             # D
    window: int = 64              # observations kept (rolling)
    safety_factor: float = 1.1    # recommend capacity with headroom
    _samples: List[float] = field(default_factory=list)
    _mem: List[float] = field(default_factory=list)

    # ------------------------------------------------------------------
    def observe(self, samples_consumed: float, mem_bytes: float) -> None:
        self._samples.append(float(samples_consumed))
        self._mem.append(float(mem_bytes))
        if len(self._samples) > self.window:
            self._samples.pop(0)
            self._mem.pop(0)

    def growth_rate(self) -> Optional[float]:
        """bytes per sample (dM/dsamples); None until ≥2 observations."""
        if len(self._samples) < 2:
            return None
        x = np.asarray(self._samples)
        y = np.asarray(self._mem)
        denom = float(((x - x.mean()) ** 2).sum())
        if denom <= 0:
            return None
        slope = float(((x - x.mean()) * (y - y.mean())).sum() / denom)
        return max(slope, 0.0)

    def categories_per_sample(self) -> Optional[float]:
        """Δφ_cats per sample implied by the growth rate."""
        g = self.growth_rate()
        if g is None:
            return None
        return g / (self.dtype_bytes * self.emb_dim)

    def predict(self, at_samples: float) -> Optional[float]:
        g = self.growth_rate()
        if g is None or not self._samples:
            return None
        return self._mem[-1] + g * max(at_samples - self._samples[-1], 0.0)

    # ------------------------------------------------------------------
    def will_oom(self, capacity_bytes: float, samples_to_completion: float
                 ) -> Tuple[bool, Optional[float]]:
        """(True, predicted_peak) if projected to exceed capacity pre-finish."""
        if not self._samples:
            return False, None
        peak = self.predict(self._samples[-1] + max(samples_to_completion, 0.0))
        if peak is None:
            return False, None
        return peak > capacity_bytes, peak

    def recommended_capacity(self, samples_to_completion: float) -> Optional[float]:
        _, peak = self.will_oom(float("inf"), samples_to_completion)
        if peak is None:
            return None
        return peak * self.safety_factor
