"""Dynamic data sharding (paper §5.1) + frequency-aware parameter placement.

The job master splits the dataset into numerous small, variably-sized shards
kept in a *shards queue*. Workers fetch shards on demand, send periodic
heartbeats carrying *progress offsets*, and report completion. The service:

* requeues the unfinished shard(s) of failed workers (no omission),
* hands stragglers smaller shards (workload rebalancing, consistent quality),
* lets new/restarted workers pull work immediately (fast elasticity),
* guarantees exactly-once *completion* coverage of the sample range.

``ParameterPlacementService`` is the job master's second planning duty: it
aggregates the per-row embedding access counts workers piggyback on their
heartbeats and serves RecShard-style placement plans — hot-row cache prefixes
for the fused embedding engine and balanced contiguous PS row ranges instead
of uniform vocab striping (the paper's hot-PS problem, §2.1/Fig 12, attacked
at placement time).

``HotTableTracker`` is the *live* evolution of that service: exponentially
decayed rolling counts that follow drifting access skew, and a hysteresis
trigger that turns "the current placement has gone hot" into a
``ReplanDecision`` — the input of ``repro.train.replan``'s mid-job
re-plan/re-shard cycle (the paper's §4–§5 *dynamic adjustment* loop applied
to embedding placement).

All methods take an explicit ``now`` timestamp so the service runs identically
under the simulator's virtual clock and a wall clock.
"""
from __future__ import annotations

import collections
import threading
from dataclasses import dataclass, replace
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class Shard:
    """Half-open sample range [start, end) with a unique index."""
    index: int
    start: int
    end: int
    epoch: int = 0

    @property
    def size(self) -> int:
        return self.end - self.start


@dataclass
class WorkerView:
    shard: Optional[Shard] = None
    progress: int = 0                  # samples processed within current shard
    last_heartbeat: float = 0.0
    samples_done: int = 0              # lifetime samples (for straggler detection)
    first_seen: float = 0.0
    is_straggler: bool = False


class ShardingService:
    def __init__(self, total_samples: int, shard_size: int = 256 * 64, *,
                 num_epochs: int = 1, min_shard: int = 64,
                 heartbeat_timeout: float = 30.0,
                 straggler_ratio: float = 0.5):
        assert total_samples > 0 and shard_size > 0
        self.total = total_samples
        self.shard_size = shard_size
        self.min_shard = min_shard
        self.heartbeat_timeout = heartbeat_timeout
        self.straggler_ratio = straggler_ratio
        self.num_epochs = num_epochs
        self._lock = threading.Lock()
        self._queue: Deque[Shard] = collections.deque()
        self._next_index = 0
        self._epoch = 0
        self._workers: Dict[str, WorkerView] = {}
        self._completed: List[Shard] = []
        self._fill_epoch(0)

    # ------------------------------------------------------------------ fill
    def _fill_epoch(self, epoch: int) -> None:
        start = 0
        while start < self.total:
            end = min(start + self.shard_size, self.total)
            self._queue.append(Shard(self._next_index, start, end, epoch))
            self._next_index += 1
            start = end

    # --------------------------------------------------------------- workers
    def _view(self, worker: str, now: float) -> WorkerView:
        if worker not in self._workers:
            self._workers[worker] = WorkerView(first_seen=now, last_heartbeat=now)
        return self._workers[worker]

    def request_shard(self, worker: str, now: float) -> Optional[Shard]:
        """Hand the next shard; stragglers receive a split (smaller) shard.

        Implements the paper's workload-rebalancing pull model (§5.1): workers
        fetch on demand, so a slow worker naturally takes fewer samples, and a
        flagged straggler gets its shard halved (down to ``min_shard``).

        Args:
          worker: caller's worker id (registered on first contact).
          now:    current (virtual or wall) time, also counts as a heartbeat.

        Returns the worker's current ``Shard`` (a new one if it held none), or
        ``None`` when the queue is drained and all epochs are exhausted.
        """
        with self._lock:
            self._reap_failures(now)
            w = self._view(worker, now)
            w.last_heartbeat = now
            if w.shard is not None:
                return w.shard                      # already holding one
            if not self._queue:
                if self._epoch + 1 < self.num_epochs:
                    self._epoch += 1
                    self._fill_epoch(self._epoch)
                else:
                    return None
            shard = self._queue.popleft()
            if w.is_straggler and shard.size > self.min_shard:
                half = shard.size // 2
                first = replace(shard, end=shard.start + half)
                second = Shard(self._next_index, shard.start + half, shard.end,
                               shard.epoch)
                self._next_index += 1
                self._queue.appendleft(second)
                shard = first
            w.shard = shard
            w.progress = 0
            return shard

    def heartbeat(self, worker: str, progress: int, now: float) -> None:
        """Record a progress-offset heartbeat (§5.1 liveness + straggler input).

        Args:
          worker:   reporting worker id.
          progress: samples processed within the worker's *current* shard
                    (monotonic within a shard; resets on a new shard).
          now:      current time; missing heartbeats past
                    ``heartbeat_timeout`` mark the worker failed.
        """
        with self._lock:
            w = self._view(worker, now)
            delta = max(0, progress - w.progress)
            w.progress = progress
            w.samples_done += delta
            w.last_heartbeat = now

    def report_done(self, worker: str, shard_index: int, now: float) -> None:
        """Mark the worker's current shard complete (exactly-once accounting).

        Args:
          worker:      reporting worker id.
          shard_index: index of the shard being completed; ignored if it does
                       not match the shard the worker actually holds (stale
                       completion after a requeue cannot double-count).
          now:         current time (counts as a heartbeat).
        """
        with self._lock:
            w = self._view(worker, now)
            if w.shard is not None and w.shard.index == shard_index:
                w.samples_done += max(0, w.shard.size - w.progress)
                self._completed.append(w.shard)
                w.shard = None
                w.progress = 0
            w.last_heartbeat = now

    def report_failure(self, worker: str, now: float) -> None:
        """Explicit failure notification (e.g. pod eviction callback)."""
        with self._lock:
            self._fail_worker(worker)

    # ------------------------------------------------------------- liveness
    def _fail_worker(self, worker: str) -> None:
        w = self._workers.get(worker)
        if w is None:
            return
        if w.shard is not None:
            self._queue.appendleft(w.shard)        # requeue unfinished shard
        del self._workers[worker]

    def _reap_failures(self, now: float) -> List[str]:
        dead = [name for name, w in self._workers.items()
                if now - w.last_heartbeat > self.heartbeat_timeout]
        for name in dead:
            self._fail_worker(name)
        return dead

    def check_failures(self, now: float) -> List[str]:
        """Reap workers whose last heartbeat is older than the timeout.

        Their unfinished shards go back to the *front* of the queue (§5.1 "no
        data omission"). Returns the list of reaped worker ids.
        """
        with self._lock:
            return self._reap_failures(now)

    # ------------------------------------------------------------ stragglers
    def detect_stragglers(self, now: float) -> List[str]:
        """Progress-offset comparison: rate < ratio × median peer rate.

        The paper's straggler mitigation (§5.1): flagged workers keep running
        but receive split shards from ``request_shard``, so one slow pod
        stops gating the barrier without being evicted.

        Args:
          now: current time (rates are lifetime samples / lifetime seconds).

        Returns worker ids *newly* flagged as stragglers by this call.
        """
        with self._lock:
            rates = {}
            for name, w in self._workers.items():
                dt = max(now - w.first_seen, 1e-9)
                rates[name] = (w.samples_done + w.progress) / dt
            if len(rates) < 2:
                return []
            vals = sorted(rates.values())
            median = vals[len(vals) // 2]
            out = []
            for name, rate in rates.items():
                w = self._workers[name]
                was = w.is_straggler
                w.is_straggler = median > 0 and rate < self.straggler_ratio * median
                if w.is_straggler and not was:
                    out.append(name)
            return out

    # ------------------------------------------------------------- accounting
    @property
    def epochs_completed(self) -> int:
        return self._epoch

    def pending_count(self) -> int:
        """Number of shards waiting in the queue (not held by any worker)."""
        with self._lock:
            return len(self._queue)

    def completed_samples(self, epoch: Optional[int] = None) -> int:
        """Total samples in completed shards (optionally for one epoch)."""
        with self._lock:
            return sum(s.size for s in self._completed
                       if epoch is None or s.epoch == epoch)

    def coverage(self, epoch: int = 0) -> Tuple[bool, int, int]:
        """Exactly-once check: (is_exact, covered, duplicated) for an epoch."""
        with self._lock:
            seen = {}
            dup = 0
            for s in self._completed:
                if s.epoch != epoch:
                    continue
                for key in range(s.start, s.end):
                    if key in seen:
                        dup += 1
                    seen[key] = True
            covered = len(seen)
            in_flight = any(w.shard is not None and w.shard.epoch == epoch
                            for w in self._workers.values())
            pending = any(s.epoch == epoch for s in self._queue)
            complete = (covered == self.total and dup == 0
                        and not in_flight and not pending)
            return complete, covered, dup


# ---------------------------------------------------------------------------
# Frequency-aware parameter placement (job-master side, RecShard-style)
# ---------------------------------------------------------------------------
class ParameterPlacementService:
    """Aggregates worker row-access reports into placement plans.

    Workers attach per-row embedding lookup *count deltas* (or raw (B, T, H)
    index tensors) to their heartbeats; the job master accumulates them into
    one pooled histogram and answers two planning queries:

    * ``hot_plan(budget)`` — per-table hot-prefix sizes for the fused
      embedding engine's VMEM cache (``pack_hot_ranges``),
    * ``ps_ranges(n_ps)`` — contiguous pooled-row ranges with balanced
      access mass for the PS shards (``balanced_vocab_ranges``), replacing
      uniform vocab striping that funnels skewed traffic onto one hot PS.

    Thread-safe like ``ShardingService``; plans are cheap enough to recompute
    on demand, so there is no cached/stale state to invalidate.
    """

    def __init__(self, table_rows: Sequence[int]):
        from repro.data.synthetic import RowFreqCounter
        self._ctr = RowFreqCounter(table_rows)   # owns the pooled histogram
        self.table_rows = self._ctr.table_rows
        self.offsets = self._ctr.offsets
        self.total_rows = self._ctr.total_rows
        self._lock = threading.Lock()
        self._reports: Dict[str, int] = {}

    def report_counts(self, worker: str, counts: np.ndarray) -> None:
        """Merge a worker's per-row lookup count *delta* (pooled layout)."""
        counts = np.asarray(counts)
        assert counts.shape == (self.total_rows,), counts.shape
        with self._lock:
            self._ctr.counts += counts
            self._ctr.n_lookups += int(counts.sum())
            self._reports[worker] = self._reports.get(worker, 0) + 1

    def report_batch(self, worker: str, sparse: np.ndarray) -> None:
        """Merge one batch of (B, T, H) per-table-local indices directly."""
        with self._lock:
            self._ctr.update(sparse)
            self._reports[worker] = self._reports.get(worker, 0) + 1

    @property
    def counts(self) -> np.ndarray:
        with self._lock:
            return self._ctr.counts.copy()

    def hot_plan(self, budget: int) -> Tuple[int, ...]:
        """Per-table hot-prefix sizes for ``budget`` VMEM cache rows.

        The measured ``table_hot`` plan for the fused embedding engine
        (``pack_hot_ranges`` on the aggregated counts).
        """
        from repro.sharding.policy import pack_hot_ranges
        return pack_hot_ranges(self.counts, self.table_rows, budget)

    def ps_ranges(self, n_ps: int) -> List[Tuple[int, int]]:
        """Balanced contiguous pooled-row range per PS shard.

        ``balanced_vocab_ranges`` on the aggregated counts — the hot-PS fix
        of §2.1/Fig 12, applied at placement time.
        """
        from repro.sharding.policy import balanced_vocab_ranges
        return balanced_vocab_ranges(self.counts, n_ps)

    def imbalance(self, n_ps: int) -> float:
        """max/mean PS load under the current balanced plan (1.0 = ideal)."""
        from repro.sharding.policy import placement_imbalance
        return placement_imbalance(self.counts, self.ps_ranges(n_ps))


# ---------------------------------------------------------------------------
# Live re-planning: decayed rolling counts + hysteresis trigger (paper §4–§5
# dynamic adjustment applied to embedding placement)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ReplanDecision:
    """One accepted live re-plan, ready for ``repro.train.replan`` to apply.

    The decision is expressed in the *current* pooled-row layout ("layout
    space"): ``permutation[row] = new_row`` keeps every row inside its own
    table but frequency-packs each table (hot rows first), after which
    ``table_hot`` prefixes feed the fused engine's VMEM cache and
    ``vocab_ranges`` are the balanced contiguous PS ranges for the new
    layout. ``imbalance_before``/``after`` are max/mean PS load under the
    old and new plans — the quantities the Fig 12 hot-PS rows report.
    """
    observed_at: int                        # tracker batch count at decision
    table_hot: Tuple[int, ...]              # per-table hot-prefix sizes
    vocab_ranges: Tuple[Tuple[int, int], ...]
    permutation: np.ndarray                 # layout row -> new layout row
    imbalance_before: float
    imbalance_after: float


class HotTableTracker:
    """Rolling-count hot/placement tracker with a hysteresis re-plan trigger.

    The static ``ParameterPlacementService`` answers "what is the best plan
    for everything seen so far"; this tracker answers the live question "has
    the access distribution drifted far enough from the *applied* plan to be
    worth a mid-job re-shard". Two mechanisms make that safe to wire into a
    training loop:

    * **Decayed rolling counts** — every ``observe`` first multiplies the
      pooled histogram by ``decay``, so the counts are an exponential moving
      window over recent batches (half-life ``ln 2 / ln(1/decay)`` observes)
      and track drifting zipf skew instead of averaging it away.
    * **Hysteresis** — ``maybe_replan`` only fires when (a) the imbalance of
      the decayed counts under the *currently applied* ranges exceeds
      ``trigger``, (b) the candidate plan improves it by at least
      ``min_gain`` (noise near the threshold cannot thrash), (c) at least
      ``cooldown`` observes have passed since the last applied re-plan, and
      (d) at least ``min_lookups`` of decayed mass has accumulated.

    The caller applies an accepted decision (permute state, recompile — see
    ``repro.train.replan``) and then calls ``mark_applied``, which permutes
    the tracker's own counts into the new layout so observation continues
    seamlessly in the post-replan id space.
    """

    def __init__(self, table_rows: Sequence[int], *, n_ps: int = 4,
                 hot_budget: int = 0, decay: float = 0.9,
                 trigger: float = 1.2, min_gain: float = 0.05,
                 cooldown: int = 8, min_lookups: int = 1024,
                 initial_ranges: Optional[Sequence[Tuple[int, int]]] = None,
                 initial_hot: Optional[Sequence[int]] = None):
        """Args:
          table_rows:  per-table row counts (pooled layout, like the config's
                       ``table_rows``).
          n_ps:        PS shard count the vocab ranges are planned for.
          hot_budget:  total rows of VMEM hot-row cache to plan
                       (``pack_hot_ranges`` budget; 0 plans no cache).
          decay:       per-observe multiplier on the rolling counts.
          trigger:     imbalance (max/mean PS load) that arms a re-plan.
          min_gain:    minimum imbalance improvement a candidate plan must
                       deliver (the hysteresis band).
          cooldown:    minimum observes between applied re-plans.
          min_lookups: minimum decayed lookup mass before any decision.
          initial_ranges: the placement plan already in effect — e.g. from a
                       layout-stamped checkpoint on resume; default = uniform
                       striping (no plan applied yet).
          initial_hot: the cache plan already in effect (same provenance).
        """
        from repro.kernels.fused_embedding import table_offsets
        from repro.sharding.policy import uniform_vocab_ranges
        self.table_rows = tuple(int(r) for r in table_rows)
        self.offsets = np.asarray(table_offsets(self.table_rows), np.int64)
        self.total_rows = int(sum(self.table_rows))
        self.n_ps = int(n_ps)
        self.hot_budget = int(hot_budget)
        self.decay = float(decay)
        self.trigger = float(trigger)
        self.min_gain = float(min_gain)
        self.cooldown = int(cooldown)
        self.min_lookups = float(min_lookups)
        self._lock = threading.Lock()
        self.counts = np.zeros((self.total_rows,), np.float64)
        self._observes = 0
        self._last_replan = -self.cooldown      # first decision is not gated
        self.n_replans = 0
        # the plan currently in effect (default: uniform striping, no cache)
        self.current_ranges: Tuple[Tuple[int, int], ...] = tuple(
            (int(s), int(e)) for s, e in (
                initial_ranges if initial_ranges is not None
                else uniform_vocab_ranges(self.total_rows, self.n_ps)))
        self.current_hot: Optional[Tuple[int, ...]] = (
            None if initial_hot is None
            else tuple(int(k) for k in initial_hot))

    # ------------------------------------------------------------- observing
    def observe(self, sparse: np.ndarray) -> None:
        """Fold one batch of (B, T, H) per-table-local ids into the window.

        Ids are in the *current layout* space — i.e. whatever the training
        step actually looks up (post-remap after earlier re-plans), which is
        exactly what workers see and report.
        """
        sparse = np.asarray(sparse)
        flat = (sparse.astype(np.int64)
                + self.offsets[None, :, None]).reshape(-1)
        with self._lock:
            self.counts *= self.decay
            self.counts += np.bincount(flat, minlength=self.total_rows)
            self._observes += 1

    def observe_counts(self, delta: np.ndarray) -> None:
        """Fold a pre-binned pooled count delta (heartbeat payload form)."""
        delta = np.asarray(delta, np.float64)
        assert delta.shape == (self.total_rows,), delta.shape
        with self._lock:
            self.counts *= self.decay
            self.counts += delta
            self._observes += 1

    # -------------------------------------------------------------- queries
    @property
    def observes(self) -> int:
        """Number of batches folded into the rolling window so far."""
        return self._observes

    def snapshot(self) -> np.ndarray:
        """Copy of the decayed pooled counts (layout space)."""
        with self._lock:
            return self.counts.copy()

    def imbalance(self) -> float:
        """max/mean PS load of the decayed counts under the APPLIED ranges."""
        from repro.sharding.policy import placement_imbalance
        with self._lock:
            return placement_imbalance(self.counts, self.current_ranges)

    # ------------------------------------------------------------- decisions
    def maybe_replan(self) -> Optional[ReplanDecision]:
        """Return a ``ReplanDecision`` if the drift trigger fires, else None.

        Pure planning — nothing is applied; the tracker keeps suggesting the
        same decision until the caller commits it with ``mark_applied``.
        """
        from repro.sharding.policy import (
            balanced_vocab_ranges, frequency_permutation, pack_hot_ranges,
            placement_imbalance,
        )
        with self._lock:
            if self._observes - self._last_replan < self.cooldown:
                return None
            if self.counts.sum() < self.min_lookups:
                return None
            imb_now = placement_imbalance(self.counts, self.current_ranges)
            if imb_now < self.trigger:
                return None
            perm = frequency_permutation(self.counts, self.table_rows)
            packed = np.empty_like(self.counts)
            packed[perm] = self.counts
            ranges = tuple(balanced_vocab_ranges(packed, self.n_ps))
            imb_after = placement_imbalance(packed, ranges)
            if imb_now - imb_after < self.min_gain:
                return None                     # not worth a migration
            hot = pack_hot_ranges(packed, self.table_rows, self.hot_budget)
            return ReplanDecision(
                observed_at=self._observes, table_hot=hot,
                vocab_ranges=ranges, permutation=perm,
                imbalance_before=float(imb_now),
                imbalance_after=float(imb_after))

    def mark_applied(self, decision: ReplanDecision) -> None:
        """Commit a decision: rotate counts into the new layout, arm cooldown.

        Must be called exactly when the training side has permuted its state
        and started remapping ids — from then on ``observe`` receives ids in
        the new layout, and the rolling window is permuted to match.
        """
        with self._lock:
            packed = np.empty_like(self.counts)
            packed[decision.permutation] = self.counts
            self.counts = packed
            self.current_ranges = tuple(decision.vocab_ranges)
            self.current_hot = tuple(decision.table_hot)
            self._last_replan = self._observes
            self.n_replans += 1
