"""Dynamic data sharding (paper §5.1) + frequency-aware parameter placement.

The job master splits the dataset into numerous small, variably-sized shards
kept in a *shards queue*. Workers fetch shards on demand, send periodic
heartbeats carrying *progress offsets*, and report completion. The service:

* requeues the unfinished shard(s) of failed workers (no omission),
* hands stragglers smaller shards (workload rebalancing, consistent quality),
* lets new/restarted workers pull work immediately (fast elasticity),
* guarantees exactly-once *completion* coverage of the sample range.

``ParameterPlacementService`` is the job master's second planning duty: it
aggregates the per-row embedding access counts workers piggyback on their
heartbeats and serves RecShard-style placement plans — hot-row cache prefixes
for the fused embedding engine and balanced contiguous PS row ranges instead
of uniform vocab striping (the paper's hot-PS problem, §2.1/Fig 12, attacked
at placement time).

All methods take an explicit ``now`` timestamp so the service runs identically
under the simulator's virtual clock and a wall clock.
"""
from __future__ import annotations

import collections
import threading
from dataclasses import dataclass, replace
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class Shard:
    """Half-open sample range [start, end) with a unique index."""
    index: int
    start: int
    end: int
    epoch: int = 0

    @property
    def size(self) -> int:
        return self.end - self.start


@dataclass
class WorkerView:
    shard: Optional[Shard] = None
    progress: int = 0                  # samples processed within current shard
    last_heartbeat: float = 0.0
    samples_done: int = 0              # lifetime samples (for straggler detection)
    first_seen: float = 0.0
    is_straggler: bool = False


class ShardingService:
    def __init__(self, total_samples: int, shard_size: int = 256 * 64, *,
                 num_epochs: int = 1, min_shard: int = 64,
                 heartbeat_timeout: float = 30.0,
                 straggler_ratio: float = 0.5):
        assert total_samples > 0 and shard_size > 0
        self.total = total_samples
        self.shard_size = shard_size
        self.min_shard = min_shard
        self.heartbeat_timeout = heartbeat_timeout
        self.straggler_ratio = straggler_ratio
        self.num_epochs = num_epochs
        self._lock = threading.Lock()
        self._queue: Deque[Shard] = collections.deque()
        self._next_index = 0
        self._epoch = 0
        self._workers: Dict[str, WorkerView] = {}
        self._completed: List[Shard] = []
        self._fill_epoch(0)

    # ------------------------------------------------------------------ fill
    def _fill_epoch(self, epoch: int) -> None:
        start = 0
        while start < self.total:
            end = min(start + self.shard_size, self.total)
            self._queue.append(Shard(self._next_index, start, end, epoch))
            self._next_index += 1
            start = end

    # --------------------------------------------------------------- workers
    def _view(self, worker: str, now: float) -> WorkerView:
        if worker not in self._workers:
            self._workers[worker] = WorkerView(first_seen=now, last_heartbeat=now)
        return self._workers[worker]

    def request_shard(self, worker: str, now: float) -> Optional[Shard]:
        """Hand the next shard; stragglers receive a split (smaller) shard."""
        with self._lock:
            self._reap_failures(now)
            w = self._view(worker, now)
            w.last_heartbeat = now
            if w.shard is not None:
                return w.shard                      # already holding one
            if not self._queue:
                if self._epoch + 1 < self.num_epochs:
                    self._epoch += 1
                    self._fill_epoch(self._epoch)
                else:
                    return None
            shard = self._queue.popleft()
            if w.is_straggler and shard.size > self.min_shard:
                half = shard.size // 2
                first = replace(shard, end=shard.start + half)
                second = Shard(self._next_index, shard.start + half, shard.end,
                               shard.epoch)
                self._next_index += 1
                self._queue.appendleft(second)
                shard = first
            w.shard = shard
            w.progress = 0
            return shard

    def heartbeat(self, worker: str, progress: int, now: float) -> None:
        with self._lock:
            w = self._view(worker, now)
            delta = max(0, progress - w.progress)
            w.progress = progress
            w.samples_done += delta
            w.last_heartbeat = now

    def report_done(self, worker: str, shard_index: int, now: float) -> None:
        with self._lock:
            w = self._view(worker, now)
            if w.shard is not None and w.shard.index == shard_index:
                w.samples_done += max(0, w.shard.size - w.progress)
                self._completed.append(w.shard)
                w.shard = None
                w.progress = 0
            w.last_heartbeat = now

    def report_failure(self, worker: str, now: float) -> None:
        """Explicit failure notification (e.g. pod eviction callback)."""
        with self._lock:
            self._fail_worker(worker)

    # ------------------------------------------------------------- liveness
    def _fail_worker(self, worker: str) -> None:
        w = self._workers.get(worker)
        if w is None:
            return
        if w.shard is not None:
            self._queue.appendleft(w.shard)        # requeue unfinished shard
        del self._workers[worker]

    def _reap_failures(self, now: float) -> List[str]:
        dead = [name for name, w in self._workers.items()
                if now - w.last_heartbeat > self.heartbeat_timeout]
        for name in dead:
            self._fail_worker(name)
        return dead

    def check_failures(self, now: float) -> List[str]:
        with self._lock:
            return self._reap_failures(now)

    # ------------------------------------------------------------ stragglers
    def detect_stragglers(self, now: float) -> List[str]:
        """Progress-offset comparison: rate < ratio × median peer rate."""
        with self._lock:
            rates = {}
            for name, w in self._workers.items():
                dt = max(now - w.first_seen, 1e-9)
                rates[name] = (w.samples_done + w.progress) / dt
            if len(rates) < 2:
                return []
            vals = sorted(rates.values())
            median = vals[len(vals) // 2]
            out = []
            for name, rate in rates.items():
                w = self._workers[name]
                was = w.is_straggler
                w.is_straggler = median > 0 and rate < self.straggler_ratio * median
                if w.is_straggler and not was:
                    out.append(name)
            return out

    # ------------------------------------------------------------- accounting
    @property
    def epochs_completed(self) -> int:
        return self._epoch

    def pending_count(self) -> int:
        with self._lock:
            return len(self._queue)

    def completed_samples(self, epoch: Optional[int] = None) -> int:
        with self._lock:
            return sum(s.size for s in self._completed
                       if epoch is None or s.epoch == epoch)

    def coverage(self, epoch: int = 0) -> Tuple[bool, int, int]:
        """Exactly-once check: (is_exact, covered, duplicated) for an epoch."""
        with self._lock:
            seen = {}
            dup = 0
            for s in self._completed:
                if s.epoch != epoch:
                    continue
                for key in range(s.start, s.end):
                    if key in seen:
                        dup += 1
                    seen[key] = True
            covered = len(seen)
            in_flight = any(w.shard is not None and w.shard.epoch == epoch
                            for w in self._workers.values())
            pending = any(s.epoch == epoch for s in self._queue)
            complete = (covered == self.total and dup == 0
                        and not in_flight and not pending)
            return complete, covered, dup


# ---------------------------------------------------------------------------
# Frequency-aware parameter placement (job-master side, RecShard-style)
# ---------------------------------------------------------------------------
class ParameterPlacementService:
    """Aggregates worker row-access reports into placement plans.

    Workers attach per-row embedding lookup *count deltas* (or raw (B, T, H)
    index tensors) to their heartbeats; the job master accumulates them into
    one pooled histogram and answers two planning queries:

    * ``hot_plan(budget)`` — per-table hot-prefix sizes for the fused
      embedding engine's VMEM cache (``pack_hot_ranges``),
    * ``ps_ranges(n_ps)`` — contiguous pooled-row ranges with balanced
      access mass for the PS shards (``balanced_vocab_ranges``), replacing
      uniform vocab striping that funnels skewed traffic onto one hot PS.

    Thread-safe like ``ShardingService``; plans are cheap enough to recompute
    on demand, so there is no cached/stale state to invalidate.
    """

    def __init__(self, table_rows: Sequence[int]):
        from repro.data.synthetic import RowFreqCounter
        self._ctr = RowFreqCounter(table_rows)   # owns the pooled histogram
        self.table_rows = self._ctr.table_rows
        self.offsets = self._ctr.offsets
        self.total_rows = self._ctr.total_rows
        self._lock = threading.Lock()
        self._reports: Dict[str, int] = {}

    def report_counts(self, worker: str, counts: np.ndarray) -> None:
        """Merge a worker's per-row lookup count *delta* (pooled layout)."""
        counts = np.asarray(counts)
        assert counts.shape == (self.total_rows,), counts.shape
        with self._lock:
            self._ctr.counts += counts
            self._ctr.n_lookups += int(counts.sum())
            self._reports[worker] = self._reports.get(worker, 0) + 1

    def report_batch(self, worker: str, sparse: np.ndarray) -> None:
        """Merge one batch of (B, T, H) per-table-local indices directly."""
        with self._lock:
            self._ctr.update(sparse)
            self._reports[worker] = self._reports.get(worker, 0) + 1

    @property
    def counts(self) -> np.ndarray:
        with self._lock:
            return self._ctr.counts.copy()

    def hot_plan(self, budget: int) -> Tuple[int, ...]:
        from repro.sharding.policy import pack_hot_ranges
        return pack_hot_ranges(self.counts, self.table_rows, budget)

    def ps_ranges(self, n_ps: int) -> List[Tuple[int, int]]:
        from repro.sharding.policy import balanced_vocab_ranges
        return balanced_vocab_ranges(self.counts, n_ps)

    def imbalance(self, n_ps: int) -> float:
        """max/mean PS load under the current balanced plan (1.0 = ideal)."""
        from repro.sharding.policy import placement_imbalance
        return placement_imbalance(self.counts, self.ps_ranges(n_ps))
