"""Logical-axis sharding rules → concrete NamedShardings.

The paper's PS architecture maps onto a 2-D/3-D device mesh:

* ``"data"`` (and ``"pod"``) — the *worker* axis: batch/data parallel, FSDP
  parameter sharding (the paper's ``w`` and, across pods, elastic scale-out).
* ``"model"`` — the *parameter-server* axis: embedding rows (vocab), attention
  heads, FFN hidden, experts (the paper's ``p``; embedding tables distributed
  across PSes, §2.1/§4.1).

Every parameter/activation is annotated with *logical* axis names; per
(arch × shape × mesh) the policy resolves them to mesh axes, handling
non-divisible cases (e.g. 24 query heads on a 16-way model axis) by falling
back to sequence sharding for attention.
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig

# logical axis vocabulary ----------------------------------------------------
#   batch     activation batch dim
#   qseq      query sequence dim (activations)
#   kvseq     KV-cache sequence dim (decode)
#   heads     attention query heads (params + activations)
#   kv_heads  attention KV heads
#   vocab     embedding-table rows / logits vocab dim
#   fsdp      weight dim sharded ZeRO-style over the data axis
#   tp        weight hidden dim sharded over the model axis (ffn/d_inner/lru)
#   expert    MoE expert dim
#   (None)    replicated

_STATE = threading.local()


@dataclass(frozen=True)
class ShardingPolicy:
    mesh: Optional[Mesh]
    rules: Dict[str, Tuple[str, ...]] = field(default_factory=dict)

    # -- resolution ---------------------------------------------------------
    def spec(self, names: Sequence[Optional[str]]) -> P:
        parts = []
        used = set()
        for n in names:
            axes = tuple(a for a in self.rules.get(n, ()) if a not in used) if n else ()
            used.update(axes)
            if len(axes) == 0:
                parts.append(None)
            elif len(axes) == 1:
                parts.append(axes[0])
            else:
                parts.append(axes)
        return P(*parts)

    def sharding(self, names: Sequence[Optional[str]]) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(names))

    def axis_size(self, logical: str) -> int:
        if self.mesh is None:
            return 1
        n = 1
        for a in self.rules.get(logical, ()):
            n *= self.mesh.shape[a]
        return n


NULL_POLICY = ShardingPolicy(mesh=None, rules={})


def current_policy() -> ShardingPolicy:
    return getattr(_STATE, "policy", NULL_POLICY)


@contextlib.contextmanager
def use_policy(policy: ShardingPolicy):
    prev = getattr(_STATE, "policy", NULL_POLICY)
    _STATE.policy = policy
    try:
        yield policy
    finally:
        _STATE.policy = prev


def constrain(x, names: Sequence[Optional[str]]):
    """with_sharding_constraint under the active policy (no-op without mesh)."""
    pol = current_policy()
    if pol.mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, pol.sharding(names))


def logical_spec(tree, spec_tree, policy: Optional[ShardingPolicy] = None):
    """Map a logical-axis spec tree to NamedShardings mirroring ``tree``."""
    pol = policy or current_policy()
    return jax.tree.map(
        lambda names: pol.sharding(names),
        spec_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(i, (str, type(None))) for i in x),
    )


# ---------------------------------------------------------------------------
def make_policy(mesh: Optional[Mesh], cfg: ModelConfig, shape: ShapeConfig,
                overrides: Optional[Dict[str, Tuple[str, ...]]] = None) -> ShardingPolicy:
    """Resolve logical-axis rules for one (arch × shape × mesh) cell."""
    if mesh is None:
        return NULL_POLICY
    axes = dict(mesh.shape)
    data_axes = tuple(a for a in ("pod", "data") if a in axes)
    model_ax = ("model",) if "model" in axes else ()
    model_size = axes.get("model", 1)
    data_size = 1
    for a in data_axes:
        data_size *= axes[a]

    rules: Dict[str, Tuple[str, ...]] = {
        "vocab": model_ax,
        "fsdp": ("data",) if "data" in axes else (),
        "tp": model_ax,
        "ffn": model_ax,
    }

    # Decode is weight-streaming-bound: if the bf16 params fit in HBM when
    # sharded over "model" alone, replicate across "data" (no per-step FSDP
    # all-gather; each chip reads weights from local HBM). Large MoE (e.g.
    # mixtral-8x22b) keeps FSDP sharding and streams weights over ICI.
    if shape.kind == "decode":
        params_bf16 = cfg.param_count() * 2.0
        if params_bf16 / max(model_size, 1) <= 12e9:
            rules["fsdp"] = ()

    # --- batch -------------------------------------------------------------
    if shape.global_batch % max(data_size, 1) == 0 and shape.global_batch >= data_size:
        rules["batch"] = data_axes
    else:
        # e.g. long_500k batch=1: free the data axis for sequence sharding
        rules["batch"] = ()

    # --- attention heads vs sequence sharding ------------------------------
    heads_ok = cfg.n_heads > 0 and cfg.n_heads % max(model_size, 1) == 0
    kv_ok = cfg.n_kv_heads > 0 and cfg.n_kv_heads % max(model_size, 1) == 0
    rules["heads"] = model_ax if heads_ok else ()
    rules["kv_heads"] = model_ax if (heads_ok and kv_ok) else ()
    # when heads cannot shard, shard the query sequence over the model axis
    rules["qseq"] = () if heads_ok else model_ax

    # --- KV-cache sequence (decode) -----------------------------------------
    rules["kvseq"] = ()
    if shape.kind == "decode":
        if rules["batch"] == ():
            # flash-decode: single long sequence, cache sharded over data axes
            rules["kvseq"] = data_axes
        elif not kv_ok:
            # kv heads don't divide the model axis: shard the cache sequence
            # over "model" instead (distributed softmax); q heads replicated
            rules["kvseq"] = model_ax
            rules["heads"] = ()
            rules["kv_heads"] = ()

    # --- experts -------------------------------------------------------------
    # Expert weights are TP-sharded inside each expert (ffn dim over "model")
    # rather than placing the expert dim on the mesh: dispatch then stays
    # fully shard-local (no all-to-all), and weights stream via the FSDP
    # all-gather — cheaper than moving token activations for these configs
    # (tokens·k·d  >>  expert param bytes per layer). Measured on
    # granite-moe: expert-dim sharding + global dispatch cost 245 GB/step of
    # collectives; this layout costs ~8 GB/step.
    rules["expert"] = ()
    rules["expert_ffn"] = model_ax

    # --- ssm / recurrent hidden ----------------------------------------------
    di = cfg.d_inner if cfg.ssm_state else (cfg.lru_width or 0)
    rules["inner"] = model_ax if (di and di % max(model_size, 1) == 0) else ()
    nh_ssm = cfg.ssm_nheads if cfg.ssm_state else 0
    rules["ssm_heads"] = model_ax if (nh_ssm and nh_ssm % max(model_size, 1) == 0) else ()

    if overrides:
        rules.update(overrides)
    return ShardingPolicy(mesh=mesh, rules=rules)
