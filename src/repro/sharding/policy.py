"""Logical-axis sharding rules → concrete NamedShardings.

The paper's PS architecture maps onto a 2-D/3-D device mesh:

* ``"data"`` (and ``"pod"``) — the *worker* axis: batch/data parallel, FSDP
  parameter sharding (the paper's ``w`` and, across pods, elastic scale-out).
* ``"model"`` — the *parameter-server* axis: embedding rows (vocab), attention
  heads, FFN hidden, experts (the paper's ``p``; embedding tables distributed
  across PSes, §2.1/§4.1). For skewed DLRM traffic the vocab axis carries an
  optional *balanced range plan* (``ShardingPolicy.vocab_ranges``): contiguous
  pooled-row ranges with ~equal access mass per PS, planned by
  ``balanced_vocab_ranges`` and re-planned live by
  ``repro.core.sharding_service.HotTableTracker`` — the placement-time fix
  for the paper's hot-PS problem, replacing blind uniform striping.

Every parameter/activation is annotated with *logical* axis names; per
(arch × shape × mesh) the policy resolves them to mesh axes, handling
non-divisible cases (e.g. 24 query heads on a 16-way model axis) by falling
back to sequence sharding for attention.
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig

# logical axis vocabulary ----------------------------------------------------
#   batch     activation batch dim
#   qseq      query sequence dim (activations)
#   kvseq     KV-cache sequence dim (decode)
#   heads     attention query heads (params + activations)
#   kv_heads  attention KV heads
#   vocab     embedding-table rows / logits vocab dim
#   fsdp      weight dim sharded ZeRO-style over the data axis
#   tp        weight hidden dim sharded over the model axis (ffn/d_inner/lru)
#   expert    MoE expert dim
#   (None)    replicated

_STATE = threading.local()


@dataclass(frozen=True)
class ShardingPolicy:
    """Resolved logical-axis rules for one (arch × shape × mesh) cell.

    ``rules`` maps each logical axis name to the mesh axes it shards over.
    ``vocab_ranges``, when set, is the frequency-balanced contiguous
    pooled-row plan for the PS ("vocab") axis — the paper's hot-PS fix.
    GSPMD NamedShardings can only express equal splits, so the ranges ride
    on the policy for every layer that *places* rows (the replan
    orchestrator, PS cost/placement models, benchmarks), while ``spec``
    keeps producing the equal-split approximation for compiled collectives.
    """
    mesh: Optional[Mesh]
    rules: Dict[str, Tuple[str, ...]] = field(default_factory=dict)
    vocab_ranges: Optional[Tuple[Tuple[int, int], ...]] = None

    # -- resolution ---------------------------------------------------------
    def spec(self, names: Sequence[Optional[str]]) -> P:
        """Resolve logical axis names to a concrete ``PartitionSpec``.

        Args:
          names: one logical axis name (or None = replicated) per array dim.

        Returns a ``PartitionSpec`` where each mesh axis is used at most once
        (duplicates later in ``names`` fall back to replication).
        """
        parts = []
        used = set()
        for n in names:
            axes = tuple(a for a in self.rules.get(n, ()) if a not in used) if n else ()
            used.update(axes)
            if len(axes) == 0:
                parts.append(None)
            elif len(axes) == 1:
                parts.append(axes[0])
            else:
                parts.append(axes)
        return P(*parts)

    def sharding(self, names: Sequence[Optional[str]]) -> Optional[NamedSharding]:
        """``spec(names)`` bound to this policy's mesh (None without a mesh)."""
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(names))

    def axis_size(self, logical: str) -> int:
        """Number of shards a logical axis is split into (1 = replicated)."""
        if self.mesh is None:
            return 1
        n = 1
        for a in self.rules.get(logical, ()):
            n *= self.mesh.shape[a]
        return n

    # -- balanced PS row placement (hot-PS fix, §2.1/Fig 12) ----------------
    def with_vocab_ranges(
            self, ranges: Optional[Sequence[Tuple[int, int]]]) -> "ShardingPolicy":
        """Copy of this policy carrying a balanced vocab-range plan.

        Args:
          ranges: contiguous pooled-row ``(start, end)`` per PS shard (e.g.
                  from ``balanced_vocab_ranges`` or a ``ReplanDecision``), or
                  None to drop back to uniform striping.
        """
        if ranges is None:
            return replace(self, vocab_ranges=None)
        return replace(self, vocab_ranges=tuple(
            (int(s), int(e)) for s, e in ranges))

    def ps_row_ranges(self, total_rows: int) -> List[Tuple[int, int]]:
        """Pooled-row range each PS shard owns under this policy.

        The balanced plan when one is attached, otherwise the uniform
        striping the "vocab" rule implies (``axis_size("vocab")`` equal
        contiguous splits — what GSPMD physically materializes).

        Args:
          total_rows: pooled embedding row count (``sum(table_rows)``).

        Returns one ``(start, end)`` half-open range per PS shard.
        """
        if self.vocab_ranges is not None:
            return list(self.vocab_ranges)
        return uniform_vocab_ranges(total_rows, self.axis_size("vocab"))


NULL_POLICY = ShardingPolicy(mesh=None, rules={})


def current_policy() -> ShardingPolicy:
    """The thread-active policy installed by ``use_policy`` (or NULL_POLICY)."""
    return getattr(_STATE, "policy", NULL_POLICY)


@contextlib.contextmanager
def use_policy(policy: ShardingPolicy):
    """Context manager installing ``policy`` as the thread-active policy."""
    prev = getattr(_STATE, "policy", NULL_POLICY)
    _STATE.policy = policy
    try:
        yield policy
    finally:
        _STATE.policy = prev


def constrain(x, names: Sequence[Optional[str]]):
    """with_sharding_constraint under the active policy (no-op without mesh)."""
    pol = current_policy()
    if pol.mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, pol.sharding(names))


def logical_spec(tree, spec_tree, policy: Optional[ShardingPolicy] = None):
    """Map a logical-axis spec tree to NamedShardings mirroring ``tree``."""
    pol = policy or current_policy()
    return jax.tree.map(
        lambda names: pol.sharding(names),
        spec_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(i, (str, type(None))) for i in x),
    )


# ---------------------------------------------------------------------------
def make_policy(mesh: Optional[Mesh], cfg: ModelConfig, shape: ShapeConfig,
                overrides: Optional[Dict[str, Tuple[str, ...]]] = None) -> ShardingPolicy:
    """Resolve logical-axis rules for one (arch × shape × mesh) cell."""
    if mesh is None:
        return NULL_POLICY
    axes = dict(mesh.shape)
    data_axes = tuple(a for a in ("pod", "data") if a in axes)
    model_ax = ("model",) if "model" in axes else ()
    model_size = axes.get("model", 1)
    data_size = 1
    for a in data_axes:
        data_size *= axes[a]

    rules: Dict[str, Tuple[str, ...]] = {
        "vocab": model_ax,
        "fsdp": ("data",) if "data" in axes else (),
        "tp": model_ax,
        "ffn": model_ax,
    }

    # Decode is weight-streaming-bound: if the bf16 params fit in HBM when
    # sharded over "model" alone, replicate across "data" (no per-step FSDP
    # all-gather; each chip reads weights from local HBM). Large MoE (e.g.
    # mixtral-8x22b) keeps FSDP sharding and streams weights over ICI.
    if shape.kind == "decode":
        params_bf16 = cfg.param_count() * 2.0
        if params_bf16 / max(model_size, 1) <= 12e9:
            rules["fsdp"] = ()

    # --- batch -------------------------------------------------------------
    if shape.global_batch % max(data_size, 1) == 0 and shape.global_batch >= data_size:
        rules["batch"] = data_axes
    else:
        # e.g. long_500k batch=1: free the data axis for sequence sharding
        rules["batch"] = ()

    # --- attention heads vs sequence sharding ------------------------------
    heads_ok = cfg.n_heads > 0 and cfg.n_heads % max(model_size, 1) == 0
    kv_ok = cfg.n_kv_heads > 0 and cfg.n_kv_heads % max(model_size, 1) == 0
    rules["heads"] = model_ax if heads_ok else ()
    rules["kv_heads"] = model_ax if (heads_ok and kv_ok) else ()
    # when heads cannot shard, shard the query sequence over the model axis
    rules["qseq"] = () if heads_ok else model_ax

    # --- KV-cache sequence (decode) -----------------------------------------
    rules["kvseq"] = ()
    if shape.kind == "decode":
        if rules["batch"] == ():
            # flash-decode: single long sequence, cache sharded over data axes
            rules["kvseq"] = data_axes
        elif not kv_ok:
            # kv heads don't divide the model axis: shard the cache sequence
            # over "model" instead (distributed softmax); q heads replicated
            rules["kvseq"] = model_ax
            rules["heads"] = ()
            rules["kv_heads"] = ()

    # --- experts -------------------------------------------------------------
    # Expert weights are TP-sharded inside each expert (ffn dim over "model")
    # rather than placing the expert dim on the mesh: dispatch then stays
    # fully shard-local (no all-to-all), and weights stream via the FSDP
    # all-gather — cheaper than moving token activations for these configs
    # (tokens·k·d  >>  expert param bytes per layer). Measured on
    # granite-moe: expert-dim sharding + global dispatch cost 245 GB/step of
    # collectives; this layout costs ~8 GB/step.
    rules["expert"] = ()
    rules["expert_ffn"] = model_ax

    # --- ssm / recurrent hidden ----------------------------------------------
    di = cfg.d_inner if cfg.ssm_state else (cfg.lru_width or 0)
    rules["inner"] = model_ax if (di and di % max(model_size, 1) == 0) else ()
    nh_ssm = cfg.ssm_nheads if cfg.ssm_state else 0
    rules["ssm_heads"] = model_ax if (nh_ssm and nh_ssm % max(model_size, 1) == 0) else ()

    if overrides:
        rules.update(overrides)
    return ShardingPolicy(mesh=mesh, rules=rules)


def make_dlrm_policy(mesh: Optional[Mesh],
                     vocab_ranges: Optional[Sequence[Tuple[int, int]]] = None
                     ) -> ShardingPolicy:
    """Policy for the paper's own DLRM workloads (pooled tables over PSes).

    The pooled embedding rows ("vocab") shard over the "model" axis — the PS
    fleet of §2.1 — and activations ("batch") over the data axes. A balanced
    ``vocab_ranges`` plan (from ``balanced_vocab_ranges`` or a live
    ``ReplanDecision``) rides on the policy so every placement-aware layer
    sees frequency-balanced PS ranges instead of uniform striping.

    Args:
      mesh:         device mesh (None = single host, no sharding).
      vocab_ranges: optional balanced contiguous pooled-row plan.

    Returns the resolved ``ShardingPolicy``.
    """
    if mesh is None:
        return NULL_POLICY.with_vocab_ranges(vocab_ranges)
    axes = dict(mesh.shape)
    data_axes = tuple(a for a in ("pod", "data") if a in axes)
    rules: Dict[str, Tuple[str, ...]] = {
        "vocab": ("model",) if "model" in axes else (),
        "batch": data_axes,
    }
    return ShardingPolicy(mesh=mesh, rules=rules).with_vocab_ranges(vocab_ranges)


# ---------------------------------------------------------------------------
# Frequency-aware pooled-row placement (RecShard-style, feeds the fused
# embedding engine's hot-row cache and the PS row-range assignment)
# ---------------------------------------------------------------------------
def pack_hot_ranges(counts: np.ndarray, table_rows: Sequence[int],
                    budget: int) -> Tuple[int, ...]:
    """Per-table hot-prefix sizes from pooled row-access counts.

    Picks the globally most-frequent ``budget`` rows and returns how many of
    them land in each table — the ``table_hot`` argument of the fused
    embedding engine. Assumes rows are frequency-packed within each table
    (hot ids lead; see ``frequency_permutation`` for hashed layouts), so the
    returned prefix of table ``t`` covers exactly its selected hot rows.
    RecShard's statistical tiering applied to the VMEM cache (paper §2.1's
    lookup hot spot).

    Args:
      counts:     (sum(table_rows),) pooled per-row access counts.
      table_rows: per-table row counts (defines table boundaries).
      budget:     total cache rows to plan (clipped to the pool size).

    Returns per-table hot-prefix sizes; never caches never-touched rows, so
    the sizes may sum to less than ``budget``.
    """
    counts = np.asarray(counts)
    table_rows = tuple(int(r) for r in table_rows)
    assert counts.shape == (sum(table_rows),), (counts.shape, sum(table_rows))
    budget = min(int(budget), counts.size)
    if budget <= 0:
        return (0,) * len(table_rows)
    top = np.argpartition(counts, -budget)[-budget:]
    top = top[counts[top] > 0]              # never cache rows never touched
    bounds = np.cumsum((0,) + table_rows)
    per_table = np.histogram(top, bins=bounds)[0]
    return tuple(int(k) for k in per_table)


def frequency_permutation(counts: np.ndarray,
                          table_rows: Sequence[int]) -> np.ndarray:
    """Per-table remap old-local-id -> frequency rank (hot rows first).

    ``perm[global_row] = new_global_row`` keeps every row inside its own
    table but reorders each table by descending access count, producing the
    frequency-packed layout `pack_hot_ranges` and the hot-row cache assume.
    Apply it to the pool rows once at (re)build time and to incoming ids at
    ingestion — the remap itself never sits on the training hot path. Live
    re-plans re-derive it from decayed counts and apply it with
    ``repro.train.replan.permute_train_state`` (bit-exact, §5.2-style
    restore onto the new layout).

    Args:
      counts:     (sum(table_rows),) pooled per-row access counts.
      table_rows: per-table row counts (permutation never crosses tables).

    Returns the (sum(table_rows),) int64 permutation, stable within ties.
    """
    counts = np.asarray(counts)
    perm = np.empty((counts.size,), np.int64)
    off = 0
    for rows in table_rows:
        rows = int(rows)
        order = np.argsort(-counts[off:off + rows], kind="stable")
        perm[off + order] = off + np.arange(rows)
        off += rows
    return perm


def uniform_vocab_ranges(total_rows: int, n_shards: int) -> List[Tuple[int, int]]:
    """Equal-size contiguous pooled-row range per PS shard (blind striping).

    The skew-oblivious baseline that ``balanced_vocab_ranges`` replaces —
    and what GSPMD equal splits physically materialize. Kept as the single
    source of the striping formula for the policy, the hot tracker's initial
    plan, and the benchmarks' baseline rows.

    Args:
      total_rows: pooled embedding row count.
      n_shards:   PS shard count.

    Returns ``n_shards`` half-open ``(start, end)`` ranges covering
    ``[0, total_rows)``.
    """
    n = max(1, int(n_shards))
    return [(i * total_rows // n, (i + 1) * total_rows // n) for i in range(n)]


def balanced_vocab_ranges(counts: np.ndarray,
                          n_shards: int) -> List[Tuple[int, int]]:
    """Contiguous pooled-row ranges with ~equal access mass per PS shard.

    Replaces uniform row striping over the "vocab" axis: a uniform split
    sends nearly all the skewed traffic to whichever shard holds the hot
    head, while equal-mass boundaries (inverse-CDF of the access histogram)
    keep per-PS lookup load balanced — the paper's hot-PS mitigation, applied
    at placement time instead of after the fact. Attach the result to a
    ``ShardingPolicy`` via ``with_vocab_ranges`` so the sharded training path
    carries the plan alongside its NamedShardings.

    Args:
      counts:   (R,) pooled per-row access counts (zeros = uniform split).
      n_shards: PS shard count.

    Returns ``n_shards`` contiguous half-open ``(start, end)`` ranges
    covering ``[0, R)``; boundary rows go to whichever side leaves the left
    shard's mass closer to its equal-mass target.
    """
    counts = np.asarray(counts, np.float64)
    n_shards = max(1, int(n_shards))
    total = counts.sum()
    if total <= 0:                           # no signal: uniform striping
        edges = np.linspace(0, counts.size, n_shards + 1).astype(np.int64)
    else:
        cum = np.cumsum(counts)
        targets = total * np.arange(1, n_shards) / n_shards
        idx = np.searchsorted(cum, targets)
        # the target falls inside row `idx`: put that row on whichever side
        # leaves the left shard's mass closer to its target
        left = np.where(idx > 0, cum[np.maximum(idx - 1, 0)], 0.0)
        inner = np.where(np.abs(left - targets) <= np.abs(cum[idx] - targets),
                         idx, idx + 1)
        edges = np.concatenate(([0], inner, [counts.size]))
        edges = np.maximum.accumulate(np.clip(edges, 0, counts.size))
    return [(int(edges[i]), int(edges[i + 1])) for i in range(n_shards)]


# ---------------------------------------------------------------------------
# Padded physical PS shards: make the balanced plan what GSPMD places
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class PaddedLayout:
    """Physical padded ``(n_ps, max_range, D)`` placement of a range plan.

    GSPMD ``NamedSharding``s can only express *equal* splits of an array
    axis, so a flat ``(sum(rows), D)`` pool sharded over the PS axis always
    materializes uniform striping — a balanced ``vocab_ranges`` plan riding
    on the policy stays advisory. This layout makes the plan physical:
    shard ``p`` owns exactly ``ranges[p]``'s rows, stored at
    ``padded[p, 0:size_p]`` and tail-padded with zero rows to ``max_range``.
    A ``NamedSharding`` of ``P("model", None, None)`` over the leading axis
    then places *exactly* the balanced plan on the mesh — physically-unequal
    PS shards via an equal split of the padded leading axis.

    Addressing: a flat pooled row ``g`` in ``ranges[p] = (start, end)``
    lives at shard ``p``, slot ``g - start``; equivalently at *padded row*
    ``p * max_range + (g - start)`` of the ``(n_ps * max_range, D)`` reshape
    the fused embedding engine consumes. Padded slots hold zeros, are never
    addressed by a translated index, and therefore contribute nothing to
    pooling and receive zero gradient.

    The dataclass is frozen and tuple-only, hence hashable — it rides in
    jit-static metadata (``fused_embedding_bag``'s custom-VJP meta) and
    recompiles the step exactly when the physical layout changes.
    """
    ranges: Tuple[Tuple[int, int], ...]

    # -- static geometry ----------------------------------------------------
    @property
    def n_ps(self) -> int:
        """PS shard count (leading axis of the padded pool)."""
        return len(self.ranges)

    @property
    def max_range(self) -> int:
        """Rows per physical shard (the largest range, floor 1)."""
        return max(1, max(e - s for s, e in self.ranges))

    @property
    def total_rows(self) -> int:
        """Real pooled rows covered (``sum(table_rows)`` of the job)."""
        return self.ranges[-1][1]

    @property
    def padded_rows(self) -> int:
        """Rows of the ``(n_ps * max_range, D)`` flattened padded pool."""
        return self.n_ps * self.max_range

    @property
    def shard_starts(self) -> Tuple[int, ...]:
        """Flat pooled row where each shard's range begins."""
        return tuple(s for s, _ in self.ranges)

    @property
    def shard_sizes(self) -> Tuple[int, ...]:
        """Real (unpadded) rows each shard physically owns."""
        return tuple(e - s for s, e in self.ranges)

    # -- row translation ----------------------------------------------------
    def shard_slot(self, rows) -> Tuple[np.ndarray, np.ndarray]:
        """Flat pooled rows → ``(shard, slot)`` coordinates.

        Args:
          rows: int array-like of flat pooled row ids in ``[0, total_rows)``.

        Returns ``(shard, slot)`` int64 arrays: ``padded[shard, slot]`` holds
        each row. Empty shards are never selected (their start equals the
        next shard's, and the rightmost match wins).
        """
        rows = np.asarray(rows, np.int64)
        starts = np.asarray(self.shard_starts, np.int64)
        shard = np.clip(np.searchsorted(starts, rows, side="right") - 1,
                        0, self.n_ps - 1)
        return shard, rows - starts[shard]

    def flat_to_padded(self, rows) -> np.ndarray:
        """Flat pooled rows → rows of the flattened padded pool.

        ``flat_to_padded(g) == shard * max_range + slot``; the inverse of
        ``padded_to_flat`` on real (non-padding) rows.
        """
        shard, slot = self.shard_slot(rows)
        return shard * self.max_range + slot

    def padded_to_flat(self, padded) -> np.ndarray:
        """Rows of the flattened padded pool → flat pooled rows.

        Args:
          padded: int array-like of padded row ids; callers must only pass
                  real rows (``padding_mask`` is True), padding slots map
                  onto whatever flat row the arithmetic lands on.
        """
        padded = np.asarray(padded, np.int64)
        shard, slot = padded // self.max_range, padded % self.max_range
        starts = np.asarray(self.shard_starts, np.int64)
        return starts[shard] + slot

    def row_translation(self) -> np.ndarray:
        """The full ``(total_rows,)`` flat → padded row map (int64).

        Memoized on the instance (read-only array): pad/unpad walk several
        pooled leaves per checkpoint or re-plan, and the map is O(rows) to
        build — compute it once per layout, not once per leaf. The cache
        rides outside the dataclass fields, so eq/hash are untouched.
        """
        cached = self.__dict__.get("_row_translation")
        if cached is None:
            cached = self.flat_to_padded(
                np.arange(self.total_rows, dtype=np.int64))
            cached.setflags(write=False)
            object.__setattr__(self, "_row_translation", cached)
        return cached

    def padding_mask(self) -> np.ndarray:
        """(n_ps, max_range) bool mask; True where a real row lives.

        ``mask.sum(axis=1)`` equals ``shard_sizes`` — the materialized
        per-shard row counts the Fig 12 bench checks against the plan.
        """
        sizes = np.asarray(self.shard_sizes, np.int64)[:, None]
        return np.arange(self.max_range, dtype=np.int64)[None, :] < sizes

    # -- array movement -----------------------------------------------------
    def pad_rows(self, flat):
        """(total_rows, ...) flat row array → (n_ps, max_range, ...) padded.

        Real rows are scattered to their (shard, slot); padding slots are
        zeros. Values move, never change — the round trip through
        ``unpad_rows`` is bit-exact.
        """
        import jax.numpy as jnp
        flat = jnp.asarray(flat)
        assert flat.shape[0] == self.total_rows, (flat.shape, self.total_rows)
        out = jnp.zeros((self.padded_rows,) + flat.shape[1:], flat.dtype)
        out = out.at[jnp.asarray(self.row_translation())].set(flat)
        return out.reshape((self.n_ps, self.max_range) + flat.shape[1:])

    def unpad_rows(self, padded):
        """(n_ps, max_range, ...) padded row array → (total_rows, ...) flat."""
        import jax.numpy as jnp
        padded = jnp.asarray(padded)
        assert padded.shape[:2] == (self.n_ps, self.max_range), padded.shape
        flat2d = padded.reshape((self.padded_rows,) + padded.shape[2:])
        return jnp.take(flat2d, jnp.asarray(self.row_translation()), axis=0)


@dataclass(frozen=True)
class EmbeddingPlan:
    """The complete static plan one fused embedding call compiles against.

    Collapses the kwargs that had accreted on ``fused_embedding_bag``
    (``offsets``, ``combiner``, ``block_b``, ``table_hot``, ``layout``) plus
    the fused sparse-update knobs into one frozen, hashable value — the
    single object threaded from the launcher through the trainer, the
    re-planner and ``kernels/ops.py`` down to the kernel's jit-static
    custom-VJP metadata. Hashability means a plan change (a live re-plan
    swapping ``table_hot``/``layout``) recompiles the step exactly once,
    and two calls with equal plans share a compilation cache entry.

    Fields:
      offsets:       static per-table flat-pool row offsets
                     (``kernels.fused_embedding.table_offsets`` output);
                     ``None`` means indices are already global flat rows.
      combiner:      "sum" | "mean" | "max" bag pooling.
      block_b:       batch rows per Pallas grid step (forward kernel).
      table_hot:     per-table hot-prefix sizes for the VMEM hot-row cache;
                     ``None``/all-zero disables the cache.
      layout:        optional ``PaddedLayout`` — the padded physical
                     placement of the pool this plan addresses.
      sparse_update: opt the training step into the fused sparse backward +
                     row-wise optimizer update (``Optimizer.update_rows``)
                     instead of the dense ``segment_sum`` gradient path.
      update_block:  rows per grid step of the fused row-update kernel.
    """
    offsets: Optional[Tuple[int, ...]] = None
    combiner: str = "sum"
    block_b: int = 8
    table_hot: Optional[Tuple[int, ...]] = None
    layout: Optional[PaddedLayout] = None
    sparse_update: bool = False
    update_block: int = 8

    def __post_init__(self) -> None:
        if self.combiner not in ("sum", "mean", "max"):
            raise ValueError(f"unknown combiner: {self.combiner!r}")
        if self.offsets is not None:
            object.__setattr__(
                self, "offsets", tuple(int(o) for o in self.offsets))
        if self.table_hot is not None:
            object.__setattr__(
                self, "table_hot", tuple(int(k) for k in self.table_hot))
        object.__setattr__(self, "block_b", int(self.block_b))
        object.__setattr__(self, "update_block", int(self.update_block))

    @property
    def n_tables(self) -> Optional[int]:
        """Table count the plan describes (``None`` when offsets are unset)."""
        return None if self.offsets is None else len(self.offsets)

    def with_combiner(self, combiner: str) -> "EmbeddingPlan":
        """Same plan, different bag pooling (the wide tower's sum view)."""
        return replace(self, combiner=combiner)

    def with_replan(self, table_hot: Optional[Sequence[int]],
                    layout: Optional[PaddedLayout]) -> "EmbeddingPlan":
        """The plan a live re-plan recompiles with: new cache + placement."""
        hot = None if table_hot is None else tuple(int(k) for k in table_hot)
        return replace(self, table_hot=hot, layout=layout)


def padded_layout_for_ranges(
        ranges: Sequence[Tuple[int, int]]) -> PaddedLayout:
    """Plan the physical padded pool layout for a contiguous range plan.

    Args:
      ranges: one half-open ``(start, end)`` flat pooled-row range per PS
              shard, contiguous from 0 (``balanced_vocab_ranges`` /
              ``uniform_vocab_ranges`` output, or a ``ReplanDecision``'s
              ``vocab_ranges``). Empty ranges are allowed — that shard is
              a fully-padded tail of zeros.

    Returns the validated ``PaddedLayout``.
    """
    rs = tuple((int(s), int(e)) for s, e in ranges)
    assert rs, "at least one shard range required"
    assert rs[0][0] == 0, f"ranges must start at 0, got {rs[0]}"
    for (s, e), (s2, _) in zip(rs, rs[1:]):
        assert e >= s and s2 == e, f"ranges must be contiguous: {rs}"
    assert rs[-1][1] >= rs[-1][0], rs[-1]
    return PaddedLayout(ranges=rs)


def placement_imbalance(counts: np.ndarray,
                        ranges: Sequence[Tuple[int, int]]) -> float:
    """max/mean per-shard access mass (1.0 = perfectly balanced).

    The hot-PS metric of Fig 12 and the live re-plan trigger quantity
    (``HotTableTracker.trigger`` compares against this).

    Args:
      counts: (R,) pooled per-row access counts.
      ranges: one ``(start, end)`` pooled-row range per PS shard.

    Returns the max/mean per-shard lookup load (1.0 when no mass observed).
    """
    counts = np.asarray(counts, np.float64)
    loads = np.array([counts[s:e].sum() for s, e in ranges])
    mean = loads.mean()
    return float(loads.max() / mean) if mean > 0 else 1.0
