from repro.sharding.policy import (  # noqa: F401
    ShardingPolicy, make_policy, make_dlrm_policy, constrain, current_policy,
    use_policy, logical_spec, pack_hot_ranges, balanced_vocab_ranges,
    uniform_vocab_ranges, frequency_permutation, placement_imbalance,
)
