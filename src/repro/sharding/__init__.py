from repro.sharding.policy import (  # noqa: F401
    ShardingPolicy, make_policy, constrain, current_policy, use_policy, logical_spec,
)
