"""Batched serving engine: slot-based continuous batching over a shared KV cache.

Requests enter a queue; the engine keeps ``batch_size`` decode slots. Each
step decodes one token for every active slot (a single jit'd ``decode_step``),
emits finished sequences (EOS or max tokens), and refills free slots from the
queue by prefilling the prompt into that slot's cache region.

Note: for simplicity the engine's cache is per-slot (one shared pytree with
batch dim = slots); prefill uses the sequential ``prefill_into_cache`` path on
CPU-sized models. Production prefill lowers the chunked ``prefill`` graph.
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import ModelAPI


@dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (prompt_len,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None


@dataclass
class Completion:
    rid: int
    tokens: List[int] = field(default_factory=list)


class ServeEngine:
    def __init__(self, api: ModelAPI, params, *, slots: int = 4,
                 max_len: int = 256, greedy: bool = True):
        self.api = api
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.greedy = greedy
        self.queue: collections.deque[Request] = collections.deque()
        self.active: List[Optional[Request]] = [None] * slots
        self.budget: List[int] = [0] * slots
        self.outputs: Dict[int, Completion] = {}
        self.caches = [api.init_cache(1, max_len, jnp.float32)
                       for _ in range(slots)]
        self.next_token = [0] * slots
        self._decode = jax.jit(api.decode_step)
        self.steps = 0

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _prefill_slot(self, slot: int, req: Request) -> None:
        from repro.models.transformer import prefill_into_cache
        cache = self.api.init_cache(1, self.max_len, jnp.float32)
        toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
        cache, logits = prefill_into_cache(self.params, cache, toks, self.api.cfg)
        self.caches[slot] = cache
        self.active[slot] = req
        self.budget[slot] = req.max_new_tokens
        self.outputs[req.rid] = Completion(req.rid)
        last = logits[0, -1]
        self.next_token[slot] = int(jnp.argmax(last))

    def _refill(self) -> None:
        for slot in range(self.slots):
            if self.active[slot] is None and self.queue:
                self._prefill_slot(slot, self.queue.popleft())

    def step(self) -> int:
        """One decode step across all active slots; returns #active."""
        self._refill()
        n_active = 0
        for slot in range(self.slots):
            req = self.active[slot]
            if req is None:
                continue
            n_active += 1
            tok = jnp.full((1, 1), self.next_token[slot], jnp.int32)
            logits, self.caches[slot] = self._decode(self.params,
                                                     self.caches[slot], tok)
            out = self.outputs[req.rid]
            out.tokens.append(self.next_token[slot])
            nxt = int(jnp.argmax(logits[0, -1]))
            self.next_token[slot] = nxt
            self.budget[slot] -= 1
            done = self.budget[slot] <= 0 or (req.eos_id is not None and nxt == req.eos_id)
            if done:
                self.active[slot] = None
        self.steps += 1
        return n_active

    def run(self) -> Dict[int, Completion]:
        while self.queue or any(a is not None for a in self.active):
            self.step()
        return self.outputs
