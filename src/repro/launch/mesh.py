"""Production mesh builders.

Single pod: 16×16 = 256 chips ("data", "model").
Multi-pod: 2×16×16 = 512 chips ("pod", "data", "model") — the "pod" axis is
additional data parallelism across ICI-disjoint pods (DCN-connected), the
elastic scale-out axis of the paper's horizontal scaling.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (possibly fake) local devices exist."""
    return jax.make_mesh((data, model), ("data", "model"))


# TPU v5e hardware constants (roofline denominators)
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link (~per chip, ring)
HBM_PER_CHIP = 16e9               # bytes
