"""Trip-count-exact cost accounting for the roofline analysis.

XLA's ``compiled.cost_analysis()`` counts ``while``/scan bodies ONCE, so a
56-layer scanned transformer reports ~1 layer of FLOPs. This module walks the
*jaxpr* instead, multiplying scan bodies by their trip counts — exact FLOPs
(dot_general/conv, the compute-relevant ops) for any of our step functions,
including remat recomputation (remat_p bodies are traversed like calls).

Also provides first-principles collective-traffic and HBM-traffic models per
(arch × shape × mesh) used for the roofline terms; the HLO-text collective
parse (per-execution) remains in dryrun records as a structural cross-check.
"""
from __future__ import annotations

from typing import Dict

import jax
import numpy as np
from jax import core as jcore

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.common import pad_vocab
from repro.sharding.policy import ShardingPolicy


# ===========================================================================
# jaxpr FLOP counter (exact trip counts)
# ===========================================================================
def _dot_flops(eqn) -> float:
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    dims = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dims
    out = eqn.outvars[0].aval
    contract = 1.0
    for d in lc:
        contract *= lhs.shape[d]
    out_elems = float(np.prod(out.shape)) if out.shape else 1.0
    return 2.0 * out_elems * contract


def _conv_flops(eqn) -> float:
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    out = eqn.outvars[0].aval
    # out_elems × (2 × kernel_spatial × in_channels / feature_groups)
    kernel_elems = float(np.prod(rhs.shape))
    out_spatial = float(np.prod(out.shape))
    fg = eqn.params.get("feature_group_count", 1)
    in_ch = rhs.shape[eqn.params["dimension_numbers"].rhs_spec[1]]
    k_spatial = kernel_elems / (in_ch * rhs.shape[
        eqn.params["dimension_numbers"].rhs_spec[0]])
    return 2.0 * out_spatial * k_spatial * in_ch / max(fg, 1) * fg / fg


def count_jaxpr_flops(jaxpr: jcore.Jaxpr, mult: float = 1.0) -> float:
    total = 0.0
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "dot_general":
            total += mult * _dot_flops(eqn)
        elif name == "conv_general_dilated":
            total += mult * _conv_flops(eqn)
        elif name == "scan":
            body = eqn.params["jaxpr"].jaxpr
            total += count_jaxpr_flops(body, mult * eqn.params["length"])
        elif name == "while":
            body = eqn.params["body_jaxpr"].jaxpr
            # trip count unknown in general; our models only use scan
            total += count_jaxpr_flops(body, mult)
        elif name == "cond":
            branches = eqn.params["branches"]
            if branches:
                total += max(count_jaxpr_flops(b.jaxpr, mult) for b in branches)
        elif "jaxpr" in eqn.params:
            inner = eqn.params["jaxpr"]
            inner = inner.jaxpr if hasattr(inner, "jaxpr") else inner
            total += count_jaxpr_flops(inner, mult)
        elif "call_jaxpr" in eqn.params:
            inner = eqn.params["call_jaxpr"]
            inner = inner.jaxpr if hasattr(inner, "jaxpr") else inner
            total += count_jaxpr_flops(inner, mult)
    return total


def flops_of(fn, *args, **kwargs) -> float:
    """Global (unpartitioned) FLOPs of fn at the given abstract inputs."""
    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    return count_jaxpr_flops(closed.jaxpr)


# ===========================================================================
# analytic collective-traffic model (per device, per step)
# ===========================================================================
def _axis_size(policy: ShardingPolicy, name: str) -> int:
    return max(policy.axis_size(name), 1)


def analytic_collectives(cfg: ModelConfig, shape: ShapeConfig,
                         policy: ShardingPolicy,
                         param_bytes_total: float) -> Dict[str, float]:
    """First-principles per-device collective bytes for one step.

    Components (ring-algorithm per-device traffic ≈ payload size):
      * FSDP: per-step all-gather of params (fwd + bwd) + reduce-scatter of
        grads over the data axis — 3 × local param bytes × (d-1)/d.
      * DP grad sync for non-FSDP-sharded params is covered by the same term.
      * TP: per-layer activation combine over the model axis (2 fwd + 2 bwd
        per transformer layer, Megatron-style), payload = local activations.
      * vocab-sharded logits: all-reduce of the softmax partials (train).
      * decode flash-decode: partial-softmax combine over the cache axis.
    """
    d_data = _axis_size(policy, "fsdp")
    d_model = _axis_size(policy, "tp")
    d_batch = _axis_size(policy, "batch")
    out: Dict[str, float] = {}
    Vp = pad_vocab(cfg.vocab_size)
    dt = 2.0  # bf16 compute
    B, S = shape.global_batch, shape.seq_len

    local_params = param_bytes_total / max(d_data * d_model, 1)
    if shape.kind == "train":
        fsdp_factor = (d_data - 1) / d_data if d_data > 1 else 0.0
        out["fsdp_allgather"] = 2.0 * local_params * fsdp_factor
        out["grad_reduce"] = 1.0 * local_params * fsdp_factor
        tokens_local = B * S / max(d_batch, 1)
        if d_model > 1 and cfg.n_heads:
            heads_ok = cfg.n_heads % d_model == 0
            if heads_ok:
                # Megatron TP: activation combine per block, fwd+bwd
                payload = cfg.d_model * dt
            else:
                # qseq-sharded attention: K/V all-gathered over "model"
                # (GQA keeps this below d_model), fwd + bwd + remat
                payload = min(cfg.d_model, 2 * cfg.n_kv_heads * cfg.head_dim) * dt
            per_layer = 4.0 * tokens_local * payload * (d_model - 1) / d_model
            out["tp_activation"] = per_layer * cfg.num_layers
        out["logits_reduce"] = tokens_local * dt * 2  # logsumexp partials
        # embedding-table lookup gather + embed-grad reduce (vocab-parallel)
        Vd = Vp * cfg.d_model * dt
        if d_model > 1:
            out["embed_lookup_gather"] = Vd * (d_model - 1) / d_model
            out["embed_grad_reduce"] = Vd * (d_model - 1) / d_model
    elif shape.kind == "prefill":
        tokens_local = B * S / max(d_batch, 1)
        fsdp_factor = (d_data - 1) / d_data if d_data > 1 else 0.0
        out["fsdp_allgather"] = local_params * fsdp_factor
        if d_model > 1 and cfg.n_heads:
            out["tp_activation"] = 2.0 * tokens_local * cfg.d_model * dt \
                * (d_model - 1) / d_model * cfg.num_layers
    else:  # decode
        fsdp_factor = (d_data - 1) / d_data if d_data > 1 else 0.0
        out["fsdp_allgather"] = local_params * fsdp_factor
        kv_shards = _axis_size(policy, "kvseq")
        if kv_shards > 1 and cfg.n_heads:
            # flash-decode partial (m, l, o) combine per attention layer
            n_attn = sum(1 for k in cfg.layer_kinds if k in ("global", "local"))
            per_layer = B * cfg.n_heads * (cfg.head_dim + 2) * 4.0 \
                * (kv_shards - 1) / kv_shards
            out["flash_decode_combine"] = per_layer * n_attn
        if d_model > 1 and cfg.n_heads:
            out["tp_activation"] = 2.0 * B * cfg.d_model * dt \
                * (d_model - 1) / d_model * cfg.num_layers
    out["total"] = sum(out.values())
    return out


# ===========================================================================
# analytic HBM-traffic model (per device, per step)
# ===========================================================================
def analytic_hbm_bytes(cfg: ModelConfig, shape: ShapeConfig,
                       policy: ShardingPolicy, param_bytes_total: float,
                       flops_per_device: float) -> Dict[str, float]:
    """Dominant HBM traffic components per device per step."""
    d_data = _axis_size(policy, "fsdp")
    d_model = _axis_size(policy, "tp")
    d_batch = _axis_size(policy, "batch")
    B, S = shape.global_batch, shape.seq_len
    dt = 2.0
    out: Dict[str, float] = {}
    local_params = param_bytes_total / max(d_data * d_model, 1)

    if shape.kind == "train":
        # params read (fwd + bwd + remat fwd) + grads written + adam state r/w
        out["params"] = 3.0 * local_params
        out["grads"] = 2.0 * local_params
        out["optimizer"] = 4.0 * local_params          # m,v read+write (f32≈2×)
        tokens_local = B * S / max(d_batch, 1)
        act_per_layer = tokens_local * cfg.d_model * dt
        out["activations"] = 6.0 * act_per_layer * cfg.num_layers / max(
            d_model if not cfg.n_heads else 1, 1)
        out["logits"] = 2.0 * tokens_local * pad_vocab(cfg.vocab_size) * dt \
            / max(d_model, 1)
    elif shape.kind == "prefill":
        out["params"] = local_params
        tokens_local = B * S / max(d_batch, 1)
        out["activations"] = 4.0 * tokens_local * cfg.d_model * dt * cfg.num_layers
        out["kv_write"] = 2.0 * tokens_local * (cfg.n_kv_heads or 1) \
            * (cfg.head_dim or 1) * dt * cfg.num_layers / max(d_model, 1)
    else:  # decode: weight-streaming + cache read dominate
        out["params"] = local_params
        kv_shards = max(_axis_size(policy, "kvseq"), 1)
        kinds = cfg.layer_kinds
        cache_bytes = 0.0
        for k in kinds:
            if k == "global":
                L = S
            elif k == "local":
                L = min(cfg.local_window, S)
            elif k == "ssm":
                cache_bytes += B * cfg.ssm_nheads * cfg.ssm_headdim \
                    * cfg.ssm_state * 4.0
                continue
            else:  # recurrent
                cache_bytes += B * (cfg.lru_width or cfg.d_model) * 4.0
                continue
            cache_bytes += 2.0 * B * L * (cfg.n_kv_heads or 1) \
                * (cfg.head_dim or 1) * dt / (kv_shards * max(
                    _axis_size(policy, "kv_heads"), 1) * max(d_batch, 1))
        out["kv_cache_read"] = cache_bytes
    out["total"] = sum(out.values())
    return out
