import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape) on the production
mesh, record memory/cost analysis and the collective schedule.

This process (and ONLY this process) fakes 512 host devices — the env var
above must be set before any jax import. Usage:

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] --out experiments/dryrun
"""
import argparse
import json
import re
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig, shape_applicable
from repro.configs.registry import ARCHS, get_arch, get_shape
from repro.launch import costs as costs_mod
from repro.launch.mesh import make_production_mesh
from repro.models.registry import ModelAPI, build_model
from repro.sharding.policy import logical_spec, make_policy, use_policy
from repro.train import optim as optim_mod
from repro.train import trainer as trainer_mod

# ---------------------------------------------------------------------------
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}
_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")
_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes of every collective op in the SPMD module.

    The partitioned module's shapes are per-device shards, so the totals
    approximate per-device collective traffic (ring algorithms move ~the
    result size per device for all-reduce; all-gather results count the full
    gathered tensor a device receives).
    """
    out: Dict[str, int] = {op: 0 for op in _COLL_OPS}
    out["count"] = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        opm = None
        for op in _COLL_OPS:
            if f" {op}(" in s or f" {op}-start(" in s:
                opm = op
                break
        if opm is None:
            continue
        lhs = s.split("=", 1)[1]
        idx = lhs.find(f" {opm}")
        result_type = lhs[:idx]
        total = sum(_shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(result_type))
        out[opm] += total
        out["count"] += 1
    out["total"] = sum(out[op] for op in _COLL_OPS)
    return out


def _memory_dict(compiled) -> Dict[str, Any]:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:                                  # pragma: no cover
        return {"error": str(e)}
    out = {}
    for name in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        val = getattr(ma, name, None)
        if val is not None:
            out[name] = int(val)
    out["repr"] = str(ma)
    return out


def _cost_dict(compiled) -> Dict[str, float]:
    try:
        ca = compiled.cost_analysis()
    except Exception as e:                                  # pragma: no cover
        return {"error": str(e)}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    keep = {}
    for k, v in (ca or {}).items():
        if isinstance(v, (int, float)) and (
                k in ("flops", "transcendentals", "optimal_seconds")
                or k.startswith("bytes accessed")):
            keep[k] = float(v)
    return keep


# ---------------------------------------------------------------------------
def batch_shardings(api: ModelAPI, shape: ShapeConfig, policy):
    rules = {
        "tokens": ("batch", None),
        "targets": ("batch", None),
        "frames": ("batch", None, None),
    }
    specs = api.input_specs(shape)
    return {k: policy.sharding(rules[k]) for k in specs}


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS bookkeeping: 6·N·D train, 2·N·D prefill/decode (MoE: active)."""
    n = cfg.param_count(active_only=cfg.n_experts > 0)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch                     # decode: 1 token each


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               verbose: bool = True, opt_name: str = "adam",
               policy_overrides=None) -> Dict[str, Any]:
    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        rec["skipped"] = why
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    policy = make_policy(mesh, cfg, shape, overrides=policy_overrides)
    api = build_model(cfg)
    master = cfg.param_dtype == "bfloat16"
    optimizer = optim_mod.adam(1e-3, master_weights=master) \
        if opt_name == "adam" else optim_mod.make(opt_name, 1e-3)
    spec_key = "adam_master" if (opt_name == "adam" and master) else opt_name

    t0 = time.perf_counter()
    with mesh, use_policy(policy):
        in_specs = api.input_specs(shape)
        b_shardings = batch_shardings(api, shape, policy)
        if shape.kind == "train":
            state_struct = jax.eval_shape(
                lambda k: trainer_mod.make_train_state(api, optimizer, k),
                jax.random.PRNGKey(0))
            state_sh = logical_spec(
                None, trainer_mod.train_state_specs(api, spec_key), policy)
            step = trainer_mod.make_train_step(api, optimizer, remat=True)
            jitted = jax.jit(step, in_shardings=(state_sh, b_shardings),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_struct, in_specs)
            rec["jaxpr_flops"] = costs_mod.flops_of(step, state_struct, in_specs)
        elif shape.kind == "prefill":
            params_struct = jax.eval_shape(api.init, jax.random.PRNGKey(0))
            params_sh = logical_spec(None, api.param_specs(), policy)
            jitted = jax.jit(api.prefill, in_shardings=(params_sh, b_shardings))
            lowered = jitted.lower(params_struct, in_specs)
            rec["jaxpr_flops"] = costs_mod.flops_of(
                api.prefill, params_struct, in_specs)
        else:  # decode
            params_struct = jax.eval_shape(api.init, jax.random.PRNGKey(0))
            params_sh = logical_spec(None, api.param_specs(), policy)
            cache_struct = jax.eval_shape(
                lambda: api.init_cache(shape.global_batch, shape.seq_len,
                                       jnp.bfloat16))
            cache_sh = logical_spec(None, api.cache_specs(), policy)
            tok_sh = {"tokens": policy.sharding(("batch", None))}
            decode_fn = lambda params, cache, batch: api.decode_step(
                params, cache, batch["tokens"])
            jitted = jax.jit(
                decode_fn,
                in_shardings=(params_sh, cache_sh, tok_sh),
                donate_argnums=(1,))
            lowered = jitted.lower(params_struct, cache_struct, in_specs)
            rec["jaxpr_flops"] = costs_mod.flops_of(
                decode_fn, params_struct, cache_struct, in_specs)
        rec["lower_s"] = time.perf_counter() - t0
        param_bytes = cfg.param_count() * (2.0 if cfg.param_dtype == "bfloat16"
                                           else 4.0)
        rec["analytic_collectives"] = costs_mod.analytic_collectives(
            cfg, shape, policy, param_bytes)
        rec["analytic_hbm"] = costs_mod.analytic_hbm_bytes(
            cfg, shape, policy, param_bytes, rec["jaxpr_flops"] / mesh.size)

        t1 = time.perf_counter()
        compiled = lowered.compile()
        rec["compile_s"] = time.perf_counter() - t1

        rec["memory"] = _memory_dict(compiled)
        rec["cost"] = _cost_dict(compiled)
        try:
            hlo = compiled.as_text()
        except Exception:
            hlo = lowered.as_text()
        rec["collectives"] = collective_bytes_from_hlo(hlo)
        rec["hlo_bytes_len"] = len(hlo)
        rec["model_flops"] = model_flops(cfg, shape)
        rec["params"] = cfg.param_count()
        rec["params_active"] = cfg.param_count(active_only=cfg.n_experts > 0)
        rec["n_devices"] = mesh.size

    if verbose:
        print(f"== {arch} × {shape_name} × {mesh_name} ==")
        print("memory_analysis:", rec["memory"].get("repr", ""))
        print("cost_analysis:", json.dumps(rec["cost"], indent=None))
        print("collectives:", json.dumps(rec["collectives"]))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    cells = []
    if args.all:
        from repro.configs.base import SHAPES
        for arch in ARCHS:
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        cells.append((args.arch, args.shape))

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch}_{shape}_{'2x16x16' if mp else '16x16'}".replace("/", "_")
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path):
                print("skip (exists):", tag)
                continue
            try:
                rec = lower_cell(arch, shape, multi_pod=mp)
            except Exception as e:
                rec = {"arch": arch, "shape": shape,
                       "mesh": "2x16x16" if mp else "16x16",
                       "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-4000:]}
                print("FAILED:", tag, rec["error"])
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)


if __name__ == "__main__":
    main()
