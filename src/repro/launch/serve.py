"""Serving launcher: batched decoding for any --arch (reduced on CPU).

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-2.7b \
        --requests 8 --slots 4 --max-new 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import reduce_config
from repro.configs.registry import get_arch
from repro.models.registry import build_model
from repro.serve.engine import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = reduce_config(get_arch(args.arch))
    if cfg.family == "encdec":
        raise SystemExit("use whisper-specific pipelines for enc-dec serving")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(args.seed))
    eng = ServeEngine(api, params, slots=args.slots, max_len=args.max_len)

    rng = np.random.default_rng(args.seed)
    for rid in range(args.requests):
        plen = int(rng.integers(4, 16))
        eng.submit(Request(rid=rid,
                           prompt=rng.integers(0, cfg.vocab_size, plen),
                           max_new_tokens=args.max_new))
    t0 = time.time()
    outs = eng.run()
    dt = time.time() - t0
    toks = sum(len(c.tokens) for c in outs.values())
    print(f"arch={cfg.name} slots={args.slots}: {toks} tokens "
          f"in {dt:.2f}s ({toks/dt:.1f} tok/s, {eng.steps} steps)")


if __name__ == "__main__":
    main()
