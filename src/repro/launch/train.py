"""Training launcher: train any --arch with the full DLRover-RM substrate.

On this CPU host it runs a reduced config end-to-end (real training); with
--mesh it builds the logical-axis policy and shardings exactly as the
production launch would (the multi-pod path is exercised by dryrun.py).

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b \
        --steps 100 --batch 8 --seq 64 [--reduced/--full] [--ckpt-dir DIR]

The paper's own DLRM workloads run the same way (``--arch wide_deep``,
``xdeepfm`` or ``dcn``) with the live re-planning loop wired in: a
``HotTableTracker`` folds every batch's sparse ids into decayed rolling
counts, and every ``--replan-every`` steps the launcher asks it whether the
placement drifted past ``--imbalance-threshold`` — if so, it snapshots,
permutes the pooled rows, recompiles the step with the measured ``table_hot``
plan, and keeps training on remapped ids (bit-exact across the cut).

    PYTHONPATH=src python -m repro.launch.train --arch wide_deep \
        --steps 200 --zipf-alpha 1.05 --replan-every 20

``--padded-shards`` additionally materializes the plan physically: the
pooled rows are stored padded as (n_ps, max_range, D) so an equal GSPMD
split of the leading axis IS the balanced plan (see
docs/EMBEDDING_LAYOUT.md); re-plans re-pad onto each new plan and
checkpoints stay flat-canonical, so --resume works across layout changes.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs.base import reduce_config
from repro.configs.registry import DLRMS, get_arch, get_dlrm
from repro.core.flash_checkpoint import FlashCheckpoint
from repro.core.sharding_service import HotTableTracker, ShardingService
from repro.data.pipeline import ShardDataLoader
from repro.data.synthetic import criteo_batch, lm_batch
from repro.models.registry import build_model
from repro.sharding.policy import padded_layout_for_ranges, uniform_vocab_ranges
from repro.train import optim, replan, trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=None,
                    help="default: 8 for LMs, the config's batch for DLRMs")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--optimizer", default=None,
                    choices=["adam", "adamw", "adagrad", "sgd"],
                    help="default: adamw for LMs, adagrad for DLRMs")
    ap.add_argument("--full", action="store_true",
                    help="use the full published config (needs real HW)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--resume", action="store_true")
    # --- DLRM / live re-planning knobs (--arch wide_deep|xdeepfm|dcn) ------
    ap.add_argument("--zipf-alpha", type=float, default=1.05,
                    help="power-law skew of the sparse-feature stream (DLRM)")
    ap.add_argument("--hot-rows", type=int, default=64,
                    help="VMEM hot-row cache budget in pooled rows (DLRM)")
    ap.add_argument("--n-ps", type=int, default=4,
                    help="PS shard count the placement plan targets (DLRM)")
    ap.add_argument("--padded-shards", action="store_true",
                    help="materialize physically-unequal PS shards: store the "
                         "pooled rows as a padded (n_ps, max_range, D) array "
                         "so an equal GSPMD split of the leading axis places "
                         "exactly the balanced range plan (DLRM)")
    ap.add_argument("--fused-update", action="store_true",
                    help="fuse the sparse embedding backward + row-wise "
                         "optimizer update into the train step: deduped COO "
                         "row grads feed Optimizer.update_rows, touching "
                         "only looked-up rows (DLRM; adagrad/adam)")
    ap.add_argument("--replan-every", type=int, default=0, metavar="N",
                    help="poll the hot tracker for a re-plan every N steps "
                         "(0 disables live re-planning)")
    ap.add_argument("--imbalance-threshold", type=float, default=1.2,
                    help="max/mean PS load that arms a re-plan")
    # --- chaos / self-healing knobs (DLRM archs only) ----------------------
    ap.add_argument("--chaos", default=None, metavar="SPEC",
                    help="scripted fault plan, e.g. 'ps_loss@10,hang@20:0.5' "
                         "(see repro.core.faults); implies --supervise")
    ap.add_argument("--chaos-seed", type=int, default=0,
                    help="seed of the corruption-byte RNG (determinism)")
    ap.add_argument("--supervise", action="store_true",
                    help="run DLRM training under the recovery supervisor "
                         "(watchdog + restore-with-backoff) even without "
                         "injected faults")
    ap.add_argument("--chaos-proc", default=None, metavar="SPEC",
                    help="process-level fault plan, e.g. 'kill@5' or "
                         "'kill_loop@3x2,stop@7': train in a REAL worker "
                         "subprocess under the job-master daemon, which "
                         "SIGKILLs/SIGSTOPs it per the plan and re-execs it "
                         "from the newest valid checkpoint (see docs/CHAOS.md)")
    ap.add_argument("--workdir", default=None,
                    help="job-master working directory (heartbeats, loss "
                         "logs, per-incarnation worker logs); default: "
                         "a fresh temp dir")
    ap.add_argument("--heartbeat-deadline", type=float, default=30.0,
                    help="job-master staleness deadline in seconds after a "
                         "worker's first 'ready' heartbeat (SIGSTOP/hang "
                         "detection)")
    ap.add_argument("--step-deadline", type=float, default=None,
                    help="watchdog per-step deadline in seconds (hang "
                         "detection; None disables)")
    ap.add_argument("--max-restarts", type=int, default=5,
                    help="capped restart budget of the supervisor")
    ap.add_argument("--event-log", default=None, metavar="PATH",
                    help="write the supervisor's structured event log (JSONL)")
    args = ap.parse_args()

    if args.arch in DLRMS:
        if args.chaos_proc is not None:
            train_dlrm_chaos_proc(args)
        elif args.chaos or args.supervise:
            train_dlrm_supervised(args)
        else:
            train_dlrm(args)
        return
    if args.batch is None:
        args.batch = 8

    cfg = get_arch(args.arch)
    if not args.full:
        cfg = reduce_config(cfg)
    api = build_model(cfg)
    opt = optim.make(args.optimizer or "adamw", args.lr)
    print(f"arch={cfg.name} family={cfg.family} params={cfg.param_count():,} "
          f"({'full' if args.full else 'reduced'})")

    ckpt = FlashCheckpoint(args.ckpt_dir) if args.ckpt_dir else None
    state = None
    if args.resume and ckpt is not None and ckpt.latest_step() is not None:
        like = jax.eval_shape(lambda k: trainer.make_train_state(api, opt, k),
                              jax.random.PRNGKey(0))
        state, step0 = ckpt.restore(like)
        print(f"resumed from step {step0}")
    if state is None:
        state = trainer.make_train_state(api, opt, jax.random.PRNGKey(0))

    step_fn = jax.jit(trainer.make_train_step(
        api, opt, remat=True, grad_compress=args.grad_compress))

    total = args.steps * args.batch
    svc = ShardingService(total, shard_size=max(args.batch * 8, 64))
    loader = ShardDataLoader(
        svc, "worker0",
        lambda idx: lm_batch(0, idx, args.seq, cfg.vocab_size),
        batch_size=args.batch)

    t0 = time.time()
    n = 0
    for batch in loader:
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        if cfg.family == "encdec":
            b["frames"] = jnp.zeros((args.batch, cfg.n_frames, cfg.d_model),
                                    jnp.float32)
        state, m = step_fn(state, b)
        n += 1
        if n % 20 == 0 or n == 1:
            print(f"step {n:5d} loss={float(m['loss']):.4f} "
                  f"gnorm={float(m['grad_norm']):.3f} "
                  f"({n*args.batch/(time.time()-t0):.1f} samples/s)")
        if ckpt is not None and n % args.ckpt_every == 0:
            ckpt.save(state, n)
    ok, covered, dup = svc.coverage(0)
    print(f"done: {n} steps, exactly-once={ok} (covered={covered} dup={dup})")
    if ckpt is not None:
        ckpt.save(state, n)
        ckpt.wait()
        print(f"checkpointed at step {n} -> {args.ckpt_dir}")


def train_dlrm(args) -> None:
    """DLRM training with the live embedding re-planning loop wired in.

    Checkpoints are layout-stamped (``replan.save_with_layout``): each blob
    carries the composed raw-id → layout map and the active cache plan, so
    ``--resume`` in a fresh process keeps training correctly no matter how
    many re-plans the previous run applied.
    """
    from repro.configs.dlrm_models import reduced_dlrm

    cfg = get_dlrm(args.arch)
    if not args.full:
        cfg = reduced_dlrm(cfg)
    cfg = dataclasses.replace(cfg, zipf_alpha=args.zipf_alpha,
                              hot_rows_k=args.hot_rows,
                              batch_size=args.batch or cfg.batch_size)
    opt_name = args.optimizer or "adagrad"       # the classic DLRM optimizer
    opt = optim.make(opt_name, args.lr)
    print(f"arch={cfg.name} kind={cfg.kind} params={cfg.param_count():,} "
          f"rows={cfg.total_embedding_rows:,} zipf_alpha={cfg.zipf_alpha} "
          f"({'full' if args.full else 'reduced'})")

    ckpt = FlashCheckpoint(args.ckpt_dir)
    remapper = replan.EmbeddingRemapper(cfg.table_rows)
    table_hot = None                             # None = cfg default plan
    vocab_ranges = None                          # None = uniform striping
    layout = None                                # None = flat pooled store
    state = None
    if args.resume and ckpt.latest_step() is not None:
        state, step0, remapper, table_hot, vocab_ranges, layout = \
            replan.restore_with_layout(cfg, opt, ckpt)
        print(f"resumed from step {step0} "
              f"(layout-stamped; cache plan {'measured' if table_hot else 'default'}; "
              f"{'padded ' + str(layout.n_ps) + '-shard' if layout else 'flat'} pool)")
    if args.padded_shards and layout is None:
        # fresh padded job (or a flat-era checkpoint upgraded in place):
        # physical shards follow the applied plan, uniform until one exists
        layout = padded_layout_for_ranges(
            vocab_ranges if vocab_ranges is not None
            else uniform_vocab_ranges(cfg.total_embedding_rows, args.n_ps))
        if state is not None:
            state = replan.pad_train_state(
                state, cfg.total_embedding_rows, layout)
    if state is None:
        state = trainer.make_dlrm_train_state(cfg, opt, jax.random.PRNGKey(0),
                                              layout=layout)
    if layout is not None:
        print(f"padded PS shards: n_ps={layout.n_ps} "
              f"max_range={layout.max_range} physical rows/shard="
              f"{list(layout.shard_sizes)} "
              f"(+{layout.padded_rows - cfg.total_embedding_rows} pad rows)")
    plan = cfg.embedding_plan(table_hot=table_hot, layout=layout,
                              sparse_update=args.fused_update)
    if args.fused_update and opt.update_rows is None:
        raise SystemExit(f"--fused-update: optimizer {opt_name!r} has no "
                         "row-update seam (use adagrad or adam)")
    if args.fused_update:
        print("fused sparse update: backward dedupe + row-wise "
              f"{opt_name} on looked-up rows only")
    step_fn = jax.jit(trainer.make_dlrm_train_step(
        cfg, opt, grad_compress=args.grad_compress, plan=plan))

    tracker = HotTableTracker(
        cfg.table_rows, n_ps=args.n_ps, hot_budget=cfg.hot_rows_k,
        trigger=args.imbalance_threshold,
        cooldown=max(args.replan_every, 1),
        min_lookups=4 * cfg.batch_size * cfg.n_tables * cfg.multi_hot,
        initial_ranges=vocab_ranges, initial_hot=table_hot)

    total = args.steps * cfg.batch_size
    svc = ShardingService(total, shard_size=max(cfg.batch_size * 8, 64))
    loader = ShardDataLoader(
        svc, "worker0", lambda idx: criteo_batch(cfg, 11, idx),
        batch_size=cfg.batch_size)

    t0 = time.time()
    n = 0
    for raw in loader:
        batch = remapper.remap_batch(raw)
        tracker.observe(batch["sparse"])        # worker-side heartbeat payload
        state, m = step_fn(state, {k: jnp.asarray(v) for k, v in batch.items()})
        n += 1
        replanned = False
        if n % 20 == 0 or n == 1:
            print(f"step {n:5d} loss={float(m['loss']):.4f} "
                  f"imbalance={tracker.imbalance():.3f} "
                  f"({n*cfg.batch_size/(time.time()-t0):.1f} samples/s)")
        if args.replan_every and n % args.replan_every == 0:
            decision = tracker.maybe_replan()
            if decision is not None:
                # old-layout snapshot (with its own layout stamp) first, so a
                # crash mid-replan loses nothing; apply_replan itself then
                # permutes, re-plans placement, and recompiles
                replan.save_with_layout(ckpt, state, int(state["step"]),
                                        remapper, table_hot, vocab_ranges,
                                        layout=layout)
                res = replan.apply_replan(state, cfg, opt, decision,
                                          remapper=remapper, opt_name=opt_name,
                                          grad_compress=args.grad_compress,
                                          layout=layout, plan=plan)
                tracker.mark_applied(decision)
                state, step_fn, layout = res.state, res.step_fn, res.layout
                plan = res.plan
                table_hot = decision.table_hot
                vocab_ranges = decision.vocab_ranges
                replanned = True
                print(f"step {n:5d} RE-PLAN: imbalance "
                      f"{decision.imbalance_before:.3f} -> "
                      f"{decision.imbalance_after:.3f}, "
                      f"cache rows {sum(decision.table_hot)}"
                      + (f", physical rows/shard {list(layout.shard_sizes)}"
                         if layout is not None else ""))
        if args.ckpt_dir and n % args.ckpt_every == 0 and not replanned:
            # key by the GLOBAL step so resumed runs sort above their
            # pre-resume checkpoints (n restarts at 0 on every process)
            replan.save_with_layout(ckpt, state, int(state["step"]),
                                    remapper, table_hot, vocab_ranges,
                                    layout=layout)
    ok, covered, dup = svc.coverage(0)
    print(f"done: {n} steps, exactly-once={ok} (covered={covered} dup={dup}), "
          f"{tracker.n_replans} re-plan(s), final imbalance "
          f"{tracker.imbalance():.3f}")
    if args.ckpt_dir:
        replan.save_with_layout(ckpt, state, int(state["step"]),
                                remapper, table_hot, vocab_ranges,
                                layout=layout)
        ckpt.wait()
        print(f"checkpointed at step {n} -> {args.ckpt_dir}")


def train_dlrm_supervised(args) -> None:
    """DLRM training under the self-healing supervisor (``--chaos`` /
    ``--supervise``).

    The scripted fault plan fires through the trainer/data/checkpoint hooks;
    the supervisor detects each abnormality (watchdog deadline, typed fault,
    EWMA outlier) and recovers from layout-stamped flash checkpoints —
    the end-to-end §5 reliability loop on the real training path.
    """
    import tempfile

    from repro.configs.dlrm_models import reduced_dlrm
    from repro.core.faults import FaultInjector, parse_chaos_spec
    from repro.train.supervisor import DLRMJob, Supervisor, SupervisorConfig

    cfg = get_dlrm(args.arch)
    if not args.full:
        cfg = reduced_dlrm(cfg)
    cfg = dataclasses.replace(cfg, zipf_alpha=args.zipf_alpha,
                              hot_rows_k=args.hot_rows,
                              batch_size=args.batch or cfg.batch_size)
    opt_name = args.optimizer or "adagrad"
    plan = parse_chaos_spec(args.chaos or "")
    injector = FaultInjector(plan, seed=args.chaos_seed) if plan.specs else None
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="chaos_ckpt_")
    ckpt = FlashCheckpoint(
        ckpt_dir, async_persist=False,      # sync: every blob restorable
        fault_hook=injector.on_persist if injector else None)
    if injector is not None:
        injector.bind_checkpoint(ckpt)
    print(f"arch={cfg.name} kind={cfg.kind} params={cfg.param_count():,} "
          f"supervised (chaos plan: {plan if plan.specs else 'none'}; "
          f"ckpt -> {ckpt_dir})")

    job = DLRMJob(cfg, ckpt, opt_name=opt_name, lr=args.lr,
                  ckpt_every=args.ckpt_every, n_ps=args.n_ps,
                  padded=args.padded_shards,
                  sparse_update=args.fused_update, injector=injector)
    sup = Supervisor(job, SupervisorConfig(
        step_deadline_s=args.step_deadline, max_restarts=args.max_restarts,
        seed=args.chaos_seed))
    try:
        report = sup.run(args.steps, resume=args.resume)
    finally:
        if args.event_log:                  # log survives a failed run too
            sup.write_event_log(args.event_log)
    for ev in report.events:
        print(f"  event step={ev.step:5d} {ev.kind} {ev.detail}")
    lat = report.recovery_latencies_s
    mean_lat = sum(lat) / len(lat) if lat else 0.0
    print(f"CHAOS completed={report.completed} final_step={report.final_step} "
          f"final_loss={report.final_loss:.6f} restarts={report.restarts} "
          f"steps_lost={report.steps_lost} "
          f"goodput={report.goodput_fraction:.3f} "
          f"recovery_latency_mean_s={mean_lat:.4f}")
    if args.event_log:
        sup.write_event_log(args.event_log, report)
        print(f"event log -> {args.event_log}")


def train_dlrm_chaos_proc(args) -> None:
    """DLRM training in a real worker subprocess under the job-master daemon
    (``--chaos-proc``).

    Unlike ``--chaos`` (in-process fault hooks under the supervisor), the
    worker here is an actual OS process the plan SIGKILLs/SIGSTOPs; the
    master detects the death via exit code or stale heartbeat and re-execs
    a fresh incarnation that resumes from the newest valid layout-stamped
    checkpoint — same process tree as a production pod restart.
    """
    import os
    import tempfile

    from repro.train.job_master import JobMaster, JobMasterConfig, WorkerSpec

    workdir = args.workdir or tempfile.mkdtemp(prefix="chaos_proc_")
    spec = WorkerSpec(
        name="worker0", workdir=workdir,
        ckpt_dir=args.ckpt_dir or os.path.join(workdir, "ckpt"),
        arch=args.arch, steps=args.steps, ckpt_every=args.ckpt_every,
        n_ps=args.n_ps, padded=args.padded_shards,
        chaos_proc=args.chaos_proc,
        opt_name=args.optimizer or "adagrad", lr=args.lr)
    master = JobMaster([spec], JobMasterConfig(
        heartbeat_deadline_s=args.heartbeat_deadline,
        max_reexecs=args.max_restarts, seed=args.chaos_seed))
    print(f"arch={args.arch} chaos-proc plan: {args.chaos_proc or 'none'} "
          f"(workdir -> {workdir}, ckpt -> {spec.ckpt_dir})")
    try:
        report = master.run()
    finally:
        if args.event_log:                  # log survives a failed run too
            master.write_event_log(args.event_log)
    for ev in report.events:
        print(f"  event {ev.kind} worker={ev.worker} {ev.detail}")
    t = report.measured_timings()
    losses = spec.read_losses()
    final_loss = losses[-1]["loss"] if losses else float("nan")
    print(f"CHAOS-PROC completed={report.completed} "
          f"final_steps={report.final_steps} reexecs={report.reexecs} "
          f"exit_history={report.exit_history} final_loss={final_loss:.6f} "
          f"reexec_mean_s={t.reexec_s():.3f} "
          f"restore_mean_s={t.flash_ckpt_load_s:.3f} "
          f"wall_s={report.wall_seconds:.1f}")
    if args.event_log:
        master.write_event_log(args.event_log, report)
        print(f"event log -> {args.event_log}")


if __name__ == "__main__":
    main()
