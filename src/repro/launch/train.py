"""Training launcher: train any --arch with the full DLRover-RM substrate.

On this CPU host it runs a reduced config end-to-end (real training); with
--mesh it builds the logical-axis policy and shardings exactly as the
production launch would (the multi-pod path is exercised by dryrun.py).

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b \
        --steps 100 --batch 8 --seq 64 [--reduced/--full] [--ckpt-dir DIR]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import reduce_config
from repro.configs.registry import get_arch
from repro.core.flash_checkpoint import FlashCheckpoint
from repro.core.sharding_service import ShardingService
from repro.data.pipeline import ShardDataLoader
from repro.data.synthetic import lm_batch
from repro.models.registry import build_model
from repro.train import optim, trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adam", "adamw", "adagrad", "sgd"])
    ap.add_argument("--full", action="store_true",
                    help="use the full published config (needs real HW)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if not args.full:
        cfg = reduce_config(cfg)
    api = build_model(cfg)
    opt = optim.make(args.optimizer, args.lr)
    print(f"arch={cfg.name} family={cfg.family} params={cfg.param_count():,} "
          f"({'full' if args.full else 'reduced'})")

    ckpt = FlashCheckpoint(args.ckpt_dir) if args.ckpt_dir else None
    state = None
    if args.resume and ckpt is not None and ckpt.latest_step() is not None:
        like = jax.eval_shape(lambda k: trainer.make_train_state(api, opt, k),
                              jax.random.PRNGKey(0))
        state, step0 = ckpt.restore(like)
        print(f"resumed from step {step0}")
    if state is None:
        state = trainer.make_train_state(api, opt, jax.random.PRNGKey(0))

    step_fn = jax.jit(trainer.make_train_step(
        api, opt, remat=True, grad_compress=args.grad_compress))

    total = args.steps * args.batch
    svc = ShardingService(total, shard_size=max(args.batch * 8, 64))
    loader = ShardDataLoader(
        svc, "worker0",
        lambda idx: lm_batch(0, idx, args.seq, cfg.vocab_size),
        batch_size=args.batch)

    t0 = time.time()
    n = 0
    for batch in loader:
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        if cfg.family == "encdec":
            b["frames"] = jnp.zeros((args.batch, cfg.n_frames, cfg.d_model),
                                    jnp.float32)
        state, m = step_fn(state, b)
        n += 1
        if n % 20 == 0 or n == 1:
            print(f"step {n:5d} loss={float(m['loss']):.4f} "
                  f"gnorm={float(m['grad_norm']):.3f} "
                  f"({n*args.batch/(time.time()-t0):.1f} samples/s)")
        if ckpt is not None and n % args.ckpt_every == 0:
            ckpt.save(state, n)
    ok, covered, dup = svc.coverage(0)
    print(f"done: {n} steps, exactly-once={ok} (covered={covered} dup={dup})")
    if ckpt is not None:
        ckpt.save(state, n)
        ckpt.wait()
        print(f"checkpointed at step {n} -> {args.ckpt_dir}")


if __name__ == "__main__":
    main()
