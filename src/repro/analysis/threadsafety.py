"""thread-safety: cross-thread attribute state must be lock-guarded.

The trainer is deliberately multi-threaded: ``Supervisor`` runs training
attempts on worker threads, ``DLRMJob`` is driven by a watchdog thread
while the main loop reads its state, ``FlashCheckpoint`` persists from a
pool thread. The failure mode is an attribute written under a class's
lock in one method and written bare in another — both paths "work" until
a preemption lands between them.

Per class that owns a lock (``self._lock = threading.Lock()/RLock()/
Condition()`` in ``__init__``), this rule computes:

* **lock regions** — statements inside ``with self._lock:``;
* **effectively-locked methods** — private helpers whose every call site
  (outside ``__init__``) is itself inside a lock region or another
  effectively-locked method (fixed point), so their bodies inherit the
  lock;
* **guarded attributes** — attributes ever written inside a lock region
  or an effectively-locked method.

A write to a guarded attribute outside all of the above (and outside
``__init__`` — construction is single-threaded by Python semantics) is a
finding. Classes with *no* lock are checked for the cruder hazard: a
method handed to ``threading.Thread(target=...)`` / ``pool.submit`` that
writes an attribute some other method also writes.

Deliberately-atomic unguarded attributes (single machine-word stores read
by monitors) are suppressed per line with a justification::

    self.seen += 1  # repolint: ignore[thread-safety] -- monotonic counter, torn reads benign
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set, Tuple

from repro.analysis.engine import Finding, ModuleContext, Rule
from repro.analysis.purity import _attr_chain

_LOCK_TYPES = {"Lock", "RLock", "Condition", "Semaphore", "BoundedSemaphore"}
_MUTATORS = {"append", "extend", "update", "add", "insert", "setdefault",
             "pop", "popitem", "remove", "discard", "clear"}


def _lock_attrs(cls: ast.ClassDef) -> Set[str]:
    attrs: Set[str] = set()
    for node in ast.walk(cls):
        if not isinstance(node, ast.Assign):
            continue
        if not isinstance(node.value, ast.Call):
            continue
        chain = _attr_chain(node.value.func)
        if not chain or chain[-1] not in _LOCK_TYPES:
            continue
        for target in node.targets:
            if isinstance(target, ast.Attribute) \
                    and isinstance(target.value, ast.Name) \
                    and target.value.id == "self":
                attrs.add(target.attr)
    return attrs


def _self_attr(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Attribute) and isinstance(node.value, ast.Name) \
            and node.value.id == "self":
        return node.attr
    return None


class _ClassModel:
    """Writes, self-calls and lock regions of one class, per method."""

    def __init__(self, ctx: ModuleContext, cls: ast.ClassDef,
                 lock_attrs: Set[str]) -> None:
        self.ctx = ctx
        self.cls = cls
        self.lock_attrs = lock_attrs
        self.methods: Dict[str, ast.FunctionDef] = {
            stmt.name: stmt for stmt in cls.body
            if isinstance(stmt, ast.FunctionDef)}
        # (method, attr, node, in_lock)
        self.writes: List[Tuple[str, str, ast.AST, bool]] = []
        # callee -> list of (caller_method, in_lock)
        self.calls: Dict[str, List[Tuple[str, bool]]] = {}
        self.thread_entries: Set[str] = set()
        for name, fn in self.methods.items():
            self._scan_method(name, fn)

    def _in_lock(self, node: ast.AST) -> bool:
        for parent in self.ctx.parents(node):
            if isinstance(parent, ast.With):
                for item in parent.items:
                    attr = _self_attr(item.context_expr)
                    if attr in self.lock_attrs:
                        return True
            if parent is self.cls:
                break
        return False

    def _scan_method(self, method: str, fn: ast.FunctionDef) -> None:
        for node in ast.walk(fn):
            if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                targets = node.targets if isinstance(node, ast.Assign) \
                    else [node.target]
                for target in targets:
                    attr = self._written_attr(target)
                    if attr:
                        self.writes.append(
                            (method, attr, node, self._in_lock(node)))
            elif isinstance(node, ast.Call):
                self._scan_call(method, node)

    @staticmethod
    def _written_attr(target: ast.AST) -> Optional[str]:
        attr = _self_attr(target)
        if attr:
            return attr
        if isinstance(target, ast.Subscript):
            return _self_attr(target.value)
        if isinstance(target, (ast.Tuple, ast.List)):
            for el in target.elts:
                inner = _ClassModel._written_attr(el)
                if inner:
                    return inner
        return None

    def _scan_call(self, method: str, node: ast.Call) -> None:
        func = node.func
        # self.helper(...)
        callee = None
        if isinstance(func, ast.Attribute):
            callee = _self_attr(func)
        if callee and callee in self.methods:
            self.calls.setdefault(callee, []).append(
                (method, self._in_lock(node)))
        # container mutation: self.attr.append(...)
        if isinstance(func, ast.Attribute) and func.attr in _MUTATORS:
            attr = _self_attr(func.value)
            if attr:
                self.writes.append((method, attr, node, self._in_lock(node)))
        # thread handoff: Thread(target=self.m) / pool.submit(self.m, ...)
        chain = _attr_chain(func)
        if chain and chain[-1] == "Thread":
            for kw in node.keywords:
                if kw.arg == "target":
                    entry = _self_attr(kw.value)
                    if entry:
                        self.thread_entries.add(entry)
        if chain and chain[-1] == "submit" and node.args:
            entry = _self_attr(node.args[0])
            if entry:
                self.thread_entries.add(entry)

    def effectively_locked(self) -> Set[str]:
        """Methods whose every non-``__init__`` call site holds the lock."""
        locked: Set[str] = set()
        changed = True
        while changed:
            changed = False
            for callee, sites in self.calls.items():
                if callee in locked or callee == "__init__":
                    continue
                outside = [(m, il) for m, il in sites if m != "__init__"]
                if not outside:
                    continue  # only constructed-time calls: not lock evidence
                if all(il or m in locked for m, il in outside):
                    locked.add(callee)
                    changed = True
        return locked


class ThreadSafetyRule(Rule):
    id = "thread-safety"
    summary = ("attributes guarded by a class lock anywhere must be guarded "
               "everywhere (or suppressed with an atomicity justification)")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            lock_attrs = _lock_attrs(node)
            if lock_attrs:
                yield from self._check_locked_class(ctx, node, lock_attrs)
            else:
                yield from self._check_lockless_class(ctx, node)

    def _check_locked_class(self, ctx: ModuleContext, cls: ast.ClassDef,
                            lock_attrs: Set[str]) -> Iterator[Finding]:
        model = _ClassModel(ctx, cls, lock_attrs)
        eff_locked = model.effectively_locked()
        guarded: Set[str] = set()
        for method, attr, _node, in_lock in model.writes:
            if in_lock or (method in eff_locked and method != "__init__"):
                guarded.add(attr)
        guarded -= lock_attrs
        for method, attr, wnode, in_lock in model.writes:
            if attr not in guarded or method == "__init__":
                continue
            if in_lock or method in eff_locked:
                continue
            yield self.finding(
                ctx, wnode,
                f"{cls.name}.{attr} is written under {cls.name}'s lock "
                f"elsewhere but written bare in {method}(); hold the lock or "
                "suppress with an atomicity justification")

    def _check_lockless_class(self, ctx: ModuleContext,
                              cls: ast.ClassDef) -> Iterator[Finding]:
        model = _ClassModel(ctx, cls, set())
        if not model.thread_entries:
            return
        writes_by_attr: Dict[str, Set[str]] = {}
        for method, attr, _node, _ in model.writes:
            writes_by_attr.setdefault(attr, set()).add(method)
        for method, attr, wnode, _ in model.writes:
            if method not in model.thread_entries:
                continue
            others = writes_by_attr[attr] - {method, "__init__"}
            if others:
                yield self.finding(
                    ctx, wnode,
                    f"{cls.name}.{attr} is written from spawned thread "
                    f"{method}() and from {sorted(others)[0]}() but "
                    f"{cls.name} has no lock; add one")
