"""Repo-aware static analysis (``scripts/repolint.py`` is the CLI).

The repo's hardest bug classes — mixing the four embedding id spaces
(``docs/EMBEDDING_LAYOUT.md``), impure host code under ``jit`` /
``pallas_call`` / ``custom_vjp``, over-budget Pallas VMEM staging, and
unguarded cross-thread state — are invariants no general-purpose linter
knows about. This package encodes them as AST rules (stdlib ``ast`` +
``tokenize`` only, no new dependencies) so CI catches violations in
seconds instead of relying on the bit-exactness test suites to trip over
them. ``docs/STATIC_ANALYSIS.md`` documents every rule and the
``# repolint: ignore[rule]`` suppression syntax.
"""
from repro.analysis.engine import (
    AnalysisConfig, Finding, ModuleContext, Rule, all_rules, iter_python_files,
    run_paths,
)

__all__ = [
    "AnalysisConfig", "Finding", "ModuleContext", "Rule", "all_rules",
    "iter_python_files", "run_paths",
]
