"""jax-purity / unseeded-random: host effects where tracing can't see them.

``jit``/``pallas_call``/``custom_vjp`` trace a function **once** and replay
the recorded computation: host-side effects inside traced code run at trace
time only (or worse, once per recompile), so RNG draws freeze, prints lie,
closed-over mutations desync, and ``if`` on a tracer raises
``TracerBoolConversionError`` only on the first data-dependent shape that
reaches it. This module finds traced code statically and flags the classic
impurities before a recompile makes them load-bearing.

Traced roots are found per module: decorators (``@jax.jit``,
``@functools.partial(jax.custom_vjp, ...)``) and higher-order call sites
(``jax.jit(f)``, ``jax.grad``/``value_and_grad``, ``jax.vmap``,
``pl.pallas_call(kernel, ...)``, ``lax.scan``/``cond``/``while_loop``,
``f.defvjp(fwd, bwd)``), following ``functools.partial`` aliases; the local
call graph is then walked conservatively (any reference to a module-local
function inside traced code marks it traced). Cross-module calls are not
followed — each module is judged on its own traced surface.

``unseeded-random`` is the determinism half: every replay surface in this
repo (fault plans, chaos runs, benchmarks) is seeded by contract, so global
NumPy/stdlib RNG state — seeded or not — is flagged everywhere, not just
under ``jit``. Use ``np.random.default_rng(seed)`` / ``random.Random(seed)``.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set, Tuple

from repro.analysis.engine import Finding, ModuleContext, Rule

_TRACING_HOFS = {"jit", "grad", "value_and_grad", "vmap", "pmap", "pallas_call",
                 "custom_vjp", "custom_jvp", "scan", "cond", "while_loop",
                 "fori_loop", "checkpoint", "remat", "defvjp", "defjvp"}
_IMPURE_CALLS = {"print", "input", "open", "exec", "eval"}
_TIME_FNS = {"time", "perf_counter", "monotonic", "process_time", "sleep"}
_TRACED_VALUE_ROOTS = {"jnp", "lax"}  # jnp.* / lax.* / jax.lax.* produce tracers
_STATIC_ATTRS = {"shape", "dtype", "ndim", "size", "at"}


def _attr_chain(node: ast.AST) -> List[str]:
    """``jax.lax.scan`` -> ["jax", "lax", "scan"]; [] when not a pure chain."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return parts[::-1]
    return []


def _is_np_random(chain: List[str]) -> bool:
    return len(chain) >= 2 and chain[0] in ("np", "numpy") and chain[1] == "random"


class _FunctionIndex:
    """All named function/lambda definitions of a module (nested included)."""

    def __init__(self, tree: ast.Module) -> None:
        self.defs: Dict[str, ast.AST] = {}
        self.aliases: Dict[str, str] = {}
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.defs.setdefault(node.name, node)
            elif isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                src = self._callable_name(node.value)
                if src:
                    self.aliases[node.targets[0].id] = src

    @staticmethod
    def _callable_name(value: ast.AST) -> Optional[str]:
        # k = functools.partial(f, ...) / k = jax.jit(f): k stands for f
        if isinstance(value, ast.Call):
            chain = _attr_chain(value.func)
            if chain and chain[-1] in _TRACING_HOFS | {"partial"} \
                    and value.args and isinstance(value.args[0], ast.Name):
                return value.args[0].id
        if isinstance(value, ast.Name):
            return value.id
        return None

    def resolve(self, name: str) -> Optional[ast.AST]:
        seen = set()
        while name in self.aliases and name not in seen:
            seen.add(name)
            name = self.aliases[name]
        return self.defs.get(name)


def find_traced_roots(tree: ast.Module, index: _FunctionIndex
                      ) -> Set[ast.AST]:
    """Function nodes that are entry points into traced execution."""
    roots: Set[ast.AST] = set()

    def add(name_or_node: object) -> None:
        if isinstance(name_or_node, ast.Lambda):
            roots.add(name_or_node)
        elif isinstance(name_or_node, str):
            node = index.resolve(name_or_node)
            if node is not None:
                roots.add(node)

    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                chain = _attr_chain(dec.func if isinstance(dec, ast.Call)
                                    else dec)
                if chain and chain[-1] in _TRACING_HOFS:
                    roots.add(node)
                # @functools.partial(jax.custom_vjp, ...) etc.
                if isinstance(dec, ast.Call) and chain \
                        and chain[-1] == "partial" and dec.args:
                    inner = _attr_chain(dec.args[0])
                    if inner and inner[-1] in _TRACING_HOFS:
                        roots.add(node)
        elif isinstance(node, ast.Call):
            chain = _attr_chain(node.func)
            if not chain or chain[-1] not in _TRACING_HOFS:
                continue
            for arg in node.args[:2 if chain[-1] in ("cond", "defvjp",
                                                     "defjvp") else 1]:
                if isinstance(arg, ast.Name):
                    add(arg.id)
                elif isinstance(arg, ast.Lambda):
                    add(arg)
            if chain[-1] in ("defvjp", "defjvp"):
                for arg in node.args:
                    if isinstance(arg, ast.Name):
                        add(arg.id)
    return roots


def traced_functions(tree: ast.Module) -> Set[ast.AST]:
    """Roots plus every module-local function referenced from traced code."""
    index = _FunctionIndex(tree)
    frontier = list(find_traced_roots(tree, index))
    traced: Set[ast.AST] = set()
    while frontier:
        fn = frontier.pop()
        if fn in traced:
            continue
        traced.add(fn)
        for node in ast.walk(fn):
            if isinstance(node, ast.Name):
                callee = index.resolve(node.id)
                if callee is not None and callee is not fn:
                    frontier.append(callee)
    return traced


class JaxPurityRule(Rule):
    id = "jax-purity"
    summary = ("no host side effects, host RNG, closed-over mutation, or "
               "host branching on traced values inside jit/pallas/custom_vjp "
               "code")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        traced = traced_functions(ctx.tree)
        seen_lines: Set[Tuple[int, int]] = set()
        for fn in traced:
            for f in self._check_function(ctx, fn, traced):
                key = (f.line, f.col)
                if key not in seen_lines:   # nested traced fns double-walk
                    seen_lines.add(key)
                    yield f

    def _check_function(self, ctx: ModuleContext, fn: ast.AST,
                        traced: Set[ast.AST]) -> Iterator[Finding]:
        local_names = self._local_bindings(fn)
        tracer_names = self._tracer_assigned_names(fn)
        for node in ast.walk(fn):
            # report nested defs once, when walked as their own traced entry
            if node is not fn and node in traced:
                continue
            if isinstance(node, (ast.Global, ast.Nonlocal)):
                yield self.finding(
                    ctx, node,
                    f"traced function mutates {type(node).__name__.lower()} "
                    f"state ({', '.join(node.names)}); thread values through "
                    "arguments/returns instead")
            elif isinstance(node, ast.Call):
                yield from self._check_call(ctx, node)
                yield from self._check_closure_mutation(ctx, node, local_names)
            elif isinstance(node, (ast.If, ast.While)):
                yield from self._check_host_branch(ctx, node.test, tracer_names)
            elif isinstance(node, ast.IfExp):
                yield from self._check_host_branch(ctx, node.test, tracer_names)

    @staticmethod
    def _local_bindings(fn: ast.AST) -> Set[str]:
        names: Set[str] = set()
        args = getattr(fn, "args", None)
        if args is not None:
            for a in (args.posonlyargs + args.args + args.kwonlyargs
                      + ([args.vararg] if args.vararg else [])
                      + ([args.kwarg] if args.kwarg else [])):
                names.add(a.arg)
        for node in ast.walk(fn):
            if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Store):
                names.add(node.id)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node is not fn:
                names.add(node.name)
        return names

    @staticmethod
    def _tracer_assigned_names(fn: ast.AST) -> Set[str]:
        """Names assigned from jnp/lax calls — likely tracers at runtime."""
        out: Set[str] = set()
        for node in ast.walk(fn):
            if not isinstance(node, ast.Assign):
                continue
            produces_tracer = any(
                (chain := _attr_chain(c.func)) and (
                    chain[0] in _TRACED_VALUE_ROOTS
                    or (len(chain) >= 2 and chain[0] == "jax"
                        and chain[1] in ("lax", "numpy", "nn")))
                for c in ast.walk(node.value) if isinstance(c, ast.Call))
            if produces_tracer:
                for t in node.targets:
                    for n in ast.walk(t):
                        if isinstance(n, ast.Name):
                            out.add(n.id)
        return out

    def _check_call(self, ctx: ModuleContext, node: ast.Call
                    ) -> Iterator[Finding]:
        chain = _attr_chain(node.func)
        if not chain:
            return
        if chain == ["print"] or (len(chain) == 1
                                  and chain[0] in _IMPURE_CALLS):
            yield self.finding(
                ctx, node,
                f"host `{chain[0]}` inside traced code runs at trace time "
                "only; use jax.debug.* or hoist it out of the jitted region")
        elif _is_np_random(chain) or chain[0] == "random":
            yield self.finding(
                ctx, node,
                f"host RNG `{'.'.join(chain)}` inside traced code freezes at "
                "trace time; use jax.random with an explicit key")
        elif chain[0] == "time" and chain[-1] in _TIME_FNS:
            yield self.finding(
                ctx, node,
                f"`{'.'.join(chain)}` inside traced code measures trace "
                "time, not step time; time outside the jitted callable")

    def _check_closure_mutation(self, ctx: ModuleContext, node: ast.Call,
                                local_names: Set[str]) -> Iterator[Finding]:
        if not isinstance(node.func, ast.Attribute):
            return
        if node.func.attr not in ("append", "extend", "update", "add",
                                  "insert", "setdefault", "pop", "remove"):
            return
        base = node.func.value
        if isinstance(base, ast.Name) and base.id not in local_names:
            yield self.finding(
                ctx, node,
                f"traced function mutates closed-over `{base.id}."
                f"{node.func.attr}(...)`; the effect happens once at trace "
                "time, not per step")

    def _check_host_branch(self, ctx: ModuleContext, test: ast.AST,
                           tracer_names: Set[str]) -> Iterator[Finding]:
        # `x is None` / `x is not None` is an identity test on the python
        # object, decided at trace time — static even when x is a tracer
        static_nodes: Set[ast.AST] = set()
        for cmp_node in ast.walk(test):
            if isinstance(cmp_node, ast.Compare) \
                    and all(isinstance(op, (ast.Is, ast.IsNot))
                            for op in cmp_node.ops):
                static_nodes.update(ast.walk(cmp_node))
        for node in ast.walk(test):
            if node in static_nodes:
                continue
            chain: List[str] = []
            if isinstance(node, ast.Call):
                chain = _attr_chain(node.func)
            elif isinstance(node, ast.Name) and node.id in tracer_names:
                if not self._under_static_attr(ctx, node, test):
                    yield self.finding(
                        ctx, test,
                        f"host `if`/`while` on traced value `{node.id}`; "
                        "use lax.cond/jnp.where or make it static")
                continue
            if chain and (chain[0] in _TRACED_VALUE_ROOTS
                          or (len(chain) >= 2 and chain[0] == "jax"
                              and chain[1] in ("lax", "numpy", "nn"))):
                yield self.finding(
                    ctx, test,
                    f"host `if`/`while` on traced expression "
                    f"`{'.'.join(chain)}(...)`; use lax.cond/jnp.where")

    def _under_static_attr(self, ctx: ModuleContext, node: ast.AST,
                           stop: ast.AST) -> bool:
        """True when the tracer only feeds .shape/.dtype/... (static) reads."""
        cur = ctx.parent(node)
        while cur is not None:
            if isinstance(cur, ast.Attribute) and cur.attr in _STATIC_ATTRS:
                return True
            if cur is stop:
                return False
            cur = ctx.parent(cur)
        return False


_LEGACY_NP_RANDOM = {
    "rand", "randn", "randint", "random", "random_sample", "ranf", "sample",
    "choice", "shuffle", "permutation", "uniform", "normal", "standard_normal",
    "poisson", "beta", "binomial", "bytes", "exponential", "gamma",
    "geometric", "lognormal", "seed", "get_state", "set_state",
}
_STDLIB_RANDOM = {
    "random", "randint", "randrange", "choice", "choices", "shuffle", "sample",
    "uniform", "gauss", "normalvariate", "seed", "getrandbits", "betavariate",
    "expovariate",
}


class UnseededRandomRule(Rule):
    id = "unseeded-random"
    summary = ("no global/unseeded RNG state anywhere: benchmarks and chaos "
               "runs must replay bit-identically "
               "(np.random.default_rng(seed), random.Random(seed))")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            chain = _attr_chain(node.func)
            if not chain:
                continue
            if _is_np_random(chain) and len(chain) == 3:
                if chain[2] == "default_rng":
                    if not node.args and not node.keywords:
                        yield self.finding(
                            ctx, node,
                            "np.random.default_rng() without a seed is "
                            "unreproducible; pass an explicit seed")
                elif chain[2] in _LEGACY_NP_RANDOM:
                    yield self.finding(
                        ctx, node,
                        f"legacy global-state RNG `{'.'.join(chain)}(...)`; "
                        "use np.random.default_rng(seed) so runs replay")
            elif chain[0] == "random" and len(chain) == 2:
                if chain[1] in _STDLIB_RANDOM:
                    yield self.finding(
                        ctx, node,
                        f"stdlib global RNG `random.{chain[1]}(...)`; use a "
                        "seeded random.Random(seed) instance")
                elif chain[1] == "Random" and not node.args:
                    yield self.finding(
                        ctx, node,
                        "random.Random() without a seed is unreproducible; "
                        "pass an explicit seed")
