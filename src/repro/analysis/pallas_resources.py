"""pallas-vmem / pallas-dma: static resource checks on Pallas kernels.

**pallas-vmem** — a ``pallas_call``'s on-chip footprint is decidable from
its call site: BlockSpec block shapes (×2: the grid pipeline
double-buffers every blocked operand) plus ``scratch_shapes`` VMEM
allocations. The checker evaluates the shape expressions with a table of
worst-case dimension bounds (``AnalysisConfig.assumed_dims``, CLI
``--assume NAME=VALUE``) and flags kernels whose upper-bound estimate
exceeds the per-core VMEM cap (default 16 MiB). An over-budget kernel
compiles on the interpret path CI runs and only explodes on real TPUs —
exactly the failure a static bound catches early. SMEM blocks and
``memory_space=ANY`` operands (manual-DMA HBM residents) don't occupy
VMEM blocks and are excluded.

**pallas-dma** — every manually-issued DMA (``pltpu.make_async_copy(...)
.start()``) must have a matching ``.wait()`` on the *same semaphore
expression* somewhere in the module (start and wait legitimately live in
different helpers, e.g. a fill/drain pair). A started-but-never-awaited
copy races the buffer consumer; the interpret path hides it.

Both rules also understand the in-place row-update idiom
(``input_output_aliases`` + ``memory_space=ANY`` pools + a DMA-semaphore
array scratch):

* pallas-dma bounds-checks semaphore slots: when the kernel function is
  statically resolvable (a plain ``def``, possibly behind
  ``functools.partial``) and a ``scratch_shapes`` entry declares
  ``pltpu.SemaphoreType.DMA((k,))``, any constant ``sem.at[i]`` with
  ``i >= k`` in that kernel is flagged — an out-of-range slot aliases a
  neighbouring semaphore and deadlocks or silently corrupts on real TPUs
  while interpret mode shrugs.
* pallas-vmem validates ``input_output_aliases`` dict literals: operand
  indices must be in range of the literal ``in_specs``/``out_specs``
  lists, and an aliased input/output pair must live in the *same* memory
  space (aliasing names one buffer; a VMEM-blocked input aliased onto an
  ``ANY`` output — or vice versa — is a miscounted operand index until it
  explodes at lowering time).
"""
from __future__ import annotations

import ast
import math
from typing import Dict, Iterator, List, Optional, Set, Tuple

from repro.analysis.engine import Finding, ModuleContext, Rule
from repro.analysis.purity import _attr_chain

_DTYPE_BYTES = {
    "float64": 8, "int64": 8, "uint64": 8,
    "float32": 4, "int32": 4, "uint32": 4,
    "bfloat16": 2, "float16": 2, "int16": 2, "uint16": 2,
    "int8": 1, "uint8": 1, "bool_": 1, "bool": 1,
}


def _eval_dim(node: ast.AST, dims: Dict[str, int], default: int) -> int:
    """Upper-bound a block-shape dimension expression."""
    if isinstance(node, ast.Constant) and isinstance(node.value, (int, float)):
        return int(node.value)
    if isinstance(node, ast.Name):
        return dims.get(node.id, default)
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        return -_eval_dim(node.operand, dims, default)
    if isinstance(node, ast.BinOp):
        left = _eval_dim(node.left, dims, default)
        right = _eval_dim(node.right, dims, default)
        if isinstance(node.op, ast.Add):
            return left + right
        if isinstance(node.op, ast.Sub):
            return left - right
        if isinstance(node.op, ast.Mult):
            return left * right
        if isinstance(node.op, (ast.Div, ast.FloorDiv)):
            return left // max(right, 1)
        if isinstance(node.op, ast.Mod):
            return max(right - 1, 0)
        if isinstance(node.op, ast.Pow):
            return left ** right
    if isinstance(node, ast.Call):
        chain = _attr_chain(node.func)
        vals = [_eval_dim(a, dims, default) for a in node.args]
        if chain and vals:
            if chain[-1] == "max":
                return max(vals)
            if chain[-1] == "min":
                return min(vals)
            if chain[-1] == "cdiv" and len(vals) == 2:
                return math.ceil(vals[0] / max(vals[1], 1))
    return default  # unresolvable: fall back to the configured bound


def _dtype_bytes(node: Optional[ast.AST]) -> int:
    if node is None:
        return 4
    chain = _attr_chain(node)
    if chain and chain[-1] in _DTYPE_BYTES:
        return _DTYPE_BYTES[chain[-1]]
    return 4  # unknown (e.g. pool.dtype): assume full-width f32


def _kw(call: ast.Call, name: str) -> Optional[ast.AST]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _as_elements(node: Optional[ast.AST]) -> List[ast.AST]:
    if node is None:
        return []
    if isinstance(node, (ast.List, ast.Tuple)):
        return list(node.elts)
    return [node]


class VmemBudgetRule(Rule):
    id = "pallas-vmem"
    summary = ("per-kernel VMEM upper bound (2x blocked operands + scratch, "
               "worst-case dims) must fit the per-core cap")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        dims = ctx.config.assumed_dims
        default = ctx.config.default_dim
        cap = ctx.config.vmem_cap_bytes
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            chain = _attr_chain(node.func)
            if not chain or chain[-1] != "pallas_call":
                continue
            parts: List[Tuple[str, int]] = []
            for label, spec in self._block_specs(ctx, node):
                nbytes = self._blockspec_bytes(spec, dims, default)
                if nbytes:
                    parts.append((label, 2 * nbytes))  # pipeline double-buffer
            for scratch in _as_elements(_kw(node, "scratch_shapes")):
                nbytes = self._scratch_bytes(scratch, dims, default)
                if nbytes:
                    parts.append(("scratch", nbytes))
            total = sum(b for _, b in parts)
            if total > cap:
                detail = " + ".join(f"{label}:{b // 1024}KiB"
                                    for label, b in parts)
                yield self.finding(
                    ctx, node,
                    f"kernel VMEM upper bound {total / 2**20:.1f} MiB exceeds "
                    f"the {cap / 2**20:.1f} MiB cap ({detail}); shrink block "
                    "shapes or raise --vmem-cap-bytes with a justification")
            yield from self._check_aliases(ctx, node)

    def _check_aliases(self, ctx: ModuleContext, call: ast.Call
                       ) -> Iterator[Finding]:
        """Validate an ``input_output_aliases`` dict literal statically."""
        aliases = _kw(call, "input_output_aliases")
        if not isinstance(aliases, ast.Dict):
            return
        in_specs = _as_elements(_kw(call, "in_specs"))
        out_specs = _as_elements(_kw(call, "out_specs"))
        n_out = len(out_specs) or len(_as_elements(_kw(call, "out_shape")))
        for k, v in zip(aliases.keys, aliases.values):
            if not (isinstance(k, ast.Constant) and isinstance(k.value, int)
                    and isinstance(v, ast.Constant)
                    and isinstance(v.value, int)
                    and k.value >= 0 and v.value >= 0):
                continue  # computed alias indices: not statically decidable
            if in_specs and k.value >= len(in_specs):
                yield self.finding(
                    ctx, k,
                    f"input_output_aliases names input {k.value} but only "
                    f"{len(in_specs)} in_specs exist; operand indices count "
                    "every input (SMEM blocks included)")
                continue
            if n_out and v.value >= n_out:
                yield self.finding(
                    ctx, v,
                    f"input_output_aliases names output {v.value} but only "
                    f"{n_out} outputs exist")
                continue
            if in_specs and out_specs:
                mem_in = self._memspace(ctx, in_specs[k.value])
                mem_out = self._memspace(ctx, out_specs[v.value])
                if mem_in and mem_out and mem_in != mem_out:
                    yield self.finding(
                        ctx, k,
                        f"aliased pair input {k.value} ({mem_in}) -> output "
                        f"{v.value} ({mem_out}) straddles memory spaces; an "
                        "alias names ONE buffer, so both specs must agree "
                        "(likely a miscounted operand index)")

    @staticmethod
    def _memspace(ctx: ModuleContext, el: ast.AST) -> Optional[str]:
        """The declared memory space of a BlockSpec element, if decidable."""
        if isinstance(el, ast.Name):
            el = VmemBudgetRule._resolve_local(ctx, el.id)
        if not isinstance(el, ast.Call):
            return None
        chain = _attr_chain(el.func)
        if not chain or chain[-1] != "BlockSpec":
            return None
        mem = _kw(el, "memory_space")
        if mem is None:
            return "VMEM"  # blocked specs default to the VMEM pipeline
        mchain = _attr_chain(mem)
        return mchain[-1] if mchain else None

    def _block_specs(self, ctx: ModuleContext, call: ast.Call
                     ) -> Iterator[Tuple[str, ast.Call]]:
        """Yield (label, BlockSpec call) for in/out specs, incl. grid_spec."""
        sources = [("in", _kw(call, "in_specs")), ("out", _kw(call, "out_specs"))]
        grid_spec = _kw(call, "grid_spec")
        if grid_spec is None and call.args:
            maybe = call.args[1] if len(call.args) > 1 else None
            if isinstance(maybe, ast.Call):
                grid_spec = maybe
        if isinstance(grid_spec, ast.Call):
            sources += [("in", _kw(grid_spec, "in_specs")),
                        ("out", _kw(grid_spec, "out_specs"))]
        elif isinstance(grid_spec, ast.Name):
            spec_def = self._resolve_local(ctx, grid_spec.id)
            if isinstance(spec_def, ast.Call):
                sources += [("in", _kw(spec_def, "in_specs")),
                            ("out", _kw(spec_def, "out_specs"))]
        for label, src in sources:
            for el in _as_elements(src):
                target = el
                if isinstance(el, ast.Name):
                    target = self._resolve_local(ctx, el.id)
                if isinstance(target, ast.Call):
                    tchain = _attr_chain(target.func)
                    if tchain and tchain[-1] == "BlockSpec":
                        yield label, target

    @staticmethod
    def _resolve_local(ctx: ModuleContext, name: str) -> Optional[ast.AST]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name) \
                    and node.targets[0].id == name:
                return node.value
        return None

    @staticmethod
    def _blockspec_bytes(spec: ast.Call, dims: Dict[str, int],
                         default: int) -> int:
        mem = _kw(spec, "memory_space")
        if mem is not None:
            mchain = _attr_chain(mem)
            if mchain and mchain[-1] in ("SMEM", "ANY"):
                return 0  # not a VMEM block
        if not spec.args:
            return 0  # whole-operand spec (memory decided by the compiler)
        shape = spec.args[0]
        if not isinstance(shape, (ast.Tuple, ast.List)):
            return 0
        n = 1
        for dim in shape.elts:
            n *= max(_eval_dim(dim, dims, default), 1)
        return n * 4  # BlockSpec carries no dtype; assume f32

    @staticmethod
    def _scratch_bytes(node: ast.AST, dims: Dict[str, int],
                       default: int) -> int:
        if not isinstance(node, ast.Call):
            return 0
        chain = _attr_chain(node.func)
        if not chain or chain[-1] != "VMEM":
            return 0  # SMEM scratch / semaphores don't consume VMEM
        if not node.args:
            return 0
        shape = node.args[0]
        if not isinstance(shape, (ast.Tuple, ast.List)):
            return 0
        n = 1
        for dim in shape.elts:
            n *= max(_eval_dim(dim, dims, default), 1)
        dtype = node.args[1] if len(node.args) > 1 else None
        return n * _dtype_bytes(dtype)


class DmaPairingRule(Rule):
    id = "pallas-dma"
    summary = ("every make_async_copy(...).start() needs a matching .wait() "
               "on the same semaphore expression in the module")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        started: Dict[str, ast.AST] = {}
        waited: Set[str] = set()
        copy_names: Dict[str, str] = {}   # var name -> semaphore expr
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            chain = _attr_chain(node.func)
            if chain and chain[-1] == "make_async_copy":
                sem = self._sem_expr(node)
                use = self._immediate_use(ctx, node)
                if use == "start":
                    started.setdefault(sem, node)
                elif use == "wait":
                    waited.add(sem)
                else:
                    assigned = self._assigned_name(ctx, node)
                    if assigned:
                        copy_names[assigned] = sem
            elif isinstance(node.func, ast.Attribute) \
                    and isinstance(node.func.value, ast.Name) \
                    and node.func.value.id in copy_names:
                sem = copy_names[node.func.value.id]
                if node.func.attr == "start":
                    started.setdefault(sem, node)
                elif node.func.attr == "wait":
                    waited.add(sem)
        for sem, node in started.items():
            if sem not in waited:
                yield self.finding(
                    ctx, node,
                    f"DMA started on semaphore `{sem}` is never awaited in "
                    "this module; add the matching .wait() (unwaited copies "
                    "race their consumer)")
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call):
                chain = _attr_chain(node.func)
                if chain and chain[-1] == "pallas_call":
                    yield from self._check_sem_slots(ctx, node)

    def _check_sem_slots(self, ctx: ModuleContext, call: ast.Call
                         ) -> Iterator[Finding]:
        """Constant ``sem.at[i]`` must fit the declared DMA((k,)) shape."""
        scratch = _as_elements(_kw(call, "scratch_shapes"))
        if not scratch:
            return
        fn = self._kernel_def(ctx, call)
        if fn is None or fn.args.vararg is not None:
            return  # kernel not statically resolvable / *refs-style packing
        params = [a.arg for a in fn.args.posonlyargs + fn.args.args]
        if len(params) < len(scratch):
            return
        caps: Dict[str, int] = {}
        for name, decl in zip(params[-len(scratch):], scratch):
            cap = self._dma_capacity(decl)
            if cap is not None:
                caps[name] = cap
        for sub in ast.walk(fn):
            if not (isinstance(sub, ast.Subscript)
                    and isinstance(sub.value, ast.Attribute)
                    and sub.value.attr == "at"
                    and isinstance(sub.value.value, ast.Name)
                    and sub.value.value.id in caps):
                continue
            idx = sub.slice
            if isinstance(idx, ast.Constant) and isinstance(idx.value, int):
                cap = caps[sub.value.value.id]
                if not -cap <= idx.value < cap:
                    yield self.finding(
                        ctx, sub,
                        f"`{ast.unparse(sub)}` indexes past the declared "
                        f"SemaphoreType.DMA(({cap},)) capacity in kernel "
                        f"`{fn.name}`; an out-of-range slot aliases a "
                        "neighbouring semaphore (interpret mode hides it)")

    @staticmethod
    def _kernel_def(ctx: ModuleContext, call: ast.Call
                    ) -> Optional[ast.FunctionDef]:
        """Resolve pallas_call's kernel argument to its FunctionDef."""
        node: Optional[ast.AST] = call.args[0] if call.args else None
        for _ in range(4):   # Name -> local assign -> partial(...) -> Name
            if isinstance(node, ast.Name):
                for cand in ast.walk(ctx.tree):
                    if isinstance(cand, ast.FunctionDef) \
                            and cand.name == node.id:
                        return cand
                node = VmemBudgetRule._resolve_local(ctx, node.id)
            elif isinstance(node, ast.Call):
                chain = _attr_chain(node.func)
                if chain and chain[-1] == "partial" and node.args:
                    node = node.args[0]
                else:
                    return None
            else:
                return None
        return None

    @staticmethod
    def _dma_capacity(decl: ast.AST) -> Optional[int]:
        """The k of a literal ``pltpu.SemaphoreType.DMA((k,))`` scratch."""
        if not isinstance(decl, ast.Call):
            return None
        chain = _attr_chain(decl.func)
        if not chain or chain[-1] != "DMA" or "SemaphoreType" not in chain:
            return None
        if len(decl.args) != 1 \
                or not isinstance(decl.args[0], (ast.Tuple, ast.List)) \
                or len(decl.args[0].elts) != 1:
            return None
        dim = decl.args[0].elts[0]
        if isinstance(dim, ast.Constant) and isinstance(dim.value, int):
            return dim.value
        return None

    @staticmethod
    def _sem_expr(call: ast.Call) -> str:
        if len(call.args) >= 3:
            return ast.unparse(call.args[2])
        kw = _kw(call, "sem")
        return ast.unparse(kw) if kw is not None else "<none>"

    @staticmethod
    def _immediate_use(ctx: ModuleContext, call: ast.Call) -> Optional[str]:
        parent = ctx.parent(call)
        if isinstance(parent, ast.Attribute) and parent.attr in ("start",
                                                                 "wait"):
            return parent.attr
        return None

    @staticmethod
    def _assigned_name(ctx: ModuleContext, call: ast.Call) -> Optional[str]:
        parent = ctx.parent(call)
        if isinstance(parent, ast.Assign) and len(parent.targets) == 1 \
                and isinstance(parent.targets[0], ast.Name):
            return parent.targets[0].id
        return None
