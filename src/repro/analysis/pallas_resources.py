"""pallas-vmem / pallas-dma: static resource checks on Pallas kernels.

**pallas-vmem** — a ``pallas_call``'s on-chip footprint is decidable from
its call site: BlockSpec block shapes (×2: the grid pipeline
double-buffers every blocked operand) plus ``scratch_shapes`` VMEM
allocations. The checker evaluates the shape expressions with a table of
worst-case dimension bounds (``AnalysisConfig.assumed_dims``, CLI
``--assume NAME=VALUE``) and flags kernels whose upper-bound estimate
exceeds the per-core VMEM cap (default 16 MiB). An over-budget kernel
compiles on the interpret path CI runs and only explodes on real TPUs —
exactly the failure a static bound catches early. SMEM blocks and
``memory_space=ANY`` operands (manual-DMA HBM residents) don't occupy
VMEM blocks and are excluded.

**pallas-dma** — every manually-issued DMA (``pltpu.make_async_copy(...)
.start()``) must have a matching ``.wait()`` on the *same semaphore
expression* somewhere in the module (start and wait legitimately live in
different helpers, e.g. a fill/drain pair). A started-but-never-awaited
copy races the buffer consumer; the interpret path hides it.
"""
from __future__ import annotations

import ast
import math
from typing import Dict, Iterator, List, Optional, Set, Tuple

from repro.analysis.engine import Finding, ModuleContext, Rule
from repro.analysis.purity import _attr_chain

_DTYPE_BYTES = {
    "float64": 8, "int64": 8, "uint64": 8,
    "float32": 4, "int32": 4, "uint32": 4,
    "bfloat16": 2, "float16": 2, "int16": 2, "uint16": 2,
    "int8": 1, "uint8": 1, "bool_": 1, "bool": 1,
}


def _eval_dim(node: ast.AST, dims: Dict[str, int], default: int) -> int:
    """Upper-bound a block-shape dimension expression."""
    if isinstance(node, ast.Constant) and isinstance(node.value, (int, float)):
        return int(node.value)
    if isinstance(node, ast.Name):
        return dims.get(node.id, default)
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        return -_eval_dim(node.operand, dims, default)
    if isinstance(node, ast.BinOp):
        left = _eval_dim(node.left, dims, default)
        right = _eval_dim(node.right, dims, default)
        if isinstance(node.op, ast.Add):
            return left + right
        if isinstance(node.op, ast.Sub):
            return left - right
        if isinstance(node.op, ast.Mult):
            return left * right
        if isinstance(node.op, (ast.Div, ast.FloorDiv)):
            return left // max(right, 1)
        if isinstance(node.op, ast.Mod):
            return max(right - 1, 0)
        if isinstance(node.op, ast.Pow):
            return left ** right
    if isinstance(node, ast.Call):
        chain = _attr_chain(node.func)
        vals = [_eval_dim(a, dims, default) for a in node.args]
        if chain and vals:
            if chain[-1] == "max":
                return max(vals)
            if chain[-1] == "min":
                return min(vals)
            if chain[-1] == "cdiv" and len(vals) == 2:
                return math.ceil(vals[0] / max(vals[1], 1))
    return default  # unresolvable: fall back to the configured bound


def _dtype_bytes(node: Optional[ast.AST]) -> int:
    if node is None:
        return 4
    chain = _attr_chain(node)
    if chain and chain[-1] in _DTYPE_BYTES:
        return _DTYPE_BYTES[chain[-1]]
    return 4  # unknown (e.g. pool.dtype): assume full-width f32


def _kw(call: ast.Call, name: str) -> Optional[ast.AST]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _as_elements(node: Optional[ast.AST]) -> List[ast.AST]:
    if node is None:
        return []
    if isinstance(node, (ast.List, ast.Tuple)):
        return list(node.elts)
    return [node]


class VmemBudgetRule(Rule):
    id = "pallas-vmem"
    summary = ("per-kernel VMEM upper bound (2x blocked operands + scratch, "
               "worst-case dims) must fit the per-core cap")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        dims = ctx.config.assumed_dims
        default = ctx.config.default_dim
        cap = ctx.config.vmem_cap_bytes
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            chain = _attr_chain(node.func)
            if not chain or chain[-1] != "pallas_call":
                continue
            parts: List[Tuple[str, int]] = []
            for label, spec in self._block_specs(ctx, node):
                nbytes = self._blockspec_bytes(spec, dims, default)
                if nbytes:
                    parts.append((label, 2 * nbytes))  # pipeline double-buffer
            for scratch in _as_elements(_kw(node, "scratch_shapes")):
                nbytes = self._scratch_bytes(scratch, dims, default)
                if nbytes:
                    parts.append(("scratch", nbytes))
            total = sum(b for _, b in parts)
            if total > cap:
                detail = " + ".join(f"{label}:{b // 1024}KiB"
                                    for label, b in parts)
                yield self.finding(
                    ctx, node,
                    f"kernel VMEM upper bound {total / 2**20:.1f} MiB exceeds "
                    f"the {cap / 2**20:.1f} MiB cap ({detail}); shrink block "
                    "shapes or raise --vmem-cap-bytes with a justification")

    def _block_specs(self, ctx: ModuleContext, call: ast.Call
                     ) -> Iterator[Tuple[str, ast.Call]]:
        """Yield (label, BlockSpec call) for in/out specs, incl. grid_spec."""
        sources = [("in", _kw(call, "in_specs")), ("out", _kw(call, "out_specs"))]
        grid_spec = _kw(call, "grid_spec")
        if grid_spec is None and call.args:
            maybe = call.args[1] if len(call.args) > 1 else None
            if isinstance(maybe, ast.Call):
                grid_spec = maybe
        if isinstance(grid_spec, ast.Call):
            sources += [("in", _kw(grid_spec, "in_specs")),
                        ("out", _kw(grid_spec, "out_specs"))]
        elif isinstance(grid_spec, ast.Name):
            spec_def = self._resolve_local(ctx, grid_spec.id)
            if isinstance(spec_def, ast.Call):
                sources += [("in", _kw(spec_def, "in_specs")),
                            ("out", _kw(spec_def, "out_specs"))]
        for label, src in sources:
            for el in _as_elements(src):
                target = el
                if isinstance(el, ast.Name):
                    target = self._resolve_local(ctx, el.id)
                if isinstance(target, ast.Call):
                    tchain = _attr_chain(target.func)
                    if tchain and tchain[-1] == "BlockSpec":
                        yield label, target

    @staticmethod
    def _resolve_local(ctx: ModuleContext, name: str) -> Optional[ast.AST]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name) \
                    and node.targets[0].id == name:
                return node.value
        return None

    @staticmethod
    def _blockspec_bytes(spec: ast.Call, dims: Dict[str, int],
                         default: int) -> int:
        mem = _kw(spec, "memory_space")
        if mem is not None:
            mchain = _attr_chain(mem)
            if mchain and mchain[-1] in ("SMEM", "ANY"):
                return 0  # not a VMEM block
        if not spec.args:
            return 0  # whole-operand spec (memory decided by the compiler)
        shape = spec.args[0]
        if not isinstance(shape, (ast.Tuple, ast.List)):
            return 0
        n = 1
        for dim in shape.elts:
            n *= max(_eval_dim(dim, dims, default), 1)
        return n * 4  # BlockSpec carries no dtype; assume f32

    @staticmethod
    def _scratch_bytes(node: ast.AST, dims: Dict[str, int],
                       default: int) -> int:
        if not isinstance(node, ast.Call):
            return 0
        chain = _attr_chain(node.func)
        if not chain or chain[-1] != "VMEM":
            return 0  # SMEM scratch / semaphores don't consume VMEM
        if not node.args:
            return 0
        shape = node.args[0]
        if not isinstance(shape, (ast.Tuple, ast.List)):
            return 0
        n = 1
        for dim in shape.elts:
            n *= max(_eval_dim(dim, dims, default), 1)
        dtype = node.args[1] if len(node.args) > 1 else None
        return n * _dtype_bytes(dtype)


class DmaPairingRule(Rule):
    id = "pallas-dma"
    summary = ("every make_async_copy(...).start() needs a matching .wait() "
               "on the same semaphore expression in the module")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        started: Dict[str, ast.AST] = {}
        waited: Set[str] = set()
        copy_names: Dict[str, str] = {}   # var name -> semaphore expr
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            chain = _attr_chain(node.func)
            if chain and chain[-1] == "make_async_copy":
                sem = self._sem_expr(node)
                use = self._immediate_use(ctx, node)
                if use == "start":
                    started.setdefault(sem, node)
                elif use == "wait":
                    waited.add(sem)
                else:
                    assigned = self._assigned_name(ctx, node)
                    if assigned:
                        copy_names[assigned] = sem
            elif isinstance(node.func, ast.Attribute) \
                    and isinstance(node.func.value, ast.Name) \
                    and node.func.value.id in copy_names:
                sem = copy_names[node.func.value.id]
                if node.func.attr == "start":
                    started.setdefault(sem, node)
                elif node.func.attr == "wait":
                    waited.add(sem)
        for sem, node in started.items():
            if sem not in waited:
                yield self.finding(
                    ctx, node,
                    f"DMA started on semaphore `{sem}` is never awaited in "
                    "this module; add the matching .wait() (unwaited copies "
                    "race their consumer)")

    @staticmethod
    def _sem_expr(call: ast.Call) -> str:
        if len(call.args) >= 3:
            return ast.unparse(call.args[2])
        kw = _kw(call, "sem")
        return ast.unparse(kw) if kw is not None else "<none>"

    @staticmethod
    def _immediate_use(ctx: ModuleContext, call: ast.Call) -> Optional[str]:
        parent = ctx.parent(call)
        if isinstance(parent, ast.Attribute) and parent.attr in ("start",
                                                                 "wait"):
            return parent.attr
        return None

    @staticmethod
    def _assigned_name(ctx: ModuleContext, call: ast.Call) -> Optional[str]:
        parent = ctx.parent(call)
        if isinstance(parent, ast.Assign) and len(parent.targets) == 1 \
                and isinstance(parent.targets[0], ast.Name):
            return parent.targets[0].id
        return None
