"""id-space: embedding row ids may only change space through a translator.

The repo speaks four embedding id spaces (``docs/EMBEDDING_LAYOUT.md``):
**raw** per-table-local ids, **flat** pooled rows (canonical), **encoded**
hot indices (``-(slot+1)`` for cache hits, store rows otherwise), and
**padded** physical rows (``shard * max_range + slot``). Mixing them
compiles fine, runs fine on un-skewed shapes, and silently corrupts
lookups/gradients under a real plan — the bug class only
``test_padded_layout.py``-style bit-exactness runs catch at test time.

This rule types variables by the repo's naming convention (``flat_idx``,
``raw_ids``, ``padded_rows3``, ``encoded_idx`` ...) and enforces:

* no assignment of one space's value to another space's name, unless it
  flows through a sanctioned translator (``translate_rows``,
  ``flat_to_padded``/``padded_to_flat``, ``encode_hot_indices``,
  ``EmbeddingRemapper.remap_batch``, ``pad_rows``/``unpad_rows``);
* no arithmetic/comparison directly mixing two spaces;
* translator inputs must come from the space the translator consumes
  (``translate_rows(padded_ids, ...)`` is the double-translation bug).

The encoded space is a supertype by contract — flat (no layout) or padded
(layout) rows are valid cold entries of an encoded stream — so flat→encoded
and padded→encoded flow without a translator.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, Optional, Set

from repro.analysis.engine import Finding, ModuleContext, Rule

SPACES = ("raw", "flat", "padded", "encoded")

_SPACE_ALIASES = {"raw": "raw", "flat": "flat", "padded": "padded",
                  "encoded": "encoded", "enc": "encoded"}
_ID_TOKENS = {"id", "ids", "idx", "index", "indices", "row", "rows"}

# translator name -> (input space of the first data argument, output space)
TRANSLATORS: Dict[str, tuple] = {
    "translate_rows": ("flat", "padded"),
    "translate_rows_np": ("flat", "padded"),
    "flat_to_padded": ("flat", "padded"),
    "padded_to_flat": ("padded", "flat"),
    "encode_hot_indices": ("flat", "encoded"),
    "remap_batch": ("raw", "flat"),
    "remap": ("raw", "flat"),
    "pad_rows": ("flat", "padded"),
    "unpad_rows": ("padded", "flat"),
    "row_translation": (None, "padded"),
    "hot_row_ids": (None, "flat"),
}

# target-space -> source spaces that may flow in without a translator
_IMPLICIT_OK = {"encoded": {"encoded", "flat", "padded"},
                "raw": {"raw"}, "flat": {"flat"}, "padded": {"padded"}}


def classify(name: str) -> Optional[str]:
    """Space of a variable name per the repo convention, or None.

    A name carries a space when one end segment is a space word and another
    segment (digits stripped) is an id token: ``flat_idx`` → flat,
    ``padded_rows3`` → padded, ``ids_raw`` → raw; ``padded_shards``,
    ``idx``, ``layout`` → None.
    """
    segs = [s.rstrip("0123456789") for s in name.lower().split("_") if s]
    if len(segs) < 2:
        return None
    for space_seg, rest in ((segs[0], segs[1:]), (segs[-1], segs[:-1])):
        space = _SPACE_ALIASES.get(space_seg)
        if space and any(s in _ID_TOKENS for s in rest):
            return space
    return None


def _call_name(node: ast.Call) -> Optional[str]:
    func = node.func
    if isinstance(func, ast.Name):
        return func.id
    if isinstance(func, ast.Attribute):
        return func.attr
    return None


class _SpaceCollector(ast.NodeVisitor):
    """Spaces carried by an expression; translator calls substitute their
    output space and hide their (sanctioned) argument conversions."""

    def __init__(self) -> None:
        self.spaces: Set[str] = set()

    def visit_Call(self, node: ast.Call) -> None:
        name = _call_name(node)
        if name in TRANSLATORS:
            out = TRANSLATORS[name][1]
            if out:
                self.spaces.add(out)
            return  # args are consumed by the translator, not mixed in
        self.generic_visit(node)

    def visit_Attribute(self, node: ast.Attribute) -> None:
        return  # attribute chains (layout.padded_rows, ...) are geometry

    def visit_Name(self, node: ast.Name) -> None:
        space = classify(node.id)
        if space:
            self.spaces.add(space)


def expr_spaces(node: ast.AST) -> Set[str]:
    c = _SpaceCollector()
    c.visit(node)
    return c.spaces


class IdSpaceRule(Rule):
    id = "id-space"
    summary = ("embedding ids must pass through a sanctioned translator "
               "to change id space (raw/flat/encoded/padded)")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node.name in TRANSLATORS:
                continue  # translator implementations convert by definition
            if self._inside_translator_def(ctx, node):
                continue
            if isinstance(node, ast.Assign):
                for target in node.targets:
                    yield from self._check_assign(ctx, target, node.value)
            elif isinstance(node, (ast.AnnAssign, ast.AugAssign)) \
                    and node.value is not None:
                yield from self._check_assign(ctx, node.target, node.value)
            elif isinstance(node, (ast.BinOp, ast.Compare)):
                yield from self._check_mixing(ctx, node)
            elif isinstance(node, ast.Call):
                yield from self._check_translator_input(ctx, node)

    def _inside_translator_def(self, ctx: ModuleContext, node: ast.AST) -> bool:
        return any(isinstance(p, (ast.FunctionDef, ast.AsyncFunctionDef))
                   and p.name in TRANSLATORS for p in ctx.parents(node))

    def _check_assign(self, ctx: ModuleContext, target: ast.AST,
                      value: ast.AST) -> Iterator[Finding]:
        if isinstance(target, (ast.Tuple, ast.List)) \
                and isinstance(value, (ast.Tuple, ast.List)) \
                and len(target.elts) == len(value.elts):
            for t, v in zip(target.elts, value.elts):
                yield from self._check_assign(ctx, t, v)
            return
        if not isinstance(target, ast.Name):
            return
        tspace = classify(target.id)
        if tspace is None:
            return
        bad = expr_spaces(value) - _IMPLICIT_OK[tspace]
        for space in sorted(bad):
            yield self.finding(
                ctx, value,
                f"{space}-space value assigned to {tspace}-space name "
                f"'{target.id}' without a sanctioned translator "
                f"(see docs/EMBEDDING_LAYOUT.md)")

    def _check_mixing(self, ctx: ModuleContext, node: ast.AST) -> Iterator[Finding]:
        if isinstance(node, ast.BinOp):
            operands = [node.left, node.right]
        else:
            operands = [node.left] + list(node.comparators)
        per_operand = [expr_spaces(o) for o in operands]
        distinct = set().union(*per_operand)
        if len(distinct) < 2:
            return
        # only flag when the spaces come from *different* operands — a single
        # operand's interior (e.g. a jnp.where select) is judged at its own
        # assignment, not here
        single = [s for s in per_operand if len(s) == 1]
        if len({next(iter(s)) for s in single}) >= 2:
            a, b = sorted(distinct)[:2]
            yield self.finding(
                ctx, node,
                f"expression mixes {a}-space and {b}-space ids directly; "
                f"translate one side first (see docs/EMBEDDING_LAYOUT.md)")

    def _check_translator_input(self, ctx: ModuleContext,
                                node: ast.Call) -> Iterator[Finding]:
        name = _call_name(node)
        if name not in TRANSLATORS or not node.args:
            return
        expect = TRANSLATORS[name][0]
        if expect is None:
            return
        got = expr_spaces(node.args[0]) - {expect}
        for space in sorted(got):
            yield self.finding(
                ctx, node,
                f"translator '{name}' consumes {expect}-space ids but was "
                f"given a {space}-space value (double translation?)")
