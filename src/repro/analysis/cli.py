"""``repolint`` CLI: run the repo-aware rules, exit nonzero on findings.

Usage (via ``scripts/repolint.py``)::

    python scripts/repolint.py src/                 # whole tree
    python scripts/repolint.py --list-rules         # registry + summaries
    python scripts/repolint.py --select id-space,pallas-vmem src/
    python scripts/repolint.py --assume D=512 --vmem-cap-bytes $((32<<20)) src/

Exit codes: 0 clean, 1 findings, 2 usage/parse errors.
"""
from __future__ import annotations

import argparse
import sys
from typing import Optional, Sequence

from repro.analysis.engine import AnalysisConfig, all_rules, run_paths


def build_parser() -> argparse.ArgumentParser:
    parser = argparse.ArgumentParser(
        prog="repolint",
        description="Repo-aware static analysis (id-space, JAX purity, "
                    "Pallas resources, thread safety, hygiene).")
    parser.add_argument("paths", nargs="*", help="files or directories to lint")
    parser.add_argument("--list-rules", action="store_true",
                        help="print rule ids and summaries, then exit")
    parser.add_argument("--select", default=None, metavar="RULES",
                        help="comma-separated rule ids to run (default: all)")
    parser.add_argument("--vmem-cap-bytes", type=int, default=None,
                        metavar="N", help="pallas-vmem per-core cap override")
    parser.add_argument("--assume", action="append", default=[],
                        metavar="NAME=INT",
                        help="bound a symbolic dimension for pallas-vmem "
                             "(repeatable)")
    return parser


def main(argv: Optional[Sequence[str]] = None) -> int:
    parser = build_parser()
    args = parser.parse_args(argv)
    rules = all_rules()

    if args.list_rules:
        width = max(len(r.id) for r in rules)
        for rule in rules:
            print(f"{rule.id:<{width}}  {rule.summary}")
        return 0

    if not args.paths:
        parser.print_usage(sys.stderr)
        print("repolint: error: no paths given (or use --list-rules)",
              file=sys.stderr)
        return 2

    if args.select:
        wanted = {r.strip() for r in args.select.split(",") if r.strip()}
        known = {r.id for r in rules}
        unknown = wanted - known
        if unknown:
            print(f"repolint: error: unknown rule(s): {', '.join(sorted(unknown))}",
                  file=sys.stderr)
            return 2
        rules = [r for r in rules if r.id in wanted]

    config = AnalysisConfig()
    if args.vmem_cap_bytes is not None:
        config.vmem_cap_bytes = args.vmem_cap_bytes
    for item in args.assume:
        name, sep, value = item.partition("=")
        if not sep or not name or not value.lstrip("-").isdigit():
            print(f"repolint: error: bad --assume {item!r} (want NAME=INT)",
                  file=sys.stderr)
            return 2
        config.assumed_dims[name] = int(value)

    findings, errors = run_paths(args.paths, rules=rules, config=config)
    for err in errors:
        print(f"repolint: parse error: {err}", file=sys.stderr)
    for finding in findings:
        print(finding.render())
    if findings or errors:
        print(f"repolint: {len(findings)} finding(s), {len(errors)} parse "
              f"error(s)", file=sys.stderr)
        return 2 if errors and not findings else 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
