"""silent-except: no bare excepts, no silently-swallowed exceptions.

In a self-healing trainer, an exception that vanishes (``except X: pass``)
is indistinguishable from success — the supervisor's restart accounting,
the flash-checkpoint event log and the chaos tests all depend on failures
leaving a trace. This rule flags:

* ``except:`` with no exception type (catches ``KeyboardInterrupt`` /
  ``SystemExit`` too, which breaks Ctrl-C and clean worker shutdown);
* handlers whose entire body is ``pass`` / ``...`` — type the exception
  *and* record it (event log, logger, counter) or re-raise.

``except SomeError: <real handling>`` is fine; judging the quality of the
handling is out of scope.
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.engine import Finding, ModuleContext, Rule


def _is_noop(stmt: ast.stmt) -> bool:
    if isinstance(stmt, ast.Pass):
        return True
    return (isinstance(stmt, ast.Expr)
            and isinstance(stmt.value, ast.Constant)
            and stmt.value.value is Ellipsis)


class SilentExceptRule(Rule):
    id = "silent-except"
    summary = ("no bare `except:`; no `except X: pass` — record or re-raise "
               "so failures leave a trace")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            if node.type is None:
                yield self.finding(
                    ctx, node,
                    "bare `except:` also catches KeyboardInterrupt/SystemExit;"
                    " name the exception type(s)")
                continue
            if all(_is_noop(s) for s in node.body):
                caught = ast.unparse(node.type)
                yield self.finding(
                    ctx, node,
                    f"`except {caught}` swallows the exception silently; "
                    "log/record it (event log, counter) or re-raise")
