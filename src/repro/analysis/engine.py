"""Rule engine: parse once, run repo-aware AST rules, honor suppressions.

A ``Rule`` sees one parsed module at a time through a ``ModuleContext``
(AST with parent links, source lines, per-line suppressions) and yields
``Finding``s. The engine is deliberately tiny — rules carry the domain
knowledge; this module only owns parsing, the suppression contract and the
registry.

Suppression syntax (both forms require the rule id, so a suppression can
never silently widen)::

    x = flat_ids + 1   # repolint: ignore[id-space] -- why the rule is wrong here
    # repolint: file-ignore[jax-purity] -- module-wide, put near the top

``# repolint: ignore`` with no rule list is NOT honored: every suppression
names what it silences.
"""
from __future__ import annotations

import ast
import io
import os
import re
import tokenize
from dataclasses import dataclass, field
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Set, Tuple

_SUPPRESS = re.compile(r"#\s*repolint:\s*(ignore|file-ignore)\[([a-z0-9_,\- ]+)\]")

_SKIP_DIRS = {"__pycache__", ".git", ".ruff_cache", ".pytest_cache", "build",
              "dist", "node_modules", ".mypy_cache"}


@dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""
    path: str
    line: int
    col: int
    rule: str
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: [{self.rule}] {self.message}"


@dataclass
class AnalysisConfig:
    """Knobs shared by the rules (CLI flags map 1:1 onto these).

    ``assumed_dims`` bounds symbolic block/scratch dimensions the Pallas
    VMEM estimator cannot resolve statically; ``default_dim`` bounds names
    absent from the table. Both are deliberately worst-case-ish: the
    estimate is an upper bound, not a measurement.
    """
    vmem_cap_bytes: int = 16 * 1024 * 1024   # one TPU core's VMEM
    default_dim: int = 512
    assumed_dims: Dict[str, int] = field(default_factory=lambda: {
        # repo-wide kernel parameter conventions (see kernels/*.py defaults)
        "block_b": 64, "block_q": 512, "block_k": 512,
        "B": 1024, "T": 64, "H": 64, "D": 256, "G": 32, "K": 8192,
        "R": 1 << 20, "n": 64, "n_k": 64, "n_q": 64,
    })


class ModuleContext:
    """One parsed module plus everything rules repeatedly need."""

    def __init__(self, path: str, source: str,
                 config: AnalysisConfig) -> None:
        self.path = path
        self.source = source
        self.config = config
        self.tree = ast.parse(source, filename=path)
        for parent in ast.walk(self.tree):
            for child in ast.iter_child_nodes(parent):
                child._repolint_parent = parent  # type: ignore[attr-defined]
        self.line_suppressions: Dict[int, Set[str]] = {}
        self.file_suppressions: Set[str] = set()
        self.warnings: List[str] = []
        self._scan_suppressions()

    def _scan_suppressions(self) -> None:
        try:
            tokens = tokenize.generate_tokens(io.StringIO(self.source).readline)
            for tok in tokens:
                if tok.type != tokenize.COMMENT:
                    continue
                m = _SUPPRESS.search(tok.string)
                if not m:
                    continue
                rules = {r.strip() for r in m.group(2).split(",") if r.strip()}
                if m.group(1) == "file-ignore":
                    self.file_suppressions |= rules
                else:
                    self.line_suppressions.setdefault(
                        tok.start[0], set()).update(rules)
        except tokenize.TokenError as e:
            # ast.parse already accepted the file, so this is near-unreachable;
            # surface it anyway — a failed comment scan means suppressions in
            # this file may silently not apply
            self.warnings.append(
                f"{self.path}: suppression scan failed: {e}")

    def parent(self, node: ast.AST) -> Optional[ast.AST]:
        return getattr(node, "_repolint_parent", None)

    def parents(self, node: ast.AST) -> Iterator[ast.AST]:
        cur = self.parent(node)
        while cur is not None:
            yield cur
            cur = self.parent(cur)

    def suppressed(self, rule: str, line: int) -> bool:
        return (rule in self.file_suppressions
                or rule in self.line_suppressions.get(line, set()))


class Rule:
    """Base class: subclasses set ``id``/``summary`` and implement ``check``."""

    id: str = ""
    summary: str = ""

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        raise NotImplementedError

    def finding(self, ctx: ModuleContext, node: ast.AST, message: str) -> Finding:
        return Finding(ctx.path, getattr(node, "lineno", 1),
                       getattr(node, "col_offset", 0), self.id, message)


def all_rules() -> List[Rule]:
    """The registry, in documentation order (``repolint --list-rules``)."""
    from repro.analysis.hygiene import SilentExceptRule
    from repro.analysis.idspace import IdSpaceRule
    from repro.analysis.pallas_resources import DmaPairingRule, VmemBudgetRule
    from repro.analysis.purity import JaxPurityRule, UnseededRandomRule
    from repro.analysis.threadsafety import ThreadSafetyRule
    return [IdSpaceRule(), JaxPurityRule(), UnseededRandomRule(),
            VmemBudgetRule(), DmaPairingRule(), ThreadSafetyRule(),
            SilentExceptRule()]


def iter_python_files(paths: Sequence[str]) -> Iterator[str]:
    """Expand files/directories into a sorted stream of ``*.py`` paths."""
    out: Set[str] = set()
    for p in paths:
        if os.path.isfile(p):
            out.add(p)
            continue
        for dirpath, dirnames, filenames in os.walk(p):
            dirnames[:] = [d for d in dirnames
                           if d not in _SKIP_DIRS and not d.startswith(".")]
            for name in filenames:
                if name.endswith(".py"):
                    out.add(os.path.join(dirpath, name))
    yield from sorted(out)


def run_paths(paths: Sequence[str], rules: Optional[Iterable[Rule]] = None,
              config: Optional[AnalysisConfig] = None,
              ) -> Tuple[List[Finding], List[str]]:
    """Run ``rules`` over every python file under ``paths``.

    Returns ``(findings, errors)`` — ``errors`` are files that failed to
    parse (reported, never silently skipped: an unparsable file would
    otherwise exempt itself from every invariant).
    """
    config = config or AnalysisConfig()
    active = list(rules) if rules is not None else all_rules()
    findings: List[Finding] = []
    errors: List[str] = []
    for path in iter_python_files(paths):
        try:
            with open(path, encoding="utf-8") as f:
                ctx = ModuleContext(path, f.read(), config)
        except (SyntaxError, UnicodeDecodeError) as e:
            errors.append(f"{path}: {type(e).__name__}: {e}")
            continue
        errors.extend(ctx.warnings)
        for rule in active:
            for finding in rule.check(ctx):
                if not ctx.suppressed(rule.id, finding.line):
                    findings.append(finding)
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings, errors
