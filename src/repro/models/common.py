"""Shared building blocks: norms, RoPE, embeddings, init, pattern-group utils."""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "float16": jnp.float16}[name]


def pad_vocab(vocab: int, multiple: int = 256) -> int:
    """Pad vocab to a lane/mesh-friendly multiple (standard TPU practice)."""
    return ((vocab + multiple - 1) // multiple) * multiple


# --- norms -------------------------------------------------------------------
def rms_norm(x, w, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


def layer_norm(x, w, b, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


# --- RoPE --------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)                      # (head_dim//2,)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                    # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]                 # (..., seq, 1, hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --- init --------------------------------------------------------------------
def dense_init(key, shape, in_axis_size: int, dtype) -> jnp.ndarray:
    scale = 1.0 / math.sqrt(max(in_axis_size, 1))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def zeros(shape, dtype):
    return jnp.zeros(shape, dtype)


class KeyGen:
    """Deterministic key splitter for readable init code."""

    def __init__(self, key):
        self._key = key

    def __call__(self):
        self._key, sub = jax.random.split(self._key)
        return sub


# --- activation --------------------------------------------------------------
def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": lambda x: jax.nn.gelu(x, approximate=True)}[name]


def softcap(x, cap: float):
    if not cap:
        return x
    return jnp.tanh(x / cap) * cap


# --- pattern-group utilities ---------------------------------------------------
def pattern_split(cfg: ModelConfig) -> Tuple[int, Tuple[str, ...], Tuple[str, ...]]:
    """num full pattern groups, the pattern, and the remainder layer kinds."""
    pat = cfg.layer_pattern
    n_groups = cfg.num_layers // len(pat)
    rest = cfg.layer_kinds[n_groups * len(pat):]
    return n_groups, pat, rest


def stack_trees(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)
