"""Dense MLP and MoE blocks (sort-based, capacity-bounded expert dispatch)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import KeyGen, act_fn, dense_init
from repro.sharding.policy import constrain


# --- dense MLP ----------------------------------------------------------------
def init_mlp(keys: KeyGen, cfg: ModelConfig, dtype):
    d, ff = cfg.d_model, cfg.d_ff
    p = {"w1": dense_init(keys(), (d, ff), d, dtype),
         "w2": dense_init(keys(), (ff, d), ff, dtype)}
    s = {"w1": ("fsdp", "ffn"), "w2": ("ffn", "fsdp")}
    if cfg.activation == "silu":
        p["w3"] = dense_init(keys(), (d, ff), d, dtype)
        s["w3"] = ("fsdp", "ffn")
    if cfg.mlp_bias:
        p["b1"] = jnp.zeros((ff,), dtype)
        p["b2"] = jnp.zeros((d,), dtype)
        s["b1"] = ("ffn",)
        s["b2"] = (None,)
    return p, s


def mlp_block(p, x, cfg: ModelConfig):
    act = act_fn(cfg.activation)
    dt = x.dtype
    h = x @ p["w1"].astype(dt)
    if "b1" in p:
        h = h + p["b1"].astype(dt)
    if cfg.activation == "silu":
        h = act(h) * (x @ p["w3"].astype(dt))
    else:
        h = act(h)
    h = constrain(h, ("batch", "qseq", "ffn"))
    y = h @ p["w2"].astype(dt)
    if "b2" in p:
        y = y + p["b2"].astype(dt)
    return y


# --- MoE ------------------------------------------------------------------------
def init_moe(keys: KeyGen, cfg: ModelConfig, dtype):
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    p = {
        "router": dense_init(keys(), (d, E), d, jnp.float32),
        "w1": dense_init(keys(), (E, d, ff), d, dtype),
        "w2": dense_init(keys(), (E, ff, d), ff, dtype),
    }
    s = {
        "router": ("fsdp", None),
        "w1": ("expert", "fsdp", "expert_ffn"),
        "w2": ("expert", "expert_ffn", "fsdp"),
    }
    if cfg.activation == "silu":
        p["w3"] = dense_init(keys(), (E, d, ff), d, dtype)
        s["w3"] = ("expert", "fsdp", "expert_ffn")
    return p, s


def moe_block(p, x, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Group-local, gather-only top-k expert dispatch with capacity dropping.

    x: (B, S, d) -> (out, aux_loss). Each batch row is a dispatch *group*
    (groups are batch-sharded, so all routing stays shard-local under SPMD).
    Every data movement is a gather (sort + take_along_axis; the inverse
    permutation is argsort(argsort)) — scatter-based dispatch over the global
    token dim forced GSPMD to all-reduce full (T·k, d) buffers (measured
    34 GB/op on granite-moe); the gather form lowers with zero collectives.
    Expert FFNs run as batched einsums on the MXU.
    """
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    act = act_fn(cfg.activation)
    dt = x.dtype
    P = S * k

    logits = jnp.einsum("gsd,de->gse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                 # (B, S, E)
    top_g, top_i = jax.lax.top_k(probs, k)                  # (B, S, k)
    top_g = top_g / jnp.maximum(jnp.sum(top_g, axis=-1, keepdims=True), 1e-9)

    # aux load-balancing loss (Switch-style, over all tokens)
    frac_routed = jnp.mean(
        jax.nn.one_hot(top_i, E, dtype=jnp.float32).sum(2), axis=(0, 1))
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac_routed / k * mean_prob)

    cap = int(max(k, (S * k * cfg.capacity_factor) / E))
    cap = min(((cap + 7) // 8) * 8, P)

    pair_e = top_i.reshape(B, P)                            # (B, S*k)
    pair_g = top_g.reshape(B, P)
    pair_t = jnp.broadcast_to(
        jnp.repeat(jnp.arange(S), k)[None, :], (B, P))

    order = jnp.argsort(pair_e, axis=1)                     # stable per group
    inv_order = jnp.argsort(order, axis=1)                  # inverse perm
    se = jnp.take_along_axis(pair_e, order, axis=1)
    st = jnp.take_along_axis(pair_t, order, axis=1)

    counts = jnp.sum(pair_e[:, :, None] == jnp.arange(E)[None, None], axis=1)
    starts = jnp.cumsum(counts, axis=1) - counts            # (B, E) exclusive
    slot = jnp.arange(P)[None, :] - jnp.take_along_axis(starts, se, axis=1)
    keep = slot < cap
    pos = jnp.where(keep, se * cap + slot, E * cap)         # sentinel = drop

    # token index for each (expert, capacity-slot): pure gathers
    idx_ec = starts[:, :, None] + jnp.arange(cap)[None, None, :]   # (B,E,cap)
    valid_ec = jnp.arange(cap)[None, None, :] < counts[:, :, None]
    idx_flat = jnp.clip(idx_ec.reshape(B, E * cap), 0, P - 1)
    tok_at = jnp.take_along_axis(st, idx_flat, axis=1)      # (B, E*cap)
    xe = jnp.take_along_axis(x, tok_at[..., None], axis=1)  # (B, E*cap, d)
    xe = jnp.where(valid_ec.reshape(B, E * cap)[..., None], xe, 0)
    xe = xe.reshape(B, E, cap, d)
    xe = constrain(xe, ("batch", "expert", None, None))

    h = jnp.einsum("gecd,edf->gecf", xe, p["w1"].astype(dt))
    if cfg.activation == "silu":
        h = act(h) * jnp.einsum("gecd,edf->gecf", xe, p["w3"].astype(dt))
    else:
        h = act(h)
    h = constrain(h, ("batch", "expert", None, "expert_ffn"))
    ye = jnp.einsum("gecf,efd->gecd", h, p["w2"].astype(dt))  # (B,E,cap,d)

    ye_pad = jnp.concatenate(
        [ye.reshape(B, E * cap, d), jnp.zeros((B, 1, d), ye.dtype)], axis=1)
    pair_pos = jnp.take_along_axis(pos, inv_order, axis=1)  # original order
    vals = jnp.take_along_axis(ye_pad, pair_pos[..., None], axis=1)  # (B,P,d)
    out = jnp.sum(vals.reshape(B, S, k, d)
                  * pair_g.reshape(B, S, k, 1).astype(dt), axis=2)
    return out.astype(dt), aux
