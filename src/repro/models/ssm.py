"""Mamba-2 SSD (state-space duality) block — chunked train/prefill + recurrent decode.

Chunked algorithm (matmul-dominant, MXU-friendly): within-chunk quadratic
attention-like term + inter-chunk state recurrence (lax.scan over chunks).
Follows the minimal SSD reference of arXiv:2405.21060 §6.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import KeyGen, dense_init, rms_norm
from repro.sharding.policy import constrain


def conv_dim(cfg: ModelConfig) -> int:
    return cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state


def init_ssm(keys: KeyGen, cfg: ModelConfig, dtype):
    d, di = cfg.d_model, cfg.d_inner
    G, N, H = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    cd = conv_dim(cfg)
    p = {
        "in_proj": dense_init(keys(), (d, 2 * di + 2 * G * N + H), d, dtype),
        "conv_w": dense_init(keys(), (cfg.ssm_conv_width, cd), cfg.ssm_conv_width, dtype),
        "conv_b": jnp.zeros((cd,), dtype),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "norm_w": jnp.zeros((di,), dtype),
        "out_proj": dense_init(keys(), (di, d), di, dtype),
    }
    s = {
        "in_proj": ("fsdp", "inner"),
        "conv_w": (None, "inner"),
        "conv_b": ("inner",),
        "dt_bias": (None,),
        "A_log": (None,),
        "D": (None,),
        "norm_w": ("inner",),
        "out_proj": ("inner", "fsdp"),
    }
    return p, s


def _causal_conv(x, w, b):
    """Depthwise causal conv1d. x: (B, L, C); w: (W, C)."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(W))
    return out + b


def _segsum(dA):
    """Stable segment-sum: out[..., i, j] = sum_{j<k<=i} dA[..., k] (i>=j)."""
    Q = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]               # (..., i, j)
    mask = jnp.tril(jnp.ones((Q, Q), bool), 0)
    return jnp.where(mask, seg, -jnp.inf)


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int):
    """SSD scan. x:(B,L,H,P) dt:(B,L,H) A:(H,) Bm/Cm:(B,L,G,N) -> y,(final state)."""
    B, L, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Q = min(chunk, L)
    L0 = L
    if L % Q:
        # pad with dt=0 steps: decay exp(0)=1 and zero input => exact no-op
        pad = Q - L % Q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        L = L + pad
    nc = L // Q

    xc = x.reshape(B, nc, Q, H, P).astype(jnp.float32)
    dtc = dt.reshape(B, nc, Q, H).astype(jnp.float32)
    Bc = Bm.reshape(B, nc, Q, G, N).astype(jnp.float32)
    Cc = Cm.reshape(B, nc, Q, G, N).astype(jnp.float32)

    dA = dtc * A                                            # (B,nc,Q,H), negative
    dA_hq = jnp.moveaxis(dA, -1, -2)                        # (B,nc,H,Q)
    cum = jnp.cumsum(dA_hq, axis=-1)                        # (B,nc,H,Q)

    # ---- within-chunk (quadratic, attention-like) --------------------------
    Lmat = jnp.exp(_segsum(dA_hq))                          # (B,nc,H,Q,Q)
    scores = jnp.einsum("bcign,bcjgn->bcgij", Cc, Bc)       # (B,nc,G,Q,Q)
    scores = jnp.repeat(scores, rep, axis=2)                # (B,nc,H,Q,Q)
    M = scores * Lmat * jnp.moveaxis(dtc, -1, -2)[..., None, :]
    Yd = jnp.einsum("bchij,bcjhp->bcihp", M, xc)            # (B,nc,Q,H,P)

    # ---- chunk states -------------------------------------------------------
    decay_states = jnp.exp(cum[..., -1:] - cum)             # (B,nc,H,Q)
    sdt = jnp.moveaxis(decay_states * jnp.moveaxis(dtc, -1, -2), -1, -2)
    S = jnp.einsum("bcjgn,bcjh,bcjhp->bchpn", Bc, sdt, xc)  # (B,nc,H,P,N)

    # ---- inter-chunk recurrence (scan over chunks) -------------------------
    chunk_decay = jnp.exp(cum[..., -1])                     # (B,nc,H)

    def step(carry, inp):
        S_c, decay_c = inp                                   # (B,H,P,N), (B,H)
        new = carry * decay_c[..., None, None] + S_c
        return new, carry                                    # emit state *before* chunk

    init = jnp.zeros((B, H, P, N), jnp.float32)
    final_state, prev_states = jax.lax.scan(
        step, init,
        (jnp.moveaxis(S, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)           # (B,nc,H,P,N)

    # ---- inter-chunk output -------------------------------------------------
    state_decay = jnp.exp(cum)                              # (B,nc,H,Q)
    Ch = jnp.repeat(Cc, rep, axis=3)                        # (B,nc,Q,H,N)
    Yo = jnp.einsum("bcihn,bchpn,bchi->bcihp", Ch, prev_states, state_decay)

    y = (Yd + Yo).reshape(B, L, H, P)[:, :L0]
    return y, final_state


def ssm_forward(p, x, cfg: ModelConfig):
    """Full Mamba-2 block, train/prefill. x: (B, L, d) -> (B, L, d)."""
    B, L, d = x.shape
    di, G, N, H = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    P = cfg.ssm_headdim
    cdt = x.dtype
    zxbcdt = x @ p["in_proj"].astype(cdt)
    z, xBC, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * G * N], axis=-1)
    xBC = jax.nn.silu(_causal_conv(xBC, p["conv_w"].astype(cdt),
                                   p["conv_b"].astype(cdt)))
    xs, Bm, Cm = jnp.split(xBC, [di, di + G * N], axis=-1)
    xs = constrain(xs, ("batch", "qseq", "inner"))
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, _ = ssd_chunked(
        xs.reshape(B, L, H, P), dt, A,
        Bm.reshape(B, L, G, N), Cm.reshape(B, L, G, N), cfg.ssm_chunk)
    y = y + p["D"][:, None] * xs.reshape(B, L, H, P).astype(jnp.float32)
    y = y.reshape(B, L, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    return y @ p["out_proj"].astype(cdt)


# --- decode -----------------------------------------------------------------
def init_ssm_cache(cfg: ModelConfig, batch: int, dtype):
    cd = conv_dim(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, cd), dtype),
        "state": jnp.zeros((batch, cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state),
                           jnp.float32),
    }


def ssm_cache_specs(cfg: ModelConfig):
    return {"conv": ("batch", None, "inner"),
            "state": ("batch", "ssm_heads", None, None)}


def ssm_decode(p, x, cache, cfg: ModelConfig):
    """One-token step. x: (B, 1, d) -> (y, new_cache)."""
    B = x.shape[0]
    di, G, N, H = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    P = cfg.ssm_headdim
    cdt = x.dtype
    zxbcdt = x[:, 0] @ p["in_proj"].astype(cdt)              # (B, ...)
    z, xBC, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * G * N], axis=-1)

    conv_in = jnp.concatenate([cache["conv"].astype(cdt), xBC[:, None, :]], axis=1)
    xBC = jax.nn.silu(jnp.einsum("bwc,wc->bc", conv_in, p["conv_w"].astype(cdt))
                      + p["conv_b"].astype(cdt))
    new_conv = conv_in[:, 1:, :]

    xs, Bm, Cm = jnp.split(xBC, [di, di + G * N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A)                                     # (B,H)
    xh = xs.reshape(B, H, P).astype(jnp.float32)
    Bh = jnp.repeat(Bm.reshape(B, G, N), H // G, axis=1)     # (B,H,N)
    Ch = jnp.repeat(Cm.reshape(B, G, N), H // G, axis=1)
    state = cache["state"] * dA[..., None, None] \
        + dt[..., None, None] * xh[..., None] * Bh[:, :, None, :]
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch) + p["D"][:, None] * xh
    y = y.reshape(B, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    out = (y @ p["out_proj"].astype(cdt))[:, None, :]
    return out, {"conv": new_conv.astype(cache["conv"].dtype), "state": state}
