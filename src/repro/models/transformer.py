"""Decoder-only LM covering dense / moe / ssm / hybrid / vlm families.

Layers are organized as repeating *pattern groups* (e.g. gemma3's 5×local +
1×global) with per-position stacked parameters, scanned with ``lax.scan`` so
the lowered HLO stays O(pattern) instead of O(num_layers). Remainder layers
(num_layers % len(pattern)) are applied unstacked.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models import mlp as mlp_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.common import (
    KeyGen, apply_rope, dense_init, dtype_of, pad_vocab, pattern_split,
    rms_norm,
)
from repro.sharding.policy import constrain


# ===========================================================================
# attention sub-block
# ===========================================================================
def init_attn(keys: KeyGen, cfg: ModelConfig, dtype):
    d, H, K, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": dense_init(keys(), (d, H, Dh), d, dtype),
        "wk": dense_init(keys(), (d, K, Dh), d, dtype),
        "wv": dense_init(keys(), (d, K, Dh), d, dtype),
        "wo": dense_init(keys(), (H, Dh, d), H * Dh, dtype),
    }
    s = {
        "wq": ("fsdp", "heads", None),
        "wk": ("fsdp", "kv_heads", None),
        "wv": ("fsdp", "kv_heads", None),
        "wo": ("heads", None, "fsdp"),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((Dh,), dtype)
        p["k_norm"] = jnp.zeros((Dh,), dtype)
        s["q_norm"] = (None,)
        s["k_norm"] = (None,)
    if cfg.attn_bias:
        p["bq"] = jnp.zeros((H, Dh), dtype)
        p["bv"] = jnp.zeros((K, Dh), dtype)
        p["bo"] = jnp.zeros((d,), dtype)
        s["bq"] = ("heads", None)
        s["bv"] = ("kv_heads", None)
        s["bo"] = (None,)
    return p, s


def _rope_theta(cfg: ModelConfig, kind: str) -> float:
    if kind == "local" and cfg.rope_local_theta is not None:
        return cfg.rope_local_theta
    return cfg.rope_theta


def _project_qkv(p, x, cfg: ModelConfig, positions, kind: str):
    rope = cfg.use_rope
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
        v = v + p["bv"].astype(dt)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if rope:
        theta = _rope_theta(cfg, kind)
        q = apply_rope(q, positions, theta)
        k = apply_rope(k, positions, theta)
    return q, k, v


def attn_apply(p, x, cfg: ModelConfig, kind: str, q_offset: int = 0,
               causal: bool = True):
    """Full-sequence attention (train/prefill)."""
    B, S, _ = x.shape
    positions = q_offset + jnp.arange(S)
    q, k, v = _project_qkv(p, x, cfg, positions, kind)
    q = constrain(q, ("batch", "qseq", "heads", None))
    window = cfg.local_window if kind == "local" else None
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              softcap=cfg.logit_softcap, q_offset=q_offset)
    out = constrain(out, ("batch", "qseq", "heads", None))
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(out.dtype))
    if "bo" in p:
        y = y + p["bo"].astype(y.dtype)
    return y


def attn_decode(p, x, kv_cache, cache_pos, step, cfg: ModelConfig, kind: str):
    """One-token attention. kv_cache: {"k","v"} (B, Lc, K, Dh); step scalar."""
    B = x.shape[0]
    Lc = kv_cache["k"].shape[1]
    pos_b = jnp.full((B,), step, jnp.int32)
    q, k, v = _project_qkv(p, x, cfg, pos_b[:, None], kind)
    idx = jnp.mod(step, Lc) if kind == "local" else jnp.minimum(step, Lc - 1)
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        kv_cache["k"], k.astype(kv_cache["k"].dtype), idx, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        kv_cache["v"], v.astype(kv_cache["v"].dtype), idx, axis=1)
    window = cfg.local_window if kind == "local" else None
    out = ops.decode_attention(q, k_cache, v_cache, cache_pos, pos_b,
                               window=window, softcap=cfg.logit_softcap)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(out.dtype))
    if "bo" in p:
        y = y + p["bo"].astype(y.dtype)
    return y, {"k": k_cache, "v": v_cache}


# ===========================================================================
# layer init / apply / decode by kind
# ===========================================================================
def init_layer(kind: str, cfg: ModelConfig, keys: KeyGen, dtype):
    d = cfg.d_model
    if kind == "ssm":
        pp, ss = ssm_mod.init_ssm(keys, cfg, dtype)
        return {"ln1": jnp.zeros((d,), dtype), "ssm": pp}, \
               {"ln1": (None,), "ssm": ss}
    p: Dict[str, Any] = {"ln1": jnp.zeros((d,), dtype), "ln2": jnp.zeros((d,), dtype)}
    s: Dict[str, Any] = {"ln1": (None,), "ln2": (None,)}
    if kind == "recurrent":
        p["rec"], s["rec"] = rglru_mod.init_rglru(keys, cfg, dtype)
    else:
        p["attn"], s["attn"] = init_attn(keys, cfg, dtype)
    if cfg.n_experts and kind in ("global", "local"):
        p["moe"], s["moe"] = mlp_mod.init_moe(keys, cfg, dtype)
    else:
        p["mlp"], s["mlp"] = mlp_mod.init_mlp(keys, cfg, dtype)
    return p, s


def apply_layer(kind: str, p, x, cfg: ModelConfig, q_offset: int = 0):
    """Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind == "ssm":
        x = x + ssm_mod.ssm_forward(p["ssm"], rms_norm(x, p["ln1"], cfg.norm_eps), cfg)
        return x, aux
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind == "recurrent":
        y, _ = rglru_mod.rglru_forward(p["rec"], h, cfg)
    else:
        y = attn_apply(p["attn"], h, cfg, kind, q_offset)
    x = x + y
    x = constrain(x, ("batch", "qseq", None))
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        y, aux = mlp_mod.moe_block(p["moe"], h, cfg)
    else:
        y = mlp_mod.mlp_block(p["mlp"], h, cfg)
    x = x + y
    x = constrain(x, ("batch", "qseq", None))
    return x, aux


def init_layer_cache(kind: str, cfg: ModelConfig, batch: int, max_len: int, dtype):
    if kind == "ssm":
        return ssm_mod.init_ssm_cache(cfg, batch, dtype)
    if kind == "recurrent":
        return rglru_mod.init_rglru_cache(cfg, batch, dtype)
    Lc = min(cfg.local_window, max_len) if kind == "local" else max_len
    K, Dh = cfg.n_kv_heads, cfg.head_dim
    return {"k": jnp.zeros((batch, Lc, K, Dh), dtype),
            "v": jnp.zeros((batch, Lc, K, Dh), dtype)}


def layer_cache_specs(kind: str, cfg: ModelConfig):
    if kind == "ssm":
        return ssm_mod.ssm_cache_specs(cfg)
    if kind == "recurrent":
        return rglru_mod.rglru_cache_specs(cfg)
    return {"k": ("batch", "kvseq", "kv_heads", None),
            "v": ("batch", "kvseq", "kv_heads", None)}


def decode_layer(kind: str, p, x, cache, pos_tree, step, cfg: ModelConfig):
    """Returns (x, new_cache). pos_tree: {"global": (B,Lg), "local": (B,Ll)}."""
    if kind == "ssm":
        y, cache = ssm_mod.ssm_decode(p["ssm"], rms_norm(x, p["ln1"], cfg.norm_eps), cache, cfg)
        return x + y, cache
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind == "recurrent":
        y, cache = rglru_mod.rglru_decode(p["rec"], h, cache, cfg)
    else:
        y, cache = attn_decode(p["attn"], h, cache, pos_tree[kind], step, cfg, kind)
    x = x + y
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        y, _ = mlp_mod.moe_block(p["moe"], h, cfg)
    else:
        y = mlp_mod.mlp_block(p["mlp"], h, cfg)
    return x + y, cache


# ===========================================================================
# whole-model init / specs
# ===========================================================================
def _stack_specs(spec_tree):
    return jax.tree.map(
        lambda t: (None,) + t, spec_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(i, (str, type(None))) for i in x))


def init_lm(cfg: ModelConfig, key) -> Dict[str, Any]:
    dtype = dtype_of(cfg.param_dtype)
    kg = KeyGen(key)
    Vp = pad_vocab(cfg.vocab_size)
    d = cfg.d_model
    n_groups, pattern, rest = pattern_split(cfg)

    params: Dict[str, Any] = {
        "embed": dense_init(kg(), (Vp, d), d, dtype),
        "final_norm": jnp.zeros((d,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(kg(), (d, Vp), d, dtype)

    pattern_params = []
    for i, kind in enumerate(pattern):
        keys_arr = jax.random.split(kg(), n_groups)
        def one(k, kind=kind):
            return init_layer(kind, cfg, KeyGen(k), dtype)[0]
        pattern_params.append(jax.vmap(one)(keys_arr))
    params["pattern"] = pattern_params
    params["rest"] = [init_layer(kind, cfg, kg, dtype)[0] for kind in rest]
    return params


def lm_param_specs(cfg: ModelConfig) -> Dict[str, Any]:
    n_groups, pattern, rest = pattern_split(cfg)
    dummy = KeyGen(jax.random.PRNGKey(0))
    # vocab-parallel embedding (Megatron-style): rows sharded over the model
    # axis only. Sharding d over "data" too makes GSPMD all-gather the whole
    # table for the logits matmul (measured 1.6 GB/step on llama) — d stays
    # replicated; the table is small once vocab-sharded.
    specs: Dict[str, Any] = {
        "embed": ("vocab", None),
        "final_norm": (None,),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = (None, "vocab")
    specs["pattern"] = [
        _stack_specs(init_layer(kind, cfg, dummy, jnp.float32)[1]) for kind in pattern
    ]
    specs["rest"] = [init_layer(kind, cfg, dummy, jnp.float32)[1] for kind in rest]
    return specs


# ===========================================================================
# forward (train / prefill)
# ===========================================================================
def embed_tokens(params, tokens, cfg: ModelConfig):
    x = params["embed"][tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x.astype(dtype_of(cfg.compute_dtype))


def unembed(params, x, cfg: ModelConfig):
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
    return constrain(logits, ("batch", "qseq", "vocab"))


def forward_lm(params, tokens, cfg: ModelConfig, *, remat: bool = False):
    """tokens (B, S) -> (logits (B, S, Vp), aux_loss)."""
    n_groups, pattern, rest = pattern_split(cfg)
    x = embed_tokens(params, tokens, cfg)
    x = constrain(x, ("batch", "qseq", None))
    aux0 = jnp.zeros((), jnp.float32)

    def group_body(carry, gparams):
        x, aux = carry
        for i, kind in enumerate(pattern):
            x, a = apply_layer(kind, gparams[i], x, cfg)
            aux = aux + a
        return (x, aux), None

    body = jax.checkpoint(group_body) if remat else group_body
    if n_groups > 0:
        (x, aux), _ = jax.lax.scan(body, (x, aux0), params["pattern"])
    else:
        aux = aux0
    for p, kind in zip(params["rest"], rest):
        x, a = apply_layer(kind, p, x, cfg)
        aux = aux + a
    return unembed(params, x, cfg), aux


def lm_loss(params, batch, cfg: ModelConfig, *, remat: bool = False):
    """batch: {"tokens": (B,S), "targets": (B,S)} -> scalar mean xent."""
    logits, aux = forward_lm(params, batch["tokens"], cfg, remat=remat)
    Vp = logits.shape[-1]
    mask = (jnp.arange(Vp) < cfg.vocab_size)
    logits = jnp.where(mask, logits.astype(jnp.float32), -1e30)
    logz = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, batch["targets"][..., None], axis=-1)[..., 0]
    nll = jnp.mean(logz - tgt)
    return nll + cfg.router_aux_weight * aux


# ===========================================================================
# decode (serve_step)
# ===========================================================================
def init_cache_lm(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    n_groups, pattern, rest = pattern_split(cfg)
    cache: Dict[str, Any] = {"step": jnp.zeros((), jnp.int32)}
    kinds = set(cfg.layer_kinds)
    if "global" in kinds:
        cache["global_pos"] = jnp.full((batch, max_len), -1, jnp.int32)
    if "local" in kinds:
        Ll = min(cfg.local_window, max_len)
        cache["local_pos"] = jnp.full((batch, Ll), -1, jnp.int32)

    def stacked(kind):
        one = init_layer_cache(kind, cfg, batch, max_len, dtype)
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (n_groups,) + a.shape), one)

    cache["pattern"] = [stacked(kind) for kind in pattern]
    cache["rest"] = [init_layer_cache(kind, cfg, batch, max_len, dtype) for kind in rest]
    return cache


def lm_cache_specs(cfg: ModelConfig):
    n_groups, pattern, rest = pattern_split(cfg)
    specs: Dict[str, Any] = {"step": ()}
    kinds = set(cfg.layer_kinds)
    if "global" in kinds:
        specs["global_pos"] = ("batch", "kvseq")
    if "local" in kinds:
        specs["local_pos"] = ("batch", None)
    specs["pattern"] = [_stack_specs(layer_cache_specs(kind, cfg)) for kind in pattern]
    specs["rest"] = [layer_cache_specs(kind, cfg) for kind in rest]
    return specs


def _cache_pos_views(cache):
    views = {}
    if "global_pos" in cache:
        views["global"] = cache["global_pos"]
    if "local_pos" in cache:
        views["local"] = cache["local_pos"]
    return views


def decode_step_lm(params, cache, tokens, cfg: ModelConfig):
    """One decode step. tokens (B, 1) -> (logits (B, 1, Vp), new_cache)."""
    n_groups, pattern, rest = pattern_split(cfg)
    step = cache["step"]
    B = tokens.shape[0]
    new_cache = dict(cache)

    # update position rings first so this step's K/V slot is valid
    if "global_pos" in cache:
        Lg = cache["global_pos"].shape[1]
        idx = jnp.minimum(step, Lg - 1)
        new_cache["global_pos"] = jax.lax.dynamic_update_slice_in_dim(
            cache["global_pos"], jnp.full((B, 1), step, jnp.int32), idx, axis=1)
    if "local_pos" in cache:
        Ll = cache["local_pos"].shape[1]
        idx = jnp.mod(step, Ll)
        new_cache["local_pos"] = jax.lax.dynamic_update_slice_in_dim(
            cache["local_pos"], jnp.full((B, 1), step, jnp.int32), idx, axis=1)
    pos_tree = _cache_pos_views(new_cache)

    x = embed_tokens(params, tokens, cfg)

    def group_body(x, xs):
        gparams, gcache = xs
        new_gcache = []
        for i, kind in enumerate(pattern):
            x, c = decode_layer(kind, gparams[i], x, gcache[i], pos_tree, step, cfg)
            new_gcache.append(c)
        return x, new_gcache

    if n_groups > 0:
        x, new_pattern = jax.lax.scan(
            group_body, x, (params["pattern"], cache["pattern"]))
        new_cache["pattern"] = new_pattern
    new_rest = []
    for p, c, kind in zip(params["rest"], cache["rest"], rest):
        x, c = decode_layer(kind, p, x, c, pos_tree, step, cfg)
        new_rest.append(c)
    new_cache["rest"] = new_rest
    new_cache["step"] = step + 1
    return unembed(params, x, cfg), new_cache


def prefill_into_cache(params, cache, tokens, cfg: ModelConfig):
    """Fill caches by running decode_step over the prompt (small-scale serving).

    Exact but sequential; used by tests/examples on reduced configs. Production
    prefill lowers ``forward_lm`` (the `prefill_*` dry-run cells).
    """
    def body(cache, tok):
        logits, cache = decode_step_lm(params, cache, tok[:, None], cfg)
        return cache, logits[:, 0]

    cache, logits = jax.lax.scan(body, cache, jnp.moveaxis(tokens, 1, 0))
    return cache, jnp.moveaxis(logits, 0, 1)
