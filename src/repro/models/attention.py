"""Scalable pure-JAX attention (chunked, flash-style accumulators).

These are the XLA-lowered implementations used for CPU execution and for the
multi-pod dry-run (memory-safe O(chunk) intermediates). The Pallas TPU kernels
in ``repro.kernels`` compute the same math with explicit VMEM tiling;
``repro.kernels.ops`` dispatches between them.

Shapes: q (B, Sq, Hq, D); k, v (B, Skv, Hkv, D); GQA via Hq = Hkv * group.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


NEG_INF = -1e30


def _softcap(x, cap: float):
    if not cap:
        return x
    return jnp.tanh(x / cap) * cap


def _block_attn(q, k, v, qpos, kpos, *, causal, window, softcap, scale):
    """One (q-block × k-block) attention with flash accumulators returned.

    q: (B, Cq, Hkv, G, D); k/v: (B, Ck, Hkv, D). Returns (o, m, l) where
    o: unnormalized weighted values, m: row max, l: row sum-exp.

    Inputs stay bf16 with f32 MXU accumulation (preferred_element_type):
    casting inputs to f32 first makes GSPMD all-gather K/V at double width
    (XLA hoists the convert above the collective).
    """
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                   preferred_element_type=jnp.float32)
    s = _softcap(s * scale, softcap)
    mask = jnp.ones((q.shape[1], k.shape[1]), dtype=bool)
    dpos = qpos[:, None] - kpos[None, :]                   # (Cq, Ck)
    if causal:
        mask &= dpos >= 0
    if window is not None:
        mask &= dpos < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1)                                 # (B,H,G,Cq)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(mask[None, None, None], p, 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o, m, l


def _merge(acc, new):
    """Merge two flash partials (o, m, l) -> combined."""
    o1, m1, l1 = acc
    o2, m2, l2 = new
    m = jnp.maximum(m1, m2)
    a1 = jnp.exp(m1 - m)
    a2 = jnp.exp(m2 - m)
    # o has layout (B, Cq, Hkv, G, D); m/l have (B, Hkv, G, Cq)
    w1 = jnp.transpose(a1, (0, 3, 1, 2))[..., None]
    w2 = jnp.transpose(a2, (0, 3, 1, 2))[..., None]
    o = o1 * w1 + o2 * w2
    l = l1 * a1 + l2 * a2
    return o, m, l


def _finalize(o, m, l, dtype):
    w = jnp.transpose(1.0 / jnp.maximum(l, 1e-30), (0, 3, 1, 2))[..., None]
    return (o * w).astype(dtype)


def chunked_attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: float = 0.0,
    q_chunk: int = 1024,
    k_chunk: int = 1024,
    q_offset: int = 0,
) -> jnp.ndarray:
    """Flash-style chunked attention; O(chunk²) memory, exact result.

    For ``window`` (local) attention, K/V are dynamically sliced to the
    reachable band so HLO FLOPs/bytes stay O(S·W) — sub-quadratic, matching
    the TPU kernel's work. Global attention scans all K blocks (standard
    2× masked-FLOP overhead for causal, noted in the roofline bookkeeping).
    """
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = D ** -0.5
    dt = q.dtype
    qg = q.reshape(B, Sq, Hkv, G, D)

    q_chunk = min(q_chunk, Sq)
    while Sq % q_chunk:
        q_chunk //= 2
    n_q = Sq // q_chunk

    if window is not None and Skv > (window + q_chunk):
        # ----- local: per-q-chunk dynamic K/V band of static length W+Cq ----
        # the band only reaches back `window` and forward to the chunk end,
        # which is exact for causal sliding windows (the only form our
        # architectures use); non-causal windows take the global path below
        assert causal, "windowed attention requires causal=True (SWA/local)"
        band = window + q_chunk

        def q_step(_, qi):
            q_blk = jax.lax.dynamic_slice_in_dim(qg, qi * q_chunk, q_chunk, axis=1)
            qpos = q_offset + qi * q_chunk + jnp.arange(q_chunk)
            start = jnp.clip(qi * q_chunk + q_chunk - band, 0, Skv - band)
            k_blk = jax.lax.dynamic_slice_in_dim(k, start, band, axis=1)
            v_blk = jax.lax.dynamic_slice_in_dim(v, start, band, axis=1)
            kpos = start + jnp.arange(band)
            o, m, l = _block_attn(q_blk, k_blk, v_blk, qpos, kpos,
                                  causal=causal, window=window,
                                  softcap=softcap, scale=scale)
            return None, _finalize(o, m, l, dt)

        _, out = jax.lax.scan(q_step, None, jnp.arange(n_q))
        out = jnp.moveaxis(out, 0, 1).reshape(B, Sq, Hq, D)
        return out

    # ----- global (or short-enough local): scan q blocks × k blocks ---------
    k_chunk = min(k_chunk, Skv)
    while Skv % k_chunk:
        k_chunk //= 2
    n_k = Skv // k_chunk

    def q_step(_, qi):
        q_blk = jax.lax.dynamic_slice_in_dim(qg, qi * q_chunk, q_chunk, axis=1)
        qpos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def k_step(acc, ki):
            k_blk = jax.lax.dynamic_slice_in_dim(k, ki * k_chunk, k_chunk, axis=1)
            v_blk = jax.lax.dynamic_slice_in_dim(v, ki * k_chunk, k_chunk, axis=1)
            kpos = ki * k_chunk + jnp.arange(k_chunk)
            new = _block_attn(q_blk, k_blk, v_blk, qpos, kpos,
                              causal=causal, window=window,
                              softcap=softcap, scale=scale)
            return _merge(acc, new), None

        o0 = jnp.zeros((B, q_chunk, Hkv, G, D), jnp.float32)
        m0 = jnp.full((B, Hkv, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, q_chunk), jnp.float32)
        (o, m, l), _ = jax.lax.scan(k_step, (o0, m0, l0), jnp.arange(n_k))
        return None, _finalize(o, m, l, dt)

    _, out = jax.lax.scan(q_step, None, jnp.arange(n_q))
    out = jnp.moveaxis(out, 0, 1).reshape(B, Sq, Hq, D)
    return out


def decode_attention(
    q: jnp.ndarray,              # (B, 1, Hq, D)
    k_cache: jnp.ndarray,        # (B, L, Hkv, D)
    v_cache: jnp.ndarray,        # (B, L, Hkv, D)
    cache_pos: jnp.ndarray,      # (B, L) int32 absolute positions, -1 = empty
    pos: jnp.ndarray,            # (B,) current absolute position
    *, window: Optional[int] = None,
    softcap: float = 0.0,
) -> jnp.ndarray:
    """Single-token attention over a (possibly ring-buffer) KV cache.

    Works for full caches and ring caches alike: masking is driven by the
    stored absolute positions. The KV-cache seq dim may be mesh-sharded
    ("kvseq"); softmax reduction then runs as a distributed flash-decode.
    """
    B, L, Hkv, D = k_cache.shape
    Hq = q.shape[2]
    G = Hq // Hkv
    scale = D ** -0.5
    qg = q.reshape(B, Hkv, G, D).astype(k_cache.dtype)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    s = _softcap(s, softcap)
    valid = (cache_pos >= 0) & (cache_pos[:, :] <= pos[:, None])
    if window is not None:
        valid &= cache_pos > (pos[:, None] - window)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, 1, Hq, D).astype(q.dtype)
