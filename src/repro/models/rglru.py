"""Griffin / RecurrentGemma RG-LRU recurrent block.

h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ ξ_t),  a_t = exp(-c·softplus(Λ)·r_t)

with block-diagonal recurrence/input gates (one block per head), a causal
depthwise temporal conv on the recurrent branch, and a GeLU-gated linear
branch. Train/prefill uses an associative scan (log-depth on TPU); decode is
the O(1) recurrent update. [arXiv:2402.19427]
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import KeyGen, dense_init
from repro.sharding.policy import constrain

_C = 8.0
CONV_W = 4


def init_rglru(keys: KeyGen, cfg: ModelConfig, dtype):
    d = cfg.d_model
    lru = cfg.lru_width or d
    nb = max(cfg.n_heads, 1)
    bw = lru // nb
    p = {
        "in_y": dense_init(keys(), (d, lru), d, dtype),
        "in_x": dense_init(keys(), (d, lru), d, dtype),
        "conv_w": dense_init(keys(), (CONV_W, lru), CONV_W, dtype),
        "conv_b": jnp.zeros((lru,), dtype),
        "gate_a_w": dense_init(keys(), (nb, bw, bw), bw, jnp.float32),
        "gate_a_b": jnp.zeros((nb, bw), jnp.float32),
        "gate_i_w": dense_init(keys(), (nb, bw, bw), bw, jnp.float32),
        "gate_i_b": jnp.zeros((nb, bw), jnp.float32),
        # Λ init so that a ≈ 0.9..0.999 at r=0.5 (Griffin appendix)
        "lam": jnp.linspace(0.3, 1.5, lru, dtype=jnp.float32),
        "out": dense_init(keys(), (lru, d), lru, dtype),
    }
    s = {
        "in_y": ("fsdp", "inner"), "in_x": ("fsdp", "inner"),
        "conv_w": (None, "inner"), "conv_b": ("inner",),
        "gate_a_w": (None, None, None), "gate_a_b": (None, None),
        "gate_i_w": (None, None, None), "gate_i_b": (None, None),
        "lam": (None,), "out": ("inner", "fsdp"),
    }
    return p, s


def _causal_conv(x, w, b):
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    return sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(W)) + b


def _gates(p, xi):
    """Block-diagonal gate projections. xi: (..., lru) -> (r, i) in f32."""
    nb, bw, _ = p["gate_a_w"].shape
    xb = xi.astype(jnp.float32).reshape(*xi.shape[:-1], nb, bw)
    r = jax.nn.sigmoid(jnp.einsum("...nb,nbc->...nc", xb, p["gate_a_w"]) + p["gate_a_b"])
    i = jax.nn.sigmoid(jnp.einsum("...nb,nbc->...nc", xb, p["gate_i_w"]) + p["gate_i_b"])
    return r.reshape(xi.shape), i.reshape(xi.shape)


def _log_a(p, r):
    return -_C * jax.nn.softplus(p["lam"]) * r


def rglru_forward(p, x, cfg: ModelConfig, h0=None):
    """Train/prefill. x: (B, L, d) -> (out, final_h)."""
    dt = x.dtype
    y = jax.nn.gelu(x @ p["in_y"].astype(dt), approximate=True)
    xi = _causal_conv(x @ p["in_x"].astype(dt), p["conv_w"].astype(dt),
                      p["conv_b"].astype(dt))
    xi = constrain(xi, ("batch", "qseq", "inner"))
    r, i = _gates(p, xi)
    log_a = _log_a(p, r)                                     # (B,L,lru) f32
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * (i * xi.astype(jnp.float32))
    if h0 is not None:
        gated = gated.at[:, 0].add(a[:, 0] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    A, Hs = jax.lax.associative_scan(combine, (a, gated), axis=1)
    h = Hs
    out = (h.astype(x.dtype) * y) @ p["out"].astype(dt)
    return out, h[:, -1]


def init_rglru_cache(cfg: ModelConfig, batch: int, dtype):
    lru = cfg.lru_width or cfg.d_model
    return {
        "conv": jnp.zeros((batch, CONV_W - 1, lru), dtype),
        "h": jnp.zeros((batch, lru), jnp.float32),
    }


def rglru_cache_specs(cfg: ModelConfig):
    return {"conv": ("batch", None, "inner"), "h": ("batch", "inner")}


def rglru_decode(p, x, cache, cfg: ModelConfig):
    """One-token step. x: (B, 1, d) -> (out, new_cache)."""
    dt = x.dtype
    y = jax.nn.gelu(x[:, 0] @ p["in_y"].astype(dt), approximate=True)
    xi_lin = x[:, 0] @ p["in_x"].astype(dt)                  # (B, lru)
    conv_in = jnp.concatenate([cache["conv"].astype(dt), xi_lin[:, None, :]], axis=1)
    xi = jnp.einsum("bwc,wc->bc", conv_in, p["conv_w"].astype(dt)) \
        + p["conv_b"].astype(dt)
    r, i = _gates(p, xi)
    log_a = _log_a(p, r)
    a = jnp.exp(log_a)
    h = a * cache["h"] + jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * (i * xi.astype(jnp.float32))
    out = ((h.astype(x.dtype) * y) @ p["out"].astype(dt))[:, None, :]
    return out, {"conv": conv_in[:, 1:, :].astype(cache["conv"].dtype), "h": h}
