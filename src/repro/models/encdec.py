"""Whisper-style encoder-decoder backbone.

Conv/audio frontend is a STUB per the assignment: the encoder consumes
precomputed frame embeddings (B, n_frames, d_model) from ``input_specs``.
Sinusoidal absolute positions (works for any formal sequence length),
bidirectional encoder self-attention, causal decoder self-attention +
cross-attention to the encoder states.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models import mlp as mlp_mod
from repro.models.common import KeyGen, dense_init, dtype_of, pad_vocab, rms_norm
from repro.models.transformer import (
    attn_apply, attn_decode, init_attn, _stack_specs,
)
from repro.sharding.policy import constrain


def sinusoid_positions(seq: int, d: int, offset=0) -> jnp.ndarray:
    pos = offset + jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, 2.0 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# --- init --------------------------------------------------------------------
def _init_enc_layer(cfg: ModelConfig, keys: KeyGen, dtype):
    d = cfg.d_model
    attn_p, attn_s = init_attn(keys, cfg, dtype)
    mlp_p, mlp_s = mlp_mod.init_mlp(keys, cfg, dtype)
    p = {"ln1": jnp.zeros((d,), dtype), "attn": attn_p,
         "ln2": jnp.zeros((d,), dtype), "mlp": mlp_p}
    s = {"ln1": (None,), "attn": attn_s, "ln2": (None,), "mlp": mlp_s}
    return p, s


def _init_dec_layer(cfg: ModelConfig, keys: KeyGen, dtype):
    d = cfg.d_model
    p, s = _init_enc_layer(cfg, keys, dtype)
    cross_p, cross_s = init_attn(keys, cfg, dtype)
    p["lnx"] = jnp.zeros((d,), dtype)
    p["cross"] = cross_p
    s["lnx"] = (None,)
    s["cross"] = cross_s
    return p, s


def init_encdec(cfg: ModelConfig, key) -> Dict[str, Any]:
    dtype = dtype_of(cfg.param_dtype)
    kg = KeyGen(key)
    Vp = pad_vocab(cfg.vocab_size)
    d = cfg.d_model
    params: Dict[str, Any] = {
        "embed": dense_init(kg(), (Vp, d), d, dtype),
        "enc_norm": jnp.zeros((d,), dtype),
        "final_norm": jnp.zeros((d,), dtype),
    }
    enc_keys = jax.random.split(kg(), cfg.encoder_layers)
    dec_keys = jax.random.split(kg(), cfg.num_layers)
    params["enc"] = jax.vmap(lambda k: _init_enc_layer(cfg, KeyGen(k), dtype)[0])(enc_keys)
    params["dec"] = jax.vmap(lambda k: _init_dec_layer(cfg, KeyGen(k), dtype)[0])(dec_keys)
    return params


def encdec_param_specs(cfg: ModelConfig) -> Dict[str, Any]:
    dummy = KeyGen(jax.random.PRNGKey(0))
    return {
        "embed": ("vocab", None),
        "enc_norm": (None,),
        "final_norm": (None,),
        "enc": _stack_specs(_init_enc_layer(cfg, dummy, jnp.float32)[1]),
        "dec": _stack_specs(_init_dec_layer(cfg, dummy, jnp.float32)[1]),
    }


# --- attention helpers ----------------------------------------------------------
def _cross_attn(p, x, kv: Tuple[jnp.ndarray, jnp.ndarray]):
    """x (B,S,d) queries; kv = (k, v) precomputed (B,F,K,Dh)."""
    k, v = kv
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
    out = ops.flash_attention(q, k.astype(dt), v.astype(dt),
                              causal=False, window=None)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(out.dtype))
    if "bo" in p:
        y = y + p["bo"].astype(y.dtype)
    return y


def cross_kv(p, enc_out):
    dt = enc_out.dtype
    k = jnp.einsum("bfd,dhk->bfhk", enc_out, p["wk"].astype(dt))
    v = jnp.einsum("bfd,dhk->bfhk", enc_out, p["wv"].astype(dt))
    if "bv" in p:
        v = v + p["bv"].astype(dt)
    return k, v


# --- forward ----------------------------------------------------------------------
def encode(params, frames, cfg: ModelConfig):
    """frames: (B, F, d_model) stub embeddings -> encoder states (B, F, d)."""
    dt = dtype_of(cfg.compute_dtype)
    x = frames.astype(dt) + sinusoid_positions(frames.shape[1], cfg.d_model).astype(dt)
    x = constrain(x, ("batch", "qseq", None))

    def body(x, lp):
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        x = x + attn_apply(lp["attn"], h, cfg, "global", causal=False)
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + mlp_mod.mlp_block(lp["mlp"], h, cfg)
        return constrain(x, ("batch", "qseq", None)), None

    x, _ = jax.lax.scan(body, x, params["enc"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def decode_full(params, enc_out, tokens, cfg: ModelConfig, *, remat: bool = False):
    """Teacher-forced decoder pass. tokens (B,S) -> logits (B,S,Vp)."""
    dt = dtype_of(cfg.compute_dtype)
    x = params["embed"][tokens].astype(dt)
    x = x + sinusoid_positions(tokens.shape[1], cfg.d_model).astype(dt)
    x = constrain(x, ("batch", "qseq", None))

    def body(x, lp):
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        x = x + attn_apply(lp["attn"], h, cfg, "global", causal=True)
        h = rms_norm(x, lp["lnx"], cfg.norm_eps)
        x = x + _cross_attn(lp["cross"], h, cross_kv(lp["cross"], enc_out))
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + mlp_mod.mlp_block(lp["mlp"], h, cfg)
        return constrain(x, ("batch", "qseq", None)), None

    body_fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(body_fn, x, params["dec"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["embed"].T.astype(x.dtype))
    return constrain(logits, ("batch", "qseq", "vocab"))


def forward_encdec(params, batch, cfg: ModelConfig, *, remat: bool = False):
    enc_out = encode(params, batch["frames"], cfg)
    return decode_full(params, enc_out, batch["tokens"], cfg, remat=remat)


def encdec_loss(params, batch, cfg: ModelConfig, *, remat: bool = False):
    logits = forward_encdec(params, batch, cfg, remat=remat)
    Vp = logits.shape[-1]
    mask = (jnp.arange(Vp) < cfg.vocab_size)
    logits = jnp.where(mask, logits.astype(jnp.float32), -1e30)
    logz = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, batch["targets"][..., None], axis=-1)[..., 0]
    return jnp.mean(logz - tgt)


# --- decode (serve_step) ------------------------------------------------------------
def init_cache_encdec(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    K, Dh, L = cfg.n_kv_heads, cfg.head_dim, cfg.num_layers
    return {
        "step": jnp.zeros((), jnp.int32),
        "pos": jnp.full((batch, max_len), -1, jnp.int32),
        "self": {"k": jnp.zeros((L, batch, max_len, K, Dh), dtype),
                 "v": jnp.zeros((L, batch, max_len, K, Dh), dtype)},
        "cross": {"k": jnp.zeros((L, batch, cfg.n_frames, K, Dh), dtype),
                  "v": jnp.zeros((L, batch, cfg.n_frames, K, Dh), dtype)},
    }


def encdec_cache_specs(cfg: ModelConfig):
    kv = {"k": (None, "batch", "kvseq", "kv_heads", None),
          "v": (None, "batch", "kvseq", "kv_heads", None)}
    ckv = {"k": (None, "batch", None, "kv_heads", None),
           "v": (None, "batch", None, "kv_heads", None)}
    return {"step": (), "pos": ("batch", "kvseq"), "self": kv, "cross": ckv}


def fill_cross_cache(params, cache, frames, cfg: ModelConfig):
    """Run the encoder and precompute per-layer cross K/V (serving prefill)."""
    enc_out = encode(params, frames, cfg)

    def per_layer(lp):
        return cross_kv(lp["cross"], enc_out)

    ks, vs = jax.vmap(per_layer, in_axes=0)(params["dec"])
    new = dict(cache)
    new["cross"] = {"k": ks.astype(cache["cross"]["k"].dtype),
                    "v": vs.astype(cache["cross"]["v"].dtype)}
    return new


def decode_step_encdec(params, cache, tokens, cfg: ModelConfig):
    """One decoder token. tokens (B,1) -> (logits, new_cache)."""
    dt = dtype_of(cfg.compute_dtype)
    step = cache["step"]
    B = tokens.shape[0]
    Lc = cache["pos"].shape[1]
    new_cache = dict(cache)
    idx = jnp.minimum(step, Lc - 1)
    new_cache["pos"] = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], jnp.full((B, 1), step, jnp.int32), idx, axis=1)

    x = params["embed"][tokens].astype(dt)
    x = x + sinusoid_positions(1, cfg.d_model, offset=step).astype(dt)

    def body(x, xs):
        lp, sk, sv, ck, cv = xs
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        y, kv = attn_decode(lp["attn"], h, {"k": sk, "v": sv},
                            new_cache["pos"], step, cfg, "global")
        x = x + y
        h = rms_norm(x, lp["lnx"], cfg.norm_eps)
        x = x + _cross_attn(lp["cross"], h, (ck, cv))
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + mlp_mod.mlp_block(lp["mlp"], h, cfg)
        return x, (kv["k"], kv["v"])

    x, (nk, nv) = jax.lax.scan(
        body, x,
        (params["dec"], cache["self"]["k"], cache["self"]["v"],
         cache["cross"]["k"], cache["cross"]["v"]))
    new_cache["self"] = {"k": nk, "v": nv}
    new_cache["step"] = step + 1
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["embed"].T.astype(x.dtype))
    return logits, new_cache
