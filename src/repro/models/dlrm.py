"""The paper's DLRM workloads: Wide&Deep (Model-X), xDeepFM (Model-Y), DCN (Model-Z).

Sparse categorical features -> embedding tables -> pooled lookups (the
paper's 30–48 % hot spot) -> dense interaction network -> CTR logit.

All ``n_tables`` embedding tables live in ONE pooled ``(sum(rows), D)``
array addressed through static per-table row offsets (``cfg.table_offsets``),
and the whole forward issues exactly one ``ops.fused_embedding_bag`` call for
the deep part (plus one for the wide part in wide_deep) instead of a Python
loop of per-table kernels. The pooled rows are sharded over the "model"
(parameter-server) axis, exactly as §2.1 describes — one spec covers every
table.

With a ``layout`` (a ``repro.sharding.policy.PaddedLayout``) the pooled
store is instead the padded ``(n_ps, max_range, D)`` array whose leading
axis GSPMD splits equally — physically-unequal PS shards materializing the
balanced range plan exactly (see ``docs/EMBEDDING_LAYOUT.md``). Values are
identical to the flat layout bit for bit; only where rows live changes.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.dlrm_models import DLRMConfig
from repro.kernels import ops
from repro.models.common import KeyGen, dense_init
from repro.sharding.policy import constrain


def init_dlrm(cfg: DLRMConfig, key, layout=None) -> Dict[str, Any]:
    """Initialize DLRM params; ``layout`` pads the pooled stores physically.

    Args:
      cfg:    the DLRM workload config.
      key:    PRNG key.
      layout: optional ``PaddedLayout``; the pooled row arrays ("tables" and
              the wide part) come back as ``(n_ps, max_range, ...)`` padded
              stores holding bit-identical row values to the flat init (the
              flat pool is drawn first, then scattered), so flat and padded
              jobs from the same key are numerically indistinguishable.
    """
    kg = KeyGen(key)
    D = cfg.embed_dim
    # one pooled row array for all tables (rows laid out at cfg.table_offsets)
    params: Dict[str, Any] = {
        "tables": dense_init(kg(), (cfg.total_embedding_rows, D), D,
                             jnp.float32),
    }
    d_in = cfg.n_dense + cfg.n_tables * D
    mlp = {}
    prev = d_in
    for li, h in enumerate(cfg.mlp_dims):
        mlp[f"w{li}"] = dense_init(kg(), (prev, h), prev, jnp.float32)
        mlp[f"b{li}"] = jnp.zeros((h,), jnp.float32)
        prev = h
    mlp["w_out"] = dense_init(kg(), (prev, 1), prev, jnp.float32)
    mlp["b_out"] = jnp.zeros((1,), jnp.float32)
    params["mlp"] = mlp

    if cfg.kind == "wide_deep":
        params["wide"] = jnp.zeros((cfg.total_embedding_rows, 1), jnp.float32)
        params["wide_dense"] = jnp.zeros((cfg.n_dense,), jnp.float32)
    if cfg.kind == "dcn":
        params["cross"] = {
            f"w{li}": dense_init(kg(), (d_in,), d_in, jnp.float32)
            for li in range(cfg.cross_layers)}
        params["cross_b"] = {
            f"b{li}": jnp.zeros((d_in,), jnp.float32)
            for li in range(cfg.cross_layers)}
    if cfg.kind == "xdeepfm":
        cin = {}
        prev_maps = cfg.n_tables
        for li, maps in enumerate(cfg.cin_layers):
            cin[f"w{li}"] = dense_init(
                kg(), (prev_maps, cfg.n_tables, maps), prev_maps * cfg.n_tables,
                jnp.float32)
            prev_maps = maps
        cin["w_out"] = dense_init(kg(), (sum(cfg.cin_layers),), sum(cfg.cin_layers),
                                  jnp.float32)
        params["cin"] = cin
    if layout is not None:
        # pad AFTER drawing every key so flat/padded inits are value-equal
        params["tables"] = layout.pad_rows(params["tables"])
        if "wide" in params:
            params["wide"] = layout.pad_rows(params["wide"])
    return params


def dlrm_param_specs(cfg: DLRMConfig, layout=None) -> Dict[str, Any]:
    """Logical-axis spec tree for ``init_dlrm``'s params.

    Args:
      cfg:    the DLRM workload config.
      layout: optional ``PaddedLayout``; padded pooled stores shard their
              *leading* (n_ps) axis over the PS/model axis — an equal split
              of n_ps shards, i.e. exactly one balanced range per device.
    """
    pooled = ("vocab", None, None) if layout is not None else ("vocab", None)
    specs: Dict[str, Any] = {
        "tables": pooled,               # pooled rows over the PS/model axis
        "mlp": {},
    }
    for li, h in enumerate(cfg.mlp_dims):
        specs["mlp"][f"w{li}"] = (None, None)
        specs["mlp"][f"b{li}"] = (None,)
    specs["mlp"]["w_out"] = (None, None)
    specs["mlp"]["b_out"] = (None,)
    if cfg.kind == "wide_deep":
        specs["wide"] = ("vocab", None, None) if layout is not None \
            else ("vocab", None)
        specs["wide_dense"] = (None,)
    if cfg.kind == "dcn":
        specs["cross"] = {f"w{li}": (None,) for li in range(cfg.cross_layers)}
        specs["cross_b"] = {f"b{li}": (None,) for li in range(cfg.cross_layers)}
    if cfg.kind == "xdeepfm":
        specs["cin"] = {f"w{li}": (None, None, None) for li in range(len(cfg.cin_layers))}
        specs["cin"]["w_out"] = (None,)
    return specs


def _pool2d(store, layout):
    """Padded (n_ps, max_range, ...) store → the engine's flattened view."""
    if layout is None:
        return store
    return store.reshape((layout.padded_rows,) + store.shape[2:])


def _resolve_plan(cfg: DLRMConfig, plan, table_hot, layout):
    """One ``EmbeddingPlan`` per forward: the explicit plan wins; otherwise
    the legacy loose kwargs build the config's default plan
    (``table_hot=None`` → ``cfg.table_hot``, matching the old behavior)."""
    if plan is not None:
        return plan
    return cfg.embedding_plan(table_hot=table_hot, layout=layout)


def sparse_param_keys(cfg: DLRMConfig) -> tuple:
    """The pooled (vocab-row) parameter leaves the fused sparse backward +
    row-wise optimizer update handles; everything else is dense."""
    return ("tables", "wide") if cfg.kind == "wide_deep" else ("tables",)


def dlrm_embeddings(params, batch, cfg: DLRMConfig, plan) -> Dict[str, Any]:
    """Every pooled-store lookup of one forward, in one dict.

    The seam the fused sparse-update training step differentiates at: the
    returned bag outputs are the only consumers of the pooled stores, so
    their cotangents (via ``jax.vjp``) feed ``ops.sparse_row_grads``
    directly instead of materializing dense (R, D) gradients.

    Returns ``{"deep": (B, n_tables, D)}`` plus ``{"wide": (B, n_tables, 1)}``
    for wide_deep.
    """
    embs = {"deep": ops.fused_embedding_bag(
        _pool2d(params["tables"], plan.layout), batch["sparse"], plan=plan)}
    if cfg.kind == "wide_deep":
        embs["wide"] = ops.fused_embedding_bag(
            _pool2d(params["wide"], plan.layout), batch["sparse"],
            plan=plan.with_combiner("sum"))
    return embs


def _field_embeddings(params, batch, cfg: DLRMConfig, table_hot=None,
                      layout=None, plan=None):
    """All per-field embeddings in ONE fused call. -> (B, n_tables, D)."""
    plan = _resolve_plan(cfg, plan, table_hot, layout)
    return ops.fused_embedding_bag(
        _pool2d(params["tables"], plan.layout), batch["sparse"], plan=plan)


def _deep_mlp(params, x, cfg: DLRMConfig):
    h = x
    for li in range(len(cfg.mlp_dims)):
        h = jax.nn.relu(h @ params["mlp"][f"w{li}"] + params["mlp"][f"b{li}"])
    return (h @ params["mlp"]["w_out"] + params["mlp"]["b_out"])[:, 0]


def dlrm_forward_from_embeddings(params, batch, embs: Dict[str, Any],
                                 cfg: DLRMConfig) -> jnp.ndarray:
    """The dense interaction network given the pooled-store lookups.

    ``embs`` is ``dlrm_embeddings``'s output; no pooled store is read here,
    so differentiating this function w.r.t. ``embs`` (and the dense params)
    is the whole backward minus the sparse scatter — the split the fused
    sparse-update step exploits.
    """
    emb = constrain(embs["deep"], ("batch", None, None))     # (B, m, D)
    B = emb.shape[0]
    x0 = jnp.concatenate([batch["dense"], emb.reshape(B, -1)], axis=-1)

    if cfg.kind == "wide_deep":
        deep = _deep_mlp(params, x0, cfg)
        wide = batch["dense"] @ params["wide_dense"] + jnp.sum(
            embs["wide"][..., 0], axis=1)
        return deep + wide

    if cfg.kind == "dcn":
        x = x0
        for li in range(cfg.cross_layers):
            w = params["cross"][f"w{li}"]
            b = params["cross_b"][f"b{li}"]
            x = x0 * (x @ w)[:, None] + b + x
        return _deep_mlp(params, x, cfg)

    if cfg.kind == "xdeepfm":
        Xk = emb                                             # (B, H0=m, D)
        feats = []
        for li in range(len(cfg.cin_layers)):
            inter = jnp.einsum("bhd,bmd->bhmd", Xk, emb)
            Xk = jnp.einsum("bhmd,hmn->bnd", inter, params["cin"][f"w{li}"])
            feats.append(jnp.sum(Xk, axis=-1))               # (B, maps)
        cin_out = jnp.concatenate(feats, axis=-1) @ params["cin"]["w_out"]
        return _deep_mlp(params, x0, cfg) + cin_out

    raise ValueError(cfg.kind)


def dlrm_forward(params, batch, cfg: DLRMConfig, table_hot=None,
                 layout=None, plan=None) -> jnp.ndarray:
    """batch: {dense (B,n_dense) f32, sparse (B,m,hot) i32} -> logit (B,).

    ``plan`` (an ``EmbeddingPlan``) carries every static knob of the fused
    embedding engine; the legacy ``table_hot``/``layout`` kwargs build the
    config's default plan (``table_hot=None`` → ``cfg.table_hot``; sparse
    ids stay in the flat space — translation happens inside the engine).
    The forward is ``dlrm_embeddings`` (every pooled-store lookup) composed
    with ``dlrm_forward_from_embeddings`` (the dense interaction network).
    """
    plan = _resolve_plan(cfg, plan, table_hot, layout)
    embs = dlrm_embeddings(params, batch, cfg, plan)
    return dlrm_forward_from_embeddings(params, batch, embs, cfg)


def dlrm_loss_from_embeddings(params, batch, embs: Dict[str, Any],
                              cfg: DLRMConfig) -> jnp.ndarray:
    """BCE-with-logits given precomputed pooled-store lookups."""
    logit = dlrm_forward_from_embeddings(params, batch, embs, cfg)
    y = batch["label"].astype(jnp.float32)
    return jnp.mean(jnp.maximum(logit, 0) - logit * y + jnp.log1p(jnp.exp(-jnp.abs(logit))))


def dlrm_loss(params, batch, cfg: DLRMConfig, table_hot=None,
              layout=None, plan=None) -> jnp.ndarray:
    """Binary cross-entropy with logits on CTR labels.

    ``plan`` (or the legacy ``table_hot``/``layout`` kwargs) is forwarded to
    ``dlrm_forward`` so a live re-plan's measured cache plan and the
    physical padded placement reach the fused engine.
    """
    logit = dlrm_forward(params, batch, cfg, table_hot=table_hot,
                         layout=layout, plan=plan)
    y = batch["label"].astype(jnp.float32)
    return jnp.mean(jnp.maximum(logit, 0) - logit * y + jnp.log1p(jnp.exp(-jnp.abs(logit))))


def dlrm_auc(params, batch, cfg: DLRMConfig, table_hot=None,
             layout=None, plan=None) -> jnp.ndarray:
    """Pairwise AUC estimate on one batch (for Fig 8 convergence tracking)."""
    logit = dlrm_forward(params, batch, cfg, table_hot=table_hot,
                         layout=layout, plan=plan)
    y = batch["label"].astype(jnp.float32)
    pos = y[:, None] > y[None, :]
    gt = (logit[:, None] > logit[None, :]).astype(jnp.float32)
    eq = (logit[:, None] == logit[None, :]).astype(jnp.float32)
    n = jnp.maximum(jnp.sum(pos), 1.0)
    return jnp.sum(pos * (gt + 0.5 * eq)) / n
