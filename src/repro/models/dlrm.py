"""The paper's DLRM workloads: Wide&Deep (Model-X), xDeepFM (Model-Y), DCN (Model-Z).

Sparse categorical features -> per-feature embedding tables -> pooled lookups
(the paper's 30–48 % hot spot, served by the Pallas ``embedding_bag`` kernel)
-> dense interaction network -> CTR logit. Tables are row-sharded over the
"model" (parameter-server) axis, exactly as §2.1 describes.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.dlrm_models import DLRMConfig
from repro.kernels import ops
from repro.models.common import KeyGen, dense_init
from repro.sharding.policy import constrain


def init_dlrm(cfg: DLRMConfig, key) -> Dict[str, Any]:
    kg = KeyGen(key)
    D = cfg.embed_dim
    params: Dict[str, Any] = {
        "tables": {f"t{i}": dense_init(kg(), (rows, D), D, jnp.float32)
                   for i, rows in enumerate(cfg.table_rows)},
    }
    d_in = cfg.n_dense + cfg.n_tables * D
    mlp = {}
    prev = d_in
    for li, h in enumerate(cfg.mlp_dims):
        mlp[f"w{li}"] = dense_init(kg(), (prev, h), prev, jnp.float32)
        mlp[f"b{li}"] = jnp.zeros((h,), jnp.float32)
        prev = h
    mlp["w_out"] = dense_init(kg(), (prev, 1), prev, jnp.float32)
    mlp["b_out"] = jnp.zeros((1,), jnp.float32)
    params["mlp"] = mlp

    if cfg.kind == "wide_deep":
        params["wide"] = {f"t{i}": jnp.zeros((rows, 1), jnp.float32)
                          for i, rows in enumerate(cfg.table_rows)}
        params["wide_dense"] = jnp.zeros((cfg.n_dense,), jnp.float32)
    if cfg.kind == "dcn":
        params["cross"] = {
            f"w{li}": dense_init(kg(), (d_in,), d_in, jnp.float32)
            for li in range(cfg.cross_layers)}
        params["cross_b"] = {
            f"b{li}": jnp.zeros((d_in,), jnp.float32)
            for li in range(cfg.cross_layers)}
    if cfg.kind == "xdeepfm":
        cin = {}
        prev_maps = cfg.n_tables
        for li, maps in enumerate(cfg.cin_layers):
            cin[f"w{li}"] = dense_init(
                kg(), (prev_maps, cfg.n_tables, maps), prev_maps * cfg.n_tables,
                jnp.float32)
            prev_maps = maps
        cin["w_out"] = dense_init(kg(), (sum(cfg.cin_layers),), sum(cfg.cin_layers),
                                  jnp.float32)
        params["cin"] = cin
    return params


def dlrm_param_specs(cfg: DLRMConfig) -> Dict[str, Any]:
    specs: Dict[str, Any] = {
        "tables": {f"t{i}": ("vocab", None) for i in range(cfg.n_tables)},
        "mlp": {},
    }
    prev = cfg.n_dense + cfg.n_tables * cfg.embed_dim
    for li, h in enumerate(cfg.mlp_dims):
        specs["mlp"][f"w{li}"] = (None, None)
        specs["mlp"][f"b{li}"] = (None,)
    specs["mlp"]["w_out"] = (None, None)
    specs["mlp"]["b_out"] = (None,)
    if cfg.kind == "wide_deep":
        specs["wide"] = {f"t{i}": ("vocab", None) for i in range(cfg.n_tables)}
        specs["wide_dense"] = (None,)
    if cfg.kind == "dcn":
        specs["cross"] = {f"w{li}": (None,) for li in range(cfg.cross_layers)}
        specs["cross_b"] = {f"b{li}": (None,) for li in range(cfg.cross_layers)}
    if cfg.kind == "xdeepfm":
        specs["cin"] = {f"w{li}": (None, None, None) for li in range(len(cfg.cin_layers))}
        specs["cin"]["w_out"] = (None,)
    return specs


def _field_embeddings(params, batch, cfg: DLRMConfig):
    """Pooled per-field embeddings via embedding_bag. -> (B, n_tables, D)."""
    outs = []
    for i in range(cfg.n_tables):
        idx = batch["sparse"][:, i, :]                      # (B, multi_hot)
        pooled = ops.embedding_bag(params["tables"][f"t{i}"], idx,
                                   combiner=cfg.pooling)
        outs.append(pooled)
    return jnp.stack(outs, axis=1)                          # (B, m, D)


def _deep_mlp(params, x, cfg: DLRMConfig):
    h = x
    for li in range(len(cfg.mlp_dims)):
        h = jax.nn.relu(h @ params["mlp"][f"w{li}"] + params["mlp"][f"b{li}"])
    return (h @ params["mlp"]["w_out"] + params["mlp"]["b_out"])[:, 0]


def dlrm_forward(params, batch, cfg: DLRMConfig) -> jnp.ndarray:
    """batch: {dense (B,n_dense) f32, sparse (B,m,hot) i32} -> logit (B,)."""
    emb = _field_embeddings(params, batch, cfg)             # (B, m, D)
    emb = constrain(emb, ("batch", None, None))
    B = emb.shape[0]
    x0 = jnp.concatenate([batch["dense"], emb.reshape(B, -1)], axis=-1)

    if cfg.kind == "wide_deep":
        deep = _deep_mlp(params, x0, cfg)
        wide = batch["dense"] @ params["wide_dense"]
        for i in range(cfg.n_tables):
            idx = batch["sparse"][:, i, :]
            wide = wide + ops.embedding_bag(
                params["wide"][f"t{i}"], idx, combiner="sum")[:, 0]
        return deep + wide

    if cfg.kind == "dcn":
        x = x0
        for li in range(cfg.cross_layers):
            w = params["cross"][f"w{li}"]
            b = params["cross_b"][f"b{li}"]
            x = x0 * (x @ w)[:, None] + b + x
        return _deep_mlp(params, x, cfg)

    if cfg.kind == "xdeepfm":
        Xk = emb                                             # (B, H0=m, D)
        feats = []
        for li in range(len(cfg.cin_layers)):
            inter = jnp.einsum("bhd,bmd->bhmd", Xk, emb)
            Xk = jnp.einsum("bhmd,hmn->bnd", inter, params["cin"][f"w{li}"])
            feats.append(jnp.sum(Xk, axis=-1))               # (B, maps)
        cin_out = jnp.concatenate(feats, axis=-1) @ params["cin"]["w_out"]
        return _deep_mlp(params, x0, cfg) + cin_out

    raise ValueError(cfg.kind)


def dlrm_loss(params, batch, cfg: DLRMConfig) -> jnp.ndarray:
    """Binary cross-entropy with logits on CTR labels."""
    logit = dlrm_forward(params, batch, cfg)
    y = batch["label"].astype(jnp.float32)
    return jnp.mean(jnp.maximum(logit, 0) - logit * y + jnp.log1p(jnp.exp(-jnp.abs(logit))))


def dlrm_auc(params, batch, cfg: DLRMConfig) -> jnp.ndarray:
    """Pairwise AUC estimate on one batch (for Fig 8 convergence tracking)."""
    logit = dlrm_forward(params, batch, cfg)
    y = batch["label"].astype(jnp.float32)
    pos = y[:, None] > y[None, :]
    gt = (logit[:, None] > logit[None, :]).astype(jnp.float32)
    eq = (logit[:, None] == logit[None, :]).astype(jnp.float32)
    n = jnp.maximum(jnp.sum(pos), 1.0)
    return jnp.sum(pos * (gt + 0.5 * eq)) / n
