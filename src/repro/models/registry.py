"""Unified model API: one object per arch exposing init/loss/prefill/decode.

Used by the trainer, the serving engine, and the multi-pod dry-run. All
methods are pure functions of pytrees, safe to ``jax.jit``/``pjit``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec as encdec_mod
from repro.models import transformer as tf_mod
from repro.models.common import dtype_of


@dataclass(frozen=True)
class ModelAPI:
    cfg: ModelConfig
    init: Callable[[Any], Any]
    param_specs: Callable[[], Any]
    loss: Callable[..., jnp.ndarray]          # (params, batch, remat=False)
    prefill: Callable[..., jnp.ndarray]       # (params, batch) -> logits
    init_cache: Callable[..., Any]            # (batch, max_len, dtype)
    cache_specs: Callable[[], Any]
    decode_step: Callable[..., Any]           # (params, cache, tokens)
    input_specs: Callable[[ShapeConfig], Dict[str, jax.ShapeDtypeStruct]]


def build_model(cfg: ModelConfig) -> ModelAPI:
    if cfg.family == "encdec":
        return _build_encdec(cfg)
    return _build_lm(cfg)


# --- decoder-only families --------------------------------------------------
def _build_lm(cfg: ModelConfig) -> ModelAPI:
    def input_specs(shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        if shape.kind == "train":
            return {"tokens": jax.ShapeDtypeStruct((B, S), i32),
                    "targets": jax.ShapeDtypeStruct((B, S), i32)}
        if shape.kind == "prefill":
            return {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        # decode: one new token; the KV cache (length S) is a separate input
        return {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}

    return ModelAPI(
        cfg=cfg,
        init=lambda key: tf_mod.init_lm(cfg, key),
        param_specs=lambda: tf_mod.lm_param_specs(cfg),
        loss=lambda params, batch, remat=False: tf_mod.lm_loss(
            params, batch, cfg, remat=remat),
        prefill=lambda params, batch: tf_mod.forward_lm(
            params, batch["tokens"], cfg)[0],
        init_cache=lambda batch, max_len, dtype=jnp.bfloat16: tf_mod.init_cache_lm(
            cfg, batch, max_len, dtype),
        cache_specs=lambda: tf_mod.lm_cache_specs(cfg),
        decode_step=lambda params, cache, tokens: tf_mod.decode_step_lm(
            params, cache, tokens, cfg),
        input_specs=input_specs,
    )


# --- encoder-decoder (whisper) -------------------------------------------------
def _build_encdec(cfg: ModelConfig) -> ModelAPI:
    def input_specs(shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        frames = jax.ShapeDtypeStruct((B, cfg.n_frames, cfg.d_model),
                                      dtype_of(cfg.compute_dtype))
        if shape.kind == "train":
            return {"frames": frames,
                    "tokens": jax.ShapeDtypeStruct((B, S), i32),
                    "targets": jax.ShapeDtypeStruct((B, S), i32)}
        if shape.kind == "prefill":
            return {"frames": frames,
                    "tokens": jax.ShapeDtypeStruct((B, S), i32)}
        return {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}

    return ModelAPI(
        cfg=cfg,
        init=lambda key: encdec_mod.init_encdec(cfg, key),
        param_specs=lambda: encdec_mod.encdec_param_specs(cfg),
        loss=lambda params, batch, remat=False: encdec_mod.encdec_loss(
            params, batch, cfg, remat=remat),
        prefill=lambda params, batch: encdec_mod.forward_encdec(params, batch, cfg),
        init_cache=lambda batch, max_len, dtype=jnp.bfloat16: encdec_mod.init_cache_encdec(
            cfg, batch, max_len, dtype),
        cache_specs=lambda: encdec_mod.encdec_cache_specs(cfg),
        decode_step=lambda params, cache, tokens: encdec_mod.decode_step_encdec(
            params, cache, tokens, cfg),
        input_specs=input_specs,
    )
