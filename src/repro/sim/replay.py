"""Trace-replay launcher: drive every scheduler over a replayed cluster trace.

The cluster-scale counterpart of ``repro.launch.train``: loads (or scales up)
a v2020-shaped job trace, maps it onto simulator jobs, and replays it through
``CloudSim`` under each requested scheduler — the full three-stage
allocate/adjust/guarantee loop for ``dlrover_rm`` — printing one CSV row per
scheduler (JCT percentiles, completion rate, CPU/memory utilization, event
counts) and optionally a JSON artifact.

    PYTHONPATH=src python -m repro.sim.replay --synthesize 2000 \\
        --schedulers dlrover_rm,static_user,es,optimus \\
        --capacity-cpu 16384 --capacity-amplitude 0.15 --json replay.json

Fully deterministic for a fixed ``(--seed, --failure-seed)`` pair: rows and
the per-run event log reproduce byte-for-byte.
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List, Optional

from repro.sim.cluster import CloudSim, SimResult
from repro.sim.trace import (
    CapacityWave, default_trace_path, load_trace, synthesize_trace,
    trace_marginals, trace_to_jobs, REPLAYABLE_STATUSES,
)


def summarize(res: SimResult) -> Dict[str, float]:
    ev = res.event_rates()
    return {
        "jobs": float(len(res.records)),
        "jcr": res.jcr(),
        "median_jct_s": res.jct_percentile(50),
        "p90_jct_s": res.jct_percentile(90),
        "cpu_util": res.mean_cpu_util(),
        "mem_util": res.mean_mem_util(),
        "oom_per_job": ev["oom_failure"],
        "failures_per_job": ev["other_failure"],
        "stragglers_per_job": ev["straggler"],
        "hot_ps_per_job": ev["hot_ps"],
    }


def replay(jobs: list, scheduler: str, *, total_cpu: float,
           total_mem_gb: float, horizon_s: float, seed: int,
           failure_seed: int, amplitude: float = 0.0,
           period_s: float = 6 * 3600.0) -> SimResult:
    profile: Optional[CapacityWave] = None
    if amplitude > 0.0:
        profile = CapacityWave(total_cpu, total_mem_gb, amplitude=amplitude,
                               period_s=period_s)
    sim = CloudSim(scheduler, total_cpu=total_cpu, total_mem_gb=total_mem_gb,
                   seed=seed, failure_seed=failure_seed,
                   straggler_rate_per_pod_per_day=0.3,
                   hotps_rate_per_pod_per_day=0.3,
                   capacity_profile=profile)
    return sim.run(jobs, horizon_s=horizon_s)


def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser(
        description="replay a v2020-shaped cluster trace through CloudSim")
    ap.add_argument("--trace", default=None,
                    help="trace CSV (default: checked-in sample)")
    ap.add_argument("--synthesize", type=int, default=0, metavar="N",
                    help="scale up: N synthetic jobs from the trace marginals")
    ap.add_argument("--schedulers", default="dlrover_rm,static_user",
                    help="comma-separated scheduler names")
    ap.add_argument("--seed", type=int, default=0,
                    help="workload-mapping + scheduler seed")
    ap.add_argument("--failure-seed", type=int, default=77,
                    help="failure/straggler/hot-PS stream seed")
    ap.add_argument("--horizon-h", type=float, default=None,
                    help="simulated horizon (default: arrivals span + 12 h)")
    ap.add_argument("--capacity-cpu", type=float, default=4096.0)
    ap.add_argument("--capacity-mem-gb", type=float, default=32768.0)
    ap.add_argument("--capacity-amplitude", type=float, default=0.0,
                    help="sinusoidal usable-capacity swing (0.15 = ±15%%)")
    ap.add_argument("--capacity-period-h", type=float, default=6.0)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write per-scheduler summaries + event logs")
    args = ap.parse_args(argv)

    rows = load_trace(args.trace or default_trace_path())
    replayable = [r for r in rows if r.status in REPLAYABLE_STATUSES]
    if args.synthesize:
        rows = synthesize_trace(args.synthesize, args.seed,
                                trace_marginals(replayable))
    jobs = trace_to_jobs(rows, seed=args.seed)
    if not jobs:
        raise SystemExit("trace contains no replayable jobs")
    span = max(j.arrival_s for j in jobs)
    horizon_s = (args.horizon_h * 3600.0 if args.horizon_h is not None
                 else span + 12 * 3600.0)

    print("scheduler,metric,value")
    out: Dict[str, Dict[str, float]] = {}
    logs: Dict[str, str] = {}
    for name in args.schedulers.split(","):
        res = replay(jobs, name, total_cpu=args.capacity_cpu,
                     total_mem_gb=args.capacity_mem_gb, horizon_s=horizon_s,
                     seed=args.seed, failure_seed=args.failure_seed,
                     amplitude=args.capacity_amplitude,
                     period_s=args.capacity_period_h * 3600.0)
        out[name] = summarize(res)
        logs[name] = res.event_log()
        for metric, value in out[name].items():
            print(f"{name},{metric},{value:.6g}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"config": vars(args), "horizon_s": horizon_s,
                       "n_jobs": len(jobs), "summaries": out,
                       "event_logs": logs}, f, indent=2)


if __name__ == "__main__":
    main()
