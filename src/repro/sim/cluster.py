"""Discrete-time cloud-cluster simulator (shared, unstable environment of §2).

Models: capacity-limited job admission (pending queues), per-pod failures
(1.5 %/pod/day, §2.2), worker stragglers and hot PSes (resource contention),
embedding-memory growth → OOM, checkpoint/restart losses, and the transition
costs of scaling (stop-and-restart vs seamless migration + flash-checkpoint).

The same engine runs every scheduler strategy; behavioral differences come
only from ``SchedulerTraits`` — exactly the paper's ablation axes.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.migration import MigrationTimings
from repro.core.oom import OOMPredictor
from repro.core.perf_model import JobResources, feature_vector
from repro.sim.schedulers import JobRuntimeView, make_scheduler
from repro.sim.workload import SimJob

TIMINGS = MigrationTimings()


@dataclass
class JobRecord:
    job_id: str
    kind: str
    arrival_s: float
    started_s: Optional[float] = None
    finished_s: Optional[float] = None
    completed: bool = False
    failures: int = 0
    ooms: int = 0
    stragglers: int = 0
    hot_pses: int = 0
    downtime_s: float = 0.0
    pending_s: float = 0.0

    @property
    def jct_s(self) -> Optional[float]:
        if self.finished_s is None:
            return None
        return self.finished_s - self.arrival_s


@dataclass
class _Running:
    job: SimJob
    view: JobRuntimeView
    record: JobRecord
    resources: JobResources
    samples_done: float = 0.0
    last_ckpt_samples: float = 0.0
    last_ckpt_at: float = 0.0
    blocked_until: float = 0.0
    straggler_until: float = 0.0
    hotps_until: float = 0.0
    capacity_loss_until: float = 0.0        # failed worker awaiting replacement
    pending_plan: Optional[JobResources] = None
    plan_apply_at: float = 0.0
    oom_pred: OOMPredictor = field(default_factory=OOMPredictor)

    def mem_used_gb(self) -> float:
        return self.job.mem_static_gb + \
            self.job.mem_growth_gb_per_msample * self.samples_done / 1e6

    def mem_capacity_gb(self) -> float:
        return self.resources.p * self.resources.mem_p


@dataclass
class SimResult:
    scheduler: str
    records: List[JobRecord]
    ts_time: List[float] = field(default_factory=list)
    ts_alloc_cpu: List[float] = field(default_factory=list)
    ts_used_cpu: List[float] = field(default_factory=list)
    ts_alloc_mem: List[float] = field(default_factory=list)
    ts_used_mem: List[float] = field(default_factory=list)
    ts_capacity_cpu: List[float] = field(default_factory=list)
    #: chronological (time_s, job_id, event) triples; see :meth:`event_log`
    events: List[Tuple[float, str, str]] = field(default_factory=list)

    def event_log(self) -> str:
        """Canonical one-line-per-event serialization; byte-identical for
        identical ``(scheduler seed, failure_seed, workload, config)`` —
        the determinism contract tests and benches pin."""
        return "\n".join(f"{t:.1f} {jid} {kind}"
                         for t, jid, kind in self.events)

    # ----------------------------------------------------------------- stats
    def jcr(self) -> float:
        done = sum(r.completed for r in self.records)
        return done / max(len(self.records), 1)

    def jct_percentile(self, q: float) -> float:
        vals = [r.jct_s for r in self.records if r.jct_s is not None]
        return float(np.percentile(vals, q)) if vals else float("nan")

    def mean_cpu_util(self) -> float:
        pairs = [(u, a) for u, a in zip(self.ts_used_cpu, self.ts_alloc_cpu) if a > 0]
        if not pairs:
            return 0.0
        return float(np.mean([u / a for u, a in pairs]))

    def mean_mem_util(self) -> float:
        pairs = [(u, a) for u, a in zip(self.ts_used_mem, self.ts_alloc_mem) if a > 0]
        if not pairs:
            return 0.0
        return float(np.mean([u / a for u, a in pairs]))

    def event_rates(self) -> Dict[str, float]:
        n = max(len(self.records), 1)
        return {
            "oom_failure": sum(r.ooms for r in self.records) / n,
            "other_failure": sum(r.failures for r in self.records) / n,
            "straggler": sum(r.stragglers for r in self.records) / n,
            "hot_ps": sum(r.hot_pses for r in self.records) / n,
        }


class CloudSim:
    """``failure_seed`` draws the failure/straggler/hot-PS RNG independently
    of the scheduler's ``seed`` (default preserves the historical
    ``seed + 1`` stream, so existing runs reproduce bit-for-bit);
    ``timings`` sets the recovery-time model — pass measured latencies
    (e.g. ``SupervisorReport.measured_timings()``) so the sim's failure
    model agrees with the real system's recovery costs.
    ``straggler_rebalance_s`` / ``unmitigated_s`` are the previously
    hardcoded recovery horizons of dynamic-sharding rebalance and
    no-intervention strategies. ``capacity_profile`` makes the usable
    cluster capacity time-varying (e.g. ``repro.sim.trace.CapacityWave``):
    called as ``profile(now) -> (total_cpu, total_mem_gb)`` each step, it
    moves the shared ``ClusterCapacity`` the scheduler also sees — already
    admitted jobs keep running through a dip, but admission and scale-up
    decisions are bounded by the shrunken envelope."""

    def __init__(self, scheduler_name: str, *, total_cpu: float = 2048.0,
                 total_mem_gb: float = 16384.0, seed: int = 0, dt: float = 15.0,
                 pod_failure_rate_per_day: float = 0.015,
                 straggler_rate_per_pod_per_day: float = 0.05,
                 hotps_rate_per_pod_per_day: float = 0.04,
                 ckpt_interval_s: float = 1800.0,
                 enable_failures: bool = True,
                 failure_seed: Optional[int] = None,
                 timings: MigrationTimings = TIMINGS,
                 straggler_rebalance_s: float = 60.0,
                 unmitigated_s: float = 1800.0,
                 capacity_profile: Optional[
                     Callable[[float], Tuple[float, float]]] = None):
        from repro.core.autoscaler import ClusterCapacity
        self.capacity = ClusterCapacity(total_cpu, total_mem_gb)
        self.capacity_profile = capacity_profile
        self.scheduler = make_scheduler(scheduler_name, self.capacity, seed)
        self.traits = self.scheduler.traits
        self.failure_seed = (seed + 1) if failure_seed is None else failure_seed
        self.rng = np.random.default_rng(self.failure_seed)
        self.dt = dt
        self.pod_failure_rate = pod_failure_rate_per_day
        self.straggler_rate = straggler_rate_per_pod_per_day
        self.hotps_rate = hotps_rate_per_pod_per_day
        self.ckpt_interval_s = ckpt_interval_s
        self.enable_failures = enable_failures
        self.timings = timings
        self.straggler_rebalance_s = straggler_rebalance_s
        self.unmitigated_s = unmitigated_s

    # ------------------------------------------------------------------
    def _true_t_iter(self, rj: _Running, r_eff: JobResources) -> float:
        x = feature_vector(r_eff, rj.job.statics)
        coef = np.concatenate([np.asarray(rj.job.true_alpha), [rj.job.true_beta]])
        return max(float(x @ coef), 1e-6)

    def _throughput(self, rj: _Running, now: float) -> Tuple[float, float, float]:
        """Effective throughput under the current disruptions.

        Hot PS: one PS at 3 % speed gates *every* worker's pull/lookup (the
        iteration waits for the slowest PS), inflating T_upd/T_emb by
        ≈ (1/0.03)/p relative to a balanced PS fleet. Worker straggler:
        async PS softens the barrier but embedding-row locking and staleness
        control still couple workers — modelled as a 50 % barrier fraction,
        throughput → (1-γ) + γ·s with γ=0.5, s=0.03 (≈ 0.515×).
        """
        r = rj.resources
        w_eff = float(r.w)
        if now < rj.capacity_loss_until:
            w_eff = max(w_eff - 1, 1.0)               # failed worker missing
        from repro.sim.workload import ps_contention
        coef = np.concatenate([np.asarray(rj.job.true_alpha), [rj.job.true_beta]])
        m = rj.job.statics.batch_size
        p = float(r.p)
        cont = ps_contention(w_eff, p, r.cpu_p)
        feats = np.array([
            m / max(r.cpu_w, 1e-9),
            w_eff / max(p * r.cpu_p, 1e-9),
            (rj.job.statics.model_size / max(p, 1e-9))
            / (rj.job.statics.bandwidth / max(w_eff, 1e-9)),
            m * rj.job.statics.emb_dim / max(p, 1e-9) * cont,
            1.0])
        terms = coef * feats                          # grad, upd, sync, emb, β
        if now < rj.hotps_until:
            hot = max(1.0, (1.0 / 0.03) / max(p, 1.0))
            terms[1] *= hot
            terms[3] *= hot
        coord = rj.job.true_serial * m * (1.0 + (w_eff / 8.0) ** 2)
        t_iter = max(float(terms.sum()) + coord, 1e-6)
        thp = m * w_eff / t_iter
        if now < rj.straggler_until:
            thp *= (0.5 + 0.5 * 0.03)                 # partial sync barrier
        # busy fractions for utilization accounting
        fw = min(terms[0] / t_iter, 1.0)
        fp = min((terms[1] + terms[3]) / t_iter, 1.0)
        return thp, fw, fp

    # ------------------------------------------------------------------
    def run(self, jobs: Sequence[SimJob], horizon_s: float,
            sample_every_s: float = 300.0) -> SimResult:
        result = SimResult(self.traits.name, [])
        pending: List[SimJob] = []
        running: Dict[str, _Running] = {}
        arrivals = sorted(jobs, key=lambda j: j.arrival_s)
        ai = 0
        used_cpu_alloc = 0.0
        used_mem_alloc = 0.0
        next_decide = self.traits.interval_s
        next_sample = 0.0
        now = 0.0

        def alloc_of(r: JobResources) -> Tuple[float, float]:
            return r.total_cpu(), r.total_mem()

        def try_start(job: SimJob) -> bool:
            nonlocal used_cpu_alloc, used_mem_alloc
            r = self.scheduler.initial_allocation(job)
            cpu, mem = alloc_of(r)
            if used_cpu_alloc + cpu > self.capacity.total_cpu or \
               used_mem_alloc + mem > self.capacity.total_mem_gb:
                return False
            rec = JobRecord(job.job_id, job.kind, job.arrival_s, started_s=now)
            rec.pending_s = now - job.arrival_s
            view = JobRuntimeView(job, r, 0.0, [])
            running[job.job_id] = _Running(job, view, rec, r)
            result.records.append(rec)
            result.events.append((now, job.job_id, "start"))
            used_cpu_alloc += cpu
            used_mem_alloc += mem
            return True

        def emit(job_id: str, kind: str) -> None:
            result.events.append((now, job_id, kind))
            self.scheduler.on_event(job_id, kind, now)

        while now < horizon_s and (ai < len(arrivals) or pending or running):
            # --- time-varying capacity (trace replay) ---------------------
            if self.capacity_profile is not None:
                cap_cpu, cap_mem = self.capacity_profile(now)
                self.capacity.total_cpu = cap_cpu
                self.capacity.total_mem_gb = cap_mem
            # --- arrivals -> pending queue --------------------------------
            while ai < len(arrivals) and arrivals[ai].arrival_s <= now:
                pending.append(arrivals[ai])
                ai += 1
            still = []
            for job in pending:
                if not try_start(job):
                    still.append(job)
            pending = still

            # --- per-job progress ------------------------------------------
            for rj in list(running.values()):
                job_id = rj.job.job_id
                if now < rj.blocked_until:
                    rj.record.downtime_s += self.dt
                    continue
                # apply deferred (seamless) plan
                if rj.pending_plan is not None and now >= rj.plan_apply_at:
                    used_cpu_alloc -= rj.resources.total_cpu()
                    used_mem_alloc -= rj.resources.total_mem()
                    rj.resources = rj.pending_plan
                    rj.view.resources = rj.pending_plan
                    used_cpu_alloc += rj.resources.total_cpu()
                    used_mem_alloc += rj.resources.total_mem()
                    rj.pending_plan = None
                    rj.view.obs_since_plan = 0
                    # flash sync downtime (seamless) already tiny
                    dtime = (self.timings.flash_ckpt_save_s + self.timings.flash_ckpt_load_s
                             if self.traits.flash_ckpt else
                             self.timings.rds_ckpt_save_s + self.timings.rds_ckpt_load_s)
                    rj.blocked_until = now + dtime
                    rj.record.downtime_s += dtime
                    continue

                thp, fw, fp = self._throughput(rj, now)
                t_iter_obs = rj.job.statics.batch_size * rj.resources.w / max(thp, 1e-9)
                t_iter_obs *= float(self.rng.lognormal(0.0, 0.03))
                rj.view.observations.append(
                    (rj.resources, rj.job.statics, t_iter_obs))
                rj.view.obs_since_plan += 1
                if len(rj.view.observations) > 256:
                    rj.view.observations.pop(0)
                rj.samples_done += thp * self.dt
                rj.view.samples_done = rj.samples_done
                rj.view.mem_used_gb = rj.mem_used_gb()
                rj.oom_pred.observe(rj.samples_done, rj.mem_used_gb() * 1e9)

                # --- checkpoint cadence ------------------------------------
                if now - rj.last_ckpt_at >= self.ckpt_interval_s:
                    rj.last_ckpt_at = now
                    rj.last_ckpt_samples = rj.samples_done

                # --- OOM ----------------------------------------------------
                cap = rj.mem_capacity_gb()
                if self.traits.oom_prevention:
                    remaining = max(rj.job.total_samples - rj.samples_done, 0.0)
                    hit, peak = rj.oom_pred.will_oom(cap * 1e9, remaining)
                    if hit and rj.mem_used_gb() > 0.7 * cap:
                        need = rj.oom_pred.recommended_capacity(remaining)
                        new_mem_p = max(need / 1e9 / rj.resources.p,
                                        rj.resources.mem_p)
                        dmem = (new_mem_p - rj.resources.mem_p) * rj.resources.p
                        if used_mem_alloc + dmem <= self.capacity.total_mem_gb:
                            used_mem_alloc += dmem
                            rj.resources = dataclasses.replace(
                                rj.resources, mem_p=new_mem_p)
                            rj.view.resources = rj.resources
                if rj.mem_used_gb() > rj.mem_capacity_gb():
                    rj.record.ooms += 1
                    emit(job_id, "oom")
                    # restart with doubled PS memory from last checkpoint
                    new_mem_p = rj.resources.mem_p * 2
                    dmem = (new_mem_p - rj.resources.mem_p) * rj.resources.p
                    used_mem_alloc += dmem
                    rj.resources = dataclasses.replace(rj.resources, mem_p=new_mem_p)
                    rj.view.resources = rj.resources
                    rj.samples_done = rj.last_ckpt_samples
                    dtime = self.timings.provision_s + self.timings.rds_ckpt_load_s
                    rj.blocked_until = now + dtime
                    rj.record.downtime_s += dtime
                    continue

                # --- random instability -------------------------------------
                if self.enable_failures:
                    pods = rj.resources.w + rj.resources.p
                    p_fail = pods * self.pod_failure_rate * self.dt / 86400.0
                    if self.rng.random() < p_fail:
                        rj.record.failures += 1
                        emit(job_id, "failure")
                        if self.traits.dynamic_sharding:
                            # shard requeued; worker replaced in background.
                            # the replacement horizon is the measured re-exec
                            # latency when the job-master harness supplied one
                            # (timings.worker_reexec_s), else pod provisioning
                            rj.capacity_loss_until = now + self.timings.reexec_s()
                        else:
                            rj.samples_done = rj.last_ckpt_samples
                            dtime = self.timings.provision_s + self.timings.rds_ckpt_load_s
                            rj.blocked_until = now + dtime
                            rj.record.downtime_s += dtime
                            continue
                    p_str = rj.resources.w * self.straggler_rate * self.dt / 86400.0
                    if now >= rj.straggler_until and self.rng.random() < p_str:
                        rj.record.stragglers += 1
                        emit(job_id, "straggler")
                        if self.traits.dynamic_sharding:
                            rj.straggler_until = now + self.straggler_rebalance_s  # rebalanced
                        elif self.traits.elastic:
                            # stop-and-restart replacement at next decision
                            rj.straggler_until = now + self.traits.interval_s
                            dtime = (self.timings.rds_ckpt_save_s + self.timings.provision_s
                                     + self.timings.rds_ckpt_load_s)
                            rj.blocked_until = now + self.traits.interval_s + dtime
                            rj.record.downtime_s += dtime
                        else:
                            rj.straggler_until = now + self.unmitigated_s  # no intervention
                    p_hot = rj.resources.p * self.hotps_rate * self.dt / 86400.0
                    if now >= rj.hotps_until and self.rng.random() < p_hot:
                        rj.record.hot_pses += 1
                        emit(job_id, "hot_ps")
                        if self.traits.seamless_migration:
                            # provisioning overlaps training; flash sync at end
                            rj.hotps_until = now + self.timings.provision_s
                            sync = (self.timings.flash_ckpt_save_s
                                    + self.timings.flash_ckpt_load_s)
                            rj.record.downtime_s += sync
                        elif self.traits.elastic:
                            rj.hotps_until = now + self.traits.interval_s
                            dtime = (self.timings.rds_ckpt_save_s + self.timings.provision_s
                                     + self.timings.rds_ckpt_load_s)
                            rj.blocked_until = now + self.traits.interval_s + dtime
                            rj.record.downtime_s += dtime
                        else:
                            rj.hotps_until = now + self.unmitigated_s

                # --- completion ----------------------------------------------
                if rj.samples_done >= rj.job.total_samples:
                    rj.record.completed = True
                    rj.record.finished_s = now
                    result.events.append((now, job_id, "complete"))
                    thp_final, _, _ = self._throughput(rj, now)
                    self.scheduler.on_complete(rj.view, thp_final)
                    used_cpu_alloc -= rj.resources.total_cpu()
                    used_mem_alloc -= rj.resources.total_mem()
                    del running[job_id]

            # --- scheduler decisions ---------------------------------------
            if self.traits.elastic and now >= next_decide and running:
                # only jobs with ≥5 fresh measurements under their current
                # plan are eligible (no decisions on stale/blocked state)
                views = [rj.view for rj in running.values()
                         if rj.view.obs_since_plan >= 5]
                plans = self.scheduler.decide(views, now) if views else {}
                for jid, plan in plans.items():
                    rj = running.get(jid)
                    if rj is None or rj.pending_plan is not None:
                        continue
                    dcpu = plan.total_cpu() - rj.resources.total_cpu()
                    dmem = plan.total_mem() - rj.resources.total_mem()
                    if used_cpu_alloc + dcpu > self.capacity.total_cpu or \
                       used_mem_alloc + dmem > self.capacity.total_mem_gb:
                        continue
                    result.events.append((now, jid, "plan"))
                    if self.traits.seamless_migration:
                        rj.pending_plan = plan
                        rj.plan_apply_at = now + self.timings.provision_s
                    else:
                        dtime = (self.timings.rds_ckpt_save_s + self.timings.provision_s
                                 + self.timings.rds_ckpt_load_s)
                        used_cpu_alloc += dcpu
                        used_mem_alloc += dmem
                        rj.resources = plan
                        rj.view.resources = plan
                        rj.view.obs_since_plan = 0
                        rj.blocked_until = now + dtime
                        rj.record.downtime_s += dtime
                next_decide = now + self.traits.interval_s

            # --- cluster sampling --------------------------------------------
            if now >= next_sample:
                used_cpu = 0.0
                used_mem = 0.0
                for rj in running.values():
                    if now < rj.blocked_until:
                        pass
                    else:
                        _, fw, fp = self._throughput(rj, now)
                        used_cpu += (rj.resources.w * rj.resources.cpu_w * fw
                                     + rj.resources.p * rj.resources.cpu_p * fp)
                    used_mem += min(rj.mem_used_gb() + rj.resources.w
                                    * rj.resources.mem_w * 0.4,
                                    rj.resources.total_mem())
                result.ts_time.append(now)
                result.ts_alloc_cpu.append(used_cpu_alloc)
                result.ts_used_cpu.append(used_cpu)
                result.ts_alloc_mem.append(used_mem_alloc)
                result.ts_used_mem.append(used_mem)
                result.ts_capacity_cpu.append(self.capacity.total_cpu)
                next_sample = now + sample_every_s

            now += self.dt
            if now >= horizon_s:
                break
        return result
