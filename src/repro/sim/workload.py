"""Synthetic job-trace generator calibrated to the paper's workloads (§6).

Three DLRM kinds (Wide&Deep / xDeepFM / DCN) with per-kind ground-truth
(α, β) performance coefficients around the paper's reported fit
(α_grad=3.48, α_upd=2.36, α_emb≈2.45·1e-4·scale, α_sync=0.68, Σβ=2.45),
heavy-tailed job sizes, Poisson arrivals, and embedding-memory growth rates
matching Fig 1(b) (≈2.3 TB / 15 h at production scale, scaled down here).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple

import numpy as np

from repro.core.perf_model import JobResources, JobStatics
from repro.core.warm_start import JobMeta

KINDS = ("wide_deep", "xdeepfm", "dcn")

# Per-kind ground-truth coefficients. Ratios follow the paper's Fig 11 fit
# (α_grad=3.48, α_upd=2.36, α_sync=0.68, Σβ=2.45); the absolute scale is
# normalized so a well-tuned job runs T_iter ≈ 0.2 s at batch 512 — and,
# critically, embedding lookups take 30–48 % of T_iter (Fig 1a), which is
# what makes user CPU over-provisioning show up as low utilization.
BASE_ALPHA: Dict[str, Tuple[float, float, float, float]] = {
    "wide_deep": (3.48e-3, 2.36e-3, 0.68e-3, 2.2e-5),
    "xdeepfm": (4.80e-3, 2.80e-3, 0.80e-3, 2.6e-5),
    "dcn": (3.90e-3, 2.50e-3, 0.72e-3, 3.0e-5),
}
BASE_BETA = 2.45e-3


@dataclass
class SimJob:
    job_id: str
    kind: str
    arrival_s: float
    total_samples: float
    statics: JobStatics
    meta: JobMeta
    true_alpha: Tuple[float, float, float, float]
    true_beta: float
    mem_static_gb: float
    mem_growth_gb_per_msample: float     # embedding growth (OOM driver)
    user_request: JobResources           # what a user would manually configure
    oracle: JobResources                 # well-tuned configuration (grid search)
    true_serial: float = 5e-5   # Amdahl: per-sample serial seconds (CPU-count
                                # invariant) — the fitted Eqn-2 model omits it,
                                # so blind CPU over-provisioning hits a wall


JOB_CPU_QUOTA = 256.0     # per-job quota (cluster policy; bounds all searches)


def ps_contention(w: float, p: float, cpu_p: float) -> float:
    """Lookup/update latency inflation when w workers share p PSes.

    The paper's Eqn 5 is a single-worker view; in reality PS-side service
    time grows superlinearly with concurrent demand (queueing), so a finite
    throughput-optimal (w, p, λ) exists. The fitted model absorbs this
    through its w/(p·λ_p) term — imperfectly, which is the realistic regime."""
    return 1.0 + (w / max(p * cpu_p, 1e-9)) ** 2


def _true_t_iter(job: "SimJob", r: JobResources) -> float:
    from repro.core.perf_model import feature_vector
    x = feature_vector(r, job.statics)
    a = np.asarray(job.true_alpha, float).copy()
    cont = ps_contention(r.w, r.p, r.cpu_p)
    coef = np.concatenate([a[:3], [a[3] * cont], [job.true_beta]])
    # coordination cost grows quadratically with workers (async staleness /
    # barrier effects): creates a finite throughput-optimal worker count
    coord = job.true_serial * job.statics.batch_size * (r.w / 8.0) ** 2
    return float(x @ coef) + job.true_serial * job.statics.batch_size + coord


def true_throughput(job: SimJob, r: JobResources) -> float:
    t = _true_t_iter(job, r)
    return job.statics.batch_size * r.w / max(t, 1e-9)


def oracle_config(job: SimJob, *, max_cpu: float = JOB_CPU_QUOTA) -> JobResources:
    """Grid-search the max-throughput config under the per-job quota — the
    'well-tuned' configuration a user reaches after ~10 trial-and-error runs
    (paper §6.1)."""
    best, best_thp = None, -1.0
    for w in (1, 2, 4, 8, 12, 16, 24, 32):
        for p in (1, 2, 4, 8, 12, 16):
            for cw in (2, 4, 8, 16, 32):
                for cp in (2, 4, 8, 16, 32):
                    r = JobResources(w=w, p=p, cpu_w=cw, cpu_p=cp, mem_p=32.0)
                    if r.total_cpu() > max_cpu:
                        continue
                    thp = true_throughput(job, r)
                    if thp > best_thp * 1.02:             # prefer smaller ties
                        best, best_thp = r, thp
    assert best is not None
    return best


def generate_jobs(n: int, seed: int = 0, *, arrival_rate_per_h: float = 30.0,
                  mean_msamples: float = 30.0) -> List[SimJob]:
    rng = np.random.default_rng(seed)
    jobs: List[SimJob] = []
    t = 0.0
    for i in range(n):
        t += rng.exponential(3600.0 / arrival_rate_per_h)
        kind = KINDS[int(rng.integers(len(KINDS)))]
        a = tuple(float(x * rng.lognormal(0, 0.15)) for x in BASE_ALPHA[kind])
        b = float(BASE_BETA * rng.lognormal(0, 0.15))
        samples = float(rng.lognormal(np.log(mean_msamples * 1e6), 0.8))
        emb_rows = float(rng.lognormal(np.log(5e6), 1.0))
        statics = JobStatics(batch_size=512, model_size=emb_rows * 16 * 4,
                             bandwidth=1e9, emb_dim=16)
        meta = JobMeta(kind, dense_params=1e6 * rng.lognormal(0, 0.5),
                       emb_rows=emb_rows, emb_dim=16, batch_size=512,
                       dataset_samples=samples, user=f"user{int(rng.integers(8))}")
        job = SimJob(
            job_id=f"job{i:04d}", kind=kind, arrival_s=t,
            total_samples=samples, statics=statics, meta=meta,
            true_alpha=a, true_beta=b,
            true_serial=float(5e-5 * rng.lognormal(0, 0.3)),
            mem_static_gb=float(rng.uniform(2, 8)),
            mem_growth_gb_per_msample=float(rng.lognormal(np.log(0.5), 0.7)),
            user_request=JobResources(w=1, p=1, cpu_w=1, cpu_p=1),  # placeholder
            oracle=JobResources(w=1, p=1, cpu_w=1, cpu_p=1),
        )
        job.oracle = oracle_config(job)
        # users misconfigure: roughly quota-sized but badly *balanced*
        # (over-provisioned worker CPU, starved PS side, guessed memory) —
        # the trial-and-error regime of §2.2
        w = int(rng.choice([2, 4, 8, 16, 24, 32]))
        p = int(rng.choice([1, 1, 2, 4]))
        cpu_w = float(rng.choice([8, 16, 32, 32]))
        cpu_p = float(rng.choice([2, 4, 8]))
        scale = min(1.0, JOB_CPU_QUOTA / (w * cpu_w + p * cpu_p))
        job.user_request = JobResources(
            w=max(1, int(round(w * scale))), p=p,
            cpu_w=cpu_w, cpu_p=cpu_p, mem_w=8.0,
            mem_p=float(rng.choice([8.0, 16.0, 32.0], p=[0.45, 0.4, 0.15])),
        )
        jobs.append(job)
    return jobs
