"""Cluster-trace ingestion: Alibaba ``cluster-trace-gpu-v2020``-shaped jobs.

The loader reads the merged per-job CSV shape used by the public PAI trace
(and by the litosly trace simulator built on it): one row per job with its
submission time, runtime, per-instance resource *plan* and instance count.
Units follow the original trace: ``plan_cpu``/``plan_gpu`` are in 1/100ths
of a core/device (``600`` = 6 cores), ``plan_mem`` is in GB, times are in
seconds.

    job_name,user,status,submit_time,duration,plan_cpu,plan_mem,plan_gpu,inst_num

A small fixture (``data/trace_v2020_sample.csv``, checked in — no network)
anchors tests and the ``--fast`` benchmark mode; ``synthesize_trace`` scales
it up deterministically by drawing from the fixture's fitted marginals
(exponential interarrivals, lognormal durations/CPU/memory, geometric
instance counts), so the fig-14/15 benches can replay thousands of jobs with
the same statistical shape. ``trace_to_jobs`` maps rows onto the simulator's
``SimJob``s: the trace's resource plan becomes the user-configured request
and ``total_samples`` is calibrated so each job's runtime under that request
reproduces the traced duration. ``CapacityWave`` models the trace's
time-varying usable capacity (the litosly simulator's pattern/period knob).
"""
from __future__ import annotations

import csv
import math
import os
import zlib
from dataclasses import dataclass
from typing import Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.perf_model import JobResources, JobStatics
from repro.core.warm_start import JobMeta
from repro.sim.workload import (
    BASE_ALPHA, BASE_BETA, JOB_CPU_QUOTA, KINDS, SimJob, oracle_config,
    true_throughput,
)

TRACE_COLUMNS = ("job_name", "user", "status", "submit_time", "duration",
                 "plan_cpu", "plan_mem", "plan_gpu", "inst_num")

#: Terminal states whose rows describe a complete, replayable job.
REPLAYABLE_STATUSES = ("Terminated",)


@dataclass(frozen=True)
class TraceJob:
    """One job row of a v2020-shaped trace (units as in the original)."""
    job_name: str
    user: str
    status: str
    submit_time: float      # seconds (trace-relative)
    duration: float         # seconds of execution
    plan_cpu: float         # per-instance CPU plan, 1/100 cores (600 = 6)
    plan_mem: float         # per-instance memory plan, GB
    plan_gpu: float         # per-instance GPU plan, 1/100 devices
    inst_num: int           # requested instances


def default_trace_path() -> str:
    """The checked-in sample trace (40 jobs, seeded, no network needed)."""
    return os.path.join(os.path.dirname(__file__), "data",
                        "trace_v2020_sample.csv")


def load_trace(path: str) -> List[TraceJob]:
    """Parse a v2020-shaped CSV; validates the header and field types."""
    rows: List[TraceJob] = []
    with open(path, newline="") as f:
        reader = csv.DictReader(f)
        header = tuple(reader.fieldnames or ())
        if header != TRACE_COLUMNS:
            raise ValueError(
                f"bad trace header {header!r}; expected {TRACE_COLUMNS!r}")
        for ln, rec in enumerate(reader, start=2):
            try:
                rows.append(TraceJob(
                    job_name=rec["job_name"], user=rec["user"],
                    status=rec["status"],
                    submit_time=float(rec["submit_time"]),
                    duration=float(rec["duration"]),
                    plan_cpu=float(rec["plan_cpu"]),
                    plan_mem=float(rec["plan_mem"]),
                    plan_gpu=float(rec["plan_gpu"]),
                    inst_num=int(rec["inst_num"])))
            except (KeyError, ValueError) as e:
                raise ValueError(f"{path}:{ln}: bad trace row {rec!r}") from e
    return rows


def write_trace(path: str, rows: Iterable[TraceJob]) -> None:
    """Inverse of :func:`load_trace` (byte-stable field formatting)."""
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(TRACE_COLUMNS)
        for r in rows:
            w.writerow([r.job_name, r.user, r.status,
                        f"{r.submit_time:g}", f"{r.duration:g}",
                        f"{r.plan_cpu:g}", f"{r.plan_mem:g}",
                        f"{r.plan_gpu:g}", r.inst_num])


# ---------------------------------------------------------------- marginals
@dataclass(frozen=True)
class TraceMarginals:
    """Sufficient statistics for the synthetic generator."""
    n_jobs: int
    interarrival_mean_s: float
    log_duration_mean: float
    log_duration_std: float
    log_cpu_mean: float          # over plan_cpu (1/100 cores)
    log_cpu_std: float
    log_mem_mean: float          # over plan_mem (GB)
    log_mem_std: float
    inst_mean: float             # mean requested instances (>= 1)
    users: Tuple[str, ...]


def trace_marginals(rows: Sequence[TraceJob]) -> TraceMarginals:
    if not rows:
        raise ValueError("cannot fit marginals on an empty trace")
    subs = sorted(r.submit_time for r in rows)
    gaps = np.diff(subs)
    inter = float(np.mean(gaps)) if len(gaps) else 600.0
    ld = np.log([max(r.duration, 1.0) for r in rows])
    lc = np.log([max(r.plan_cpu, 100.0) for r in rows])
    lm = np.log([max(r.plan_mem, 1.0) for r in rows])
    inst = np.array([max(r.inst_num, 1) for r in rows], float)
    return TraceMarginals(
        n_jobs=len(rows),
        interarrival_mean_s=max(inter, 1.0),
        log_duration_mean=float(ld.mean()),
        log_duration_std=float(ld.std()) or 0.1,
        log_cpu_mean=float(lc.mean()), log_cpu_std=float(lc.std()) or 0.1,
        log_mem_mean=float(lm.mean()), log_mem_std=float(lm.std()) or 0.1,
        inst_mean=float(inst.mean()),
        users=tuple(sorted({r.user for r in rows})))


def synthesize_trace(n: int, seed: int,
                     marginals: Optional[TraceMarginals] = None,
                     ) -> List[TraceJob]:
    """Deterministic, seeded generator matching the fixture's marginals.

    Same ``(n, seed, marginals)`` ⇒ identical rows. Durations/CPU/memory are
    lognormal, interarrivals exponential, instance counts geometric — the
    family the v2020 trace's heavy-tailed job population is usually
    summarized by.
    """
    m = marginals or trace_marginals(load_trace(default_trace_path()))
    rng = np.random.default_rng(seed)
    users = m.users or ("u0",)
    out: List[TraceJob] = []
    t = 0.0
    # geometric with mean inst_mean: p = 1/mean (support starts at 1)
    p_inst = min(1.0, 1.0 / max(m.inst_mean, 1.0))
    for i in range(n):
        t += float(rng.exponential(m.interarrival_mean_s))
        dur = float(np.exp(rng.normal(m.log_duration_mean, m.log_duration_std)))
        cpu = float(np.exp(rng.normal(m.log_cpu_mean, m.log_cpu_std)))
        mem = float(np.exp(rng.normal(m.log_mem_mean, m.log_mem_std)))
        inst = int(rng.geometric(p_inst))
        out.append(TraceJob(
            job_name=f"syn{i:05d}",
            user=str(users[int(rng.integers(len(users)))]),
            status="Terminated",
            submit_time=round(t, 1),
            duration=round(max(dur, 60.0), 1),
            plan_cpu=float(np.clip(round(cpu / 100) * 100, 100, 3200)),
            plan_mem=float(np.clip(round(mem, 1), 2.0, 128.0)),
            plan_gpu=0.0,
            inst_num=int(np.clip(inst, 1, 48))))
    return out


# ------------------------------------------------------------ SimJob mapping
def _kind_of(job_name: str) -> str:
    """Stable model-kind assignment (independent of the synthesis seed)."""
    return KINDS[zlib.crc32(job_name.encode()) % len(KINDS)]


def trace_to_jobs(rows: Sequence[TraceJob], seed: int = 0, *,
                  with_oracle: bool = False,
                  min_duration_s: float = 60.0) -> List[SimJob]:
    """Map replayable trace rows onto simulator jobs.

    The trace's per-instance plan becomes the user-configured request (the
    §2.2 trial-and-error regime: plan-CPU-sized workers, a thin PS fleet),
    and ``total_samples`` is calibrated so the job's runtime *under that
    request* equals the traced ``duration`` — replaying the trace with the
    ``static_user`` scheduler reproduces the original durations, and every
    improvement a smarter scheduler shows is earned against that anchor.
    ``with_oracle`` additionally grid-searches each job's well-tuned config
    (needed only by the ``static_tuned`` baseline; it is slow at scale).
    """
    rng = np.random.default_rng(seed)
    jobs: List[SimJob] = []
    usable = [r for r in rows
              if r.status in REPLAYABLE_STATUSES
              and r.duration >= min_duration_s and r.inst_num >= 1]
    usable.sort(key=lambda r: (r.submit_time, r.job_name))
    t0 = usable[0].submit_time if usable else 0.0
    for i, row in enumerate(usable):
        kind = _kind_of(row.job_name)
        a0, a1, a2, a3 = (float(x * rng.lognormal(0, 0.15))
                          for x in BASE_ALPHA[kind])
        alpha = (a0, a1, a2, a3)
        beta = float(BASE_BETA * rng.lognormal(0, 0.15))
        inst = int(np.clip(row.inst_num, 1, 48))
        n_ps = max(1, inst // 4)
        n_w = max(1, inst - n_ps)
        cores = float(np.clip(row.plan_cpu / 100.0, 1.0, 32.0))
        cpu_p = float(rng.choice([2.0, 4.0, 8.0]))
        scale = min(1.0, JOB_CPU_QUOTA / (n_w * cores + n_ps * cpu_p))
        request = JobResources(
            w=max(1, int(round(n_w * scale))), p=n_ps,
            cpu_w=cores, cpu_p=cpu_p, mem_w=8.0,
            mem_p=float(np.clip(row.plan_mem, 4.0, 64.0)))
        emb_rows = float(rng.lognormal(np.log(5e6), 1.0))
        statics = JobStatics(batch_size=512, model_size=emb_rows * 16 * 4,
                             bandwidth=1e9, emb_dim=16)
        job = SimJob(
            job_id=f"trace{i:05d}", kind=kind,
            arrival_s=row.submit_time - t0,
            total_samples=1.0,                      # calibrated just below
            statics=statics,
            meta=JobMeta(kind, dense_params=1e6 * rng.lognormal(0, 0.5),
                         emb_rows=emb_rows, emb_dim=16, batch_size=512,
                         dataset_samples=1.0, user=row.user),
            true_alpha=alpha, true_beta=beta,
            true_serial=float(5e-5 * rng.lognormal(0, 0.3)),
            mem_static_gb=float(rng.uniform(2, 8)),
            mem_growth_gb_per_msample=float(rng.lognormal(np.log(0.3), 0.7)),
            user_request=request,
            oracle=request)
        samples = true_throughput(job, request) * row.duration
        job.total_samples = max(samples, 1e4)
        # JobMeta is frozen: rebuild it with the calibrated dataset size
        job.meta = JobMeta(kind, dense_params=job.meta.dense_params,
                           emb_rows=emb_rows, emb_dim=16, batch_size=512,
                           dataset_samples=job.total_samples, user=row.user)
        if with_oracle:
            job.oracle = oracle_config(job)
        jobs.append(job)
    return jobs


# ------------------------------------------------------- time-varying capacity
@dataclass(frozen=True)
class CapacityWave:
    """Sinusoidal usable-capacity profile (litosly's pattern/period knob).

    The shared production cluster's capacity available to elastic training
    ebbs with the colocated serving tide; ``amplitude=0.2`` means usable
    CPU/memory swings ±20 % around the base over each ``period_s``.
    """
    base_cpu: float
    base_mem_gb: float
    amplitude: float = 0.0
    period_s: float = 6 * 3600.0
    phase: float = 0.0

    def __call__(self, t: float) -> Tuple[float, float]:
        factor = 1.0 + self.amplitude * math.sin(
            2.0 * math.pi * (t / self.period_s + self.phase))
        factor = max(factor, 0.05)
        return self.base_cpu * factor, self.base_mem_gb * factor
