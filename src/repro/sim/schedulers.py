"""Scheduler strategies for the cloud simulator.

* ``dlrover_rm`` — the paper's system: warm-start + NNLS/NSGA-II/greedy
  auto-scaling + dynamic data sharding + seamless migration + flash-ckpt +
  OOM prediction.
* ``es``       — Elastic Scheduler (Or et al. [42]): workers-only heuristic
  hill-climbing, fixed ±step, stop-and-restart transitions.
* ``optimus``  — Optimus [44]: marginal-gain greedy adding/removing one
  worker OR one PS per round, ignores transition cost, stop-and-restart.
* ``static_tuned`` / ``static_user`` — fixed allocations (oracle / user guess).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.autoscaler import ClusterCapacity, JobState
from repro.core.brain import ClusterBrain
from repro.core.perf_model import JobResources, JobStatics, PerfModel
from repro.core.warm_start import ConfigDB, ConfigRecord
from repro.sim.workload import SimJob


@dataclass
class SchedulerTraits:
    name: str
    elastic: bool = True
    warm_start: bool = False
    dynamic_sharding: bool = False      # straggler mitigation + no-restart recovery
    seamless_migration: bool = False
    flash_ckpt: bool = False
    oom_prevention: bool = False
    interval_s: float = 180.0           # decision cadence (paper §6.2: 3 min)


@dataclass
class JobRuntimeView:
    """What the scheduler can observe about a running job."""
    job: SimJob
    resources: JobResources
    samples_done: float
    observations: List[Tuple[JobResources, JobStatics, float]]
    mem_used_gb: float = 0.0
    obs_since_plan: int = 0     # fresh measurements under the current plan
    model: PerfModel = field(default_factory=PerfModel)

    def refit(self) -> None:
        if len(self.observations) >= 4:
            self.model.fit(self.observations[-128:])


class Scheduler:
    traits = SchedulerTraits(name="base", elastic=False)

    def __init__(self, capacity: ClusterCapacity, seed: int = 0):
        self.capacity = capacity
        self.rng = np.random.default_rng(seed)
        self.config_db = ConfigDB()

    # -------------------------------------------------------------- initial
    def initial_allocation(self, job: SimJob) -> JobResources:
        return job.user_request

    # -------------------------------------------------------------- periodic
    def decide(self, views: Sequence[JobRuntimeView], now: float = 0.0
               ) -> Dict[str, JobResources]:
        return {}

    # -------------------------------------------------------------- events
    def on_event(self, job_id: str, kind: str, now: float) -> None:
        """Instability signal (failure/straggler/hot_ps/oom) from the engine.

        Baselines ignore it; DLRover-RM feeds it to the brain's stage-3
        degradation ledger so the next ``decide`` prioritizes the victim."""

    def on_complete(self, view: JobRuntimeView, throughput: float) -> None:
        self.config_db.add(ConfigRecord(
            meta=view.job.meta, final_config=view.resources,
            throughput=throughput))


class StaticUser(Scheduler):
    traits = SchedulerTraits(name="static_user", elastic=False)


class StaticTuned(Scheduler):
    traits = SchedulerTraits(name="static_tuned", elastic=False)

    def initial_allocation(self, job: SimJob) -> JobResources:
        return job.oracle


class DLRoverRM(Scheduler):
    """The paper's system, driven end-to-end by the real ``ClusterBrain``:
    the simulator exercises stage 1 (similarity warm start + kind-model
    refinement) on admission, stage 2 (NSGA-II + weighted greedy) every
    decision interval, and stage 3 (degradation feedback into the WG
    weights) through ``on_event`` — the same controller object the
    launcher-side ``JobMaster`` path uses."""

    traits = SchedulerTraits(
        name="dlrover_rm", elastic=True, warm_start=True, dynamic_sharding=True,
        seamless_migration=True, flash_ckpt=True, oom_prevention=True)

    def __init__(self, capacity: ClusterCapacity, seed: int = 0):
        super().__init__(capacity, seed)
        self.brain = ClusterBrain(capacity, idle_penalty=1.0, trust_factor=2.0)
        # one config DB: completions recorded by the engine feed stage 1
        self.config_db = self.brain.config_db

    def initial_allocation(self, job: SimJob) -> JobResources:
        # stage 1: warm start from history, refined by the kind-level model.
        # Cold-start default matches the baselines' (fair comparison): the
        # advantage must come from the three-stage loop, not a better guess.
        return self.brain.allocate(
            job.meta, job.statics,
            default=JobResources(w=4, p=2, cpu_w=8, cpu_p=8))

    def on_event(self, job_id: str, kind: str, now: float) -> None:
        self.brain.report_degradation(job_id, kind, now)      # stage 3

    def on_complete(self, view: JobRuntimeView, throughput: float) -> None:
        self.brain.record_history(
            view.job.meta, view.job.statics, view.observations,
            final_config=view.resources, throughput=throughput)

    def decide(self, views: Sequence[JobRuntimeView], now: float = 0.0
               ) -> Dict[str, JobResources]:
        jobs: List[JobState] = []
        for v in views:
            v.refit()
            if not v.model.fitted:
                continue
            jobs.append(JobState(
                job_id=v.job.job_id, statics=v.job.statics, current=v.resources,
                model=v.model,
                remaining_samples=max(v.job.total_samples - v.samples_done, 0.0)))
        if not jobs:
            return {}
        plans = self.brain.adjust(jobs, now=now)              # stage 2
        # memory right-sizing: PS memory tracks observed usage + headroom
        vmap = {v.job.job_id: v for v in views}
        for jid, plan in list(plans.items()):
            v = vmap.get(jid)
            if v is not None and v.mem_used_gb > 0:
                need = max(v.mem_used_gb * 1.3 / max(plan.p, 1), 4.0)
                plans[jid] = dataclasses.replace(plan, mem_p=need)
        return plans


_BASELINE_DEFAULT = JobResources(w=4, p=2, cpu_w=8, cpu_p=8, mem_p=16.0)


class ElasticScheduler(Scheduler):
    """ES [42]: measurement-driven worker hill-climbing (workers only).

    Explores upward while per-worker scaling efficiency holds, then settles;
    re-opens exploration only if throughput later degrades ≥20 % from its
    best. Every change is a stop-and-restart transition (the engine charges
    it), which is exactly the paper's critique.
    """
    traits = SchedulerTraits(name="es", elastic=True)

    def __init__(self, capacity: ClusterCapacity, seed: int = 0):
        super().__init__(capacity, seed)
        self._last: Dict[str, Tuple[int, float]] = {}
        self._settled: Dict[str, bool] = {}
        self._best_thp: Dict[str, float] = {}

    def initial_allocation(self, job: SimJob) -> JobResources:
        return _BASELINE_DEFAULT                # sane scheduler default

    def decide(self, views: Sequence[JobRuntimeView], now: float = 0.0
               ) -> Dict[str, JobResources]:
        plans: Dict[str, JobResources] = {}
        for v in views:
            if not v.observations:
                continue
            jid = v.job.job_id
            r, s, t_iter = v.observations[-1]
            thp = s.batch_size * r.w / max(t_iter, 1e-9)
            best = self._best_thp.get(jid, 0.0)
            self._best_thp[jid] = max(best, thp)
            if self._settled.get(jid):
                if best > 0 and thp < 0.8 * best:
                    self._settled[jid] = False       # regression: re-explore
                else:
                    continue
            w = v.resources.w
            prev = self._last.get(jid)
            if prev is None:
                new_w = w + 1
            else:
                prev_w, prev_thp = prev
                gain = (thp - prev_thp) / max(prev_thp, 1e-9)
                if w > prev_w and gain > 0.05 * (w - prev_w):
                    new_w = w + 1                    # still scaling well
                elif w > prev_w:
                    new_w = prev_w                   # step back and settle
                    self._settled[jid] = True
                else:
                    new_w = w + 1
            new_w = int(np.clip(new_w, 1, 32))
            self._last[jid] = (w, thp)
            if new_w != w:
                plans[jid] = dataclasses.replace(v.resources, w=new_w)
        return plans


class Optimus(Scheduler):
    """Optimus [44]: marginal-gain greedy, ±1 worker or PS, no transition cost."""
    traits = SchedulerTraits(name="optimus", elastic=True)

    def initial_allocation(self, job: SimJob) -> JobResources:
        return _BASELINE_DEFAULT                # sane scheduler default

    def decide(self, views: Sequence[JobRuntimeView], now: float = 0.0
               ) -> Dict[str, JobResources]:
        plans: Dict[str, JobResources] = {}
        for v in views:
            v.refit()
            if not v.model.fitted:
                continue
            base = v.model.throughput(v.resources, v.job.statics)
            best, best_gain = None, 0.0
            moves = [
                dataclasses.replace(v.resources, w=v.resources.w + 1),
                dataclasses.replace(v.resources, p=v.resources.p + 1),
            ]
            if v.resources.w > 1:
                moves.append(dataclasses.replace(v.resources, w=v.resources.w - 1))
            if v.resources.p > 1:
                moves.append(dataclasses.replace(v.resources, p=v.resources.p - 1))
            for cand in moves:
                gain = (v.model.throughput(cand, v.job.statics) - base) \
                    / max(cand.total_cpu(), 1.0)
                if gain > best_gain:
                    best, best_gain = cand, gain
            # require a ≥5 % predicted throughput gain to move at all
            if best is not None and \
               v.model.throughput(best, v.job.statics) > 1.05 * base:
                plans[v.job.job_id] = best
        return plans


SCHEDULERS = {
    "dlrover_rm": DLRoverRM,
    "es": ElasticScheduler,
    "optimus": Optimus,
    "static_tuned": StaticTuned,
    "static_user": StaticUser,
}


def make_scheduler(name: str, capacity: ClusterCapacity, seed: int = 0) -> Scheduler:
    return SCHEDULERS[name](capacity, seed)
