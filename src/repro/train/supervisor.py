"""Self-healing supervisor over the real DLRM training loop (paper §5).

DLRover-RM's reliability pillar: an unstable shared cloud loses ~1.5 %/pod/
day, stragglers appear from resource contention, and jobs hang. The paper's
JCT/completion-rate wins come from *detecting* these abnormalities and
recovering fast — flash checkpoints plus elastic re-scaling — rather than
restarting from scratch. This module is that loop on the repo's real
training path:

* ``DLRMJob`` — one restartable DLRM training job: deterministic batches
  keyed by **global step** (the property that makes recovery bit-exact),
  layout-stamped flash checkpoints on a cadence, and typed recovery entry
  points (restore, elastic shrink onto surviving PS shards, graceful
  degradation after OOM).
* ``Supervisor`` — wraps the job with a step-deadline **watchdog** (hang
  detection via a cancellable worker thread), **EWMA step-time straggler
  detection**, and a recovery driver with exponential backoff + jitter and
  a capped restart budget. Every fault → detect → recover transition lands
  in a structured event log with recovery-latency and steps-lost metrics.

Recovery is bit-exact: batches are a pure function of the global step, flash
checkpoints verify per-leaf checksums, and restore falls back to the newest
*valid* blob — so the post-recovery loss trajectory equals the no-fault
run's after the restored step (``tests/test_supervisor_chaos.py`` asserts
equality, not closeness).

Scope note: the watchdog abandons a hung *attempt* (injected stalls are
cancellable sleeps and unwind via ``AttemptAbandoned``); a truly wedged
native call can only be killed at process level. That process level exists
now: ``repro.train.job_master`` promotes this supervisor to a daemon that
spawns ``DLRMJob`` loops as real subprocesses (``repro.train.worker_main``),
monitors heartbeat files + exit codes, and re-execs dead workers from the
newest valid checkpoint — its public names are re-exported here so the
in-process and process-level supervision surfaces live side by side.
"""
from __future__ import annotations

import json
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from concurrent.futures import TimeoutError as FutureTimeout
from dataclasses import asdict, dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.dlrm_models import DLRMConfig
from repro.core.faults import (
    AttemptAbandoned, FaultError, FaultInjector, PSShardLoss, TransientOOM,
)
from repro.core.flash_checkpoint import FlashCheckpoint
from repro.core.migration import MigrationTimings
from repro.data.synthetic import criteo_batch
from repro.sharding.policy import (
    PaddedLayout, padded_layout_for_ranges, uniform_vocab_ranges,
)
from repro.train.job_master import (  # noqa: F401  (process-level surface)
    JobMaster, JobMasterConfig, JobMasterReport, ReexecBudgetExceeded,
    WorkerSpec,
)
from repro.train import elastic, optim, replan
from repro.train import trainer as trainer_mod


class RestartBudgetExceeded(RuntimeError):
    """The supervisor's capped restart budget ran out; the job is failed."""


# ------------------------------------------------------------------------ job
class DLRMJob:
    """One restartable DLRM training job (the unit a supervisor heals).

    Batches are generated directly from the deterministic synthetic stream,
    indexed by global step — sample ``i`` of step ``n`` is absolute sample
    ``n * batch_size + i`` — so a replay after restore consumes byte-
    identical data (the §5.1 exactly-once property, applied to recovery).

    Args:
      cfg:        the DLRM workload config.
      ckpt:       flash-checkpoint store (memory + optional disk tier).
      opt_name:   optimizer name ("adagrad", "adam", ...).
      lr:         learning rate.
      init_seed:  PRNG seed of the fresh-parameter init.
      data_seed:  seed of the deterministic sample stream.
      ckpt_every: checkpoint cadence in global steps.
      n_ps:       PS shard count of the (padded) placement plan.
      padded:     materialize physically-unequal PS shards (PaddedLayout).
      sparse_update: compile the fused sparse backward + row-wise optimizer
                  update into the step (``EmbeddingPlan.sparse_update``);
                  requires an optimizer with an ``update_rows`` seam.
      injector:   optional ``FaultInjector`` wired through the batch hook.
    """

    def __init__(self, cfg: DLRMConfig, ckpt: FlashCheckpoint, *,
                 opt_name: str = "adagrad", lr: float = 0.05,
                 init_seed: int = 0, data_seed: int = 11,
                 ckpt_every: int = 10, n_ps: int = 4, padded: bool = False,
                 sparse_update: bool = False,
                 injector: Optional[FaultInjector] = None):
        self.cfg = cfg
        self.ckpt = ckpt
        self.opt_name = opt_name
        self.opt = optim.make(opt_name, lr)
        self.init_seed = init_seed
        self.data_seed = data_seed
        self.ckpt_every = max(int(ckpt_every), 1)
        self.n_ps = int(n_ps)
        self.injector = injector
        self.layout: Optional[PaddedLayout] = None
        if padded:
            self.layout = padded_layout_for_ranges(
                uniform_vocab_ranges(cfg.total_embedding_rows, self.n_ps))
        self.sparse_update = bool(sparse_update)
        self.table_hot: Optional[Any] = None     # measured cache plan rows
        self.vocab_ranges: Optional[Any] = None  # applied placement ranges
        self.remapper = replan.EmbeddingRemapper(cfg.table_rows)
        self.state: Optional[Dict[str, Any]] = None
        self.step_fn: Optional[Callable[..., Any]] = None
        self.global_step = 0
        self.generation = 0          # bumped on every recovery; stale
        self._lock = threading.RLock()  # attempts see it and abandon
        self._cancel: Optional[threading.Event] = None
        self.losses: Dict[int, float] = {}
        self.degrade_level = 0

    # ------------------------------------------------------------ lifecycle
    def _compile(self) -> None:
        jitted = jax.jit(trainer_mod.make_dlrm_train_step(
            self.cfg, self.opt, plan=self.cfg.embedding_plan(
                table_hot=self.table_hot, layout=self.layout,
                sparse_update=self.sparse_update)))
        if self.state is not None:
            # warm the compile cache on a throwaway step NOW, outside the
            # watchdog deadline — else every (re)compile's first step reads
            # as a hang and the supervisor restart-loops on its own JIT
            out = jitted(self.state, self._raw_batch(self.global_step))
            jax.block_until_ready(out)
        fn = jitted
        if self.injector is not None:
            # trainer-layer fault seam: crash-class faults (PS loss, OOM)
            # and stalls fire where the step actually executes
            fn = trainer_mod.with_step_hooks(
                fn, before=lambda state, batch: self.injector.before_step(
                    self.global_step, self._cancel))
        self.step_fn = fn

    def start(self, resume: bool = True) -> int:
        """Fresh init — or resume from the newest valid checkpoint."""
        if resume and self.ckpt.latest_step() is not None:
            try:
                return self.restore()
            except FileNotFoundError as e:
                # every blob corrupt: fall through to fresh init, but leave a
                # trace in the checkpoint event log — a silent fresh start
                # after data loss is indistinguishable from a clean boot
                self.ckpt.note("restore_failed_fresh_start", error=str(e))
        with self._lock:             # a stale attempt may still be running
            self.state = trainer_mod.make_dlrm_train_state(
                self.cfg, self.opt, jax.random.PRNGKey(self.init_seed),
                layout=self.layout)
            self.global_step = 0
            self._compile()
            self.save()              # step-0 blob: recovery never lacks a base
        return 0

    def _raw_batch(self, gstep: int) -> Dict[str, jnp.ndarray]:
        B = self.cfg.batch_size
        raw = criteo_batch(self.cfg, self.data_seed,
                           np.arange(gstep * B, (gstep + 1) * B))
        return {k: jnp.asarray(v)
                for k, v in self.remapper.remap_batch(raw).items()}

    def batch_for(self, gstep: int) -> Dict[str, jnp.ndarray]:
        """Deterministic batch of global step ``gstep`` (remapped, on device)."""
        if self.injector is not None:
            self.injector.on_batch(gstep)       # data-pipeline fault hook
        return self._raw_batch(gstep)

    def run_step(self, generation: Optional[int] = None,
                 cancel: Optional[threading.Event] = None) -> Dict[str, Any]:
        """Execute one training step; saves on the checkpoint cadence.

        ``generation`` (from the supervisor) guards against an abandoned
        watchdog attempt racing a recovery: a stale attempt raises
        ``AttemptAbandoned`` instead of touching state. ``cancel`` threads
        the watchdog's cancellation into injected stalls, so a hung attempt
        unwinds promptly (releasing the state lock) once detected.
        """
        with self._lock:
            if generation is not None and generation != self.generation:
                raise AttemptAbandoned(f"stale attempt gen={generation}")
            self._cancel = cancel
            gstep = self.global_step
            batch = self.batch_for(gstep)
            assert self.step_fn is not None, "run_step before start()"
            state, m = self.step_fn(self.state, batch)
            loss = float(m["loss"])             # forces host sync: real timing
            self.state = state
            self.global_step = gstep + 1
            self.losses[gstep] = loss
            if self.global_step % self.ckpt_every == 0:
                self.save()
            return {"loss": loss, "step": gstep}

    # ----------------------------------------------------------- checkpoints
    def save(self) -> None:
        replan.save_with_layout(self.ckpt, self.state, self.global_step,
                                self.remapper, self.table_hot,
                                self.vocab_ranges, layout=self.layout)

    def restore(self, *, onto_n_ps: Optional[int] = None) -> int:
        """Restore from the newest valid checkpoint (typed recovery action).

        ``onto_n_ps`` re-resumes a padded job onto that many *surviving* PS
        shards (elastic shrink after ``PSShardLoss``); None keeps the
        stamped layout. Returns the restored global step.
        """
        with self._lock:
            self.generation += 1
            self.ckpt.wait()                     # flush in-flight persists
            (self.state, step, self.remapper, self.table_hot,
             self.vocab_ranges, self.layout) = elastic.resume_dlrm_stamped(
                self.cfg, self.opt, self.ckpt, onto_n_ps=onto_n_ps)
            if onto_n_ps is not None and self.layout is not None:
                self.n_ps = self.layout.n_ps
            self.global_step = step
            self._compile()
            return step

    # ------------------------------------------------------------ degradation
    def degrade(self) -> str:
        """Graceful degradation ladder for repeated OOM (typed action).

        First occurrence drops the VMEM hot-row cache (frees the largest
        discretionary reservation); repeats halve the batch size (floor 8).
        The step is recompiled; training resumes at the same global step —
        an injected OOM kills the attempt before state mutates.
        """
        import dataclasses
        with self._lock:
            self.generation += 1
            self.degrade_level += 1
            if self.degrade_level == 1 and (
                    self.table_hot is not None or self.cfg.hot_rows_k > 0):
                self.table_hot = None
                self.cfg = dataclasses.replace(self.cfg, hot_rows_k=0)
                action = "drop_hot_cache"
            else:
                new_b = max(self.cfg.batch_size // 2, 8)
                self.cfg = dataclasses.replace(self.cfg, batch_size=new_b)
                action = f"shrink_batch_to_{new_b}"
            self._compile()
            return action


# ----------------------------------------------------------------- supervisor
@dataclass
class SupervisorConfig:
    """Detection thresholds and the recovery policy knobs."""
    step_deadline_s: Optional[float] = None   # watchdog; None disables
    straggler_factor: float = 3.0             # step_time > factor * EWMA
    ewma_alpha: float = 0.25
    ewma_warmup_steps: int = 5
    max_restarts: int = 5                     # capped restart budget
    backoff_base_s: float = 0.05
    backoff_cap_s: float = 2.0
    backoff_jitter: float = 0.25              # ± fraction of the delay
    seed: int = 0                             # jitter RNG (determinism)


@dataclass
class SupervisorEvent:
    """One structured entry of the fault → detect → recover log."""
    t: float
    kind: str                                 # fault_detected | recovered | ...
    step: int
    detail: Dict[str, Any] = field(default_factory=dict)


@dataclass
class SupervisorReport:
    """Outcome + metrics of one supervised run."""
    completed: bool
    final_step: int
    final_loss: float
    restarts: int
    steps_lost: int
    step_attempts: int
    productive_steps: int
    wall_seconds: float
    recovery_latencies_s: List[float]
    events: List[SupervisorEvent]

    @property
    def goodput_fraction(self) -> float:
        """Fraction of executed step attempts that advanced training."""
        return self.productive_steps / max(self.step_attempts, 1)

    def measured_timings(self) -> MigrationTimings:
        """Feed measured recovery latencies back into the cluster simulator.

        Maps the supervisor's observed flash-restore latency onto the sim's
        ``MigrationTimings`` so ``sim/cluster.py``'s failure model and the
        real system agree on recovery cost.
        """
        load = (float(np.mean(self.recovery_latencies_s))
                if self.recovery_latencies_s else
                MigrationTimings.flash_ckpt_load_s)
        return MigrationTimings(flash_ckpt_load_s=max(load, 1e-3))


class Supervisor:
    """Watchdog + recovery driver around a ``DLRMJob``.

    Detection: a per-step deadline (hang), EWMA step-time outliers
    (straggler), and typed ``FaultError``s surfacing from the hooks
    (PS loss, OOM). Recovery: restore from the newest valid flash
    checkpoint with exponential backoff + jitter under a capped restart
    budget; PS loss additionally shrinks the padded layout onto the
    surviving shard count; repeated OOM walks the degradation ladder.
    """

    def __init__(self, job: DLRMJob, config: Optional[SupervisorConfig] = None,
                 *, injector: Optional[FaultInjector] = None):
        self.job = job
        self.cfg = config or SupervisorConfig()
        self.injector = injector if injector is not None else job.injector
        self.job.injector = self.injector
        self.events: List[SupervisorEvent] = []
        self.restarts = 0
        self._consecutive_failures = 0
        self._rng = np.random.default_rng(self.cfg.seed)
        self._ewma: Optional[float] = None
        self._ewma_n = 0
        self.recovery_latencies: List[float] = []
        self.steps_lost = 0
        self.step_attempts = 0
        self._pool = ThreadPoolExecutor(max_workers=1)

    # ------------------------------------------------------------------ log
    def _event(self, kind: str, step: int, **detail) -> SupervisorEvent:
        ev = SupervisorEvent(time.time(), kind, int(step), detail)
        self.events.append(ev)
        return ev

    def write_event_log(self, path: str,
                        report: Optional[SupervisorReport] = None) -> None:
        """Dump the structured event log as JSONL (one event per line); a
        final ``summary`` line carries the report's metrics."""
        with open(path, "w") as f:
            for ev in self.events:
                f.write(json.dumps(asdict(ev)) + "\n")
            if report is not None:
                f.write(json.dumps({
                    "kind": "summary", "completed": report.completed,
                    "final_step": report.final_step,
                    "final_loss": report.final_loss,
                    "restarts": report.restarts,
                    "steps_lost": report.steps_lost,
                    "goodput_fraction": report.goodput_fraction,
                    "recovery_latency_mean_s": float(np.mean(
                        report.recovery_latencies_s))
                    if report.recovery_latencies_s else 0.0,
                    "wall_seconds": report.wall_seconds}) + "\n")

    # ------------------------------------------------------------- attempts
    def _attempt(self, gstep: int, generation: int,
                 cancel: threading.Event) -> Dict[str, Any]:
        if cancel.is_set():
            raise AttemptAbandoned(f"step {gstep} cancelled")
        return self.job.run_step(generation, cancel)

    def _backoff(self) -> float:
        d = min(self.cfg.backoff_base_s * 2 ** max(
            self._consecutive_failures - 1, 0), self.cfg.backoff_cap_s)
        d *= 1.0 + self.cfg.backoff_jitter * float(self._rng.uniform(-1, 1))
        return max(d, 0.0)

    def _recover(self, cause: str, at_step: int, *,
                 onto_n_ps: Optional[int] = None,
                 degrade: bool = False) -> None:
        self.restarts += 1
        self._consecutive_failures += 1
        if self.restarts > self.cfg.max_restarts:
            self._event("restart_budget_exceeded", at_step, cause=cause,
                        restarts=self.restarts,
                        budget=self.cfg.max_restarts)
            raise RestartBudgetExceeded(
                f"{self.restarts - 1} restarts exhausted the budget of "
                f"{self.cfg.max_restarts} (last cause: {cause})")
        delay = self._backoff()
        time.sleep(delay)
        t0 = time.perf_counter()
        detail: Dict[str, Any] = {"cause": cause, "backoff_s": round(delay, 4)}
        if degrade:
            detail["action"] = self.job.degrade()
            restored = self.job.global_step     # state intact: retry in place
        else:
            restored = self.job.restore(onto_n_ps=onto_n_ps)
            detail["action"] = ("elastic_shrink" if onto_n_ps is not None
                                else "restore")
            if onto_n_ps is not None:
                detail["surviving_n_ps"] = onto_n_ps
        latency = time.perf_counter() - t0
        lost = max(at_step - restored, 0)
        self.steps_lost += lost
        self.recovery_latencies.append(latency)
        self._event("recovered", restored, recovery_latency_s=round(latency, 4),
                    steps_lost=lost, **detail)

    # ------------------------------------------------------------------ run
    def run(self, total_steps: int, *, resume: bool = True) -> SupervisorReport:
        """Supervise the job until ``total_steps`` global steps completed.

        Raises ``RestartBudgetExceeded`` when recovery stops making
        progress; any other exception propagates (the supervisor only
        swallows *typed* faults it knows how to heal).
        """
        t_start = time.perf_counter()
        start_step = self.job.start(resume=resume)
        if start_step:
            self._event("resumed", start_step)
        last_loss = float("nan")
        try:
            while self.job.global_step < total_steps:
                gstep = self.job.global_step
                generation = self.job.generation
                cancel = threading.Event()
                self.step_attempts += 1
                t0 = time.perf_counter()
                fut = self._pool.submit(self._attempt, gstep, generation,
                                        cancel)
                try:
                    m = fut.result(timeout=self.cfg.step_deadline_s)
                except FutureTimeout:
                    cancel.set()
                    self._event("fault_detected", gstep, fault="hang",
                                deadline_s=self.cfg.step_deadline_s)
                    # the abandoned attempt unwinds via AttemptAbandoned /
                    # the generation guard; a fresh worker serves recovery
                    self._pool.shutdown(wait=False)
                    self._pool = ThreadPoolExecutor(max_workers=1)
                    self._recover("hang", gstep)
                    continue
                except PSShardLoss as e:
                    self._event("fault_detected", gstep, fault="ps_loss",
                                n_lost=e.n_lost)
                    survivors = None
                    if self.job.layout is not None:
                        survivors = max(self.job.layout.n_ps - e.n_lost, 1)
                    self._recover("ps_loss", gstep, onto_n_ps=survivors)
                    continue
                except TransientOOM:
                    self._event("fault_detected", gstep, fault="oom")
                    self._recover("oom", gstep, degrade=True)
                    continue
                except AttemptAbandoned:
                    continue
                except FaultError as e:          # unknown typed fault: restore
                    self._event("fault_detected", gstep,
                                fault=type(e).__name__.lower())
                    self._recover(type(e).__name__, gstep)
                    continue
                dt = time.perf_counter() - t0
                self._consecutive_failures = 0
                last_loss = m["loss"]
                self._observe_step_time(gstep, dt)
        finally:
            self._pool.shutdown(wait=False)
        report = SupervisorReport(
            completed=True, final_step=self.job.global_step,
            final_loss=last_loss, restarts=self.restarts,
            steps_lost=self.steps_lost, step_attempts=self.step_attempts,
            productive_steps=self.job.global_step - start_step,
            wall_seconds=time.perf_counter() - t_start,
            recovery_latencies_s=list(self.recovery_latencies),
            events=list(self.events))
        return report

    def _observe_step_time(self, gstep: int, dt: float) -> None:
        """EWMA straggler detection over completed-step wall times."""
        if self._ewma is None:
            self._ewma = dt
        self._ewma_n += 1
        warm = self._ewma_n > self.cfg.ewma_warmup_steps
        if warm and dt > self.cfg.straggler_factor * self._ewma:
            self._event("straggler_detected", gstep,
                        step_time_s=round(dt, 4),
                        ewma_s=round(self._ewma, 4),
                        factor=round(dt / self._ewma, 2))
            # fold a clipped sample so one outlier can't poison the baseline
            dt = self.cfg.straggler_factor * self._ewma
        a = self.cfg.ewma_alpha
        self._ewma = a * dt + (1 - a) * self._ewma
