"""Worker-process entrypoint spawned (and re-exec'd) by the job master.

One incarnation of one worker: build the reduced DLRM job, resume from the
newest valid layout-stamped checkpoint in ``--ckpt-dir`` (fresh init when
none), then train to ``--steps`` global steps, publishing a heartbeat file
after every step and appending each step's loss to a shared JSONL log.

Bit-exactness across kills is inherited, not re-implemented: batches are a
pure function of the global step (``DLRMJob``), checkpoints are layout-
stamped and checksum-verified (``FlashCheckpoint`` + ``resume_dlrm_stamped``),
so incarnation *k* replaying steps the dead incarnation already ran recomputes
byte-identical losses — the kill-matrix suite (``tests/test_chaos_proc.py``)
asserts the merged loss log equals a never-killed run's to the ulp.

``--chaos-proc`` scripts this process's own death
(``repro.core.faults.ProcessFaultInjector``): SIGKILL before a scheduled
step, SIGSTOP (the master's heartbeat deadline must catch it), or SIGKILL
inside the checkpoint pre-commit window. ``--incarnation`` (supplied by the
master) gates which specs fire, so a re-exec'd worker does not re-die
unless the plan says so (``kill_loop``).

Invoked as ``python -m repro.train.worker_main`` — heavy imports happen
*after* the first "boot" heartbeat so the master can tell "booting" from
"dead" immediately.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import List, Optional


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(prog="repro.train.worker_main")
    ap.add_argument("--arch", default="wide_deep")
    ap.add_argument("--steps", type=int, required=True,
                    help="train until this many global steps completed")
    ap.add_argument("--ckpt-dir", required=True)
    ap.add_argument("--ckpt-every", type=int, default=3)
    ap.add_argument("--n-ps", type=int, default=4)
    ap.add_argument("--padded", action="store_true")
    ap.add_argument("--optimizer", default="adagrad")
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--init-seed", type=int, default=0)
    ap.add_argument("--data-seed", type=int, default=11)
    ap.add_argument("--heartbeat", required=True,
                    help="heartbeat JSON path (atomically replaced per step)")
    ap.add_argument("--losses", required=True,
                    help="append-only JSONL of {incarnation, step, loss}")
    ap.add_argument("--fault-log", default=None,
                    help="append-only JSONL of fired process faults")
    ap.add_argument("--chaos-proc", default="",
                    help="process-level fault plan (kill/stop/kill_ckpt/"
                         "kill_loop specs; see repro.core.faults)")
    ap.add_argument("--incarnation", type=int, default=0,
                    help="0 for the first exec; +1 per job-master re-exec")
    args = ap.parse_args(argv)

    # publish liveness before the heavy imports/JIT: the master's spawn
    # grace (not its per-step deadline) covers everything until "ready"
    from repro.train.job_master import write_heartbeat
    pid = os.getpid()

    def beat(step: int, phase: str, restore_s: float = 0.0) -> None:
        write_heartbeat(args.heartbeat, pid=pid,
                        incarnation=args.incarnation, step=step,
                        phase=phase, restore_s=restore_s)

    beat(-1, "boot")

    from repro.configs.dlrm_models import reduced_dlrm
    from repro.configs.registry import get_dlrm
    from repro.core.faults import ProcessFaultInjector, parse_chaos_spec
    from repro.core.flash_checkpoint import FlashCheckpoint
    from repro.train.supervisor import DLRMJob

    cfg = reduced_dlrm(get_dlrm(args.arch))
    injector = ProcessFaultInjector(
        parse_chaos_spec(args.chaos_proc), incarnation=args.incarnation,
        log_path=args.fault_log)
    ckpt = FlashCheckpoint(
        args.ckpt_dir, async_persist=False,  # sync: every blob restorable
        pre_commit_hook=injector.on_pre_commit)
    job = DLRMJob(cfg, ckpt, opt_name=args.optimizer, lr=args.lr,
                  init_seed=args.init_seed, data_seed=args.data_seed,
                  ckpt_every=args.ckpt_every, n_ps=args.n_ps,
                  padded=args.padded)
    t0 = time.perf_counter()
    start_step = job.start(resume=True)      # newest valid stamped blob
    # every later beat re-publishes restore_s: steps can outpace the master's
    # poll, so the "ready" beat alone would often be replaced before it's read
    restore_s = time.perf_counter() - t0
    beat(start_step, "ready", restore_s=restore_s)

    with open(args.losses, "a") as losses:
        while job.global_step < args.steps:
            injector.before_step(job.global_step)   # may SIGKILL/SIGSTOP here
            m = job.run_step()
            losses.write(json.dumps({
                "incarnation": args.incarnation, "step": m["step"],
                "loss": m["loss"]}) + "\n")
            losses.flush()
            beat(job.global_step, "step", restore_s=restore_s)
    job.save()                               # final blob on the way out
    ckpt.wait()
    beat(job.global_step, "done", restore_s=restore_s)
    return 0


if __name__ == "__main__":
    sys.exit(main())
