"""Elastic re-meshing: resume a job on a different device mesh or row layout.

TPU analog of the paper's horizontal scaling: the flash-checkpoint stores
mesh-agnostic host arrays; this module rebuilds shardings for the *new* mesh
(via the logical-axis policy) and device_puts the restored state — i.e. a
seamless worker/PS count change without re-partitioning logic in user code.

``resume_dlrm_on_mesh`` is the same substrate for the paper's own DLRM
workloads, with one extra degree of freedom: an optional ``ReplanDecision``
from the live re-planning loop, applied as a bit-exact pooled-row
permutation after restore — so a checkpoint written under the OLD placement
plan resumes under the NEW one (see ``repro.train.replan``).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax

from repro.configs.base import ShapeConfig
from repro.configs.dlrm_models import DLRMConfig
from repro.core.flash_checkpoint import FlashCheckpoint
from repro.models.registry import ModelAPI
from repro.sharding.policy import (
    ShardingPolicy, logical_spec, make_dlrm_policy, make_policy,
)
from repro.train import trainer as trainer_mod
from repro.train.optim import Optimizer


def state_shardings(api: ModelAPI, opt_name: str, policy: ShardingPolicy):
    """NamedShardings for the full train state under a policy."""
    specs = trainer_mod.train_state_specs(api, opt_name)
    return logical_spec(None, specs, policy)


def save_for_elasticity(ckpt: FlashCheckpoint, state, step: int) -> None:
    ckpt.save(state, step)


def resume_on_mesh(api: ModelAPI, optimizer: Optimizer, opt_name: str,
                   ckpt: FlashCheckpoint, mesh, shape: ShapeConfig,
                   *, step: Optional[int] = None) -> Tuple[Dict[str, Any], int, ShardingPolicy]:
    """Restore the latest checkpoint onto a (possibly different) mesh."""
    policy = make_policy(mesh, api.cfg, shape)
    like = jax.eval_shape(
        lambda k: trainer_mod.make_train_state(api, optimizer, k),
        jax.random.PRNGKey(0))
    shardings = state_shardings(api, opt_name, policy) if mesh is not None else None
    state, restored_step = ckpt.restore(like, step, shardings=shardings)
    return state, restored_step, policy


# --- DLRM (paper workloads) -------------------------------------------------
def dlrm_state_shardings(cfg: DLRMConfig, opt_name: str,
                         policy: ShardingPolicy):
    """NamedShardings for the full DLRM train state under a policy."""
    specs = trainer_mod.dlrm_train_state_specs(cfg, opt_name)
    return logical_spec(None, specs, policy)


def resume_dlrm_on_mesh(cfg: DLRMConfig, optimizer: Optimizer, opt_name: str,
                        ckpt: FlashCheckpoint, mesh, *,
                        decision=None, step: Optional[int] = None
                        ) -> Tuple[Dict[str, Any], int, ShardingPolicy]:
    """Restore a DLRM checkpoint onto a mesh and (optionally) a new row plan.

    Args:
      cfg, optimizer, opt_name: the job being resumed.
      ckpt:     flash-checkpoint holding mesh-agnostic host arrays.
      mesh:     target mesh (None = single host).
      decision: optional ``ReplanDecision``; its permutation is applied to
                the restored pooled rows (bit-exact) and its balanced
                ``vocab_ranges`` ride on the returned policy.
      step:     checkpoint step (None = latest).

    Returns ``(state, restored_step, policy)``; the caller recompiles its
    train step with ``table_hot=decision.table_hot`` to finish the re-plan.
    """
    ranges = None if decision is None else decision.vocab_ranges
    policy = make_dlrm_policy(mesh, vocab_ranges=ranges)
    like = jax.eval_shape(
        lambda k: trainer_mod.make_dlrm_train_state(cfg, optimizer, k),
        jax.random.PRNGKey(0))
    shardings = dlrm_state_shardings(cfg, opt_name, policy) \
        if mesh is not None else None
    state, restored_step = ckpt.restore(like, step, shardings=shardings)
    if decision is not None:
        from repro.train.replan import permute_train_state
        state = permute_train_state(state, cfg.total_embedding_rows,
                                    decision.permutation)
    return state, restored_step, policy
