"""Elastic re-meshing: resume a job on a different device mesh or row layout.

TPU analog of the paper's horizontal scaling: the flash-checkpoint stores
mesh-agnostic host arrays; this module rebuilds shardings for the *new* mesh
(via the logical-axis policy) and device_puts the restored state — i.e. a
seamless worker/PS count change without re-partitioning logic in user code.

``resume_dlrm_on_mesh`` is the same substrate for the paper's own DLRM
workloads, with two extra degrees of freedom: an optional ``ReplanDecision``
from the live re-planning loop, applied as a bit-exact pooled-row
permutation after restore — so a checkpoint written under the OLD placement
plan resumes under the NEW one (see ``repro.train.replan``) — and optional
``from_layout``/``layout`` padded physical layouts, so a job checkpointed
with ``n_ps`` physically-unequal PS shards resumes onto a different shard
count (or back to the flat pool) bit-exactly.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax

from repro.configs.base import ShapeConfig
from repro.configs.dlrm_models import DLRMConfig
from repro.core.flash_checkpoint import FlashCheckpoint
from repro.models.registry import ModelAPI
from repro.sharding.policy import (
    ShardingPolicy, logical_spec, make_dlrm_policy, make_policy,
)
from repro.train import trainer as trainer_mod
from repro.train.optim import Optimizer


def state_shardings(api: ModelAPI, opt_name: str, policy: ShardingPolicy):
    """NamedShardings for the full train state under a policy."""
    specs = trainer_mod.train_state_specs(api, opt_name)
    return logical_spec(None, specs, policy)


def save_for_elasticity(ckpt: FlashCheckpoint, state, step: int) -> None:
    ckpt.save(state, step)


def resume_on_mesh(api: ModelAPI, optimizer: Optimizer, opt_name: str,
                   ckpt: FlashCheckpoint, mesh, shape: ShapeConfig,
                   *, step: Optional[int] = None) -> Tuple[Dict[str, Any], int, ShardingPolicy]:
    """Restore the latest checkpoint onto a (possibly different) mesh."""
    policy = make_policy(mesh, api.cfg, shape)
    like = jax.eval_shape(
        lambda k: trainer_mod.make_train_state(api, optimizer, k),
        jax.random.PRNGKey(0))
    shardings = state_shardings(api, opt_name, policy) if mesh is not None else None
    state, restored_step = ckpt.restore(like, step, shardings=shardings)
    return state, restored_step, policy


# --- DLRM (paper workloads) -------------------------------------------------
def dlrm_state_shardings(cfg: DLRMConfig, opt_name: str,
                         policy: ShardingPolicy, layout=None):
    """NamedShardings for the full DLRM train state under a policy.

    ``layout`` (a ``PaddedLayout``) switches the pooled-store specs to the
    padded ``(n_ps, max_range, ...)`` form, whose leading axis the "vocab"
    rule splits equally — one balanced range per PS device.
    """
    specs = trainer_mod.dlrm_train_state_specs(cfg, opt_name, layout=layout)
    return logical_spec(None, specs, policy)


def resume_dlrm_on_mesh(cfg: DLRMConfig, optimizer: Optimizer, opt_name: str,
                        ckpt: FlashCheckpoint, mesh, *,
                        decision=None, step: Optional[int] = None,
                        from_layout=None, layout=None
                        ) -> Tuple[Dict[str, Any], int, ShardingPolicy]:
    """Restore a DLRM checkpoint onto a mesh and (optionally) a new row plan.

    The layout degrees of freedom make this the "resume onto a different
    PS count" path for physically-padded jobs: a blob saved padded on
    ``from_layout`` (say 4 shards) restores bit-exactly onto ``layout``
    (say 2 shards, or flat) — the checkpointed rows are re-based through
    the canonical flat space, so any (from_layout, layout) pair composes,
    including with a ``ReplanDecision`` permutation in between.

    Args:
      cfg, optimizer, opt_name: the job being resumed.
      ckpt:     flash-checkpoint holding mesh-agnostic host arrays.
      mesh:     target mesh (None = single host).
      decision: optional ``ReplanDecision``; its permutation is applied to
                the restored pooled rows (bit-exact) and its balanced
                ``vocab_ranges`` ride on the returned policy.
      step:     checkpoint step (None = latest).
      from_layout: the ``PaddedLayout`` the blob was *saved* on (None =
                saved flat). Plain ``ckpt.save`` blobs store whatever layout
                the live state had, so the caller must say which.
      layout:   the ``PaddedLayout`` to resume *onto* (None = flat). The
                caller compiles its step with the same ``layout``.

    Returns ``(state, restored_step, policy)``; the caller recompiles its
    train step with ``table_hot=decision.table_hot`` (and ``layout``) to
    finish the re-plan.
    """
    from repro.train.replan import (pad_train_state, permute_train_state,
                                    unpad_train_state)
    R = cfg.total_embedding_rows
    ranges = None if decision is None else decision.vocab_ranges
    policy = make_dlrm_policy(mesh, vocab_ranges=ranges)
    like = jax.eval_shape(
        lambda k: trainer_mod.make_dlrm_train_state(cfg, optimizer, k,
                                                    layout=from_layout),
        jax.random.PRNGKey(0))
    state, restored_step = ckpt.restore(like, step)
    if from_layout is not None:
        state = unpad_train_state(state, R, from_layout)
    if decision is not None:
        state = permute_train_state(state, R, decision.permutation)
    if layout is not None:
        state = pad_train_state(state, R, layout)
    if mesh is not None:
        state = jax.device_put(
            state, dlrm_state_shardings(cfg, opt_name, policy, layout=layout))
    return state, restored_step, policy


def resume_dlrm_stamped(cfg: DLRMConfig, optimizer: Optimizer,
                        ckpt: FlashCheckpoint, *,
                        onto_n_ps: Optional[int] = None, mesh=None,
                        opt_name: str = "adagrad", step: Optional[int] = None):
    """Elastic re-resume of a *layout-stamped* blob, e.g. after a PS loss.

    The stamped-blob analog of ``resume_dlrm_on_mesh(from_layout=, layout=)``:
    the blob's own ``padded_n_ps`` stamp plays the ``from_layout`` role, and
    ``onto_n_ps`` — the *surviving* shard count after a PS-shard loss — the
    ``layout`` role. Checkpoints store the canonical flat row order, so a
    job padded on N shards re-resumes bit-exactly onto any smaller (or
    larger) shard count; the supervisor's ``PSShardLoss`` recovery is this
    call with ``onto_n_ps = n_ps - n_lost``.

    The shrunk placement is the uniform plan over the survivors — the live
    re-planning loop re-balances it from real counts at its next trigger.

    Args:
      cfg, optimizer: the job being resumed.
      ckpt:      flash checkpoint holding ``save_with_layout`` blobs.
      onto_n_ps: surviving PS shard count (None = keep the stamped layout;
                 ignored for flat jobs, which have no physical shards).
      mesh:      optional target mesh for re-placement.
      opt_name:  optimizer name for sharding specs when a mesh is given.
      step:      checkpoint step (None = newest valid).

    Returns ``(state, restored_step, remapper, table_hot, vocab_ranges,
    layout)`` exactly like ``replan.restore_with_layout``, with ``state``
    padded onto (and ``layout``/``vocab_ranges`` describing) the surviving
    shard count.
    """
    from repro.sharding.policy import (padded_layout_for_ranges,
                                       uniform_vocab_ranges)
    from repro.train import replan as replan_mod
    R = cfg.total_embedding_rows
    state, restored_step, remapper, table_hot, vocab_ranges, layout = \
        replan_mod.restore_with_layout(cfg, optimizer, ckpt, step=step)
    if onto_n_ps is not None and layout is not None and \
            onto_n_ps != layout.n_ps:
        state = replan_mod.unpad_train_state(state, R, layout)
        ranges = uniform_vocab_ranges(R, onto_n_ps)
        layout = padded_layout_for_ranges(ranges)
        state = replan_mod.pad_train_state(state, R, layout)
        vocab_ranges = tuple((int(s), int(e)) for s, e in ranges)
    if mesh is not None:
        policy = make_dlrm_policy(mesh, vocab_ranges=vocab_ranges)
        state = jax.device_put(
            state, dlrm_state_shardings(cfg, opt_name, policy, layout=layout))
    return state, restored_step, remapper, table_hot, vocab_ranges, layout
