"""Elastic re-meshing: resume a job on a different device mesh.

TPU analog of the paper's horizontal scaling: the flash-checkpoint stores
mesh-agnostic host arrays; this module rebuilds shardings for the *new* mesh
(via the logical-axis policy) and device_puts the restored state — i.e. a
seamless worker/PS count change without re-partitioning logic in user code.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax

from repro.configs.base import ShapeConfig
from repro.core.flash_checkpoint import FlashCheckpoint
from repro.models.registry import ModelAPI
from repro.sharding.policy import ShardingPolicy, logical_spec, make_policy
from repro.train import trainer as trainer_mod
from repro.train.optim import Optimizer


def state_shardings(api: ModelAPI, opt_name: str, policy: ShardingPolicy):
    """NamedShardings for the full train state under a policy."""
    specs = trainer_mod.train_state_specs(api, opt_name)
    return logical_spec(None, specs, policy)


def save_for_elasticity(ckpt: FlashCheckpoint, state, step: int) -> None:
    ckpt.save(state, step)


def resume_on_mesh(api: ModelAPI, optimizer: Optimizer, opt_name: str,
                   ckpt: FlashCheckpoint, mesh, shape: ShapeConfig,
                   *, step: Optional[int] = None) -> Tuple[Dict[str, Any], int, ShardingPolicy]:
    """Restore the latest checkpoint onto a (possibly different) mesh."""
    policy = make_policy(mesh, api.cfg, shape)
    like = jax.eval_shape(
        lambda k: trainer_mod.make_train_state(api, optimizer, k),
        jax.random.PRNGKey(0))
    shardings = state_shardings(api, opt_name, policy) if mesh is not None else None
    state, restored_step = ckpt.restore(like, step, shardings=shardings)
    return state, restored_step, policy
