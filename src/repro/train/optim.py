"""Minimal sharded optimizers (adam/adamw/adagrad/sgd) as pure pytree transforms.

Optimizer state mirrors the parameter sharding (ZeRO-style: the state inherits
the param PartitionSpec, so Adam moments are sharded over data+model axes).
Includes global-norm clipping and optional bf16 gradient compression — the
paper's "communication-efficient sync" analog (Gupta et al. [20] in §7) — to
halve cross-pod all-reduce bytes.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    """(init, update) pytree transform + the optional sparse row seam.

    ``update_rows(rows, row_grads, state, params)``, when present, applies
    the row-wise update of this optimizer to exactly the given rows of one
    pooled (R, D) parameter leaf: ``rows`` are deduplicated store rows
    (entries ``>= R`` are padding and ignored), ``row_grads`` the matching
    accumulated gradient rows, ``state`` the per-leaf slice of the optimizer
    state (moment pools in the same row space, plus shared scalars such as
    ``count``). Returns ``(new_params, new_leaf_state)`` where
    ``new_leaf_state`` holds only the per-leaf moment arrays — shared
    scalars are advanced by the dense-side ``update``. Duplicated rows are
    a contract violation (the fused backward dedupes); clipping is the
    caller's job (``clip_norm`` advertises this optimizer's default so the
    trainer can clip the joint dense+sparse tree once).
    """
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Tuple[Any, Any]]  # (grads, state, params)
    update_rows: Optional[Callable[[Any, Any, Any, Any], Tuple[Any, Any]]] = None
    clip_norm: Optional[float] = None


class SparseRowGrad(NamedTuple):
    """COO gradient leaf for a pooled (R, D) parameter: rows + values.

    ``rows`` (N,) int32 deduplicated store rows (entries equal to the pool's
    row count are padding produced by the static-shape dedupe and carry zero
    values); ``vals`` (N, D) f32 accumulated cotangents. A NamedTuple is a
    pytree node, so a grad tree may hold these leaves in place of dense
    arrays — ``global_norm``/``clip_by_global_norm``/``compress_grads``
    skip the integer ``rows`` child via their inexact-dtype guard.
    """
    rows: Any
    vals: Any

    def to_dense(self, num_rows: int) -> jnp.ndarray:
        """Scatter-add back to the dense (R, D) gradient (reference oracle).

        Rows ``>= num_rows`` are dropped by JAX's out-of-bounds scatter
        semantics — exactly the padding contract.
        """
        D = self.vals.shape[-1]
        return jnp.zeros((num_rows, D), self.vals.dtype).at[self.rows].add(
            self.vals)


def _tree_zeros_like(params, dtype=None):
    return jax.tree.map(lambda p: jnp.zeros_like(p, dtype=dtype or p.dtype), params)


def _inexact(x) -> bool:
    return jnp.issubdtype(jnp.asarray(x).dtype, jnp.inexact)


def global_norm(tree) -> jnp.ndarray:
    """L2 norm over every inexact leaf (int leaves — e.g. ``SparseRowGrad``
    rows or step counters — carry no gradient mass and are skipped)."""
    leaves = [l for l in jax.tree.leaves(tree) if _inexact(l)]
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(
        lambda g: g * scale.astype(g.dtype) if _inexact(g) else g, grads), norm


def compress_grads(grads, dtype=jnp.bfloat16):
    """Cast-compress gradients (halves all-reduce bytes; lossy in mantissa).

    Integer leaves (sparse row ids) are addressing, not gradient payload —
    they pass through untouched.
    """
    return jax.tree.map(
        lambda g: g.astype(dtype).astype(g.dtype) if _inexact(g) else g, grads)


# ---------------------------------------------------------------------------
def adam(lr: float, *, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0, clip_norm: Optional[float] = 1.0,
         master_weights: bool = False) -> Optimizer:
    """Adam with f32 moments; optional f32 master copy for bf16 params.

    With ``master_weights=True`` (production mixed precision: bf16 params in
    the forward/backward — halving FSDP all-gather and grad all-reduce bytes
    — while updates accumulate in an f32 master kept sharded in opt state).
    """
    def init(params):
        state = {"m": _tree_zeros_like(params, jnp.float32),
                 "v": _tree_zeros_like(params, jnp.float32),
                 "count": jnp.zeros((), jnp.int32)}
        if master_weights:
            state["master"] = jax.tree.map(
                lambda p: p.astype(jnp.float32), params)
        return state

    def update(grads, state, params):
        if clip_norm is not None:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        count = state["count"] + 1
        tc = count.astype(jnp.float32)
        m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                         state["v"], grads)
        mh = jax.tree.map(lambda m: m / (1 - b1 ** tc), m)
        vh = jax.tree.map(lambda v: v / (1 - b2 ** tc), v)
        new_state = {"m": m, "v": v, "count": count}
        if master_weights:
            ref = state["master"]
            new_master = jax.tree.map(
                lambda mh, vh, w: w - lr * (mh / (jnp.sqrt(vh) + eps)
                                            + weight_decay * w),
                mh, vh, ref)
            new_state["master"] = new_master
            updates = jax.tree.map(
                lambda nm, p: nm.astype(p.dtype) - p, new_master, params)
        else:
            updates = jax.tree.map(
                lambda mh, vh, p: (-lr * (mh / (jnp.sqrt(vh) + eps)
                                          + weight_decay * p.astype(jnp.float32))
                                   ).astype(p.dtype),
                mh, vh, params)
        return updates, new_state

    def update_rows(rows, row_grads, state, params):
        # lazy (row-wise) adam: moments of untouched rows are NOT decayed —
        # the standard sparse-adam semantics; bias correction uses the
        # shared step count the dense-side update advances
        from repro.kernels import ops as kernel_ops
        tc = (state["count"] + 1).astype(jnp.float32)
        new_params, new_m, new_v = kernel_ops.fused_row_update(
            params, rows, row_grads, state["m"], state["v"], kind="adam",
            lr=lr, b1=b1, b2=b2, eps=eps, count=tc,
            weight_decay=weight_decay)
        return new_params, {"m": new_m, "v": new_v}

    return Optimizer(init, update,
                     update_rows=None if master_weights else update_rows,
                     clip_norm=clip_norm)


def adamw(lr: float, *, weight_decay: float = 0.01, **kw) -> Optimizer:
    return adam(lr, weight_decay=weight_decay, **kw)


def adagrad(lr: float, *, eps: float = 1e-10,
            clip_norm: Optional[float] = None) -> Optimizer:
    """The classic DLRM optimizer (sparse-friendly per-coordinate scaling)."""
    def init(params):
        return {"acc": _tree_zeros_like(params, jnp.float32)}

    def update(grads, state, params):
        if clip_norm is not None:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        acc = jax.tree.map(lambda a, g: a + jnp.square(g.astype(jnp.float32)),
                           state["acc"], grads)
        updates = jax.tree.map(
            lambda g, a, p: (-lr * g.astype(jnp.float32)
                             / (jnp.sqrt(a) + eps)).astype(p.dtype),
            grads, acc, params)
        return updates, {"acc": acc}

    def update_rows(rows, row_grads, state, params):
        # row-wise adagrad is bit-exact vs the dense path: untouched rows
        # see g == 0, so their accumulator and params are exact no-ops
        from repro.kernels import ops as kernel_ops
        new_params, new_acc = kernel_ops.fused_row_update(
            params, rows, row_grads, state["acc"], kind="adagrad",
            lr=lr, eps=eps)
        return new_params, {"acc": new_acc}

    return Optimizer(init, update, update_rows=update_rows,
                     clip_norm=clip_norm)


def sgd(lr: float, *, momentum: float = 0.0,
        clip_norm: Optional[float] = None) -> Optimizer:
    def init(params):
        if momentum:
            return {"mom": _tree_zeros_like(params, jnp.float32)}
        return {}

    def update(grads, state, params):
        if clip_norm is not None:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        if momentum:
            mom = jax.tree.map(lambda m, g: momentum * m + g.astype(jnp.float32),
                               state["mom"], grads)
            updates = jax.tree.map(lambda m, p: (-lr * m).astype(p.dtype), mom, params)
            return updates, {"mom": mom}
        updates = jax.tree.map(lambda g, p: (-lr * g).astype(p.dtype), grads, params)
        return updates, state

    return Optimizer(init, update, clip_norm=clip_norm)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u, params, updates)


def make(name: str, lr: float, **kw) -> Optimizer:
    return {"adam": adam, "adamw": adamw, "adagrad": adagrad, "sgd": sgd}[name](lr, **kw)


def state_specs(opt_name: str, param_specs):
    """Logical-axis specs for optimizer state (mirrors param sharding)."""
    is_spec = lambda x: isinstance(x, tuple) and all(
        isinstance(i, (str, type(None))) for i in x)
    mirror = lambda: jax.tree.map(lambda s: s, param_specs, is_leaf=is_spec)
    if opt_name in ("adam_master", "adamw_master"):
        return {"m": mirror(), "v": mirror(), "count": (), "master": mirror()}
    if opt_name in ("adam", "adamw"):
        return {"m": mirror(), "v": mirror(), "count": ()}
    if opt_name == "adagrad":
        return {"acc": mirror()}
    if opt_name == "sgd":
        return {}
    raise ValueError(opt_name)
