"""Train-step construction: loss+grad+optimizer under pjit with logical sharding.

``make_train_step`` builds the jit-able step for any ModelAPI (LM families,
whisper) — this is what the launcher runs and what the multi-pod dry-run
lowers. ``make_dlrm_train_step`` is the analogous step for the paper's own
DLRM workloads. Distributed-optimization knobs:

* ``remat``            — activation checkpointing over pattern groups
* ``grad_compress``    — bf16-cast gradients before the cross-replica
                          all-reduce (halves DP sync bytes; §7 [20] analog)
* sharded optimizer state (ZeRO) via ``optim.state_specs``
"""
from __future__ import annotations

from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.configs.dlrm_models import DLRMConfig
from repro.models.dlrm import dlrm_loss
from repro.models.registry import ModelAPI
from repro.train import optim as optim_mod
from repro.train.optim import Optimizer


def make_train_state(api: ModelAPI, optimizer: Optimizer, key) -> Dict[str, Any]:
    params = api.init(key)
    return {"params": params, "opt": optimizer.init(params),
            "step": jnp.zeros((), jnp.int32)}


def train_state_specs(api: ModelAPI, opt_name: str) -> Dict[str, Any]:
    pspecs = api.param_specs()
    return {"params": pspecs, "opt": optim_mod.state_specs(opt_name, pspecs),
            "step": ()}


def make_train_step(api: ModelAPI, optimizer: Optimizer, *,
                    remat: bool = True,
                    grad_compress: bool = False) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics)."""

    def train_step(state, batch):
        def loss_fn(params):
            return api.loss(params, batch, remat=remat)

        loss, grads = jax.value_and_grad(loss_fn)(state["params"])
        if grad_compress:
            grads = optim_mod.compress_grads(grads)
        gnorm = optim_mod.global_norm(grads)
        updates, opt_state = optimizer.update(grads, state["opt"], state["params"])
        params = optim_mod.apply_updates(state["params"], updates)
        new_state = {"params": params, "opt": opt_state, "step": state["step"] + 1}
        return new_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def make_eval_step(api: ModelAPI) -> Callable:
    def eval_step(state, batch):
        return api.loss(state["params"], batch, remat=False)
    return eval_step


def with_step_hooks(step_fn: Callable, *,
                    before: Callable = None, after: Callable = None) -> Callable:
    """Wrap a compiled train step with host-side hooks.

    ``before(state, batch)`` runs on the host immediately before dispatching
    the step — this is the trainer-layer seam the fault injector
    (``repro.core.faults.FaultInjector.before_step``) fires through, so
    scripted crashes/stalls happen exactly where the step executes;
    ``after(new_state, metrics)`` runs once the step returns. Apply to the
    *jitted* callable: the hooks stay outside the traced computation and
    run on every invocation (not once at trace time).
    """
    def wrapped(state, batch):
        if before is not None:
            before(state, batch)
        new_state, metrics = step_fn(state, batch)
        if after is not None:
            after(new_state, metrics)
        return new_state, metrics

    return wrapped


# --- DLRM ---------------------------------------------------------------------
def make_dlrm_train_state(cfg: DLRMConfig, optimizer: Optimizer,
                          key, layout=None) -> Dict[str, Any]:
    """Fresh DLRM train state {params, opt, step} (shape source for restores).

    ``layout`` (a ``PaddedLayout``) builds the pooled stores — and their
    optimizer-state mirrors — on the padded physical layout; row values are
    bit-identical to the flat init from the same key.
    """
    from repro.models.dlrm import init_dlrm
    params = init_dlrm(cfg, key, layout=layout)
    return {"params": params, "opt": optimizer.init(params),
            "step": jnp.zeros((), jnp.int32)}


def dlrm_train_state_specs(cfg: DLRMConfig, opt_name: str,
                           layout=None) -> Dict[str, Any]:
    """Logical-axis spec tree mirroring ``make_dlrm_train_state``'s output."""
    from repro.models.dlrm import dlrm_param_specs
    pspecs = dlrm_param_specs(cfg, layout=layout)
    return {"params": pspecs, "opt": optim_mod.state_specs(opt_name, pspecs),
            "step": ()}


def make_dlrm_train_step(cfg: DLRMConfig, optimizer: Optimizer,
                         grad_compress: bool = False, *,
                         table_hot=None, layout=None, plan=None) -> Callable:
    """DLRM train step compiled against one ``EmbeddingPlan``.

    ``plan`` bakes every static knob of the fused embedding engine into the
    compiled step — the hot-row cache plan, the padded physical placement,
    and whether the step runs the fused sparse backward + row-wise
    optimizer update (``plan.sparse_update``, requires an optimizer with an
    ``update_rows`` seam; otherwise the dense path runs). The legacy
    ``table_hot``/``layout`` kwargs build the config's default plan. A live
    re-plan recompiles with a new plan.
    """
    if plan is None:
        plan = cfg.embedding_plan(table_hot=table_hot, layout=layout)
    if plan.sparse_update and optimizer.update_rows is not None:
        return _make_dlrm_sparse_step(cfg, optimizer, grad_compress, plan)

    def train_step(state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: dlrm_loss(p, batch, cfg, plan=plan))(state["params"])
        if grad_compress:
            grads = optim_mod.compress_grads(grads)
        gnorm = optim_mod.global_norm(grads)
        updates, opt_state = optimizer.update(grads, state["opt"], state["params"])
        params = optim_mod.apply_updates(state["params"], updates)
        new_state = {"params": params, "opt": opt_state, "step": state["step"] + 1}
        return new_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def _split_opt_state(opt_state, sparse_keys):
    """Split a dict-of-mirrors optimizer state at the pooled-store leaves.

    Entries mirroring the param tree (dicts containing every sparse key)
    are split into a dense remainder + one slice per pooled store; shared
    scalars (adam's ``count``) stay in the dense state AND ride along in
    every per-leaf slice, as ``Optimizer.update_rows`` expects.
    """
    dense_state, leaf_state = {}, {k: {} for k in sparse_keys}
    for name, sub in opt_state.items():
        if isinstance(sub, dict) and all(k in sub for k in sparse_keys):
            dense_state[name] = {k: v for k, v in sub.items()
                                 if k not in sparse_keys}
            for k in sparse_keys:
                leaf_state[k][name] = sub[k]
        else:
            dense_state[name] = sub
            for k in sparse_keys:
                leaf_state[k][name] = sub
    return dense_state, leaf_state


def _make_dlrm_sparse_step(cfg: DLRMConfig, optimizer: Optimizer,
                           grad_compress: bool, plan) -> Callable:
    """The fused sparse-update DLRM step (``plan.sparse_update=True``).

    Instead of materializing dense (R, D) gradients for the pooled stores
    and letting the optimizer touch every row, the step (a) differentiates
    only the dense interaction network via ``jax.vjp`` at the
    ``dlrm_embeddings`` seam, (b) turns each store's bag cotangent into
    deduped COO row grads (``ops.sparse_row_grads``, a ``SparseRowGrad``
    grad leaf), and (c) applies the row-wise optimizer update to exactly
    those rows (``Optimizer.update_rows`` → the fused row-update kernel,
    moments updated in place in the pool layout). Clipping happens once
    over the joint dense+sparse tree (``optimizer.clip_norm``), so the
    dense-subtree clip inside ``optimizer.update`` is an exact no-op.
    """
    from repro.kernels import ops as kernel_ops
    from repro.models import dlrm as dlrm_mod

    sparse_keys = dlrm_mod.sparse_param_keys(cfg)
    emb_of = {"tables": "deep", "wide": "wide"}
    plan_of = {"tables": plan, "wide": plan.with_combiner("sum")}

    def train_step(state, batch):
        params = state["params"]
        embs = dlrm_mod.dlrm_embeddings(params, batch, cfg, plan)
        dense_params = {k: v for k, v in params.items()
                        if k not in sparse_keys}
        loss, vjp = jax.vjp(
            lambda dp, e: dlrm_mod.dlrm_loss_from_embeddings(
                dp, batch, e, cfg),
            dense_params, embs)
        dense_grads, g_embs = vjp(jnp.ones((), loss.dtype))

        grads = dict(dense_grads)
        for k in sparse_keys:
            pool = dlrm_mod._pool2d(params[k], plan.layout)
            rows, vals, _ = kernel_ops.sparse_row_grads(
                pool, batch["sparse"], g_embs[emb_of[k]], plan=plan_of[k])
            grads[k] = optim_mod.SparseRowGrad(rows, vals)

        if grad_compress:
            grads = optim_mod.compress_grads(grads)
        gnorm = optim_mod.global_norm(grads)
        if optimizer.clip_norm is not None:
            grads, _ = optim_mod.clip_by_global_norm(grads,
                                                     optimizer.clip_norm)

        dense_state, leaf_state = _split_opt_state(state["opt"], sparse_keys)
        dense_only = {k: v for k, v in grads.items() if k not in sparse_keys}
        updates, new_dense_state = optimizer.update(
            dense_only, dense_state, dense_params)
        new_params = dict(optim_mod.apply_updates(dense_params, updates))
        new_opt = dict(new_dense_state)
        for k in sparse_keys:
            store = params[k]
            pool = dlrm_mod._pool2d(store, plan.layout)
            leaf = {name: (dlrm_mod._pool2d(arr, plan.layout)
                           if getattr(arr, "shape", None) == store.shape
                           else arr)
                    for name, arr in leaf_state[k].items()}
            new_pool, new_leaf = optimizer.update_rows(
                grads[k].rows, grads[k].vals, leaf, pool)
            new_params[k] = new_pool.reshape(store.shape)
            for name, arr in new_leaf.items():
                new_opt[name] = dict(new_opt[name])
                new_opt[name][k] = arr.reshape(leaf_state[k][name].shape)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        return new_state, {"loss": loss, "grad_norm": gnorm}

    return train_step
