"""Train-step construction: loss+grad+optimizer under pjit with logical sharding.

``make_train_step`` builds the jit-able step for any ModelAPI (LM families,
whisper) — this is what the launcher runs and what the multi-pod dry-run
lowers. ``make_dlrm_train_step`` is the analogous step for the paper's own
DLRM workloads. Distributed-optimization knobs:

* ``remat``            — activation checkpointing over pattern groups
* ``grad_compress``    — bf16-cast gradients before the cross-replica
                          all-reduce (halves DP sync bytes; §7 [20] analog)
* sharded optimizer state (ZeRO) via ``optim.state_specs``
"""
from __future__ import annotations

from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.configs.dlrm_models import DLRMConfig
from repro.models.dlrm import dlrm_loss
from repro.models.registry import ModelAPI
from repro.train import optim as optim_mod
from repro.train.optim import Optimizer


def make_train_state(api: ModelAPI, optimizer: Optimizer, key) -> Dict[str, Any]:
    params = api.init(key)
    return {"params": params, "opt": optimizer.init(params),
            "step": jnp.zeros((), jnp.int32)}


def train_state_specs(api: ModelAPI, opt_name: str) -> Dict[str, Any]:
    pspecs = api.param_specs()
    return {"params": pspecs, "opt": optim_mod.state_specs(opt_name, pspecs),
            "step": ()}


def make_train_step(api: ModelAPI, optimizer: Optimizer, *,
                    remat: bool = True,
                    grad_compress: bool = False) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics)."""

    def train_step(state, batch):
        def loss_fn(params):
            return api.loss(params, batch, remat=remat)

        loss, grads = jax.value_and_grad(loss_fn)(state["params"])
        if grad_compress:
            grads = optim_mod.compress_grads(grads)
        gnorm = optim_mod.global_norm(grads)
        updates, opt_state = optimizer.update(grads, state["opt"], state["params"])
        params = optim_mod.apply_updates(state["params"], updates)
        new_state = {"params": params, "opt": opt_state, "step": state["step"] + 1}
        return new_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def make_eval_step(api: ModelAPI) -> Callable:
    def eval_step(state, batch):
        return api.loss(state["params"], batch, remat=False)
    return eval_step


def with_step_hooks(step_fn: Callable, *,
                    before: Callable = None, after: Callable = None) -> Callable:
    """Wrap a compiled train step with host-side hooks.

    ``before(state, batch)`` runs on the host immediately before dispatching
    the step — this is the trainer-layer seam the fault injector
    (``repro.core.faults.FaultInjector.before_step``) fires through, so
    scripted crashes/stalls happen exactly where the step executes;
    ``after(new_state, metrics)`` runs once the step returns. Apply to the
    *jitted* callable: the hooks stay outside the traced computation and
    run on every invocation (not once at trace time).
    """
    def wrapped(state, batch):
        if before is not None:
            before(state, batch)
        new_state, metrics = step_fn(state, batch)
        if after is not None:
            after(new_state, metrics)
        return new_state, metrics

    return wrapped


# --- DLRM ---------------------------------------------------------------------
def make_dlrm_train_state(cfg: DLRMConfig, optimizer: Optimizer,
                          key, layout=None) -> Dict[str, Any]:
    """Fresh DLRM train state {params, opt, step} (shape source for restores).

    ``layout`` (a ``PaddedLayout``) builds the pooled stores — and their
    optimizer-state mirrors — on the padded physical layout; row values are
    bit-identical to the flat init from the same key.
    """
    from repro.models.dlrm import init_dlrm
    params = init_dlrm(cfg, key, layout=layout)
    return {"params": params, "opt": optimizer.init(params),
            "step": jnp.zeros((), jnp.int32)}


def dlrm_train_state_specs(cfg: DLRMConfig, opt_name: str,
                           layout=None) -> Dict[str, Any]:
    """Logical-axis spec tree mirroring ``make_dlrm_train_state``'s output."""
    from repro.models.dlrm import dlrm_param_specs
    pspecs = dlrm_param_specs(cfg, layout=layout)
    return {"params": pspecs, "opt": optim_mod.state_specs(opt_name, pspecs),
            "step": ()}


def make_dlrm_train_step(cfg: DLRMConfig, optimizer: Optimizer,
                         grad_compress: bool = False, *,
                         table_hot=None, layout=None) -> Callable:
    """DLRM train step; ``table_hot`` bakes a measured hot-row cache plan
    into the compiled step and ``layout`` the padded physical placement
    (a live re-plan recompiles with the new plans)."""
    def train_step(state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: dlrm_loss(p, batch, cfg, table_hot=table_hot,
                                layout=layout))(state["params"])
        if grad_compress:
            grads = optim_mod.compress_grads(grads)
        gnorm = optim_mod.global_norm(grads)
        updates, opt_state = optimizer.update(grads, state["opt"], state["params"])
        params = optim_mod.apply_updates(state["params"], updates)
        new_state = {"params": params, "opt": opt_state, "step": state["step"] + 1}
        return new_state, {"loss": loss, "grad_norm": gnorm}

    return train_step
