"""Job-master daemon (paper §3/§5): real worker processes, re-exec'd on death.

PR 6's supervisor heals a job *inside one interpreter* — injected faults are
scripted exceptions, and the watchdog can only abandon an attempt. The
paper's reliability claims, though, are about processes dying in a real
cluster: pod evictions (SIGKILL), wedged parameter servers (a process that
stops answering without exiting), kills that land mid-checkpoint-write.
This module is the job-master side of that contract:

* ``WorkerSpec`` — the launch recipe of one worker: the argv of
  ``repro.train.worker_main`` (a real ``DLRMJob`` loop), its heartbeat /
  loss-log / checkpoint paths, and its ``--chaos-proc`` fault plan.
* ``JobMaster`` — spawns each worker as a subprocess, monitors **heartbeat
  files + exit codes**, and re-execs dead workers with capped exponential
  backoff. A worker that exits nonzero (or is SIGKILLed) is re-exec'd; a
  worker whose heartbeat goes stale without exiting (SIGSTOP, wedged native
  call) is SIGKILLed first — the kill path the in-process watchdog could
  only model. The re-exec'd incarnation restores the newest *valid*
  layout-stamped flash checkpoint (``DLRMJob.start(resume=True)`` →
  ``resume_dlrm_stamped``), so recovery is bit-exact by construction.
* ``JobMasterReport`` — outcome + measured re-exec/restore latencies;
  ``measured_timings()`` maps them onto ``repro.core.migration.
  MigrationTimings`` so ``repro.sim.cluster`` prices worker replacement
  with what re-exec actually costs instead of a pod-provision constant.

Heartbeat protocol (one JSON file per worker, atomically replaced)::

    {"pid": ..., "incarnation": k, "step": n, "phase": p, "t": wall,
     "restore_s": r}
    phase: "boot"  - process alive, heavy imports / compile in progress
           "ready" - restored (from step n) and compiled; restore_s measured
           "step"  - completed global step n
           "done"  - finished all steps; exiting 0

Staleness uses the payload's own wall clock: a worker in "boot" gets
``spawn_grace_s`` (JIT compile takes seconds), after that each heartbeat
must arrive within ``heartbeat_deadline_s``. A heartbeat whose incarnation
is not the live one is a dead incarnation's leftover and counts as "boot".

This module is deliberately **stdlib-only** (no jax import): the master
must stay responsive while workers compile, and its own failure domain
should not include the accelerator stack.
"""
from __future__ import annotations

import json
import os
import signal
import subprocess
import sys
import time
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, IO, List, Optional, Sequence, Tuple

from repro.core.migration import MigrationTimings

#: repo ``src`` dir, so spawned workers resolve ``repro`` like the master did
_SRC_DIR = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", ".."))

PHASES = ("boot", "ready", "step", "done")


class ReexecBudgetExceeded(RuntimeError):
    """A worker kept dying past the capped re-exec budget; the job failed."""


class JobMasterDeadlineExceeded(RuntimeError):
    """The whole run overshot ``run_deadline_s`` (e.g. a hung re-exec)."""


# ------------------------------------------------------------------ heartbeat
def write_heartbeat(path: str, *, pid: int, incarnation: int, step: int,
                    phase: str, restore_s: float = 0.0) -> None:
    """Atomically publish a worker heartbeat (tmp file + ``os.replace``)."""
    if phase not in PHASES:
        raise ValueError(f"unknown heartbeat phase {phase!r}")
    payload = {"pid": int(pid), "incarnation": int(incarnation),
               "step": int(step), "phase": phase, "t": time.time(),
               "restore_s": float(restore_s)}
    tmp = f"{path}.tmp-{pid}"
    with open(tmp, "w") as f:
        json.dump(payload, f)
    os.replace(tmp, path)


def read_heartbeat(path: str) -> Optional[Dict[str, Any]]:
    """Read the newest heartbeat; None when absent (never raises on torn
    content — writes are atomic, but the very first read may race the
    worker's first publish)."""
    try:
        with open(path) as f:
            return json.load(f)
    except (FileNotFoundError, json.JSONDecodeError):
        return None


# ----------------------------------------------------------------- worker spec
@dataclass(frozen=True)
class WorkerSpec:
    """Launch recipe of one named worker process.

    The master re-execs the same argv on every death, with only
    ``--incarnation`` advanced — the worker derives everything else
    (restore point, fault gating) from the checkpoint dir and the plan.
    """
    name: str
    workdir: str                     # heartbeat / loss-log / stdout live here
    ckpt_dir: str
    arch: str = "wide_deep"
    steps: int = 10
    ckpt_every: int = 3
    n_ps: int = 4
    padded: bool = True
    chaos_proc: str = ""             # ProcessFaultInjector plan (may be "")
    opt_name: str = "adagrad"
    lr: float = 0.05
    init_seed: int = 0
    data_seed: int = 11
    extra_args: Tuple[str, ...] = ()

    @property
    def heartbeat_path(self) -> str:
        return os.path.join(self.workdir, f"hb_{self.name}.json")

    @property
    def losses_path(self) -> str:
        return os.path.join(self.workdir, f"losses_{self.name}.jsonl")

    @property
    def faults_path(self) -> str:
        return os.path.join(self.workdir, f"faults_{self.name}.jsonl")

    def argv(self, incarnation: int, python: str = sys.executable) -> List[str]:
        cmd = [python, "-m", "repro.train.worker_main",
               "--arch", self.arch, "--steps", str(self.steps),
               "--ckpt-dir", self.ckpt_dir,
               "--ckpt-every", str(self.ckpt_every),
               "--n-ps", str(self.n_ps),
               "--optimizer", self.opt_name, "--lr", str(self.lr),
               "--init-seed", str(self.init_seed),
               "--data-seed", str(self.data_seed),
               "--heartbeat", self.heartbeat_path,
               "--losses", self.losses_path,
               "--fault-log", self.faults_path,
               "--incarnation", str(incarnation)]
        if self.padded:
            cmd.append("--padded")
        if self.chaos_proc:
            cmd += ["--chaos-proc", self.chaos_proc]
        cmd += list(self.extra_args)
        return cmd

    def read_losses(self) -> List[Dict[str, Any]]:
        """All recorded ``{incarnation, step, loss}`` lines, across every
        incarnation (replayed steps appear once per incarnation)."""
        out = []
        try:
            with open(self.losses_path) as f:
                for line in f:
                    if line.strip():
                        out.append(json.loads(line))
        except FileNotFoundError:
            return []                    # no incarnation recorded a step yet
        return out


# --------------------------------------------------------------------- config
@dataclass
class JobMasterConfig:
    """Monitor cadence, staleness deadlines, and the re-exec policy."""
    poll_interval_s: float = 0.05
    heartbeat_deadline_s: float = 10.0   # after "ready": stale => SIGKILL
    spawn_grace_s: float = 120.0         # boot -> ready (imports + JIT)
    max_reexecs: int = 5                 # capped re-exec budget per worker
    backoff_base_s: float = 0.05
    backoff_cap_s: float = 2.0
    backoff_jitter: float = 0.25         # ± fraction, deterministic from seed
    seed: int = 0
    run_deadline_s: Optional[float] = None   # whole-run wall cap; None = off


@dataclass
class JobMasterEvent:
    """One structured entry of the spawn → death → re-exec log."""
    t: float
    kind: str                  # spawned | worker_died | heartbeat_stale | ...
    worker: str
    detail: Dict[str, Any] = field(default_factory=dict)


@dataclass
class JobMasterReport:
    """Outcome + measured recovery costs of one mastered run."""
    completed: bool
    final_steps: Dict[str, int]
    reexecs: int
    exit_history: Dict[str, List[int]]       # worker -> exit codes seen
    reexec_latencies_s: List[float]          # death detect -> next "ready"
    restore_latencies_s: List[float]         # worker-measured ckpt restores
    wall_seconds: float
    events: List[JobMasterEvent]

    def measured_timings(self) -> MigrationTimings:
        """Feed measured process-recovery latencies into the cluster sim.

        Re-exec latency (death → replacement ready) maps onto
        ``worker_reexec_s`` — the horizon ``repro.sim.cluster`` uses for
        dynamic-sharding worker replacement — and the worker's own measured
        flash-restore time onto ``flash_ckpt_load_s``.
        """
        kw: Dict[str, float] = {}
        if self.reexec_latencies_s:
            kw["worker_reexec_s"] = max(
                sum(self.reexec_latencies_s) / len(self.reexec_latencies_s),
                1e-3)
        if self.restore_latencies_s:
            kw["flash_ckpt_load_s"] = max(
                sum(self.restore_latencies_s) / len(self.restore_latencies_s),
                1e-3)
        return MigrationTimings(**kw)


# ----------------------------------------------------------------- the daemon
class _WorkerState:
    """Mutable monitor-side record of one worker (master internal)."""

    def __init__(self, spec: WorkerSpec):
        self.spec = spec
        self.proc: Optional[subprocess.Popen] = None
        self.log_file: Optional[IO[bytes]] = None
        self.incarnation = -1
        self.spawned_at = 0.0
        self.death_detected_at: Optional[float] = None
        self.ready_seen = False          # current incarnation reached "ready"
        self.completed = False
        self.reexecs = 0
        self.exit_codes: List[int] = []
        self.final_step = -1


class JobMaster:
    """Spawn, monitor (heartbeats + exit codes), and re-exec real workers.

    ``run()`` returns when every worker's process exited 0 with a "done"
    heartbeat at ``spec.steps``; it raises ``ReexecBudgetExceeded`` when a
    worker dies past its budget, ``JobMasterDeadlineExceeded`` when the
    whole run overshoots ``run_deadline_s``. Live workers are always killed
    on the way out — the master never leaks processes.
    """

    def __init__(self, workers: Sequence[WorkerSpec],
                 config: Optional[JobMasterConfig] = None, *,
                 python: str = sys.executable):
        if not workers:
            raise ValueError("JobMaster needs at least one WorkerSpec")
        names = [w.name for w in workers]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate worker names: {names}")
        self.config = config or JobMasterConfig()
        self.python = python
        self._workers = [_WorkerState(w) for w in workers]
        self.events: List[JobMasterEvent] = []
        self.reexec_latencies_s: List[float] = []
        self.restore_latencies_s: List[float] = []
        # deterministic backoff jitter without numpy: seeded stdlib Random
        import random
        self._rng = random.Random(self.config.seed)

    # ------------------------------------------------------------------ log
    def _event(self, kind: str, worker: str, **detail: Any) -> JobMasterEvent:
        ev = JobMasterEvent(time.time(), kind, worker, detail)
        self.events.append(ev)
        return ev

    def write_event_log(self, path: str,
                        report: Optional[JobMasterReport] = None) -> None:
        """Dump the structured event log as JSONL; a final ``summary`` line
        carries the report's metrics (same shape as the supervisor's log)."""
        with open(path, "w") as f:
            for ev in self.events:
                f.write(json.dumps(asdict(ev)) + "\n")
            if report is not None:
                lat = report.reexec_latencies_s
                f.write(json.dumps({
                    "kind": "summary", "completed": report.completed,
                    "final_steps": report.final_steps,
                    "reexecs": report.reexecs,
                    "exit_history": report.exit_history,
                    "reexec_latency_mean_s":
                        sum(lat) / len(lat) if lat else 0.0,
                    "wall_seconds": report.wall_seconds}) + "\n")

    # ---------------------------------------------------------------- spawn
    def _spawn(self, ws: _WorkerState) -> None:
        ws.incarnation += 1
        spec = ws.spec
        os.makedirs(spec.workdir, exist_ok=True)
        env = dict(os.environ)
        env["PYTHONPATH"] = _SRC_DIR + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
        log_path = os.path.join(spec.workdir,
                                f"{spec.name}.{ws.incarnation}.log")
        if ws.log_file is not None:
            ws.log_file.close()
        ws.log_file = open(log_path, "ab")
        ws.proc = subprocess.Popen(
            spec.argv(ws.incarnation, self.python), env=env,
            stdout=ws.log_file, stderr=subprocess.STDOUT,
            start_new_session=True)      # its own process group: clean kills
        ws.spawned_at = time.time()
        ws.ready_seen = False
        self._event("spawned", spec.name, incarnation=ws.incarnation,
                    pid=ws.proc.pid, log=log_path)

    def _kill(self, ws: _WorkerState) -> None:
        """SIGKILL a live worker (also reaps it); no-op when already dead."""
        if ws.proc is not None and ws.proc.poll() is None:
            try:
                os.kill(ws.proc.pid, signal.SIGKILL)
            except ProcessLookupError:
                # exited between poll() and kill(); the wait() below reaps it
                self._event("kill_raced_exit", ws.spec.name,
                            incarnation=ws.incarnation, pid=ws.proc.pid)
            ws.proc.wait(timeout=30)

    def _backoff_s(self, ws: _WorkerState) -> float:
        c = self.config
        d = min(c.backoff_base_s * 2 ** max(ws.reexecs - 1, 0), c.backoff_cap_s)
        return max(d * (1.0 + c.backoff_jitter * self._rng.uniform(-1, 1)), 0.0)

    # -------------------------------------------------------------- monitor
    def _heartbeat(self, ws: _WorkerState) -> Optional[Dict[str, Any]]:
        """Current incarnation's heartbeat, or None while it hasn't spoken."""
        hb = read_heartbeat(ws.spec.heartbeat_path)
        if hb is None or hb.get("incarnation") != ws.incarnation:
            return None                  # a dead incarnation's leftover
        return hb

    def _stale(self, ws: _WorkerState, hb: Optional[Dict[str, Any]],
               now: float) -> Optional[str]:
        """Staleness verdict: None = healthy, else a reason string."""
        c = self.config
        if hb is None or hb.get("phase") == "boot":
            since = now - ws.spawned_at
            if since > c.spawn_grace_s:
                return f"no ready heartbeat within spawn grace ({since:.1f}s)"
            return None
        since = now - float(hb.get("t", 0.0))
        if since > c.heartbeat_deadline_s:
            return (f"heartbeat stale {since:.1f}s > "
                    f"{c.heartbeat_deadline_s}s (phase={hb.get('phase')}, "
                    f"step={hb.get('step')})")
        return None

    def _observe_recovery(self, ws: _WorkerState,
                          hb: Optional[Dict[str, Any]]) -> None:
        """First ready/step/done heartbeat of a re-exec'd incarnation closes
        the death → ready latency measurement."""
        if hb is None or ws.ready_seen or hb.get("phase") == "boot":
            return
        ws.ready_seen = True
        if ws.death_detected_at is not None:
            latency = time.time() - ws.death_detected_at
            self.reexec_latencies_s.append(latency)
            # incarnation 0's "restore" is a fresh init, not a checkpoint
            # load — only re-exec'd incarnations feed the restore mean
            if float(hb.get("restore_s", 0.0)) > 0.0:
                self.restore_latencies_s.append(float(hb["restore_s"]))
            self._event("reexec_ready", ws.spec.name,
                        incarnation=ws.incarnation,
                        reexec_latency_s=round(latency, 4),
                        resumed_step=hb.get("step"))
            ws.death_detected_at = None

    def _handle_death(self, ws: _WorkerState, cause: str, **detail: Any) -> None:
        ws.death_detected_at = time.time()
        self._event(cause, ws.spec.name, incarnation=ws.incarnation, **detail)
        ws.reexecs += 1
        if ws.reexecs > self.config.max_reexecs:
            self._event("reexec_budget_exceeded", ws.spec.name,
                        reexecs=ws.reexecs - 1,
                        budget=self.config.max_reexecs)
            raise ReexecBudgetExceeded(
                f"worker {ws.spec.name!r}: {ws.reexecs - 1} re-execs "
                f"exhausted the budget of {self.config.max_reexecs} "
                f"(last cause: {cause})")
        delay = self._backoff_s(ws)
        time.sleep(delay)
        self._spawn(ws)
        self._event("reexec", ws.spec.name, incarnation=ws.incarnation,
                    backoff_s=round(delay, 4), cause=cause)

    def _poll_one(self, ws: _WorkerState, now: float) -> None:
        assert ws.proc is not None
        hb = self._heartbeat(ws)
        self._observe_recovery(ws, hb)
        rc = ws.proc.poll()
        if rc is not None:
            ws.exit_codes.append(rc)
            if rc == 0 and hb is not None and hb.get("phase") == "done" \
                    and int(hb.get("step", -1)) >= ws.spec.steps:
                ws.completed = True
                ws.final_step = int(hb["step"])
                self._event("worker_done", ws.spec.name,
                            incarnation=ws.incarnation, step=ws.final_step)
                return
            self._handle_death(
                ws, "worker_died", exit_code=rc,
                signal=signal.Signals(-rc).name if rc < 0 else None,
                last_step=None if hb is None else hb.get("step"))
            return
        reason = self._stale(ws, hb, now)
        if reason is not None:
            # alive but silent: SIGSTOPped or wedged — kill the husk first
            self._kill(ws)
            ws.exit_codes.append(-signal.SIGKILL)
            self._handle_death(ws, "heartbeat_stale", reason=reason,
                               last_step=None if hb is None else hb.get("step"))

    # ------------------------------------------------------------------ run
    def run(self) -> JobMasterReport:
        t_start = time.time()
        try:
            for ws in self._workers:
                self._spawn(ws)
            while not all(ws.completed for ws in self._workers):
                if self.config.run_deadline_s is not None and \
                        time.time() - t_start > self.config.run_deadline_s:
                    self._event("run_deadline_exceeded", "*",
                                deadline_s=self.config.run_deadline_s)
                    raise JobMasterDeadlineExceeded(
                        f"job master overshot run_deadline_s="
                        f"{self.config.run_deadline_s}")
                time.sleep(self.config.poll_interval_s)
                now = time.time()
                for ws in self._workers:
                    if not ws.completed:
                        self._poll_one(ws, now)
        finally:
            for ws in self._workers:
                self._kill(ws)
                if ws.log_file is not None:
                    ws.log_file.close()
                    ws.log_file = None
        return JobMasterReport(
            completed=all(ws.completed for ws in self._workers),
            final_steps={ws.spec.name: ws.final_step for ws in self._workers},
            reexecs=sum(ws.reexecs for ws in self._workers),
            exit_history={ws.spec.name: list(ws.exit_codes)
                          for ws in self._workers},
            reexec_latencies_s=list(self.reexec_latencies_s),
            restore_latencies_s=list(self.restore_latencies_s),
            wall_seconds=time.time() - t_start,
            events=list(self.events))
