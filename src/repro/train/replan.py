"""Live embedding re-planning: observed skew → re-sharded, recompiled step.

DLRover-RM's core claim is *dynamic* adjustment (§4–§5): the job master
watches a running job and re-allocates mid-flight. For embeddings the thing
worth re-allocating is row placement — which rows sit in the fused engine's
VMEM hot-row cache and how the pooled rows split across PS shards — because
skew drifts (RecShard / MTrainS): yesterday's hot head is today's lukewarm
middle, and a plan frozen at compile time re-creates the hot-PS problem it
was built to solve.

This module closes the loop around ``HotTableTracker``'s ``ReplanDecision``:

    observe (decayed rolling counts, worker-side ids)
      → trigger (imbalance over threshold, hysteresis)
        → snapshot   (FlashCheckpoint, old layout — §5.2 flash checkpoint)
        → permute    (pooled rows + optimizer moments, within-table only)
        → re-plan    (balanced vocab ranges onto the ShardingPolicy,
                      measured ``table_hot`` prefixes for the VMEM cache)
        → recompile  (``make_dlrm_train_step(..., table_hot=new plan)``)
        → remap      (incoming ids, off the hot path, composable)

Everything is **bit-exact**: a permutation gathers identical row values, ids
are remapped consistently, and the backward ``segment_sum`` sees the same
per-row contributions in the same flat order — so the resumed step's forward
loss equals the pre-replan checkpoint's to the last ulp (test_replan.py
asserts this), and an OLD-plan checkpoint restores losslessly onto a NEW
plan via ``restore_on_plan`` / ``elastic.resume_dlrm_on_mesh``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.dlrm_models import DLRMConfig
from repro.core.flash_checkpoint import FlashCheckpoint
from repro.core.sharding_service import ReplanDecision
from repro.kernels.fused_embedding import table_offsets
from repro.sharding.policy import ShardingPolicy, make_dlrm_policy
from repro.train import elastic
from repro.train import trainer as trainer_mod
from repro.train.optim import Optimizer


class EmbeddingRemapper:
    """Composable raw-id → current-layout remap (ingestion side of a re-plan).

    The data stream keeps emitting *raw* per-table-local ids; after each
    applied re-plan the pooled rows move, so lookups must go through the
    composed permutation. The remap is a single numpy take per batch on the
    input pipeline — it never touches the jit-compiled training step.
    """

    def __init__(self, table_rows):
        self.table_rows = tuple(int(r) for r in table_rows)
        self.offsets = np.asarray(table_offsets(self.table_rows), np.int64)
        self.total_rows = int(sum(self.table_rows))
        # raw global row -> current layout global row (identity before any plan)
        self.map = np.arange(self.total_rows, dtype=np.int64)
        self.n_plans = 0

    def compose(self, permutation: np.ndarray) -> None:
        """Fold one applied re-plan's layout permutation into the remap."""
        self.map = np.asarray(permutation, np.int64)[self.map]
        self.n_plans += 1

    def remap(self, sparse: np.ndarray) -> np.ndarray:
        """(B, T, H) raw per-table-local ids → current-layout local ids.

        Permutations never cross table boundaries, so the result is again a
        valid per-table-local id tensor (same dtype as the input).
        """
        sparse = np.asarray(sparse)
        g = sparse.astype(np.int64) + self.offsets[None, :, None]
        return (self.map[g] - self.offsets[None, :, None]).astype(sparse.dtype)

    def remap_batch(self, batch: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        """Copy of a criteo-style batch dict with its "sparse" ids remapped."""
        out = dict(batch)
        out["sparse"] = self.remap(batch["sparse"])
        return out


def permute_train_state(state, total_rows: int, permutation: np.ndarray):
    """Move every pooled-row leaf of a DLRM train state to a new layout.

    Applies ``new[perm[i]] = old[i]`` along axis 0 of the embedding-table
    leaves — ``params["tables"]``, the wide part, and their optimizer-state
    mirrors (adagrad accumulators, adam moments), identified by carrying a
    ``tables``/``wide`` path key AND a leading dim of ``total_rows``. Dense
    MLP/cross/CIN leaves and scalars pass through untouched.

    Args:
      state:       {params, opt, step} pytree (host or device arrays).
      total_rows:  ``cfg.total_embedding_rows`` of the job.
      permutation: layout permutation from a ``ReplanDecision``.

    Returns a new state pytree; row *values* are moved, never changed, which
    is what makes re-planning bit-exact.
    """
    inv = jnp.asarray(np.argsort(np.asarray(permutation)))

    def visit(path, leaf):
        keys = {p.key for p in path if isinstance(p, jax.tree_util.DictKey)}
        if not ({"tables", "wide"} & keys):
            return leaf
        if getattr(leaf, "ndim", 0) >= 1 and leaf.shape[0] == total_rows:
            return jnp.take(jnp.asarray(leaf), inv, axis=0)
        return leaf

    return jax.tree_util.tree_map_with_path(visit, state)


@dataclass
class ReplanResult:
    """Everything the training loop swaps in after an applied re-plan."""
    state: Dict[str, Any]                   # permuted (and re-placed) state
    step_fn: Callable                       # recompiled with the new table_hot
    policy: ShardingPolicy                  # carries the balanced vocab ranges
    decision: ReplanDecision


def apply_replan(state, cfg: DLRMConfig, optimizer: Optimizer,
                 decision: ReplanDecision, *,
                 remapper: Optional[EmbeddingRemapper] = None,
                 mesh=None, opt_name: str = "adagrad",
                 grad_compress: bool = False) -> ReplanResult:
    """Execute one live re-plan on a running job's state.

    The seamless-migration recipe of §5.2 applied to row placement: permute
    the pooled rows and their optimizer moments to the decision's
    frequency-packed layout, attach the balanced vocab ranges to the
    sharding policy (re-placing the state when a mesh is present), and
    recompile the train step with the measured ``table_hot`` cache plan.
    The caller must also route future batches through ``remapper`` (composed
    here) and call ``tracker.mark_applied(decision)`` so observation follows
    the layout. For crash safety, write a layout-stamped snapshot of the
    OLD state with ``save_with_layout`` *before* calling this (stamping the
    pre-compose map) — ``restore_on_plan`` then resumes it onto the new
    plan bit-exactly; a single blob schema, no format ambiguity.

    Args:
      state:     live {params, opt, step} pytree (old layout).
      cfg:       the DLRM job config.
      optimizer: the job's optimizer (for the recompiled step).
      decision:  an accepted ``HotTableTracker.maybe_replan`` decision.
      remapper:  optional id remapper to compose with the new permutation.
      mesh:      optional device mesh; the permuted state is re-placed under
                 the new policy's shardings.
      opt_name:  optimizer name for state specs ("adagrad", "adam", ...).
      grad_compress: forwarded to the recompiled train step.

    Returns a ``ReplanResult``; training continues with ``result.state`` and
    ``result.step_fn`` on remapped batches.
    """
    new_state = permute_train_state(state, cfg.total_embedding_rows,
                                    decision.permutation)
    if remapper is not None:
        remapper.compose(decision.permutation)
    policy = make_dlrm_policy(mesh, vocab_ranges=decision.vocab_ranges)
    if mesh is not None:
        shardings = elastic.dlrm_state_shardings(cfg, opt_name, policy)
        new_state = jax.device_put(new_state, shardings)
    step_fn = jax.jit(trainer_mod.make_dlrm_train_step(
        cfg, optimizer, grad_compress=grad_compress,
        table_hot=decision.table_hot))
    return ReplanResult(state=new_state, step_fn=step_fn, policy=policy,
                        decision=decision)


def restore_on_plan(cfg: DLRMConfig, optimizer: Optimizer, opt_name: str,
                    ckpt: FlashCheckpoint, decision: ReplanDecision, *,
                    mesh=None, step: Optional[int] = None,
                    grad_compress: bool = False
                    ) -> Tuple[Dict[str, Any], int, Callable, ShardingPolicy,
                               EmbeddingRemapper]:
    """Restore an OLD-plan layout-stamped checkpoint onto a NEW plan.

    The elastic-restart half of re-planning: a worker that joins (or a job
    that restarts) after a re-plan only has checkpoints written under the
    previous layout (via ``save_with_layout``). Restoring through the
    decision's permutation yields a state whose forward loss on remapped
    batches is bit-identical to what the old layout would have produced —
    the restored remapper is returned already composed with the decision.

    Args:
      cfg, optimizer, opt_name: the job being resumed.
      ckpt:     flash checkpoint holding the old-layout stamped snapshot.
      decision: the applied re-plan to restore onto.
      mesh:     optional target mesh.
      step:     checkpoint step (None = latest).
      grad_compress: forwarded to the recompiled train step.

    Returns ``(state, restored_step, step_fn, policy, remapper)``.
    """
    state, restored_step, remapper, _old_hot, _old_ranges = \
        restore_with_layout(cfg, optimizer, ckpt, step=step)
    state = permute_train_state(state, cfg.total_embedding_rows,
                                decision.permutation)
    remapper.compose(decision.permutation)
    policy = make_dlrm_policy(mesh, vocab_ranges=decision.vocab_ranges)
    if mesh is not None:
        state = jax.device_put(
            state, elastic.dlrm_state_shardings(cfg, opt_name, policy))
    step_fn = jax.jit(trainer_mod.make_dlrm_train_step(
        cfg, optimizer, grad_compress=grad_compress,
        table_hot=decision.table_hot))
    return state, restored_step, step_fn, policy, remapper


# --------------------------------------------------------- layout-stamped ckpt
def save_with_layout(ckpt: FlashCheckpoint, state, step: int,
                     remapper: EmbeddingRemapper,
                     table_hot: Optional[Tuple[int, ...]] = None,
                     vocab_ranges: Optional[Sequence[Tuple[int, int]]] = None
                     ) -> None:
    """Checkpoint the state together with its row-layout provenance.

    A plain state snapshot is only restorable by a process that still holds
    the ``ReplanDecision`` history (the permutations live in memory). This
    variant stamps the remapper's composed raw-id → layout map, the active
    ``table_hot`` cache plan and the applied PS ``vocab_ranges`` into the
    blob, making the checkpoint self-describing: a *fresh* process restores
    with ``restore_with_layout`` and keeps training (and re-planning from
    the correct baseline) no matter how many re-plans preceded it.

    Args:
      ckpt:      flash checkpoint to write to.
      state:     live {params, opt, step} pytree (current layout).
      step:      checkpoint step key.
      remapper:  the job's id remapper (its map matches ``state``'s layout).
      table_hot: the cache plan compiled into the current step (None = the
                 config default).
      vocab_ranges: the applied balanced PS ranges (None = uniform striping,
                 i.e. no placement plan applied yet).
    """
    hot = (np.full(len(remapper.table_rows), -1, np.int64)
           if table_hot is None else np.asarray(table_hot, np.int64))
    ranges = (np.zeros((0,), np.int64) if vocab_ranges is None
              else np.asarray(vocab_ranges, np.int64).reshape(-1))
    ckpt.save({"state": state, "layout": np.asarray(remapper.map, np.int64),
               "table_hot": hot, "vocab_ranges": ranges}, step)


def restore_with_layout(cfg: DLRMConfig, optimizer: Optimizer,
                        ckpt: FlashCheckpoint, *, step: Optional[int] = None
                        ) -> Tuple[Dict[str, Any], int, EmbeddingRemapper,
                                   Optional[Tuple[int, ...]],
                                   Optional[Tuple[Tuple[int, int], ...]]]:
    """Restore a ``save_with_layout`` checkpoint in a fresh process.

    Args:
      cfg, optimizer: the job being resumed (shape source for the restore).
      ckpt: flash checkpoint holding layout-stamped blobs.
      step: checkpoint step (None = latest).

    Returns ``(state, restored_step, remapper, table_hot, vocab_ranges)``:
    the remapper is reconstructed from the stamped map (route raw batches
    through it), ``table_hot`` is the cache plan to recompile with (None =
    config default), and ``vocab_ranges`` is the applied placement plan to
    seed a fresh ``HotTableTracker``'s baseline with (None = uniform).
    """
    n_tables = len(cfg.table_rows)
    like = {
        "state": jax.eval_shape(
            lambda k: trainer_mod.make_dlrm_train_state(cfg, optimizer, k),
            jax.random.PRNGKey(0)),
        "layout": jax.ShapeDtypeStruct((cfg.total_embedding_rows,), jnp.int64),
        "table_hot": jax.ShapeDtypeStruct((n_tables,), jnp.int64),
        # placeholder shape: restore takes leaf shapes from the stored blob
        "vocab_ranges": jax.ShapeDtypeStruct((0,), jnp.int64),
    }
    blob, restored_step = ckpt.restore(like, step)
    remapper = EmbeddingRemapper(cfg.table_rows)
    remapper.map = np.asarray(blob["layout"], np.int64)
    hot = np.asarray(blob["table_hot"])
    table_hot = None if (hot < 0).any() else tuple(int(k) for k in hot)
    flat_ranges = np.asarray(blob["vocab_ranges"]).reshape(-1, 2)
    vocab_ranges = (None if flat_ranges.size == 0 else
                    tuple((int(s), int(e)) for s, e in flat_ranges))
    return blob["state"], restored_step, remapper, table_hot, vocab_ranges
