"""Live embedding re-planning: observed skew → re-sharded, recompiled step.

DLRover-RM's core claim is *dynamic* adjustment (§4–§5): the job master
watches a running job and re-allocates mid-flight. For embeddings the thing
worth re-allocating is row placement — which rows sit in the fused engine's
VMEM hot-row cache and how the pooled rows split across PS shards — because
skew drifts (RecShard / MTrainS): yesterday's hot head is today's lukewarm
middle, and a plan frozen at compile time re-creates the hot-PS problem it
was built to solve.

This module closes the loop around ``HotTableTracker``'s ``ReplanDecision``:

    observe (decayed rolling counts, worker-side ids)
      → trigger (imbalance over threshold, hysteresis)
        → snapshot   (FlashCheckpoint, old layout — §5.2 flash checkpoint)
        → permute    (pooled rows + optimizer moments, within-table only)
        → re-plan    (balanced vocab ranges onto the ShardingPolicy,
                      measured ``table_hot`` prefixes for the VMEM cache)
        → recompile  (``make_dlrm_train_step(..., table_hot=new plan)``)
        → remap      (incoming ids, off the hot path, composable)

Everything is **bit-exact**: a permutation gathers identical row values, ids
are remapped consistently, and the backward ``segment_sum`` sees the same
per-row contributions in the same flat order — so the resumed step's forward
loss equals the pre-replan checkpoint's to the last ulp (test_replan.py
asserts this), and an OLD-plan checkpoint restores losslessly onto a NEW
plan via ``restore_on_plan`` / ``elastic.resume_dlrm_on_mesh``.

Padded physical shards ride the same loop: on a job running the
``(n_ps, max_range, D)`` padded pool (``--padded-shards``), a re-plan's new
balanced ranges imply a new *physical* layout, so ``apply_replan`` unpads
the state to the canonical flat row space, permutes there, and re-pads onto
``padded_layout_for_ranges(decision.vocab_ranges)`` — GSPMD then
materializes exactly the new plan. ``pad_train_state`` /
``unpad_train_state`` are the bit-exact movers; checkpoints always store
the flat order (see ``save_with_layout``), making every blob restorable
onto any layout and shard count. ``docs/EMBEDDING_LAYOUT.md`` is the
authoritative walkthrough of the id spaces and their lifecycles.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.dlrm_models import DLRMConfig
from repro.core.flash_checkpoint import FlashCheckpoint
from repro.core.sharding_service import ReplanDecision
from repro.kernels.fused_embedding import table_offsets
from repro.sharding.policy import (
    EmbeddingPlan, PaddedLayout, ShardingPolicy, make_dlrm_policy,
    padded_layout_for_ranges, uniform_vocab_ranges,
)
from repro.train import elastic
from repro.train import trainer as trainer_mod
from repro.train.optim import Optimizer


class EmbeddingRemapper:
    """Composable raw-id → current-layout remap (ingestion side of a re-plan).

    The data stream keeps emitting *raw* per-table-local ids; after each
    applied re-plan the pooled rows move, so lookups must go through the
    composed permutation. The remap is a single numpy take per batch on the
    input pipeline — it never touches the jit-compiled training step.
    """

    def __init__(self, table_rows):
        self.table_rows = tuple(int(r) for r in table_rows)
        self.offsets = np.asarray(table_offsets(self.table_rows), np.int64)
        self.total_rows = int(sum(self.table_rows))
        # raw global row -> current layout global row (identity before any plan)
        self.map = np.arange(self.total_rows, dtype=np.int64)
        self.n_plans = 0

    def compose(self, permutation: np.ndarray) -> None:
        """Fold one applied re-plan's layout permutation into the remap.

        Args:
          permutation: ``(total_rows,)`` flat-row map of the applied
                       ``ReplanDecision`` (``perm[old_row] = new_row``).
                       Always expressed in the canonical FLAT space — padded
                       jobs compose the same permutations, since padding is
                       a placement of the flat order, not a reordering.
        """
        self.map = np.asarray(permutation, np.int64)[self.map]
        self.n_plans += 1

    def remap(self, sparse: np.ndarray) -> np.ndarray:
        """(B, T, H) raw per-table-local ids → current-layout local ids.

        Permutations never cross table boundaries, so the result is again a
        valid per-table-local id tensor (same dtype as the input).

        Out-of-range raw ids raise ``ValueError`` naming the offending
        table and its bound — an id past its table's rows would otherwise
        silently index a *neighboring table's* rows after the offset shift
        (or garbage past the pool), corrupting gradients with no error.

        Args:
          sparse: (B, T, H) raw per-table-local int ids from the stream.

        Returns the remapped (B, T, H) local ids under the current layout.
        """
        sparse = np.asarray(sparse)
        rows = np.asarray(self.table_rows, np.int64)
        bad = (sparse < 0) | (sparse.astype(np.int64) >= rows[None, :, None])
        if bad.any():
            b, t, h = (int(i[0]) for i in np.nonzero(bad))
            raise ValueError(
                f"sparse id {int(sparse[b, t, h])} out of range for table "
                f"{t} (rows={int(rows[t])}): raw ids must lie in "
                f"[0, {int(rows[t])}) — refusing to index garbage rows")
        g = sparse.astype(np.int64) + self.offsets[None, :, None]
        return (self.map[g] - self.offsets[None, :, None]).astype(sparse.dtype)

    def remap_batch(self, batch: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        """Copy of a criteo-style batch dict with its "sparse" ids remapped."""
        out = dict(batch)
        out["sparse"] = self.remap(batch["sparse"])
        return out


def permute_train_state(state, total_rows: int, permutation: np.ndarray):
    """Move every pooled-row leaf of a DLRM train state to a new layout.

    Applies ``new[perm[i]] = old[i]`` along axis 0 of the embedding-table
    leaves — ``params["tables"]``, the wide part, and their optimizer-state
    mirrors (adagrad accumulators, adam moments), identified by carrying a
    ``tables``/``wide`` path key AND a leading dim of ``total_rows``. Dense
    MLP/cross/CIN leaves and scalars pass through untouched.

    Args:
      state:       {params, opt, step} pytree (host or device arrays).
      total_rows:  ``cfg.total_embedding_rows`` of the job.
      permutation: layout permutation from a ``ReplanDecision``.

    Returns a new state pytree; row *values* are moved, never changed, which
    is what makes re-planning bit-exact.
    """
    inv = jnp.asarray(np.argsort(np.asarray(permutation)))

    def visit(path, leaf):
        keys = {p.key for p in path if isinstance(p, jax.tree_util.DictKey)}
        if not ({"tables", "wide"} & keys):
            return leaf
        if getattr(leaf, "ndim", 0) >= 1 and leaf.shape[0] == total_rows:
            return jnp.take(jnp.asarray(leaf), inv, axis=0)
        return leaf

    return jax.tree_util.tree_map_with_path(visit, state)


def _map_pooled_leaves(state, match, move):
    """Apply ``move`` to every pooled-row leaf of a DLRM train state.

    Shared walker for pad/unpad: a leaf qualifies when its path carries a
    ``tables``/``wide`` dict key AND ``match(leaf)`` accepts its shape —
    params and their optimizer-state mirrors (adagrad accumulators, adam
    moments) alike. Everything else passes through untouched.
    """
    def visit(path, leaf):
        keys = {p.key for p in path if isinstance(p, jax.tree_util.DictKey)}
        if ({"tables", "wide"} & keys) and match(leaf):
            return move(leaf)
        return leaf

    return jax.tree_util.tree_map_with_path(visit, state)


def pad_train_state(state, total_rows: int, layout: PaddedLayout):
    """Flat-layout DLRM train state → padded physical layout.

    Every pooled-row leaf — ``params["tables"]``, the wide part, their
    optimizer moments — of shape ``(total_rows, ...)`` is scattered to
    ``(n_ps, max_range, ...)`` per ``layout`` (padding slots zero). Values
    move, never change: ``unpad_train_state`` inverts this bit-exactly.

    Args:
      state:      {params, opt, step} pytree on the flat layout.
      total_rows: ``cfg.total_embedding_rows`` of the job.
      layout:     target physical layout.

    Returns the padded state pytree.
    """
    return _map_pooled_leaves(
        state,
        lambda leaf: getattr(leaf, "ndim", 0) >= 1
        and leaf.shape[0] == total_rows,
        layout.pad_rows)


def unpad_train_state(state, total_rows: int, layout: PaddedLayout):
    """Padded-layout DLRM train state → the canonical flat layout.

    Inverse of ``pad_train_state``: gathers the real rows of every
    ``(n_ps, max_range, ...)`` pooled leaf back into ``(total_rows, ...)``
    order, dropping the padding. Bit-exact.

    Args:
      state:      {params, opt, step} pytree on ``layout``.
      total_rows: ``cfg.total_embedding_rows`` of the job.
      layout:     the layout ``state`` currently lives on.

    Returns the flat state pytree.
    """
    del total_rows  # shape is implied by the layout; kept for symmetry
    return _map_pooled_leaves(
        state,
        lambda leaf: getattr(leaf, "ndim", 0) >= 2
        and leaf.shape[:2] == (layout.n_ps, layout.max_range),
        layout.unpad_rows)


@dataclass
class ReplanResult:
    """Everything the training loop swaps in after an applied re-plan."""
    state: Dict[str, Any]                   # permuted (and re-placed) state
    step_fn: Callable                       # recompiled with the new table_hot
    policy: ShardingPolicy                  # carries the balanced vocab ranges
    decision: ReplanDecision
    layout: Optional[PaddedLayout] = None   # physical layout of `state`
    plan: Optional[EmbeddingPlan] = None    # the plan `step_fn` compiled with


def apply_replan(state, cfg: DLRMConfig, optimizer: Optimizer,
                 decision: ReplanDecision, *,
                 remapper: Optional[EmbeddingRemapper] = None,
                 mesh=None, opt_name: str = "adagrad",
                 grad_compress: bool = False,
                 layout: Optional[PaddedLayout] = None,
                 plan: Optional[EmbeddingPlan] = None) -> ReplanResult:
    """Execute one live re-plan on a running job's state.

    The seamless-migration recipe of §5.2 applied to row placement: permute
    the pooled rows and their optimizer moments to the decision's
    frequency-packed layout, attach the balanced vocab ranges to the
    sharding policy (re-placing the state when a mesh is present), and
    recompile the train step with the measured ``table_hot`` cache plan.
    The caller must also route future batches through ``remapper`` (composed
    here) and call ``tracker.mark_applied(decision)`` so observation follows
    the layout. For crash safety, write a layout-stamped snapshot of the
    OLD state with ``save_with_layout`` *before* calling this (stamping the
    pre-compose map) — ``restore_on_plan`` then resumes it onto the new
    plan bit-exactly; a single blob schema, no format ambiguity.

    On a padded job (``layout`` given), the new balanced ranges imply a NEW
    physical layout (different shard boundaries, possibly a different
    ``max_range``): the state is unpadded to the canonical flat space,
    permuted there, and re-padded onto the layout planned from
    ``decision.vocab_ranges`` — so the compiled shards materialize exactly
    the new plan. Still bit-exact end to end.

    Args:
      state:     live {params, opt, step} pytree (old layout; padded on
                 ``layout`` when one is given).
      cfg:       the DLRM job config.
      optimizer: the job's optimizer (for the recompiled step).
      decision:  an accepted ``HotTableTracker.maybe_replan`` decision.
      remapper:  optional id remapper to compose with the new permutation.
      mesh:      optional device mesh; the permuted state is re-placed under
                 the new policy's shardings.
      opt_name:  optimizer name for state specs ("adagrad", "adam", ...).
      grad_compress: forwarded to the recompiled train step.
      layout:    the padded physical layout ``state`` currently lives on
                 (None = flat). Padded jobs come back padded on the NEW
                 layout (``result.layout``).
      plan:      the ``EmbeddingPlan`` the OLD step was compiled with (None
                 = the config default). The recompiled step runs under
                 ``plan.with_replan(decision.table_hot, new_layout)`` —
                 every other knob (combiner, ``sparse_update``, blocks)
                 carries over, so a fused sparse-update job stays fused
                 across a re-plan. The applied plan rides back on
                 ``result.plan``.

    Returns a ``ReplanResult``; training continues with ``result.state`` and
    ``result.step_fn`` on remapped batches.
    """
    R = cfg.total_embedding_rows
    flat_state = state if layout is None else \
        unpad_train_state(state, R, layout)
    new_state = permute_train_state(flat_state, R, decision.permutation)
    new_layout = None
    if layout is not None:
        new_layout = padded_layout_for_ranges(decision.vocab_ranges)
        new_state = pad_train_state(new_state, R, new_layout)
    if remapper is not None:
        remapper.compose(decision.permutation)
    policy = make_dlrm_policy(mesh, vocab_ranges=decision.vocab_ranges)
    if mesh is not None:
        shardings = elastic.dlrm_state_shardings(cfg, opt_name, policy,
                                                 layout=new_layout)
        new_state = jax.device_put(new_state, shardings)
    base_plan = plan if plan is not None else cfg.embedding_plan()
    new_plan = base_plan.with_replan(decision.table_hot, new_layout)
    step_fn = jax.jit(trainer_mod.make_dlrm_train_step(
        cfg, optimizer, grad_compress=grad_compress, plan=new_plan))
    return ReplanResult(state=new_state, step_fn=step_fn, policy=policy,
                        decision=decision, layout=new_layout, plan=new_plan)


def restore_on_plan(cfg: DLRMConfig, optimizer: Optimizer, opt_name: str,
                    ckpt: FlashCheckpoint, decision: ReplanDecision, *,
                    mesh=None, step: Optional[int] = None,
                    grad_compress: bool = False, padded: bool = False,
                    plan: Optional[EmbeddingPlan] = None
                    ) -> Tuple[Dict[str, Any], int, Callable, ShardingPolicy,
                               EmbeddingRemapper]:
    """Restore an OLD-plan layout-stamped checkpoint onto a NEW plan.

    The elastic-restart half of re-planning: a worker that joins (or a job
    that restarts) after a re-plan only has checkpoints written under the
    previous layout (via ``save_with_layout``). Restoring through the
    decision's permutation yields a state whose forward loss on remapped
    batches is bit-identical to what the old layout would have produced —
    the restored remapper is returned already composed with the decision.

    Args:
      cfg, optimizer, opt_name: the job being resumed.
      ckpt:     flash checkpoint holding the old-layout stamped snapshot.
      decision: the applied re-plan to restore onto.
      mesh:     optional target mesh.
      step:     checkpoint step (None = latest).
      grad_compress: forwarded to the recompiled train step.
      padded:   materialize the new plan physically — the returned state is
                padded onto ``padded_layout_for_ranges(decision.vocab_ranges)``
                and ``step_fn`` is compiled for it. A checkpoint stamped
                padded implies this automatically (a padded job stays
                padded across restarts).
      plan:     the job's ``EmbeddingPlan`` template (None = config
                default); the step recompiles under
                ``plan.with_replan(decision.table_hot, new layout)``, so
                fused sparse-update jobs resume fused.

    Returns ``(state, restored_step, step_fn, policy, remapper)``; when
    padded, rebuild the layout with
    ``padded_layout_for_ranges(decision.vocab_ranges)``.
    """
    R = cfg.total_embedding_rows
    state, restored_step, remapper, _old_hot, _old_ranges, old_layout = \
        restore_with_layout(cfg, optimizer, ckpt, step=step)
    if old_layout is not None:      # stamped padded: back to flat to permute
        state = unpad_train_state(state, R, old_layout)
    state = permute_train_state(state, R, decision.permutation)
    new_layout = None
    if padded or old_layout is not None:
        new_layout = padded_layout_for_ranges(decision.vocab_ranges)
        state = pad_train_state(state, R, new_layout)
    remapper.compose(decision.permutation)
    policy = make_dlrm_policy(mesh, vocab_ranges=decision.vocab_ranges)
    if mesh is not None:
        state = jax.device_put(
            state, elastic.dlrm_state_shardings(cfg, opt_name, policy,
                                                layout=new_layout))
    base_plan = plan if plan is not None else cfg.embedding_plan()
    new_plan = base_plan.with_replan(decision.table_hot, new_layout)
    step_fn = jax.jit(trainer_mod.make_dlrm_train_step(
        cfg, optimizer, grad_compress=grad_compress, plan=new_plan))
    return state, restored_step, step_fn, policy, remapper


# --------------------------------------------------------- layout-stamped ckpt
def save_with_layout(ckpt: FlashCheckpoint, state, step: int,
                     remapper: EmbeddingRemapper,
                     table_hot: Optional[Tuple[int, ...]] = None,
                     vocab_ranges: Optional[Sequence[Tuple[int, int]]] = None,
                     layout: Optional[PaddedLayout] = None) -> None:
    """Checkpoint the state together with its row-layout provenance.

    A plain state snapshot is only restorable by a process that still holds
    the ``ReplanDecision`` history (the permutations live in memory). This
    variant stamps the remapper's composed raw-id → layout map, the active
    ``table_hot`` cache plan and the applied PS ``vocab_ranges`` into the
    blob, making the checkpoint self-describing: a *fresh* process restores
    with ``restore_with_layout`` and keeps training (and re-planning from
    the correct baseline) no matter how many re-plans preceded it.

    Padded states are stored in the **canonical flat row order** (unpadded
    before flattening) plus a ``padded_n_ps`` stamp: one blob schema
    round-trips bit-exactly between flat and padded jobs, onto any future
    shard count — padding is a restore-time placement choice, not a storage
    format.

    Args:
      ckpt:      flash checkpoint to write to.
      state:     live {params, opt, step} pytree (current layout; padded on
                 ``layout`` when one is given).
      step:      checkpoint step key.
      remapper:  the job's id remapper (its map matches ``state``'s layout).
      table_hot: the cache plan compiled into the current step (None = the
                 config default).
      vocab_ranges: the applied balanced PS ranges (None = uniform striping,
                 i.e. no placement plan applied yet).
      layout:    the padded physical layout ``state`` lives on (None = flat).
                 Stamped as ``padded_n_ps`` so a fresh ``--resume`` comes
                 back padded on the same plan.
    """
    hot = (np.full(len(remapper.table_rows), -1, np.int64)
           if table_hot is None else np.asarray(table_hot, np.int64))
    ranges = (np.zeros((0,), np.int64) if vocab_ranges is None
              else np.asarray(vocab_ranges, np.int64).reshape(-1))
    if layout is not None:
        state = unpad_train_state(state, remapper.total_rows, layout)
    ckpt.save({"state": state, "layout": np.asarray(remapper.map, np.int64),
               "table_hot": hot, "vocab_ranges": ranges,
               "padded_n_ps": np.asarray(
                   0 if layout is None else layout.n_ps, np.int64)}, step)


def restore_with_layout(cfg: DLRMConfig, optimizer: Optimizer,
                        ckpt: FlashCheckpoint, *, step: Optional[int] = None
                        ) -> Tuple[Dict[str, Any], int, EmbeddingRemapper,
                                   Optional[Tuple[int, ...]],
                                   Optional[Tuple[Tuple[int, int], ...]],
                                   Optional[PaddedLayout]]:
    """Restore a ``save_with_layout`` checkpoint in a fresh process.

    Args:
      cfg, optimizer: the job being resumed (shape source for the restore).
      ckpt: flash checkpoint holding layout-stamped blobs.
      step: checkpoint step (None = latest).

    Returns ``(state, restored_step, remapper, table_hot, vocab_ranges,
    layout)``: the remapper is reconstructed from the stamped map (route raw
    batches through it), ``table_hot`` is the cache plan to recompile with
    (None = config default), ``vocab_ranges`` is the applied placement plan
    to seed a fresh ``HotTableTracker``'s baseline with (None = uniform),
    and ``layout`` is the stamped padded physical layout — when not None the
    returned state is already padded onto it (rebuilt from the stamped
    ranges, or uniform striping when no plan was applied yet); compile the
    step with ``layout=layout``. Blobs written before the padded-shard era
    lack the stamp and restore as flat (``layout=None``).
    """
    n_tables = len(cfg.table_rows)
    like = {
        "state": jax.eval_shape(
            lambda k: trainer_mod.make_dlrm_train_state(cfg, optimizer, k),
            jax.random.PRNGKey(0)),
        "layout": jax.ShapeDtypeStruct((cfg.total_embedding_rows,), jnp.int64),
        "table_hot": jax.ShapeDtypeStruct((n_tables,), jnp.int64),
        # placeholder shape: restore takes leaf shapes from the stored blob
        "vocab_ranges": jax.ShapeDtypeStruct((0,), jnp.int64),
        # absent in pre-padded-era blobs: zero-fills to 0 (= flat); every
        # OTHER missing leaf still raises (truncated blobs must not restore)
        "padded_n_ps": jax.ShapeDtypeStruct((), jnp.int64),
    }
    blob, restored_step = ckpt.restore(
        like, step,
        optional_leaves=(jax.tree_util.keystr(
            (jax.tree_util.DictKey("padded_n_ps"),)),))
    remapper = EmbeddingRemapper(cfg.table_rows)
    remapper.map = np.asarray(blob["layout"], np.int64)
    hot = np.asarray(blob["table_hot"])
    table_hot = None if (hot < 0).any() else tuple(int(k) for k in hot)
    flat_ranges = np.asarray(blob["vocab_ranges"]).reshape(-1, 2)
    vocab_ranges = (None if flat_ranges.size == 0 else
                    tuple((int(s), int(e)) for s, e in flat_ranges))
    state = blob["state"]
    n_ps = int(np.asarray(blob["padded_n_ps"]))
    layout = None
    if n_ps > 0:
        layout = padded_layout_for_ranges(
            vocab_ranges if vocab_ranges is not None
            else uniform_vocab_ranges(cfg.total_embedding_rows, n_ps))
        state = pad_train_state(state, cfg.total_embedding_rows, layout)
    return state, restored_step, remapper, table_hot, vocab_ranges, layout
