"""Constants shared by the Pallas kernels and their pure-jnp oracles.

Two different "very negative" numbers exist for two different jobs, and the
distinction matters:

* ``NEG_INF`` — identity element for max-pooling accumulators. Must be the
  most negative finite float32 so that ``max(NEG_INF, x) == x`` for *every*
  finite ``x`` (a table row can legitimately hold -1e31; an init of -1e30
  would silently win the max). Used by the embedding-bag kernels and oracles.
* ``MASK_VALUE`` — additive mask for pre-softmax attention scores. Chosen
  large enough that ``exp(MASK_VALUE - m)`` underflows to 0 but small enough
  that masked-score arithmetic (subtracting running maxima, multiplying by
  scale factors) cannot overflow to -inf and poison the softmax with NaNs.
"""
from __future__ import annotations

NEG_INF = -3.0e38       # max-combiner identity (≈ most negative finite f32)
MASK_VALUE = -1e30      # attention score mask (softmax-safe)
