"""Public jit-friendly kernel wrappers with implementation dispatch.

impl:
  "xla"       — scalable pure-JAX (chunked flash) path; default on CPU and for
                 the multi-pod dry-run (memory-safe lowering, same math).
  "pallas"    — Pallas TPU kernels (compiled for TPU targets).
  "interpret" — Pallas kernels in interpret mode (CPU correctness testing).

Set globally via ``set_default_impl`` or per-call with ``impl=``.
"""
from __future__ import annotations

import os

from repro.models import attention as _xla_attn

_DEFAULT_IMPL = os.environ.get("REPRO_KERNEL_IMPL", "xla")


def set_default_impl(impl: str) -> None:
    global _DEFAULT_IMPL
    assert impl in ("xla", "pallas", "interpret"), impl
    _DEFAULT_IMPL = impl


def get_default_impl() -> str:
    return _DEFAULT_IMPL


def flash_attention(q, k, v, *, causal=True, window=None, softcap=0.0,
                    q_chunk=1024, k_chunk=1024, q_offset=0, impl=None):
    impl = impl or _DEFAULT_IMPL
    if impl in ("pallas", "interpret"):
        from repro.kernels import flash_attention as fa
        return fa.flash_attention(
            q, k, v, causal=causal, window=window, softcap=softcap,
            interpret=(impl == "interpret"))
    return _xla_attn.chunked_attention(
        q, k, v, causal=causal, window=window, softcap=softcap,
        q_chunk=q_chunk, k_chunk=k_chunk, q_offset=q_offset)


def decode_attention(q, k_cache, v_cache, cache_pos, pos, *, window=None,
                     softcap=0.0, impl=None):
    impl = impl or _DEFAULT_IMPL
    if impl in ("pallas", "interpret"):
        from repro.kernels import decode_attention as da
        return da.decode_attention(
            q, k_cache, v_cache, cache_pos, pos, window=window,
            softcap=softcap, interpret=(impl == "interpret"))
    return _xla_attn.decode_attention(
        q, k_cache, v_cache, cache_pos, pos, window=window, softcap=softcap)


def fused_embedding_bag(pool, indices, weights=None, *, offsets=None,
                        combiner="sum", impl=None, block_b=8,
                        table_hot=None, layout=None):
    """Multi-table fused embedding engine (one call for all tables).

    pool (R, D) row-concatenated tables — or, with ``layout`` (a
    ``repro.sharding.policy.PaddedLayout``), the (n_ps * max_range, D)
    flattening of the padded physically-sharded store; indices (B, T, H)
    per-table-local rows (``offsets`` = static per-table row offsets, None
    if already global flat rows); weights (B, T, H)? -> (B, T, D).
    ``table_hot`` = per-table counts of frequency-packed hot leading rows
    served from the VMEM hot-row cache on the Pallas path. All impls share
    a custom VJP whose backward scatter-adds sparse table gradients via
    ``segment_sum``.

    ``table_hot`` and ``layout`` are static compile-time plans: a live
    re-plan (``repro.train.replan``) permutes (and re-pads) the pool rows to
    the new layout and re-enters here with the new plans — numerics are
    identical for any plan, so old-plan checkpoints restore bit-exactly
    onto new ones.
    """
    impl = impl or _DEFAULT_IMPL
    from repro.kernels import fused_embedding as fe
    return fe.fused_embedding_bag(
        pool, indices, weights, offsets=offsets, combiner=combiner,
        method=impl, block_b=block_b, table_hot=table_hot, layout=layout)


def embedding_bag(table, indices, weights=None, *, combiner="sum", impl=None):
    """Fused embedding gather + pooling. table (R, D); indices (B, n); -> (B, D).

    Single-table convenience wrapper over ``fused_embedding_bag`` (T=1), so
    every caller gets the same combiner semantics (weights apply before
    sum/mean/max) and the sparse-gradient VJP.
    """
    out = fused_embedding_bag(
        table, indices[:, None, :],
        None if weights is None else weights[:, None, :],
        combiner=combiner, impl=impl)
    return out[:, 0]
