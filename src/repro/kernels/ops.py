"""Public jit-friendly kernel wrappers with implementation dispatch.

impl:
  "xla"       — scalable pure-JAX (chunked flash) path; default on CPU and for
                 the multi-pod dry-run (memory-safe lowering, same math).
  "pallas"    — Pallas TPU kernels (compiled for TPU targets).
  "interpret" — Pallas kernels in interpret mode (CPU correctness testing).

Set globally via ``set_default_impl`` or per-call with ``impl=``.
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention as _xla_attn

_DEFAULT_IMPL = os.environ.get("REPRO_KERNEL_IMPL", "xla")


def set_default_impl(impl: str) -> None:
    global _DEFAULT_IMPL
    assert impl in ("xla", "pallas", "interpret"), impl
    _DEFAULT_IMPL = impl


def get_default_impl() -> str:
    return _DEFAULT_IMPL


def flash_attention(q, k, v, *, causal=True, window=None, softcap=0.0,
                    q_chunk=1024, k_chunk=1024, q_offset=0, impl=None):
    impl = impl or _DEFAULT_IMPL
    if impl in ("pallas", "interpret"):
        from repro.kernels import flash_attention as fa
        return fa.flash_attention(
            q, k, v, causal=causal, window=window, softcap=softcap,
            interpret=(impl == "interpret"))
    return _xla_attn.chunked_attention(
        q, k, v, causal=causal, window=window, softcap=softcap,
        q_chunk=q_chunk, k_chunk=k_chunk, q_offset=q_offset)


def decode_attention(q, k_cache, v_cache, cache_pos, pos, *, window=None,
                     softcap=0.0, impl=None):
    impl = impl or _DEFAULT_IMPL
    if impl in ("pallas", "interpret"):
        from repro.kernels import decode_attention as da
        return da.decode_attention(
            q, k_cache, v_cache, cache_pos, pos, window=window,
            softcap=softcap, interpret=(impl == "interpret"))
    return _xla_attn.decode_attention(
        q, k_cache, v_cache, cache_pos, pos, window=window, softcap=softcap)


def embedding_bag(table, indices, weights=None, *, combiner="sum", impl=None):
    """Fused embedding gather + pooling. table (R, D); indices (B, n); -> (B, D)."""
    impl = impl or _DEFAULT_IMPL
    if impl in ("pallas", "interpret"):
        from repro.kernels import embedding_bag as eb
        return eb.embedding_bag(table, indices, weights, combiner=combiner,
                                interpret=(impl == "interpret"))
    from repro.kernels import ref
    return ref.embedding_bag_ref(table, indices, weights, combiner=combiner)
