"""Public jit-friendly kernel wrappers with implementation dispatch.

impl:
  "xla"       — scalable pure-JAX (chunked flash) path; default on CPU and for
                 the multi-pod dry-run (memory-safe lowering, same math).
  "pallas"    — Pallas TPU kernels (compiled for TPU targets).
  "interpret" — Pallas kernels in interpret mode (CPU correctness testing).

Set globally via ``set_default_impl`` or per-call with ``impl=``.

Embedding calls are planned by a single ``repro.sharding.policy
.EmbeddingPlan`` value (``plan=``): the frozen, hashable bundle of the
static knobs (``offsets``/``combiner``/``block_b``/``table_hot``/
``layout``/sparse-update flags) that used to accrete as loose kwargs. The
loose kwargs survive as a deprecation shim that builds a plan and warns
once per process.
"""
from __future__ import annotations

import os
import warnings

from repro.models import attention as _xla_attn

_DEFAULT_IMPL = os.environ.get("REPRO_KERNEL_IMPL", "xla")

_LEGACY_KWARGS_WARNED = False


def _shim_plan(offsets, combiner, block_b, table_hot, layout):
    """Build an ``EmbeddingPlan`` from the deprecated loose kwargs.

    Warns once per process — but only when a loose kwarg was actually
    passed; a bare call (all defaults) silently gets the default plan.
    """
    global _LEGACY_KWARGS_WARNED
    legacy = (offsets is not None or combiner is not None
              or block_b is not None or table_hot is not None
              or layout is not None)
    if legacy and not _LEGACY_KWARGS_WARNED:
        _LEGACY_KWARGS_WARNED = True
        warnings.warn(
            "loose embedding kwargs (offsets/combiner/block_b/table_hot/"
            "layout) are deprecated; pass plan=EmbeddingPlan(...) instead",
            DeprecationWarning, stacklevel=3)
    from repro.sharding.policy import EmbeddingPlan
    return EmbeddingPlan(
        offsets=None if offsets is None else tuple(int(o) for o in offsets),
        combiner=combiner or "sum",
        block_b=8 if block_b is None else block_b,
        table_hot=None if table_hot is None else
        tuple(int(k) for k in table_hot),
        layout=layout)


def set_default_impl(impl: str) -> None:
    global _DEFAULT_IMPL
    assert impl in ("xla", "pallas", "interpret"), impl
    _DEFAULT_IMPL = impl


def get_default_impl() -> str:
    return _DEFAULT_IMPL


def flash_attention(q, k, v, *, causal=True, window=None, softcap=0.0,
                    q_chunk=1024, k_chunk=1024, q_offset=0, impl=None):
    impl = impl or _DEFAULT_IMPL
    if impl in ("pallas", "interpret"):
        from repro.kernels import flash_attention as fa
        return fa.flash_attention(
            q, k, v, causal=causal, window=window, softcap=softcap,
            interpret=(impl == "interpret"))
    return _xla_attn.chunked_attention(
        q, k, v, causal=causal, window=window, softcap=softcap,
        q_chunk=q_chunk, k_chunk=k_chunk, q_offset=q_offset)


def decode_attention(q, k_cache, v_cache, cache_pos, pos, *, window=None,
                     softcap=0.0, impl=None):
    impl = impl or _DEFAULT_IMPL
    if impl in ("pallas", "interpret"):
        from repro.kernels import decode_attention as da
        return da.decode_attention(
            q, k_cache, v_cache, cache_pos, pos, window=window,
            softcap=softcap, interpret=(impl == "interpret"))
    return _xla_attn.decode_attention(
        q, k_cache, v_cache, cache_pos, pos, window=window, softcap=softcap)


def fused_embedding_bag(pool, indices, weights=None, *, plan=None, impl=None,
                        offsets=None, combiner=None, block_b=None,
                        table_hot=None, layout=None):
    """Multi-table fused embedding engine (one call for all tables).

    pool (R, D) row-concatenated tables — or, with a padded ``plan.layout``
    (a ``repro.sharding.policy.PaddedLayout``), the (n_ps * max_range, D)
    flattening of the padded physically-sharded store; indices (B, T, H)
    per-table-local rows; weights (B, T, H)? -> (B, T, D).

    ``plan`` (a ``repro.sharding.policy.EmbeddingPlan``) carries every
    static knob: per-table ``offsets``, the ``combiner``, the Pallas
    ``block_b``, the hot-row cache plan ``table_hot`` and the physical
    ``layout``. Plans are frozen and hashable compile-time values: a live
    re-plan (``repro.train.replan``) permutes (and re-pads) the pool rows
    and re-enters here with ``plan.with_replan(...)`` — numerics are
    identical for any plan, so old-plan checkpoints restore bit-exactly
    onto new ones. All impls share a custom VJP whose backward dedupes and
    scatter-adds sparse table gradients.

    The loose ``offsets``/``combiner``/``block_b``/``table_hot``/``layout``
    kwargs are deprecated (warn-once shim building a plan internally).
    """
    impl = impl or _DEFAULT_IMPL
    if plan is None:
        plan = _shim_plan(offsets, combiner, block_b, table_hot, layout)
    else:
        assert (offsets is None and combiner is None and block_b is None
                and table_hot is None and layout is None), \
            "pass the static knobs inside plan=, not alongside it"
    from repro.kernels import fused_embedding as fe
    return fe.fused_embedding_bag(pool, indices, weights, method=impl,
                                  plan=plan)


def sparse_row_grads(pool, indices, g, weights=None, *, plan):
    """Fused sparse backward: bag cotangents → deduped COO row gradients.

    The training-step entry to ``fused_embedding.sparse_row_grads`` (see
    there for the contract): returns ``(rows, vals, dweights)`` where
    scattering ``vals`` at ``rows`` reproduces the dense pool gradient bit
    for bit, and ``(rows, vals)`` feed ``Optimizer.update_rows`` /
    ``fused_row_update`` directly.
    """
    from repro.kernels import fused_embedding as fe
    return fe.sparse_row_grads(pool, indices, g, weights, plan=plan)


def fused_row_update(params, rows, vals, *state, kind, impl=None, block=8,
                     **hyper):
    """Row-wise optimizer update on deduped COO row grads (in place).

    params (R, D) pool; rows (N,) deduplicated store rows (entries >= R are
    inert padding); vals (N, D) summed row grads; ``state`` the optimizer's
    moment pools in the same row space — ``(acc,)`` for ``kind="adagrad"``,
    ``(m, v)`` for ``kind="adam"``. Returns the updated ``(params, *state)``.
    Dispatches to the Pallas fused kernel ("pallas"/"interpret") or the XLA
    gather/scatter fallback ("xla"); hyperparameters ride in ``hyper``
    (see ``repro.kernels.fused_update``).
    """
    impl = impl or _DEFAULT_IMPL
    from repro.kernels import fused_update as fu
    if kind == "adagrad":
        (acc,) = state
        return fu.adagrad_row_update(params, acc, rows, vals, method=impl,
                                     block=block, **hyper)
    if kind == "adam":
        m, v = state
        return fu.adam_row_update(params, m, v, rows, vals, method=impl,
                                  block=block, **hyper)
    raise ValueError(f"unknown row-update kind: {kind!r}")


def embedding_bag(table, indices, weights=None, *, plan=None, combiner=None,
                  impl=None):
    """Fused embedding gather + pooling. table (R, D); indices (B, n); -> (B, D).

    Single-table convenience wrapper over ``fused_embedding_bag`` (T=1), so
    every caller gets the same combiner semantics (weights apply before
    sum/mean/max) and the sparse-gradient VJP. Prefer ``plan=`` (an
    ``EmbeddingPlan``); the loose ``combiner=`` kwarg is the deprecated
    shim form.
    """
    if plan is None:
        plan = _shim_plan(None, combiner, None, None, None)
    else:
        assert combiner is None, \
            "pass the combiner inside plan=, not alongside it"
    out = fused_embedding_bag(
        table, indices[:, None, :],
        None if weights is None else weights[:, None, :],
        plan=plan, impl=impl)
    return out[:, 0]
