"""Fused row-wise optimizer updates: touched rows only, moments in place.

The dense training path materializes a (R, D) gradient for every pooled
embedding store and lets the optimizer touch all ~R rows per step, even
though a batch looks up a tiny skewed subset — the FBGEMM fused-sparse-
adagrad observation. This module is the update half of the fused sparse
backward: it consumes the deduped COO row gradients produced by
``fused_embedding.sparse_row_grads`` (``rows`` (N,) store rows with an
out-of-bounds sentinel tail, ``vals`` (N, D) f32 summed cotangents) and
applies the row-wise adagrad/adam update to exactly those rows of the
parameter pool and its moment pools.

Two implementations share one arithmetic contract:

XLA fallback
    One gather per state array, the row-wise update expression, one scatter
    back. Sentinel rows read a clamped row (harmless) and their writes are
    dropped by JAX's out-of-bounds scatter semantics — padding rows of a
    ``PaddedLayout`` store are never named by ``rows`` at all, so they are
    untouched by construction.

Pallas kernel
    Grid over row blocks; each step receives its (block,) row-id slice in
    SMEM and its (block, D) value slice in VMEM, while the parameter and
    moment pools stay off-chip (``memory_space=ANY``) and are aliased
    input→output (``input_output_aliases``) so the update is in place. Per
    row, the kernel DMAs the parameter/moment rows into (1, D) VMEM
    staging, applies the *same* f32 expressions as the XLA fallback, and
    DMAs the result back — guarded by ``pl.when(row < R)`` so the sentinel
    tail never issues a DMA. Identical expressions keep interpret mode
    within a ULP or two of the fallback (XLA may contract the multiply-adds
    into FMAs differently between the two lowerings).

Row-wise vs dense semantics: adagrad's dense update is an exact no-op on
rows with zero gradient, so the row-wise form is bit-identical to the dense
path. Adam is *lazy*: moments of untouched rows are not decayed (standard
sparse-adam semantics); its reference oracle is the dense gradient with the
row-wise expression applied to the touched rows.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _pad_to_block(rows, vals, num_rows: int, block: int):
    """Pad the COO pair to a whole number of row blocks (sentinel/zero)."""
    n = rows.shape[0]
    n_pad = pl.cdiv(n, block) * block - n
    if n_pad:
        rows = jnp.concatenate(
            [rows, jnp.full((n_pad,), num_rows, rows.dtype)])
        vals = jnp.pad(vals, ((0, n_pad), (0, 0)))
    return rows, vals


# ---------------------------------------------------------------------------
# adagrad
# ---------------------------------------------------------------------------
def _adagrad_xla(params, acc, rows, vals, *, lr: float, eps: float):
    g = vals
    acc_rows = acc[rows] + jnp.square(g)
    upd = (-lr * g / (jnp.sqrt(acc_rows) + eps)).astype(params.dtype)
    return params.at[rows].add(upd), acc.at[rows].set(acc_rows)


def _adagrad_kernel(rows_ref, vals_ref, p_hbm, a_hbm, p_out, a_out,
                    p_stage, a_stage, sem, *, R: int, block: int,
                    lr: float, eps: float):
    del p_hbm, a_hbm   # aliased with p_out/a_out; all access goes via out refs
    for r in range(block):
        row = rows_ref[r]

        @pl.when(row < R)
        def update_row(row=row, r=r):
            fetch_p = pltpu.make_async_copy(
                p_out.at[pl.ds(row, 1), :], p_stage, sem.at[0])
            fetch_a = pltpu.make_async_copy(
                a_out.at[pl.ds(row, 1), :], a_stage, sem.at[1])
            fetch_p.start()
            fetch_a.start()
            fetch_p.wait()
            fetch_a.wait()
            g = pl.load(vals_ref, (pl.ds(r, 1), slice(None)))
            acc_row = a_stage[...] + jnp.square(g)
            upd = (-lr * g / (jnp.sqrt(acc_row) + eps)).astype(p_stage.dtype)
            p_stage[...] = p_stage[...] + upd
            a_stage[...] = acc_row
            store_p = pltpu.make_async_copy(
                p_stage, p_out.at[pl.ds(row, 1), :], sem.at[0])
            store_a = pltpu.make_async_copy(
                a_stage, a_out.at[pl.ds(row, 1), :], sem.at[1])
            store_p.start()
            store_a.start()
            store_p.wait()
            store_a.wait()


def _adagrad_pallas(params, acc, rows, vals, *, lr, eps, block, interpret):
    R, D = params.shape
    rows, vals = _pad_to_block(rows, vals, R, block)
    n_blocks = rows.shape[0] // block
    kernel = functools.partial(
        _adagrad_kernel, R=R, block=block, lr=lr, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,), memory_space=pltpu.SMEM),
            pl.BlockSpec((block, D), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),    # params (aliased out 0)
            pl.BlockSpec(memory_space=pltpu.ANY),    # acc    (aliased out 1)
        ],
        out_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(params.shape, params.dtype),
            jax.ShapeDtypeStruct(acc.shape, acc.dtype),
        ],
        input_output_aliases={2: 0, 3: 1},
        scratch_shapes=[
            pltpu.VMEM((1, D), params.dtype),
            pltpu.VMEM((1, D), acc.dtype),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        interpret=interpret,
    )(rows, vals, params, acc)


def adagrad_row_update(params: jnp.ndarray, acc: jnp.ndarray,
                       rows: jnp.ndarray, vals: jnp.ndarray, *,
                       lr: float, eps: float = 1e-10, method: str = "xla",
                       block: int = 8) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Row-wise adagrad on deduped COO row grads. -> (params, acc).

    Args:
      params: (R, D) parameter pool (flat or flattened padded store).
      acc:    (R, D) f32 second-moment accumulator pool (same row space).
      rows:   (N,) deduplicated store rows; entries ``>= R`` are padding.
      vals:   (N, D) summed row gradients (zero on padding entries).
      lr/eps: adagrad hyperparameters (``train.optim.adagrad`` defaults).
      method: "xla" (gather/scatter fallback), "pallas", or "interpret".
      block:  rows per Pallas grid step.

    Matches the dense adagrad update fed the dense gradient that
    ``SparseRowGrad.to_dense`` reconstructs, up to FMA-contraction ULPs
    (zero-grad rows are exact no-ops either way).
    """
    rows = rows.astype(jnp.int32)
    vals = vals.astype(jnp.float32)
    if method in ("pallas", "interpret"):
        return _adagrad_pallas(params, acc, rows, vals, lr=lr, eps=eps,
                               block=max(1, block),
                               interpret=(method == "interpret"))
    return _adagrad_xla(params, acc, rows, vals, lr=lr, eps=eps)


# ---------------------------------------------------------------------------
# adam (lazy row-wise)
# ---------------------------------------------------------------------------
def _adam_xla(params, m, v, rows, vals, bias, *, lr, b1, b2, eps, wd):
    g = vals
    m_rows = b1 * m[rows] + (1 - b1) * g
    v_rows = b2 * v[rows] + (1 - b2) * jnp.square(g)
    mh = m_rows / bias[0]
    vh = v_rows / bias[1]
    p32 = params[rows].astype(jnp.float32)
    upd = (-lr * (mh / (jnp.sqrt(vh) + eps) + wd * p32)).astype(params.dtype)
    return (params.at[rows].add(upd), m.at[rows].set(m_rows),
            v.at[rows].set(v_rows))


def _adam_kernel(rows_ref, vals_ref, bias_ref, p_hbm, m_hbm, v_hbm,
                 p_out, m_out, v_out, p_stage, m_stage, v_stage, sem, *,
                 R: int, block: int, lr: float, b1: float, b2: float,
                 eps: float, wd: float):
    del p_hbm, m_hbm, v_hbm   # aliased with the out refs
    for r in range(block):
        row = rows_ref[r]

        @pl.when(row < R)
        def update_row(row=row, r=r):
            fetch_p = pltpu.make_async_copy(
                p_out.at[pl.ds(row, 1), :], p_stage, sem.at[0])
            fetch_m = pltpu.make_async_copy(
                m_out.at[pl.ds(row, 1), :], m_stage, sem.at[1])
            fetch_v = pltpu.make_async_copy(
                v_out.at[pl.ds(row, 1), :], v_stage, sem.at[2])
            fetch_p.start()
            fetch_m.start()
            fetch_v.start()
            fetch_p.wait()
            fetch_m.wait()
            fetch_v.wait()
            g = pl.load(vals_ref, (pl.ds(r, 1), slice(None)))
            m_row = b1 * m_stage[...] + (1 - b1) * g
            v_row = b2 * v_stage[...] + (1 - b2) * jnp.square(g)
            mh = m_row / bias_ref[0]
            vh = v_row / bias_ref[1]
            p32 = p_stage[...].astype(jnp.float32)
            upd = (-lr * (mh / (jnp.sqrt(vh) + eps)
                          + wd * p32)).astype(p_stage.dtype)
            p_stage[...] = p_stage[...] + upd
            m_stage[...] = m_row
            v_stage[...] = v_row
            store_p = pltpu.make_async_copy(
                p_stage, p_out.at[pl.ds(row, 1), :], sem.at[0])
            store_m = pltpu.make_async_copy(
                m_stage, m_out.at[pl.ds(row, 1), :], sem.at[1])
            store_v = pltpu.make_async_copy(
                v_stage, v_out.at[pl.ds(row, 1), :], sem.at[2])
            store_p.start()
            store_m.start()
            store_v.start()
            store_p.wait()
            store_m.wait()
            store_v.wait()


def _adam_pallas(params, m, v, rows, vals, bias, *, lr, b1, b2, eps, wd,
                 block, interpret):
    R, D = params.shape
    rows, vals = _pad_to_block(rows, vals, R, block)
    n_blocks = rows.shape[0] // block
    kernel = functools.partial(
        _adam_kernel, R=R, block=block, lr=lr, b1=b1, b2=b2, eps=eps, wd=wd)
    return pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,), memory_space=pltpu.SMEM),
            pl.BlockSpec((block, D), lambda i: (i, 0)),
            # bias-correction denominators: tiny, grid-constant, scalar mem
            pl.BlockSpec((2,), lambda i: (0,), memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.ANY),    # params (aliased out 0)
            pl.BlockSpec(memory_space=pltpu.ANY),    # m      (aliased out 1)
            pl.BlockSpec(memory_space=pltpu.ANY),    # v      (aliased out 2)
        ],
        out_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(params.shape, params.dtype),
            jax.ShapeDtypeStruct(m.shape, m.dtype),
            jax.ShapeDtypeStruct(v.shape, v.dtype),
        ],
        input_output_aliases={3: 0, 4: 1, 5: 2},
        scratch_shapes=[
            pltpu.VMEM((1, D), params.dtype),
            pltpu.VMEM((1, D), m.dtype),
            pltpu.VMEM((1, D), v.dtype),
            pltpu.SemaphoreType.DMA((3,)),
        ],
        interpret=interpret,
    )(rows, vals, bias, params, m, v)


def adam_row_update(params: jnp.ndarray, m: jnp.ndarray, v: jnp.ndarray,
                    rows: jnp.ndarray, vals: jnp.ndarray, *, lr: float,
                    count, b1: float = 0.9, b2: float = 0.999,
                    eps: float = 1e-8, weight_decay: float = 0.0,
                    method: str = "xla", block: int = 8):
    """Lazy row-wise adam on deduped COO row grads. -> (params, m, v).

    Args:
      params:  (R, D) parameter pool.
      m, v:    (R, D) f32 first/second-moment pools (same row space).
      rows:    (N,) deduplicated store rows; entries ``>= R`` are padding.
      vals:    (N, D) summed row gradients.
      lr/b1/b2/eps/weight_decay: adam hyperparameters.
      count:   the step count *after* this step (the dense-side update's
               incremented counter) — bias correction must agree with it.
      method:  "xla", "pallas", or "interpret".
      block:   rows per Pallas grid step.

    Lazy semantics: untouched rows' moments are not decayed (sparse-adam
    convention); weight decay likewise only reaches touched rows.
    """
    rows = rows.astype(jnp.int32)
    vals = vals.astype(jnp.float32)
    tc = jnp.asarray(count, jnp.float32)
    # one shared bias-correction computation feeds both impls bit-identically
    bias = jnp.stack([1 - b1 ** tc, 1 - b2 ** tc])
    kw = dict(lr=lr, b1=b1, b2=b2, eps=eps, wd=weight_decay)
    if method in ("pallas", "interpret"):
        return _adam_pallas(params, m, v, rows, vals, bias,
                            block=max(1, block),
                            interpret=(method == "interpret"), **kw)
    return _adam_xla(params, m, v, rows, vals, bias, **kw)
