"""Pure-jnp oracles for every Pallas kernel (ground truth in tests)."""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.kernels.common import MASK_VALUE, NEG_INF  # noqa: F401  (shared)


def embedding_bag_ref(table, indices, weights=None, *, combiner="sum"):
    """table (R, D); indices (B, n) int; weights (B, n) or None -> (B, D)."""
    gathered = table[indices]                               # (B, n, D)
    if weights is not None:
        gathered = gathered * weights[..., None]
    if combiner == "sum":
        return jnp.sum(gathered, axis=1)
    if combiner == "mean":
        return jnp.mean(gathered, axis=1)
    if combiner == "max":
        return jnp.max(gathered, axis=1)
    raise ValueError(combiner)


def fused_embedding_bag_ref(pool, indices, weights=None, *,
                            offsets: Optional[Sequence[int]] = None,
                            combiner="sum"):
    """Multi-table oracle over the pooled layout: one take, one reduction.

    pool (R, D) row-concatenated tables; indices (B, T, H) per-table-local
    rows (global if ``offsets`` is None); weights (B, T, H)? -> (B, T, D).
    Differentiable via plain autodiff — the ground truth for the fused
    engine's custom VJP.
    """
    B, T, H = indices.shape
    idx = indices.astype(jnp.int32)
    if offsets is not None:
        idx = idx + jnp.asarray(offsets, jnp.int32)[None, :, None]
    gathered = jnp.take(pool, idx.reshape(-1), axis=0).reshape(
        B, T, H, pool.shape[1])
    if weights is not None:
        gathered = gathered * weights[..., None]
    if combiner == "sum":
        return jnp.sum(gathered, axis=2)
    if combiner == "mean":
        return jnp.mean(gathered, axis=2)
    if combiner == "max":
        return jnp.max(gathered, axis=2)
    raise ValueError(combiner)


def attention_ref(q, k, v, *, causal=True, window: Optional[int] = None,
                  softcap: float = 0.0, q_offset: int = 0):
    """Naive quadratic attention. q (B,Sq,Hq,D); k,v (B,Skv,Hkv,D)."""
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, D).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32)) * (D ** -0.5)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    qpos = q_offset + jnp.arange(Sq)
    kpos = jnp.arange(Skv)
    dpos = qpos[:, None] - kpos[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= dpos >= 0
    if window is not None:
        mask &= dpos < window
    s = jnp.where(mask[None, None, None], s, MASK_VALUE)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, Hq, D).astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, cache_pos, pos, *,
                         window: Optional[int] = None, softcap: float = 0.0):
    """q (B,1,Hq,D); caches (B,L,Hkv,D); cache_pos (B,L); pos (B,)."""
    B, L, Hkv, D = k_cache.shape
    Hq = q.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, D).astype(jnp.float32)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache.astype(jnp.float32)) * (D ** -0.5)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    valid = (cache_pos >= 0) & (cache_pos <= pos[:, None])
    if window is not None:
        valid &= cache_pos > (pos[:, None] - window)
    s = jnp.where(valid[:, None, None, :], s, MASK_VALUE)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, 1, Hq, D).astype(q.dtype)
