"""Pallas TPU kernel: block-wise flash attention (causal / sliding-window / GQA).

VMEM tiling: q block (block_q, head_dim), k/v blocks (block_k, head_dim);
running (m, l, acc) scratch in VMEM; the (block_q, block_k) score tile lives
only in registers/VMEM — the full S×S matrix is never materialized in HBM.
Fully-masked k-blocks are skipped with ``pl.when`` (causal upper triangle and
out-of-window bands contribute zero work on TPU).

Layout: kernel operates on (B, H, S, D); the public wrapper transposes from
the model's (B, S, H, D). GQA maps q-head h to kv-head h // group via the
k/v BlockSpec index_map — kv blocks are DMA'd once per group.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import MASK_VALUE as NEG_INF


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, block_q: int, block_k: int, n_k: int,
                  causal: bool, window: Optional[int], softcap: float,
                  kv_len: int):
    qb = pl.program_id(2)
    kb = pl.program_id(3)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qb * block_q
    k_start = kb * block_k

    # --- block-level reachability guard: skip fully-masked tiles -----------
    live = jnp.bool_(True)
    if causal:
        live &= k_start <= q_start + block_q - 1
    if window is not None:
        live &= k_start + block_k - 1 >= q_start - window + 1

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                 # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)                 # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = kpos < kv_len
        if causal:
            mask &= qpos >= kpos
        if window is not None:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(p, v)
        m_scr[...] = m_new

    @pl.when(kb == n_k - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: Optional[int] = None,
                    softcap: float = 0.0, block_q: int = 512,
                    block_k: int = 512, interpret: bool = False) -> jnp.ndarray:
    """q (B, Sq, Hq, D); k, v (B, Skv, Hkv, D) -> (B, Sq, Hq, D)."""
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = D ** -0.5

    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    q_pad = (-Sq) % block_q
    k_pad = (-Skv) % block_k
    qt = jnp.moveaxis(q, 2, 1)                              # (B, H, Sq, D)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    if q_pad:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, q_pad), (0, 0)))
    if k_pad:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, k_pad), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, k_pad), (0, 0)))
    Sq_p, Skv_p = Sq + q_pad, Skv + k_pad
    n_q = Sq_p // block_q
    n_k = Skv_p // block_k

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        n_k=n_k, causal=causal, window=window, softcap=softcap, kv_len=Skv)

    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, qb, kb: (b, h, qb, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, qb, kb: (b, h // G, kb, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, qb, kb: (b, h // G, kb, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, qb, kb: (b, h, qb, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq_p, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    out = out[:, :, :Sq, :]
    return jnp.moveaxis(out, 1, 2)
