"""Pallas TPU kernel: single-token (decode) attention over long KV caches.

Flash-decode adaptation for TPU: the cache's sequence dim is tiled into
VMEM-sized blocks; the grid walks (batch, kv-head, k-block) with running
(m, l, acc) scratch. For GQA, all G query heads of one kv-head are processed
together as a (G, D) × (D, block_k) matmul — MXU-friendly even at batch 1.
Masking is position-driven (absolute positions stored alongside the ring/
linear cache), so the same kernel serves full caches and SWA ring buffers.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import MASK_VALUE as NEG_INF


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, cpos_ref, o_ref,
                   m_scr, l_scr, acc_scr, *,
                   scale: float, block_k: int, n_k: int,
                   window: Optional[int], softcap: float):
    b = pl.program_id(0)
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                     # (G, D)
    k = k_ref[0, 0].astype(jnp.float32)                     # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (G, bk)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap

    pos = pos_ref[b]                                        # scalar current position
    cpos = cpos_ref[0]                                      # (bk,) absolute positions
    valid = (cpos >= 0) & (cpos <= pos)
    if window is not None:
        valid &= cpos > pos - window
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    p = jnp.where(valid[None, :], p, 0.0)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(p, v)
    m_scr[...] = m_new

    @pl.when(kb == n_k - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                     cache_pos: jnp.ndarray, pos: jnp.ndarray, *,
                     window: Optional[int] = None, softcap: float = 0.0,
                     block_k: int = 512, interpret: bool = False) -> jnp.ndarray:
    """q (B,1,Hq,D); caches (B,L,Hkv,D); cache_pos (B,L); pos (B,) -> (B,1,Hq,D)."""
    B, L, Hkv, D = k_cache.shape
    Hq = q.shape[2]
    G = Hq // Hkv
    scale = D ** -0.5

    block_k = min(block_k, L)
    k_pad = (-L) % block_k
    kt = jnp.moveaxis(k_cache, 2, 1)                        # (B, Hkv, L, D)
    vt = jnp.moveaxis(v_cache, 2, 1)
    cp = cache_pos.astype(jnp.int32)
    if k_pad:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, k_pad), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, k_pad), (0, 0)))
        cp = jnp.pad(cp, ((0, 0), (0, k_pad)), constant_values=-1)
    Lp = L + k_pad
    n_k = Lp // block_k
    qg = q.reshape(B, Hkv, G, D)                            # (B, Hkv, G, D)

    kernel = functools.partial(
        _decode_kernel, scale=scale, block_k=block_k, n_k=n_k,
        window=window, softcap=softcap)

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,                          # pos (B,)
            grid=(B, Hkv, n_k),
            in_specs=[
                pl.BlockSpec((1, 1, G, D), lambda b, h, kb, pos: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, block_k, D), lambda b, h, kb, pos: (b, h, kb, 0)),
                pl.BlockSpec((1, 1, block_k, D), lambda b, h, kb, pos: (b, h, kb, 0)),
                pl.BlockSpec((1, block_k), lambda b, h, kb, pos: (b, kb)),
            ],
            out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, kb, pos: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, D), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        interpret=interpret,
    )(pos.astype(jnp.int32), qg, kt, vt, cp)
    return out.reshape(B, 1, Hq, D)
