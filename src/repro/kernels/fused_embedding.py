"""Fused multi-table embedding engine: pipelined DMA + hot-row cache + sparse VJP.

The paper's #1 hot spot is embedding lookups (30–48 % of DLRM iteration time,
§1 Fig 1a). The naive formulation issues one gather/pool per table — for a
Criteo-style model that is 26 kernel launches per step, each with its own grid
setup, and 26 scatter-adds in the backward pass. This module fuses *all*
tables into a single call and pipelines the memory traffic:

Pooled-table layout
    Every table shares the embedding width ``D``, so the ``T`` tables are
    concatenated row-wise into one pool ``(sum(rows_t), D)``. Per-table row
    ranges are addressed by static ``offsets`` (exclusive cumulative sums of
    the per-table row counts). A batch of per-table-local indices
    ``(B, T, H)`` becomes global pool rows by adding ``offsets[t]`` — after
    which the table dimension is just another axis of one big gather.

Padded physical layout (unequal PS shards)
    With ``layout`` (a ``repro.sharding.policy.PaddedLayout``) the engine
    addresses the *padded* pool ``(n_ps * max_range, D)`` — the flattened
    form of the ``(n_ps, max_range, D)`` store whose leading axis GSPMD
    splits equally, placing exactly the balanced range plan on the mesh.
    Lookups keep flowing in as **flat** pooled rows (the canonical id space
    every planner and the hot-row contract speak); the engine translates
    them to padded rows — ``shard * max_range + (row - shard_start)`` — on
    both forward paths and in the backward ``segment_sum``. Padding slots
    are never addressed, so they contribute zero to pooling and receive
    zero gradient, and numerics are bit-identical to the flat layout (same
    rows, same reduce order). See ``docs/EMBEDDING_LAYOUT.md``.

Hot-row cache (skew-aware placement contract)
    Real sparse-feature traffic is power-law skewed: a tiny fraction of rows
    serves most lookups (RecShard / MTrainS). Under frequency-aware placement
    the hot rows of table ``t`` are *packed* into its leading local ids
    ``[0, table_hot[t])`` (see ``repro.sharding.policy.pack_hot_ranges``).
    The engine mirrors those prefixes into a VMEM-resident cache
    ``(sum(table_hot), D)`` and consults it before issuing any HBM DMA: hot
    lookups become direct VMEM loads, only the cold tail pays an HBM round
    trip. On the XLA path the packed prefix *is* the cache — it stays
    hardware-cache-resident by construction, so no extra gather is issued.
    The custom-VJP backward is unchanged either way because global row ids
    are preserved (the cache only re-routes forward reads). The plan is not
    frozen for the job's lifetime: when access skew drifts, the live
    re-planner (``repro.train.replan``) re-packs the pool and recompiles
    with a fresh ``table_hot`` — any plan computes identical numerics.

Forward (Pallas path, double-buffered)
    The grid is ``(ceil(B/block_b), T)``; the batch is padded on the host to
    a whole number of blocks so no grid step ever sees unspecified block
    padding. Each step receives its ``(block_b, 1, H)`` slice of the
    *encoded* index tensor as a tiny SMEM block (hot lookups are encoded as
    ``-(cache_slot+1)``, cold ones as the global pool row). Row staging is
    double-buffered across grid steps — two VMEM staging buffers and two DMA
    semaphores: while step ``i`` drains its buffer and reduces it into a
    ``(block_b, 1, D)`` output block, step ``i``'s body has already issued
    the copies for step ``i+1`` into the other buffer (the next step's index
    slice is delivered through a second, look-ahead SMEM block), so HBM copy
    latency overlaps the reduction instead of serializing with it.

Forward (XLA fallback)
    One ``jnp.take`` over the pool + one reduction over the hot axis — no
    Python per-table loop, so CPU/dry-run paths get one fused HLO gather
    instead of ``T`` of them.

Backward (custom VJP — the paper's sparse-gradient aggregation)
    Differentiating through the gather loop would replay ``T`` scatter-adds
    (and is impossible through the Pallas kernel). Instead ``jax.custom_vjp``
    computes per-lookup row gradients analytically (sum/mean broadcast,
    max via a tie-normalized argmax mask matching ``jax.grad``-of-``jnp.max``
    semantics) and aggregates duplicate rows with a single
    ``jax.ops.segment_sum`` over the flattened global indices — deduplication
    and scatter-add in one fused op, shared by every impl. Cached rows need
    no special casing: their cotangents land on the same global ids.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

COMBINERS = ("sum", "mean", "max")


def table_offsets(table_rows: Sequence[int]) -> Tuple[int, ...]:
    """Exclusive cumulative row offsets for a pooled-table layout.

    Args:
      table_rows: per-table row counts.

    Returns one flat-pool start row per table; table ``t``'s local id ``i``
    is flat pooled row ``offsets[t] + i``.
    """
    offs, acc = [], 0
    for r in table_rows:
        offs.append(acc)
        acc += int(r)
    return tuple(offs)


def cache_slot_offsets(table_hot: Sequence[int]) -> Tuple[int, ...]:
    """Exclusive cumulative cache-slot offsets of the per-table hot prefixes.

    Args:
      table_hot: per-table hot-prefix sizes (``pack_hot_ranges`` output).

    Returns the cache slot where each table's hot rows begin: table ``t``'s
    hot local id ``i < table_hot[t]`` occupies slot ``offsets[t] + i`` of the
    ``(sum(table_hot), D)`` VMEM cache.
    """
    return table_offsets(table_hot)


def hot_row_ids(offsets: Sequence[int], table_hot: Sequence[int]) -> np.ndarray:
    """Flat pool row ids mirrored by the cache (per-table leading ranges).

    Args:
      offsets:   per-table flat-pool start rows (``table_offsets``).
      table_hot: per-table hot-prefix sizes.

    Returns the ``(sum(table_hot),)`` int64 ids in cache-slot order — the
    rows to gather when materializing the cache, under any physical layout.
    """
    parts = [np.arange(o, o + k, dtype=np.int64)
             for o, k in zip(offsets, table_hot) if k > 0]
    if not parts:
        return np.zeros((0,), np.int64)
    return np.concatenate(parts)


# ---------------------------------------------------------------------------
# flat → padded row translation (physically-unequal PS shards)
# ---------------------------------------------------------------------------
def translate_rows(rows: jnp.ndarray, layout) -> jnp.ndarray:
    """Flat pooled rows → rows of the flattened padded pool (traced).

    The jit-side twin of ``PaddedLayout.flat_to_padded``: finds each row's
    shard with a ``searchsorted`` over the static shard starts (rightmost
    match, so empty shards are never selected) and rebases it to
    ``shard * max_range + slot``.

    Args:
      rows:   int array of flat pooled row ids (any shape).
      layout: a ``repro.sharding.policy.PaddedLayout`` (duck-typed: only
              ``shard_starts``, ``max_range`` and ``n_ps`` are read, keeping
              this module free of cross-package imports).

    Returns padded row ids, same shape/dtype as ``rows``.
    """
    starts = jnp.asarray(layout.shard_starts, rows.dtype)
    shard = jnp.clip(jnp.searchsorted(starts, rows, side="right") - 1,
                     0, layout.n_ps - 1)
    return shard * layout.max_range + rows - starts[shard]


def translate_rows_np(rows: np.ndarray, layout) -> np.ndarray:
    """Host-side ``translate_rows`` for static plans (cache row gathers).

    Delegates to ``layout.flat_to_padded`` — one implementation of the
    subtle rightmost-match/empty-shard logic, shared with the traced twin's
    tests, instead of a drifting copy.
    """
    return layout.flat_to_padded(np.asarray(rows, np.int64))


def encode_hot_indices(idx, offsets: Sequence[int],
                       table_hot: Sequence[int]):
    """Route each lookup: hot rows -> ``-(cache_slot+1)``, cold -> flat row.

    Hot rows of table ``t`` are its leading local ids ``[0, table_hot[t])``
    (the frequency-packed placement contract); their cache slots are laid
    out contiguously per table. Encoding always happens in the FLAT id space
    — under a padded physical layout the cold entries are rebased into the
    padded space *after* this (hot detection would be meaningless on padded
    ids, whose shard-local arithmetic destroys table locality).

    Args:
      idx:       (B, T, H) *flat* global index tensor (offsets applied).
      offsets:   per-table flat-pool start rows (``table_offsets``).
      table_hot: per-table hot-prefix sizes.

    Returns ``(encoded, hit)``: ``encoded`` is ``idx`` with hot lookups
    replaced by ``-(cache_slot + 1)``, ``hit`` the boolean hot mask.
    """
    off = jnp.asarray(offsets, jnp.int32)[None, :, None]
    k = jnp.asarray(table_hot, jnp.int32)[None, :, None]
    coff = jnp.asarray(cache_slot_offsets(table_hot), jnp.int32)[None, :, None]
    local = idx - off
    hit = local < k
    slot = coff + local
    return jnp.where(hit, -slot - 1, idx), hit


# ---------------------------------------------------------------------------
# Pallas kernel: (ceil(B/block_b), T) grid, double-buffered row staging
# ---------------------------------------------------------------------------
def _fill_stage(stage_ref, sem, blk_ref, pool_ref, cache_ref, *,
                R: int, K: int, H: int, block_b: int):
    """Stage one block's rows: hot slots from VMEM cache, cold rows via DMA."""
    for r in range(block_b):
        for j in range(H):
            v = blk_ref[r, 0, j]
            if cache_ref is None:
                pltpu.make_async_copy(
                    pool_ref.at[pl.ds(jnp.clip(v, 0, R - 1), 1), :],
                    stage_ref.at[r].at[pl.ds(j, 1), :],
                    sem,
                ).start()
            else:
                @pl.when(v >= 0)
                def start_cold(v=v, r=r, j=j):
                    pltpu.make_async_copy(
                        pool_ref.at[pl.ds(jnp.clip(v, 0, R - 1), 1), :],
                        stage_ref.at[r].at[pl.ds(j, 1), :],
                        sem,
                    ).start()

                @pl.when(v < 0)
                def copy_hot(v=v, r=r, j=j):
                    slot = jnp.clip(-v - 1, 0, K - 1)
                    row = pl.load(cache_ref, (pl.ds(slot, 1), slice(None)))
                    pl.store(stage_ref,
                             (pl.ds(r, 1), pl.ds(j, 1), slice(None)),
                             row[None])


def _drain_stage(stage_ref, sem, blk_ref, pool_ref, cached: bool, *,
                 R: int, H: int, block_b: int):
    """Wait for exactly the DMAs `_fill_stage` issued for this block."""
    for r in range(block_b):
        for j in range(H):
            v = blk_ref[r, 0, j]
            cp = pltpu.make_async_copy(
                pool_ref.at[pl.ds(jnp.clip(v, 0, R - 1), 1), :],
                stage_ref.at[r].at[pl.ds(j, 1), :],
                sem,
            )
            if cached:
                @pl.when(v >= 0)
                def wait_cold(cp=cp):
                    cp.wait()
            else:
                cp.wait()


def _fused_kernel(idx_ref, nxt_ref, pool_ref, *refs,
                  R: int, K: int, H: int, block_b: int, combiner: str,
                  weighted: bool, cached: bool):
    # refs = (cache_ref?, w_ref?, out_ref, stage_a, stage_b, sem)
    i = 0
    cache_ref = refs[i] if cached else None
    i += int(cached)
    w_ref = refs[i] if weighted else None
    i += int(weighted)
    out_ref, stage_a, stage_b, sem = refs[i], refs[i + 1], refs[i + 2], refs[i + 3]

    step = pl.program_id(0) * pl.num_programs(1) + pl.program_id(1)
    nsteps = pl.num_programs(0) * pl.num_programs(1)
    parity = jax.lax.rem(step, 2)
    fill_kw = dict(R=R, K=K, H=H, block_b=block_b)
    drain_kw = dict(R=R, H=H, block_b=block_b)

    # warm-up: the very first step stages its own rows
    @pl.when(step == 0)
    def warmup():
        _fill_stage(stage_a, sem.at[0], idx_ref, pool_ref, cache_ref, **fill_kw)

    # prefetch step i+1's rows into the other buffer while this step reduces
    @pl.when((step + 1 < nsteps) & (parity == 0))
    def prefetch_into_b():
        _fill_stage(stage_b, sem.at[1], nxt_ref, pool_ref, cache_ref, **fill_kw)

    @pl.when((step + 1 < nsteps) & (parity == 1))
    def prefetch_into_a():
        _fill_stage(stage_a, sem.at[0], nxt_ref, pool_ref, cache_ref, **fill_kw)

    def reduce_from(stage_ref):
        rows = stage_ref[...].astype(jnp.float32)      # (block_b, H, D)
        if weighted:
            rows = rows * w_ref[:, 0, :][..., None]    # (block_b, H, 1)
        if combiner == "max":
            res = jnp.max(rows, axis=1)
        else:
            res = jnp.sum(rows, axis=1)
            if combiner == "mean":
                res = res / H
        out_ref[...] = res[:, None, :].astype(out_ref.dtype)

    @pl.when(parity == 0)
    def consume_a():
        _drain_stage(stage_a, sem.at[0], idx_ref, pool_ref, cached, **drain_kw)
        reduce_from(stage_a)

    @pl.when(parity == 1)
    def consume_b():
        _drain_stage(stage_b, sem.at[1], idx_ref, pool_ref, cached, **drain_kw)
        reduce_from(stage_b)


def _pallas_forward(pool, enc_idx, weights, cache, *, B, T, H, combiner,
                    block_b, interpret):
    R, D = pool.shape
    K = 0 if cache is None else cache.shape[0]
    nb = pl.cdiv(B, block_b)
    nsteps = nb * T
    # pad the batch to whole blocks: encoded index 0 is a harmless cold DMA
    # of pool row 0, so no grid step ever sees unspecified block padding
    B_pad = nb * block_b
    enc_idx = enc_idx.reshape(B, T, H)
    if B_pad != B:
        enc_idx = jnp.pad(enc_idx, ((0, B_pad - B), (0, 0), (0, 0)))
        if weights is not None:
            weights = jnp.pad(weights.reshape(B, T, H),
                              ((0, B_pad - B), (0, 0), (0, 0)))

    def nxt_map(bb, t):
        # look-ahead SMEM block: the (bb, t) step receives step bb*T+t+1's
        # index slice so it can prefetch into the idle staging buffer
        lin = jnp.minimum(bb * T + t + 1, nsteps - 1)
        return (lin // T, jax.lax.rem(lin, T), 0)

    kernel = functools.partial(
        _fused_kernel, R=R, K=max(K, 1), H=H, block_b=block_b,
        combiner=combiner, weighted=weights is not None, cached=K > 0)
    in_specs = [
        # per-step (block_b, 1, H) encoded-index slices staged to SMEM — the
        # full index tensor never has to fit on-chip
        pl.BlockSpec((block_b, 1, H), lambda bb, t: (bb, t, 0),
                     memory_space=pltpu.SMEM),
        pl.BlockSpec((block_b, 1, H), nxt_map, memory_space=pltpu.SMEM),
        pl.BlockSpec(memory_space=pltpu.ANY),        # pool (manual DMA)
    ]
    args = [enc_idx, enc_idx, pool]
    if K > 0:
        # constant index map -> fetched once, VMEM-resident across the grid
        in_specs.append(pl.BlockSpec((K, D), lambda bb, t: (0, 0)))
        args.append(cache)
    if weights is not None:
        in_specs.append(
            pl.BlockSpec((block_b, 1, H), lambda bb, t: (bb, t, 0)))
        args.append(weights.reshape(B_pad, T, H))
    out = pl.pallas_call(
        kernel,
        grid=(nb, T),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_b, 1, D), lambda bb, t: (bb, t, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_b, H, D), pool.dtype),
            pltpu.VMEM((block_b, H, D), pool.dtype),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        out_shape=jax.ShapeDtypeStruct((B_pad, T, D), pool.dtype),
        interpret=interpret,
    )(*args)
    return out[:B] if B_pad != B else out


# ---------------------------------------------------------------------------
# XLA fallback: one take + one reduction (no per-table Python loop)
# ---------------------------------------------------------------------------
def _xla_forward(pool, flat_idx, weights, *, B, T, H, combiner):
    D = pool.shape[1]
    rows = jnp.take(pool, flat_idx, axis=0).reshape(B, T, H, D)
    if weights is not None:
        rows = rows * weights.reshape(B, T, H)[..., None]
    if combiner == "sum":
        out = jnp.sum(rows, axis=2)
    elif combiner == "mean":
        out = jnp.mean(rows, axis=2)
    else:
        out = jnp.max(rows, axis=2)
    return out.astype(pool.dtype)   # weights are f32; match the Pallas path


# ---------------------------------------------------------------------------
# sparse-gradient aggregation: the dedupe+segment step both backward paths share
# ---------------------------------------------------------------------------
def dedupe_rows(store_idx: jnp.ndarray, g_rows: jnp.ndarray,
                num_rows: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Deduplicate row cotangents: (N,) rows + (N, D) grads → COO row grads.

    Duplicate store rows (the same id looked up twice in a batch — or twice
    inside one bag) are segment-reduced into a single entry, accumulating in
    a deterministic order (stable sort preserves the original order of equal
    rows). Output keeps the static input length: entry ``j`` of the result
    is the ``j``-th *distinct* row with its summed gradient; the tail is
    padded with the sentinel row ``num_rows`` and zero values. The sentinel
    is out of bounds on purpose — JAX scatter drops out-of-bounds updates,
    so the tail is inert for both the dense scatter-add and the fused
    row-wise optimizer update.

    Args:
      store_idx: (N,) int store rows (flat or padded space — whichever space
                 the pool being updated lives in).
      g_rows:    (N, D) per-lookup row cotangents.
      num_rows:  static row count of the store (the sentinel value).

    Returns ``(rows, vals)``: (N,) int rows (deduped + sentinel tail),
    (N, D) summed values (zero tail).
    """
    n = store_idx.shape[0]
    order = jnp.argsort(store_idx, stable=True)
    sorted_rows = store_idx[order]
    first = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_rows[1:] != sorted_rows[:-1]])
    seg = jnp.cumsum(first) - 1                    # dense segment id per entry
    vals = jax.ops.segment_sum(g_rows[order], seg, num_segments=n)
    rows = jnp.full((n,), num_rows, sorted_rows.dtype).at[seg].set(sorted_rows)
    return rows, vals


def _row_cotangents(pool, store_idx, w, g, *, combiner: str, B: int, T: int,
                    H: int):
    """Per-lookup row cotangents for one pooled bag output cotangent ``g``.

    Args:
      pool:      (R, D) store (only read for max ties and weighted ``dw``).
      store_idx: (B*T*H,) store rows of every lookup.
      w:         optional (B, T, H) f32 per-lookup weights.
      g:         (B, T, D) f32 output cotangent.

    Returns ``(g_rows, dw)``: (B, T, H, D) f32 cotangent per looked-up row,
    and the (B, T, H) weight cotangent (None when unweighted).
    """
    D = pool.shape[1]
    if combiner == "max":
        rows = jnp.take(pool, store_idx, axis=0).reshape(B, T, H, D)
        rows = rows.astype(jnp.float32)
        v = rows if w is None else rows * w[..., None]
        m = jnp.max(v, axis=2)                             # (B, T, D)
        # jax.grad(jnp.max) splits the cotangent evenly among tied argmaxes;
        # the normalized indicator reproduces that exactly (duplicate indices
        # inside one bag are the common tie source).
        tie = (v == m[:, :, None, :]).astype(jnp.float32)
        tie = tie / jnp.sum(tie, axis=2, keepdims=True)
        g_v = g[:, :, None, :] * tie                       # d loss / d v
        dw = None if w is None else jnp.sum(g_v * rows, axis=-1)
        g_rows = g_v if w is None else g_v * w[..., None]
        return g_rows, dw
    g_v = jnp.broadcast_to(g[:, :, None, :], (B, T, H, D))
    if combiner == "mean":
        g_v = g_v / H
    if w is None:
        return g_v, None
    rows = jnp.take(pool, store_idx, axis=0).reshape(B, T, H, D)
    dw = jnp.sum(g_v * rows.astype(jnp.float32), axis=-1)
    return g_v * w[..., None], dw


def sparse_row_grads(pool: jnp.ndarray, indices: jnp.ndarray, g: jnp.ndarray,
                     weights: Optional[jnp.ndarray] = None, *, plan):
    """Fused sparse backward: bag cotangents → deduped COO row gradients.

    The sparse twin of the custom VJP's pool gradient: instead of
    materializing the dense (R, D) scatter, it stops at the deduped
    (rows, vals) pair — exactly what ``Optimizer.update_rows`` (the fused
    row-wise optimizer update) consumes. Scattering ``vals`` at ``rows``
    into zeros reproduces the dense gradient bit for bit (same dedupe, same
    accumulation order).

    Args:
      pool:    (R, D) store (flat, or the flattened padded pool under
               ``plan.layout``).
      indices: (B, T, H) per-table-local (or global flat) lookup rows.
      g:       (B, T, D) cotangent of the fused bag output.
      weights: optional (B, T, H) per-lookup scalars.
      plan:    the ``EmbeddingPlan`` the forward ran under (duck-typed:
               ``offsets``, ``combiner``, ``layout`` are read).

    Returns ``(rows, vals, dweights)``: (B*T*H,) deduped store rows with
    sentinel tail, (B*T*H, D) f32 summed row grads, and the weights
    cotangent (None when unweighted).
    """
    B, T, H = indices.shape
    R = pool.shape[0]
    idx = indices.astype(jnp.int32)
    if plan.offsets is not None:
        idx = idx + jnp.asarray(plan.offsets, jnp.int32)[None, :, None]
    flat_idx = idx.reshape(-1)
    store_idx = flat_idx if plan.layout is None else \
        translate_rows(flat_idx, plan.layout)
    w = None if weights is None else \
        weights.astype(jnp.float32).reshape(B, T, H)
    g_rows, dw = _row_cotangents(pool, store_idx, w, g.astype(jnp.float32),
                                 combiner=plan.combiner, B=B, T=T, H=H)
    rows, vals = dedupe_rows(store_idx, g_rows.reshape(B * T * H, -1), R)
    dweights = None if dw is None else dw.reshape(weights.shape).astype(
        weights.dtype)
    return rows, vals, dweights


# ---------------------------------------------------------------------------
# custom VJP: forward dispatches impls, backward is dedupe + one scatter-add
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _fused(pool, flat_idx, weights, meta):
    combiner, B, T, H, method, block_b, hot, layout = meta
    if method in ("pallas", "interpret"):
        if hot is not None:
            offsets, table_hot = hot
            # the cache is gathered from `pool` *inside* the VJP-wrapped
            # forward, so gradients through cached rows flow to the pool
            # exactly like uncached ones (row ids are preserved). Flat
            # layout: the hot prefixes are contiguous, one slice per table.
            # Padded layout: a table's prefix may straddle a shard boundary,
            # so gather the statically-translated row ids instead.
            if layout is None:
                cache = jnp.concatenate([
                    jax.lax.slice_in_dim(pool, o, o + k)
                    for o, k in zip(offsets, table_hot) if k > 0])
            else:
                ids = translate_rows_np(hot_row_ids(offsets, table_hot),
                                        layout)
                cache = jnp.take(pool, jnp.asarray(ids), axis=0)
            # hot detection speaks FLAT local ids (the placement contract);
            # encode first, then rebase only the cold (non-negative) entries
            # into the padded space
            enc, _ = encode_hot_indices(flat_idx.reshape(B, T, H),
                                        offsets, table_hot)
            if layout is not None:
                enc = jnp.where(enc < 0, enc,
                                translate_rows(jnp.maximum(enc, 0), layout))
        else:
            cache = None
            enc = flat_idx.reshape(B, T, H)
            if layout is not None:
                enc = translate_rows(enc, layout)
        return _pallas_forward(pool, enc, weights, cache, B=B, T=T, H=H,
                               combiner=combiner, block_b=block_b,
                               interpret=(method == "interpret"))
    # XLA path: under frequency-packed placement the hot prefixes are already
    # contiguous in the pool and stay hardware-cache-resident; a separate
    # cache gather would only add traffic, so the plain fused take IS the
    # cached path here (bit-identical by construction).
    idx = flat_idx if layout is None else translate_rows(flat_idx, layout)
    return _xla_forward(pool, idx, weights, B=B, T=T, H=H,
                        combiner=combiner)


def _fused_fwd(pool, flat_idx, weights, meta):
    return _fused(pool, flat_idx, weights, meta), (pool, flat_idx, weights)


def _fused_bwd(meta, res, g):
    combiner, B, T, H, method, block_b, hot, layout = meta
    pool, flat_idx, weights = res
    R, D = pool.shape
    # gradients deposit into the physical store's row space: flat rows when
    # the pool is unpadded, padded rows under a layout (whose padding slots
    # are never addressed, so they receive exactly zero)
    store_idx = flat_idx if layout is None else translate_rows(flat_idx, layout)
    w = None if weights is None else weights.reshape(B, T, H)
    g_rows, dw = _row_cotangents(pool, store_idx, w, g.astype(jnp.float32),
                                 combiner=combiner, B=B, T=T, H=H)

    # Sparse-gradient aggregation through the explicit dedupe+segment step
    # shared with ``sparse_row_grads``: one scatter of the deduped values
    # reproduces the old per-index segment_sum (and makes the dense path the
    # bit-exact oracle for the fused row-wise update, which consumes the
    # same (rows, vals) pair).
    rows, vals = dedupe_rows(store_idx, g_rows.reshape(B * T * H, D), R)
    dpool = jnp.zeros((R, D), jnp.float32).at[rows].add(vals)
    dweights = None if dw is None else dw.reshape(weights.shape).astype(
        weights.dtype)
    return dpool.astype(pool.dtype), None, dweights


_fused.defvjp(_fused_fwd, _fused_bwd)


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------
def fused_embedding_bag(pool: jnp.ndarray, indices: jnp.ndarray,
                        weights: Optional[jnp.ndarray] = None, *,
                        offsets: Optional[Sequence[int]] = None,
                        combiner: str = "sum", method: str = "xla",
                        block_b: int = 8,
                        table_hot: Optional[Sequence[int]] = None,
                        layout=None, plan=None) -> jnp.ndarray:
    """Pool per-table embedding bags for all tables in one fused call.

    Args:
      pool:      row store for every table. Flat layout (``layout=None``):
                 the (R, D) row-concatenation of all tables, R =
                 ``sum(table_rows)``. Padded layout: the
                 (n_ps * max_range, D) flattening of the physically-sharded
                 ``(n_ps, max_range, D)`` store (padding rows zero).
      indices:   (B, T, H) per-table-local (or, with ``offsets=None``, global
                 flat-pool) int rows; T tables, H lookups ("hot" axis) per
                 bag. Always expressed in the FLAT id space — the engine
                 translates into the padded space itself.
      weights:   optional (B, T, H) per-lookup scalars, applied before the
                 combiner (so weighted mean/max match the unfused oracle).
      offsets:   static per-table flat-pool row offsets; ``None`` means
                 indices are already global flat-pool rows.
      combiner:  "sum" | "mean" | "max".
      method:    "xla" (one take + reduce), "pallas", or "interpret".
      block_b:   batch rows per Pallas grid step.
      table_hot: optional per-table counts of frequency-packed hot rows — the
                 leading ``table_hot[t]`` local rows of table ``t`` are served
                 from the VMEM-resident hot-row cache on the Pallas path
                 instead of an HBM DMA. Requires ``offsets`` when ``T > 1``.
                 Numerics are identical with or without it.
      layout:    optional ``repro.sharding.policy.PaddedLayout`` describing
                 the padded physical placement of ``pool``. Hashable and
                 jit-static (rides in the custom-VJP meta): changing the
                 physical layout recompiles, as a live re-plan requires.
                 Numerics are bit-identical to the flat layout.
      plan:      optional ``repro.sharding.policy.EmbeddingPlan`` supplying
                 ``offsets``/``combiner``/``block_b``/``table_hot``/``layout``
                 in one hashable value (overrides the loose kwargs; the
                 preferred form — see ``kernels/ops.py``).

    Returns (B, T, D); gradients flow to ``pool`` (sparse scatter-add of
    the deduped row cotangents, into padded rows under ``layout``) and
    ``weights``.
    """
    if plan is not None:
        offsets, combiner, block_b = plan.offsets, plan.combiner, plan.block_b
        table_hot, layout = plan.table_hot, plan.layout
    assert combiner in COMBINERS, combiner
    assert indices.ndim == 3, f"indices must be (B, T, H), got {indices.shape}"
    B, T, H = indices.shape
    if layout is not None:
        assert pool.shape[0] == layout.padded_rows, \
            (pool.shape, layout.padded_rows)
    idx = indices.astype(jnp.int32)
    if offsets is not None:
        off = jnp.asarray(offsets, jnp.int32)
        assert off.shape == (T,), (off.shape, T)
        idx = idx + off[None, :, None]
    hot = None
    if table_hot is not None:
        table_hot = tuple(int(k) for k in table_hot)
        assert len(table_hot) == T, (len(table_hot), T)
        if sum(table_hot) > 0:
            offs = tuple(int(o) for o in offsets) if offsets is not None \
                else (0,) * T
            assert offsets is not None or T == 1, \
                "table_hot with T > 1 requires offsets"
            hot = (offs, table_hot)
    flat_idx = idx.reshape(-1)
    w = None if weights is None else weights.astype(jnp.float32)
    meta = (combiner, B, T, H, method, max(1, min(block_b, B)), hot, layout)
    return _fused(pool, flat_idx, w, meta)
