"""Fused multi-table embedding engine: one kernel + sparse-gradient VJP.

The paper's #1 hot spot is embedding lookups (30–48 % of DLRM iteration time,
§1 Fig 1a). The naive formulation issues one gather/pool per table — for a
Criteo-style model that is 26 kernel launches per step, each with its own grid
setup, and 26 scatter-adds in the backward pass. This module fuses *all*
tables into a single call at three levels:

Pooled-table layout
    Every table shares the embedding width ``D``, so the ``T`` tables are
    concatenated row-wise into one pool ``(sum(rows_t), D)``. Per-table row
    ranges are addressed by static ``offsets`` (exclusive cumulative sums of
    the per-table row counts). A batch of per-table-local indices
    ``(B, T, H)`` becomes global pool rows by adding ``offsets[t]`` — after
    which the table dimension is just another axis of one big gather.

Forward (Pallas path)
    The grid is ``(ceil(B/block_b), T)``. Each step receives its
    ``(block_b, 1, H)`` slice of the offset-adjusted index tensor as a tiny
    SMEM block (staged per step — the whole index tensor never has to fit in
    SMEM, which matters at Criteo scale), DMAs the ``block_b * H`` rows it
    names from the HBM pool into a VMEM staging buffer (async copies issued
    back-to-back, then drained), and reduces them vectorized into a
    ``(block_b, 1, D)`` output block. One kernel launch serves every table,
    every combiner (sum/mean/max), weighted or not.

Forward (XLA fallback)
    One ``jnp.take`` over the pool + one reduction over the hot axis — no
    Python per-table loop, so CPU/dry-run paths get one fused HLO gather
    instead of ``T`` of them.

Backward (custom VJP — the paper's sparse-gradient aggregation)
    Differentiating through the gather loop would replay ``T`` scatter-adds
    (and is impossible through the Pallas kernel). Instead ``jax.custom_vjp``
    computes per-lookup row gradients analytically (sum/mean broadcast,
    max via a tie-normalized argmax mask matching ``jax.grad``-of-``jnp.max``
    semantics) and aggregates duplicate rows with a single
    ``jax.ops.segment_sum`` over the flattened global indices — deduplication
    and scatter-add in one fused op, shared by every impl.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

COMBINERS = ("sum", "mean", "max")


def table_offsets(table_rows: Sequence[int]) -> Tuple[int, ...]:
    """Exclusive cumulative row offsets for a pooled-table layout."""
    offs, acc = [], 0
    for r in table_rows:
        offs.append(acc)
        acc += int(r)
    return tuple(offs)


# ---------------------------------------------------------------------------
# Pallas kernel: (ceil(B/block_b), T) grid, block_b*H rows DMA'd per step
# ---------------------------------------------------------------------------
def _fused_kernel(idx_ref, pool_ref, *refs,
                  R: int, H: int, block_b: int, combiner: str,
                  weighted: bool):
    # refs = (w_ref?, out_ref, stage_ref, sem); w_ref present iff weighted
    if weighted:
        w_ref, out_ref, stage_ref, sem = refs
    else:
        out_ref, stage_ref, sem = refs

    copies = []
    for r in range(block_b):
        for j in range(H):
            # clip guards padded tail-block rows (unspecified block padding)
            # and keeps every DMA source inside the pool
            row = jnp.clip(idx_ref[r, 0, j], 0, R - 1)
            cp = pltpu.make_async_copy(
                pool_ref.at[pl.ds(row, 1), :],
                stage_ref.at[r].at[pl.ds(j, 1), :],
                sem,
            )
            cp.start()
            copies.append(cp)
    for cp in copies:
        cp.wait()

    rows = stage_ref[...].astype(jnp.float32)       # (block_b, H, D)
    if weighted:
        rows = rows * w_ref[:, 0, :][..., None]     # (block_b, H, 1)
    if combiner == "max":
        res = jnp.max(rows, axis=1)
    else:
        res = jnp.sum(rows, axis=1)
        if combiner == "mean":
            res = res / H
    out_ref[...] = res[:, None, :].astype(out_ref.dtype)


def _pallas_forward(pool, flat_idx, weights, *, B, T, H, combiner, block_b,
                    interpret):
    R, D = pool.shape
    nb = pl.cdiv(B, block_b)
    kernel = functools.partial(
        _fused_kernel, R=R, H=H, block_b=block_b, combiner=combiner,
        weighted=weights is not None)
    in_specs = [
        # per-step (block_b, 1, H) index slice staged to SMEM — the full
        # index tensor never has to fit on-chip
        pl.BlockSpec((block_b, 1, H), lambda bb, t: (bb, t, 0),
                     memory_space=pltpu.SMEM),
        pl.BlockSpec(memory_space=pltpu.ANY),        # pool (manual DMA)
    ]
    args = (flat_idx.reshape(B, T, H), pool)
    if weights is not None:
        in_specs.append(
            pl.BlockSpec((block_b, 1, H), lambda bb, t: (bb, t, 0)))
        args = args + (weights.reshape(B, T, H),)
    return pl.pallas_call(
        kernel,
        grid=(nb, T),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_b, 1, D), lambda bb, t: (bb, t, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_b, H, D), pool.dtype),
            pltpu.SemaphoreType.DMA,
        ],
        out_shape=jax.ShapeDtypeStruct((B, T, D), pool.dtype),
        interpret=interpret,
    )(*args)


# ---------------------------------------------------------------------------
# XLA fallback: one take + one reduction (no per-table Python loop)
# ---------------------------------------------------------------------------
def _xla_forward(pool, flat_idx, weights, *, B, T, H, combiner):
    D = pool.shape[1]
    rows = jnp.take(pool, flat_idx, axis=0).reshape(B, T, H, D)
    if weights is not None:
        rows = rows * weights.reshape(B, T, H)[..., None]
    if combiner == "sum":
        out = jnp.sum(rows, axis=2)
    elif combiner == "mean":
        out = jnp.mean(rows, axis=2)
    else:
        out = jnp.max(rows, axis=2)
    return out.astype(pool.dtype)   # weights are f32; match the Pallas path


# ---------------------------------------------------------------------------
# custom VJP: forward dispatches impls, backward is one segment_sum
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _fused(pool, flat_idx, weights, meta):
    combiner, B, T, H, method, block_b = meta
    if method in ("pallas", "interpret"):
        return _pallas_forward(pool, flat_idx, weights, B=B, T=T, H=H,
                               combiner=combiner, block_b=block_b,
                               interpret=(method == "interpret"))
    return _xla_forward(pool, flat_idx, weights, B=B, T=T, H=H,
                        combiner=combiner)


def _fused_fwd(pool, flat_idx, weights, meta):
    return _fused(pool, flat_idx, weights, meta), (pool, flat_idx, weights)


def _fused_bwd(meta, res, g):
    combiner, B, T, H, method, block_b = meta
    pool, flat_idx, weights = res
    R, D = pool.shape
    g = g.astype(jnp.float32)                              # (B, T, D)
    w = None if weights is None else weights.reshape(B, T, H)

    if combiner == "max":
        rows = jnp.take(pool, flat_idx, axis=0).reshape(B, T, H, D)
        rows = rows.astype(jnp.float32)
        v = rows if w is None else rows * w[..., None]
        m = jnp.max(v, axis=2)                             # (B, T, D)
        # jax.grad(jnp.max) splits the cotangent evenly among tied argmaxes;
        # the normalized indicator reproduces that exactly (duplicate indices
        # inside one bag are the common tie source).
        tie = (v == m[:, :, None, :]).astype(jnp.float32)
        tie = tie / jnp.sum(tie, axis=2, keepdims=True)
        g_v = g[:, :, None, :] * tie                       # d loss / d v
        dw = None if w is None else jnp.sum(g_v * rows, axis=-1)
        g_rows = g_v if w is None else g_v * w[..., None]
    else:
        g_v = jnp.broadcast_to(g[:, :, None, :], (B, T, H, D))
        if combiner == "mean":
            g_v = g_v / H
        if w is None:
            dw = None
            g_rows = g_v
        else:
            rows = jnp.take(pool, flat_idx, axis=0).reshape(B, T, H, D)
            dw = jnp.sum(g_v * rows.astype(jnp.float32), axis=-1)
            g_rows = g_v * w[..., None]

    # Sparse-gradient aggregation: duplicate global rows are deduplicated and
    # scatter-added in one fused segment reduction over the flat indices.
    dpool = jax.ops.segment_sum(
        g_rows.reshape(B * T * H, D), flat_idx, num_segments=R)
    dweights = None if dw is None else dw.reshape(weights.shape).astype(
        weights.dtype)
    return dpool.astype(pool.dtype), None, dweights


_fused.defvjp(_fused_fwd, _fused_bwd)


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------
def fused_embedding_bag(pool: jnp.ndarray, indices: jnp.ndarray,
                        weights: Optional[jnp.ndarray] = None, *,
                        offsets: Optional[Sequence[int]] = None,
                        combiner: str = "sum", method: str = "xla",
                        block_b: int = 8) -> jnp.ndarray:
    """Pool per-table embedding bags for all tables in one fused call.

    Args:
      pool:     (R, D) row-concatenation of every table.
      indices:  (B, T, H) per-table-local (or, with ``offsets=None``, global)
                int rows; T tables, H lookups ("hot") per bag.
      weights:  optional (B, T, H) per-lookup scalars, applied before the
                combiner (so weighted mean/max match the unfused oracle).
      offsets:  static per-table row offsets into ``pool``; ``None`` means
                indices are already global pool rows.
      combiner: "sum" | "mean" | "max".
      method:   "xla" (one take + reduce), "pallas", or "interpret".
      block_b:  batch rows per Pallas grid step.

    Returns (B, T, D); gradients flow to ``pool`` (sparse scatter-add via
    ``segment_sum``) and ``weights``.
    """
    assert combiner in COMBINERS, combiner
    assert indices.ndim == 3, f"indices must be (B, T, H), got {indices.shape}"
    B, T, H = indices.shape
    idx = indices.astype(jnp.int32)
    if offsets is not None:
        off = jnp.asarray(offsets, jnp.int32)
        assert off.shape == (T,), (off.shape, T)
        idx = idx + off[None, :, None]
    flat_idx = idx.reshape(-1)
    w = None if weights is None else weights.astype(jnp.float32)
    meta = (combiner, B, T, H, method, max(1, min(block_b, B)))
    return _fused(pool, flat_idx, w, meta)
